"""Tendency-monitor subsystem: bitwise history resume, probe pytree
round-trip, drift state machine, one-program dispatch census, and the
embeddings front-end rung."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.api import FastVAT
from repro.checkpoint import ckpt
from repro.configs import smoke_config
from repro.configs.base import ShapeConfig, TrainConfig
from repro.data.tokens import make_batch
from repro.models import model as M
from repro.monitor import (AUX_NAME, COLLAPSE, OK, WARN, DriftConfig,
                           DriftDetector, ProbeSpec, TendencyHistory,
                           TendencyMonitor, TendencyTrace, default_probes,
                           probe_dispatch_stats, worst_state)
from repro.train.loop import train

SHAPE = ShapeConfig("tiny", 32, 4, "train")


def _tc(tmpdir, **kw):
    kw.setdefault("lr", 1e-2)
    kw.setdefault("total_steps", 8)
    kw.setdefault("ckpt_every", 4)
    kw.setdefault("diag_every", 2)
    return TrainConfig(ckpt_dir=str(tmpdir), **kw)


def _saved_history(ckpt_dir):
    arrays = ckpt.load_aux(str(ckpt_dir), AUX_NAME)
    assert arrays is not None, "checkpoint should carry a tendency sidecar"
    return TendencyHistory.from_arrays(arrays)


# ------------------------------------------------- bitwise resume pin ----


def test_history_bitwise_identical_after_interrupt_resume(tmp_path):
    """The acceptance pin: killed+resumed run serializes the same history
    (digest over schema + probes + steps + field bytes) as an
    uninterrupted run."""
    cfg = smoke_config("gemma-2b")
    a, b = tmp_path / "a", tmp_path / "b"
    train(cfg, _tc(a), SHAPE, log=lambda s: None)
    with pytest.raises(KeyboardInterrupt):
        train(cfg, _tc(b), SHAPE, log=lambda s: None, interrupt_at=5)
    train(cfg, _tc(b), SHAPE, log=lambda s: None)
    ha, hb = _saved_history(a), _saved_history(b)
    assert ha.steps == [2, 4, 6, 8]
    assert ha.steps == hb.steps
    assert ha.probes == hb.probes
    assert ha.digest() == hb.digest()


def test_train_loop_surfaces_per_probe_metrics(tmp_path):
    cfg = smoke_config("gemma-2b")
    logs = []
    _, hist = train(cfg, _tc(tmp_path), SHAPE, log=logs.append)
    diag = [h for h in hist if "vat_block_score" in h]
    assert len(diag) == 4                      # steps 2, 4, 6, 8
    row = diag[-1]
    for name in ("embed_table", "acts_final", "grad_embed"):
        for field in ("block_score", "k_est", "hopkins", "state"):
            assert f"tendency/{name}/{field}" in row
    # legacy keys are fed from the embedding probe
    assert row["vat_block_score"] == row["tendency/embed_table/block_score"]
    assert any("[tendency]" in line for line in logs)


# --------------------------------------------------- history schema ----


def test_history_append_only_and_roundtrip():
    h = TendencyHistory(("p", "q"))
    row = {"p": {"hopkins": 0.7, "block_score": 0.5, "k_est": 3.0},
           "q": {"hopkins": 0.6, "block_score": 0.4, "k_est": 2.0}}
    h.append(10, row)
    with pytest.raises(ValueError):            # non-increasing step
        h.append(10, row)
    with pytest.raises(ValueError):            # missing probe
        h.append(20, {"p": row["p"]})
    h.append(20, row)
    back = TendencyHistory.from_arrays(h.to_arrays())
    assert back.steps == [10, 20]
    assert back.digest() == h.digest()
    back.truncate(10)
    assert back.steps == [10]
    assert back.digest() != h.digest()
    bad = h.to_arrays()
    bad["schema"] = np.asarray([99], np.int64)
    with pytest.raises(ValueError):
        TendencyHistory.from_arrays(bad)


# ------------------------------------------------ probe pytree shape ----


def test_trace_dict_is_a_pytree():
    spec = ProbeSpec("p", "embedding", sample=16)
    tr = TendencyTrace(hopkins=jnp.float32(0.8), block_score=jnp.float32(0.5),
                       k_est=jnp.float32(3.0), thumbnail=jnp.zeros((4, 4)),
                       spec=spec)
    traces = {"p": tr}
    leaves, treedef = jax.tree_util.tree_flatten(traces)
    assert len(leaves) == 4                    # 3 scalars + thumbnail
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert back["p"].spec == spec              # static aux survives
    assert float(back["p"].block_score) == 0.5
    doubled = jax.tree_util.tree_map(lambda x: x * 2, traces)
    assert float(doubled["p"].hopkins) == pytest.approx(1.6)


def test_probe_spec_validates_kind():
    with pytest.raises(ValueError):
        ProbeSpec("bad", "activations")


def test_default_probes_router_only_for_moe():
    dense = smoke_config("gemma-2b")
    moe = smoke_config("phi3.5-moe-42b-a6.6b")
    assert [s.kind for s in default_probes(dense)] == \
        ["embedding", "layer", "grad"]
    assert "router" in [s.kind for s in default_probes(moe)]
    # embedding probe first: it feeds the legacy metric keys
    assert default_probes(moe)[0].kind == "embedding"


# ------------------------------------------------ drift state machine ----


def test_drift_collapse_trajectory():
    """Synthetic embedding collapse: score 0.8 -> 0, k 5 -> 1 must pass
    through WARN and end in COLLAPSE; that is the acceptance pin."""
    det = DriftDetector(DriftConfig())
    states = []
    for i in range(20):
        t = i / 19.0
        states.append(det.update(0.8 * (1 - t) ** 2, 5.0 - 4.0 * t, 0.7))
    assert states[-1] == COLLAPSE
    assert WARN in states                      # degradation seen on the way
    assert states[0] == OK                     # warm-up never alerts


def test_drift_healthy_trajectory_stays_ok():
    rng = np.random.default_rng(0)
    det = DriftDetector(DriftConfig())
    states = [det.update(0.75 + 0.03 * rng.standard_normal(), 5.0, 0.8)
              for _ in range(40)]
    assert set(states) == {OK}


def test_drift_warn_on_relative_drop_without_collapse():
    det = DriftDetector(DriftConfig())
    for _ in range(6):
        det.update(0.8, 5.0, 0.8)
    state = OK
    for _ in range(12):
        state = det.update(0.3, 5.0, 0.8)      # big drop, k stays healthy
    assert state == WARN                       # not COLLAPSE: k_est held up


def test_worst_state_ordering():
    assert worst_state([OK, OK]) == OK
    assert worst_state([OK, WARN]) == WARN
    assert worst_state([WARN, COLLAPSE, OK]) == COLLAPSE


# --------------------------------------------- one-program census pin ----


def test_one_diag_step_is_one_program():
    """A diag step compiles exactly one probe program; re-observing with
    the same (cfg, specs) dispatches warm — no new program, no retrace."""
    cfg = smoke_config("gemma-2b")
    # unique sample size => fresh lru_cache entry even across test runs
    specs = default_probes(cfg, sample=37)
    mon = TendencyMonitor(cfg, specs=specs, seed=3)
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, SHAPE).items()}
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    before = probe_dispatch_stats()
    mon.observe(1, params, batch)
    after_first = probe_dispatch_stats()
    assert after_first["programs"] - before["programs"] == 1
    assert after_first["traces"] - before["traces"] == 1

    mon.observe(2, params, batch)
    after_second = probe_dispatch_stats()
    assert after_second == after_first         # warm: nothing moved
    assert len(mon.history) == 2


def test_observe_is_deterministic_in_seed_and_step():
    cfg = smoke_config("gemma-2b")
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, SHAPE).items()}
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    a = TendencyMonitor(cfg, seed=7).observe(5, params, batch)
    b = TendencyMonitor(cfg, seed=7).observe(5, params, batch)
    assert a == b
    c = TendencyMonitor(cfg, seed=8).observe(5, params, batch)
    assert a != c


# ------------------------------------------- embeddings front-end rung ----


def test_fit_embeddings_routes_through_rung_ladder():
    cfg = smoke_config("gemma-2b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, SHAPE)
    fv = FastVAT()
    res = fv.fit_embeddings(params, cfg, batch).result
    n = SHAPE.global_batch * SHAPE.seq_len
    assert res.meta.method == "embed"
    assert res.meta.n == n
    assert res.meta.encoder is not None and res.meta.encoder.startswith(
        cfg.name + "@")
    assert res.order.shape == (n,)
    rep = fv.assess()
    assert rep.method == "embed"
    assert np.isfinite(rep.hopkins)


def test_fit_with_encoder_callable():
    rng = np.random.default_rng(0)
    X = np.vstack([rng.normal(0, 0.3, (60, 6)),
                   rng.normal(4, 0.3, (60, 6))]).astype(np.float32)

    def encoder(x):
        return jnp.tanh(jnp.asarray(x) @ jnp.eye(6, 3))

    fv = FastVAT(seed=0)
    res = fv.fit(X, encoder=encoder).result
    assert res.meta.method == "embed"
    assert "encoder@" in res.meta.encoder      # qualname ends in .encoder
    assert res.order.shape == (120,)
    assert fv.assess().clustered                # two clear blobs survive


def test_embed_method_without_encoder_raises():
    with pytest.raises(ValueError, match="encoder"):
        FastVAT(method="embed").fit(np.zeros((10, 3), np.float32))
