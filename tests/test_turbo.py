"""Turbo Flash-VAT (ISSUE 5): persistent Prim megakernel + sharded engine.

Pins the tentpole contract end to end:

* bitwise ordering identity of the persistent engine (XLA mirror AND
  Pallas megakernel) with ``vat_from_dist`` on the materialized matrix
  and with the PR-4 stepwise engine — per metric, at n in {64, 257,
  1024}, solo + batched + sharded-on-1-device;
* lazy-Prim pruning soundness: prune=True vs prune=False inside the SAME
  kernel are bitwise-equal while the traffic census shrinks;
* the dispatch-count regression gate: the Turbo path compiles to ONE
  loop-free pallas_call, the stepwise path to zero, and the persistent
  path is never silently swapped for the stepwise engine;
* VMEM-seam routing at the state-size guard boundary (+/-1 byte);
* the sharded engine's multi-device bitwise identity (8 fake CPU
  devices, divisible and non-divisible n) via subprocess.
"""
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import core
from repro.api import FastVAT
from repro.kernels import ops as kops
from repro.kernels import prim_persist as kpp
from repro.kernels import ref as kref
from repro.kernels.ref import METRICS
from repro.core.vat import _streamed_seed_pivot


def _points(n, d=5, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))


def _contig_blobs(n, k=4, d=3, seed=1, sep=40.0):
    """Cluster-contiguous layout: same-cluster points occupy adjacent
    indices, so megakernel tiles are spatially coherent and pruning has
    something to prune."""
    rng = np.random.default_rng(seed)
    centers = (sep * rng.normal(size=(k, d))).astype(np.float32)
    lab = np.sort(rng.integers(0, k, size=n))
    X = centers[lab] + rng.normal(size=(n, d)).astype(np.float32)
    return jnp.asarray(X.astype(np.float32))


# ------------------------------------------------ bitwise ordering oracle ----

@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("n", [64, 257, 1024])
def test_persistent_bitwise_vs_materialized_and_stepwise(metric, n):
    """The acceptance contract: persistent == vat_from_dist on the
    materialized matrix == the PR-4 stepwise engine, bit for bit."""
    X = _points(n, d=3 + n % 5, seed=n)
    R = kops.pairwise_dist(X, metric=metric)
    want = core.vat_from_dist(R).order
    turbo = core.vat_matrix_free(X, metric=metric)
    stepw = core.vat_matrix_free(X, metric=metric, turbo=False)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(turbo.order))
    np.testing.assert_array_equal(np.asarray(stepw.order),
                                  np.asarray(turbo.order))
    np.testing.assert_array_equal(np.asarray(stepw.edges),
                                  np.asarray(turbo.edges))


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("n", [64, 257, 1024])
def test_megakernel_matches_mirror(metric, n):
    """The Pallas megakernel (interpret mode, block=64 => multi-tile +
    padding at 257/1024) drives the same ordering as the XLA mirror."""
    X = _points(n, d=6, seed=n + 1)
    a = core.vat_matrix_free(X, metric=metric)
    b = core.vat_matrix_free(X, metric=metric, use_pallas=True, block=64)
    np.testing.assert_array_equal(np.asarray(a.order), np.asarray(b.order))
    # edge VALUES cross a lowering boundary (the kernel's lane-padded dot
    # vs the mirror's unpadded dot) — ulp-close, not bitwise; the bitwise
    # edge contract holds among same-lowering engines (mirror/stepwise/
    # sharded, pinned elsewhere in this file)
    np.testing.assert_allclose(np.asarray(a.edges), np.asarray(b.edges),
                               rtol=1e-5, atol=1e-5)


def test_persistent_batched_matches_solo():
    Xb = jnp.stack([_points(150, d=6, seed=s) for s in range(4)])
    bt = core.vat_matrix_free_batch(Xb)
    bp = core.vat_matrix_free_batch(Xb, use_pallas=True, block=64)
    for i in range(4):
        solo = core.vat_matrix_free(Xb[i])
        np.testing.assert_array_equal(np.asarray(bt.order[i]),
                                      np.asarray(solo.order))
        np.testing.assert_array_equal(np.asarray(bp.order[i]),
                                      np.asarray(solo.order))


@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("n", [64, 257])
def test_sharded_one_device_bitwise(metric, n):
    """Sharded-on-1-device == solo, orderings AND edges, every metric
    (n=257 exercises the internal pad-to-axis-size path trivially)."""
    X = _points(n, d=4, seed=n + 2)
    mesh = jax.make_mesh((1,), ("data",))
    solo = core.vat_matrix_free(X, metric=metric)
    sh = core.vat_matrix_free_sharded(X, mesh, metric=metric)
    np.testing.assert_array_equal(np.asarray(solo.order),
                                  np.asarray(sh.order))
    np.testing.assert_array_equal(np.asarray(solo.edges),
                                  np.asarray(sh.edges))


@pytest.mark.parametrize("metric", ["euclidean", "cosine"])
def test_sharded_pallas_step_matches_solo(metric):
    """The sharded engine's Pallas route: the local frontier state is
    padded once to the step kernel's block (here 64, with nl=201 not a
    multiple — the divisibility seam), and the ordering still matches
    the solo engine."""
    X = _points(201, d=5, seed=31)
    mesh = jax.make_mesh((1,), ("data",))
    solo = core.vat_matrix_free(X, metric=metric)
    sh = core.vat_matrix_free_sharded(X, mesh, metric=metric,
                                      use_pallas=True, block=64)
    np.testing.assert_array_equal(np.asarray(solo.order),
                                  np.asarray(sh.order))


def test_sharded_seed_never_materializes_shard_by_n(monkeypatch):
    """The sharded seed must stream (bs, bs) blocks — never an (n/P, n)
    strip (the compiled-memory contract the docstring promises)."""
    real = kops.pairwise_dist

    def guarded(A, B=None, **kw):
        assert B is not None and A.shape[0] <= 1024 and B.shape[0] <= 1024, \
            (A.shape, None if B is None else B.shape)
        return real(A, B, **kw)

    # distributed.py imports the ops MODULE, so the module attr patch
    # is what its trace sees
    monkeypatch.setattr(kops, "pairwise_dist", guarded)
    X = _points(2_111, d=3, seed=17)               # fresh shape
    mesh = jax.make_mesh((1,), ("data",))
    sh = core.vat_matrix_free_sharded(X, mesh)
    solo = core.vat_matrix_free(X)
    np.testing.assert_array_equal(np.asarray(solo.order),
                                  np.asarray(sh.order))


# --------------------------------------------------- lazy-Prim pruning ----

@pytest.mark.parametrize("metric", ["euclidean", "sqeuclidean", "manhattan"])
def test_pruning_is_bitwise_sound_and_cuts_traffic(metric):
    """prune=True vs prune=False inside the SAME kernel: identical
    orderings/edges (the lazy-fold exactness proof), strictly less tile
    traffic on cluster-contiguous data.  Cosine is excluded by design:
    no triangle inequality => its radius is +inf and pruning degrades to
    the eager schedule."""
    X = _contig_blobs(700)
    aux = kref.metric_aux_ref(X, metric=metric)
    i0 = _streamed_seed_pivot(X, metric=metric)
    o1, e1, s1 = kpp.prim_persist_pallas(X, aux, i0, metric=metric,
                                         block=64, interpret=True)
    o0, e0, s0 = kpp.prim_persist_pallas(X, aux, i0, metric=metric,
                                         block=64, interpret=True,
                                         prune=False)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o0))
    np.testing.assert_array_equal(np.asarray(e1), np.asarray(e0))
    # the eager schedule folds every LIVE tile every step (dead tiles —
    # fully selected — are skipped by both schedules), so it is bounded
    # by the fold-everything count; bound pruning must still cut tile
    # fetches well below the eager schedule on well-separated contiguous
    # clusters
    assert int(s0[0]) <= (700 - 1) * (704 // 64)
    assert int(s1[0]) < int(s0[0]) * 2 // 3, (int(s1[0]), int(s0[0]))


@pytest.mark.parametrize("offset", [100.0, 1000.0])
@pytest.mark.parametrize("metric", ["euclidean", "sqeuclidean", "manhattan"])
def test_pruning_sound_on_uncentered_data(metric, offset):
    """Regression (review finding): the Gram-trick rows the bound is
    compared against carry ABSOLUTE cancellation error ~eps·max‖x‖², so
    on data offset far from the origin a purely relative bound margin
    over-prunes.  The norm-scaled slack must keep prune on/off bitwise
    at any offset."""
    rng = np.random.default_rng(offset == 100.0)
    centers = (5.0 * rng.normal(size=(4, 3))).astype(np.float32)
    lab = np.sort(rng.integers(0, 4, size=500))
    X = jnp.asarray(
        (centers[lab] + rng.normal(size=(500, 3)) + offset).astype(
            np.float32))
    aux = kref.metric_aux_ref(X, metric=metric)
    i0 = _streamed_seed_pivot(X, metric=metric)
    o1, e1, _ = kpp.prim_persist_pallas(X, aux, i0, metric=metric,
                                        block=64, interpret=True)
    o0, e0, _ = kpp.prim_persist_pallas(X, aux, i0, metric=metric,
                                        block=64, interpret=True,
                                        prune=False)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o0))
    np.testing.assert_array_equal(np.asarray(e1), np.asarray(e0))


@pytest.mark.parametrize("offset", [1e3, 1e4, 1e6])
@pytest.mark.parametrize("metric", ["euclidean", "sqeuclidean", "manhattan"])
def test_pruning_exact_under_auto_plan_at_any_offset(metric, offset):
    """ISSUE 10 satellite: where the raw Gram slack would eat the whole
    signal (offsets to 1e6), the auto policy's resolved plan —
    conditioned coordinates + direct-form tiles + the 4-ulp slack —
    keeps prune on/off bitwise inside the megakernel."""
    from repro.numerics import resolve
    # sep=2 keeps the pairwise-gap proxy small enough that κ crosses
    # KAPPA_SAFE already at the 1e3 offset
    X = _contig_blobs(500, k=4, seed=3, sep=2.0)
    Xc, rep = resolve(np.asarray(X) + np.float32(offset), metric=metric)
    assert rep.conditioned and rep.form == "direct"
    Xj = jnp.asarray(Xc)
    aux = kref.metric_aux_ref(Xj, metric=metric)
    i0 = _streamed_seed_pivot(Xj, metric=metric, form=rep.form)
    o1, e1, _ = kpp.prim_persist_pallas(Xj, aux, i0, metric=metric,
                                        form=rep.form, block=64,
                                        interpret=True)
    o0, e0, _ = kpp.prim_persist_pallas(Xj, aux, i0, metric=metric,
                                        form=rep.form, block=64,
                                        interpret=True, prune=False)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o0))
    np.testing.assert_array_equal(np.asarray(e1), np.asarray(e0))


def test_pruned_megakernel_matches_mirror_on_clustered_data():
    """Pruning engaged (clustered contiguous data) still reproduces the
    XLA mirror's ordering bitwise for the triangle metrics.  sep=8 keeps
    clusters far enough to prune (~2x fetch cut) while coordinates stay
    near the origin — at sep=40 the Gram trick's cancellation noise
    (~|x|^2 * eps) exceeds within-cluster frontier gaps and ANY two dot
    lowerings legitimately flip near-ties (see docs/kernels.md)."""
    X = _contig_blobs(500, k=3, seed=7, sep=8.0)
    for metric in ("euclidean", "sqeuclidean", "manhattan"):
        a = core.vat_matrix_free(X, metric=metric)
        b = core.vat_matrix_free(X, metric=metric, use_pallas=True, block=64)
        np.testing.assert_array_equal(np.asarray(a.order),
                                      np.asarray(b.order))


# ---------------------------------------- dispatch census / HBM traffic ----

def test_turbo_compiles_to_one_loop_free_pallas_call():
    """The dispatch-count regression gate.  Turbo + Pallas: exactly one
    pallas_call OUTSIDE any loop (the megakernel; the seed scan's
    pairwise tile legitimately sits inside its fori_loop).  Stepwise:
    every pallas_call is loop-nested — re-dispatched each Prim step."""
    X = _points(257, d=5, seed=3)
    turbo = kops.kernel_dispatch_stats(
        lambda A: core.vat_matrix_free(A, use_pallas=True, block=64), X)
    stepw = kops.kernel_dispatch_stats(
        lambda A: core.vat_matrix_free(A, use_pallas=True, block=64,
                                       turbo=False), X)
    assert turbo["persistent"] == 1, turbo
    assert stepw["persistent"] == 0, stepw
    assert stepw["pallas_calls"] >= 2, stepw   # seed tile + stream step


def test_turbo_never_falls_back_to_stepwise(monkeypatch):
    """The guard fallback is the persistent MIRROR, never the stepwise
    engine — even when the megakernel's VMEM guard rejects the shape."""
    def boom(*a, **k):
        raise AssertionError("turbo path reached the stepwise engine")
    monkeypatch.setattr(kops, "prim_stream_step", boom)
    monkeypatch.setattr(kpp, "PERSIST_VMEM_BUDGET", 0)   # reject everything
    X = _points(193, d=4, seed=5)                        # fresh shape
    order = np.asarray(core.vat_matrix_free(X, use_pallas=True).order)
    assert sorted(order.tolist()) == list(range(193))


def test_turbo_compiled_memory_stays_linear():
    """HBM side of the regression gate: the persistent program's compiled
    temp+output stays far below one (n, n) buffer (and below the n*d
    working set times a small constant)."""
    n = 32_768
    X = jnp.zeros((n, 4), jnp.float32)
    c = jax.jit(lambda A: core.vat_matrix_free(A)).lower(X).compile()
    ma = c.memory_analysis()
    total = ma.temp_size_in_bytes + ma.output_size_in_bytes
    assert total < (n * n * 4) // 8, total
    # seed tile (~4 MiB) + a few O(n) vectors
    assert total < 32 * 1024 * 1024, total


# ----------------------------------------------------- VMEM-seam guard ----

def test_vmem_seam_routing_flips_at_guard(monkeypatch):
    """At guard+1 the megakernel runs; at guard-1 the dispatch falls back
    to the XLA mirror; outputs are bitwise-equal on both sides."""
    n, d, block = 257, 4, 64
    need = kpp.persist_state_bytes(n, d, block=block)
    X = _points(n, d=d, seed=11)
    aux = kref.metric_aux_ref(X)
    i0 = _streamed_seed_pivot(X, metric="euclidean")

    calls = {"pallas": 0, "ref": 0}
    real_pallas, real_ref = kpp.prim_persist_pallas, kref.prim_persist_ref

    def rec_pallas(*a, **k):
        calls["pallas"] += 1
        return real_pallas(*a, **k)

    def rec_ref(*a, **k):
        calls["ref"] += 1
        return real_ref(*a, **k)

    monkeypatch.setattr("repro.kernels.ops.prim_persist_pallas", rec_pallas)
    monkeypatch.setattr("repro.kernels.ops.ref.prim_persist_ref", rec_ref)

    monkeypatch.setattr(kpp, "PERSIST_VMEM_BUDGET", need + 1)
    assert kpp.persist_supported(n, d, block=block)
    above = kops.prim_persist(X, aux, i0, block=block, use_pallas=True)
    assert calls == {"pallas": 1, "ref": 0}

    monkeypatch.setattr(kpp, "PERSIST_VMEM_BUDGET", need - 1)
    assert not kpp.persist_supported(n, d, block=block)
    below = kops.prim_persist(X, aux, i0, block=block, use_pallas=True)
    assert calls == {"pallas": 1, "ref": 1}

    np.testing.assert_array_equal(np.asarray(above[0]), np.asarray(below[0]))
    # edge values cross the kernel/mirror lowering boundary: ulp-close
    np.testing.assert_allclose(np.asarray(above[1]), np.asarray(below[1]),
                               rtol=1e-5, atol=1e-5)


def test_state_bytes_scale_and_real_budget():
    """The guard arithmetic: state is O(n), independent of X's O(n·d)
    footprint beyond one tile, and the ISSUE's n=100k case fits the real
    budget comfortably."""
    small = kpp.persist_state_bytes(1024, 8)
    big = kpp.persist_state_bytes(100_000, 8)
    assert big < small * 200                       # linear-ish, not n*d-ish
    assert kpp.persist_supported(100_000, 8)
    assert not kpp.persist_supported(500_000_000, 8)


# ------------------------------------------------- seed-scan dispatch ----

@pytest.mark.parametrize("metric", ["euclidean", "manhattan"])
def test_seed_scan_pallas_routing_and_equivalence(metric, monkeypatch):
    """ISSUE 5 satellite: the seed scan goes through kernels.ops pairwise
    dispatch, so use_pallas reaches the MXU tile; the selected seed (and
    the whole ordering) matches the XLA route."""
    calls = []
    real = kops.pairwise_dist

    def recording(X, Y=None, **kw):
        calls.append(kw.get("use_pallas", False))
        return real(X, Y, **kw)

    # core.vat imports the ops MODULE (as kops), so patching the module
    # attribute is seen by the seed scan
    monkeypatch.setattr(kops, "pairwise_dist", recording)
    X = _points(201, d=4, seed=13)                 # fresh shape per metric
    a = _streamed_seed_pivot(X, metric=metric)
    assert calls and not any(calls)
    calls.clear()
    b = _streamed_seed_pivot(X, metric=metric, use_pallas=True)
    assert calls and all(calls)
    assert int(a) == int(b)


# ------------------------------------------------------- facade surface ----

def test_facade_turbo_knob_orderings_agree():
    X = np.asarray(_contig_blobs(300, k=3, seed=10))
    auto = FastVAT(method="flashvat", sample_size=32).fit(X)
    off = FastVAT(method="flashvat", sample_size=32, turbo=False).fit(X)
    np.testing.assert_array_equal(auto.order(), off.order())
    assert auto.assess()["k_est"] == 3


def test_registry_auto_threshold_raised():
    from repro.api import MEDIUM_N, select_method
    assert MEDIUM_N == 50_000
    assert select_method(30_000) == "flashvat"
    # past the exact ceiling the approx kNN-MST rung takes over (ISSUE 6)
    assert select_method(MEDIUM_N + 1) == "approx"


# ------------------------------------------------ sharded multi-device ----

SHARD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from repro import core
    rng = np.random.default_rng(1)
    mesh = jax.make_mesh((8,), ("data",))
    for metric in ("euclidean", "sqeuclidean", "manhattan", "cosine"):
        for n in (64, 100):      # 100 % 8 != 0 -> internal padding
            X = jnp.asarray(rng.normal(size=(n, 4)).astype(np.float32))
            solo = core.vat_matrix_free(X, metric=metric)
            sh = core.vat_matrix_free_sharded(X, mesh, metric=metric)
            assert np.array_equal(np.asarray(solo.order), np.asarray(sh.order)), (metric, n)
            assert np.array_equal(np.asarray(solo.edges), np.asarray(sh.edges)), (metric, n)
    # Pallas local step on a real multi-shard mesh: per-shard nl=13 with
    # block=8 exercises the per-shard pad_points seam
    X = jnp.asarray(rng.normal(size=(100, 4)).astype(np.float32))
    sh = core.vat_matrix_free_sharded(X, mesh, use_pallas=True, block=8)
    assert np.array_equal(np.asarray(core.vat_matrix_free(X).order),
                          np.asarray(sh.order)), "pallas sharded order"
    print("SHARD_TURBO_OK")
""")


def test_sharded_multi_device_subprocess():
    # JAX_PLATFORMS=cpu: without it backend init can hang probing for a
    # TPU plugin (same pattern as test_core_extra's dvat test)
    r = subprocess.run([sys.executable, "-c", SHARD_SCRIPT],
                       capture_output=True, text=True, timeout=300,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"})
    assert "SHARD_TURBO_OK" in r.stdout, r.stderr[-2000:]
