"""Flash-VAT: the matrix-free fused Prim engine (ISSUE 4 tentpole).

Pins the whole contract: per-metric *bitwise* ordering agreement with
``vat_from_dist`` on the materialized matrix, Pallas-vs-XLA fused-step
equivalence, batched agreement, the no-(n, n)-intermediate property
(both a compiled memory-analysis bound and a pairwise-dist tripwire,
mirroring ``tests/test_bigvat.py``), the ``use_pallas`` threading from
``vat()``/``vat_batch()`` into ``vat_order``'s masked argmin, and the
n = 100 000 exact-fit-on-CPU acceptance run."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import core
from repro.api import FastVAT
from repro.kernels import ops as kops
from repro.kernels.ref import METRICS


def _points(n, d=5, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))


def _blobs(n, k=3, d=2, seed=0, sep=40.0):
    rng = np.random.default_rng(seed)
    centers = (sep * rng.normal(size=(k, d))).astype(np.float32)
    lab = rng.integers(0, k, size=n)
    X = centers[lab] + rng.normal(size=(n, d)).astype(np.float32)
    return jnp.asarray(X.astype(np.float32)), lab.astype(np.int32)


# ------------------------------------------------ bitwise ordering oracle ----

@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("n", [64, 257, 1024])
def test_matrix_free_ordering_bitwise_identical(metric, n):
    """The acceptance contract: for every metric, the matrix-free order
    equals ``vat_from_dist`` on the materialized matrix bit for bit —
    same Gram-trick rows, same seed rule, same tie-breaking."""
    X = _points(n, d=3 + n % 5, seed=n)
    R = kops.pairwise_dist(X, metric=metric)
    want = core.vat_from_dist(R).order
    got = core.vat_matrix_free(X, metric=metric).order
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


@pytest.mark.parametrize("metric", METRICS)
def test_matrix_free_pallas_step_matches_xla(metric):
    """The fused stepwise Pallas kernel (interpret mode on CPU) drives
    the same ordering as the XLA reference step.  turbo=False pins the
    PR-4 stepwise engine explicitly now that the persistent Turbo engine
    is the default (tests/test_turbo.py owns the Turbo contract)."""
    X = _points(257, d=6, seed=11)
    a = core.vat_matrix_free(X, metric=metric, turbo=False).order
    b = core.vat_matrix_free(X, metric=metric, use_pallas=True,
                             turbo=False, block=64).order
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_matrix_free_edges_are_prim_frontier_minima():
    """edges[t] is the MST edge weight that admitted vertex order[t]:
    the min dissimilarity to the already-visited prefix."""
    X = _points(120, d=4, seed=2)
    R = np.asarray(kops.pairwise_dist(X))
    res = core.vat_matrix_free(X)
    order = np.asarray(res.order)
    edges = np.asarray(res.edges)
    assert edges[0] == 0.0
    for t in range(1, len(order)):
        want = R[order[t], order[:t]].min()
        assert edges[t] == pytest.approx(want, abs=1e-6)


def test_matrix_free_blobs_order_keeps_clusters_contiguous():
    X, lab = _blobs(900, k=4, seed=3)
    order = np.asarray(core.vat_matrix_free(X).order)
    assert sorted(order.tolist()) == list(range(len(lab)))
    runs = 1 + int(np.sum(lab[order][1:] != lab[order][:-1]))
    assert runs == 4


def test_matrix_free_direct_form_bitwise_on_adversarial_data():
    """ISSUE 10 satellite: the matrix-free engine speaks the direct form
    too — on the shared adversarial pool (near-duplicate pairs at offset
    1e4) the resolved plan keeps it bitwise with ``vat_from_dist`` on
    the materialized direct-form matrix."""
    from _numerics_data import adversarial
    from repro.numerics import resolve
    X = adversarial("near_duplicates", n=96)
    for metric in ("euclidean", "manhattan"):
        Xc, rep = resolve(X, metric=metric)
        assert rep.conditioned and rep.form == "direct"
        Xj = jnp.asarray(Xc)
        R = kops.pairwise_dist(Xj, metric=metric, form="direct")
        want = core.vat_from_dist(R).order
        got = core.vat_matrix_free(Xj, metric=metric, form="direct").order
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


# ------------------------------------------------------ batched agreement ----

def test_matrix_free_batch_agrees_with_solo():
    """Stepwise batched engines (XLA vmap + batched slab-of-1 Pallas
    kernel) vs the solo default; turbo batched agreement lives in
    tests/test_turbo.py."""
    Xb = jnp.stack([_points(150, d=6, seed=s) for s in range(4)])
    xla = core.vat_matrix_free_batch(Xb, turbo=False)
    pal = core.vat_matrix_free_batch(Xb, use_pallas=True, turbo=False,
                                     block=64)
    for i in range(4):
        solo = core.vat_matrix_free(Xb[i])
        np.testing.assert_array_equal(np.asarray(xla.order[i]),
                                      np.asarray(solo.order))
        np.testing.assert_array_equal(np.asarray(pal.order[i]),
                                      np.asarray(solo.order))


# ------------------------------------------- no (n, n) intermediate, ever ----

def test_matrix_free_never_materializes_pairwise(monkeypatch):
    """Tripwire mirroring test_bigvat: the engine must never form an
    (n, n)-scale object.  The seed scan legitimately streams bounded
    SQUARE TILES through the pairwise front door (ISSUE 5 satellite:
    ``kernels.ops.pairwise_dist`` so use_pallas reaches the MXU tile),
    so the tripwire admits strict row/column blocks and booms on any
    self-dissimilarity call or full-size operand pair."""
    real = kops.pairwise_dist
    n = 2_333

    def guarded(X, Y=None, **kw):
        if Y is None or (X.shape[0] >= n and Y.shape[0] >= n):
            raise AssertionError("vat_matrix_free materialized a matrix")
        assert X.shape[0] < n and Y.shape[0] < n
        return real(X, Y, **kw)

    def boom(*a, **k):
        raise AssertionError("vat_matrix_free materialized a batch matrix")

    # core.vat imports the ops MODULE, so the module attr patch is seen
    monkeypatch.setattr(kops, "pairwise_dist", guarded)
    monkeypatch.setattr(kops, "pairwise_dist_batch", boom)
    X = _points(n, d=3, seed=4)
    order = np.asarray(core.vat_matrix_free(X).order)
    assert sorted(order.tolist()) == list(range(n))


def test_matrix_free_compiled_memory_is_not_quadratic():
    """Memory-shape assertion on the *compiled* program: XLA's own
    accounting shows temp + output far below one (n, n) f32 buffer."""
    n = 32_768
    X = jnp.zeros((n, 4), jnp.float32)
    c = jax.jit(lambda A: core.vat_matrix_free(A)).lower(X).compile()
    ma = c.memory_analysis()
    nn_bytes = n * n * 4
    assert ma.temp_size_in_bytes + ma.output_size_in_bytes < nn_bytes // 8, (
        ma.temp_size_in_bytes, ma.output_size_in_bytes, nn_bytes)


def test_flashvat_100k_exact_fit_on_cpu():
    """The headline acceptance run: an exact n = 100 000 VAT ordering on
    CPU — a size where the materialized matrix would need 40 GB."""
    n = 100_000
    X, lab = _blobs(n, k=3, d=2, seed=5)
    res = jax.block_until_ready(core.vat_matrix_free(X))
    order = np.asarray(res.order)
    assert sorted(order.tolist()) == list(range(n))
    runs = 1 + int(np.sum(lab[order][1:] != lab[order][:-1]))
    assert runs == 3          # exact ordering keeps true clusters contiguous


# ------------------------------------------------------------ rung surface ----

def test_flashvat_rung_renders_like_bigvat():
    X, lab = _blobs(3_000, k=3, seed=2)
    fv = FastVAT(method="flashvat", sample_size=64).fit(np.asarray(X))
    res = fv.result
    assert sorted(fv.order().tolist()) == list(range(3_000))
    assert np.asarray(res.rstar).shape == (64, 64)
    assert res.ivat_image is not None
    assert int(np.asarray(res.group_sizes).sum()) == 3_000
    assert np.asarray(res.extension_labels).shape == (3_000,)
    img = fv.image(resolution=100)
    assert img.shape == (100, 100)
    rep = fv.assess()
    assert rep["method"] == "flashvat" and rep["k_est"] == 3
    assert rep["clustered"]


def test_flashvat_rejects_precomputed():
    D = np.zeros((32, 32), np.float32)
    with pytest.raises(ValueError, match="precomputed"):
        FastVAT(method="flashvat", metric="precomputed").fit(D)


def test_flashvat_fit_many_matches_solo():
    Xs = np.stack([np.asarray(_blobs(400, seed=s)[0]) for s in (7, 8)])
    fb = FastVAT(method="flashvat", sample_size=32).fit_many(Xs)
    assert fb.image().shape[0] == 2
    for i in range(2):
        solo = FastVAT(method="flashvat", sample_size=32).fit(Xs[i])
        np.testing.assert_array_equal(fb.order()[i], solo.order())
    reps = fb.assess()
    assert [r["batch_index"] for r in reps] == [0, 1]


# ------------------------------- use_pallas threading into vat_order ----

def test_vat_threads_use_pallas_into_argmin(monkeypatch):
    """ISSUE 4 satellite: vat(use_pallas=True) must reach the fused
    ``prim_update`` masked-argmin kernel — it used to stop at the
    distance matrix, leaving the kernel unreachable from the public API."""
    calls = []
    real = kops.masked_argmin

    def recording(vals, mask, **kw):
        calls.append(kw.get("use_pallas", False))
        return real(vals, mask, **kw)

    monkeypatch.setattr(kops, "masked_argmin", recording)
    X = _points(97, d=3, seed=9)       # fresh shape => fresh trace
    core.vat(X, use_pallas=True)
    assert calls and all(calls)


@pytest.mark.parametrize("metric", ["euclidean", "cosine"])
def test_vat_pallas_argmin_ordering_equivalence(metric):
    """Pallas-vs-XLA ordering equivalence through the public vat()."""
    X = _points(130, d=4, seed=10)
    a = core.vat(X, metric=metric)
    b = core.vat(X, metric=metric, use_pallas=True)
    np.testing.assert_array_equal(np.asarray(a.order), np.asarray(b.order))


def test_vat_batch_pallas_argmin_ordering_equivalence():
    Xb = jnp.stack([_points(90, d=3, seed=s) for s in range(3)])
    a = core.vat_batch(Xb)
    b = core.vat_batch(Xb, use_pallas=True)
    np.testing.assert_array_equal(np.asarray(a.order), np.asarray(b.order))


def test_vat_from_dist_pallas_argmin_param():
    R = kops.pairwise_dist(_points(75, d=3, seed=12))
    a = core.vat_from_dist(R)
    b = core.vat_from_dist(R, use_pallas_argmin=True)
    np.testing.assert_array_equal(np.asarray(a.order), np.asarray(b.order))
