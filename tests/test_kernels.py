"""Pallas kernel sweeps (interpret=True on CPU) vs the pure-jnp oracles."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref
from repro.kernels.pairwise_dist import pairwise_dist_pallas
from repro.kernels.prim_update import masked_argmin_pallas


@pytest.mark.parametrize("n,m,d", [
    (8, 8, 1), (17, 9, 3), (64, 64, 4), (100, 37, 10),
    (256, 256, 128), (300, 200, 130), (5, 400, 2),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_shapes_dtypes(n, m, d, dtype):
    rng = np.random.default_rng(n * 1000 + m + d)
    X = jnp.asarray(rng.normal(size=(n, d)), dtype)
    Y = jnp.asarray(rng.normal(size=(m, d)), dtype)
    got = pairwise_dist_pallas(X, Y, interpret=True)
    want = ref.pairwise_dist_ref(X, Y)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("block", [8, 64, 256])
def test_pairwise_block_sizes(block):
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(130, 7)), jnp.float32)
    got = pairwise_dist_pallas(X, block=block, interpret=True)
    want = ref.pairwise_dist_ref(X)
    # near-zero self distances amplify f32 Gram-trick cancellation through
    # the sqrt; 5e-3 absolute is the honest tolerance there
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=5e-3)


def test_pairwise_self_distance_zero_diag():
    rng = np.random.default_rng(1)
    X = jnp.asarray(rng.normal(size=(33, 5)), jnp.float32)
    R = ops.pairwise_dist(X, use_pallas=True)
    assert np.allclose(np.diag(np.asarray(R)), 0.0)
    # symmetry
    np.testing.assert_allclose(np.asarray(R), np.asarray(R).T, atol=1e-5)


@pytest.mark.parametrize("n", [4, 17, 1000, 1024, 2049])
@pytest.mark.parametrize("block", [8, 1024])
def test_masked_argmin_sweep(n, block):
    rng = np.random.default_rng(n)
    vals = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    mask = jnp.asarray(rng.random(n) < 0.5)
    mask = mask.at[0].set(False)  # keep at least one candidate
    gv, gi = masked_argmin_pallas(vals, mask, block=block, interpret=True)
    wv, wi = ref.masked_argmin_ref(vals, mask)
    assert int(gi) == int(wi)
    assert float(gv) == pytest.approx(float(wv))


def test_masked_argmin_tie_breaking():
    vals = jnp.asarray([3.0, 1.0, 1.0, 2.0])
    mask = jnp.zeros(4, bool)
    _, gi = masked_argmin_pallas(vals, mask, block=2, interpret=True)
    _, wi = ref.masked_argmin_ref(vals, mask)
    assert int(gi) == int(wi) == 1  # first-index tie break


def test_ops_dispatch_consistency():
    rng = np.random.default_rng(2)
    X = jnp.asarray(rng.normal(size=(50, 6)), jnp.float32)
    a = ops.pairwise_dist(X, use_pallas=False)
    b = ops.pairwise_dist(X, use_pallas=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_prim_kernel_in_vat_loop():
    """The fused argmin kernel drives Prim end-to-end (interpret mode)."""
    import jax.numpy as jnp
    from repro.core.vat import vat_order
    from repro.kernels import ops as kops
    rng = np.random.default_rng(5)
    X = jnp.asarray(rng.normal(size=(48, 4)), jnp.float32)
    R = kops.pairwise_dist(X)
    a = vat_order(R)
    b = vat_order(R, use_pallas_argmin=True)
    assert np.array_equal(np.asarray(a), np.asarray(b))
