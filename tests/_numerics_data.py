"""Shared adversarial datasets for the numerics-shield tests (ISSUE 10).

One module so ``test_numerics`` / ``test_metrics`` / ``test_flashvat`` /
``test_turbo`` all draw the SAME worst-case geometries the certification
harness sweeps (``repro.numerics.certify.GENERATORS``), with the same
deterministic seeding — any failure against these fixtures reproduces
byte-for-byte under ``python -m repro.numerics.certify``.

``ADVERSARIAL_NAMES`` is the stable tuple tests feed to
``strategies.sampled_from`` (works with the deterministic hypothesis
stub and the real library alike); ``adversarial(name)`` materializes one
dataset.  ``grid_clusters`` builds the exact-arithmetic clustered grid
the shift-invariance pins use: every coordinate is a multiple of 0.125
and n is a power of two, so the f64 mean inside
``repro.numerics.condition.condition_transform`` is EXACT and
``fit(X + c·1)`` must match ``fit(X)`` bitwise for any f32-exact c.
"""
from __future__ import annotations

import zlib

import numpy as np

from repro.numerics.certify import GENERATORS

#: Stable, sorted generator names — the sampled_from pool.
ADVERSARIAL_NAMES = tuple(sorted(GENERATORS))


def adversarial(name: str, n: int = 64, seed: int = 0) -> np.ndarray:
    """One adversarial (n, d) float32 dataset, seeded exactly like
    ``certify.sweep`` so test data and certification cells coincide."""
    gsalt = zlib.crc32(name.encode()) & 0xFFFF
    rng = np.random.default_rng(np.random.SeedSequence([seed, gsalt]))
    return GENERATORS[name](rng, n)


def grid_clusters(n: int = 64, d: int = 4, offset: float = 1000.0,
                  seed: int = 0) -> np.ndarray:
    """Two clusters on the 0.125 grid at a large common offset.

    Exactness budget (what makes the shift-invariance pin BITWISE):

      * coordinates are ``offset + g·0.125`` with integer ``|g| <= 64``
        — exact in f32 up to offsets of 1e6 (ulp there is 0.0625);
      * n is a power of two, so the f64 column mean is an exact
        multiple of ``0.125 / n`` and centering is exact arithmetic;
      * adding an f32-exact ``c`` shifts the mean by exactly ``c``, so
        the centered f64 array — and therefore the conditioned f32
        array every kernel sees — is bitwise identical.

    At the default offset 1000 the condition estimate κ is ~1e5, well
    past ``KAPPA_SAFE``, so the auto policy conditions the BASE fit too
    (both sides of the pin take the same code path).
    """
    assert n > 1 and n & (n - 1) == 0, "n must be a power of two"
    rng = np.random.default_rng(seed)
    g = rng.integers(-16, 17, size=(n, d)).astype(np.float64) * 0.125
    g[n // 2:, 0] += 6.0     # 48 grid steps between the cluster centers
    return np.asarray(g + offset, np.float32)
