"""Sharding rules, mesh builders, input specs, and a reduced-mesh dry-run."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get_config
from repro.data.tokens import input_specs
from repro.models import sharding as SH


@pytest.fixture()
def mesh16():
    # shape-only stand-in mesh: 1 real device but we only test spec logic
    return jax.make_mesh((1, 1), ("data", "model"))


class FakeMesh:
    """Spec-rule testing double with arbitrary axis sizes."""
    def __init__(self, sizes):
        self.axis_names = tuple(sizes)
        import numpy as np
        self.devices = np.empty(tuple(sizes.values()), object)


def test_spec_rules_tp_fsdp():
    mesh = FakeMesh({"data": 16, "model": 16})
    s = SH.spec_for("layers/wq", (32, 4096, 4096), mesh)
    assert s == P(None, "data", "model")
    s = SH.spec_for("layers/wo", (32, 4096, 4096), mesh)
    assert s == P(None, "model", "data")
    s = SH.spec_for("layers/e_up", (32, 16, 4096, 6400), mesh)
    assert s == P(None, "model", "data", None)
    s = SH.spec_for("embed", (32000, 4096), mesh)
    assert s == P("model", "data")


def test_spec_divisibility_fallback():
    mesh = FakeMesh({"data": 16, "model": 16})
    # whisper vocab 51866 is not divisible by 16 -> unsharded vocab dim
    s = SH.spec_for("embed", (51866, 1280), mesh)
    assert s == P(None, "data")
    # odd inner dim entirely unshardable
    s = SH.spec_for("layers/wq", (2, 897, 1283), mesh)
    assert s == P(None, None, None)
    # norms replicated
    s = SH.spec_for("layers/ln1", (32, 4096), mesh)
    assert s == P(None, None)


def test_hint_noop_without_mesh():
    SH.set_mesh(None)
    x = jnp.ones((4, 4))
    assert SH.hint(x, "dp", "model") is x


def test_make_production_mesh_requires_512_devices():
    # on this 1-device process the production mesh must fail loudly,
    # proving dryrun's forced device count is what makes it work
    from repro.launch.mesh import make_production_mesh
    if len(jax.devices()) < 256:
        with pytest.raises(Exception):
            make_production_mesh()


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_input_specs_are_abstract(arch):
    cfg = get_config(arch)
    specs = input_specs(cfg, SHAPES["train_4k"])
    for v in specs.values():
        assert isinstance(v, jax.ShapeDtypeStruct)


DRYRUN_SMALL = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from repro.configs import smoke_config
    from repro.configs.base import ShapeConfig, TrainConfig
    from repro.data.tokens import input_specs
    from repro.launch.shardspecs import batch_shardings, state_shardings
    from repro.models import sharding
    from repro.train import steps as S

    cfg = smoke_config("phi3.5-moe-42b-a6.6b").replace(
        d_model=64, n_heads=4, n_kv_heads=4, head_dim=16)
    shape = ShapeConfig("t", 64, 8, "train")
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    sharding.set_mesh(mesh)
    tc = TrainConfig()
    specs = input_specs(cfg, shape)
    state_shape = jax.eval_shape(
        lambda: S.init_state(cfg, tc, jax.random.PRNGKey(0), jnp.float32))
    fn = jax.jit(S.build_train_step(cfg, tc),
                 in_shardings=(state_shardings(state_shape, mesh),
                               batch_shardings(cfg, mesh, specs)),
                 donate_argnums=(0,))
    with mesh:
        compiled = fn.lower(state_shape, specs).compile()
    txt = compiled.as_text()
    assert any(k in txt for k in ("all-reduce", "all-gather")), "no collectives?"
    ca = compiled.cost_analysis()   # dict (jax >= 0.5) or [dict] (0.4.x)
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    print("SMALL_DRYRUN_OK", ca.get("flops"))
""")


def test_small_mesh_dryrun_subprocess():
    r = subprocess.run([sys.executable, "-c", DRYRUN_SMALL],
                       capture_output=True, text=True, timeout=500,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"})
    assert "SMALL_DRYRUN_OK" in r.stdout, r.stderr[-3000:]
