"""Corrupt-state recovery: history integrity + checkpoint sidecars
(ISSUE 9 satellite c + the tentpole's recovery rung).

Pins the recovery policy end to end:

* schema-2 history carries per-row uint64 checksums + the digest;
  `from_arrays` (strict) refuses a flipped byte, `recover` salvages the
  longest verifiable prefix.
* `ckpt.load_aux` survives truncated / byte-flipped / missing sidecars:
  warn + return None by default, typed `CorruptSidecar` under strict.
* `TendencyMonitor.restore` degrades instead of crashing: truncate to
  the last verifiable row (WARN) or start fresh — and a resumed train
  run completes either way.  The bitwise digest-identity pin for the
  UNcorrupted interrupt+resume path stays in test_monitor.py.
"""
import numpy as np
import pytest

import repro.faults as faults
from repro.checkpoint import ckpt
from repro.checkpoint.ckpt import CorruptSidecar
from repro.configs import smoke_config
from repro.configs.base import ShapeConfig, TrainConfig
from repro.monitor import AUX_NAME, TendencyHistory, TendencyMonitor
from repro.train.loop import train

SHAPE = ShapeConfig("tiny", 32, 4, "train")


@pytest.fixture(autouse=True)
def _clean_registry():
    faults.disarm_all()
    yield
    faults.disarm_all()


def _history(steps=(2, 4, 6, 8, 10), probes=("p", "q")):
    h = TendencyHistory(probes)
    for i, s in enumerate(steps):
        h.append(s, {p: {"hopkins": 0.5 + 0.01 * i + 0.1 * j,
                         "block_score": 0.4 + 0.02 * i,
                         "k_est": float(2 + (i + j) % 3)}
                     for j, p in enumerate(probes)})
    return h


def _truncated_digest(h, keep_rows):
    ref = TendencyHistory.from_arrays(h.to_arrays())
    ref.truncate(h.steps[keep_rows - 1] if keep_rows else -1)
    return ref.digest()


# =================================================== history schema 2 ===

def test_to_arrays_carries_integrity_metadata():
    h = _history()
    arrays = h.to_arrays()
    assert int(arrays["schema"][0]) == 2
    assert arrays["row_check"].dtype == np.uint64
    assert arrays["row_check"].shape == (len(h),)
    assert bytes(arrays["digest"]) == bytes.fromhex(h.digest())
    back = TendencyHistory.from_arrays(arrays)
    assert back.digest() == h.digest()


def test_schema1_payload_loads_unverified():
    h = _history()
    arrays = h.to_arrays()
    del arrays["row_check"], arrays["digest"]
    arrays["schema"] = np.asarray([1], np.int64)
    back = TendencyHistory.from_arrays(arrays)
    assert back.steps == h.steps
    assert back.digest() == h.digest()


def test_from_arrays_detects_flipped_value():
    h = _history()
    arrays = h.to_arrays()
    arrays["p/hopkins"] = arrays["p/hopkins"].copy()
    arrays["p/hopkins"][3] += np.float32(0.25)
    with pytest.raises(ValueError, match="checksum mismatch at step 8"):
        TendencyHistory.from_arrays(arrays)


def test_from_arrays_detects_tampered_steps():
    h = _history()
    arrays = h.to_arrays()
    arrays["steps"] = arrays["steps"].copy()
    arrays["steps"][1] = 5
    with pytest.raises(ValueError, match="checksum mismatch"):
        TendencyHistory.from_arrays(arrays)


def test_from_arrays_detects_row_check_length_mismatch():
    arrays = _history().to_arrays()
    arrays["row_check"] = arrays["row_check"][:2]
    with pytest.raises(ValueError, match="row_check length"):
        TendencyHistory.from_arrays(arrays)


def test_recover_truncates_to_verifiable_prefix():
    h = _history()
    arrays = h.to_arrays()
    arrays["q/k_est"] = arrays["q/k_est"].copy()
    arrays["q/k_est"][2] = np.float32(99.0)       # poison row index 2
    out = TendencyHistory.recover(arrays)
    assert out is not None
    hist, dropped = out
    assert hist.steps == [2, 4] and dropped == 3
    assert hist.digest() == _truncated_digest(h, 2)


def test_recover_tampered_row_check_truncates():
    h = _history()
    arrays = h.to_arrays()
    arrays["row_check"] = arrays["row_check"].copy()
    arrays["row_check"][1] ^= np.uint64(1)
    hist, dropped = TendencyHistory.recover(arrays)
    assert hist.steps == [2] and dropped == 4
    assert hist.digest() == _truncated_digest(h, 1)


def test_recover_clean_payload_keeps_everything():
    h = _history()
    hist, dropped = TendencyHistory.recover(h.to_arrays())
    assert dropped == 0
    assert hist.digest() == h.digest()


def test_recover_schema1_nonmonotonic_steps():
    arrays = _history().to_arrays()
    del arrays["row_check"], arrays["digest"]
    arrays["schema"] = np.asarray([1], np.int64)
    arrays["steps"] = np.asarray([2, 4, 3, 8, 10], np.int64)
    hist, dropped = TendencyHistory.recover(arrays)
    assert hist.steps == [2, 4] and dropped == 3


def test_recover_structurally_unreadable_returns_none():
    arrays = _history().to_arrays()
    del arrays["probes"]
    assert TendencyHistory.recover(arrays) is None
    assert TendencyHistory.recover({"probes": np.asarray([])}) is None


def test_deserialize_fault_site_corrupts_payload():
    h = _history()
    arrays = h.to_arrays()
    keys = sorted(arrays)
    seed = keys.index("p/block_score")            # target a field column
    with faults.injected("history.deserialize", kind="corrupt", seed=seed):
        with pytest.raises(ValueError, match="mismatch"):
            TendencyHistory.from_arrays(arrays)
    # the fault mutated from_arrays' private copy, not the caller's dict
    assert TendencyHistory.from_arrays(arrays).digest() == h.digest()


# ================================================== checkpoint sidecar ==

def _save_with_history(tmp_path, step=4, arrays=None):
    tree = {"w": np.arange(6, dtype=np.float32)}
    arrays = arrays if arrays is not None else _history().to_arrays()
    ckpt.save(str(tmp_path), step, tree, aux_arrays={AUX_NAME: arrays})
    return arrays


def test_sidecar_roundtrip_clean(tmp_path):
    _save_with_history(tmp_path)
    back = ckpt.load_aux(str(tmp_path), AUX_NAME)
    assert TendencyHistory.from_arrays(back).steps == [2, 4, 6, 8, 10]


def test_missing_sidecar_returns_none(tmp_path):
    ckpt.save(str(tmp_path), 4, {"w": np.zeros(3, np.float32)})
    assert ckpt.load_aux(str(tmp_path), AUX_NAME) is None


def test_truncated_sidecar_recovered(tmp_path):
    with faults.injected("ckpt.aux_write", kind="truncate"):
        _save_with_history(tmp_path)
    with pytest.warns(RuntimeWarning, match="unreadable"):
        assert ckpt.load_aux(str(tmp_path), AUX_NAME) is None
    with pytest.raises(CorruptSidecar):
        ckpt.load_aux(str(tmp_path), AUX_NAME, strict=True)


def test_byte_flipped_sidecar_recovered(tmp_path):
    with faults.injected("ckpt.aux_write", kind="corrupt", seed=11):
        _save_with_history(tmp_path)
    with pytest.warns(RuntimeWarning, match="unreadable"):
        assert ckpt.load_aux(str(tmp_path), AUX_NAME) is None


def test_read_fault_recovered_and_strict(tmp_path):
    _save_with_history(tmp_path)
    with faults.injected("ckpt.aux_read", exc=OSError, times=-1,
                         message="injected I/O error"):
        with pytest.warns(RuntimeWarning, match="unreadable"):
            assert ckpt.load_aux(str(tmp_path), AUX_NAME) is None
        with pytest.raises(CorruptSidecar, match="unreadable"):
            ckpt.load_aux(str(tmp_path), AUX_NAME, strict=True)
    assert ckpt.load_aux(str(tmp_path), AUX_NAME) is not None  # disarmed


def test_weights_survive_sidecar_corruption(tmp_path):
    """The recovery policy's whole point: a torn sidecar never blocks
    restoring the weights checkpoint it rides with."""
    tree = {"w": np.arange(6, dtype=np.float32)}
    with faults.injected("ckpt.aux_write", kind="truncate"):
        ckpt.save(str(tmp_path), 4, tree,
                  aux_arrays={AUX_NAME: _history().to_arrays()})
    restored, manifest = ckpt.restore(
        str(tmp_path), {"w": np.zeros(6, np.float32)})
    assert manifest["step"] == 4
    assert np.array_equal(np.asarray(restored["w"]), tree["w"])


# ================================================== monitor recovery ====

def _tc(tmpdir, **kw):
    kw.setdefault("lr", 1e-2)
    kw.setdefault("total_steps", 8)
    kw.setdefault("ckpt_every", 4)
    kw.setdefault("diag_every", 2)
    return TrainConfig(ckpt_dir=str(tmpdir), **kw)


def test_monitor_restore_recovers_verifiable_prefix(tmp_path):
    cfg = smoke_config("gemma-2b")
    mon = TendencyMonitor(cfg)
    probes = tuple(s.name for s in mon.specs)
    good = _history(steps=(2, 4, 6), probes=probes)
    arrays = good.to_arrays()
    col = f"{probes[0]}/hopkins"
    arrays[col] = arrays[col].copy()
    arrays[col][2] += np.float32(1.0)             # poison the last row
    _save_with_history(tmp_path, step=6, arrays=arrays)
    with pytest.warns(RuntimeWarning, match="recovered 2 rows, dropped 1"):
        assert mon.restore(str(tmp_path), upto_step=6)
    assert mon.history.steps == [2, 4]
    assert mon.history.digest() == _truncated_digest(good, 2)
    assert set(mon.states()) == set(probes)       # detectors replayed


def test_monitor_restore_unrecoverable_starts_fresh(tmp_path):
    cfg = smoke_config("gemma-2b")
    mon = TendencyMonitor(cfg)
    probes = tuple(s.name for s in mon.specs)
    arrays = _history(steps=(2, 4), probes=probes).to_arrays()
    arrays["row_check"] = arrays["row_check"].copy()
    arrays["row_check"][:] ^= np.uint64(1)        # no verifiable prefix
    _save_with_history(tmp_path, step=4, arrays=arrays)
    with pytest.warns(RuntimeWarning, match="unrecoverable"):
        assert not mon.restore(str(tmp_path), upto_step=4)
    assert len(mon.history) == 0


def test_train_resume_survives_corrupt_sidecar(tmp_path):
    """Degradation, not collapse: a resumed run whose history sidecar
    was torn on disk restarts the history fresh and still completes."""
    cfg = smoke_config("gemma-2b")
    with pytest.raises(KeyboardInterrupt):
        train(cfg, _tc(tmp_path), SHAPE, log=lambda s: None, interrupt_at=5)
    step = ckpt.latest_step(str(tmp_path))
    assert step == 4
    sidecar = f"{tmp_path}/step_{step:08d}/{AUX_NAME}.npz"
    with open(sidecar, "r+b") as f:               # tear it mid-file
        f.truncate(200)
    with pytest.warns(RuntimeWarning, match="unreadable"):
        _, hist = train(cfg, _tc(tmp_path), SHAPE, log=lambda s: None)
    saved = ckpt.load_aux(str(tmp_path), AUX_NAME)
    assert saved is not None
    resumed = TendencyHistory.from_arrays(saved)
    assert resumed.steps == [6, 8]                # fresh past the tear
