"""Approximate VAT (kNN-graph Borůvka MST) — the property-based oracle
suite certifying the million-point rung against the exact engine:

  * the kNN kernel (ref / blocked / Pallas) agrees with a dense top-k
    oracle bit for bit, ties included,
  * full-graph (k = n-1) Borůvka reproduces the Prim oracle's MST weight
    and edge multiset on every metric,
  * the kNN-MST weight respects its documented bounds: never below the
    exact MST weight, non-increasing in k while the graph stays
    connected, equal to exact at k = n-1 — and the ordering at k = n-1
    is BITWISE the exact engine's,
  * connectivity repair turns adversarially disconnected fixtures into
    spanning trees and reports the defect honestly,
  * massive distance ties (duplicated points) cannot hang the hooking /
    pointer-jump machinery.
"""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro import core
from repro.core import approx_mst
from repro.core.approx_mst import _prim_edges_np, boruvka_mst
from repro.kernels import ops as kops
from repro.kernels import ref

METRICS = ("euclidean", "sqeuclidean", "manhattan", "cosine")


def _data(seed, n, d=4):
    rng = np.random.default_rng(seed)
    # spread points out so distance ties only occur where we plant them
    return (rng.normal(size=(n, d)) * rng.uniform(0.5, 2.0, size=d)
            ).astype(np.float32)


def _blobs(n, k=3, d=4, seed=0, sep=40.0):
    rng = np.random.default_rng(seed)
    centers = (sep * rng.normal(size=(k, d))).astype(np.float32)
    lab = rng.integers(0, k, size=n)
    X = centers[lab] + rng.normal(scale=1.0, size=(n, d)).astype(np.float32)
    return X.astype(np.float32), lab.astype(np.int32)


def _exact_mst_weight(X, metric="euclidean") -> float:
    R = np.asarray(kops.pairwise_dist(jnp.asarray(X), metric=metric),
                   np.float64)
    return float(sum(w for _, _, w in _prim_edges_np(R)))


def _runs(lab, order) -> int:
    lo = lab[np.asarray(order)]
    return 1 + int(np.sum(lo[1:] != lo[:-1]))


# ------------------------------------------------- kNN kernel oracle ----

@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(4, 80),
       d=st.integers(1, 6), metric=st.sampled_from(METRICS),
       use_pallas=st.booleans())
def test_knn_graph_matches_dense_oracle(seed, n, d, metric, use_pallas):
    """Blocked and Pallas kNN agree with the dense lax.top_k oracle on
    indices EXACTLY (the shared lower-index tie contract) and on
    distances numerically."""
    X = jnp.asarray(_data(seed, n, d))
    k = min(7, n - 1)
    dr, ir = ref.knn_graph_ref(X, k=k, metric=metric)
    db, ib = kops.knn_graph(X, k=k, metric=metric, use_pallas=use_pallas,
                            block=32 if use_pallas else 16)
    np.testing.assert_array_equal(np.asarray(ib), np.asarray(ir))
    np.testing.assert_allclose(np.asarray(db), np.asarray(dr),
                               rtol=1e-5, atol=1e-5)


# -------------------------------------------- full-graph MST oracle ----

@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(4, 512),
       metric=st.sampled_from(METRICS))
def test_full_graph_boruvka_matches_prim_oracle(seed, n, metric):
    """At k = n-1 the kNN graph IS the complete graph, so Borůvka must
    reproduce the host Prim oracle: one component, n-1 edges, the same
    weight multiset, within the logarithmic pass cap."""
    X = _data(seed, n)
    R = np.asarray(kops.pairwise_dist(jnp.asarray(X), metric=metric),
                   np.float64)
    oracle_w = np.sort([w for _, _, w in _prim_edges_np(R)])
    dj, ij = kops.knn_graph(jnp.asarray(X), k=n - 1, metric=metric)
    tree, passes, ncomp, repair_w = boruvka_mst(
        np.asarray(ij), np.asarray(dj), X=X, metric=metric)
    assert ncomp == 1 and repair_w == 0.0
    assert tree.src.size == n - 1
    assert passes <= int(np.ceil(np.log2(n))) + 2
    np.testing.assert_allclose(np.sort(tree.weight.astype(np.float64)),
                               oracle_w, rtol=1e-5, atol=1e-5)


# --------------------------------------------- weight bound / k knob ----

@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 1_000), sep=st.floats(4.0, 40.0),
       anchored=st.booleans())
def test_knn_mst_weight_bounded_and_monotone(seed, sep, anchored):
    """The documented error model: every reported tree weight lower-bounds
    at the exact MST weight (its edges are true distances), the stats
    decompose (repair <= total, repaired_edges = components - 1), and —
    while the graph stays connected, where G_k is nested in G_k' — the
    weight is non-increasing in k."""
    X, _ = _blobs(500, k=3, seed=seed, sep=sep)
    exact_w = _exact_mst_weight(X)
    mode = "anchored" if anchored else "exact"
    connected_w = []
    for k in (3, 8, 20):
        s = core.approx_vat(X, k=k, knn_mode=mode).stats
        assert s.mode == mode and s.k == k
        assert s.mst_weight >= exact_w * (1 - 1e-5) - 1e-4
        assert s.repaired_edges == max(s.components - 1, 0)
        assert 0.0 <= s.repair_weight <= s.mst_weight + 1e-6
        if s.components == 1:
            connected_w.append(s.mst_weight)
    for a, b in zip(connected_w, connected_w[1:]):
        assert b <= a * (1 + 1e-5) + 1e-4


def test_full_k_weight_equals_exact():
    X, _ = _blobs(300, k=3, seed=7)
    s = core.approx_vat(X, k=299, knn_mode="exact").stats
    np.testing.assert_allclose(s.mst_weight, _exact_mst_weight(X),
                               rtol=1e-5, atol=1e-4)
    assert s.components == 1 and s.repair_weight == 0.0


# ------------------------------------- ordering vs the exact engine ----

@settings(max_examples=5, deadline=None)
@given(cfg=st.tuples(st.integers(0, 10_000), st.integers(16, 400)),
       metric=st.sampled_from(("euclidean", "manhattan")))
def test_full_k_ordering_bitwise_matches_exact_engine(cfg, metric):
    """k = n-1 certification: the approximate pipeline (complete kNN
    graph -> Borůvka -> tree Prim, default largest-radius seed) must
    reproduce ``vat_matrix_free``'s ordering BITWISE — the seed rule,
    the tie rules and the tree all coincide with the exact engine's."""
    seed, n = cfg
    X = _data(seed, n, 3)
    res = core.approx_vat(X, k=n - 1, knn_mode="exact", metric=metric)
    exact = core.vat_matrix_free(jnp.asarray(X), metric=metric)
    np.testing.assert_array_equal(res.order, np.asarray(exact.order))
    np.testing.assert_allclose(res.edges, np.asarray(exact.edges), atol=1e-5)


def test_full_k_ordering_bitwise_at_1024():
    X = _data(99, 1024, 5)
    res = core.approx_vat(X, k=1023, knn_mode="exact")
    exact = core.vat_matrix_free(jnp.asarray(X))
    np.testing.assert_array_equal(res.order, np.asarray(exact.order))


@pytest.mark.parametrize("n,k", [(1024, 64), (2048, 24), (4096, 16)])
def test_modest_k_preserves_exact_cluster_structure(n, k):
    """Overlap-size certification at practical k: both engines keep each
    well-separated cluster one contiguous run (same permutation domain,
    same macro structure), even though the micro order may differ."""
    X, lab = _blobs(n, k=4, seed=n)
    exact_order = np.asarray(core.vat_matrix_free(jnp.asarray(X)).order)
    res = core.approx_vat(X, k=k)
    assert sorted(res.order.tolist()) == list(range(n))
    assert _runs(lab, res.order) == _runs(lab, exact_order) == 4


def test_anchored_mode_preserves_cluster_structure():
    X, lab = _blobs(3_000, k=5, seed=3)
    res = core.approx_vat(X, k=10, knn_mode="anchored")
    assert res.stats.mode == "anchored"
    assert sorted(res.order.tolist()) == list(range(3_000))
    assert _runs(lab, res.order) == 5


# --------------------------------------------- connectivity repair ----

def test_disconnected_blobs_repaired_to_spanning():
    """Adversarial fixture: 4 blobs separated by ~1000, k = 3 — no kNN
    edge can cross blobs, so the graph is disconnected by construction.
    Repair must splice it to spanning and report the defect."""
    rng = np.random.default_rng(0)
    centers = np.array([[0, 0], [1000, 0], [0, 1000], [1000, 1000]],
                       np.float32)
    X = np.concatenate([
        c + rng.normal(scale=0.5, size=(100, 2)).astype(np.float32)
        for c in centers])
    lab = np.repeat(np.arange(4), 100)
    res = core.approx_vat(X, k=3, knn_mode="exact")
    s = res.stats
    assert s.components >= 4
    assert s.repaired_edges == s.components - 1
    assert s.repair_weight >= 3 * 900          # >= 3 cross-blob splices
    assert sorted(res.order.tolist()) == list(range(400))
    assert _runs(lab, res.order) == 4          # blobs stay contiguous


def test_chain_repair_past_repair_max_c(monkeypatch):
    """Past REPAIR_MAX_C surviving components the repair degrades to the
    O(C) representative chain — still spanning, still reported."""
    monkeypatch.setattr(approx_mst, "REPAIR_MAX_C", 2)
    rng = np.random.default_rng(1)
    centers = np.array([[0, 0], [500, 0], [0, 500]], np.float32)
    X = np.concatenate([
        c + rng.normal(scale=0.5, size=(60, 2)).astype(np.float32)
        for c in centers])
    res = core.approx_vat(X, k=3, knn_mode="exact")
    s = res.stats
    assert s.components >= 3
    assert s.repaired_edges == s.components - 1
    assert s.repair_weight > 0.0
    assert sorted(res.order.tolist()) == list(range(180))


def test_boruvka_disconnected_without_x_raises():
    idx = np.array([[1], [0], [3], [2]], np.int32)       # two 2-cliques
    dist = np.ones((4, 1), np.float32)
    with pytest.raises(ValueError, match="disconnected"):
        boruvka_mst(idx, dist)


# ------------------------------------------------- tie robustness ----

@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 1_000), base_n=st.integers(3, 20),
       dup=st.integers(2, 4))
def test_boruvka_survives_duplicate_points(seed, base_n, dup):
    """Every point duplicated `dup` times: zero-distance ties everywhere.
    The lexicographic edge keys and the 2-cycle break must still yield a
    spanning tree within the pass cap (a broken tie rule hangs or drops
    vertices here)."""
    X = np.repeat(_data(seed, base_n, 2), dup, axis=0)
    n = base_n * dup
    res = core.approx_vat(X, k=min(6, n - 1), knn_mode="exact")
    assert sorted(res.order.tolist()) == list(range(n))
    assert np.isfinite(res.stats.mst_weight)
    assert res.stats.n_passes <= int(np.ceil(np.log2(n))) + 2


# ------------------------------------------------------- edge cases ----

def test_small_n_and_validation():
    assert core.approx_vat(_data(0, 1, 3)).order.tolist() == [0]
    res2 = core.approx_vat(_data(0, 2, 3), k=50)   # k clamps to n-1
    assert sorted(res2.order.tolist()) == [0, 1]
    assert res2.stats.k == 1
    with pytest.raises(ValueError, match="knn_mode"):
        core.approx_vat(_data(0, 8, 2), knn_mode="bogus")
