"""t-SNE validation utility: separates what should separate."""
import numpy as np
import jax
import jax.numpy as jnp

from repro import core


def test_tsne_separates_two_clusters():
    rng = np.random.default_rng(0)
    X = jnp.asarray(np.concatenate([
        rng.normal(scale=0.3, size=(40, 10)),
        rng.normal(scale=0.3, size=(40, 10)) + 4.0]), jnp.float32)
    Y = core.tsne(X, jax.random.PRNGKey(0), perplexity=15.0, iters=300)
    assert Y.shape == (80, 2)
    assert bool(jnp.all(jnp.isfinite(Y)))
    a, b = np.asarray(Y[:40]), np.asarray(Y[40:])
    # inter-cluster centroid gap dwarfs intra-cluster spread
    gap = np.linalg.norm(a.mean(0) - b.mean(0))
    spread = max(a.std(), b.std())
    assert gap > 2.0 * spread


def test_tsne_agrees_with_vat_on_spotify():
    """Paper §4.4.2: both t-SNE and VAT show no structure on spotify."""
    from repro.data.synth import make_dataset
    X, _ = make_dataset("spotify")
    Xj = jnp.asarray(X[:150])
    Y = core.tsne(Xj, jax.random.PRNGKey(0), perplexity=20.0, iters=250)
    # no separation: single diffuse mass (silhouette-free check: the
    # kmeans-2 split has tiny inter/intra ratio compared to real clusters)
    labels, _, _ = core.kmeans(Y, jax.random.PRNGKey(1), k=2)
    a = np.asarray(Y)[np.asarray(labels) == 0]
    b = np.asarray(Y)[np.asarray(labels) == 1]
    gap = np.linalg.norm(a.mean(0) - b.mean(0))
    spread = max(a.std(), b.std())
    assert gap < 4.0 * spread  # clustered data shows >> this
