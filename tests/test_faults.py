"""Unit tests for the deterministic fault-injection registry (ISSUE 9).

Covers the registry contract the chaos suite builds on: site-name
validation, count scheduling (after/times), match predicates, every
fault kind (raise / delay / corrupt / truncate over bytes, arrays, flat
dicts, and files), determinism of the corruption choices, and the
zero-overhead disarmed fast path.
"""
import os

import numpy as np
import pytest

import repro.faults as faults


@pytest.fixture(autouse=True)
def _clean_registry():
    faults.disarm_all()
    yield
    faults.disarm_all()


SITE = "serve.execute"


class TestRegistry:
    def test_unknown_site_rejected(self):
        with pytest.raises(ValueError, match="unknown injection site"):
            faults.arm("serve.exeucte")  # typo'd on purpose

    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError, match="kind"):
            faults.arm(SITE, kind="explode")

    def test_arm_disarm_roundtrip(self):
        faults.arm(SITE)
        assert faults.is_armed(SITE)
        assert SITE in faults.armed()
        faults.disarm(SITE)
        assert not faults.is_armed(SITE)
        assert faults.armed() == {}

    def test_disarm_all(self):
        faults.arm(SITE)
        faults.arm("serve.build")
        faults.disarm_all()
        assert faults.armed() == {}

    def test_injected_context_manager_disarms(self):
        with faults.injected(SITE):
            assert faults.is_armed(SITE)
            with pytest.raises(faults.FaultInjected):
                faults.fault_point(SITE)
        assert not faults.is_armed(SITE)

    def test_disarmed_fast_path_returns_data(self):
        payload = np.arange(5)
        out = faults.fault_point(SITE, data=payload)
        assert out is payload          # identity: untouched, uncopied

    def test_armed_other_site_returns_data(self):
        faults.arm("serve.build")
        payload = b"abc"
        assert faults.fault_point(SITE, data=payload) is payload


class TestScheduling:
    def test_times_limits_firings(self):
        faults.arm(SITE, times=2)
        for _ in range(2):
            with pytest.raises(faults.FaultInjected):
                faults.fault_point(SITE)
        faults.fault_point(SITE)       # third hit: clean
        assert faults.stats()[SITE] == {"hits": 3, "fired": 2}

    def test_after_skips_initial_hits(self):
        faults.arm(SITE, after=2, times=1)
        faults.fault_point(SITE)
        faults.fault_point(SITE)
        with pytest.raises(faults.FaultInjected):
            faults.fault_point(SITE)
        faults.fault_point(SITE)
        assert faults.stats()[SITE] == {"hits": 4, "fired": 1}

    def test_times_forever(self):
        faults.arm(SITE, times=-1)
        for _ in range(5):
            with pytest.raises(faults.FaultInjected):
                faults.fault_point(SITE)

    def test_match_gates_hit_counting(self):
        faults.arm(SITE, times=1,
                   match=lambda ctx: "poison" in ctx.get("tags", []))
        faults.fault_point(SITE, context={"tags": ["clean"]})
        with pytest.raises(faults.FaultInjected):
            faults.fault_point(SITE, context={"tags": ["clean", "poison"]})
        # the non-matching visit did not consume the firing budget
        assert faults.stats()[SITE] == {"hits": 1, "fired": 1}


class TestKinds:
    def test_raise_default_exception_carries_site(self):
        faults.arm(SITE)
        with pytest.raises(faults.FaultInjected) as ei:
            faults.fault_point(SITE)
        assert ei.value.site == SITE

    def test_raise_custom_exception_and_message(self):
        faults.arm(SITE, exc=OSError, message="disk on fire")
        with pytest.raises(OSError, match="disk on fire"):
            faults.fault_point(SITE)

    def test_delay_uses_injected_sleep(self):
        slept = []
        faults.arm(SITE, kind="delay", delay_s=1.5)
        faults.fault_point(SITE, sleep=slept.append)
        assert slept == [1.5]

    def test_corrupt_bytes_deterministic(self):
        payload = bytes(range(64))
        faults.arm(SITE, kind="corrupt", times=-1, seed=7)
        a = faults.fault_point(SITE, data=payload)
        b = faults.fault_point(SITE, data=payload)
        assert a == b != payload
        assert len(a) == len(payload)
        diff = [i for i in range(64) if a[i] != payload[i]]
        assert len(diff) == 1          # exactly one flipped byte
        assert 0 < diff[0] < 63        # away from both ends

    def test_corrupt_array_copies(self):
        arr = np.zeros(16, np.float32)
        faults.arm(SITE, kind="corrupt")
        out = faults.fault_point(SITE, data=arr)
        assert not np.array_equal(out, arr)
        assert np.array_equal(arr, np.zeros(16, np.float32))  # original safe

    def test_corrupt_dict_flips_one_value(self):
        d = {"a": np.zeros(8, np.float32), "b": np.ones(8, np.float32)}
        faults.arm(SITE, kind="corrupt", seed=0)
        out = faults.fault_point(SITE, data=d)
        changed = [k for k in d if not np.array_equal(out[k], d[k])]
        assert len(changed) == 1

    def test_truncate_bytes(self):
        faults.arm(SITE, kind="truncate")
        out = faults.fault_point(SITE, data=bytes(range(10)))
        assert out == bytes(range(5))

    def test_truncate_array(self):
        faults.arm(SITE, kind="truncate")
        out = faults.fault_point(SITE, data=np.arange(10))
        assert out.shape == (5,)

    def test_corrupt_file_in_place(self, tmp_path):
        p = os.path.join(tmp_path, "blob.bin")
        original = bytes(range(256))
        with open(p, "wb") as f:
            f.write(original)
        faults.arm(SITE, kind="corrupt", seed=3)
        faults.fault_point(SITE, path=p)
        with open(p, "rb") as f:
            raw = f.read()
        assert len(raw) == 256 and raw != original

    def test_truncate_file_in_place(self, tmp_path):
        p = os.path.join(tmp_path, "blob.bin")
        with open(p, "wb") as f:
            f.write(bytes(256))
        faults.arm(SITE, kind="truncate")
        faults.fault_point(SITE, path=p)
        assert os.path.getsize(p) == 128

    def test_unsupported_payload_type(self):
        faults.arm(SITE, kind="corrupt")
        with pytest.raises(TypeError, match="cannot corrupt"):
            faults.fault_point(SITE, data=[1, 2, 3])
