"""Streaming VAT: bounded memory, exact on the reservoir, detects drift."""
import numpy as np
import jax.numpy as jnp

from repro import core
from repro.core.streaming import StreamingVAT


def test_reservoir_bounded_and_exact():
    rng = np.random.default_rng(0)
    sv = StreamingVAT(cap=64, d=3)
    for _ in range(10):
        sv.update(rng.normal(size=(50, 3)))
    assert len(sv.pts) == 64
    assert sv.n_seen == 500
    # ordering is exactly batch VAT of the reservoir
    batch = core.vat(jnp.asarray(sv.pts))
    assert np.array_equal(sv.order(), np.asarray(batch.order))


def test_streaming_detects_emerging_clusters():
    rng = np.random.default_rng(1)
    sv = StreamingVAT(cap=96, d=2)
    sv.update(rng.normal(size=(200, 2)))          # single blob
    _, score1, _ = sv.tendency()
    # a second, far cluster starts streaming in
    sv.update(rng.normal(size=(200, 2)) + 12.0)
    _, score2, k2 = sv.tendency()
    assert score2 > score1
    assert k2 >= 2


def test_absorption_keeps_counts():
    sv = StreamingVAT(cap=4, d=1)
    sv.update(np.array([[0.0], [1.0], [2.0], [3.0]]))
    sv.update(np.array([[0.001]] * 5))            # near-duplicates absorbed
    assert len(sv.pts) == 4
    assert sv.counts.sum() == 9


def test_absorption_running_mean_exact():
    """Regression: the absorb path must weight the slot mean by the OLD
    multiplicity (the pre-fix code incremented counts first, so a slot
    that had absorbed c points averaged as if it held c+1 — every absorbed
    point was under-weighted and the slot drifted toward its first value)."""
    sv = StreamingVAT(cap=2, d=1)
    sv.update(np.array([[0.0], [8.0]]))           # reservoir full, sep = 8
    sv.update(np.array([[2.0]]))                  # absorbed into slot 0
    assert sv.counts[0] == 2
    np.testing.assert_allclose(sv.pts[0], [1.0])  # mean of {0, 2}
    sv.update(np.array([[4.0]]))                  # absorbed again (|1-4|<7)
    assert sv.counts[0] == 3
    np.testing.assert_allclose(sv.pts[0], [2.0])  # mean of {0, 2, 4}
    # slot 1 untouched throughout
    np.testing.assert_allclose(sv.pts[1], [8.0])
    assert sv.counts[1] == 1


# ---------------------------- metric threading (ISSUE 5 satellite) ----

import pytest  # noqa: E402

from repro.kernels.ref import METRICS  # noqa: E402


@pytest.mark.parametrize("metric", METRICS)
def test_streaming_metric_threads_end_to_end(metric):
    """The reservoir's VAT queries run in the stream's metric: order()
    equals batch VAT of the reservoir under the SAME metric, and (for
    any non-euclidean metric) generally differs from the euclidean
    ordering of the same points."""
    rng = np.random.default_rng(7)
    sv = StreamingVAT(cap=48, d=4, metric=metric)
    for _ in range(6):
        sv.update(rng.normal(size=(40, 4)) + rng.integers(0, 3) * 5.0)
    assert len(sv.pts) == 48
    batch = core.vat(jnp.asarray(sv.pts), metric=metric)
    assert np.array_equal(sv.order(), np.asarray(batch.order))


def test_streaming_metric_shapes_reservoir_geometry():
    """A cosine stream must thin by ANGLE: rays at the same angle but
    wildly different radii are near-duplicates for cosine (absorbed),
    while the euclidean reservoir keeps them apart."""
    rng = np.random.default_rng(3)
    angles = rng.uniform(0, 2 * np.pi, size=400)
    radii = rng.uniform(0.5, 20.0, size=400)
    X = np.stack([radii * np.cos(angles), radii * np.sin(angles)], 1)
    cos_sv = StreamingVAT(cap=32, d=2, metric="cosine")
    euc_sv = StreamingVAT(cap=32, d=2, metric="euclidean")
    cos_sv.update(X)
    euc_sv.update(X)
    # the cosine reservoir absorbs same-direction points regardless of
    # radius, so it folds far more of the stream into running means than
    # the euclidean one (evictions reset a slot's count, so sums stay
    # below n_seen for both)
    assert cos_sv.counts.sum() > euc_sv.counts.sum()
    cos_angles = np.sort(np.arctan2(cos_sv.pts[:, 1], cos_sv.pts[:, 0]))
    # the cosine reservoir covers the circle: no angular gap should be
    # grossly larger than uniform spacing
    gaps = np.diff(np.concatenate([cos_angles, cos_angles[:1] + 2 * np.pi]))
    assert gaps.max() < 6 * (2 * np.pi / 32)


def test_streaming_rejects_unknown_metric():
    with pytest.raises(ValueError, match="metric"):
        StreamingVAT(cap=8, d=2, metric="chebyshev")
