"""Streaming VAT: bounded memory, exact on the reservoir, detects drift."""
import numpy as np
import jax.numpy as jnp

from repro import core
from repro.core.streaming import StreamingVAT


def test_reservoir_bounded_and_exact():
    rng = np.random.default_rng(0)
    sv = StreamingVAT(cap=64, d=3)
    for _ in range(10):
        sv.update(rng.normal(size=(50, 3)))
    assert len(sv.pts) == 64
    assert sv.n_seen == 500
    # ordering is exactly batch VAT of the reservoir
    batch = core.vat(jnp.asarray(sv.pts))
    assert np.array_equal(sv.order(), np.asarray(batch.order))


def test_streaming_detects_emerging_clusters():
    rng = np.random.default_rng(1)
    sv = StreamingVAT(cap=96, d=2)
    sv.update(rng.normal(size=(200, 2)))          # single blob
    _, score1, _ = sv.tendency()
    # a second, far cluster starts streaming in
    sv.update(rng.normal(size=(200, 2)) + 12.0)
    _, score2, k2 = sv.tendency()
    assert score2 > score1
    assert k2 >= 2


def test_absorption_keeps_counts():
    sv = StreamingVAT(cap=4, d=1)
    sv.update(np.array([[0.0], [1.0], [2.0], [3.0]]))
    sv.update(np.array([[0.001]] * 5))            # near-duplicates absorbed
    assert len(sv.pts) == 4
    assert sv.counts.sum() == 9


def test_absorption_running_mean_exact():
    """Regression: the absorb path must weight the slot mean by the OLD
    multiplicity (the pre-fix code incremented counts first, so a slot
    that had absorbed c points averaged as if it held c+1 — every absorbed
    point was under-weighted and the slot drifted toward its first value)."""
    sv = StreamingVAT(cap=2, d=1)
    sv.update(np.array([[0.0], [8.0]]))           # reservoir full, sep = 8
    sv.update(np.array([[2.0]]))                  # absorbed into slot 0
    assert sv.counts[0] == 2
    np.testing.assert_allclose(sv.pts[0], [1.0])  # mean of {0, 2}
    sv.update(np.array([[4.0]]))                  # absorbed again (|1-4|<7)
    assert sv.counts[0] == 3
    np.testing.assert_allclose(sv.pts[0], [2.0])  # mean of {0, 2, 4}
    # slot 1 untouched throughout
    np.testing.assert_allclose(sv.pts[1], [8.0])
    assert sv.counts[1] == 1
