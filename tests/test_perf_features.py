"""Beyond-paper performance features: exactness + invariants.

These are the §Perf levers — each must be *semantics-preserving* (or have
its approximation contract tested).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.models import model as M
from repro.models.moe import moe_ffn


def test_vocab_padding_preserves_logits():
    cfg0 = smoke_config("phi3-mini-3.8b").replace(vocab=123)  # odd vocab
    cfgp = cfg0.replace(vocab_pad=64)                         # pads to 128
    assert cfgp.padded_vocab == 128
    p0 = M.init_params(cfg0, jax.random.PRNGKey(0))
    pp = M.init_params(cfgp, jax.random.PRNGKey(0))
    # share weights: padded embed/lm_head rows beyond vocab are irrelevant
    pp["embed"] = pp["embed"].at[:123].set(p0["embed"])
    pp["lm_head"] = pp["lm_head"].at[:, :123].set(p0["lm_head"])
    pp["layers"] = p0["layers"]
    pp["final_norm"] = p0["final_norm"]
    toks = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    l0, _ = M.forward(p0, cfg0, {"tokens": toks})
    lp, _ = M.forward(pp, cfgp, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(lp[..., :123]), np.asarray(l0),
                               atol=1e-5)
    # padded entries can never win an argmax
    assert bool(jnp.all(jnp.argmax(lp, -1) < 123))


def test_chunked_ce_matches_full():
    from repro.train.steps import loss_fn
    cfg = smoke_config("gemma-2b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.asarray([[1, 2, 3, 4, 5, 6, 7, 8]], jnp.int32),
             "labels": jnp.asarray([[2, 3, -1, 5, 6, 7, 8, 9]], jnp.int32)}
    full, _ = loss_fn(params, cfg, batch)
    chunked, _ = loss_fn(params, cfg.replace(ce_chunk=4), batch)
    np.testing.assert_allclose(float(chunked), float(full), rtol=1e-5)


def test_head_padding_exact_function():
    cfg0 = smoke_config("whisper-large-v3")
    cfgp = cfg0.replace(head_pad=8)
    assert cfgp.eff_heads == 8 and cfg0.eff_heads == 4
    p0 = M.init_params(cfg0, jax.random.PRNGKey(0))
    pp = M.init_params(cfgp, jax.random.PRNGKey(0))
    for lname in ("layers", "enc_layers"):
        for w in ("wq", "wk", "wv", "x_wq", "x_wk", "x_wv"):
            if w in pp[lname]:
                d = pp[lname][w]
                pp[lname][w] = jnp.zeros_like(d).at[
                    ..., :p0[lname][w].shape[-1]].set(p0[lname][w])
        for w in ("wo", "x_wo"):
            if w in pp[lname]:
                d = pp[lname][w]
                pp[lname][w] = jnp.zeros_like(d).at[
                    ..., :p0[lname][w].shape[-2], :].set(p0[lname][w])
        for w in pp[lname]:
            if w not in ("wq", "wk", "wv", "wo", "x_wq", "x_wk", "x_wv",
                         "x_wo"):
                pp[lname][w] = p0[lname][w]
    for k in ("embed", "lm_head", "final_norm", "enc_final_norm"):
        if k in pp:
            pp[k] = p0[k]
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray([[1, 2, 3, 4]], jnp.int32),
             "enc_frames": jnp.asarray(rng.normal(size=(1, 16, 64)),
                                       jnp.float32)}
    l0, _ = M.forward(p0, cfg0, batch)
    lp, _ = M.forward(pp, cfgp, batch)
    np.testing.assert_array_equal(np.asarray(l0), np.asarray(lp))


def test_head_padding_refuses_gqa():
    cfg = smoke_config("phi3-mini-3.8b").replace(n_kv_heads=2, head_pad=8)
    # GQA (q != kv) must not pad — group mapping would break
    assert cfg.eff_heads == cfg.n_heads
    assert cfg.eff_kv_heads == cfg.n_kv_heads


def test_group_limited_routing_containment():
    cfg = smoke_config("deepseek-v3-671b").replace(
        n_experts=8, top_k=2, route_groups=4, route_top_groups=1,
        d_ff_expert=32, capacity_factor=8.0)
    key = jax.random.PRNGKey(0)
    D = cfg.d_model
    x = jax.random.normal(key, (64, D))
    router = jax.random.normal(key, (D, 8))
    logits = x @ router
    probs = jax.nn.softmax(logits, -1)
    G, gsz = 4, 2
    gscore = jnp.sum(jax.lax.top_k(probs.reshape(-1, G, gsz), 2)[0], -1)
    gidx = jnp.argmax(gscore, -1)
    masked = jnp.where(
        jnp.repeat(jax.nn.one_hot(gidx, G, dtype=bool), gsz, -1), probs, 0)
    _, ids = jax.lax.top_k(masked, 2)
    # top-1 group => both selected experts must share one group
    assert bool(jnp.all(ids[:, 0] // gsz == ids[:, 1] // gsz))


def test_momentum_free_adafactor_state_is_smaller():
    from repro.configs.base import TrainConfig
    from repro.optim import adamw as O
    params = {"w": jnp.zeros((64, 64)), "b": jnp.zeros((64,))}
    st_m = O.init_opt(TrainConfig(optimizer="adafactor", b1=0.9), params)
    st_0 = O.init_opt(TrainConfig(optimizer="adafactor", b1=0.0), params)
    assert st_0.m is None and st_m.m is not None
    # and it still optimizes
    tc = TrainConfig(optimizer="adafactor", b1=0.0, lr=0.1,
                     warmup_steps=1, total_steps=2000, weight_decay=0.0)
    p = {"w": jnp.full((4, 4), 3.0)}
    st = O.init_opt(tc, p)
    for _ in range(200):
        p, st = O.apply_opt(tc, p, {"w": 2 * p["w"]}, st)
    assert float(jnp.max(jnp.abs(p["w"]))) < 0.5
