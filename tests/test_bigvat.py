"""Big-VAT: oracle agreement with exact VAT, the no-(n,n)-allocation
property of the tiled pass, FastVAT routing, and a regression pin on the
shard_map import fix."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import core
from repro.core.bigvat import bigvat, nearest_prototype_assign, smoothed_image
from repro.api import FastVAT, assess_tendency, select_method, SMALL_N, MEDIUM_N


def _blobs(n, k=3, d=2, seed=0, sep=40.0):
    rng = np.random.default_rng(seed)
    centers = (sep * rng.normal(size=(k, d))).astype(np.float32)
    lab = rng.integers(0, k, size=n)
    X = centers[lab] + rng.normal(scale=1.0, size=(n, d)).astype(np.float32)
    return X.astype(np.float32), lab.astype(np.int32)


# ------------------------------------------------------------ oracle ----

def test_bigvat_k_est_matches_exact_vat():
    """bigvat's sample image yields the same block_structure_score
    k-estimate as exact VAT on the full (n, n) matrix."""
    X, _ = _blobs(600, k=3)
    _, k_exact = core.block_structure_score(core.vat(jnp.asarray(X)).rstar)
    res = bigvat(X, s=64)
    _, k_big = core.block_structure_score(res.sample.vat.rstar)
    assert int(k_big) == int(k_exact) == 3


def test_bigvat_grouping_keeps_clusters_contiguous():
    X, lab = _blobs(2_000, k=4, seed=1)
    res = bigvat(X, s=64)
    order = np.asarray(res.order)
    assert sorted(order.tolist()) == list(range(len(X)))  # permutation
    runs = 1 + int(np.sum(lab[order][1:] != lab[order][:-1]))
    assert runs == 4
    assert int(np.sum(np.asarray(res.group_sizes))) == len(X)


def test_bigvat_smoothed_image_has_block_structure():
    X, _ = _blobs(3_000, k=3, seed=2)
    res = bigvat(X, s=64)
    img = smoothed_image(res, resolution=128)
    assert img.shape == (128, 128)
    score, k = core.block_structure_score(jnp.asarray(img))
    assert float(score) > 0.5


# ---------------------------------------------- no-(n,n) allocation ----

def test_tiled_pass_never_materializes_nxn(monkeypatch):
    """Memory-shape assertion: every distance tile the extension pass
    produces is at most (block, s) — nothing O(n^2), nothing even O(n)."""
    from repro.kernels import ops as kops
    n, s, block = 50_000, 64, 4_096
    X, _ = _blobs(n, k=3, d=2, seed=3)
    P = X[:s]

    shapes = []
    real = kops.pairwise_dist

    def recording(Xa, Ya=None, **kw):
        out = real(Xa, Ya, **kw)
        shapes.append(tuple(out.shape))
        return out

    monkeypatch.setattr(kops, "pairwise_dist", recording)
    labels, dists = nearest_prototype_assign(X, P, block=block)
    assert labels.shape == (n,) and dists.shape == (n,)
    assert shapes, "tiled pass never went through kernels.ops.pairwise_dist"
    assert all(r <= block and c <= s for r, c in shapes), shapes
    # correctness of the tiling: matches a brute-force (chunked) argmin
    ref_lab = np.asarray(jnp.argmin(real(jnp.asarray(X[:1000]), jnp.asarray(P)), axis=1))
    np.testing.assert_array_equal(np.asarray(labels)[:1000], ref_lab)


def test_bigvat_accepts_memmap(tmp_path):
    """Out-of-core input: X as np.memmap streams through the tiled pass."""
    X, _ = _blobs(5_000, k=3, seed=4)
    path = tmp_path / "X.f32"
    mm = np.memmap(path, dtype=np.float32, mode="w+", shape=X.shape)
    mm[:] = X
    mm.flush()
    ro = np.memmap(path, dtype=np.float32, mode="r", shape=X.shape)
    res = bigvat(ro, s=32, block=1024)
    assert sorted(np.asarray(res.order).tolist()) == list(range(len(X)))


# ------------------------------------------------------ FastVAT api ----

def test_select_method_thresholds():
    assert select_method(SMALL_N) == "vat"
    assert select_method(SMALL_N + 1) == "flashvat"
    assert select_method(MEDIUM_N) == "flashvat"
    assert select_method(MEDIUM_N + 1) == "approx"


def test_fastvat_auto_routes_vat():
    X, _ = _blobs(400)
    fv = FastVAT().fit(X)
    assert fv.method_resolved == "vat"
    assert fv.image().shape == (400, 400)
    assert sorted(fv.order().tolist()) == list(range(400))


def test_fastvat_auto_routes_flashvat():
    """The mid-size window now gets *exact* matrix-free VAT, not the
    sampled approximation — the Flash-VAT promotion."""
    X, _ = _blobs(5_000)
    fv = FastVAT(sample_size=64).fit(X)
    assert fv.method_resolved == "flashvat"
    assert sorted(fv.order().tolist()) == list(range(5_000))  # full, exact
    assert fv.image(resolution=128).shape == (128, 128)
    assert len(fv.sample_indices()) == 64


def test_fastvat_explicit_svat_still_works():
    X, _ = _blobs(5_000)
    fv = FastVAT(method="svat", sample_size=64).fit(X)
    assert fv.method_resolved == "svat"
    assert fv.image().shape == (64, 64)
    assert len(fv.sample_indices()) == 64


def test_fastvat_explicit_bigvat_past_flash_window():
    # bigvat is opt-in now (the approx rung owns the auto fallback —
    # ISSUE 6) but the explicit pipeline must keep working just past the
    # flashvat window it used to own.
    n = MEDIUM_N + 1_000
    X, lab = _blobs(n, k=3)
    fv = FastVAT(method="bigvat", sample_size=64, block=8_192).fit(X)
    assert fv.method_resolved == "bigvat"
    assert fv.image(resolution=100).shape == (100, 100)
    order = fv.order()
    assert sorted(order.tolist()) == list(range(n))
    rep = fv.assess()
    assert rep["method"] == "bigvat" and rep["k_est"] == 3
    assert rep["clustered"]


def test_fastvat_explicit_ivat():
    X, _ = _blobs(300)
    fv = FastVAT(method="ivat").fit(X)
    iv = fv.image()
    # geodesic max-min distances never exceed the direct ones
    assert np.all(iv <= fv.image(use_ivat=False) + 1e-4)


def test_fastvat_validation():
    with pytest.raises(ValueError):
        FastVAT(method="nope")
    with pytest.raises(RuntimeError):
        FastVAT().order()  # not fitted
    if jax.device_count() < 2:
        with pytest.raises(RuntimeError):
            FastVAT(method="dvat").fit(_blobs(64)[0])


def test_assess_tendency_oneshot():
    X, _ = _blobs(500, k=2, seed=5)
    rep = assess_tendency(X)
    assert rep["method"] == "vat" and rep["k_est"] == 2 and rep["clustered"]


# -------------------------------------------- shard_map import pin ----

def test_shard_map_import_fix():
    """Regression: repro.core.distributed must import on any JAX that has
    shard_map at either home (jax.shard_map or jax.experimental.shard_map),
    and repro.core must expose the availability flag."""
    import repro.core.distributed as dist
    assert callable(dist._shard_map_impl)
    assert core.HAS_DISTRIBUTED is True
    assert core.dvat is dist.dvat


def test_core_degrades_without_distributed(monkeypatch):
    """repro.core import survives a JAX with no shard_map anywhere."""
    import builtins
    import importlib
    import sys

    real_import = builtins.__import__

    def no_shard_map(name, *args, **kwargs):
        if name == "repro.core.distributed":
            raise ImportError("simulated: no shard_map in this jax")
        return real_import(name, *args, **kwargs)

    saved = {k: v for k, v in sys.modules.items() if k.startswith("repro.core")}
    for k in saved:
        monkeypatch.delitem(sys.modules, k)
    monkeypatch.setattr(builtins, "__import__", no_shard_map)
    try:
        mod = importlib.import_module("repro.core")
        assert mod.HAS_DISTRIBUTED is False
        assert mod.dvat is None
        assert "dvat" not in mod.__all__
        assert callable(mod.vat)
    finally:
        monkeypatch.setattr(builtins, "__import__", real_import)
        for k in [k for k in sys.modules if k.startswith("repro.core")]:
            del sys.modules[k]
        sys.modules.update(saved)
        # `from repro import core` resolves via the package attribute, so
        # restore it too or the degraded module leaks to later tests
        import repro
        if "repro.core" in saved:
            repro.core = saved["repro.core"]
