"""VAT correctness: accelerated paths == pure-Python oracle (paper's claim
of unchanged mathematical behaviour), plus structural properties."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro import core
from repro.core import naive


def _data(seed, n, d):
    rng = np.random.default_rng(seed)
    # spread points out to avoid distance ties (tie-break conventions differ
    # only in degenerate data)
    return (rng.normal(size=(n, d)) * rng.uniform(0.5, 2.0, size=d)
            ).astype(np.float32)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(4, 60),
       d=st.integers(1, 8))
def test_vat_matches_naive(seed, n, d):
    X = _data(seed, n, d)
    res = core.vat(jnp.asarray(X))
    rstar_n, order_n = naive.vat_naive(X.tolist())
    assert np.array_equal(np.asarray(res.order), np.asarray(order_n))
    # f32 Gram trick vs float64 python loops: near-zero distances keep
    # O(sqrt(eps_f32)) absolute error
    np.testing.assert_allclose(np.asarray(res.rstar), np.asarray(rstar_n),
                               atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(4, 40))
def test_order_is_permutation(seed, n):
    X = _data(seed, n, 3)
    order = np.asarray(core.vat(jnp.asarray(X)).order)
    assert sorted(order.tolist()) == list(range(n))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(5, 40))
def test_ivat_matches_naive_and_is_ultrametric(seed, n):
    X = _data(seed, n, 3)
    res = core.vat(jnp.asarray(X))
    iv = core.ivat_from_vat(res.rstar)
    iv_n = np.asarray(naive.ivat_naive(np.asarray(res.rstar).tolist()))
    np.testing.assert_allclose(np.asarray(iv), iv_n, atol=1e-4)
    ivn = np.asarray(iv)
    # geodesic max-min distance never exceeds the direct distance
    assert np.all(ivn <= np.asarray(res.rstar) + 1e-4)
    # strong (ultrametric) triangle inequality d(i,k) <= max(d(i,j), d(j,k))
    for _ in range(20):
        i, j, k = np.random.default_rng(seed).integers(0, n, 3)
        assert ivn[i, k] <= max(ivn[i, j], ivn[j, k]) + 1e-4


def test_vat_reveals_blocks_on_clustered_data():
    rng = np.random.default_rng(0)
    X = np.concatenate([rng.normal(size=(50, 2)),
                        rng.normal(size=(50, 2)) + 12.0]).astype(np.float32)
    res = core.vat(jnp.asarray(X))
    score, k_est = core.block_structure_score(res.rstar)
    assert float(score) > 0.5
    assert int(k_est) == 2
    # the ordering keeps each cluster contiguous
    first_half = set(np.asarray(res.order)[:50].tolist())
    assert first_half in ({*range(50)}, {*range(50, 100)})


def test_vat_from_dist_equivalent():
    X = _data(3, 30, 4)
    from repro.kernels import ops
    R = ops.pairwise_dist(jnp.asarray(X))
    a = core.vat(jnp.asarray(X))
    b = core.vat_from_dist(R)
    assert np.array_equal(np.asarray(a.order), np.asarray(b.order))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(5, 40))
def test_vat_invariant_to_input_permutation(seed, n):
    """Shuffling the input points permutes the ordering but preserves the
    reordered image's entry multiset (same MST geometry)."""
    X = _data(seed, n, 3)
    perm = np.random.default_rng(seed).permutation(n)
    a = core.vat(jnp.asarray(X))
    b = core.vat(jnp.asarray(X[perm]))
    ea = np.sort(np.asarray(a.rstar), axis=None)
    eb = np.sort(np.asarray(b.rstar), axis=None)
    np.testing.assert_allclose(ea, eb, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_vat_keeps_separated_clusters_contiguous(seed):
    """Any well-separated cluster occupies a contiguous index range in the
    VAT ordering (the theoretical guarantee behind the dark blocks)."""
    rng = np.random.default_rng(seed)
    sizes = rng.integers(5, 20, size=3)
    centers = np.array([[0, 0], [40, 0], [0, 40]], np.float32)
    X = np.concatenate([
        centers[i] + rng.normal(size=(s, 2)).astype(np.float32)
        for i, s in enumerate(sizes)])
    labels = np.repeat(np.arange(3), sizes)
    order = np.asarray(core.vat(jnp.asarray(X)).order)
    lab_in_order = labels[order]
    # each label appears as one contiguous run
    changes = int(np.sum(lab_in_order[1:] != lab_in_order[:-1]))
    assert changes == 2
