"""Metric pluggability (ISSUE 3): per-metric correctness properties,
Pallas-vs-XLA-ref agreement, brute-force ordering oracles, and the
precomputed-dissimilarity round trip."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.api import FastVAT
from repro.core.naive import vat_order_naive
from repro.kernels import ops, ref
from repro.kernels.pairwise_dist import (pairwise_dist_pallas,
                                         pairwise_dist_pallas_batch)

METRICS = ref.METRICS
TRIANGLE_METRICS = ("euclidean", "manhattan")  # true metrics; sqeuclidean
                                               # and 1-cos are not


def _numpy_dissim(X, Y, metric):
    """Independent numpy oracle — direct broadcast formulas, no Gram trick."""
    X = np.asarray(X, np.float64)
    Y = np.asarray(Y, np.float64)
    diff = X[:, None, :] - Y[None, :, :]
    if metric == "euclidean":
        return np.sqrt(np.sum(diff * diff, -1))
    if metric == "sqeuclidean":
        return np.sum(diff * diff, -1)
    if metric == "manhattan":
        return np.sum(np.abs(diff), -1)
    nx = np.linalg.norm(X, axis=-1)
    ny = np.linalg.norm(Y, axis=-1)
    denom = np.maximum(nx[:, None] * ny[None, :], 1e-12)
    return np.clip(1.0 - (X @ Y.T) / denom, 0.0, 2.0)


def _points(seed, n, d):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(n, d)).astype(np.float32) + 0.5)


# ------------------------------------------------------- properties ----

@pytest.mark.parametrize("metric", METRICS)
@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000), n=st.integers(2, 60), d=st.integers(1, 9))
def test_metric_properties(metric, seed, n, d):
    """Symmetry, zero diagonal, non-negativity — for every metric, on
    both dispatch paths."""
    X = _points(seed, n, d)
    for use_pallas in (False, True):
        R = np.asarray(ops.pairwise_dist(X, metric=metric,
                                         use_pallas=use_pallas))
        np.testing.assert_allclose(R, R.T, atol=1e-5)
        np.testing.assert_allclose(np.diag(R), 0.0, atol=1e-6)
        assert R.min() >= 0.0


@pytest.mark.parametrize("metric", TRIANGLE_METRICS)
@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 1000), n=st.integers(3, 30))
def test_triangle_inequality(metric, seed, n):
    """d(i,k) <= d(i,j) + d(j,k) for the true metrics, all triples."""
    X = _points(seed, n, 4)
    R = np.asarray(ops.pairwise_dist(X, metric=metric), np.float64)
    lhs = R[:, None, :]                       # d(i, k)
    rhs = R[:, :, None] + R[None, :, :]       # d(i, j) + d(j, k)
    assert np.all(lhs <= rhs + 1e-4)


def test_metric_matches_independent_numpy_oracle():
    """The XLA refs agree with direct float64 broadcast formulas — so the
    Gram-trick decomposition can't hide a shared misunderstanding."""
    rng = np.random.default_rng(3)
    X = jnp.asarray(rng.normal(size=(40, 6)).astype(np.float32))
    Y = jnp.asarray(rng.normal(size=(23, 6)).astype(np.float32))
    for metric in METRICS:
        got = np.asarray(ref.pairwise_dissim_ref(X, Y, metric=metric))
        want = _numpy_dissim(X, Y, metric)
        np.testing.assert_allclose(got, want, atol=5e-4)


# ------------------------------------------- pallas vs ref, per metric ----

@pytest.mark.parametrize("metric", METRICS)
@pytest.mark.parametrize("n,m,d", [(17, 9, 3), (64, 64, 4), (100, 37, 130)])
def test_pairwise_pallas_matches_ref_per_metric(metric, n, m, d):
    rng = np.random.default_rng(n * 100 + m + d)
    X = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    Y = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    got = pairwise_dist_pallas(X, Y, metric=metric, interpret=True)
    want = ref.pairwise_dissim_ref(X, Y, metric=metric)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=5e-3)


@pytest.mark.parametrize("metric", METRICS)
def test_pairwise_batch_pallas_matches_ref_per_metric(metric):
    rng = np.random.default_rng(7)
    X = jnp.asarray(rng.normal(size=(3, 33, 5)).astype(np.float32))
    got = pairwise_dist_pallas_batch(X, metric=metric, interpret=True)
    want = jax.vmap(
        lambda A: ref.pairwise_dissim_ref(A, metric=metric))(X)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=5e-3)


@pytest.mark.parametrize("metric", METRICS)
def test_facade_pallas_ordering_matches_xla_per_metric(metric):
    """Acceptance: Pallas and XLA paths agree per metric through FastVAT."""
    rng = np.random.default_rng(11)
    X = np.concatenate([rng.normal(size=(25, 4)),
                        rng.normal(size=(25, 4)) + 6]).astype(np.float32)
    a = FastVAT(metric=metric).fit(X)
    b = FastVAT(metric=metric, use_pallas=True).fit(X)
    assert np.array_equal(a.order(), b.order())


# ------------------------------------------- brute-force order oracles ----

@pytest.mark.parametrize("metric", METRICS)
def test_facade_ordering_pinned_against_naive_prim(metric):
    """Acceptance: FastVAT(metric=...).fit(X) reproduces the pure-Python
    Prim oracle run on the same dissimilarity matrix, bitwise."""
    rng = np.random.default_rng(13)
    X = np.concatenate([rng.normal(size=(20, 3)),
                        rng.normal(size=(20, 3)) + 7]).astype(np.float32)
    R = np.asarray(ops.pairwise_dist(jnp.asarray(X), metric=metric),
                   np.float64)
    want = vat_order_naive(R.tolist())
    got = FastVAT(metric=metric).fit(X).order()
    assert got.tolist() == want


def test_facade_auto_policy_matches_naive_prim_on_adversarial_data():
    """ISSUE 10 satellite: on the shared adversarial pool (huge common
    offset) the default auto policy conditions and switches to direct
    -form tiles — and the fit still reproduces the pure-Python Prim run
    on the conditioned matrix bitwise."""
    from _numerics_data import adversarial
    from repro.numerics import resolve
    X = adversarial("offset_clusters", n=48)
    for metric in ("euclidean", "sqeuclidean", "manhattan"):
        Xc, rep = resolve(X, metric=metric)
        assert rep.conditioned and rep.form == "direct"
        R = np.asarray(ops.pairwise_dist(jnp.asarray(Xc), metric=metric,
                                         form="direct"), np.float64)
        want = vat_order_naive(R.tolist())
        got = FastVAT(metric=metric).fit(X).order()
        assert got.tolist() == want


# --------------------------------------------------- precomputed input ----

def test_precomputed_round_trip_bitwise():
    """Acceptance: fit(squareform(pdist(X))) reproduces fit(X)'s ordering
    bitwise.  The matrix handed in is the exact f32 matrix the euclidean
    fit computes internally, so the Prim pass must visit identically."""
    rng = np.random.default_rng(17)
    X = np.concatenate([rng.normal(size=(40, 5)),
                        rng.normal(size=(40, 5)) + 9]).astype(np.float32)
    direct = FastVAT().fit(X)
    D = np.asarray(ops.pairwise_dist(jnp.asarray(X)))
    via_matrix = FastVAT(metric="precomputed").fit(D)
    assert np.array_equal(via_matrix.order(), direct.order())
    np.testing.assert_array_equal(
        via_matrix.image(use_ivat=False), direct.image(use_ivat=False))
    scipy = pytest.importorskip("scipy.spatial.distance")
    D2 = scipy.squareform(scipy.pdist(X)).astype(np.float32)
    via_scipy = FastVAT(metric="precomputed").fit(D2)
    assert np.array_equal(via_scipy.order(), direct.order())


def test_precomputed_batched_round_trip():
    rng = np.random.default_rng(19)
    Xs = rng.normal(size=(3, 30, 4)).astype(np.float32)
    direct = FastVAT(method="ivat").fit_many(Xs)
    Ds = np.asarray(ops.pairwise_dist_batch(jnp.asarray(Xs)))
    via = FastVAT(method="ivat", metric="precomputed").fit_many(Ds)
    assert np.array_equal(via.order(), direct.order())
    np.testing.assert_array_equal(via.image(), direct.image())
    reps = via.assess()
    assert len(reps) == 3 and all(np.isnan(r["hopkins"]) for r in reps)


def test_precomputed_validation():
    fv = FastVAT(metric="precomputed")
    with pytest.raises(ValueError, match="square"):
        fv.fit(np.zeros((4, 5), np.float32))
    asym = np.triu(np.ones((5, 5), np.float32), 1)
    with pytest.raises(ValueError, match="symmetric"):
        fv.fit(asym)
    hot_diag = np.ones((5, 5), np.float32)
    with pytest.raises(ValueError, match="diagonal"):
        fv.fit(hot_diag)
    with pytest.raises(ValueError, match="precomputed"):
        FastVAT(method="svat", metric="precomputed").fit(
            np.zeros((6, 6), np.float32))


def test_precomputed_auto_falls_back_to_exact_rung():
    """Auto-selection with a precomputed matrix picks the exact rung even
    past SMALL_N — the O(n^2) object already exists. Holds for fit_many
    too (strict batching only applies to raw-data input)."""
    from repro.api import SMALL_N, select_method
    assert select_method(SMALL_N * 2, precomputed=True) == "vat"
    assert select_method(SMALL_N * 2, precomputed=True,
                         batched=True) == "vat"
    n = 80
    rng = np.random.default_rng(31)
    Xs = rng.normal(size=(2, n, 3)).astype(np.float32)
    Ds = np.asarray(ops.pairwise_dist_batch(jnp.asarray(Xs)))
    fv = FastVAT(metric="precomputed").fit_many(Ds)   # auto resolves
    assert fv.method_resolved == "vat" and fv.order().shape == (2, n)


def test_metric_validation():
    with pytest.raises(ValueError, match="metric"):
        FastVAT(metric="hamming")
    with pytest.raises(ValueError, match="metric"):
        ops.pairwise_dist(jnp.zeros((3, 2)), metric="precomputed")


def test_manhattan_finds_translated_blobs():
    rng = np.random.default_rng(23)
    X = np.concatenate([rng.normal(size=(30, 6)),
                        rng.normal(size=(30, 6)) + 10]).astype(np.float32)
    rep = FastVAT(metric="manhattan").fit(X).assess()
    assert rep["k_est"] == 2 and rep["metric"] == "manhattan"


def test_cosine_finds_directional_clusters():
    """Cosine sees *direction*: two clusters along orthogonal axes are
    separated even though their radii overlap completely."""
    rng = np.random.default_rng(29)
    r = rng.uniform(1.0, 10.0, size=(60, 1))
    axis = np.zeros((60, 4), np.float32)
    axis[:30, 0] = 1.0
    axis[30:, 1] = 1.0
    X = (r * (axis + 0.05 * rng.normal(size=(60, 4)))).astype(np.float32)
    rep = FastVAT(metric="cosine").fit(X).assess()
    assert rep["k_est"] == 2 and rep["metric"] == "cosine"
    # euclidean can't: the radial spread drowns the angular gap
    rep_e = FastVAT(metric="euclidean").fit(X).assess()
    assert rep_e["block_score"] < rep["block_score"]
