"""The approx rung's API seams: auto-selection boundaries (monkeypatched
thresholds, like test_turbo.py's VMEM-seam tests), capability flags, the
error report riding on ``ResultMeta``, and the memory story — a dispatch
census pinning the kNN kernel to Pallas calls plus the no-(n,n) tripwire
mirror from test_bigvat.py."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import core
from repro.api import FastVAT, registry as reg, select_method
from repro.api.registry import MEDIUM_N
from repro.core.approx_mst import ApproxStats, knn_graph_anchored
from repro.kernels import ops as kops


def _blobs(n, k=3, d=2, seed=0, sep=40.0):
    rng = np.random.default_rng(seed)
    centers = (sep * rng.normal(size=(k, d))).astype(np.float32)
    lab = rng.integers(0, k, size=n)
    X = centers[lab] + rng.normal(scale=1.0, size=(n, d)).astype(np.float32)
    return X.astype(np.float32), lab.astype(np.int32)


def _lower_threshold(monkeypatch, name: str, threshold: float):
    """Re-register `name` with a test threshold, restored on teardown."""
    monkeypatch.setitem(
        reg._REGISTRY, name,
        dataclasses.replace(reg.get_rung(name), auto_threshold=threshold))


# ------------------------------------------------ selection seams ----

def test_exact_to_approx_boundary_flips_at_threshold(monkeypatch):
    """±1 around the flashvat ceiling flips the auto route to approx —
    exercised at a test-sized threshold so the fixture stays tiny."""
    assert select_method(MEDIUM_N) == "flashvat"
    assert select_method(MEDIUM_N + 1) == "approx"
    _lower_threshold(monkeypatch, "vat", 50)
    _lower_threshold(monkeypatch, "flashvat", 100)
    assert select_method(100) == "flashvat"
    assert select_method(101) == "approx"
    assert select_method(10**9) == "approx"    # the unbounded fallback


def test_auto_fit_routes_approx_past_threshold(monkeypatch):
    """A fit just past the (lowered) exact ceiling resolves approx
    end-to-end: banded image, spanning order, stats on meta."""
    _lower_threshold(monkeypatch, "vat", 50)
    _lower_threshold(monkeypatch, "flashvat", 100)
    X, lab = _blobs(300, k=3, seed=2)
    fv = FastVAT(sample_size=32, knn_k=8).fit(X)
    assert fv.method_resolved == "approx"
    assert sorted(fv.order().tolist()) == list(range(300))
    assert fv.image(resolution=64).shape == (64, 64)
    s = fv.result.meta.approx
    assert isinstance(s, ApproxStats) and s.k == 8
    rep = fv.assess()
    assert rep["method"] == "approx" and rep["k_est"] == 3


def test_auto_fit_routes_exact_at_threshold(monkeypatch):
    _lower_threshold(monkeypatch, "vat", 50)
    _lower_threshold(monkeypatch, "flashvat", 100)
    X, _ = _blobs(100, k=3, seed=2)
    fv = FastVAT(sample_size=32).fit(X)
    assert fv.method_resolved == "flashvat"
    assert fv.result.meta.approx is None       # exact rungs report none


# ---------------------------------------------- capability flags ----

def test_approx_rung_capabilities():
    rung = reg.get_rung("approx")
    assert rung.auto_threshold == float("inf")
    assert not rung.supports_precomputed       # needs points, not a matrix
    assert not rung.supports_batch
    assert reg.get_rung("bigvat").auto_threshold is None   # demoted: opt-in
    assert "approx" in reg.methods()


def test_approx_rejects_precomputed():
    D = np.zeros((8, 8), np.float32)
    with pytest.raises(ValueError, match="precomputed"):
        FastVAT(method="approx", metric="precomputed").fit(D)


def test_precomputed_auto_still_falls_back_exact():
    """Huge-n precomputed input keeps routing to the exact rung — the
    (n, n) matrix already exists, so approx has nothing to save."""
    assert select_method(10**9, precomputed=True) == "vat"


def test_explicit_bigvat_still_available():
    X, _ = _blobs(400, k=2, seed=3)
    fv = FastVAT(method="bigvat", sample_size=32).fit(X)
    assert fv.method_resolved == "bigvat"


# -------------------------------------------- meta / pytree seams ----

def test_approx_stats_meta_stays_valid_pytree_aux():
    """ApproxStats is frozen + hashable, so a TendencyResult carrying it
    survives flatten/unflatten (meta is static aux data)."""
    X, _ = _blobs(200, k=2, seed=4)
    res = FastVAT(method="approx", knn_k=6, sample_size=16).fit(X).result
    assert hash(res.meta) == hash(res.meta)
    leaves, treedef = jax.tree_util.tree_flatten(res)
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert back.meta.approx == res.meta.approx


# ------------------------------------------------ dispatch census ----

def _iter_avals(jaxpr):
    """Every intermediate abstract value a jaxpr (and its subjaxprs) binds."""
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            if hasattr(v.aval, "shape"):
                yield v.aval
        for p in eqn.params.values():
            for u in (p if isinstance(p, (list, tuple)) else (p,)):
                sub = getattr(u, "jaxpr", u)
                if hasattr(sub, "eqns"):
                    yield from _iter_avals(sub)


def test_knn_kernel_census_pallas_no_nxn_no_f64():
    """The dispatch pin: the Pallas kNN path holds >= 1 pallas_call in
    its jaxpr while the blocked XLA path holds none — and NEITHER ever
    binds an (n, n)-sized intermediate nor any float64 array (the
    memory contract the million-point rung rests on)."""
    n, k, block = 600, 8, 128
    X = jnp.asarray(np.random.default_rng(0).normal(
        size=(n, 4)).astype(np.float32))
    pal = kops.kernel_dispatch_stats(
        lambda A: kops.knn_graph(A, k=k, use_pallas=True, block=block), X)
    xla = kops.kernel_dispatch_stats(
        lambda A: kops.knn_graph(A, k=k, use_pallas=False, block=block), X)
    assert pal["pallas_calls"] >= 1, pal
    assert xla["pallas_calls"] == 0, xla
    for use_pallas in (True, False):
        jaxpr = jax.make_jaxpr(
            lambda A: kops.knn_graph(A, k=k, use_pallas=use_pallas,
                                     block=block))(X).jaxpr
        avals = list(_iter_avals(jaxpr))
        biggest = max(int(np.prod(a.shape, dtype=int)) for a in avals)
        assert biggest < n * n / 4, (use_pallas, biggest)
        assert not any(a.dtype == np.float64 for a in avals), use_pallas


# ------------------------------------------------ no-(n,n) tripwire ----

def test_anchored_knn_never_materializes_nxn(monkeypatch):
    """Tripwire mirror of test_bigvat: every distance tile the anchored
    assignment pass produces is (assign_block, anchors) at most —
    nothing O(n^2) — and the pass IS tripwire-visible (it goes through
    kernels.ops.pairwise_dist, not around it)."""
    n, k, ab = 5_000, 6, 1_024
    X, _ = _blobs(n, k=3, seed=5)
    shapes = []
    real = kops.pairwise_dist

    def recording(Xa, Ya=None, **kw):
        out = real(Xa, Ya, **kw)
        shapes.append(tuple(out.shape))
        return out

    monkeypatch.setattr(kops, "pairwise_dist", recording)
    dist, idx = knn_graph_anchored(X, k=k, assign_block=ab)
    assert dist.shape == (n, k) and idx.shape == (n, k)
    assert dist.dtype == np.float32            # never an (n, k) float64
    assert shapes, "anchored pass never went through kernels.ops.pairwise_dist"
    assert all(r <= ab and c < n for r, c in shapes), shapes
    # and the graph it built is usable: mostly-filled valid slots
    valid = np.isfinite(dist) & (idx >= 0)
    assert valid.mean() > 0.95


def test_approx_fit_path_never_materializes_nxn(monkeypatch):
    """End-to-end tripwire on the registry fit: every pairwise_dist call
    the whole approx fit makes (band rendering included) stays far below
    (n, n)."""
    n = 2_000
    X, _ = _blobs(n, k=3, seed=6)
    shapes = []
    real = kops.pairwise_dist

    def recording(Xa, Ya=None, **kw):
        out = real(Xa, Ya, **kw)
        shapes.append(tuple(out.shape))
        return out

    monkeypatch.setattr(kops, "pairwise_dist", recording)
    fv = FastVAT(method="approx", sample_size=64, knn_k=8).fit(X)
    assert fv.method_resolved == "approx"
    assert all(r * c <= n * 64 for r, c in shapes), shapes


# -------------------------------------------- demo acceptance test ----

def test_approx_demo_acceptance(monkeypatch):
    """examples/approx_demo.py shrunk to test size: end-to-end through
    the demo's own run(), with the memory pins — every pairwise_dist
    tile far below (n, n), int32 ordering out, working set a small
    fraction of the dense matrix."""
    import importlib.util
    import os
    path = os.path.join(os.path.dirname(__file__), "..", "examples",
                        "approx_demo.py")
    spec = importlib.util.spec_from_file_location("approx_demo", path)
    demo = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(demo)

    shapes = []
    real = kops.pairwise_dist

    def recording(Xa, Ya=None, **kw):
        out = real(Xa, Ya, **kw)
        shapes.append(tuple(out.shape))
        return out

    monkeypatch.setattr(kops, "pairwise_dist", recording)
    n = 1_500
    info = demo.run(n=n, k=6, sample_size=32)
    assert info["method"] == "approx"
    assert sorted(info["order"].tolist()) == list(range(n))
    assert info["order"].dtype == np.int32
    assert info["runs"] == 5                   # 5 generated blobs
    assert info["stats"].k == 6
    assert all(r * c <= n * 64 for r, c in shapes), shapes
    assert info["working_bytes"] * 20 < info["dense_bytes"]
