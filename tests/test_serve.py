"""The serving layer's certification suite (ISSUE 7).

Four pillars:

* **Deterministic concurrency** — the virtual-clock rig
  (tests/_serve_clock.py) drives the clock-free ``CoalescerCore`` and
  asserts exactly which requests land in which batch: window flushes,
  max-batch closure, deadline expiry (including the deadline==flush
  tie, which rides the batch), backpressure, drain.  Zero real sleeps.
* **Program-cache census** — a warm-cache request compiles ZERO new
  programs (trace counter + cache miss deltas), every code-shaping
  knob is in the ProgramKey (distinctness sweep), LRU eviction at the
  configured bound.
* **Bitwise fidelity** — served results equal solo ``FastVAT.fit``
  bit for bit across rungs and metrics, for coalesced batches, and
  under real-thread mixed-shape concurrent load; the pad-to-bucket
  invariant is property-tested at bucket boundaries +-1 (hypothesis
  stub).
* **Routing + lifecycle** — SLO cost-model routing, precomputed/oversize
  rejection, warm(), close() drain semantics, warm-below-cold latency.
"""
import os
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from _serve_clock import CoalesceRig, VirtualClock, make_key
from repro.api import FastVAT
from repro.api.registry import predict_latency_us, select_method_for_slo
from repro.serve import (Backpressure, DeadlineExceeded, ProgramCache,
                         ServeConfig, ServeError, TendencyServer, bucket_n,
                         pad_rows, real_positions, resolve_key, restrict,
                         trace_census)


def _blobs(n, d=3, seed=0):
    rng = np.random.default_rng(seed)
    half = n // 2
    return np.concatenate([
        rng.normal(size=(half, d)),
        rng.normal(size=(n - half, d)) + 6.0]).astype(np.float32)


def _solo(X, method, metric="euclidean"):
    return FastVAT(method=method, metric=metric).fit(X).result


def _same_result(a, b) -> bool:
    """Bitwise equality of two TendencyResults' array fields."""
    for f in ("order", "rstar", "ivat_image", "sample_idx",
              "extension_labels", "group_sizes"):
        va, vb = getattr(a, f), getattr(b, f)
        if (va is None) != (vb is None):
            return False
        if va is not None and not np.array_equal(np.asarray(va),
                                                 np.asarray(vb)):
            return False
    return True


# ================================================ virtual-clock rig ====
# Pure scheduling logic: no JAX, no threads, no sleeps.

def test_window_coalesces_same_bucket():
    rig = CoalesceRig(window=1.0)
    rig.submit("a", 0.0)
    rig.submit("b", 0.5)                      # same bucket, inside window
    assert rig.batch_tags() == []             # window still open
    rig.run_until(1.0)                        # flush at opened + window
    assert rig.batch_tags() == [["a", "b"]]
    assert rig.dispatches[0][0] == 1.0


def test_distinct_buckets_never_share_a_batch():
    rig = CoalesceRig(window=1.0)
    rig.submit("small", 0.0, n=100)           # bucket 128
    rig.submit("large", 0.1, n=200)           # bucket 256
    rig.run_until(2.0)
    assert rig.batch_tags() == [["small"], ["large"]]
    assert rig.dispatches[0][1].n_bucket == 128
    assert rig.dispatches[1][1].n_bucket == 256


def test_max_batch_flushes_immediately():
    rig = CoalesceRig(window=1.0, max_batch=2)
    rig.submit("a", 0.0)
    rig.submit("b", 0.1)                      # hits max_batch: no waiting
    assert rig.batch_tags() == [["a", "b"]]
    assert rig.dispatches[0][0] == 0.1
    rig.submit("c", 0.2)                      # opens a NEW window
    rig.run_until(1.2)
    assert rig.batch_tags() == [["a", "b"], ["c"]]


def test_deadline_expires_queued_request():
    rig = CoalesceRig(window=1.0)
    rig.submit("doomed", 0.0, timeout_s=0.4)
    rig.run_until(2.0)
    assert rig.expired == [(0.4, "doomed")]
    assert rig.batch_tags() == []             # nothing left to dispatch


def test_deadline_expires_one_lane_batch_survives():
    rig = CoalesceRig(window=1.0)
    rig.submit("doomed", 0.0, timeout_s=0.4)
    rig.submit("alive", 0.0, timeout_s=10.0)
    rig.run_until(1.0)
    assert rig.expired == [(0.4, "doomed")]
    assert rig.batch_tags() == [["alive"]]


def test_deadline_equal_to_flush_rides_the_batch():
    # events at equal time are ordered flush-first (coalesce.next_event's
    # (time, kind) tuple), so deadline == window-flush means served
    rig = CoalesceRig(window=1.0)
    rig.submit("edge", 0.0, timeout_s=1.0)
    rig.run_until(1.0)
    assert rig.expired == []
    assert rig.batch_tags() == [["edge"]]


def test_backpressure_bounds_the_queue():
    rig = CoalesceRig(window=10.0, max_pending=2)
    rig.submit("a", 0.0)
    rig.submit("b", 0.1, n=200)               # different bucket, still queued
    with pytest.raises(Backpressure):
        rig.submit("c", 0.2)
    assert rig.core.rejected == 1
    assert rig.core.pending == 2              # rejected request not queued


def test_due_flush_at_full_queue_submit_is_never_lost():
    """REVIEW regression: a submit arriving with the queue full while a
    window flush is due must dispatch that flush (poll-then-enqueue),
    not raise a Backpressure that strands the batch's futures — and the
    freed capacity admits the new request."""
    rig = CoalesceRig(window=1.0, max_pending=2)
    rig.submit("a", 0.0)                      # bucket 128
    rig.submit("b", 0.5, n=200)               # bucket 256; queue now full
    rig.submit("c", 1.0)                      # a's flush due exactly now
    assert rig.batch_tags() == [["a"]]        # the due flush was recorded
    assert rig.core.pending == 2              # b still queued, c admitted
    assert rig.core.rejected == 0


def test_due_expiry_at_full_queue_submit_is_never_lost():
    """Same protocol for deadlines: a due expiry at submit time is
    recorded (its future will be failed), never swallowed by the
    bound check."""
    rig = CoalesceRig(window=10.0, max_pending=2)
    rig.submit("a", 0.0, timeout_s=0.4)
    rig.submit("b", 0.1, n=200)
    rig.submit("c", 0.5)                      # a's deadline due at 0.4
    assert rig.expired == [(0.4, "a")]
    assert rig.core.pending == 2 and rig.core.rejected == 0


def test_rejection_has_no_side_effects_on_the_queue():
    """try_enqueue's Backpressure raise must leave the queue exactly as
    if the rejected submit never happened — queued requests, their
    windows, and the event schedule are untouched."""
    rig = CoalesceRig(window=10.0, max_pending=2)
    rig.submit("a", 0.0)
    rig.submit("b", 0.1, n=200)
    before = (rig.core.pending, rig.core.submitted, rig.core.next_event())
    with pytest.raises(Backpressure):
        rig.submit("c", 0.2)
    assert (rig.core.pending, rig.core.submitted,
            rig.core.next_event()) == before
    assert rig.core.rejected == 1
    rig.run_until(10.1)                       # both still flush normally
    assert rig.batch_tags() == [["a"], ["b"]]


def test_late_arrival_opens_a_fresh_window():
    rig = CoalesceRig(window=1.0)
    rig.submit("a", 0.0)
    rig.run_until(3.0)
    rig.submit("b", 5.0)
    rig.run_until(5.5)
    assert rig.batch_tags() == [["a"]]        # b's window open until 6.0
    rig.run_until(6.0)
    assert rig.batch_tags() == [["a"], ["b"]]


def test_drain_flushes_open_windows_but_honors_deadlines():
    rig = CoalesceRig(window=100.0)
    rig.submit("late", 0.0, timeout_s=0.5)
    rig.submit("fine", 0.0, timeout_s=50.0)
    rig.drain(1.0)                            # shutdown long before flush
    assert rig.expired == [(0.5, "late")]
    assert rig.batch_tags() == [["fine"]]


def test_scheduler_counters():
    rig = CoalesceRig(window=1.0, max_batch=8)
    for i, t in enumerate([0.0, 0.2, 0.4]):
        rig.submit(i, t)
    rig.run_until(1.0)
    c = rig.core
    assert (c.submitted, c.dispatched_batches, c.dispatched_requests,
            c.timeouts, c.rejected, c.pending) == (3, 1, 3, 0, 0, 0)


def test_virtual_clock_is_monotonic():
    clk = VirtualClock(5.0)
    assert clk() == 5.0
    clk.advance(1.5)
    assert clk() == 6.5
    with pytest.raises(ValueError):
        clk.set(2.0)
    with pytest.raises(ValueError):
        clk.advance(-1.0)


# ============================================== program-cache census ===

def test_every_code_shaping_knob_is_key_material():
    """Any knob that changes compiled code must change the ProgramKey."""
    base = dict(n=100, d=4)
    variants = [
        make_key(**base),
        make_key(**base, rung="ivat"),
        make_key(n=100, d=4, rung="flashvat"),
        make_key(**base, metric="cosine"),
        make_key(**base, metric="manhattan"),
        make_key(n=300, d=4),                     # different n-bucket
        make_key(n=100, d=8),                     # d is never padded
        make_key(**base, mesh="tpu:8"),           # mesh fingerprint
        make_key(**base, turbo=True),
        make_key(**base, turbo=False),
        make_key(**base, knn_k=31),
        make_key(**base, use_pallas=True),
        make_key(**base, sample_size=128),
        make_key(**base).with_batch(2),
        make_key(**base).with_batch(4),
    ]
    assert len(set(variants)) == len(variants)


def test_flashvat_keys_on_exact_n_padded_rungs_on_bucket():
    cfg = ServeConfig()
    kv = resolve_key(100, 4, method="vat", config=cfg, mesh="test:1")
    kf = resolve_key(100, 4, method="flashvat", config=cfg, mesh="test:1")
    assert kv.n_bucket == bucket_n(100) == 128
    assert kf.n_bucket == 100                 # band-render shapes need n
    # two flashvat ns one bucket apart stay distinct programs
    kf2 = resolve_key(101, 4, method="flashvat", config=cfg, mesh="test:1")
    assert kf != kf2


def test_lru_eviction_at_capacity():
    cache = ProgramCache(capacity=2)
    k1, k2, k3 = (make_key(n, 4).with_batch(1) for n in (10, 100, 200))
    built = []
    for k in (k1, k2, k3):                    # k3 insertion evicts k1
        cache.get(k, lambda k=k: built.append(k) or object())
    assert built == [k1, k2, k3]
    assert k1 not in cache and k2 in cache and k3 in cache
    s = cache.stats()
    assert (s.hits, s.misses, s.evictions, s.size) == (0, 3, 1, 2)
    cache.get(k2, lambda: pytest.fail("k2 must be a hit"))
    assert cache.stats().hits == 1


def test_lru_hit_refreshes_recency():
    cache = ProgramCache(capacity=2)
    k1, k2, k3 = (make_key(n, 4).with_batch(1) for n in (10, 100, 200))
    cache.get(k1, object)
    cache.get(k2, object)
    cache.get(k1, object)                     # refresh k1 -> k2 is LRU
    cache.get(k3, object)
    assert k1 in cache and k2 not in cache and k3 in cache


def test_warm_cache_compiles_zero_new_programs():
    """The headline census pin: the second request in a bucket re-enters
    neither Python tracing nor XLA compilation."""
    with TendencyServer(ServeConfig(window_s=0.001)) as srv:
        srv.fit(_blobs(50))                   # cold: compiles bucket-64
        t0, s0 = trace_census()["traces"], srv.stats().cache
        res = srv.fit(_blobs(60, seed=1))     # same bucket, different n
        t1, s1 = trace_census()["traces"], srv.stats().cache
    assert t1 - t0 == 0
    assert s1.misses - s0.misses == 0
    assert s1.hits - s0.hits == 1
    assert _same_result(res, _solo(_blobs(60, seed=1), "vat"))


def test_warm_precompiles_the_request_path():
    with TendencyServer(ServeConfig(window_s=0.001)) as srv:
        key = srv.warm(50, 3, batch=1)
        assert key.b_bucket == 1 and key.n_bucket == 64
        t0, m0 = trace_census()["traces"], srv.stats().cache.misses
        srv.fit(_blobs(50))
        assert trace_census()["traces"] - t0 == 0
        assert srv.stats().cache.misses - m0 == 0


def test_warm_with_slo_precompiles_the_slo_routed_key():
    """REVIEW regression: SLO-routed traffic must be warmable — warm()
    with the requests' slo_ms targets the router's key (ivat here, not
    the size policy's vat), so the fits are pure cache hits."""
    with TendencyServer(ServeConfig(window_s=0.001)) as srv:
        key = srv.warm(60, 3, slo_ms=50.0, batch=1)
        assert key.rung == "ivat"             # size policy would say vat
        t0, m0 = trace_census()["traces"], srv.stats().cache.misses
        res = srv.fit(_blobs(60), slo_ms=50.0)
        assert trace_census()["traces"] - t0 == 0
        assert srv.stats().cache.misses - m0 == 0
    assert res.meta.method == "ivat"


# ============================================== bitwise fidelity =======

@pytest.mark.parametrize("method,metric", [
    ("vat", "euclidean"), ("vat", "sqeuclidean"),
    ("vat", "manhattan"), ("vat", "cosine"),
    ("ivat", "euclidean"), ("ivat", "cosine"),
    ("flashvat", "euclidean"), ("flashvat", "manhattan"),
])
def test_served_equals_solo_bitwise(method, metric):
    X = _blobs(60)
    with TendencyServer(ServeConfig(window_s=0.001)) as srv:
        served = srv.fit(X, method=method, metric=metric)
    assert served.meta.method == method
    assert _same_result(served, _solo(X, method, metric))


def test_coalesced_batch_members_equal_solo_bitwise():
    """Four requests in one window -> ONE batched dispatch, every lane
    bitwise-identical to its solo fit."""
    Xs = [_blobs(40 + 7 * i, seed=i) for i in range(4)]
    cfg = ServeConfig(window_s=0.25, max_batch=8)
    with TendencyServer(cfg) as srv:
        srv.warm(64, 3, method="vat", batch=4)
        futures = [srv.submit(X, method="vat") for X in Xs]
        results = [f.result(timeout=60) for f in futures]
        st = srv.stats()
    assert st.dispatched_batches == 1
    assert st.dispatched_requests == 4
    assert st.coalesce_rate == 4.0
    for X, res in zip(Xs, results):
        assert _same_result(res, _solo(X, "vat"))


def test_mixed_concurrent_stress_is_bitwise_exact():
    """Real threads, mixed shapes/metrics/rungs submitted concurrently;
    every result must equal its solo fit bit for bit."""
    cases = []
    for i in range(14):
        n = (40, 50, 60, 64)[i % 4]
        method = ("vat", "ivat")[i % 2]
        cases.append((_blobs(n, seed=i), method))
    cases += [(_blobs(80, seed=99), "flashvat"),
              (_blobs(80, seed=98), "flashvat")]
    cfg = ServeConfig(window_s=0.02, max_batch=4)
    with TendencyServer(cfg) as srv:
        with ThreadPoolExecutor(max_workers=8) as pool:
            futs = [pool.submit(
                lambda X, m: srv.submit(X, method=m).result(timeout=300),
                X, m) for X, m in cases]
            results = [f.result(timeout=300) for f in futs]
        st = srv.stats()
    assert st.submitted == len(cases)
    assert st.dispatched_requests == len(cases)
    assert st.timeouts == 0 and st.rejected == 0
    for (X, method), res in zip(cases, results):
        assert res.meta.method == method
        assert _same_result(res, _solo(X, method)), \
            f"served {method} n={X.shape[0]} diverged from solo"


# ------------------------------ pad-to-bucket property (hypothesis) ----

@settings(max_examples=8, deadline=None)
@given(n=st.sampled_from([63, 64, 65, 127, 128, 129, 255, 256, 257]),
       metric=st.sampled_from(["euclidean", "sqeuclidean", "manhattan",
                               "cosine"]),
       seed=st.integers(min_value=0, max_value=3))
def test_padding_never_perturbs_the_ordering(n, metric, seed):
    """Dup-row-0 padding to the bucket, then extraction, reproduces the
    unpadded fit bitwise — at bucket boundaries +-1, every metric."""
    X = _blobs(n, seed=seed)
    solo = _solo(X, "vat", metric)
    Xp = pad_rows(X, bucket_n(n))
    padded = _solo(Xp, "vat", metric)
    pos = real_positions(np.asarray(padded.order), n)
    assert np.array_equal(np.asarray(padded.order)[pos],
                          np.asarray(solo.order))
    assert np.array_equal(restrict(np.asarray(padded.rstar), pos),
                          np.asarray(solo.rstar))


def test_padding_preserves_the_ivat_image():
    n = 65                                    # just past a boundary
    X = _blobs(n, seed=2)
    solo = _solo(X, "ivat")
    padded = _solo(pad_rows(X, bucket_n(n)), "ivat")
    pos = real_positions(np.asarray(padded.order), n)
    assert np.array_equal(restrict(np.asarray(padded.ivat_image), pos),
                          np.asarray(solo.ivat_image))


# ============================================== routing ================

def test_slo_router_buys_fidelity_with_budget():
    # calibrated model at n=1024: vat ~18ms, flashvat ~33ms, ivat ~39ms
    servable = ("vat", "ivat", "flashvat")
    assert select_method_for_slo(1024, 50e3, restrict=servable) == "ivat"
    assert select_method_for_slo(1024, 20e3, restrict=servable) == "vat"
    # nothing fits a 1ms budget: degrade to the cheapest feasible rung
    assert select_method_for_slo(1024, 1e3, restrict=servable) == "vat"
    # past the materialized rungs' cap_n only flashvat is feasible
    assert select_method_for_slo(30_000, 60e6, restrict=servable) \
        == "flashvat"
    with pytest.raises(LookupError):
        select_method_for_slo(100, 1e3, restrict=("dvat",))  # unmodeled


def test_slo_router_ranks_fidelity_explicitly_not_by_cost():
    """REVIEW regression: flashvat's base cost dominates at small n, so
    it predicts COSTLIER than ivat while rendering a coarser picture —
    the router must rank by the explicit fidelity order, not cost."""
    servable = ("vat", "ivat", "flashvat")
    assert predict_latency_us("flashvat", 500) \
        > predict_latency_us("ivat", 500)
    assert select_method_for_slo(500, 40e3, restrict=servable) == "ivat"
    # unrestricted: approx's huge base cost must not buy it the win
    assert select_method_for_slo(200, 1e6) == "ivat"


def test_latency_model_predictions_are_monotonic():
    assert predict_latency_us("dvat", 100) is None
    for method in ("vat", "ivat", "flashvat", "approx"):
        lo, hi = (predict_latency_us(method, n) for n in (100, 10_000))
        assert lo is not None and hi > lo
    # coalescing amortizes base cost: 4 lanes < 4x one lane
    one = predict_latency_us("vat", 512)
    four = predict_latency_us("vat", 512, batch=4)
    assert one < four < 4 * one


def test_resolve_key_slo_routes_through_cost_model():
    cfg = ServeConfig()
    k = resolve_key(1024, 4, metric="euclidean", config=cfg,
                    slo_ms=50.0, mesh="test:1")
    assert k.rung == "ivat"
    k = resolve_key(1024, 4, metric="euclidean", config=cfg,
                    slo_ms=20.0, mesh="test:1")
    assert k.rung == "vat"


def test_precomputed_metric_is_rejected():
    with pytest.raises(ValueError, match="precomputed"):
        resolve_key(100, 100, metric="precomputed", config=ServeConfig(),
                    mesh="test:1")


def test_oversize_request_gets_actionable_error():
    with pytest.raises(ValueError, match="servable"):
        resolve_key(60_000, 4, config=ServeConfig(), mesh="test:1")


def test_unservable_method_is_rejected():
    with pytest.raises(ValueError, match="serving layer"):
        resolve_key(100, 4, method="bigvat", config=ServeConfig(),
                    mesh="test:1")


# ============================================== lifecycle ==============

def test_real_thread_deadline_timeout():
    # window far beyond the deadline: the request must expire, not fit
    cfg = ServeConfig(window_s=30.0)
    with TendencyServer(cfg) as srv:
        fut = srv.submit(_blobs(50), timeout_s=0.05)
        with pytest.raises(DeadlineExceeded):
            fut.result(timeout=10)
        deadline = time.monotonic() + 10
        while srv.stats().timeouts == 0 and time.monotonic() < deadline:
            time.sleep(0.005)
        assert srv.stats().timeouts == 1


def test_server_backpressure_leaves_queued_request_servable():
    """A rejected submit must not disturb the queued request: its
    future still resolves (close() drains it) bitwise-equal to solo."""
    srv = TendencyServer(ServeConfig(window_s=30.0, max_pending=1))
    fut = srv.submit(_blobs(50))
    with pytest.raises(Backpressure):
        srv.submit(_blobs(70))
    assert srv.stats().rejected == 1
    srv.close()
    assert _same_result(fut.result(timeout=60), _solo(_blobs(50), "vat"))


def test_close_drains_queued_requests():
    cfg = ServeConfig(window_s=30.0)          # would queue for 30s
    srv = TendencyServer(cfg)
    fut = srv.submit(_blobs(50))
    srv.close()                               # drain executes it now
    assert _same_result(fut.result(timeout=60), _solo(_blobs(50), "vat"))
    with pytest.raises(ServeError):
        srv.submit(_blobs(50))


def test_warm_cache_latency_strictly_below_cold():
    """The point of the AOT cache: a warm fit never pays trace/compile."""
    X = _blobs(50)
    with TendencyServer(ServeConfig(window_s=0.001)) as srv:
        t0 = time.perf_counter()
        srv.fit(X)                            # cold: trace + XLA compile
        cold = time.perf_counter() - t0
        warm = []
        for _ in range(5):
            t0 = time.perf_counter()
            srv.fit(X)
            warm.append(time.perf_counter() - t0)
    assert sorted(warm)[len(warm) // 2] < cold


def test_from_result_restores_the_facade_surface():
    X = _blobs(60)
    with TendencyServer(ServeConfig(window_s=0.001)) as srv:
        served = srv.fit(X)
    fv = FastVAT.from_result(served, X=X)
    ref = FastVAT(method="vat").fit(X)
    assert np.array_equal(fv.order(), ref.order())
    assert np.array_equal(fv.image(), ref.image())
    assert fv.assess() == ref.assess()


# ============================================== example acceptance =====

def test_serve_route_example_end_to_end():
    """examples/serve_route.py shrunk to test size: submit -> coalesce ->
    result through the real server, facts dict checked."""
    import importlib.util
    path = os.path.join(os.path.dirname(__file__), "..", "examples",
                        "serve_route.py")
    spec = importlib.util.spec_from_file_location("serve_route", path)
    demo = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(demo)
    facts = demo.run(n_requests=6, n_points=48, d=3, window_ms=250.0,
                     max_batch=8)
    assert facts["n_requests"] == 6
    assert facts["dispatched_batches"] == 1   # all six rode one window
    assert facts["coalesce_rate"] == 6.0
    assert facts["bitwise_vs_solo"] is True
    assert facts["slo_routed_rung"] == "ivat"
    assert facts["warm_hit_rate"] > 0.0


# ============================================== bench + schema v5 ======

def _bench_modules():
    # the bench harness is a repo-root namespace package; importable
    # when the suite runs from the repo root (the documented command)
    return (pytest.importorskip("benchmarks.bench"),
            pytest.importorskip("benchmarks.bench_schema"))


def test_bench_serve_warm_p50_strictly_below_cold():
    """The CI-gated serve table's acceptance pin: warm-cache p50 sits
    strictly below the cold start, and load rows carry percentiles."""
    bench, _ = _bench_modules()
    rows = bench.bench_serve(smoke=True, reps=2)
    by_name = {r["name"]: r for r in rows}
    cold = by_name["serve/n48/cold_fit"]["us_per_call"]
    warm = by_name["serve/n48/warm_fit"]
    assert warm["us_per_call"] < cold
    assert warm["percentiles"]["p50_us"] <= warm["percentiles"]["p99_us"]
    conc = by_name["serve/n48/concurrent_c4"]
    assert conc["derived"]["qps"] > 0
    assert conc["derived"]["coalesce_rate"] >= 1.0
    assert set(conc["percentiles"]) == {"p50_us", "p99_us"}


def test_bench_schema_v5_percentiles_rules():
    _, schema = _bench_modules()

    def doc(version, row_extra):
        row = {"table": "serve", "name": "serve/x", "metric": "euclidean",
               "us_per_call": 1.0, "peak_bytes": None, "derived": {},
               **row_extra}
        return {"schema_version": version,
                "created_utc": "2026-08-09T00:00:00Z",
                "host": {"platform": "p", "python": "3", "jax": "0",
                         "backend": "cpu", "cpu_count": 1},
                "config": {"smoke": True, "reps": 1, "tables": ["serve"]},
                "rows": [row]}

    good = {"percentiles": {"p50_us": 10.0, "p99_us": 20.0}}
    assert schema.validate(doc(5, good))
    with pytest.raises(ValueError, match="schema_version >= 5"):
        schema.validate(doc(4, good))
    with pytest.raises(ValueError, match="exactly keys"):
        schema.validate(doc(5, {"percentiles": {"p50_us": 1.0}}))
    with pytest.raises(ValueError, match="p99_us must be >= p50_us"):
        schema.validate(doc(5, {"percentiles": {"p50_us": 9.0,
                                                "p99_us": 1.0}}))
    with pytest.raises(ValueError, match="number >= 0"):
        schema.validate(doc(5, {"percentiles": {"p50_us": -1.0,
                                                "p99_us": 1.0}}))
