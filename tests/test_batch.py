"""Batched VAT engine: one compiled program over a (b, n, d) stack must be
bit-for-bit the same assessment as b solo runs (ISSUE 2 acceptance), on
both the XLA and the Pallas-interpret paths."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import core
from repro.api import FastVAT
from repro.kernels import ops, ref
from repro.kernels.pairwise_dist import pairwise_dist_pallas_batch


def _stack(seed=0, b=8, n=256, d=5):
    rng = np.random.default_rng(seed)
    scale = rng.uniform(0.5, 2.0, size=d).astype(np.float32)
    return jnp.asarray(rng.normal(size=(b, n, d)).astype(np.float32) * scale)


def test_vat_batch_bitwise_identical_to_solo():
    """The ISSUE 2 acceptance stack: (8, 256, d)."""
    Xb = _stack()
    bres = core.vat_batch(Xb)
    for i in range(Xb.shape[0]):
        solo = core.vat(Xb[i])
        assert np.array_equal(np.asarray(bres.order[i]),
                              np.asarray(solo.order))
        assert np.array_equal(np.asarray(bres.rstar[i]),
                              np.asarray(solo.rstar))


def test_ivat_batch_bitwise_identical_to_solo():
    Xb = _stack(seed=1)
    iv_b, bres = core.ivat_batch(Xb)
    for i in range(Xb.shape[0]):
        R = ops.pairwise_dist(Xb[i])
        img, solo = core.ivat(R)
        assert np.array_equal(np.asarray(bres.order[i]),
                              np.asarray(solo.order))
        assert np.array_equal(np.asarray(iv_b[i]), np.asarray(img))


@pytest.mark.parametrize("b,n,d", [(3, 17, 2), (2, 130, 7), (8, 64, 128)])
def test_pairwise_batch_pallas_matches_ref(b, n, d):
    rng = np.random.default_rng(b * 100 + n + d)
    X = jnp.asarray(rng.normal(size=(b, n, d)).astype(np.float32))
    got = pairwise_dist_pallas_batch(X, interpret=True)
    want = jax.vmap(ref.pairwise_dist_ref)(X)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=5e-3)


def test_pairwise_batch_dispatch_zero_diag():
    X = _stack(seed=2, b=3, n=33, d=4)
    for use_pallas in (False, True):
        R = ops.pairwise_dist_batch(X, use_pallas=use_pallas)
        assert R.shape == (3, 33, 33)
        assert np.allclose(np.asarray(jnp.diagonal(R, axis1=1, axis2=2)), 0.0)


def test_ivat_batch_from_dist_matches_solo():
    """The precomputed-distances entry point mirrors solo ``ivat(R)``."""
    Xb = _stack(seed=6, b=3, n=48, d=3)
    Rb = ops.pairwise_dist_batch(Xb)
    iv_b, bres = core.ivat_batch_from_dist(Rb)
    for i in range(3):
        img, solo = core.ivat(Rb[i])
        assert np.array_equal(np.asarray(bres.order[i]),
                              np.asarray(solo.order))
        assert np.array_equal(np.asarray(iv_b[i]), np.asarray(img))


def test_fit_many_pallas_matches_xla():
    """use_pallas reaches both the distance grid and the fused iVAT kernel
    through the facade (solo fit and fit_many alike)."""
    Xs = np.asarray(_stack(seed=7, b=2, n=40, d=3))
    a = FastVAT(method="ivat").fit_many(Xs)
    b = FastVAT(method="ivat", use_pallas=True).fit_many(Xs)
    assert np.array_equal(a.order(), b.order())
    np.testing.assert_allclose(a.image(), b.image(), atol=5e-3)
    sa = FastVAT(method="ivat").fit(Xs[0])
    sb = FastVAT(method="ivat", use_pallas=True).fit(Xs[0])
    assert np.array_equal(sa.order(), sb.order())
    np.testing.assert_allclose(sa.image(), sb.image(), atol=5e-3)


def test_vat_batch_pallas_orders_match_xla():
    Xb = _stack(seed=3, b=4, n=96, d=6)
    a = core.vat_batch(Xb)
    b_ = core.vat_batch(Xb, use_pallas=True)
    assert np.array_equal(np.asarray(a.order), np.asarray(b_.order))


# ---------------------------------------------------------- facade ----

def test_fit_many_matches_solo_fits():
    Xs = np.asarray(_stack(seed=4, b=4, n=80, d=3))
    fv = FastVAT(method="ivat").fit_many(Xs)
    assert fv.order().shape == (4, 80)
    assert fv.image().shape == (4, 80, 80)
    reps = fv.assess()
    assert len(reps) == 4
    for i, rep in enumerate(reps):
        solo = FastVAT(method="ivat").fit(Xs[i])
        assert np.array_equal(fv.order()[i], solo.order())
        srep = solo.assess()
        assert rep["batch_index"] == i
        # block structure is a deterministic function of rstar — exact;
        # hopkins draws per-dataset keys, so only sanity-check its range
        for key in ("block_score", "k_est"):
            assert rep[key] == srep[key], key
        assert 0.0 < rep["hopkins"] < 1.0


def test_fit_many_auto_resolves_and_guards():
    Xs = np.asarray(_stack(seed=5, b=2, n=32, d=2))
    fv = FastVAT().fit_many(Xs)
    assert fv.method_resolved == "vat" and fv.batched
    with pytest.raises(ValueError, match="svat"):
        FastVAT(method="svat").fit_many(Xs)
    with pytest.raises(ValueError, match="stack"):
        FastVAT().fit_many(Xs[0])
