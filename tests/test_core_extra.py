"""Hopkins / sVAT / diagnostics / distributed VAT properties."""
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro import core
from repro.kernels import ops


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), n=st.integers(20, 200), d=st.integers(1, 6))
def test_hopkins_in_unit_interval(seed, n, d):
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    h = float(core.hopkins(X, jax.random.PRNGKey(seed)))
    assert 0.0 <= h <= 1.0


def test_hopkins_separates_uniform_from_clustered():
    rng = np.random.default_rng(0)
    U = jnp.asarray(rng.uniform(size=(400, 2)), jnp.float32)
    C = jnp.asarray(np.concatenate([rng.normal(scale=.05, size=(200, 2)),
                                    rng.normal(scale=.05, size=(200, 2)) + 3]),
                    jnp.float32)
    hu = float(core.hopkins(U, jax.random.PRNGKey(1)))
    hc = float(core.hopkins(C, jax.random.PRNGKey(1)))
    assert hc > 0.8 > hu + 0.1


def test_svat_sample_is_valid_subset():
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(300, 3)), jnp.float32)
    res = core.svat(X, jax.random.PRNGKey(0), s=32)
    idx = np.asarray(res.sample_idx)
    assert len(np.unique(idx)) == 32
    assert res.vat.rstar.shape == (32, 32)


def test_svat_preserves_block_structure():
    rng = np.random.default_rng(0)
    X = jnp.asarray(np.concatenate([
        rng.normal(size=(300, 2)), rng.normal(size=(300, 2)) + 15,
        rng.normal(size=(300, 2)) - 15]), jnp.float32)
    res = core.svat(X, jax.random.PRNGKey(0), s=48)
    score, k = core.block_structure_score(res.vat.rstar)
    assert float(score) > 0.6
    assert int(k) == 3


def test_maximin_covers_clusters():
    rng = np.random.default_rng(0)
    X = jnp.asarray(np.concatenate(
        [rng.normal(size=(100, 2)) + c for c in ([0, 0], [20, 0], [0, 20])]),
        jnp.float32)
    idx = np.asarray(core.maximin_sample(X, 6, jax.random.PRNGKey(0)))
    labels = idx // 100
    assert set(labels.tolist()) == {0, 1, 2}


def test_diagnostics_report_shapes_and_ranges():
    rng = np.random.default_rng(0)
    acts = jnp.asarray(np.concatenate([rng.normal(size=(100, 8)),
                                       rng.normal(size=(100, 8)) + 8]),
                       jnp.float32)
    rep = core.activation_report(acts, jax.random.PRNGKey(0), sample=64)
    assert 0.0 <= float(rep.hopkins) <= 1.0
    assert 0.0 <= float(rep.block_score) <= 1.0
    assert rep.rstar.shape == (64, 64)
    assert int(rep.k_est) >= 2


def test_router_collapse_detection():
    rng = np.random.default_rng(0)
    # collapsed router: all tokens produce ~identical logits
    collapsed = jnp.asarray(rng.normal(size=(1, 16))
                            + 0.01 * rng.normal(size=(256, 16)), jnp.float32)
    healthy = jnp.asarray(np.concatenate(
        [rng.normal(size=(64, 16)) + 6 * np.eye(16)[i % 16]
         for i in range(4)]), jnp.float32)
    rc = core.router_tendency(collapsed, jax.random.PRNGKey(0))
    rh = core.router_tendency(healthy, jax.random.PRNGKey(0))
    assert float(rh.block_score) > float(rc.block_score)


def test_dvat_matches_vat_single_device():
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(64, 4)), jnp.float32)
    mesh = jax.make_mesh((1,), ("data",))
    d = core.dvat(X, mesh)
    assert np.array_equal(np.asarray(d.order), np.asarray(core.vat(X).order))


def test_pairwise_dist_sharded_matches():
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(64, 4)), jnp.float32)
    mesh = jax.make_mesh((1,), ("data",))
    R = core.pairwise_dist_sharded(X, mesh)
    np.testing.assert_allclose(np.asarray(R),
                               np.asarray(ops.pairwise_dist(X)), atol=2e-3)


MULTI_DEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from repro import core
    rng = np.random.default_rng(1)
    X = jnp.asarray(rng.normal(size=(64, 4)).astype(np.float32))
    mesh = jax.make_mesh((8,), ("data",))
    d = core.dvat(X, mesh)
    assert np.array_equal(np.asarray(d.order), np.asarray(core.vat(X).order)), "order mismatch"
    d2 = core.dvat(X, mesh, exact_start=False)
    assert sorted(np.asarray(d2.order).tolist()) == list(range(64))
    print("MULTIDEV_OK")
""")


def test_dvat_multi_device_subprocess():
    # JAX_PLATFORMS=cpu: the 8-fake-device trick targets the host platform,
    # and without it backend init can hang probing for a TPU plugin
    r = subprocess.run([sys.executable, "-c", MULTI_DEV_SCRIPT],
                       capture_output=True, text=True, timeout=300,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"})
    assert "MULTIDEV_OK" in r.stdout, r.stderr[-2000:]
