"""End-to-end behaviour: the paper's full pipeline on its own datasets.

This is the "does the system do what the paper says" test — VAT images
show structure exactly where the paper's Table 3 says they should, the
accelerated paths agree, and the serving/training integration of the
technique works.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import core
from repro.data.synth import DATASETS, make_dataset


def test_paper_pipeline_structured_vs_unstructured():
    """Blobs must show strong block structure; spotify-like noise must not
    (the paper's key qualitative claim, Figures 2 & 3)."""
    Xb, _ = make_dataset("blobs")
    Xs, _ = make_dataset("spotify")
    sb, _ = core.block_structure_score(core.vat(jnp.asarray(Xb)).rstar)
    ss, _ = core.block_structure_score(core.vat(jnp.asarray(Xs)).rstar)
    assert float(sb) > 0.85
    assert float(ss) < 0.55
    assert float(sb) - float(ss) > 0.4


@pytest.mark.parametrize("name", DATASETS)
def test_all_paper_datasets_run_end_to_end(name):
    X, _ = make_dataset(name)
    Xj = jnp.asarray(X)
    res = core.vat(Xj, use_pallas=False)
    assert res.rstar.shape == (len(X), len(X))
    h = float(core.hopkins(Xj, jax.random.PRNGKey(0)))
    assert 0.0 < h < 1.0
    iv, _ = core.ivat(res.dist)
    assert bool(jnp.all(iv <= res.rstar + 1e-4))


def test_pallas_and_xla_paths_identical_order():
    X, _ = make_dataset("iris")
    a = core.vat(jnp.asarray(X), use_pallas=False)
    b = core.vat(jnp.asarray(X), use_pallas=True)
    assert np.array_equal(np.asarray(a.order), np.asarray(b.order))


def test_ivat_sharpens_moons():
    """iVAT's geodesic transform makes the two crescents crisp blocks even
    though euclidean VAT shows only faint structure (paper §4.4.4)."""
    X, _ = make_dataset("moons")
    res = core.vat(jnp.asarray(X))
    iv = core.ivat_from_vat(res.rstar)
    _, k_vat = core.block_structure_score(res.rstar)
    s_ivat, k_ivat = core.block_structure_score(iv)
    # geodesic transform collapses within-crescent jumps: far fewer cuts
    assert int(k_ivat) < int(k_vat)
    assert int(k_ivat) <= 3
    assert float(s_ivat) > 0.5


def test_vat_diagnostics_in_training():
    """The framework integration: VAT runs inside the train loop and
    reports on embedding health."""
    from repro.configs import smoke_config
    from repro.configs.base import ShapeConfig, TrainConfig
    from repro.train.loop import train
    cfg = smoke_config("internvl2-1b")
    tc = TrainConfig(total_steps=6, diag_every=3, ckpt_every=100,
                     ckpt_dir="/tmp/repro_test_sys_ckpt", lr=1e-3)
    import shutil
    shutil.rmtree(tc.ckpt_dir, ignore_errors=True)
    _, hist = train(cfg, tc, ShapeConfig("t", 32, 4, "train"),
                    log=lambda s: None)
    diag = [h for h in hist if "vat_block_score" in h]
    assert len(diag) == 2
    assert all(0 <= h["hopkins"] <= 1 for h in diag)


def test_serving_batch_grouping_by_svat():
    """sVAT-driven request grouping: embeddings of two prompt familes are
    split into the right groups (examples/serve_route.py logic)."""
    rng = np.random.default_rng(0)
    emb = np.concatenate([rng.normal(size=(40, 16)),
                          rng.normal(size=(40, 16)) + 10]).astype(np.float32)
    res = core.svat(jnp.asarray(emb), jax.random.PRNGKey(0), s=16)
    score, k = core.block_structure_score(res.vat.rstar)
    assert int(k) == 2 and float(score) > 0.5
