"""Make `pytest tests/` work with or without PYTHONPATH=src, and fall back
to the deterministic `hypothesis` stub when the real library is absent."""
import importlib.util
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

if importlib.util.find_spec("hypothesis") is None:
    _stub_path = os.path.join(os.path.dirname(__file__), "_hypothesis_stub.py")
    _spec = importlib.util.spec_from_file_location("hypothesis", _stub_path)
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _mod.strategies
