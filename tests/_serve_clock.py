"""Deterministic concurrency rig for the serving layer (ISSUE 7).

The scheduling logic under test — coalescing windows, deadlines,
max-batch closure, backpressure — lives entirely in the clock-free
``repro.serve.coalesce.CoalescerCore``: every transition takes "now" as
an argument.  This rig drives that state machine with a
:class:`VirtualClock`, so tests inject exact arrival times and assert
exactly which requests land in which batched dispatch — zero real
sleeps, zero threads, zero flake.

``TendencyServer`` drives the *same* core with ``time.monotonic``; the
threaded path is covered separately by real-thread stress tests in
test_serve.py.  The rig records, never executes: dispatched batches are
collected as (time, key, tags) tuples and expired requests as
(time, tag), so assertions read like a schedule transcript.
"""
from __future__ import annotations

from concurrent.futures import Future

import numpy as np

from repro.serve.bucketing import bucket_n
from repro.serve.cache import ProgramKey
from repro.serve.coalesce import CoalescerCore, ServeRequest


class VirtualClock:
    """A monotonic clock a test advances by hand."""

    def __init__(self, t: float = 0.0):
        self._t = float(t)

    def __call__(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"time only moves forward, got dt={dt}")
        self._t += dt
        return self._t

    def set(self, t: float) -> float:
        if t < self._t:
            raise ValueError(f"time only moves forward: {t} < {self._t}")
        self._t = float(t)
        return self._t


def make_key(n: int = 100, d: int = 4, *, rung: str = "vat",
             metric: str = "euclidean", mesh: str = "test:1",
             **overrides) -> ProgramKey:
    """A group ProgramKey the way resolve_key would build it, minus the
    live-mesh lookup (tests pin the mesh string for determinism)."""
    n_bucket = bucket_n(n) if rung in ("vat", "ivat") else n
    return ProgramKey(rung=rung, b_bucket=0, n_bucket=n_bucket, d=d,
                      metric=metric, mesh=mesh, **overrides)


def make_request(tag, now: float, *, n: int = 100, d: int = 4,
                 timeout_s: float = 10.0,
                 key: ProgramKey | None = None) -> ServeRequest:
    """A ServeRequest with a tiny placeholder payload (the rig never
    executes batches, so X only needs the right shape)."""
    return ServeRequest(X=np.zeros((n, d), np.float32), n=n,
                        key=key if key is not None else make_key(n, d),
                        arrival=now, deadline=now + timeout_s,
                        future=Future(), tag=tag)


class CoalesceRig:
    """Drives a CoalescerCore on a VirtualClock, recording the schedule.

    Attributes:
      dispatches: list of (time, ProgramKey, [tags]) per flushed batch,
        in flush order.
      expired: list of (time, tag) per deadline-expired request.
    """

    def __init__(self, *, window: float = 1.0, max_batch: int = 8,
                 max_pending: int = 256, t0: float = 0.0):
        self.clock = VirtualClock(t0)
        self.core = CoalescerCore(window=window, max_batch=max_batch,
                                  max_pending=max_pending)
        self.dispatches: list[tuple[float, ProgramKey, list]] = []
        self.expired: list[tuple[float, object]] = []

    def _record(self, batches, expired) -> None:
        for b in batches:
            self.dispatches.append(
                (b.created, b.key, [r.tag for r in b.requests]))
        for r in expired:
            self.expired.append((r.deadline, r.tag))

    def submit(self, tag, t: float, *, n: int = 100, d: int = 4,
               timeout_s: float = 10.0,
               key: ProgramKey | None = None) -> ServeRequest:
        """Advance to t and submit one request, recording any resulting
        flushes/expiries. Returns the request for future inspection.

        Mirrors TendencyServer.submit's poll-then-enqueue protocol: due
        events are recorded BEFORE the bound check, so a Backpressure
        raise never swallows a dispatch.
        """
        self.clock.set(t)
        req = make_request(tag, t, n=n, d=d, timeout_s=timeout_s, key=key)
        self._record(*self.core.poll(t))
        flush = self.core.try_enqueue(req, t)   # may raise Backpressure
        if flush is not None:
            self._record([flush], [])
        return req

    def run_until(self, t: float) -> None:
        """Advance to t, replaying every due flush/deadline event."""
        self.clock.set(t)
        self._record(*self.core.poll(t))

    def drain(self, t: float) -> None:
        """Advance to t and flush everything (shutdown semantics)."""
        self.clock.set(t)
        self._record(*self.core.drain(t))

    def batch_tags(self) -> list[list]:
        """Just the tag lists, in dispatch order (the usual assertion)."""
        return [tags for _, _, tags in self.dispatches]
