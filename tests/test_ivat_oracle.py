"""iVAT correctness against a brute-force minimax-path oracle.

The iVAT image is, mathematically, the matrix of *minimax path distances*
(a.k.a. max-min / bottleneck geodesics) over the complete graph: the cost
of a path is its largest edge, and D'[i, j] is the cheapest such cost over
all i -> j paths.  The Havens & Bezdek recurrence computes this in O(n^2)
but only along a VAT ordering — the oracle here is an ordering-free
Floyd–Warshall variant (min-max instead of plus-min), so agreement checks
the recurrence itself, not a reimplementation of it."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro import core
from repro.kernels import ops
from repro.kernels.ivat_update import ivat_from_vat_pallas


def minimax_path_brute(R: np.ndarray) -> np.ndarray:
    """Floyd–Warshall for bottleneck shortest paths: O(n^3), any ordering."""
    D = np.array(R, np.float64)
    n = D.shape[0]
    for k in range(n):
        D = np.minimum(D, np.maximum(D[:, k:k + 1], D[k:k + 1, :]))
    np.fill_diagonal(D, 0.0)
    return D


def _rstar(seed, n, d):
    rng = np.random.default_rng(seed)
    X = (rng.normal(size=(n, d)) * rng.uniform(0.5, 2.0, size=d)
         ).astype(np.float32)
    return core.vat(jnp.asarray(X)).rstar


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(3, 40),
       d=st.integers(1, 6))
def test_ivat_recurrence_equals_minimax_oracle(seed, n, d):
    rstar = _rstar(seed, n, d)
    want = minimax_path_brute(np.asarray(rstar))
    got = np.asarray(core.ivat_from_vat(rstar))
    np.testing.assert_allclose(got, want, atol=1e-5)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(3, 40))
def test_ivat_pallas_kernel_equals_minimax_oracle(seed, n):
    rstar = _rstar(seed, n, 3)
    want = minimax_path_brute(np.asarray(rstar))
    got = np.asarray(ivat_from_vat_pallas(rstar, interpret=True))
    np.testing.assert_allclose(got, want, atol=1e-5)


@pytest.mark.parametrize("n", [2, 3, 17, 130])
def test_ivat_pallas_matches_xla_exactly(n):
    rstar = _rstar(n, n, 4)
    a = np.asarray(ops.ivat_from_vat(rstar))
    b = np.asarray(ops.ivat_from_vat(rstar, use_pallas=True))
    assert np.array_equal(a, b)


def test_ivat_pallas_batched_matches_per_matrix():
    rstars = jnp.stack([_rstar(s, 48, 3) for s in range(5)])
    got = np.asarray(ops.ivat_from_vat(rstars, use_pallas=True))
    for i in range(5):
        want = np.asarray(ops.ivat_from_vat(rstars[i]))
        assert np.array_equal(got[i], want)


def test_ivat_trivial_sizes():
    one = jnp.zeros((1, 1))
    assert np.asarray(ivat_from_vat_pallas(one, interpret=True)).shape == (1, 1)
    two = jnp.asarray([[0.0, 3.0], [3.0, 0.0]])
    out = np.asarray(ivat_from_vat_pallas(two, interpret=True))
    np.testing.assert_allclose(out, np.asarray(two))


def test_ivat_fallback_above_vmem_ceiling():
    """n > MAX_FUSED_N must silently take the XLA path (no Pallas VMEM blowup)."""
    from repro.kernels.ivat_update import MAX_FUSED_N
    n = MAX_FUSED_N + 1
    rng = np.random.default_rng(0)
    X = rng.normal(size=(n, 2)).astype(np.float32)
    rstar = core.vat(jnp.asarray(X)).rstar
    a = ops.ivat_from_vat(rstar, use_pallas=True)   # falls back
    b = ops.ivat_from_vat(rstar)
    assert np.array_equal(np.asarray(a), np.asarray(b))


def _seam_sizes():
    from repro.kernels.ivat_update import MAX_FUSED_N
    return [MAX_FUSED_N - 1, MAX_FUSED_N, MAX_FUSED_N + 1]


@pytest.mark.parametrize("n", _seam_sizes())
def test_ivat_vmem_seam_bitwise(n):
    """ISSUE 4 satellite: straddle the fused kernel's VMEM ceiling.
    At MAX_FUSED_N−1 and MAX_FUSED_N the ``use_pallas=True`` dispatch
    runs the fused kernel right at its slab budget; at MAX_FUSED_N+1 it
    silently falls back to XLA — all three must agree with the XLA path
    bit for bit, so the seam is invisible to callers."""
    rstar = _rstar(n, n, 3)
    a = np.asarray(ops.ivat_from_vat(rstar))
    b = np.asarray(ops.ivat_from_vat(rstar, use_pallas=True))
    assert np.array_equal(a, b)
