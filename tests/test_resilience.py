"""Chaos tests for the graceful-degradation ladder (ISSUE 9 tentpole).

Three layers:

* Unit: RetryPolicy determinism/bounds, the clock-free CircuitBreaker
  state machine, fallback_chain composition, breaker_family identity.
* Admission: the typed InvalidInput refusal at FastVAT.fit/fit_many and
  TendencyServer.submit, across rungs (satellite a).
* Integration: a real threaded TendencyServer on a VirtualClock with an
  injectable no-op sleep — armed faults drive the ladder and the tests
  pin EXACT ResilienceStats counter trajectories (the acceptance
  scenarios of ISSUE 9), including the poison-lane batch split, the
  build-fault fallback chain, the breaker trip/cooldown/probe cycle,
  and the dispatcher-death failsafe (satellite b).
"""
import numpy as np
import pytest

import repro.faults as faults
from repro.api import FastVAT, InvalidInput
from repro.serve import (BreakerConfig, CircuitBreaker, ExecutionError,
                         ResilienceStats, RetryPolicy, ServeConfig,
                         ServeError, TendencyServer, breaker_family,
                         fallback_chain)
from repro.serve.resilience import CLOSED, HALF_OPEN, OPEN

from _serve_clock import VirtualClock, make_key


@pytest.fixture(autouse=True)
def _clean_registry():
    faults.disarm_all()
    yield
    faults.disarm_all()


def _blobs(n, d=3, seed=0):
    rng = np.random.default_rng(seed)
    half = n // 2
    return np.concatenate([
        rng.normal(size=(half, d)),
        rng.normal(size=(n - half, d)) + 6.0]).astype(np.float32)


def _solo(X, method):
    return FastVAT(method=method).fit(X).result


def _same_result(a, b) -> bool:
    for f in ("order", "rstar", "ivat_image", "sample_idx",
              "extension_labels", "group_sizes"):
        va, vb = getattr(a, f), getattr(b, f)
        if (va is None) != (vb is None):
            return False
        if va is not None and not np.array_equal(np.asarray(va),
                                                 np.asarray(vb)):
            return False
    return True


# ====================================================== unit: retry ====

def test_retry_policy_deterministic_and_bounded():
    pol = RetryPolicy(max_attempts=3, backoff_s=0.01, backoff_cap_s=0.05,
                      jitter=0.25)
    a = [pol.delay_s(i, seed=7) for i in range(5)]
    b = [pol.delay_s(i, seed=7) for i in range(5)]
    assert a == b                       # deterministic in (seed, attempt)
    for i, delay in enumerate(a):
        base = min(0.05, 0.01 * 2 ** i)
        assert base * 0.75 <= delay <= base * 1.25
    assert pol.delay_s(0, seed=1) != pol.delay_s(0, seed=2)


def test_retry_policy_no_jitter_exact():
    pol = RetryPolicy(backoff_s=0.01, backoff_cap_s=1.0, jitter=0.0)
    assert pol.delay_s(0) == 0.01
    assert pol.delay_s(3) == 0.08


def test_retry_policy_rejects_zero_attempts():
    with pytest.raises(ValueError, match="max_attempts"):
        RetryPolicy(max_attempts=0)


# ==================================================== unit: breaker ====

def test_breaker_opens_after_threshold():
    b = CircuitBreaker(BreakerConfig(threshold=3, cooldown_s=10.0))
    assert b.state == CLOSED
    for t in range(2):
        b.record_failure(float(t))
        assert b.state == CLOSED and b.allow_primary(float(t))
    b.record_failure(2.0)
    assert b.state == OPEN and b.opens == 1
    assert not b.allow_primary(11.9)     # cooldown not elapsed
    assert b.allow_primary(12.0)         # -> HALF_OPEN probe
    assert b.state == HALF_OPEN and b.probes == 1
    assert not b.allow_primary(12.0)     # only ONE probe admitted


def test_breaker_halfopen_failure_reopens():
    b = CircuitBreaker(BreakerConfig(threshold=2, cooldown_s=5.0))
    b.record_failure(0.0)
    b.record_failure(0.0)
    assert b.state == OPEN
    assert b.allow_primary(5.0)          # probe
    b.record_failure(5.0)
    assert b.state == OPEN and b.opens == 2
    assert b.allow_primary(10.0)         # second probe after new cooldown
    b.record_success(10.0)
    assert b.state == CLOSED and b.failures == 0


def test_breaker_success_resets_consecutive_count():
    b = CircuitBreaker(BreakerConfig(threshold=2))
    b.record_failure(0.0)
    b.record_success(0.0)
    b.record_failure(0.0)
    assert b.state == CLOSED             # never two *consecutive* failures


# ============================================== unit: fallback chain ====

def test_fallback_chain_vat_plain_has_no_fallback():
    key = make_key(rung="vat")
    assert fallback_chain(key) == (key,)


def test_fallback_chain_pallas_drops_to_xla():
    key = make_key(rung="vat", use_pallas=True)
    chain = fallback_chain(key)
    assert [k.use_pallas for k in chain] == [True, False]
    assert all(k.rung == "vat" for k in chain)


def test_fallback_chain_ivat_steps_down_to_vat():
    key = make_key(rung="ivat")
    chain = fallback_chain(key)
    assert [k.rung for k in chain] == ["ivat", "vat"]
    assert chain[0].n_bucket == chain[1].n_bucket   # same padding proof


def test_fallback_chain_ivat_pallas_full_ladder():
    chain = fallback_chain(make_key(rung="ivat", use_pallas=True))
    assert [(k.rung, k.use_pallas) for k in chain] == [
        ("ivat", True), ("ivat", False), ("vat", False)]


def test_fallback_chain_flashvat_turbo():
    chain = fallback_chain(make_key(n=300, rung="flashvat",
                                    use_pallas=True, turbo=None))
    assert [(k.use_pallas, k.turbo) for k in chain] == [
        (True, None), (False, None), (False, False)]
    # stepwise flashvat is already the bottom: nothing below it
    assert fallback_chain(make_key(n=300, rung="flashvat",
                                   turbo=False)) == \
        (make_key(n=300, rung="flashvat", turbo=False),)


def test_breaker_family_is_lane_count_agnostic():
    key = make_key(rung="ivat")
    assert breaker_family(key.with_batch(1)) == \
        breaker_family(key.with_batch(8))
    assert breaker_family(make_key(rung="vat")) != \
        breaker_family(make_key(rung="ivat"))


# ================================================ admission (sat. a) ====

@pytest.mark.parametrize("method", ["vat", "ivat", "flashvat"])
@pytest.mark.parametrize("bad", [np.nan, np.inf, -np.inf])
def test_fit_rejects_non_finite_across_rungs(method, bad):
    X = _blobs(64)
    X[7, 1] = bad
    with pytest.raises(InvalidInput) as ei:
        FastVAT(method=method).fit(X)
    assert ei.value.reason == "non_finite"


def test_fit_validate_false_skips_admission():
    X = _blobs(32)
    X[3, 0] = np.nan
    res = FastVAT(method="vat", validate=False).fit(X)
    assert res.order().shape == (32,)    # garbage-in tolerated on opt-out


def test_fit_rejects_too_few_points_and_degenerate():
    with pytest.raises(InvalidInput) as ei:
        FastVAT().fit(np.zeros((3, 2), np.float32))
    assert ei.value.reason == "too_few_points"
    with pytest.raises(InvalidInput) as ei:
        FastVAT().fit(np.ones((16, 2), np.float32))
    assert ei.value.reason == "degenerate"


def test_fit_rejects_bad_dtype():
    with pytest.raises(InvalidInput) as ei:
        FastVAT().fit(np.array([["a", "b"]] * 8))
    assert ei.value.reason == "dtype"


def test_fit_precomputed_rejects_non_finite():
    X = _blobs(16)
    D = np.linalg.norm(X[:, None] - X[None, :], axis=-1)
    D[3, 5] = D[5, 3] = np.nan
    with pytest.raises(InvalidInput) as ei:
        FastVAT(metric="precomputed").fit(D.astype(np.float32))
    assert ei.value.reason == "non_finite"


def test_fit_many_names_poison_lane():
    Xs = np.stack([_blobs(32), _blobs(32, seed=1)])
    Xs[1, 5, 0] = np.inf
    with pytest.raises(InvalidInput, match=r"lane\(s\) \[1\]"):
        FastVAT(method="vat").fit_many(Xs)


# ========================================== server chaos integration ====

def _chaos_server(**cfg):
    cfg.setdefault("window_s", 999.0)
    cfg.setdefault("retry", RetryPolicy(max_attempts=2, jitter=0.0))
    clock = VirtualClock()
    srv = TendencyServer(ServeConfig(**cfg), clock=clock,
                         sleep=lambda s: None)
    return srv, clock


def test_submit_admission_rejects_and_counts():
    srv, _ = _chaos_server(max_batch=1)
    try:
        X = _blobs(32)
        X[0, 0] = np.nan
        with pytest.raises(InvalidInput):
            srv.submit(X)
        with pytest.raises(InvalidInput):
            srv.submit(np.ones((16, 3), np.float32))
        stats = srv.stats().resilience
        assert stats.invalid_rejects == 2
        assert stats == ResilienceStats(invalid_rejects=2)  # nothing else
    finally:
        srv.close()


def test_poison_lane_fails_alone_batchmates_bitwise_correct():
    """ISSUE 9 acceptance: one poisoned lane of a 4-lane coalesced batch
    fails typed; the other three get results bitwise-equal to solo fits."""
    srv, _ = _chaos_server(max_batch=4)
    try:
        faults.arm("serve.execute", times=-1,
                   match=lambda ctx: "poison" in ctx.get("tags", ()))
        Xs = {tag: _blobs(48, seed=i)
              for i, tag in enumerate(["a", "b", "poison", "c"])}
        futs = {tag: srv.submit(X, method="vat", tag=tag)
                for tag, X in Xs.items()}    # 4th submit flushes the batch
        for tag in ("a", "b", "c"):
            served = futs[tag].result(timeout=120)
            assert _same_result(served, _solo(Xs[tag], "vat"))
        with pytest.raises(ExecutionError) as ei:
            futs["poison"].result(timeout=120)
        assert isinstance(ei.value.__cause__, faults.FaultInjected)
        assert ei.value.__cause__.site == "serve.execute"
        stats = srv.stats().resilience
        # batch level: 2 attempts -> 1 retry, then split; solo poison
        # lane: 2 attempts -> 1 retry, ladder exhausted -> failed.
        # vat-without-pallas has no fallback level, so fallbacks == 0.
        assert stats.splits == 1
        assert stats.retries == 2
        assert stats.failed == 1
        assert stats.fallbacks == 0
        assert stats.degraded == 0
        assert stats.breakers == ()
    finally:
        srv.close()


def test_build_fault_served_via_fallback_chain():
    """ISSUE 9 acceptance: a primary whose program BUILD fails is served
    by the next chain level — an error turned into a (coarser) result."""
    srv, _ = _chaos_server(max_batch=1)
    try:
        faults.arm("serve.build", times=-1,
                   match=lambda ctx: ctx.get("rung") == "ivat")
        X = _blobs(48)
        served = srv.submit(X, method="ivat").result(timeout=120)
        assert served.meta.method == "vat"     # stepped down one rung
        assert _same_result(served, _solo(X, "vat"))
        stats = srv.stats().resilience
        assert stats.fallbacks == 1
        assert stats.retries == 1              # 2 attempts at the primary
        assert stats.degraded == 1
        assert stats.failed == 0 and stats.splits == 0
    finally:
        srv.close()


def test_breaker_trips_pins_fallback_and_reprobes():
    """ISSUE 9 acceptance: repeated primary failures open the breaker
    (fallback pinned, no primary attempts), cooldown admits one probe,
    and a healthy probe closes it — all on the virtual clock."""
    srv, clock = _chaos_server(
        max_batch=1, retry=RetryPolicy(max_attempts=1),
        breaker=BreakerConfig(threshold=2, cooldown_s=10.0))
    try:
        faults.arm("serve.build", times=-1,
                   match=lambda ctx: ctx.get("rung") == "ivat")
        X = _blobs(48)

        def ivat_fit():
            return srv.submit(X, method="ivat").result(timeout=120)

        ivat_fit()                             # failure 1: still CLOSED
        assert srv.breaker_state(48, 3, method="ivat") == CLOSED
        ivat_fit()                             # failure 2: trips OPEN
        assert srv.breaker_state(48, 3, method="ivat") == OPEN

        built_before = faults.stats()["serve.build"]["fired"]
        served = ivat_fit()                    # pinned: no primary attempt
        assert served.meta.method == "vat"
        assert faults.stats()["serve.build"]["fired"] == built_before

        stats = srv.stats().resilience
        assert stats.breaker_opens == 1
        assert stats.breaker_probes == 0
        assert stats.degraded == 3
        assert stats.fallbacks == 3            # 2 failures + 1 pinned skip
        assert stats.breakers and stats.breakers[0][1] == OPEN
        assert stats.open_breakers == 1

        clock.advance(10.0)                    # cooldown elapses
        ivat_fit()                             # probe fires... and fails
        stats = srv.stats().resilience
        assert stats.breaker_probes == 1
        assert stats.breaker_opens == 2        # HALF_OPEN failure reopens
        assert srv.breaker_state(48, 3, method="ivat") == OPEN

        faults.disarm("serve.build")           # "deploy the fix"
        clock.advance(10.0)
        served = ivat_fit()                    # healthy probe: recovers
        assert served.meta.method == "ivat"
        assert _same_result(served, _solo(X, "ivat"))
        assert srv.breaker_state(48, 3, method="ivat") == CLOSED
        stats = srv.stats().resilience
        assert stats.breaker_probes == 2
        assert stats.breakers == ()            # healthy again
    finally:
        srv.close()


def test_transient_fault_absorbed_by_retry():
    srv, _ = _chaos_server(max_batch=1)
    try:
        faults.arm("serve.execute", times=1)   # fires once, then clean
        X = _blobs(48)
        served = srv.submit(X, method="vat").result(timeout=120)
        assert _same_result(served, _solo(X, "vat"))
        stats = srv.stats().resilience
        assert stats.retries == 1
        assert stats.failed == 0 and stats.fallbacks == 0
    finally:
        srv.close()


def test_delay_fault_runs_on_injected_sleep():
    slept = []
    clock = VirtualClock()
    srv = TendencyServer(ServeConfig(window_s=999.0, max_batch=1),
                         clock=clock, sleep=slept.append)
    try:
        faults.arm("serve.execute", kind="delay", delay_s=2.5)
        X = _blobs(48)
        srv.submit(X, method="vat").result(timeout=120)
        assert 2.5 in slept                    # no real wall-clock sleep
    finally:
        srv.close()


# =========================================== dispatcher death (sat. b) ==

class _Die(BaseException):
    """Not an Exception: sails past the ladder's handlers, killing the
    dispatcher thread — the failsafe under test."""


def test_dispatcher_death_fails_all_futures_typed():
    srv, _ = _chaos_server(max_batch=2)
    try:
        faults.arm("serve.execute", exc=_Die, times=1)
        q = srv.submit(_blobs(100), method="vat", tag="queued")  # other key
        f1 = srv.submit(_blobs(48), method="vat", tag="x")
        f2 = srv.submit(_blobs(48, seed=1), method="vat", tag="y")
        # the 48-point pair flushed at max_batch and killed the thread;
        # the queued 100-point request must fail too — never hang.
        for fut in (f1, f2, q):
            with pytest.raises(ServeError, match="dispatcher thread died"):
                fut.result(timeout=120)
        with pytest.raises(ServeError, match="closed"):
            srv.submit(_blobs(48))
    finally:
        srv.close()                            # idempotent after death


def test_close_dispatches_queued_requests():
    """close() audit: requests still coalescing (window never elapsed)
    are drained and served, not dropped."""
    srv, _ = _chaos_server(max_batch=8)
    X = _blobs(48)
    fut = srv.submit(X, method="vat")
    assert not fut.done()                      # window_s=999: still queued
    srv.close()
    assert _same_result(fut.result(timeout=120), _solo(X, "vat"))


# ======================================= disarmed-path byte identity ====

def test_disarmed_server_stats_all_zero():
    srv, _ = _chaos_server(max_batch=1)
    try:
        X = _blobs(48)
        served = srv.submit(X, method="vat").result(timeout=120)
        assert _same_result(served, _solo(X, "vat"))
        assert srv.stats().resilience == ResilienceStats()
    finally:
        srv.close()
