"""K-Means / DBSCAN baselines + ARI (paper Table 3 machinery)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import core
from repro.data.synth import make_dataset


def test_kmeans_recovers_blobs():
    X, y = make_dataset("blobs")
    labels, centers, inertia = core.kmeans(jnp.asarray(X),
                                           jax.random.PRNGKey(0), k=3)
    assert core.adjusted_rand_index(np.asarray(labels), y) > 0.95
    assert float(inertia) > 0


def test_kmeans_fails_on_circles_dbscan_succeeds():
    """The paper's headline qualitative comparison (Table 3, Circles)."""
    X, y = make_dataset("circles")
    km, _, _ = core.kmeans(jnp.asarray(X), jax.random.PRNGKey(0), k=2)
    db = core.dbscan(jnp.asarray(X), eps=0.12, min_pts=5)
    ari_km = core.adjusted_rand_index(np.asarray(km), y)
    ari_db = core.adjusted_rand_index(np.asarray(db), y)
    assert ari_db > 0.95 > ari_km + 0.5


def test_dbscan_moons():
    X, y = make_dataset("moons")
    db = core.dbscan(jnp.asarray(X), eps=0.12, min_pts=5)
    assert core.adjusted_rand_index(np.asarray(db), y) > 0.9


def test_dbscan_labels_noise():
    rng = np.random.default_rng(0)
    X = np.concatenate([rng.normal(scale=0.05, size=(50, 2)),
                        np.array([[5.0, 5.0]])]).astype(np.float32)
    db = np.asarray(core.dbscan(jnp.asarray(X), eps=0.3, min_pts=5))
    assert db[-1] == -1          # the far outlier is noise
    assert len(set(db[:50].tolist())) == 1


def test_ari_properties():
    a = np.array([0, 0, 1, 1, 2, 2])
    assert core.adjusted_rand_index(a, a) == pytest.approx(1.0)
    perm = np.array([5, 5, 3, 3, 9, 9])   # same partition, renamed
    assert core.adjusted_rand_index(a, perm) == pytest.approx(1.0)
    rng = np.random.default_rng(0)
    b = rng.integers(0, 3, 600)
    c = rng.integers(0, 3, 600)
    assert abs(core.adjusted_rand_index(b, c)) < 0.05   # ~0 for random


def test_pca_shape_and_variance_order():
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(100, 5)) * np.array([10, 5, 1, .1, .01]),
                    jnp.float32)
    P = core.pca(X, k=2)
    assert P.shape == (100, 2)
    v = np.var(np.asarray(P), axis=0)
    assert v[0] >= v[1]
