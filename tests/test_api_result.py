"""The unified API (ISSUE 3 tentpole): every rung returns the same
``TendencyResult`` pytree, the registry drives dispatch, ``assess()``
has one stable shape, and the single seed source pins repeatability."""
import doctest
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro
from repro.api import (FastVAT, MEDIUM_N, METHODS, METRICS, SMALL_N,
                       ResultMeta, Rung, TendencyReport, TendencyResult,
                       assess_tendency, registry, select_method)


def _blobs(n=120, k=2, d=3, seed=0, sep=9.0):
    rng = np.random.default_rng(seed)
    centers = (sep * rng.normal(size=(k, d))).astype(np.float32)
    lab = rng.integers(0, k, size=n)
    return (centers[lab] + rng.normal(size=(n, d))).astype(np.float32)


# ----------------------------------------------- uniform result shape ----

@pytest.mark.parametrize("method", ["vat", "ivat", "svat", "flashvat",
                                    "bigvat"])
def test_every_rung_returns_tendency_result(method):
    X = _blobs()
    fv = FastVAT(method=method, sample_size=32).fit(X)
    res = fv.result
    assert isinstance(res, TendencyResult)
    assert res.meta.method == method and res.meta.batch is None
    assert res.meta.n == len(X)
    # branch-free queries work on every rung
    order = fv.order()
    assert order.ndim == 1 and len(set(order.tolist())) == len(order)
    img = fv.image()
    assert img.ndim == 2 and img.shape[0] == img.shape[1]
    rep = fv.assess()
    assert isinstance(rep, TendencyReport) and rep["method"] == method


@pytest.mark.parametrize("method", ["vat", "ivat"])
def test_batched_rungs_return_tendency_result(method):
    Xs = np.stack([_blobs(60, seed=s) for s in range(3)])
    fv = FastVAT(method=method).fit_many(Xs)
    res = fv.result
    assert isinstance(res, TendencyResult)
    assert res.meta.batch == 3 and fv.batched
    assert fv.order().shape == (3, 60)
    assert fv.image().shape == (3, 60, 60)
    reps = fv.assess()
    assert [r["batch_index"] for r in reps] == [0, 1, 2]


DVAT_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax
    from repro.api import FastVAT, TendencyResult
    rng = np.random.default_rng(1)
    X = np.concatenate([rng.normal(size=(32, 4)),
                        rng.normal(size=(32, 4)) + 8]).astype(np.float32)
    fv = FastVAT(method="dvat", sample_size=16).fit(X)
    assert isinstance(fv.result, TendencyResult), type(fv.result)
    assert sorted(fv.order().tolist()) == list(range(64))
    assert fv.image().shape == (16, 16)          # maximin-sample image
    rep = fv.assess()
    assert rep["method"] == "dvat" and rep["k_est"] == 2, dict(rep)
    print("DVAT_RESULT_OK")
""")


def test_dvat_returns_tendency_result_subprocess():
    r = subprocess.run([sys.executable, "-c", DVAT_SCRIPT],
                       capture_output=True, text=True, timeout=300,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"})
    assert "DVAT_RESULT_OK" in r.stdout, r.stderr[-2000:]


def test_tendency_result_is_a_pytree():
    fv = FastVAT(method="bigvat", sample_size=16).fit(_blobs(200))
    res = fv.result
    leaves = jax.tree_util.tree_leaves(res)
    assert leaves and all(hasattr(x, "shape") for x in leaves)
    # round-trips through tree_map with meta (aux data) intact
    mapped = jax.tree_util.tree_map(lambda x: x, res)
    assert isinstance(mapped, TendencyResult)
    assert mapped.meta == res.meta
    assert jax.block_until_ready(res) is res or True  # no crash


def test_image_use_ivat_contract():
    X = _blobs()
    fv = FastVAT(method="vat").fit(X)
    assert fv.result.ivat_image is None
    iv = fv.image(use_ivat=True)       # derived on demand from rstar
    assert np.all(iv <= fv.image(use_ivat=False) + 1e-4)
    fi = FastVAT(method="ivat").fit(X)
    assert fi.result.ivat_image is not None
    np.testing.assert_array_equal(fi.image(), np.asarray(fi.result.ivat_image))


# ------------------------------------------------------ assess shape ----

def test_assess_stable_shape_and_dict_compat():
    X = _blobs()
    rep = FastVAT().fit(X).assess()
    reps = FastVAT().fit_many(np.stack([X, X])).assess()
    # identical keys solo and batched (the old dict had batch_index only
    # in the batched flavor)
    assert tuple(rep.keys()) == tuple(reps[0].keys())
    assert rep["batch_index"] is None and reps[1]["batch_index"] == 1
    # dict-like access idioms all work
    assert rep["method"] == rep.method == dict(rep)["method"]
    assert rep.get("nope", 42) == 42
    assert "hopkins" in rep and len(rep) == 8
    assert isinstance(rep.as_dict(), dict)
    with pytest.raises(KeyError):
        rep["no_such_key"]


def test_precomputed_reports_compare_equal_despite_nan_hopkins():
    """Regression: dataclass equality must not be NaN-poisoned — two
    identical precomputed fits (hopkins=nan) report equal."""
    from repro.kernels import ops
    X = _blobs(40)
    D = np.asarray(ops.pairwise_dist(jnp.asarray(X)))
    a = FastVAT(metric="precomputed").fit(D).assess()
    b = FastVAT(metric="precomputed").fit(D).assess()
    assert np.isnan(a["hopkins"]) and a == b
    assert a != FastVAT().fit(X).assess()


def test_assess_tendency_oneshot_returns_report():
    rep = assess_tendency(_blobs(seed=3))
    assert isinstance(rep, TendencyReport)
    assert rep["clustered"] and rep["metric"] == "euclidean"


# ------------------------------------------------- single seed source ----

def test_seed_repeatability_pinned():
    """ISSUE 3 satellite: host-side (Hopkins subsample) and device-side
    sampling both derive from ResultMeta.seed — same seed, same report,
    bit for bit; the subsample rng no longer free-rides on global numpy
    state."""
    X = _blobs(n=3_000, seed=5)        # n > hopkins cap => subsample path
    a = FastVAT(method="svat", sample_size=32, seed=7).fit(X).assess()
    b = FastVAT(method="svat", sample_size=32, seed=7).fit(X).assess()
    assert a == b                      # dataclass equality: every field
    c = FastVAT(method="svat", sample_size=32, seed=8).fit(X).assess()
    assert a["hopkins"] != c["hopkins"]


def test_result_meta_seed_derivation():
    m = ResultMeta(method="vat", seed=3)
    assert np.array_equal(m.jax_key(1), m.jax_key(1))
    assert not np.array_equal(m.jax_key(1), m.jax_key(2))
    assert m.host_rng(1).integers(1 << 30) == m.host_rng(1).integers(1 << 30)
    assert (m.host_rng(1).integers(1 << 30)
            != m.host_rng(2).integers(1 << 30))
    # jax- and host-side streams share the seed *source*, not the values
    m2 = ResultMeta(method="vat", seed=4)
    assert m.host_rng(1).integers(1 << 30) != m2.host_rng(1).integers(1 << 30)


# ------------------------------------------------------------ registry ----

def test_registry_drives_dispatch_and_extension():
    """A third-party rung registers and immediately works through the
    facade — no facade edits (the ConiVAT/DeepVAT extension path)."""
    def toy_fit(X, meta, opts):
        from repro import core
        res = core.vat(jnp.asarray(np.asarray(X, np.float32)),
                       metric=meta.metric)
        return TendencyResult(order=res.order, rstar=res.rstar,
                              ivat_image=None, sample_idx=None,
                              extension_labels=None, meta=meta)

    rung = Rung(name="toyvat", fit=toy_fit, supports_precomputed=False)
    registry.register(rung)
    try:
        assert "toyvat" in registry.methods()
        fv = FastVAT(method="toyvat").fit(_blobs())
        assert isinstance(fv.result, TendencyResult)
        assert fv.assess()["method"] == "toyvat"
        with pytest.raises(ValueError, match="already registered"):
            registry.register(rung)
        registry.register(rung, overwrite=True)   # idempotent replace
    finally:
        del registry._REGISTRY["toyvat"]


def test_select_method_is_capability_driven():
    assert select_method(SMALL_N) == "vat"
    # flashvat (exact, matrix-free) owns svat's former auto window
    assert select_method(SMALL_N + 1) == "flashvat"
    assert select_method(MEDIUM_N) == "flashvat"
    # the approx kNN-MST rung owns bigvat's former auto window (ISSUE 6)
    assert select_method(MEDIUM_N + 1) == "approx"
    assert select_method(100, batched=True) == "vat"
    assert select_method(SMALL_N + 1, batched=True, strict=True) \
        == "flashvat"
    with pytest.raises(LookupError):
        select_method(MEDIUM_N + 1, batched=True, strict=True)


def test_rung_capability_flags():
    assert registry.get_rung("vat").supports_batch
    assert registry.get_rung("ivat").supports_precomputed
    assert not registry.get_rung("bigvat").supports_batch
    assert not registry.get_rung("svat").supports_precomputed
    assert registry.get_rung("flashvat").supports_batch
    assert not registry.get_rung("flashvat").supports_precomputed
    assert registry.get_rung("svat").auto_threshold is None  # opt-in now
    assert registry.get_rung("dvat").check is not None
    with pytest.raises(KeyError, match="registered"):
        registry.get_rung("nope")


# ------------------------------------------------- public API surface ----

#: The documented public surface (docs/api.md) — every name must import.
PUBLIC_ROOT = ("FastVAT", "assess_tendency", "TendencyResult",
               "TendencyReport", "ResultMeta", "METRICS", "select_method",
               "InvalidInput", "NumericsPolicy", "NumericsReport")
PUBLIC_API = PUBLIC_ROOT + ("Rung", "RungOptions", "register", "get_rung",
                            "registry", "METHODS", "SMALL_N", "MEDIUM_N",
                            "COMPUTED_METRICS", "validate_metric",
                            "validate_points", "validate_dissimilarity")


def test_api_stability_every_documented_name_imports():
    for name in PUBLIC_ROOT:
        assert getattr(repro, name) is not None, name
    import repro.api as api_pkg
    for name in PUBLIC_API:
        assert getattr(api_pkg, name) is not None, name
    assert set(PUBLIC_ROOT) == set(repro.__all__)
    assert set(PUBLIC_API) <= set(api_pkg.__all__)
    # the legacy import spelling keeps working
    from repro.api import FastVAT as F2  # noqa: F401
    assert "auto" in METHODS and "precomputed" in METRICS


def test_api_doctests_pass():
    """The tier-1 gate runs the api package doctests even without the
    --doctest-modules flag CI adds."""
    import repro.api.facade
    import repro.api.metrics
    import repro.api.registry
    import repro.api.result
    for mod in (repro.api.facade, repro.api.metrics, repro.api.registry,
                repro.api.result, repro):
        result = doctest.testmod(mod)
        assert result.failed == 0, mod.__name__
