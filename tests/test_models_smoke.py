"""Per-arch smoke tests: reduced same-family config, one forward + one
train step + one decode step on CPU; finite outputs, right shapes."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config, smoke_config, cells, SUBQUADRATIC
from repro.configs.base import ShapeConfig, TrainConfig
from repro.data.tokens import make_batch, input_specs
from repro.models import model as M
from repro.train import steps as S

SHAPE = ShapeConfig("tiny", 32, 2, "train")


@pytest.fixture(scope="module", params=sorted(ARCHS))
def arch(request):
    return request.param


def test_full_config_matches_assignment():
    c = get_config("deepseek-v3-671b")
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab) == (61, 7168, 128, 129280)
    assert (c.n_experts, c.top_k) == (256, 8) and c.use_mla and c.mtp
    c = get_config("gemma-2b")
    assert (c.n_layers, c.d_model, c.head_dim, c.n_kv_heads) == (18, 2048, 256, 1)
    c = get_config("zamba2-2.7b")
    assert (c.n_layers, c.d_model, c.ssm_state) == (54, 2560, 64)
    assert set(ARCHS) == {
        "zamba2-2.7b", "phi3-mini-3.8b", "nemotron-4-15b", "gemma-2b",
        "starcoder2-7b", "whisper-large-v3", "rwkv6-3b",
        "phi3.5-moe-42b-a6.6b", "deepseek-v3-671b", "internvl2-1b"}


def test_cells_skip_rules():
    for a in ARCHS:
        has_long = "long_500k" in cells(a)
        assert has_long == (a in SUBQUADRATIC)


def test_forward_and_train_step(arch):
    cfg = smoke_config(arch)
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, SHAPE).items()}
    tc = TrainConfig(lr=1e-2, warmup_steps=1, total_steps=4)
    state = S.init_state(cfg, tc, jax.random.PRNGKey(0))
    logits, aux = M.forward(state.params, cfg, batch)
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab
    assert bool(jnp.all(jnp.isfinite(logits)))
    step = jax.jit(S.build_train_step(cfg, tc))
    state2, metrics = step(state, batch)
    assert np.isfinite(metrics["loss"])
    # parameters actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.sum(jnp.abs(a - b))),
                     state.params, state2.params))
    assert delta > 0


def test_decode_step(arch):
    cfg = smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    cache = M.init_cache(cfg, batch=2, max_len=8, dtype=jnp.float32)
    serve = jax.jit(S.build_serve_step(cfg))
    toks = jnp.ones((2, 1), jnp.int32)
    for pos in range(3):
        toks, cache = serve(params, cache, toks, jnp.int32(pos))
    assert toks.shape == (2, 1)
    assert bool(jnp.all((toks >= 0) & (toks < cfg.vocab)))


def test_input_specs_cover_all_cells(arch):
    from repro.configs import SHAPES
    cfg = get_config(arch)
    for cell in cells(arch):
        specs = input_specs(cfg, SHAPES[cell])
        assert "tokens" in specs
        if SHAPES[cell].kind == "decode":
            assert specs["tokens"].shape[1] == 1
        else:
            total = specs["tokens"].shape[1] + (
                cfg.n_patches if cfg.family == "vlm" else 0)
            assert total == SHAPES[cell].seq_len


def test_moe_capacity_conservation():
    """Dispatch property: every kept entry lands in exactly one buffer slot
    and combine returns tokens unchanged when experts are identity."""
    from repro.models.moe import moe_ffn
    cfg = smoke_config("phi3.5-moe-42b-a6.6b").replace(
        n_experts=4, top_k=2, d_ff_expert=64, capacity_factor=8.0)
    key = jax.random.PRNGKey(0)
    D = cfg.d_model
    p = {
        "router": jax.random.normal(key, (D, 4)) * 0.1,
        "e_gate": jnp.zeros((4, D, 64)),
        "e_up": jnp.zeros((4, D, 64)),
        "e_down": jnp.zeros((4, 64, D)),
    }
    h = jax.random.normal(key, (2, 8, D))
    out, aux = moe_ffn(p, h, cfg)
    # zero experts -> zero output, but finite aux loss
    assert float(jnp.max(jnp.abs(out))) == 0.0
    assert np.isfinite(float(aux)) and float(aux) > 0


def test_mla_decode_matches_prefill_logits():
    """Absorbed MLA decode must agree with expanded-form prefill attention."""
    # capacity_factor high enough that prefill drops nothing (decode never
    # drops, so parity requires a drop-free prefill)
    cfg = smoke_config("deepseek-v3-671b").replace(mtp=False, n_layers=1,
                                                   capacity_factor=16.0)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    toks = jnp.asarray([[3, 5, 7, 11]], jnp.int32)
    logits_full, _ = M.forward(params, cfg, {"tokens": toks})
    cache = M.init_cache(cfg, 1, 8, jnp.float32)
    outs = []
    for t in range(4):
        lg, cache = M.decode_step(params, cfg, toks[:, t:t + 1], cache,
                                  jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(logits_full),
                               atol=2e-3, rtol=2e-3)


def test_gqa_decode_matches_prefill_logits():
    cfg = smoke_config("phi3-mini-3.8b").replace(n_layers=2)
    params = M.init_params(cfg, jax.random.PRNGKey(2))
    toks = jnp.asarray([[3, 5, 7, 11, 2]], jnp.int32)
    logits_full, _ = M.forward(params, cfg, {"tokens": toks})
    cache = M.init_cache(cfg, 1, 8, jnp.float32)
    outs = []
    for t in range(5):
        lg, cache = M.decode_step(params, cfg, toks[:, t:t + 1], cache,
                                  jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(logits_full),
                               atol=2e-3, rtol=2e-3)


def test_rwkv_decode_matches_prefill_logits():
    cfg = smoke_config("rwkv6-3b").replace(n_layers=2)
    params = M.init_params(cfg, jax.random.PRNGKey(3))
    toks = jnp.asarray([[3, 5, 7, 11]], jnp.int32)
    logits_full, _ = M.forward(params, cfg, {"tokens": toks})
    cache = M.init_cache(cfg, 1, 8, jnp.float32)
    outs = []
    for t in range(4):
        lg, cache = M.decode_step(params, cfg, toks[:, t:t + 1], cache,
                                  jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(logits_full),
                               atol=2e-3, rtol=2e-3)


def test_mamba_decode_matches_prefill_logits():
    cfg = smoke_config("zamba2-2.7b")
    params = M.init_params(cfg, jax.random.PRNGKey(4))
    toks = jnp.asarray([[3, 5, 7, 11, 2, 9, 1, 4]], jnp.int32)
    logits_full, _ = M.forward(params, cfg, {"tokens": toks})
    cache = M.init_cache(cfg, 1, 8, jnp.float32)
    outs = []
    for t in range(8):
        lg, cache = M.decode_step(params, cfg, toks[:, t:t + 1], cache,
                                  jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(logits_full),
                               atol=5e-3, rtol=5e-3)
