"""Minimal, deterministic stand-in for the `hypothesis` subset these tests
use (`given`, `settings(max_examples=, deadline=)`, and the
`strategies.integers` / `floats` / `sampled_from` / `booleans` / `tuples`
strategies).

The container has no `hypothesis` wheel and installing packages is off the
table, so `conftest.py` registers this module under the name "hypothesis"
when the real library is missing.  Each `@given` test is then run on
`max_examples` pseudo-random draws from a fixed seed — property testing
degrades to deterministic fuzzing, which keeps the oracle sweeps
meaningful (and CI green) without the dependency.

Tests written against this stub must stay real-hypothesis-compatible
(CI environments that do carry the wheel get true shrinking for free):
only keyword forms the real library also accepts are implemented, and
draw semantics match — `integers`/`floats` bounds are inclusive,
`sampled_from` takes a non-empty sequence, `tuples` composes strategies
positionally.
"""
from __future__ import annotations

import functools
import inspect
import types

import numpy as np


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def floats(min_value: float, max_value: float) -> _Strategy:
    """Uniform floats on the inclusive [min_value, max_value] interval.

    The real library's `floats` defaults (NaN/inf generation, subnormal
    hunting) need explicit bounds to be disabled anyway, so requiring
    both bounds here keeps stub- and real-runs drawing from the same
    domain.
    """
    lo, hi = float(min_value), float(max_value)
    return _Strategy(lambda rng: float(rng.uniform(lo, hi)))


def sampled_from(elements) -> _Strategy:
    """One element of a fixed non-empty sequence, like hypothesis's."""
    pool = list(elements)
    if not pool:
        raise ValueError("sampled_from requires a non-empty sequence")
    return _Strategy(lambda rng: pool[int(rng.integers(len(pool)))])


def booleans() -> _Strategy:
    return _Strategy(lambda rng: bool(rng.integers(2)))


def tuples(*strats: _Strategy) -> _Strategy:
    """Fixed-shape tuple of component draws, like hypothesis's."""
    return _Strategy(lambda rng: tuple(s.draw(rng) for s in strats))


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = integers
strategies.floats = floats
strategies.sampled_from = sampled_from
strategies.booleans = booleans
strategies.tuples = tuples


def settings(max_examples: int = 10, deadline=None, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def given(**strat_kwargs):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n_examples = getattr(wrapper, "_max_examples", 10)
            rng = np.random.default_rng(0)
            for _ in range(n_examples):
                drawn = {k: s.draw(rng) for k, s in strat_kwargs.items()}
                fn(*args, **{**kwargs, **drawn})
        # hide the drawn params from pytest's fixture resolution, exactly
        # as real hypothesis does
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items()
            if name not in strat_kwargs])
        del wrapper.__wrapped__
        return wrapper
    return deco
