"""Minimal, deterministic stand-in for the `hypothesis` subset these tests
use (`given`, `settings(max_examples=, deadline=)`, `strategies.integers`).

The container has no `hypothesis` wheel and installing packages is off the
table, so `conftest.py` registers this module under the name "hypothesis"
when the real library is missing.  Each `@given` test is then run on
`max_examples` pseudo-random draws from a fixed seed — property testing
degrades to deterministic fuzzing, which keeps the oracle sweeps
meaningful (and CI green) without the dependency.
"""
from __future__ import annotations

import functools
import inspect
import types

import numpy as np


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = integers


def settings(max_examples: int = 10, deadline=None, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def given(**strat_kwargs):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n_examples = getattr(wrapper, "_max_examples", 10)
            rng = np.random.default_rng(0)
            for _ in range(n_examples):
                drawn = {k: s.draw(rng) for k, s in strat_kwargs.items()}
                fn(*args, **{**kwargs, **drawn})
        # hide the drawn params from pytest's fixture resolution, exactly
        # as real hypothesis does
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items()
            if name not in strat_kwargs])
        del wrapper.__wrapped__
        return wrapper
    return deco
