"""Serving prefill handoff: prefill(prompt) then decode_step continues
exactly as if the whole sequence had been forwarded at once."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.models import model as M

PROMPT = [3, 5, 7, 11]
CONT = [2, 9]


def _parity(arch, extra=None, atol=5e-3):
    cfg = smoke_config(arch)
    if arch == "deepseek-v3-671b":
        cfg = cfg.replace(mtp=False, capacity_factor=16.0)
    if arch == "phi3.5-moe-42b-a6.6b":
        cfg = cfg.replace(capacity_factor=16.0)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    full_toks = jnp.asarray([PROMPT + CONT], jnp.int32)
    batch_full = {"tokens": full_toks}
    batch_pre = {"tokens": jnp.asarray([PROMPT], jnp.int32)}
    if extra:
        batch_full.update(extra)
        batch_pre.update(extra)
    logits_full, _ = M.forward(params, cfg, batch_full)

    lp, cache, pos = M.prefill(params, cfg, batch_pre, max_len=16,
                               cache_dtype=jnp.float32)
    # prefill logits match the full forward on the prompt part
    np.testing.assert_allclose(np.asarray(lp), 
                               np.asarray(logits_full[:, :len(PROMPT)]),
                               atol=atol, rtol=atol)
    # decode continues to match (pos returned by prefill is absolute,
    # patches included for VLM)
    pos = int(pos)
    for i, t in enumerate(CONT):
        lg, cache = M.decode_step(params, cfg,
                                  jnp.asarray([[t]], jnp.int32), cache,
                                  jnp.int32(pos + i))
        np.testing.assert_allclose(
            np.asarray(lg[:, 0]),
            np.asarray(logits_full[:, len(PROMPT) + i]),
            atol=atol, rtol=atol)


def test_prefill_parity_dense():
    _parity("phi3-mini-3.8b")


def test_prefill_parity_mla_moe():
    _parity("deepseek-v3-671b")


def test_prefill_parity_ssm():
    _parity("rwkv6-3b")


def test_prefill_parity_hybrid():
    _parity("zamba2-2.7b", atol=1e-2)


def test_prefill_parity_audio():
    rng = np.random.default_rng(0)
    frames = jnp.asarray(rng.normal(size=(1, 16, 64)), jnp.float32)
    _parity("whisper-large-v3", extra={"enc_frames": frames})


def test_prefill_parity_vlm():
    rng = np.random.default_rng(0)
    patches = jnp.asarray(rng.normal(size=(1, 4, 64)), jnp.float32)
    _parity("internvl2-1b", extra={"patches": patches})
