"""Fault-tolerance integration: checkpoint/resume determinism, straggler
skip, checkpoint atomicity, optimizer behaviour, gradient compression."""
import os
import shutil

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import ckpt
from repro.configs import smoke_config
from repro.configs.base import ShapeConfig, TrainConfig
from repro.optim import adamw as O
from repro.optim import compression as C
from repro.train.loop import train

SHAPE = ShapeConfig("tiny", 32, 4, "train")


def _tc(tmpdir, **kw):
    kw.setdefault("lr", 1e-2)
    kw.setdefault("total_steps", 10)
    kw.setdefault("ckpt_every", 4)
    kw.setdefault("diag_every", 5)
    return TrainConfig(ckpt_dir=str(tmpdir), **kw)


def test_loss_decreases(tmp_path):
    cfg = smoke_config("gemma-2b")
    state, hist = train(cfg, _tc(tmp_path, total_steps=15), SHAPE,
                        log=lambda s: None)
    assert hist[-1]["loss"] < hist[0]["loss"]
    assert any("vat_block_score" in h for h in hist)  # diagnostics ran


def test_resume_is_bitwise_deterministic(tmp_path):
    cfg = smoke_config("phi3-mini-3.8b")
    a, b = tmp_path / "a", tmp_path / "b"
    # uninterrupted run
    tc = _tc(a, total_steps=8, ckpt_every=4)
    state_full, _ = train(cfg, tc, SHAPE, log=lambda s: None)
    # interrupted at step 5 (after the step-4 checkpoint), then resumed
    tc2 = _tc(b, total_steps=8, ckpt_every=4)
    with pytest.raises(KeyboardInterrupt):
        train(cfg, tc2, SHAPE, log=lambda s: None, interrupt_at=5)
    state_res, _ = train(cfg, tc2, SHAPE, log=lambda s: None)
    for pa, pb in zip(jax.tree.leaves(state_full.params),
                      jax.tree.leaves(state_res.params)):
        np.testing.assert_array_equal(np.asarray(pa), np.asarray(pb))


def test_straggler_deadline_skips(tmp_path):
    cfg = smoke_config("gemma-2b")
    tc = _tc(tmp_path, total_steps=4)
    logs = []
    _, hist = train(cfg, tc, SHAPE, log=logs.append,
                    step_deadline_s=1e-12)   # impossible deadline
    assert len(hist) == 0                    # every batch skipped, no hang
    assert any("straggler" in line for line in logs)


def test_checkpoint_roundtrip_and_gc(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, tree, keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 5
    dirs = sorted(os.listdir(tmp_path))
    assert dirs == ["step_00000004", "step_00000005"]  # GC kept last 2
    got, manifest = ckpt.restore(str(tmp_path), tree)
    assert manifest["step"] == 5
    np.testing.assert_array_equal(np.asarray(got["a"]), np.arange(6).reshape(2, 3))
    assert got["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_no_partial_publish(tmp_path):
    """A tmp.<step> dir must never be visible as a restorable checkpoint."""
    tree = {"w": jnp.zeros((8,))}
    ckpt.save(str(tmp_path), 1, tree)
    os.makedirs(tmp_path / "tmp.999", exist_ok=True)  # simulated crash debris
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_adamw_and_adafactor_optimize_quadratic():
    for opt in ("adamw", "adafactor"):
        tc = TrainConfig(lr=0.1, warmup_steps=1, total_steps=2000,
                         optimizer=opt, weight_decay=0.0)
        params = {"w": jnp.asarray([[3.0, -2.0], [1.0, 4.0]])}
        st = O.init_opt(tc, params)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}       # d/dw ||w||^2
            params, st = O.apply_opt(tc, params, grads, st)
        assert float(jnp.max(jnp.abs(params["w"]))) < 0.5, opt


def test_gradient_compression_error_feedback():
    params = {"w": jnp.zeros((8, 8))}
    ef = C.ef_init(params)
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(8, 8)),
                          jnp.float32)}
    sent1, ef = C.compress(g, ef, frac=0.1)
    nz = int(jnp.sum(sent1["w"] != 0))
    assert nz <= 8  # ~10% of 64, top-k by magnitude
    # residual carries the unsent mass: sent + residual == accumulated grad
    np.testing.assert_allclose(
        np.asarray(sent1["w"] + ef.residual["w"]), np.asarray(g["w"]),
        atol=1e-6)
    # a second round with zero grad flushes more of the residual
    sent2, ef2 = C.compress({"w": jnp.zeros((8, 8))}, ef, frac=0.1)
    assert float(jnp.sum(jnp.abs(ef2.residual["w"]))) \
        < float(jnp.sum(jnp.abs(ef.residual["w"])))


def test_clip_by_global_norm():
    g = {"w": jnp.full((10,), 10.0)}
    clipped, gn = O.clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(np.sqrt(1000.0), rel=1e-5)
    norm_after = float(jnp.linalg.norm(clipped["w"]))
    assert norm_after == pytest.approx(1.0, rel=1e-4)
