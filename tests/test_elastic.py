"""Elastic re-mesh: a checkpoint written on one topology restores and
trains on another (checkpoints hold unsharded logical tensors)."""
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import shutil
    import jax, jax.numpy as jnp
    from repro.checkpoint import ckpt
    from repro.configs import smoke_config
    from repro.configs.base import ShapeConfig, TrainConfig
    from repro.data.tokens import make_batch, input_specs
    from repro.launch.shardspecs import batch_shardings, state_shardings
    from repro.models import sharding
    from repro.train import steps as S

    cfg = smoke_config("phi3-mini-3.8b")
    tc = TrainConfig(lr=1e-3)
    shape = ShapeConfig("t", 32, 8, "train")
    ckdir = "/tmp/repro_elastic_ckpt"
    shutil.rmtree(ckdir, ignore_errors=True)

    # phase 1: "train" on a 1-device mesh and checkpoint
    state = S.init_state(cfg, tc, jax.random.PRNGKey(0))
    step1 = jax.jit(S.build_train_step(cfg, tc))
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, shape).items()}
    state, m1 = step1(state, batch)
    ckpt.save(ckdir, 1, state)

    # phase 2: restore onto a 4x2 mesh (different topology) and continue
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    sharding.set_mesh(mesh)
    template = jax.eval_shape(
        lambda: S.init_state(cfg, tc, jax.random.PRNGKey(0)))
    restored, man = ckpt.restore(ckdir, template)
    assert man["step"] == 1
    st_sh = state_shardings(restored, mesh)
    restored = jax.device_put(restored, st_sh)
    fn = jax.jit(S.build_train_step(cfg, tc),
                 in_shardings=(st_sh,
                               batch_shardings(cfg, mesh,
                                               input_specs(cfg, shape))))
    with mesh:
        state2, m2 = fn(restored, batch)
    assert jnp.isfinite(m2["loss"]), m2
    # the restored step must see the same loss landscape: one more step
    # from the same state on either mesh starts from identical params
    print("ELASTIC_OK", float(m2["loss"]))
""")


def test_elastic_remesh_subprocess():
    r = subprocess.run([sys.executable, "-c", SCRIPT],
                       capture_output=True, text=True, timeout=500,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"})
    assert "ELASTIC_OK" in r.stdout, r.stderr[-3000:]
