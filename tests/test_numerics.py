"""Numerics shield (ISSUE 10 tentpole): condition-aware dispatch,
precision policies, and certified ordering stability.

Pins the whole contract:

* the policy/statistics layer (κ derivation constants, ``as_policy``
  coercion, ``condition_stats`` on solo + batched + pathological input);
* the conditioning transform's EXACTNESS properties (power-of-2 scale,
  bitwise shift cancellation on exact-arithmetic grid data);
* ``resolve`` planning per mode (fast / safe / auto × metric);
* bf16 storage: quantization shape, certification, the counted
  fallback, and the ``kernels.numerics_trip`` fault site;
* the acceptance pin — ``fit(X)`` vs ``fit(X + c·1)`` BITWISE-equal
  orderings under the default auto policy for |c| up to 1e6, across
  vat / ivat / flashvat / turbo-off / approx, solo and batched;
* cosine zero-norm admission (solo, batched, streaming, and the
  ``validate=False`` escape hatch);
* the certification harness itself (smoke sweep + oracle sanity);
* the serving layer: the resolved plan as ProgramKey material, the
  per-request ``NumericsReport``, and the resilience fallback counter.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from _numerics_data import ADVERSARIAL_NAMES, adversarial, grid_clusters
from repro import faults
from repro.api import FastVAT
from repro.api.validation import InvalidInput
from repro.core.streaming import StreamingVAT
from repro.numerics import (CONDITIONED_METRICS, KAPPA_BF16, KAPPA_SAFE,
                            NumericsPolicy, NumericsReport, as_policy,
                            condition_stats, condition_transform,
                            lb_slack_ulps, resolve)
from repro.numerics.certify import (certify_fit, ordering_excess,
                                    oracle_dissim, sweep)
from repro.numerics.condition import _quantize_bf16
from repro.serve import ServeConfig, TendencyServer, resolve_key


def _near_origin(n=64, d=4, seed=0):
    rng = np.random.default_rng(seed)
    half = n // 2
    return np.concatenate([rng.normal(size=(half, d)),
                           rng.normal(size=(n - half, d)) + 6.0]
                          ).astype(np.float32)


# ------------------------------------------------ policy & constants ----

def test_kappa_safe_is_the_documented_derivation():
    """κ_safe = 1/(1024·eps_f32): 64-ulp Gram error below gap/16."""
    eps = float(np.finfo(np.float32).eps)
    assert KAPPA_SAFE == 1.0 / (1024.0 * eps) == 8192.0
    assert KAPPA_BF16 == 16.0


def test_lb_slack_ulps_per_form():
    """The shared pruning-slack constant: 64 ulps for the cancelling
    Gram decomposition, 4 for the cancellation-free direct form."""
    assert lb_slack_ulps("gram") == 64.0
    assert lb_slack_ulps("direct") == 4.0
    with pytest.raises(ValueError, match="form"):
        lb_slack_ulps("exact")


def test_as_policy_coercion_and_validation():
    p = as_policy("safe")
    assert isinstance(p, NumericsPolicy) and p.mode == "safe"
    assert as_policy(p) is p
    with pytest.raises(TypeError, match="numerics"):
        as_policy(3.14)
    with pytest.raises(ValueError, match="mode"):
        NumericsPolicy(mode="yolo")
    with pytest.raises(ValueError, match="dtype"):
        NumericsPolicy(dtype="f16")


def test_condition_stats_sees_the_offset():
    near = condition_stats(_near_origin())
    far = condition_stats(_near_origin() + 1.0e4)
    assert near.kappa < KAPPA_SAFE < far.kappa
    # centering removes the offset: the post-transform κ is the near one
    assert far.kappa_centered < KAPPA_SAFE
    assert far.max_sq_norm > 1e7 and far.centered_max_sq < 1e3


def test_condition_stats_batched_takes_worst_lane():
    good = _near_origin(seed=1)
    bad = _near_origin(seed=2) + 1.0e4
    st_b = condition_stats(np.stack([good, bad]))
    assert st_b.kappa == condition_stats(bad).kappa
    assert st_b.gap_proxy == min(condition_stats(good).gap_proxy,
                                 condition_stats(bad).gap_proxy)
    with pytest.raises(ValueError, match="shape"):
        condition_stats(np.zeros(5, np.float32))


def test_condition_stats_degenerate_inputs():
    zero = condition_stats(np.zeros((8, 3), np.float32))
    assert zero.kappa == 0.0 and zero.gap_proxy == 0.0
    # all-identical nonzero points: finite norm over zero gap -> inf
    same = condition_stats(np.ones((8, 3), np.float32) * 5.0)
    assert same.kappa == float("inf")


# ------------------------------------------------------ the transform ----

def test_condition_transform_scale_is_power_of_two():
    X = _near_origin() * 37.3 + 1234.5
    C = condition_transform(X)
    assert C.dtype == np.float32
    amax = float(np.max(np.abs(C)))
    assert 1.0 <= amax < 2.0
    # the documented formula, replayed: f64 center, exact 2^-k rescale
    spread64 = np.asarray(X, np.float64) - np.mean(
        np.asarray(X, np.float64), axis=0)
    scale = float(np.exp2(-np.floor(np.log2(np.max(np.abs(spread64))))))
    np.testing.assert_array_equal(
        C, np.asarray(spread64 * scale, np.float32))


def test_condition_transform_cancels_exact_shifts_bitwise():
    """The heart of the shift-invariance pin, isolated: on the exact
    -arithmetic grid, transform(X + c) == transform(X) to the bit."""
    X = grid_clusters()
    base = condition_transform(X)
    for c in (1e3, 1e4, 1e6, -1e6):
        shifted = condition_transform(X + np.float32(c))
        np.testing.assert_array_equal(base, shifted)


def test_condition_transform_batched_is_per_lane():
    Xs = np.stack([grid_clusters(seed=0), grid_clusters(seed=1) + 512.0])
    Cb = condition_transform(Xs)
    np.testing.assert_array_equal(Cb[0], condition_transform(Xs[0]))
    np.testing.assert_array_equal(Cb[1], condition_transform(Xs[1]))


# -------------------------------------------------- resolve planning ----

@pytest.mark.parametrize("metric", CONDITIONED_METRICS)
def test_resolve_auto_thresholds_on_kappa(metric):
    near = _near_origin()
    Xo, rep = resolve(near, metric=metric)
    assert (rep.form, rep.conditioned) == ("gram", False)
    assert Xo is not near or Xo.dtype == np.float32  # unchanged f32 pass
    np.testing.assert_array_equal(Xo, near)
    Xc, repc = resolve(near + 1.0e4, metric=metric)
    assert (repc.form, repc.conditioned) == ("direct", True)
    assert repc.kappa > KAPPA_SAFE
    assert float(np.max(np.abs(Xc))) < 2.0


def test_resolve_fast_and_safe_modes():
    X = _near_origin() + 1.0e4
    _, fast = resolve(X, metric="euclidean", policy="fast")
    assert (fast.form, fast.conditioned) == ("gram", False)
    Xs_, safe = resolve(_near_origin(), metric="euclidean", policy="safe")
    assert (safe.form, safe.conditioned) == ("direct", True)


def test_resolve_cosine_never_conditions():
    """Centering is not an isometry of cosine — even safe mode must
    pass the coordinates through untouched."""
    X = _near_origin() + 1.0e4
    for policy in ("fast", "auto", "safe"):
        Xo, rep = resolve(X, metric="cosine", policy=policy)
        assert (rep.form, rep.conditioned) == ("gram", False)
        np.testing.assert_array_equal(Xo, X)


def test_resolve_batched_shape_guard():
    with pytest.raises(ValueError, match="batched"):
        resolve(_near_origin(), metric="euclidean", batched=True)
    Xs = np.stack([_near_origin(seed=3), _near_origin(seed=4) + 1e4])
    Xo, rep = resolve(Xs, metric="euclidean", batched=True)
    assert rep.conditioned and Xo.shape == Xs.shape


# ------------------------------------------------------ bf16 storage ----

def test_quantize_bf16_is_storage_rounding():
    rng = np.random.default_rng(5)
    X = rng.normal(size=(64, 4)).astype(np.float32)
    Q = _quantize_bf16(X)
    assert Q.dtype == np.float32 and Q.shape == X.shape
    # every value sits on the bf16 lattice (low 16 mantissa bits clear)
    assert not np.any(Q.view(np.uint32) & 0xFFFF)
    # round-to-nearest: relative error within one bf16 ulp
    np.testing.assert_allclose(Q, X, rtol=2.0 ** -8)
    np.testing.assert_array_equal(_quantize_bf16(Q), Q)  # idempotent


def test_resolve_bf16_certifies_on_conditioned_grid():
    X = grid_clusters()
    Xo, rep = resolve(X, metric="euclidean",
                      policy=NumericsPolicy(dtype="bf16"))
    assert rep.dtype == "bf16" and rep.fallbacks == 0
    assert rep.conditioned           # the grid sits at offset 1000
    assert not np.any(Xo.view(np.uint32) & 0xFFFF)


def test_resolve_bf16_counted_fallback_on_wide_data():
    """mixed_scale under auto sits below KAPPA_SAFE (no conditioning),
    but its raw κ is far above KAPPA_BF16: the bf16 request degrades to
    f32 with fallbacks=1 — never silently."""
    X = adversarial("mixed_scale")
    stats = condition_stats(X)
    assert KAPPA_BF16 < stats.kappa < KAPPA_SAFE
    Xo, rep = resolve(X, metric="euclidean",
                      policy=NumericsPolicy(dtype="bf16"))
    assert not rep.conditioned
    assert rep.dtype == "f32" and rep.fallbacks == 1


def test_resolve_bf16_fault_trip():
    """The chaos seam: kernels.numerics_trip fails certification on
    demand, producing the same counted degradation."""
    X = grid_clusters()
    with faults.injected("kernels.numerics_trip"):
        _, rep = resolve(X, metric="euclidean",
                         policy=NumericsPolicy(dtype="bf16"))
    assert rep.dtype == "f32" and rep.fallbacks == 1
    _, clean = resolve(X, metric="euclidean",
                       policy=NumericsPolicy(dtype="bf16"))
    assert clean.dtype == "bf16" and clean.fallbacks == 0


# ------------------------------------------------ facade integration ----

def test_fit_stamps_numerics_report():
    fv = FastVAT().fit(_near_origin())
    rep = fv.result.meta.numerics
    assert isinstance(rep, NumericsReport)
    assert (rep.mode, rep.form, rep.conditioned) == ("auto", "gram", False)
    far = FastVAT().fit(_near_origin() + 1.0e4)
    assert far.result.meta.numerics.form == "direct"


def test_precomputed_and_memmap_bypass_the_prepass(tmp_path):
    import jax.numpy as jnp
    from repro.kernels import ops as kops
    X = _near_origin(n=48)
    D = np.asarray(kops.pairwise_dist(jnp.asarray(X)))
    via = FastVAT(metric="precomputed").fit(D)
    assert via.result.meta.numerics is None
    mm_path = tmp_path / "pts.f32"
    mm = np.memmap(mm_path, dtype=np.float32, mode="w+", shape=X.shape)
    mm[:] = X + 1.0e4                 # ill-conditioned, but out-of-core
    mm.flush()
    via_mm = FastVAT(method="vat").fit(mm)
    assert via_mm.result.meta.numerics is None


def test_fit_many_stamps_worst_lane_report():
    Xs = np.stack([_near_origin(seed=7), _near_origin(seed=8) + 1.0e4])
    fv = FastVAT(method="ivat").fit_many(Xs)
    rep = fv.result.meta.numerics
    assert rep.conditioned and rep.form == "direct"
    assert rep.kappa > KAPPA_SAFE


# ------------------------------------- the shift-invariance acceptance ----

SHIFTS = (1e3, 1e4, 1e6, -1e6)
SOLO_CONFIGS = (
    ("vat", {}),
    ("ivat", {}),
    ("flashvat", {"sample_size": 32}),                  # turbo engine
    ("flashvat", {"sample_size": 32, "turbo": False}),  # stepwise engine
    ("approx", {"knn_k": 8}),
)


@pytest.mark.parametrize("metric", CONDITIONED_METRICS)
@pytest.mark.parametrize("method,kw", SOLO_CONFIGS,
                         ids=["vat", "ivat", "flashvat", "turbo-off",
                              "approx"])
def test_orderings_shift_invariant_bitwise_solo(metric, method, kw):
    """ISSUE 10 acceptance: under the default auto policy,
    ``fit(X + c·1)`` reproduces ``fit(X)``'s ordering BITWISE for |c|
    up to 1e6 — every translation-invariant metric, every rung."""
    X = grid_clusters()
    base = FastVAT(method=method, metric=metric, **kw).fit(X)
    assert base.result.meta.numerics.conditioned   # κ(X) > KAPPA_SAFE
    for c in SHIFTS:
        shifted = FastVAT(method=method, metric=metric, **kw).fit(
            X + np.float32(c))
        rep = shifted.result.meta.numerics
        assert rep.conditioned and rep.form == "direct"
        np.testing.assert_array_equal(shifted.order(), base.order(),
                                      err_msg=f"c={c}")


@pytest.mark.parametrize("method,kw", SOLO_CONFIGS[:3],
                         ids=["vat", "ivat", "flashvat"])
def test_orderings_shift_invariant_bitwise_batched(method, kw):
    Xs = np.stack([grid_clusters(seed=0), grid_clusters(seed=1)])
    base = FastVAT(method=method, metric="sqeuclidean", **kw).fit_many(Xs)
    for c in (1e3, -1e6):
        shifted = FastVAT(method=method, metric="sqeuclidean",
                          **kw).fit_many(Xs + np.float32(c))
        np.testing.assert_array_equal(shifted.order(), base.order(),
                                      err_msg=f"c={c}")


def test_fast_mode_is_the_preshield_path():
    """numerics='fast' must leave the data untouched — byte-for-byte
    the pre-shield Gram behavior, even on hostile offsets."""
    X = grid_clusters()
    fv = FastVAT(numerics="fast").fit(X + np.float32(1e4))
    rep = fv.result.meta.numerics
    assert (rep.form, rep.conditioned) == ("gram", False)


# ------------------------------------------------- zero-norm admission ----

def _with_zero_row(n=32, d=4, seed=11):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d)).astype(np.float32)
    X[n // 2] = 0.0
    return X


def test_cosine_zero_norm_rejected_solo():
    X = _with_zero_row()
    with pytest.raises(InvalidInput, match="zero-norm") as ei:
        FastVAT(metric="cosine").fit(X)
    assert ei.value.reason == "zero_norm"
    # other metrics are perfectly happy with the origin as a point
    FastVAT(metric="euclidean").fit(X)
    # and the escape hatch keeps the documented eps-guard semantics
    fv = FastVAT(metric="cosine", validate=False).fit(X)
    assert len(fv.order()) == 32


def test_cosine_zero_norm_rejected_batched():
    Xs = np.stack([_with_zero_row(seed=12), _with_zero_row(seed=13)])
    Xs[0, 16] = 1.0                       # lane 1 carries the zero row
    with pytest.raises(InvalidInput) as ei:
        FastVAT(metric="cosine").fit_many(Xs)
    assert ei.value.reason == "zero_norm"
    FastVAT(metric="cosine", validate=False).fit_many(Xs)


def test_cosine_zero_norm_rejected_streaming():
    sv = StreamingVAT(cap=16, d=4, metric="cosine")
    sv.update(np.abs(_with_zero_row(seed=14)[:8]) + 0.1)
    n_before = len(sv.pts)
    chunk = _with_zero_row(n=8, seed=15)
    with pytest.raises(InvalidInput) as ei:
        sv.update(chunk)
    assert ei.value.reason == "zero_norm"
    assert len(sv.pts) == n_before        # whole chunk refused atomically
    relaxed = StreamingVAT(cap=16, d=4, metric="cosine", validate=False)
    relaxed.update(chunk)
    assert relaxed.n_seen == 8


# -------------------------------------------- adversarial properties ----

@settings(max_examples=5, deadline=None)
@given(name=st.sampled_from(ADVERSARIAL_NAMES),
       metric=st.sampled_from(CONDITIONED_METRICS))
def test_auto_policy_certifies_on_adversarial_data(name, metric):
    """Property sweep over the shared worst-case pool: a vat fit under
    the default auto policy always meets its certification bound."""
    X = adversarial(name, n=48)
    r = certify_fit(X, method="vat", metric=metric, generator=name)
    assert r.ok, r


def test_fast_mode_actually_fails_on_the_adversary():
    """The shield is load-bearing: the SAME data that certifies under
    auto breaks its bound when conditioning is forced off."""
    X = adversarial("tiny_gaps", n=48)
    r_auto = certify_fit(X, method="vat", metric="sqeuclidean",
                         policy="auto")
    r_fast = certify_fit(X, method="vat", metric="sqeuclidean",
                         policy="fast")
    assert r_auto.ok and r_auto.conditioned
    assert not r_fast.ok and r_fast.excess > r_auto.excess


# ----------------------------------------------- certification harness ----

def test_oracle_excess_of_the_oracle_is_zero():
    X = _near_origin(n=24)
    from repro.core.naive import vat_order_naive
    order = vat_order_naive(oracle_dissim(X, "euclidean").tolist())
    excess, exact = ordering_excess(X, order, "euclidean")
    assert excess == 0.0 and exact


def test_certify_smoke_sweep_passes():
    results = sweep(methods=("vat",), metrics=("euclidean",),
                    generators=None, n=32)
    assert len(results) == 5 * 3          # 5 generators x 3 policies
    assert all(r.ok for r in results), [r for r in results if not r.ok]
    # determinism: the same seed reproduces the same cells exactly
    again = sweep(methods=("vat",), metrics=("euclidean",), n=32)
    assert results == again


# ----------------------------------------------------- serving layer ----

def test_program_key_carries_the_resolved_plan():
    cfg = ServeConfig()
    kg = resolve_key(100, 4, method="vat", config=cfg)
    kd = resolve_key(100, 4, method="vat", config=cfg, num_form="direct")
    kb = resolve_key(100, 4, method="vat", config=cfg, num_dtype="bf16")
    assert len({kg, kd, kb}) == 3         # no cross-plan coalescing
    assert (kg.num_form, kg.num_dtype) == ("gram", "f32")


def test_serve_resolves_per_request_and_matches_solo():
    X = grid_clusters()
    with TendencyServer(ServeConfig(window_s=0.001)) as srv:
        near = srv.fit(_near_origin())
        far = srv.fit(X + np.float32(1e4))
    assert near.meta.numerics.form == "gram"
    rep = far.meta.numerics
    assert rep.conditioned and rep.form == "direct"
    solo = FastVAT(method="vat").fit(X + np.float32(1e4))
    np.testing.assert_array_equal(np.asarray(far.order), solo.order())


def test_serve_bf16_fallback_is_counted():
    cfg = ServeConfig(window_s=0.001,
                      numerics=NumericsPolicy(dtype="bf16"))
    X = grid_clusters()
    with TendencyServer(cfg) as srv:
        clean = srv.fit(X)
        assert clean.meta.numerics.dtype == "bf16"
        assert srv.stats().resilience.numerics_fallbacks == 0
        with faults.injected("kernels.numerics_trip"):
            tripped = srv.fit(X + np.float32(4096.0))
        assert tripped.meta.numerics.dtype == "f32"
        assert tripped.meta.numerics.fallbacks == 1
        assert srv.stats().resilience.numerics_fallbacks == 1
