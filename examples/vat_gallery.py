"""Reproduce the paper's figures: VAT + iVAT images for all 7 datasets.

Writes grayscale PGM images to ./gallery/ (viewable anywhere; no
matplotlib dependency) and prints the Table 2/3 summary.

Run:  PYTHONPATH=src python examples/vat_gallery.py
"""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import core
from repro.data.synth import DATASETS, make_dataset

OUT = os.path.join(os.path.dirname(__file__), "gallery")


def save_pgm(path: str, img: np.ndarray) -> None:
    """img float (n,n) -> 8-bit PGM; dark = similar (paper convention)."""
    g = img / (img.max() + 1e-9)
    g8 = (g * 255).astype(np.uint8)
    with open(path, "wb") as f:
        f.write(f"P5 {g8.shape[1]} {g8.shape[0]} 255\n".encode())
        f.write(g8.tobytes())


def main():
    os.makedirs(OUT, exist_ok=True)
    print(f"{'dataset':10s} {'hopkins':>8s} {'block':>6s} {'k_est':>5s}")
    for name in DATASETS:
        X, _ = make_dataset(name)
        Xj = jnp.asarray(X)
        res = core.vat(Xj)
        iv = core.ivat_from_vat(res.rstar)
        save_pgm(os.path.join(OUT, f"{name}_vat.pgm"), np.asarray(res.rstar))
        save_pgm(os.path.join(OUT, f"{name}_ivat.pgm"), np.asarray(iv))
        h = float(core.hopkins(Xj, jax.random.PRNGKey(0)))
        s, k = core.block_structure_score(res.rstar)
        print(f"{name:10s} {h:8.3f} {float(s):6.3f} {int(k):5d}")
    print(f"\nimages -> {OUT}/<dataset>_{{vat,ivat}}.pgm")


if __name__ == "__main__":
    main()
