"""Batched VAT engine demo: many datasets, one compiled program.

The DeepVAT-style workload — assess a stack of embedding sets (here:
synthetic datasets with 1..4 clusters) in a single ``fit_many`` call,
then verify the batch is bitwise-identical to solo fits and print each
dataset's machine-checkable verdict.

Run:  PYTHONPATH=src python examples/batch_demo.py
"""
import time

import numpy as np

import jax

from repro import FastVAT


def make_stack(b: int = 8, n: int = 256, d: int = 8, seed: int = 0):
    """(b, n, d) stack; dataset i has (i % 4) + 1 Gaussian clusters."""
    rng = np.random.default_rng(seed)
    stack, k_true = [], []
    for i in range(b):
        k = (i % 4) + 1
        centers = rng.normal(scale=12.0, size=(k, d))
        sizes = np.full(k, n // k)
        sizes[: n - sizes.sum()] += 1
        X = np.concatenate([
            centers[j] + rng.normal(size=(sz, d)) for j, sz in enumerate(sizes)])
        stack.append(X[rng.permutation(n)].astype(np.float32))
        k_true.append(k)
    return np.stack(stack), k_true


def main():
    Xs, k_true = make_stack()
    b, n, d = Xs.shape

    fv = FastVAT(method="ivat").fit_many(Xs)        # warmup absorbs compile
    t0 = time.perf_counter()
    fv = FastVAT(method="ivat").fit_many(Xs)
    jax.block_until_ready(fv.result.rstar)
    t_batch = time.perf_counter() - t0

    t0 = time.perf_counter()
    solos = [FastVAT(method="ivat").fit(Xs[i]) for i in range(b)]
    jax.block_until_ready(solos[-1].result.rstar)
    t_loop = time.perf_counter() - t0

    orders = fv.order()                             # (b, n)
    for i, solo in enumerate(solos):
        assert np.array_equal(orders[i], solo.order()), i

    print(f"stack: {b} datasets x ({n}, {d})   "
          f"fit_many: {t_batch*1e3:.1f} ms   solo loop: {t_loop*1e3:.1f} ms")
    print("batch == solo orderings: bitwise-identical\n")
    print(f"{'dataset':>8} {'k_true':>6} {'k_est':>5} {'hopkins':>8} "
          f"{'block':>6}  verdict")
    for rep, kt in zip(fv.assess(), k_true):
        print(f"{rep['batch_index']:>8} {kt:>6} {rep['k_est']:>5} "
              f"{rep['hopkins']:>8.3f} {rep['block_score']:>6.3f}  "
              f"{'clustered' if rep['clustered'] else 'uniform-ish'}")


if __name__ == "__main__":
    main()
