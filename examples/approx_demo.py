"""Million-point VAT on one CPU: the approx rung end-to-end.

Exact VAT at n = 1,000,000 would need a 4 TB float32 (n, n) matrix; even
the matrix-free Turbo engine's O(n^2 d) work is hours on a CPU.  The
approx rung (kNN-graph Borůvka MST, ``docs/scaling.md``) fits the same
million points in minutes with an O(n·k) working set — this script runs
it and prints the wall time, the error report it certified itself with,
and a working-set audit (dominant arrays + peak RSS) against the (n, n)
matrix it never built.

Run:  PYTHONPATH=src python examples/approx_demo.py            # 1M points
      PYTHONPATH=src python examples/approx_demo.py --n 50000 --k 10
"""
import argparse
import resource
import time

import numpy as np

from repro import FastVAT

#: anchored-search probes (mirrors core.approx_vat's default) — only
#: used for the working-set estimate printed below.
PROBES = 2


def make_blobs(n: int, k: int = 5, d: int = 8, seed: int = 0):
    """(n, d) float32 Gaussian blobs + labels, built blockwise so the
    generator itself stays inside the demo's memory story."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(scale=20.0, size=(k, d)).astype(np.float32)
    lab = rng.integers(0, k, size=n)
    X = np.empty((n, d), np.float32)
    for s in range(0, n, 100_000):
        e = min(s + 100_000, n)
        X[s:e] = centers[lab[s:e]] + rng.normal(
            size=(e - s, d)).astype(np.float32)
    return X, lab


def run(n: int = 1_000_000, k: int = 15, sample_size: int = 256,
        seed: int = 0) -> dict:
    """Fit the approx rung on n blob points; return the printed facts."""
    X, lab = make_blobs(n, seed=seed)

    t0 = time.perf_counter()
    fv = FastVAT(method="approx", knn_k=k, sample_size=sample_size).fit(X)
    wall = time.perf_counter() - t0

    res = fv.result
    order = fv.order()
    stats = res.meta.approx
    runs = 1 + int(np.sum(lab[order][1:] != lab[order][:-1]))

    # Working set: the dominant arrays each stage actually holds.  The
    # anchored merge buffers (n, probes, k) f32+i64 dwarf everything
    # else; the (n, n) matrix exact VAT needs is printed for scale.
    working = {
        "X (n, d) f32": X.nbytes,
        "kNN graph (n, k) f32+i32": n * k * 8,
        "merge buffers (n, probes, k) f32+i64": n * PROBES * k * 12,
        "MST edges 3x(n-1)": (n - 1) * 12,
    }
    dense = n * n * 4

    print(f"n = {n:,}  d = {X.shape[1]}  k = {k}   "
          f"method = {fv.method_resolved}")
    print(f"wall: {wall:.1f} s   order is a permutation: "
          f"{np.array_equal(np.sort(order), np.arange(n))}   "
          f"cluster runs: {runs} (true clusters: {lab.max() + 1})")
    print(f"error report: {stats}")
    print("working set:")
    for name, b in working.items():
        print(f"  {name:<40s} {b / 2**20:10.1f} MiB")
    print(f"  {'exact (n, n) f32 — NEVER built':<40s} "
          f"{dense / 2**30:10.1f} GiB")
    rss_kib = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    print(f"peak RSS: {rss_kib / 2**20:.2f} GiB "
          f"(dense matrix would be {dense / rss_kib / 2**10:,.0f}x that)")
    return {"n": n, "k": k, "wall": wall, "method": fv.method_resolved,
            "order": order, "stats": stats, "runs": runs,
            "working_bytes": max(working.values()), "dense_bytes": dense}


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--n", type=int, default=1_000_000)
    ap.add_argument("--k", type=int, default=15)
    ap.add_argument("--sample-size", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()
    run(n=a.n, k=a.k, sample_size=a.sample_size, seed=a.seed)


if __name__ == "__main__":
    main()
