"""End-to-end driver: train an LM with in-loop VAT cluster-tendency
diagnostics, survive an interruption, and resume from checkpoint.

Default runs a ~15M-param gemma-family model for 120 steps on CPU
(minutes); --arch/--steps/--dim scale it up (the same script drives the
full configs on a real pod — the launcher only changes the mesh).

Run:  PYTHONPATH=src python examples/train_diagnostics.py [--steps 120]
"""
import argparse
import shutil

from repro.configs import get_config, smoke_config
from repro.configs.base import ShapeConfig, TrainConfig
from repro.monitor import STATE_NAMES
from repro.train.loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--dim", type=int, default=256,
                    help="d_model override (0 = full config)")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--fresh", action="store_true")
    args = ap.parse_args()

    if args.dim:
        cfg = smoke_config(args.arch).replace(
            d_model=args.dim, n_layers=4, d_ff=4 * args.dim, vocab=2048,
            n_heads=8, n_kv_heads=8, head_dim=args.dim // 8)
    else:
        cfg = get_config(args.arch)

    tc = TrainConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps,
                     ckpt_every=40, diag_every=20,
                     ckpt_dir="/tmp/repro_example_ckpt")
    if args.fresh:
        shutil.rmtree(tc.ckpt_dir, ignore_errors=True)
    shape = ShapeConfig("example", args.seq, args.batch, "train")

    state, hist = train(cfg, tc, shape)
    print(f"\nloss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"over {len(hist)} steps")
    diag = [h for h in hist if "vat_block_score" in h]
    if diag:
        print("embedding tendency (VAT diagnostics):")
        for h in diag:
            print(f"  hopkins={h['hopkins']:.3f} "
                  f"block_score={h['vat_block_score']:.3f} "
                  f"k_est={int(h['vat_k_est'])}")
        # per-probe drift rows from the tendency monitor (the "router"
        # probe — present on MoE archs — is the expert-health signal)
        probes = sorted({k.split("/")[1] for k in diag[-1]
                         if k.startswith("tendency/")})
        print("per-probe tendency (last diag step first):")
        for name in probes:
            h = diag[-1]
            state = STATE_NAMES[h[f"tendency/{name}/state"]]
            print(f"  {name:<12} state={state:<8} "
                  f"score={h[f'tendency/{name}/block_score']:.3f} "
                  f"k={int(h[f'tendency/{name}/k_est'])} "
                  f"hopkins={h[f'tendency/{name}/hopkins']:.3f}")


if __name__ == "__main__":
    main()
