"""Big-VAT demo: cluster tendency of n = 100,000 points on a laptop CPU.

Exact VAT at this n would need a 40 GB (n, n) float32 matrix — Big-VAT
(clusiVAT pipeline, see docs/scaling.md) never materializes anything
larger than O(block * s), so the whole run fits in a few hundred MB and a
few seconds.  The demo generates 5 Gaussian blobs, lets the ``FastVAT``
facade auto-select the bigvat rung, and prints the smoothed VAT image plus
the tendency report.

Run:  PYTHONPATH=src python examples/bigvat_demo.py
"""
import time

import numpy as np

from repro import FastVAT
from repro.data.synth import make_big_blobs

N = 100_000
K = 5


def ascii_image(R, size=40):
    R = np.asarray(R)
    idx = np.linspace(0, R.shape[0] - 1, size).astype(int)
    sub = R[np.ix_(idx, idx)]
    sub = sub / (sub.max() + 1e-9)
    chars = " .:-=+*#%@"   # dark blocks = close points
    return "\n".join("".join(chars[int((1 - v) * (len(chars) - 1))]
                             for v in row) for row in sub)


def main():
    X, labels = make_big_blobs(n=N, k=K)
    print(f"n={len(X):,} d={X.shape[1]}  "
          f"(exact VAT would need a {len(X)**2 * 4 / 1e9:.0f} GB matrix)")

    t0 = time.perf_counter()
    fv = FastVAT(sample_size=256, block=8192).fit(X)
    dt = time.perf_counter() - t0
    assert fv.method_resolved == "bigvat", fv.method_resolved

    report = fv.assess()
    print(ascii_image(fv.image(resolution=256)))
    print(f"\nmethod={report['method']}  hopkins={report['hopkins']:.3f}  "
          f"block_score={report['block_score']:.3f}  k_est={report['k_est']}"
          f"  (true k={K})")
    print(f"wall time: {dt:.2f}s — peak intermediate "
          f"O(block*s) = {fv.block * fv.sample_size * 4 / 1e6:.0f} MB")

    # the full-data ordering keeps each blob contiguous (few label changes)
    lab_in_order = labels[fv.order()]
    changes = int(np.sum(lab_in_order[1:] != lab_in_order[:-1]))
    print(f"label runs along the n={len(X):,} ordering: {changes + 1} "
          f"(ideal {K})")


if __name__ == "__main__":
    main()
