"""Serving example: tendency-as-a-service end to end (ISSUE 7).

A frontend receives a burst of cluster-tendency requests.  Instead of
paying trace + compile per call, it drives ``repro.serve``'s
:class:`TendencyServer`:

  * ``warm()`` AOT-compiles the request path once,
  * ``submit()`` enqueues each dataset and returns a Future,
  * the coalescer packs the burst into ONE batched ``fit_batch``
    dispatch (all requests share a shape bucket),
  * each Future resolves to a result bitwise-identical to the solo
    ``FastVAT.fit`` — which the example verifies,
  * the cost-model router picks a rung under a latency SLO
    (``resolve_key(..., slo_ms=...)``).

Run:  PYTHONPATH=src python examples/serve_route.py
"""
import numpy as np

from repro.api import FastVAT
from repro.serve import ServeConfig, TendencyServer, resolve_key


def run(n_requests: int = 12, n_points: int = 90, d: int = 4,
        window_ms: float = 50.0, max_batch: int = 16,
        seed: int = 0) -> dict:
    """Drive submit -> coalesce -> result and return checkable facts.

    Args:
      n_requests: burst size (all same shape bucket -> one dispatch
        when the burst fits ``max_batch`` and the window).
      n_points, d: per-request dataset shape.
      window_ms: coalescing window.
      max_batch: per-dispatch lane cap.
      seed: dataset generator seed.

    Returns:
      dict of facts the acceptance test asserts: dispatch counts,
      coalesce rate, cache hit rate, a bitwise-vs-solo verdict, and
      the SLO router's pick for a reference workload.
    """
    rng = np.random.default_rng(seed)
    datasets = []
    for _ in range(n_requests):
        half = n_points // 2
        datasets.append(np.concatenate([
            rng.normal(size=(half, d)),
            rng.normal(size=(n_points - half, d)) + 7.0,
        ]).astype(np.float32))

    config = ServeConfig(window_s=window_ms / 1e3, max_batch=max_batch)
    with TendencyServer(config) as server:
        # pre-compile the exact program the burst will hit: n-bucket of
        # n_points, lane bucket of the burst size
        server.warm(n_points, d, method="vat", batch=n_requests)
        futures = [server.submit(X, method="vat") for X in datasets]
        results = [f.result(timeout=300) for f in futures]
        stats = server.stats()

    # every served result must equal its solo fit bit for bit
    solo = FastVAT(method="vat").fit(datasets[0]).result
    bitwise = bool(
        np.array_equal(np.asarray(results[0].order), np.asarray(solo.order))
        and np.array_equal(np.asarray(results[0].rstar),
                           np.asarray(solo.rstar)))

    report = FastVAT.from_result(results[0], X=datasets[0]).assess()

    # the SLO router, shown on a reference workload: at n=1024 a 50 ms
    # budget affords the geodesic (iVAT) image, a 20 ms budget does not
    slo_key = resolve_key(1024, d, metric="euclidean", config=config,
                          slo_ms=50.0)

    return {
        "n_requests": n_requests,
        "dispatched_batches": stats.dispatched_batches,
        "dispatched_requests": stats.dispatched_requests,
        "coalesce_rate": stats.coalesce_rate,
        "warm_hit_rate": stats.cache.hit_rate,
        "compiled_programs": stats.cache.misses,
        "bitwise_vs_solo": bitwise,
        "slo_routed_rung": slo_key.rung,
        "k_est": int(report["k_est"]),
        "clustered": bool(report["clustered"]),
    }


def main():
    facts = run()
    print(f"served {facts['n_requests']} requests in "
          f"{facts['dispatched_batches']} batched dispatch(es) "
          f"(coalesce rate {facts['coalesce_rate']:.1f} req/batch)")
    print(f"program cache: {facts['compiled_programs']} compiled, "
          f"hit rate {facts['warm_hit_rate']:.0%}")
    print(f"served result bitwise-equal to solo FastVAT.fit: "
          f"{facts['bitwise_vs_solo']}")
    print(f"SLO router at n=1024, 50 ms budget -> "
          f"{facts['slo_routed_rung']}")
    print(f"tendency verdict: k_est={facts['k_est']} "
          f"clustered={facts['clustered']}")


if __name__ == "__main__":
    main()
