"""Serving example: sVAT-driven request routing + batched greedy decoding.

A serving frontend receives a mixed bag of requests; sVAT over the prompt
embeddings reveals how many request families are in flight, maximin
sampling picks the batch groups, and each group decodes together against
a KV cache (prefix locality => better cache behaviour on real serving
stacks).  Uses a reduced model so it runs on CPU in seconds.

Run:  PYTHONPATH=src python examples/serve_route.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro import core
from repro.configs import smoke_config
from repro.models import model as M
from repro.train.steps import build_serve_step


def main():
    cfg = smoke_config("phi3-mini-3.8b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    # 32 requests from two prompt families (e.g. two system prompts)
    rng = np.random.default_rng(0)
    fam = rng.integers(0, 2, 32)
    prompts = np.where(fam[:, None] == 0,
                       rng.integers(1, 40, (32, 8)),
                       rng.integers(80, 120, (32, 8))).astype(np.int32)

    # prompt embeddings from the serving encoder (stubbed here: an
    # untrained embed table carries no semantics, so we synthesize the
    # family-separated embeddings a trained encoder would produce)
    emb = (rng.normal(size=(32, 64)) + fam[:, None] * 4.0).astype(np.float32)
    rep = core.activation_report(jnp.asarray(emb), jax.random.PRNGKey(1),
                                 sample=32)
    k = int(rep.k_est)
    print(f"request-pool tendency: hopkins={float(rep.hopkins):.3f} "
          f"block_score={float(rep.block_score):.3f} -> {k} groups")

    # group by k-means over the embeddings (k from VAT) and decode batched
    labels, _, _ = core.kmeans(jnp.asarray(emb), jax.random.PRNGKey(2), k=k)
    serve = jax.jit(build_serve_step(cfg))
    for g in range(k):
        idx = np.where(np.asarray(labels) == g)[0]
        toks = jnp.asarray(prompts[idx, -1:])          # last prompt token
        cache = M.init_cache(cfg, len(idx), 32, jnp.float32)
        pos = 0
        outs = []
        for step in range(8):
            toks, cache = serve(params, cache, toks, jnp.int32(pos))
            pos += 1
            outs.append(np.asarray(toks)[:, 0])
        gen = np.stack(outs, axis=1)
        print(f"group {g}: {len(idx)} requests, generated {gen.shape[1]} "
              f"tokens each; majority family: {int(np.median(fam[idx]))}")


if __name__ == "__main__":
    main()
