"""Flash-VAT demo: *exact* VAT of n = 100,000 points on a laptop CPU.

Big-VAT (examples/bigvat_demo.py) reaches this n by sampling — the
ordering it extends is approximate.  Flash-VAT gets the **exact** VAT
ordering at the same n: the matrix-free fused Prim engine
(``core.vat_matrix_free``, kernels/prim_stream.py) recomputes each
pivot's distance row tile-by-tile and reduces it on the fly, so peak
memory is O(n·d) + O(n) frontier state instead of the 40 GB (n, n)
float32 matrix exact VAT used to require.  The ordering is
bitwise-identical to materialized VAT (pinned in tests/test_flashvat.py)
— no approximation anywhere, just a memory-for-recompute trade.

The demo fits 3 Gaussian blobs through the ``FastVAT`` facade with
``method="flashvat"`` (auto-selection picks flashvat for
2_048 < n <= 50_000; at n = 1e5 the default is still the faster,
approximate bigvat, so we opt in), prints the band-rendered VAT image,
the tendency report, and the exactness evidence: every ground-truth
cluster is one contiguous run of the full-n ordering.

Run:  PYTHONPATH=src python examples/flashvat_demo.py
      (~1 minute on CPU with the Turbo persistent engine — ISSUE 5 cut
      the 100-170 s stepwise traversal to ~60 s; exact VAT is still
      O(n^2 d) work, the engines change the constant, not the bound)
"""
import time

import numpy as np

from repro import FastVAT
from repro.data.synth import make_big_blobs

N = 100_000
K = 3


def ascii_image(R, size=40):
    R = np.asarray(R)
    idx = np.linspace(0, R.shape[0] - 1, size).astype(int)
    sub = R[np.ix_(idx, idx)]
    sub = sub / (sub.max() + 1e-9)
    chars = " .:-=+*#%@"   # dark blocks = close points
    return "\n".join("".join(chars[int((1 - v) * (len(chars) - 1))]
                             for v in row) for row in sub)


def main():
    X, labels = make_big_blobs(n=N, k=K)
    print(f"n={len(X):,} d={X.shape[1]}  exact, matrix-free "
          f"(materialized VAT would need a "
          f"{len(X)**2 * 4 / 1e9:.0f} GB matrix; Flash-VAT holds "
          f"{len(X) * X.shape[1] * 4 / 1e6:.1f} MB of points + O(n) state)")

    t0 = time.perf_counter()
    fv = FastVAT(method="flashvat", sample_size=256).fit(X)
    dt = time.perf_counter() - t0

    report = fv.assess()
    print(ascii_image(fv.image(resolution=256)))
    print(f"\nmethod={report['method']}  hopkins={report['hopkins']:.3f}  "
          f"block_score={report['block_score']:.3f}  k_est={report['k_est']}"
          f"  (true k={K})")
    print(f"wall time: {dt:.2f}s")

    # exactness, not approximation: the full-n ordering keeps every
    # ground-truth blob perfectly contiguous
    lab_in_order = labels[fv.order()]
    runs = 1 + int(np.sum(lab_in_order[1:] != lab_in_order[:-1]))
    print(f"label runs along the n={len(X):,} exact ordering: {runs} "
          f"(ideal {K})")


if __name__ == "__main__":
    main()
