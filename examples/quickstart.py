"""Quickstart: Fast-VAT in 30 lines.

Computes a VAT image of a clustered dataset three ways (pure-Python
baseline, XLA, Pallas kernel), checks they agree, prints the speedup and
an ASCII rendering of the reordered dissimilarity matrix.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import core
from repro.core import naive
from repro.data.synth import make_dataset


def ascii_image(R, size=32):
    R = np.asarray(R)
    n = R.shape[0]
    idx = np.linspace(0, n - 1, size).astype(int)
    sub = R[np.ix_(idx, idx)]
    sub = sub / (sub.max() + 1e-9)
    chars = " .:-=+*#%@"   # dark blocks = close points
    return "\n".join("".join(chars[int((1 - v) * (len(chars) - 1))]
                             for v in row) for row in sub)


def main():
    X, _ = make_dataset("blobs")
    Xj = jnp.asarray(X)

    t0 = time.perf_counter()
    rstar_naive, order_naive = naive.vat_naive(X[:300].tolist())
    t_naive = time.perf_counter() - t0

    res = core.vat(Xj)                       # XLA path
    jax.block_until_ready(res.rstar)
    t0 = time.perf_counter()
    res = core.vat(Xj)
    jax.block_until_ready(res.rstar)
    t_jax = time.perf_counter() - t0

    res_p = core.vat(Xj, use_pallas=True)    # Pallas kernel (interpret on CPU)
    # the two paths agree to f32 tolerance (orders can differ on ties)
    np.testing.assert_allclose(np.asarray(res_p.dist), np.asarray(res.dist),
                               atol=5e-3)
    sp, _ = core.block_structure_score(res_p.rstar)

    h = core.hopkins(Xj, jax.random.PRNGKey(0))
    score, k_est = core.block_structure_score(res.rstar)

    # the same pipeline through the facade — every rung returns one
    # TendencyResult, and any metric (or a precomputed matrix) plugs in
    from repro import FastVAT
    rep = FastVAT(metric="manhattan").fit(X).assess()
    rep_pre = FastVAT(metric="precomputed").fit(np.asarray(res.dist)).assess()
    assert rep_pre["k_est"] == int(k_est)    # same matrix, same verdict

    print(ascii_image(res.rstar))
    print(f"\nhopkins={float(h):.3f}  block_score={float(score):.3f} "
          f"k_est={int(k_est)}  (manhattan k_est={rep['k_est']})")
    print(f"naive python (n=300): {t_naive*1e3:.1f} ms   "
          f"jax (n={len(X)}): {t_jax*1e3:.1f} ms")
    n_scale = (len(X) / 300) ** 2
    print(f"speedup at equal n:   ~{t_naive*n_scale/t_jax:.0f}x")


if __name__ == "__main__":
    main()
