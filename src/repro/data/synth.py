"""The paper's seven evaluation datasets, generated deterministically.

Sizes follow the paper (Iris 150x4, Mall 200x2-ish, Spotify 500x10,
synthetic sets ~1000 points).  Iris/Mall/Spotify have no bundled files in
this offline container, so structurally-matched surrogates are generated:
  * iris   — 3 anisotropic Gaussians in 4-D with one overlapping pair
             (mirrors setosa-separable / versicolor-virginica-overlap)
  * mall   — 5 customer segments in (income, spend) space
  * spotify— 500x10 weakly-structured audio-feature-like noise (the paper's
             point for this set is that VAT shows NO structure)
Each returns (X float32 (n,d), labels int32 (n,) or None).
"""
from __future__ import annotations

import numpy as np

_N = 1000  # synthetic dataset size, matches the paper's ~1k scale


def _blobs(rng, n=_N, spread=1.0):
    # well-separated triangle of isotropic Gaussians (sklearn-blobs style)
    centers = np.array([[0.0, 0.0], [10.0, 0.0], [5.0, 9.0]], np.float32)
    lab = rng.integers(0, 3, size=n)
    X = centers[lab] + rng.normal(scale=spread, size=(n, 2))
    return X.astype(np.float32), lab.astype(np.int32)


def make_dataset(name: str, seed: int = 0):
    rng = np.random.default_rng(seed)
    if name == "iris":
        c = np.array([[5.0, 3.4, 1.5, 0.2], [5.9, 2.8, 4.3, 1.3],
                      [6.6, 3.0, 5.6, 2.0]], np.float32)
        lab = np.repeat(np.arange(3), 50)
        X = c[lab] + rng.normal(scale=[0.35, 0.38, 0.17, 0.10],
                                size=(150, 4))
        return X.astype(np.float32), lab.astype(np.int32)
    if name == "mall":
        centers = np.array([[25, 80], [25, 20], [55, 50], [85, 80], [85, 15]],
                           np.float32)
        lab = rng.integers(0, 5, size=200)
        X = centers[lab] + rng.normal(scale=8.0, size=(200, 2))
        return X.astype(np.float32), lab.astype(np.int32)
    if name == "spotify":
        # 500 x 10 audio-feature-like matrix: strongly correlated features
        # (high Hopkins, like the paper's 0.87) but NO block structure —
        # the case where VAT visually overrides a misleading statistic
        A = rng.normal(size=(10, 10)) * (rng.random(10) ** 2)[None, :]
        base = rng.normal(size=(500, 10)) @ A
        return base.astype(np.float32), None
    if name == "blobs":
        return _blobs(rng)
    if name == "moons":
        n = _N
        t = rng.random(n) * np.pi
        half = rng.integers(0, 2, n)
        x = np.where(half == 0, np.cos(t), 1.0 - np.cos(t))
        y = np.where(half == 0, np.sin(t), 0.5 - np.sin(t))
        X = np.stack([x, y], 1) + rng.normal(scale=0.06, size=(n, 2))
        return X.astype(np.float32), half.astype(np.int32)
    if name == "circles":
        n = _N
        t = rng.random(n) * 2 * np.pi
        ring = rng.integers(0, 2, n)
        r = np.where(ring == 0, 1.0, 0.45)
        X = np.stack([r * np.cos(t), r * np.sin(t)], 1)
        X = X + rng.normal(scale=0.04, size=(n, 2))
        return X.astype(np.float32), ring.astype(np.int32)
    if name == "gmm":
        # overlapping gaussian mixture (the paper's "blurred diagonal" case)
        centers = np.array([[0, 0], [2.5, 0], [1.2, 2.0]], np.float32)
        lab = rng.integers(0, 3, size=_N)
        X = centers[lab] + rng.normal(scale=0.9, size=(_N, 2))
        return X.astype(np.float32), lab.astype(np.int32)
    raise KeyError(name)


DATASETS = ("iris", "mall", "spotify", "blobs", "moons", "circles", "gmm")


def make_big_blobs(n: int = 100_000, k: int = 5, d: int = 8, seed: int = 0,
                   scale: float = 1.5):
    """Well-separated Gaussian blobs at Big-VAT scale (n >> 1e4).

    Shared by examples/bigvat_demo.py and benchmarks table4 so the demo
    and the benchmark measure the same distribution.
    Returns (X float32 (n, d), labels int32 (n,)).
    """
    rng = np.random.default_rng(seed)
    centers = rng.uniform(-40.0, 40.0, size=(k, d)).astype(np.float32)
    lab = rng.integers(0, k, size=n)
    X = centers[lab] + rng.normal(scale=scale, size=(n, d)).astype(np.float32)
    return X.astype(np.float32), lab.astype(np.int32)
