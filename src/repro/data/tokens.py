"""Token batch pipeline: synthetic corpus + ShapeDtypeStruct input specs.

``input_specs(cfg, shape)`` is the single source of truth for what a
(train|prefill|decode) step consumes — the dry-run lowers against these
and the real pipeline produces concretely-shaped matches.

The synthetic corpus is a deterministic Zipf-ish token stream with enough
local structure (bigram template mixing) that a ~100M model visibly learns
within a few hundred steps — good enough to validate the training loop
end-to-end without shipping a dataset.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig


def _text_len(cfg: ModelConfig, seq_len: int) -> int:
    """Text-token count for a shape (VLM cells reserve patch positions)."""
    if cfg.family == "vlm":
        return seq_len - cfg.n_patches
    return seq_len


def input_specs(cfg: ModelConfig, shape: ShapeConfig, *,
                dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "decode":
        specs = {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
        return specs
    T = _text_len(cfg, S)
    specs = {"tokens": jax.ShapeDtypeStruct((B, T), i32)}
    if shape.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((B, T), i32)
    if cfg.family == "vlm":
        specs["patches"] = jax.ShapeDtypeStruct((B, cfg.n_patches, cfg.d_model),
                                                dtype)
    if cfg.family == "audio":
        specs["enc_frames"] = jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model),
                                                   dtype)
    return specs


class SyntheticCorpus:
    """Deterministic structured token stream (host-side, numpy).

    Tokens follow mixed bigram templates: each stream picks one of
    `n_templates` cyclic patterns plus Zipf noise, giving the model a
    learnable conditional distribution.
    """

    def __init__(self, vocab: int, seed: int = 0, n_templates: int = 8):
        self.vocab = vocab
        self.rng = np.random.default_rng(seed)
        self.templates = self.rng.integers(
            0, vocab, size=(n_templates, 64), dtype=np.int32)

    def batch(self, batch: int, seq: int, step: int = 0) -> dict:
        rng = np.random.default_rng(hash((step, batch, seq)) % (2**32))
        t_idx = rng.integers(0, len(self.templates), size=batch)
        offs = rng.integers(0, 64, size=batch)
        base = np.stack([
            np.resize(np.roll(self.templates[t], -o), seq + 1)
            for t, o in zip(t_idx, offs)])
        noise = rng.zipf(1.5, size=(batch, seq + 1)) % self.vocab
        mask = rng.random((batch, seq + 1)) < 0.15
        stream = np.where(mask, noise, base).astype(np.int32)
        return {"tokens": stream[:, :-1], "labels": stream[:, 1:]}


def make_batch(cfg: ModelConfig, shape: ShapeConfig, step: int = 0,
               corpus: SyntheticCorpus | None = None,
               dtype=jnp.bfloat16) -> dict:
    """Concrete host batch matching input_specs (for smokes / real training)."""
    corpus = corpus or SyntheticCorpus(cfg.vocab)
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        b = corpus.batch(B, 1, step)
        return {"tokens": b["tokens"]}
    T = _text_len(cfg, S)
    out = dict(corpus.batch(B, T, step))
    if shape.kind != "train":
        out.pop("labels")
    rng = np.random.default_rng(step + 7)
    if cfg.family == "vlm":
        out["patches"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_patches, cfg.d_model)), dtype)
    if cfg.family == "audio":
        out["enc_frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.enc_seq, cfg.d_model)), dtype)
    return out
