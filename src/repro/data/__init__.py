from repro.data.tokens import input_specs, make_batch, SyntheticCorpus
from repro.data.synth import DATASETS, make_dataset
