"""The numerics shield (ISSUE 10): condition-aware dispatch + precision.

One subsystem owns every floating-point-robustness decision the fast
engines used to make implicitly:

  * ``condition.py`` — the per-fit conditioning pre-pass: scale
    statistics, the Gram-cancellation condition estimate κ, the
    isometry-safe conditioning transform (mean-center + power-of-2
    rescale), the ``fast | safe | auto`` policy resolution, and the
    bf16 storage certification with its counted fallback.
  * ``certify.py`` — the adversarial certification harness: worst-case
    generators run through every rung × policy against the f64
    reference oracle (kept import-light; it pulls the API layer in,
    so the package root deliberately does NOT import it — import
    ``repro.numerics.certify`` explicitly).

See docs/numerics.md for the condition estimate's derivation and the
policy table.
"""
from repro.numerics.condition import (CONDITIONED_METRICS, KAPPA_BF16,
                                      KAPPA_SAFE, ConditionStats,
                                      NumericsPolicy, NumericsReport,
                                      as_policy, condition_stats,
                                      condition_transform, lb_slack_ulps,
                                      resolve)

__all__ = [
    "CONDITIONED_METRICS", "KAPPA_BF16", "KAPPA_SAFE",
    "ConditionStats", "NumericsPolicy", "NumericsReport",
    "as_policy", "condition_stats", "condition_transform",
    "lb_slack_ulps", "resolve",
]
