"""Conditioning pre-pass: scale diagnostics, κ, and the policy resolver.

The fast engines buy speed with the Gram decomposition ``‖x‖² + ‖y‖² −
2 x·y``, whose cancellation error is ABSOLUTE — up to ``C·eps·max‖x‖²``
regardless of how small the distance being computed is.  On centered
O(1) data that error is ulps; on data offset 1e4 from the origin it is
larger than typical inter-point gaps and silently reorders near-ties.
This module decides, per fit and on the host (before any program is
traced), three things:

  1. **How bad is it?**  ``condition_stats`` streams a cheap pre-pass
     over X: max‖x‖², the coordinate spread, a pairwise-gap proxy (the
     median nonzero squared distance over a deterministic strided
     subsample), and the condition estimate

         κ = max‖x‖² / gap_proxy

     — the ratio of the Gram error's scale to the scale of the
     distances it perturbs.

  2. **What to run.**  ``resolve`` maps a ``NumericsPolicy`` to a
     concrete plan: the tile ``form`` ("gram" | "direct") every kernel
     takes statically, plus whether to apply the conditioning transform.
     ``auto`` (the default) keeps today's fast path byte-for-byte while
     κ ≤ ``KAPPA_SAFE`` and switches to direct-form tiles on
     conditioned data beyond it.

  3. **The transform.**  ``condition_transform`` mean-centers in f64 and
     rescales by a power of two before casting back to f32.  Both pieces
     are ordering-isometries of the translation-invariant metrics
     (euclidean / sqeuclidean / manhattan): centering is an exact
     translation, and a power-of-2 rescale commutes BITWISE through the
     whole distance computation (multiplying every coordinate by 2^k is
     exact in binary floating point; squared distances scale by the
     exact factor 2^2k and euclidean distances by 2^k, so every min /
     argmin / tie compares identically).  Cosine and precomputed input
     are left untouched (centering is not an isometry of cosine).

Derivation of ``KAPPA_SAFE`` (why 8192): the engines' Gram rows carry
absolute error bounded in practice by ``64·eps·max‖x‖²`` (the same
64-ulp allowance the Turbo pruning bound debits — see
``lb_slack_ulps``).  An ordering can only flip when that error spans a
real inter-point gap; demanding the error stay below ``gap/16`` gives

    64·eps·max_sq ≤ gap/16   ⇔   κ = max_sq/gap ≤ 1/(1024·eps) = 8192.

The threshold is deliberately a power of two and deliberately
conservative by the 16× guard factor: below it the Gram path is
certifiably order-safe, above it ``auto`` pays the ~2× direct-form cost.

bf16 storage (``NumericsPolicy.dtype="bf16"``) is certified the same
way BEFORE fitting: bf16's eps is 2^-8, so quantizing the conditioned
coordinates perturbs squared distances by up to ``~4·eps_bf16·max_sq``
relative to the post-transform scale; requiring that below ``gap/4``
gives ``KAPPA_BF16 = 16``.  A fit whose conditioned κ exceeds it falls
back to f32 — a counted degradation (``NumericsReport.fallbacks``,
mirrored into ``ResilienceStats.numerics_fallbacks`` by the serving
layer) with the ``kernels.numerics_trip`` fault site at the decision so
the chaos CLI can script the trip deterministically.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro import faults

#: Largest condition estimate at which the Gram-form tiles are
#: certifiably order-safe (see module docstring for the derivation:
#: 64·eps·max_sq ≤ gap/16  ⇔  κ ≤ 1/(1024·eps_f32) = 8192).
KAPPA_SAFE = 8192.0

#: Largest CONDITIONED condition estimate at which bf16 coordinate
#: storage passes certification (4·eps_bf16·max_sq ≤ gap/4 with
#: eps_bf16 = 2^-8  ⇔  κ ≤ 16).
KAPPA_BF16 = 16.0

#: Metrics the conditioning transform is an ordering-isometry of.
#: Cosine is scale- but not translation-invariant; "precomputed" never
#: reaches the kernels as points at all.
CONDITIONED_METRICS = ("euclidean", "sqeuclidean", "manhattan")

#: Rows the gap-proxy subsample is capped at — the pre-pass must stay
#: O(n·d + s²) with s tiny next to any fit.
_GAP_SAMPLE = 256

_F32_EPS = float(np.finfo(np.float32).eps)

_MODES = ("fast", "safe", "auto")
_DTYPES = ("f32", "bf16")
_FORMS = ("gram", "direct")


def lb_slack_ulps(form: str) -> float:
    """Per-form ulp allowance for absolute row error at scale max‖x‖².

    The shared constant behind two consumers: the Turbo engine's lazy
    pruning bound debits ``lb_slack_ulps(form)·eps·max‖x‖²`` (squared
    units) from every tile lower bound, and ``KAPPA_SAFE`` above is
    derived from the gram value.

      * "gram"   -> 64.0 — the aux + aux_q − 2·cross decomposition sums
        three terms of magnitude max‖x‖²; 64 ulps covers their combined
        rounding + cancellation with >10× headroom (the PR-5 constant,
        unchanged so every existing prune pin stays bitwise).
      * "direct" -> 4.0 — the (x−y)² form has no cancellation: its
        error is RELATIVE to the computed distance, the multiplicative
        ``_LB_MARGIN`` already covers that, and the tiny absolute
        allowance only guards the final sum's rounding at full scale.
    """
    check_form(form)
    return 64.0 if form == "gram" else 4.0


def check_form(form: str) -> None:
    if form not in _FORMS:
        raise ValueError(f"form must be one of {_FORMS}, got {form!r}")


@dataclasses.dataclass(frozen=True)
class NumericsPolicy:
    """What the caller ASKS for (``FastVAT(numerics=...)`` and
    ``ServeConfig.numerics``); ``resolve`` turns it into a plan.

    Attributes:
      mode: "fast" — always Gram-form tiles on the data as given
        (byte-for-byte the pre-shield behavior); "safe" — always
        direct-form tiles on conditioned data; "auto" (default) —
        fast while κ ≤ ``KAPPA_SAFE``, safe beyond.
      dtype: coordinate storage — "f32" (default) or "bf16" (quantize
        the conditioned coordinates to bf16 precision; accumulation
        stays f32 everywhere).  bf16 is certified per fit and falls
        back to f32 when the certification bound fails.
    """

    mode: str = "auto"
    dtype: str = "f32"

    def __post_init__(self):
        if self.mode not in _MODES:
            raise ValueError(f"numerics mode must be one of {_MODES}, "
                             f"got {self.mode!r}")
        if self.dtype not in _DTYPES:
            raise ValueError(f"numerics dtype must be one of {_DTYPES}, "
                             f"got {self.dtype!r}")


def as_policy(numerics) -> NumericsPolicy:
    """Coerce the facade knob: a policy passes through, a string is a
    mode shorthand ("auto" == NumericsPolicy(mode="auto"))."""
    if isinstance(numerics, NumericsPolicy):
        return numerics
    if isinstance(numerics, str):
        return NumericsPolicy(mode=numerics)
    raise TypeError("numerics must be a NumericsPolicy or a mode string "
                    f"('fast' | 'safe' | 'auto'), got {numerics!r}")


@dataclasses.dataclass(frozen=True)
class NumericsReport:
    """What a fit ACTUALLY ran — stamped on ``ResultMeta.numerics``.

    Frozen and hashable so ``ResultMeta`` stays valid pytree aux data.

    Attributes:
      kappa: the pre-transform condition estimate (worst lane for a
        batched fit).
      mode: the requested policy mode.
      form: tile form the kernels ran ("gram" | "direct").
      dtype: coordinate storage the fit actually used ("f32" | "bf16" —
        f32 after a bf16 certification fallback).
      conditioned: whether the mean-center + power-of-2 rescale was
        applied before kernel entry.
      fallbacks: counted degradations (currently: 1 when bf16 was
        requested but failed certification or was fault-tripped).
    """

    kappa: float
    mode: str
    form: str
    dtype: str
    conditioned: bool
    fallbacks: int = 0


@dataclasses.dataclass(frozen=True)
class ConditionStats:
    """The pre-pass scale statistics (all f64, computed on the host).

    Attributes:
      max_sq_norm: max‖x‖² of the data as given — the Gram error scale.
      centered_max_sq: max‖x − mean‖² — the error scale conditioning
        would leave.
      spread: max over dims of (max − min) coordinate extent.
      gap_proxy: median nonzero squared euclidean distance over the
        strided subsample — the scale an ordering flip must span.
      kappa: max_sq_norm / gap_proxy (∞ when the proxy is 0).
      kappa_centered: centered_max_sq / gap_proxy — what κ becomes
        after conditioning (the bf16 certification input).
    """

    max_sq_norm: float
    centered_max_sq: float
    spread: float
    gap_proxy: float
    kappa: float
    kappa_centered: float


def _stats_one(X: np.ndarray) -> ConditionStats:
    Xd = np.asarray(X, np.float64)
    sq = np.einsum("nd,nd->n", Xd, Xd)
    max_sq = float(np.max(sq)) if sq.size else 0.0
    mean = np.mean(Xd, axis=0)
    C = Xd - mean
    csq = np.einsum("nd,nd->n", C, C)
    centered_max_sq = float(np.max(csq)) if csq.size else 0.0
    spread = float(np.max(np.ptp(Xd, axis=0))) if Xd.size else 0.0
    n = Xd.shape[0]
    stride = max(1, n // _GAP_SAMPLE)
    S = Xd[::stride][:_GAP_SAMPLE]
    ssq = np.einsum("nd,nd->n", S, S)
    G = ssq[:, None] + ssq[None, :] - 2.0 * (S @ S.T)
    np.maximum(G, 0.0, out=G)
    off = G[np.triu_indices(S.shape[0], k=1)]
    nz = off[off > 0.0]
    gap = float(np.median(nz)) if nz.size else 0.0
    kappa = max_sq / gap if gap > 0.0 else (0.0 if max_sq == 0.0
                                            else float("inf"))
    kc = centered_max_sq / gap if gap > 0.0 else (
        0.0 if centered_max_sq == 0.0 else float("inf"))
    return ConditionStats(max_sq_norm=max_sq,
                          centered_max_sq=centered_max_sq, spread=spread,
                          gap_proxy=gap, kappa=kappa, kappa_centered=kc)


def condition_stats(X) -> ConditionStats:
    """Scale statistics of an (n, d) matrix or (b, n, d) stack.

    κ is always measured on squared-euclidean geometry regardless of
    the metric the fit will run — the Gram decomposition whose error it
    bounds is the squared-euclidean one, and the manhattan/cosine tiles
    inherit the SAME coordinate-scale pathologies.  A batched stack
    reports the worst lane (max κ, max scales, min gap): conditioning
    is all-or-nothing per fit, so the plan must be safe for every lane.
    """
    arr = np.asarray(X, np.float64)
    if arr.ndim == 2:
        return _stats_one(arr)
    if arr.ndim != 3:
        raise ValueError(f"condition_stats wants (n, d) or (b, n, d), "
                         f"got shape {arr.shape}")
    per = [_stats_one(lane) for lane in arr]
    return ConditionStats(
        max_sq_norm=max(s.max_sq_norm for s in per),
        centered_max_sq=max(s.centered_max_sq for s in per),
        spread=max(s.spread for s in per),
        gap_proxy=min(s.gap_proxy for s in per),
        kappa=max(s.kappa for s in per),
        kappa_centered=max(s.kappa_centered for s in per))


def condition_transform(X) -> np.ndarray:
    """Mean-center (f64) + power-of-2 rescale; returns f32.

    Per dataset (batched stacks transform each lane independently):
    subtract the f64 column means, then multiply by ``2^-ceil`` where
    ``ceil = floor(log2(max |centered|))`` so coordinates land in
    [-2, 2).  The scale is a power of two, so the rescale is EXACT in
    binary floating point and commutes bitwise through every distance
    formula (see module docstring); the centering is where the actual
    conditioning happens — it removes the common offset that inflates
    ‖x‖² without moving any pairwise difference.

    The transform is a pure function of the centered coordinates:
    ``condition_transform(X + c·1) == condition_transform(X)`` bitwise
    whenever the f64 arithmetic of ``(X + c) − mean(X + c)`` is exact —
    which the shift-invariance pins arrange and real uncentered data
    matches to the last ulp of the f64 mean.
    """
    Xd = np.asarray(X, np.float64)
    mean = np.mean(Xd, axis=-2, keepdims=True)
    C = Xd - mean
    amax = np.max(np.abs(C), axis=(-2, -1), keepdims=True)
    # scale = 2^-floor(log2(amax)): exact powers of two, never 0/inf
    safe = np.where(amax > 0.0, amax, 1.0)
    scale = np.exp2(-np.floor(np.log2(safe)))
    return np.asarray(C * scale, np.float32)


def _quantize_bf16(X: np.ndarray) -> np.ndarray:
    """Round f32 coordinates to bf16 storage precision, back in f32.

    bf16 is f32 with the low 16 mantissa bits dropped; round-to-nearest
    -even on the retained bits matches what accelerator storage does.
    Keeping the result in an f32 container means every existing tile
    runs unchanged with f32 accumulation — this models the STORAGE
    precision (what the ROADMAP's accelerator rung will keep in HBM),
    not a compute downgrade.
    """
    u = np.ascontiguousarray(X, np.float32).view(np.uint32)
    rounded = (u + 0x7FFF + ((u >> 16) & 1)) & 0xFFFF0000
    return rounded.astype(np.uint32).view(np.float32).reshape(X.shape)


def resolve(X, *, metric: str, policy: NumericsPolicy | str | None = None,
            batched: bool = False):
    """The host pre-pass: turn (data, metric, policy) into a plan.

    Runs before anything is traced or enqueued — the returned ``form``
    and ``dtype`` are STATIC by the time a kernel sees them, which is
    what lets the serving layer key cached programs on the resolved
    plan (``ProgramKey.num_form`` / ``num_dtype``) instead of on data.

    Args:
      X: (n, d) points, or a (b, n, d) stack with ``batched=True``.
      metric: the fit's metric; conditioning only applies to
        ``CONDITIONED_METRICS`` (cosine/precomputed pass through).
      policy: a ``NumericsPolicy``, a mode string, or None (defaults).
      batched: X carries a leading batch axis.

    Returns:
      (X_out (np.float32, same shape), NumericsReport) — ``X_out`` is
      X unchanged (fast mode / gram-auto; also any non-conditioned
      metric) or the conditioned (and possibly bf16-quantized) copy.
    """
    policy = as_policy(policy if policy is not None else NumericsPolicy())
    Xf = np.asarray(X, np.float32)
    if batched and Xf.ndim != 3:
        raise ValueError(f"resolve(batched=True) wants (b, n, d), got "
                         f"shape {Xf.shape}")
    conditionable = metric in CONDITIONED_METRICS
    stats = condition_stats(Xf)

    if policy.mode == "fast":
        condition = False
    elif policy.mode == "safe":
        condition = conditionable
    else:  # auto: today's path verbatim while the Gram bound holds
        condition = conditionable and stats.kappa > KAPPA_SAFE
    form = "direct" if condition else "gram"

    Xout = condition_transform(Xf) if condition else Xf

    dtype, fallbacks = "f32", 0
    if policy.dtype == "bf16":
        kappa_eff = stats.kappa_centered if condition else stats.kappa
        certified = conditionable and kappa_eff <= KAPPA_BF16
        try:
            faults.fault_point("kernels.numerics_trip",
                               context={"metric": metric, "mode": policy.mode,
                                        "kappa": kappa_eff,
                                        "certified": certified})
        except faults.FaultInjected:
            certified = False
        if certified:
            Xout = _quantize_bf16(Xout)
            dtype = "bf16"
        else:
            fallbacks = 1

    report = NumericsReport(kappa=stats.kappa, mode=policy.mode, form=form,
                            dtype=dtype, conditioned=condition,
                            fallbacks=fallbacks)
    return Xout, report
