"""Adversarial certification harness (ISSUE 10 tentpole, part 4).

The numerics shield makes a quantified promise: under the default
``auto`` policy a fit's ordering stays *spanning-tree-faithful* to the
f64 oracle geometry even on adversarially ill-conditioned input.  This
module is where that promise is checked, end to end, through the real
public surface (``FastVAT``) rather than against kernel internals:

  1. **Generators** — deterministic worst-case datasets, each targeting
     one failure mode of the fast engines (huge common offsets, tiny
     gaps at scale, near-duplicate ties, mixed per-dimension scales,
     shell data maximizing ‖x‖² against gap).
  2. **Oracle** — f64 pairwise dissimilarities (numpy, no Gram trick:
     explicit differences) traversed by the pure-Python
     ``core.naive.vat_order_naive`` Prim — the repo's ground-truth VAT.
  3. **Quantification** — a fitted ordering is scored by its spanning
     -tree weight *measured in the f64 oracle geometry*: ``w(order) =
     Σ_i min_{j<i} R64[order[i], order[j]]``.  For the oracle ordering
     this is the exact MST weight; any mis-ordering caused by f32/bf16
     error shows up as relative excess weight.  Ordering equality is
     checked first (the common case on clean fits) but is NOT required
     — near-ties may legitimately resolve differently at different
     precisions without changing the tree weight materially.

Bounds: ``EXCESS_F32 = 1e-5`` for f32 fits, ``EXCESS_BF16 = 1e-2`` for
certified-bf16 fits (bf16 keeps 8 mantissa bits, so relative coordinate
perturbation ~2^-9 can move the tree weight by that order).  A bf16
request that FAILED certification ran at f32 (the counted fallback) and
is held to the f32 bound — degradation must not loosen the promise.

Run as a module for the CI gate::

    python -m repro.numerics.certify --smoke

which sweeps every exact rung × policy × conditioned metric over the
generators and exits nonzero if any cell breaks its bound.  Import-light
callers note: this module pulls in the API layer (FastVAT), so the
``repro.numerics`` package root deliberately does not import it.
"""
from __future__ import annotations

import argparse
import dataclasses
import zlib

import numpy as np

from repro.core.naive import vat_order_naive
from repro.numerics.condition import (CONDITIONED_METRICS, NumericsPolicy,
                                      as_policy, condition_stats)

#: Relative spanning-tree excess bounds per realized storage dtype.
EXCESS_F32 = 1e-5
EXCESS_BF16 = 1e-2

#: The approx rung carries a kNN-graph spanning defect that is a
#: property of the RUNG, not of numerics (measured and reported on
#: ``ResultMeta.approx``; it can be large in squared geometry, where a
#: missing cross-cluster edge's detour weight is amplified).  The shield
#: therefore certifies approx against its own best-numerics baseline:
#: ``sweep`` measures the safe-f32 excess per (generator, metric) once
#: and passes it as ``slack`` — a policy only fails if it adds error ON
#: TOP of the rung's intrinsic defect.


# ------------------------------------------------------------------
# Adversarial generators — pure functions of a seed, small n so the
# pure-Python oracle stays cheap.  Each returns (n, d) float32.
# ------------------------------------------------------------------

def _offset_clusters(rng: np.random.Generator, n: int = 64) -> np.ndarray:
    """Two unit clusters translated 1e4 from the origin: the canonical
    Gram catastrophe (max‖x‖² ~ 1e8 vs gaps ~ 1)."""
    half = n // 2
    a = rng.normal(size=(half, 4))
    b = rng.normal(size=(n - half, 4)) + 6.0
    return np.asarray(np.concatenate([a, b]) + 1.0e4, np.float32)


def _tiny_gaps(rng: np.random.Generator, n: int = 64) -> np.ndarray:
    """A jittered lattice with inter-point gaps ~1e-2 sitting at offset
    1e3 — the gaps are BELOW the Gram error scale there."""
    base = rng.permutation(n).astype(np.float64)[:, None] * 1e-2
    jitter = rng.normal(size=(n, 3)) * 1e-3
    X = np.concatenate([base, np.zeros((n, 2))], axis=1) + jitter
    return np.asarray(X + 1.0e3, np.float32)


def _near_duplicates(rng: np.random.Generator, n: int = 64) -> np.ndarray:
    """Pairs of near-identical points (separation 1e-3) at offset 1e4 —
    cancellation noise larger than the pair separations reorders the
    duplicate chains under the naive fast path."""
    half = n // 2
    base = rng.normal(size=(half, 4)) * 3.0
    dup = base + rng.normal(size=(half, 4)) * 1e-3
    return np.asarray(np.concatenate([base, dup]) + 1.0e4, np.float32)


def _mixed_scale(rng: np.random.Generator, n: int = 64) -> np.ndarray:
    """Per-dimension scales spanning six orders of magnitude, with the
    large dimensions carrying a common offset."""
    scales = np.array([1e-3, 1e-1, 1e1, 1e3])
    X = rng.normal(size=(n, 4)) * scales
    X[:, 3] += 1.0e4
    return np.asarray(X, np.float32)


def _shell(rng: np.random.Generator, n: int = 64) -> np.ndarray:
    """Points on a thin shell of radius 1e3: every ‖x‖² is maximal for
    the spread, so κ is large with NO mean offset to remove — the
    conditioning transform must still win via the gap-aware dispatch."""
    V = rng.normal(size=(n, 4))
    V /= np.linalg.norm(V, axis=1, keepdims=True)
    R = 1.0e3 * (1.0 + rng.normal(size=(n, 1)) * 1e-4)
    return np.asarray(V * R, np.float32)


GENERATORS = {
    "offset_clusters": _offset_clusters,
    "tiny_gaps": _tiny_gaps,
    "near_duplicates": _near_duplicates,
    "mixed_scale": _mixed_scale,
    "shell": _shell,
}


# ------------------------------------------------------------------
# f64 oracle
# ------------------------------------------------------------------

def oracle_dissim(X, metric: str) -> np.ndarray:
    """f64 pairwise dissimilarity by explicit differences (no Gram)."""
    Xd = np.asarray(X, np.float64)
    if metric in ("euclidean", "sqeuclidean"):
        diff = Xd[:, None, :] - Xd[None, :, :]
        sq = np.einsum("ijd,ijd->ij", diff, diff)
        return np.sqrt(sq) if metric == "euclidean" else sq
    if metric == "manhattan":
        return np.abs(Xd[:, None, :] - Xd[None, :, :]).sum(axis=-1)
    if metric == "cosine":
        norms = np.sqrt(np.einsum("nd,nd->n", Xd, Xd))
        denom = np.maximum(norms[:, None] * norms[None, :], 1e-300)
        return np.clip(1.0 - (Xd @ Xd.T) / denom, 0.0, 2.0)
    raise ValueError(f"no f64 oracle for metric {metric!r}")


def tree_weight(R64: np.ndarray, order) -> float:
    """Spanning-tree weight of an ordering in the oracle geometry."""
    order = np.asarray(order)
    w = 0.0
    for i in range(1, len(order)):
        w += float(np.min(R64[order[i], order[:i]]))
    return w


def ordering_excess(X, order, metric: str) -> tuple[float, bool]:
    """(relative excess tree weight vs the f64 oracle, exact-equality).

    Exactness means the fitted ordering IS the oracle Prim traversal;
    excess 0.0 with exact=False means a different-but-equally-minimal
    traversal (legitimate tie resolution).
    """
    R64 = oracle_dissim(X, metric)
    oracle = vat_order_naive(R64.tolist())
    exact = bool(np.array_equal(np.asarray(order), np.asarray(oracle)))
    w_opt = tree_weight(R64, oracle)
    if w_opt <= 0.0:
        return (0.0 if exact else float("inf")), exact
    w_fit = tree_weight(R64, order)
    return max(0.0, (w_fit - w_opt) / w_opt), exact


# ------------------------------------------------------------------
# Certification
# ------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CertResult:
    """One certified cell of the (generator × rung × policy) sweep."""

    generator: str
    method: str
    metric: str
    mode: str
    dtype_requested: str
    dtype_ran: str          # after any counted bf16 fallback
    kappa: float
    conditioned: bool
    fallbacks: int
    excess: float
    bound: float
    exact: bool
    ok: bool


def _bound_for(dtype_ran: str, slack: float = 0.0) -> float:
    return (EXCESS_BF16 if dtype_ran == "bf16" else EXCESS_F32) + slack


def certify_fit(X, *, method: str = "auto", metric: str = "euclidean",
                policy=None, use_pallas: bool = False,
                generator: str = "custom", slack: float = 0.0) -> CertResult:
    """Run one fit through FastVAT and score it against the f64 oracle.

    The fit goes through the full public path — admission, the numerics
    pre-pass, rung dispatch — so what is certified is what users run.
    ``slack`` widens the bound by a rung-intrinsic allowance; ``sweep``
    supplies the approx rung's measured safe-policy baseline here so
    approx cells certify "no numerics error ADDED", not "no kNN defect".
    """
    from repro.api.facade import FastVAT
    policy = as_policy(policy if policy is not None else NumericsPolicy())
    fv = FastVAT(method=method, metric=metric, numerics=policy,
                 use_pallas=use_pallas).fit(np.asarray(X, np.float32))
    rep = fv.result.meta.numerics
    excess, exact = ordering_excess(X, fv.order(), metric)
    bound = _bound_for(rep.dtype, slack)
    return CertResult(generator=generator, method=fv.method_resolved,
                      metric=metric, mode=policy.mode,
                      dtype_requested=policy.dtype, dtype_ran=rep.dtype,
                      kappa=rep.kappa, conditioned=rep.conditioned,
                      fallbacks=rep.fallbacks, excess=excess, bound=bound,
                      exact=exact, ok=bool(exact or excess <= bound))


#: The default certification matrix: every exact rung the ladder
#: auto-dispatches plus the approx rung, under the shipping policies.
DEFAULT_METHODS = ("vat", "ivat", "flashvat", "approx")
DEFAULT_POLICIES = (NumericsPolicy(mode="auto"),
                    NumericsPolicy(mode="safe"),
                    NumericsPolicy(mode="auto", dtype="bf16"))


def sweep(*, methods=DEFAULT_METHODS, metrics=CONDITIONED_METRICS,
          policies=DEFAULT_POLICIES, generators=None, seed: int = 0,
          n: int = 64, use_pallas: bool = False) -> list[CertResult]:
    """The full adversarial sweep; deterministic in ``seed``."""
    gens = generators if generators is not None else GENERATORS
    out: list[CertResult] = []
    for gname, gen in gens.items():
        # crc32, not hash(): string hashing is salted per process and
        # the sweep must be bitwise-reproducible across runs
        gsalt = zlib.crc32(gname.encode()) & 0xFFFF
        rng = np.random.default_rng(np.random.SeedSequence([seed, gsalt]))
        X = gen(rng, n)
        for metric in metrics:
            approx_base: float | None = None
            for method in methods:
                for policy in policies:
                    slack = 0.0
                    if method == "approx":
                        if approx_base is None:
                            # the rung's intrinsic kNN spanning defect,
                            # measured once under the best-numerics
                            # policy (safe: conditioned + direct form)
                            approx_base = certify_fit(
                                X, method="approx", metric=metric,
                                policy=NumericsPolicy(mode="safe"),
                                use_pallas=use_pallas).excess
                        slack = approx_base
                    out.append(certify_fit(
                        X, method=method, metric=metric, policy=policy,
                        use_pallas=use_pallas, generator=gname,
                        slack=slack))
    return out


def summarize(results: list[CertResult]) -> str:
    """Human-readable table of a sweep (one line per cell)."""
    lines = [f"{'generator':<16} {'method':<9} {'metric':<12} "
             f"{'mode':<5} {'dtype':<5} {'kappa':>10} {'excess':>10} "
             f"{'bound':>8}  ok"]
    for r in results:
        lines.append(
            f"{r.generator:<16} {r.method:<9} {r.metric:<12} "
            f"{r.mode:<5} {r.dtype_ran:<5} {r.kappa:>10.3g} "
            f"{r.excess:>10.3g} {r.bound:>8.1g}  "
            f"{'OK' if r.ok else 'FAIL'}"
            + ("  (exact)" if r.exact else "")
            + (f"  [bf16 fallback x{r.fallbacks}]" if r.fallbacks else ""))
    fails = sum(not r.ok for r in results)
    lines.append(f"{len(results)} cells, {fails} failing")
    return "\n".join(lines)


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        description="Adversarial numerics certification sweep")
    parser.add_argument("--smoke", action="store_true",
                        help="CI-sized sweep: one metric, smaller matrix")
    parser.add_argument("--seed", type=int, default=0)
    parser.add_argument("--n", type=int, default=64)
    parser.add_argument("--use-pallas", action="store_true")
    args = parser.parse_args(argv)
    if args.smoke:
        results = sweep(methods=("vat", "flashvat"),
                        metrics=("euclidean",),
                        generators={k: GENERATORS[k] for k in
                                    ("offset_clusters", "near_duplicates")},
                        seed=args.seed, n=args.n,
                        use_pallas=args.use_pallas)
    else:
        results = sweep(seed=args.seed, n=args.n,
                        use_pallas=args.use_pallas)
    print(summarize(results))
    return 1 if any(not r.ok for r in results) else 0


if __name__ == "__main__":
    raise SystemExit(main())
