"""Model zoo assembly: init / forward / decode for every assigned family.

One parameter-pytree + pure-function design (no flax):

  init_params(cfg, key, dtype)            -> params pytree
  forward(params, cfg, batch)             -> (logits, aux_loss)   [train/prefill]
  init_cache(cfg, batch, max_len, dtype)  -> cache pytree
  decode_step(params, cfg, tokens, cache, pos) -> (logits, cache) [serving]

Layers are *stacked* (leading L axis) and iterated with lax.scan so the
HLO stays compact (one layer body regardless of depth) — essential for
61-layer dry-run compiles and for FSDP gather/compute overlap.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import sharding
from repro.models.attention import (KVCache, MLACache, cross_block, gqa_block,
                                    mla_block)
from repro.models.common import dense_init, keygen, rms_norm, rope_freqs
from repro.models.mamba2 import MambaState, init_mamba_state, mamba_block, _dims
from repro.models.moe import dense_ffn, moe_ffn
from repro.models.rwkv6 import RWKVState, init_rwkv_state, rwkv_block

# --------------------------------------------------------------- init ----


def _init_tree(key, spec: dict, dtype) -> dict:
    """spec: name -> (shape, scale|None). Deterministic per-name keys."""
    out = {}
    for i, (name, (shape, scale)) in enumerate(sorted(spec.items())):
        sub = jax.random.fold_in(key, i)
        if scale == "zeros":
            out[name] = jnp.zeros(shape, dtype)
        elif scale == "ones":
            out[name] = jnp.ones(shape, dtype)
        elif isinstance(scale, (int, float)) or scale is None:
            out[name] = dense_init(sub, shape, scale, dtype)
        else:  # callable
            out[name] = scale(sub, shape).astype(dtype)
    return out


def _attn_spec(cfg: ModelConfig, L: tuple[int, ...]) -> dict:
    D = cfg.d_model
    if cfg.use_mla:
        dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
        return {
            "wq_a": ((*L, D, cfg.q_lora_rank), None),
            "q_norm": ((*L, cfg.q_lora_rank), "zeros"),
            "wq_b": ((*L, cfg.q_lora_rank, cfg.n_heads * (dn + dr)), None),
            "wkv_a": ((*L, D, cfg.kv_lora_rank + dr), None),
            "kv_norm": ((*L, cfg.kv_lora_rank), "zeros"),
            "wkv_b": ((*L, cfg.kv_lora_rank, cfg.n_heads * (dn + dv)), None),
            "wo": ((*L, cfg.n_heads * dv, D), None),
        }
    return {
        "wq": ((*L, D, cfg.q_dim), None),
        "wk": ((*L, D, cfg.kv_dim), None),
        "wv": ((*L, D, cfg.kv_dim), None),
        "wo": ((*L, cfg.q_dim, D), None),
    }


def _ffn_spec(cfg: ModelConfig, L: tuple[int, ...], d_ff: int,
              prefix: str = "w") -> dict:
    D = cfg.d_model
    spec = {
        f"{prefix}_up": ((*L, D, d_ff), None),
        f"{prefix}_down": ((*L, d_ff, D), None),
    }
    if cfg.gated:
        spec[f"{prefix}_gate"] = ((*L, D, d_ff), None)
    return spec


def _moe_spec(cfg: ModelConfig, L: tuple[int, ...]) -> dict:
    D, E, Fe = cfg.d_model, cfg.n_experts, cfg.d_ff_expert or cfg.d_ff
    spec = {
        "router": ((*L, D, E), 0.02),
        "e_up": ((*L, E, D, Fe), None),
        "e_down": ((*L, E, Fe, D), None),
    }
    if cfg.gated:
        spec["e_gate"] = ((*L, E, D, Fe), None)
    if cfg.n_shared_experts > 0:
        spec.update(_ffn_spec(cfg, L, Fe * cfg.n_shared_experts, prefix="s"))
    return spec


def _mamba_spec(cfg: ModelConfig, L: tuple[int, ...]) -> dict:
    inner, H, P, N = _dims(cfg)
    D = cfg.d_model
    proj_out = 2 * inner + 2 * N + H

    def a_init(k, shape):
        return jnp.log(jax.random.uniform(k, shape, jnp.float32, 1.0, 16.0))

    def dt_init(k, shape):
        dt = jnp.exp(jax.random.uniform(k, shape, jnp.float32,
                                        jnp.log(1e-3), jnp.log(1e-1)))
        return dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus

    return {
        "ln": ((*L, D), "zeros"),
        "in_proj": ((*L, D, proj_out), None),
        "conv": ((*L, 4, inner + 2 * N), lambda k, s: 0.1 * jax.random.normal(k, s)),
        "a_log": ((*L, H), a_init),
        "dt_bias": ((*L, H), dt_init),
        "skip_d": ((*L, H), "ones"),
        "norm": ((*L, inner), "zeros"),
        "out_proj": ((*L, inner, D), None),
    }


def _rwkv_spec(cfg: ModelConfig, L: tuple[int, ...]) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    N = cfg.rwkv_head_dim
    H = D // N
    half = lambda k, s: jnp.full(s, 0.5, jnp.float32)
    spec = {
        "ln1": ((*L, D), "zeros"), "ln2": ((*L, D), "zeros"),
        "mu_r": ((*L, D), half), "mu_k": ((*L, D), half),
        "mu_v": ((*L, D), half), "mu_w": ((*L, D), half),
        "mu_g": ((*L, D), half),
        "w_recv": ((*L, D, D), None), "w_key": ((*L, D, D), None),
        "w_val": ((*L, D, D), None), "w_gateproj": ((*L, D, D), None),
        "w0": ((*L, D), lambda k, s: jnp.full(s, -4.6, jnp.float32)),
        "w_lora_a": ((*L, D, 64), 0.02), "w_lora_b": ((*L, 64, D), 0.02),
        "u": ((*L, H, N), 0.02),
        "ln_x": ((*L, D), "zeros"),
        "w_out": ((*L, D, D), None),
        "cm_mu_k": ((*L, D), half), "cm_mu_r": ((*L, D), half),
        "w_up": ((*L, D, F), None), "w_down": ((*L, F, D), None),
        "w_recv_cm": ((*L, D, D), None),
    }
    return spec


def _block_spec(cfg: ModelConfig, L: tuple[int, ...], moe: bool) -> dict:
    spec = {"ln1": ((*L, cfg.d_model), "zeros"),
            "ln2": ((*L, cfg.d_model), "zeros")}
    spec.update(_attn_spec(cfg, L))
    if moe:
        spec.update(_moe_spec(cfg, L))
    else:
        spec.update(_ffn_spec(cfg, L, cfg.d_ff))
    return spec


def init_params(cfg: ModelConfig, key: jax.Array, dtype=jnp.float32) -> dict:
    kg = keygen(key)
    D, V = cfg.d_model, cfg.padded_vocab
    params: dict[str, Any] = {
        "embed": dense_init(next(kg), (V, D), 0.02, dtype),
        "final_norm": jnp.zeros((D,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(next(kg), (D, V), None, dtype)

    fam = cfg.family
    if fam in ("dense", "vlm"):
        params["layers"] = _init_tree(next(kg),
                                      _block_spec(cfg, (cfg.n_layers,), False),
                                      dtype)
    elif fam == "moe":
        params["layers"] = _init_tree(next(kg),
                                      _block_spec(cfg, (cfg.n_layers,), True),
                                      dtype)
        if cfg.mtp:
            mtp = _block_spec(cfg, (), False)
            mtp["mtp_proj"] = ((2 * D, D), None)
            mtp["mtp_norm"] = ((D,), "zeros")
            params["mtp_block"] = _init_tree(next(kg), mtp, dtype)
    elif fam == "ssm":
        params["layers"] = _init_tree(next(kg), _rwkv_spec(cfg, (cfg.n_layers,)),
                                      dtype)
    elif fam == "hybrid":
        nsb = cfg.n_layers // cfg.attn_every
        k_inner = cfg.attn_every - 1
        params["layers"] = _init_tree(next(kg),
                                      _mamba_spec(cfg, (nsb, k_inner)), dtype)
        params["shared_attn"] = _init_tree(next(kg), _block_spec(cfg, (), False),
                                           dtype)
    elif fam == "audio":
        enc = _block_spec(cfg, (cfg.n_enc_layers,), False)
        params["enc_layers"] = _init_tree(next(kg), enc, dtype)
        params["enc_final_norm"] = jnp.zeros((D,), dtype)
        dec = _block_spec(cfg, (cfg.n_layers,), False)
        dec.update({f"x_{k}": v for k, v in _attn_spec(cfg, (cfg.n_layers,)).items()})
        dec["ln_x_attn"] = ((cfg.n_layers, D), "zeros")
        params["layers"] = _init_tree(next(kg), dec, dtype)
    else:
        raise ValueError(f"unknown family {fam}")
    return params


# ------------------------------------------------------------ forward ----


def _maybe_remat(fn, cfg):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return fn


def _attn(p, h, cfg, cos, sin, cache=None, pos=None, causal=True):
    if cfg.use_mla:
        hn = h
        if "q_norm" in p:  # latent norms applied inside projections
            pass
        return mla_block(p, hn, cfg, cos, sin, cache=cache, pos=pos)
    return gqa_block(p, h, cfg, cos, sin, causal=causal, cache=cache, pos=pos)


def _dense_block(p, h, cfg, cos, sin, cache=None, pos=None, causal=True):
    a, new_cache = _attn(p, rms_norm(h, p["ln1"], cfg.norm_eps), cfg, cos, sin,
                         cache=cache, pos=pos, causal=causal)
    h = h + a
    h = h + dense_ffn(p, rms_norm(h, p["ln2"], cfg.norm_eps), cfg)
    return h, new_cache


def _moe_block(p, h, cfg, cos, sin, cache=None, pos=None, taps=False):
    a, new_cache = _attn(p, rms_norm(h, p["ln1"], cfg.norm_eps), cfg, cos, sin,
                         cache=cache, pos=pos)
    h = h + a
    if taps:
        y, aux, logits = moe_ffn(p, rms_norm(h, p["ln2"], cfg.norm_eps), cfg,
                                 return_logits=True)
        return h + y, aux, new_cache, logits
    y, aux = moe_ffn(p, rms_norm(h, p["ln2"], cfg.norm_eps), cfg)
    return h + y, aux, new_cache


def _embed_tokens(params, cfg, tokens):
    h = params["embed"][tokens]
    if cfg.tie_embeddings:  # gemma-style input scaling
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    return h


def _lm_head(params, cfg, h):
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = (h @ params["embed"].T).astype(jnp.float32)
    else:
        logits = (h @ params["lm_head"]).astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab:   # mask padding rows out of softmax
        iota = jnp.arange(cfg.padded_vocab)
        logits = jnp.where(iota >= cfg.vocab, -1e30, logits)
    return logits


def _rope_tables(cfg, positions):
    if cfg.use_mla:
        return rope_freqs(positions, cfg.qk_rope_dim, cfg.rope_theta)
    return rope_freqs(positions, cfg.head_dim, cfg.rope_theta)


def forward(params: dict, cfg: ModelConfig, batch: dict, *,
            return_hidden: bool = False, taps: bool = False):
    """Full-sequence forward. Returns (logits (B,S,V) f32, aux_loss scalar),
    or (final hidden states, aux) with return_hidden=True (chunked-CE path).

    batch: tokens (B,S[-n_patches]); vlm adds patches (B,n_patches,D);
    audio adds enc_frames (B,enc_seq,D).

    With ``taps=True`` (a trace-time static flag) the scanned layer bodies
    additionally emit their per-layer outputs as scan ys, and forward
    returns ``(primary, aux, taps_dict)`` where taps_dict has
    ``"layer_out"`` — stacked (L, B, S, D) hidden states after each layer
    (outer super-blocks for hybrid, decoder layers for audio) — and, for
    the moe family, ``"router_logits"`` — stacked (L, T, E) float32 router
    logits.  This is the monitor subsystem's intercept hook: everything
    stays device-resident, no host sync.
    """
    if cfg.family == "audio":
        return _forward_encdec(params, cfg, batch,
                               return_hidden=return_hidden, taps=taps)

    tokens = batch["tokens"]
    h = _embed_tokens(params, cfg, tokens)
    if cfg.family == "vlm":
        h = jnp.concatenate([batch["patches"].astype(h.dtype), h], axis=1)
    B, S, D = h.shape
    h = sharding.hint(h, "dp", "model" if cfg.seq_shard else None, None)
    cos, sin = _rope_tables(cfg, jnp.arange(S))
    aux = jnp.zeros((), jnp.float32)
    tap_tree = None

    fam = cfg.family
    if fam in ("dense", "vlm"):
        def body(h, lp):
            h, _ = _dense_block(lp, h, cfg, cos, sin)
            return h, (h if taps else None)
        h, ys = lax.scan(_maybe_remat(body, cfg), h, params["layers"])
        if taps:
            tap_tree = {"layer_out": ys}
    elif fam == "moe":
        def body(carry, lp):
            h, aux = carry
            if taps:
                h, a, _, logits = _moe_block(lp, h, cfg, cos, sin, taps=True)
                return (h, aux + a), (h, logits)
            h, a, _ = _moe_block(lp, h, cfg, cos, sin)
            return (h, aux + a), None
        (h, aux), ys = lax.scan(_maybe_remat(body, cfg), (h, aux),
                                params["layers"])
        if taps:
            tap_tree = {"layer_out": ys[0], "router_logits": ys[1]}
    elif fam == "ssm":
        def body(h, lp):
            h, _ = rwkv_block(lp, h, cfg)
            return h, (h if taps else None)
        h, ys = lax.scan(_maybe_remat(body, cfg), h, params["layers"])
        if taps:
            tap_tree = {"layer_out": ys}
    elif fam == "hybrid":
        shared = params["shared_attn"]

        def inner(h, lp):
            hn = rms_norm(h, lp["ln"], cfg.norm_eps)
            d, _ = mamba_block(lp, hn, cfg)
            return h + d, None

        def outer(h, lps):
            h, _ = lax.scan(inner, h, lps)
            h, _ = _dense_block(shared, h, cfg, cos, sin)
            return h, (h if taps else None)
        h, ys = lax.scan(_maybe_remat(outer, cfg), h, params["layers"])
        if taps:
            tap_tree = {"layer_out": ys}
    else:
        raise ValueError(fam)

    if cfg.family == "moe" and cfg.mtp and "mtp_block" in params \
            and "labels" in batch:
        aux = aux + _mtp_loss(params, cfg, h, batch, cos, sin)
    if cfg.family == "vlm":
        h = h[:, batch["patches"].shape[1]:, :]
    primary = h if return_hidden else _lm_head(params, cfg, h)
    if taps:
        return primary, aux, tap_tree
    return primary, aux


def _mtp_loss(params, cfg, h, batch, cos, sin):
    """DeepSeek-V3 multi-token prediction: one extra block predicts t+2."""
    p = params["mtp_block"]
    tokens = batch["tokens"]
    nxt = jnp.roll(tokens, -1, axis=1)
    e = _embed_tokens(params, cfg, nxt)
    hin = jnp.concatenate([rms_norm(h, p["mtp_norm"], cfg.norm_eps), e],
                          axis=-1) @ p["mtp_proj"]
    hout, _ = _dense_block(p, hin, cfg, cos, sin)
    S = hout.shape[1]
    labels2 = jnp.roll(batch["labels"], -1, axis=1)
    labels2 = jnp.where(jnp.arange(S)[None, :] >= S - 2, -1, labels2)
    ce, _, cnt = ce_from_hidden(params, cfg, hout, labels2,
                                chunk=cfg.ce_chunk)
    return 0.3 * ce / jnp.maximum(cnt, 1.0)


def _forward_encdec(params, cfg, batch, *, return_hidden=False, taps=False):
    """Whisper: encoder over precomputed frame embeddings + causal decoder."""
    frames = batch["enc_frames"]
    B = frames.shape[0]
    h = frames.astype(params["embed"].dtype)
    cos_e, sin_e = _rope_tables(cfg, jnp.arange(h.shape[1]))

    def enc_body(h, lp):
        h, _ = _dense_block(lp, h, cfg, cos_e, sin_e, causal=False)
        return h, None
    h, _ = lax.scan(_maybe_remat(enc_body, cfg), h, params["enc_layers"])
    enc_out = rms_norm(h, params["enc_final_norm"], cfg.norm_eps)

    tokens = batch["tokens"]
    hd_ = _embed_tokens(params, cfg, tokens)
    S = hd_.shape[1]
    cos_d, sin_d = _rope_tables(cfg, jnp.arange(S))

    def dec_body(h, lp):
        h, _ = _dec_block(lp, h, enc_out, cfg, cos_d, sin_d)
        return h, (h if taps else None)
    hd_, ys = lax.scan(_maybe_remat(dec_body, cfg), hd_, params["layers"])
    aux = jnp.zeros((), jnp.float32)
    primary = hd_ if return_hidden else _lm_head(params, cfg, hd_)
    if taps:
        return primary, aux, {"layer_out": ys}
    return primary, aux


def _dec_block(lp, h, enc_out, cfg, cos, sin, cache=None, pos=None,
               enc_kv=None):
    a, new_cache = gqa_block(lp, rms_norm(h, lp["ln1"], cfg.norm_eps), cfg,
                             cos, sin, causal=True, cache=cache, pos=pos)
    h = h + a
    xp = {k[2:]: v for k, v in lp.items() if k.startswith("x_")}
    hx = rms_norm(h, lp["ln_x_attn"], cfg.norm_eps)
    if enc_kv is None:
        Hkv, hd = cfg.eff_kv_heads, cfg.head_dim
        Be, Se, _ = enc_out.shape
        k = (enc_out @ xp["wk"]).reshape(Be, Se, Hkv, hd)
        v = (enc_out @ xp["wv"]).reshape(Be, Se, Hkv, hd)
        enc_kv = (k, v)
    h = h + cross_block(xp, hx, enc_kv, cfg)
    h = h + dense_ffn(lp, rms_norm(h, lp["ln2"], cfg.norm_eps), cfg)
    return h, new_cache


# ------------------------------------------------------------- decode ----


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> dict:
    """Zero-filled decoding cache for `batch` streams of up to `max_len`."""
    fam = cfg.family
    L = cfg.n_layers
    if fam in ("dense", "vlm", "audio") or (fam == "moe" and not cfg.use_mla):
        kv = KVCache(
            k=jnp.zeros((L, batch, max_len, cfg.eff_kv_heads, cfg.head_dim), dtype),
            v=jnp.zeros((L, batch, max_len, cfg.eff_kv_heads, cfg.head_dim), dtype))
        cache = {"kv": kv}
        if fam == "audio":
            cache["enc_kv"] = (
                jnp.zeros((L, batch, cfg.enc_seq, cfg.eff_kv_heads, cfg.head_dim), dtype),
                jnp.zeros((L, batch, cfg.enc_seq, cfg.eff_kv_heads, cfg.head_dim), dtype))
        return cache
    if fam == "moe":  # MLA latent cache
        return {"mla": MLACache(
            c_kv=jnp.zeros((L, batch, max_len, cfg.kv_lora_rank), dtype),
            k_rope=jnp.zeros((L, batch, max_len, cfg.qk_rope_dim), dtype))}
    if fam == "ssm":
        st = init_rwkv_state(cfg, batch, dtype)
        return {"rwkv": jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (L, *x.shape)), st)}
    if fam == "hybrid":
        nsb = L // cfg.attn_every
        k_inner = cfg.attn_every - 1
        ms = init_mamba_state(cfg, batch, dtype)
        mamba = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None, None], (nsb, k_inner, *x.shape)), ms)
        kv = KVCache(
            k=jnp.zeros((nsb, batch, max_len, cfg.eff_kv_heads, cfg.head_dim), dtype),
            v=jnp.zeros((nsb, batch, max_len, cfg.eff_kv_heads, cfg.head_dim), dtype))
        return {"mamba": mamba, "kv": kv}
    raise ValueError(fam)


def decode_step(params: dict, cfg: ModelConfig, tokens: jax.Array,
                cache: dict, pos) -> tuple[jax.Array, dict]:
    """One token step: tokens (B,1) -> (logits (B,1,V) f32, new cache)."""
    h = _embed_tokens(params, cfg, tokens)
    cos, sin = _rope_tables(cfg, pos + jnp.arange(1))
    fam = cfg.family

    if fam in ("dense", "vlm"):
        def body(h, xs):
            lp, c = xs
            h, nc = _dense_block(lp, h, cfg, cos, sin, cache=c, pos=pos)
            return h, nc
        h, kv = lax.scan(body, h, (params["layers"], cache["kv"]))
        return _lm_head(params, cfg, h), {"kv": kv}

    if fam == "moe":
        key = "mla" if cfg.use_mla else "kv"
        def body(carry, xs):
            lp, c = xs
            h, _, nc = _moe_block(lp, carry, cfg, cos, sin, cache=c, pos=pos)
            return h, nc
        h, nc = lax.scan(body, h, (params["layers"], cache[key]))
        return _lm_head(params, cfg, h), {key: nc}

    if fam == "ssm":
        def body(h, xs):
            lp, st = xs
            h, ns = rwkv_block(lp, h, cfg, state=st)
            return h, ns
        h, ns = lax.scan(body, h, (params["layers"], cache["rwkv"]))
        return _lm_head(params, cfg, h), {"rwkv": ns}

    if fam == "hybrid":
        shared = params["shared_attn"]

        def inner(h, xs):
            lp, st = xs
            hn = rms_norm(h, lp["ln"], cfg.norm_eps)
            d, ns = mamba_block(lp, hn, cfg, state=st)
            return h + d, ns

        def outer(h, xs):
            lps, sts, kvc = xs
            h, nsts = lax.scan(inner, h, (lps, sts))
            h, nkv = _dense_block(shared, h, cfg, cos, sin, cache=kvc, pos=pos)
            return h, (nsts, nkv)
        h, (nm, nkv) = lax.scan(outer, h,
                                (params["layers"], cache["mamba"], cache["kv"]))
        return _lm_head(params, cfg, h), {"mamba": nm, "kv": nkv}

    if fam == "audio":
        def body(h, xs):
            lp, c, ek, ev = xs
            h, nc = _dec_block(lp, h, None, cfg, cos, sin, cache=c, pos=pos,
                               enc_kv=(ek, ev))
            return h, nc
        ek, ev = cache["enc_kv"]
        h, kv = lax.scan(body, h, (params["layers"], cache["kv"], ek, ev))
        return _lm_head(params, cfg, h), {"kv": kv, "enc_kv": cache["enc_kv"]}

    raise ValueError(fam)


# ------------------------------------------------------- chunked loss ----


def ce_sums(logits, labels):
    """(sum CE, sum lse^2, token count) with labels<0 masked out."""
    mask = (labels >= 0).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None],
                             axis=-1)[..., 0]
    return (jnp.sum((lse - ll) * mask), jnp.sum(jnp.square(lse) * mask),
            jnp.sum(mask))


def ce_from_hidden(params, cfg: ModelConfig, h, labels, *, chunk: int = 0):
    """CE sums from final hidden states; chunk>0 scans over sequence chunks
    so the (B, S, V) f32 logits tensor never materializes (the logits peak
    dominates HBM for fat-vocab archs)."""
    B, S, D = h.shape
    if chunk <= 0 or S <= chunk or S % chunk != 0:
        return ce_sums(_lm_head(params, cfg, h), labels)
    nc = S // chunk
    hs = jnp.moveaxis(h.reshape(B, nc, chunk, D), 1, 0)
    ls = jnp.moveaxis(labels.reshape(B, nc, chunk), 1, 0)

    def body(carry, xs):
        hc, lc = xs
        ce, z, cnt = ce_sums(_lm_head(params, cfg, hc), lc)
        return (carry[0] + ce, carry[1] + z, carry[2] + cnt), None

    zero = jnp.zeros((), jnp.float32)
    (ce, z, cnt), _ = lax.scan(body, (zero, zero, zero), (hs, ls))
    return ce, z, cnt


# ------------------------------------------------------ serving prefill ----


def prefill(params: dict, cfg: ModelConfig, batch: dict, max_len: int,
            cache_dtype=jnp.bfloat16):
    """Full-sequence prefill that RETURNS the decode cache.

    The serving handoff: run the prompt once, keep per-layer KV/latent/
    state, then `decode_step` continues from position S.  Implemented by
    running each block in cache mode against a zero cache at pos=0 with
    the whole prompt as one "step" (dynamic_update_slice writes [0, S)).

    Returns (logits (B,S,V) f32, cache, next_pos).
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    if cfg.family == "vlm":
        S = S + cfg.n_patches
    cache = init_cache(cfg, B, max_len, cache_dtype)
    h = _embed_tokens(params, cfg, tokens)
    if cfg.family == "vlm":
        h = jnp.concatenate([batch["patches"].astype(h.dtype), h], axis=1)
    cos, sin = _rope_tables(cfg, jnp.arange(S))
    fam = cfg.family
    pos0 = jnp.int32(0)

    if fam in ("dense", "vlm"):
        def body(h, xs):
            lp, c = xs
            h, nc = _dense_block(lp, h, cfg, cos, sin, cache=c, pos=pos0)
            return h, nc
        h, kv = lax.scan(body, h, (params["layers"], cache["kv"]))
        new_cache = {"kv": kv}
    elif fam == "moe":
        key = "mla" if cfg.use_mla else "kv"
        def body(h, xs):
            lp, c = xs
            h, _, nc = _moe_block(lp, h, cfg, cos, sin, cache=c, pos=pos0)
            return h, nc
        h, nc = lax.scan(body, h, (params["layers"], cache[key]))
        new_cache = {key: nc}
    elif fam == "ssm":
        # run the recurrence over the full prompt, keep the final state
        def body(h, lp):
            h, ns = rwkv_block(lp, h, cfg, return_state=True)
            return h, ns
        h, ns = lax.scan(body, h, params["layers"])
        new_cache = {"rwkv": jax.tree.map(
            lambda c, n: n.astype(c.dtype), cache["rwkv"], ns)}
    elif fam == "hybrid":
        shared = params["shared_attn"]

        def inner(h, lp):
            hn = rms_norm(h, lp["ln"], cfg.norm_eps)
            d, ns = mamba_block(lp, hn, cfg, return_state=True)
            return h + d, ns

        def outer(h, xs):
            lps, kvc = xs
            h, nsts = lax.scan(inner, h, lps)
            h, nkv = _dense_block(shared, h, cfg, cos, sin, cache=kvc,
                                  pos=pos0)
            return h, (nsts, nkv)
        h, (nm, nkv) = lax.scan(outer, h, (params["layers"], cache["kv"]))
        new_cache = {"mamba": jax.tree.map(
            lambda c, n: n.astype(c.dtype), cache["mamba"], nm),
            "kv": nkv}
    elif fam == "audio":
        # encode once, fill cross-attn K/V + run decoder prompt with cache
        frames = batch["enc_frames"].astype(h.dtype)
        he = frames
        cos_e, sin_e = _rope_tables(cfg, jnp.arange(he.shape[1]))

        def enc_body(he, lp):
            he, _ = _dense_block(lp, he, cfg, cos_e, sin_e, causal=False)
            return he, None
        he, _ = lax.scan(_maybe_remat(enc_body, cfg), he,
                         params["enc_layers"])
        enc_out = rms_norm(he, params["enc_final_norm"], cfg.norm_eps)
        Hkv, hd = cfg.eff_kv_heads, cfg.head_dim
        Be, Se, _ = enc_out.shape

        def dec_body(h, xs):
            lp, c = xs
            xp = {k[2:]: v for k, v in lp.items() if k.startswith("x_")}
            ek = (enc_out @ xp["wk"]).reshape(Be, Se, Hkv, hd)
            ev = (enc_out @ xp["wv"]).reshape(Be, Se, Hkv, hd)
            h, nc = _dec_block(lp, h, None, cfg, cos, sin, cache=c,
                               pos=pos0, enc_kv=(ek, ev))
            return h, (nc, ek.astype(cache_dtype), ev.astype(cache_dtype))
        h, (kv, eks, evs) = lax.scan(dec_body, h,
                                     (params["layers"], cache["kv"]))
        new_cache = {"kv": kv, "enc_kv": (eks, evs)}
    else:
        raise ValueError(fam)

    if cfg.family == "vlm":
        h = h[:, cfg.n_patches:, :]
    return _lm_head(params, cfg, h), new_cache, jnp.int32(S)
