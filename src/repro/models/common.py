"""Shared building blocks: norms, RoPE, activations, initializers."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale + bias).astype(x.dtype)


def activation(name: str):
    if name in ("swiglu",):
        return jax.nn.silu
    if name in ("geglu", "gelu"):
        return lambda x: jax.nn.gelu(x, approximate=True)
    if name == "relu2":  # nemotron squared ReLU
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name}")


def rope_freqs(positions: jax.Array, dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables for `positions` (any shape) over `dim` rope dims."""
    half = dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (..., S, H, hd) with cos/sin (..., S, hd/2) — rotate-half convention."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]  # broadcast over the head axis
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s],
                           axis=-1).astype(x.dtype)


def sinusoidal_pos(seq: int, dim: int, dtype=jnp.float32) -> jax.Array:
    """Classic transformer sinusoidal position table (whisper encoder)."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    div = jnp.exp(-jnp.log(10_000.0) * jnp.arange(0, dim, 2, jnp.float32) / dim)
    tab = jnp.zeros((seq, dim), jnp.float32)
    tab = tab.at[:, 0::2].set(jnp.sin(pos * div))
    tab = tab.at[:, 1::2].set(jnp.cos(pos * div))
    return tab.astype(dtype)


def dense_init(key: jax.Array, shape: tuple[int, ...], scale: float | None = None,
               dtype=jnp.float32) -> jax.Array:
    """Truncated-normal fan-in init (0.02-style for embeds, 1/sqrt(fan_in) else)."""
    if scale is None:
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        scale = fan_in ** -0.5
    return (scale * jax.random.truncated_normal(key, -2.0, 2.0, shape,
                                                jnp.float32)).astype(dtype)


def keygen(key: jax.Array):
    """Infinite deterministic key splitter."""
    while True:
        key, sub = jax.random.split(key)
        yield sub
