"""Mamba2 (SSD — state-space duality) block, chunked matmul formulation.

Training/prefill runs the chunkwise algorithm: within a chunk of length L
the output is a masked (L x L) matmul (MXU work), between chunks a single
(B,H,N,P) state carries through a lax.scan — O(S) time, O(B H N P) state,
bounded memory (the L x L decay tensor is per-chunk only).

Decode is the pure recurrence: h' = exp(dA) h + B (dt x);  y = C h + D x.

The short causal conv over (x, B, C) keeps a (window-1)-deep conv state
for decode, mirroring the CUDA reference implementation's layout.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import sharding
from repro.models.common import rms_norm

_CONV_W = 4  # short conv window


class MambaState(NamedTuple):
    ssm: jax.Array    # (B, H, N, P) f32
    conv: jax.Array   # (B, CONV_W-1, inner + 2N)


def _dims(cfg):
    inner = cfg.ssm_expand * cfg.d_model
    P = cfg.ssm_head_dim
    H = inner // P
    N = cfg.ssm_state
    return inner, H, P, N


def _split_proj(zxbcdt, cfg):
    inner, H, P, N = _dims(cfg)
    z = zxbcdt[..., :inner]
    xBC = zxbcdt[..., inner:2 * inner + 2 * N]
    dt = zxbcdt[..., 2 * inner + 2 * N:]
    return z, xBC, dt


def _causal_conv(xBC, conv_k):
    """Depthwise causal conv, window 4: xBC (B,S,C), conv_k (W,C)."""
    pad = jnp.pad(xBC, ((0, 0), (_CONV_W - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xBC.shape[1], :] * conv_k[i]
              for i in range(_CONV_W))
    return jax.nn.silu(out)


def mamba_block(p, u, cfg, *, state: MambaState | None = None,
                return_state: bool = False):
    """u (B,S,D) -> (B,S,D).

    state=None: full-sequence (train / prefill); pass return_state=True to
    also get the final recurrent state (serving prefill handoff).
    state!=None with S==1: single-token decode.
    """
    B, S, D = u.shape
    inner, H, P, N = _dims(cfg)
    zxbcdt = u @ p["in_proj"]
    zxbcdt = sharding.hint(zxbcdt, "dp", None, "model")
    z, xBC, dt = _split_proj(zxbcdt, cfg)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))               # (H,)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))   # (B,S,H)

    if state is None:
        xBC_raw = xBC
        xBC = _causal_conv(xBC, p["conv"])
        new_state = None
        x, Bm, Cm = (xBC[..., :inner], xBC[..., inner:inner + N],
                     xBC[..., inner + N:])
        xh = x.reshape(B, S, H, P)
        y, final_ssm = _ssd_chunked(xh, Bm, Cm, dt, A, cfg)     # f32
        y = y + p["skip_d"].astype(jnp.float32)[None, None, :, None] \
            * xh.astype(jnp.float32)
        y = y.reshape(B, S, inner).astype(u.dtype)
        if return_state:
            # conv state = last (window-1) *pre-conv* inputs
            pad = jnp.pad(xBC_raw, ((0, 0), (_CONV_W - 1, 0), (0, 0)))
            new_state = MambaState(ssm=final_ssm,
                                   conv=pad[:, S:S + _CONV_W - 1, :])
    else:
        # ---- decode: conv state + recurrence ----
        win = jnp.concatenate([state.conv, xBC], axis=1)       # (B, W, C)
        conv_out = jax.nn.silu(jnp.einsum("bwc,wc->bc", win, p["conv"]))
        new_conv = win[:, 1:, :]
        x = conv_out[:, :inner].reshape(B, H, P)
        Bm = conv_out[:, inner:inner + N]
        Cm = conv_out[:, inner + N:]
        dt1 = dt[:, 0]                                          # (B,H)
        dA = jnp.exp(dt1 * A[None, :])                          # (B,H)
        xbar = (x.astype(jnp.float32) * dt1[..., None])         # (B,H,P)
        ssm = (state.ssm * dA[:, :, None, None]
               + jnp.einsum("bn,bhp->bhnp", Bm.astype(jnp.float32), xbar))
        y = jnp.einsum("bn,bhnp->bhp", Cm.astype(jnp.float32), ssm)
        y = y + p["skip_d"].astype(jnp.float32)[None, :, None] * x
        y = y.reshape(B, 1, inner).astype(u.dtype)
        new_state = MambaState(ssm=ssm, conv=new_conv)

    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return y @ p["out_proj"], new_state


def _ssd_chunked(x, Bm, Cm, dt, A, cfg):
    """Chunkwise SSD scan.

    x (B,S,H,P); Bm/Cm (B,S,N); dt (B,S,H); A (H,) -> y (B,S,H*P)
    """
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    L = min(cfg.ssm_chunk, S)
    assert S % L == 0, f"seq {S} not divisible by ssm chunk {L}"
    nc = S // L

    xf = x.astype(jnp.float32) * dt[..., None]                  # xbar
    dA = dt * A[None, None, :]                                  # (B,S,H) <=0
    ch = lambda t: t.reshape(B, nc, L, *t.shape[2:]).swapaxes(0, 1)
    xs = (ch(xf), ch(Bm.astype(jnp.float32)), ch(Cm.astype(jnp.float32)),
          ch(dA))
    tri = jnp.tril(jnp.ones((L, L), bool))

    def step(state, chunk):
        xc, bc, cc, dac = chunk                                 # (B,L,...)
        seg = jnp.cumsum(dac, axis=1)                           # (B,L,H)
        # inter-chunk: contribution of the carried state
        y_prev = jnp.einsum("bln,bhnp->blhp", cc, state) * jnp.exp(seg)[..., None]
        # intra-chunk: masked decay matmul
        diff = seg[:, :, None, :] - seg[:, None, :, :]          # (B,L,L,H) t,s
        decay = jnp.exp(jnp.where(tri[None, :, :, None], diff, -jnp.inf))
        cb = jnp.einsum("bln,bsn->bls", cc, bc)
        y_intra = jnp.einsum("bls,blsh,bshp->blhp", cb, decay, xc)
        # state update
        total = seg[:, -1]                                      # (B,H)
        edge = jnp.exp(total[:, None, :] - seg)                 # (B,L,H)
        state = (state * jnp.exp(total)[:, :, None, None]
                 + jnp.einsum("bsn,bsh,bshp->bhnp", bc, edge, xc))
        return state, y_prev + y_intra

    state0 = jnp.zeros((B, H, N, P), jnp.float32)
    final, ys = lax.scan(step, state0, xs)                      # (nc,B,L,H,P)
    return ys.swapaxes(0, 1).reshape(B, S, H, P), final         # f32


def init_mamba_state(cfg, batch: int, dtype) -> MambaState:
    inner, H, P, N = _dims(cfg)
    return MambaState(
        ssm=jnp.zeros((batch, H, N, P), jnp.float32),
        conv=jnp.zeros((batch, _CONV_W - 1, inner + 2 * N), dtype))
