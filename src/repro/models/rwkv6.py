"""RWKV-6 (Finch) block: data-dependent-decay linear attention, no KV cache.

Time-mix keeps a per-head (N x N) matrix state updated once per token —
decode is O(1) in sequence length, which is why rwkv6 runs the long_500k
cell that quadratic-attention archs skip.  Training materializes r/k/v/w
for the whole sequence (matmuls) and runs the recurrence as a lax.scan.

The decay is the Finch LoRA form: w = exp(-exp(w0 + tanh(x W1) W2)),
data-dependent per channel per token.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import sharding
from repro.models.common import rms_norm


class RWKVState(NamedTuple):
    wkv: jax.Array      # (B, H, N, N) f32 linear-attention state
    tm_last: jax.Array  # (B, D) previous token (time-mix shift)
    cm_last: jax.Array  # (B, D) previous token (channel-mix shift)


def _heads(t, H, N):
    return t.reshape(*t.shape[:-1], H, N)


def _mix(x, xprev, mu):
    return x + (xprev - x) * mu


def _decay(xw, p):
    lora = jnp.tanh(xw @ p["w_lora_a"]) @ p["w_lora_b"]
    return jnp.exp(-jnp.exp(p["w0"].astype(jnp.float32)
                            + lora.astype(jnp.float32)))


def rwkv_block(p, hin, cfg, *, state: RWKVState | None = None,
               return_state: bool = False):
    """hin (B,S,D) residual stream -> (B,S,D).

    state=None: full sequence (optionally return the final state for the
    serving prefill handoff).  state!=None with S==1: decode.
    """
    B, S, D = hin.shape
    N = cfg.rwkv_head_dim
    H = D // N

    # ---- time mix ----
    x = rms_norm(hin, p["ln1"], cfg.norm_eps)
    if state is None:
        xprev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
        wkv0 = jnp.zeros((B, H, N, N), jnp.float32)
    else:
        xprev = state.tm_last[:, None, :]
        wkv0 = state.wkv
    xr = _mix(x, xprev, p["mu_r"])
    xk = _mix(x, xprev, p["mu_k"])
    xv = _mix(x, xprev, p["mu_v"])
    xw = _mix(x, xprev, p["mu_w"])
    xg = _mix(x, xprev, p["mu_g"])
    r = _heads(xr @ p["w_recv"], H, N).astype(jnp.float32)
    k = _heads(xk @ p["w_key"], H, N).astype(jnp.float32)
    v = _heads(xv @ p["w_val"], H, N).astype(jnp.float32)
    g = jax.nn.silu(xg @ p["w_gateproj"])
    w = _heads(_decay(xw, p), H, N)                     # (B,S,H,N) in (0,1)
    u = p["u"].astype(jnp.float32)                      # (H,N)

    def step(wkv, inp):
        rt, kt, vt, wt = inp                            # (B,H,N)
        kv = kt[..., :, None] * vt[..., None, :]        # (B,H,N,N)
        y = jnp.einsum("bhn,bhnm->bhm", rt,
                       wkv + u[None, :, :, None] * kv)
        wkv = wt[..., :, None] * wkv + kv
        return wkv, y

    seq_first = lambda t: jnp.moveaxis(t, 1, 0)          # (S,B,H,N)
    new_wkv, ys = lax.scan(step, wkv0,
                           (seq_first(r), seq_first(k),
                            seq_first(v), seq_first(w)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, D).astype(x.dtype)
    y = rms_norm(y, p["ln_x"], cfg.norm_eps) * g
    h = hin + y @ p["w_out"]

    # ---- channel mix ----
    x2 = rms_norm(h, p["ln2"], cfg.norm_eps)
    if state is None:
        x2prev = jnp.pad(x2, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        x2prev = state.cm_last[:, None, :]
    hk = _mix(x2, x2prev, p["cm_mu_k"])
    hr = _mix(x2, x2prev, p["cm_mu_r"])
    kcm = jnp.square(jax.nn.relu(hk @ p["w_up"]))
    kcm = sharding.hint(kcm, "dp", None, "model")
    vcm = kcm @ p["w_down"]
    rcm = jax.nn.sigmoid(hr @ p["w_recv_cm"])
    h = h + rcm * vcm

    new_state = None
    if state is not None or return_state:
        new_state = RWKVState(wkv=new_wkv, tm_last=x[:, -1, :],
                              cm_last=x2[:, -1, :])
    return h, new_state


def init_rwkv_state(cfg, batch: int, dtype) -> RWKVState:
    D = cfg.d_model
    N = cfg.rwkv_head_dim
    H = D // N
    return RWKVState(
        wkv=jnp.zeros((batch, H, N, N), jnp.float32),
        tm_last=jnp.zeros((batch, D), dtype),
        cm_last=jnp.zeros((batch, D), dtype))
