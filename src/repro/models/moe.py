"""Mixture-of-Experts FFN with capacity-based dispatch (GShard-style).

Expert-parallel layout: the leading expert dim of the (E, C, D) dispatch
buffer and the expert weight stacks shard over the mesh `model` axis; the
capacity dim shards over the batch axes.  Dispatch/combine are scatter-add
and gather in the global view — under SPMD these lower to the all-to-all
pattern of classic EP.

Position computation is the slot-major cumsum trick: entries are ordered
(slot, token) so slot 0 of every token beats slot 1 for buffer space, and
tokens that overflow an expert's capacity are *dropped* (contribute zero;
the residual stream carries them — standard capacity-factor semantics).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import sharding
from repro.models.common import activation


def dense_ffn(p, h, cfg, prefix="w"):
    """Gated (or plain) FFN: h (B,S,D) -> (B,S,D)."""
    act = activation(cfg.act)
    up = h @ p[f"{prefix}_up"]
    up = sharding.hint(up, "dp", None, "model")
    if cfg.gated:
        gate = act(h @ p[f"{prefix}_gate"])
        gate = sharding.hint(gate, "dp", None, "model")
        inner = gate * up
    else:
        inner = act(up)
    return inner @ p[f"{prefix}_down"]


def moe_ffn(p, h, cfg, *, return_logits=False):
    """MoE FFN: returns (out (B,S,D), aux_loss scalar).

    p: router (D,E); e_gate/e_up (E,D,F); e_down (E,F,D);
       optional shared-expert weights s_gate/s_up/s_down.

    With ``return_logits=True`` also returns the (T, E) float32 router
    logits so diagnostics (monitor router probes) can assess routing
    health without recomputing the forward pass.
    """
    B, S, D = h.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    x = h.reshape(T, D)
    x = sharding.hint(x, "dp", None)

    logits = (x @ p["router"]).astype(jnp.float32)        # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    if cfg.route_groups > 1:
        # group-limited routing (DeepSeek-V3): keep only the top-g expert
        # groups per token, confining dispatch traffic to a fraction of
        # the mesh (groups map to contiguous device blocks under EP)
        G = cfg.route_groups
        gsz = E // G
        gscore = jnp.sum(jax.lax.top_k(probs.reshape(T, G, gsz),
                                       min(2, gsz))[0], axis=-1)  # (T, G)
        _, gidx = jax.lax.top_k(gscore, cfg.route_top_groups)
        gmask = jnp.zeros((T, G), bool).at[
            jnp.arange(T)[:, None], gidx].set(True)
        probs = jnp.where(jnp.repeat(gmask, gsz, axis=1), probs, 0.0)
    w, ids = jax.lax.top_k(probs, K)                      # (T, K)
    w = (w / (jnp.sum(w, axis=-1, keepdims=True) + 1e-9)).astype(h.dtype)

    cap = int(K * T * cfg.capacity_factor / E)
    cap = max(cap, 1)

    # slot-major flattening: (K*T,) with slot 0 entries first
    ids_f = ids.T.reshape(-1)                             # (KT,)
    tok_f = jnp.tile(jnp.arange(T), K)
    w_f = w.T.reshape(-1)
    oh = jax.nn.one_hot(ids_f, E, dtype=jnp.int32)        # (KT, E)
    pos_in_e = jnp.sum(jnp.cumsum(oh, axis=0) * oh, axis=1) - 1
    keep = pos_in_e < cap
    pos_c = jnp.clip(pos_in_e, 0, cap - 1)

    # dispatch: scatter-add tokens into the (E, cap, D) buffer
    contrib = jnp.where(keep[:, None], x[tok_f], 0).astype(h.dtype)
    buf = jnp.zeros((E, cap, D), h.dtype).at[ids_f, pos_c].add(contrib)
    e_axes = ("model", "data") if sharding.ep2d() else "model"
    buf = sharding.hint(buf, e_axes, None if sharding.ep2d() else "dp", None)

    # expert compute (batched over the expert dim — EP over `model`)
    act = activation(cfg.act)
    up = jnp.einsum("ecd,edf->ecf", buf, p["e_up"])
    if cfg.gated:
        gate = act(jnp.einsum("ecd,edf->ecf", buf, p["e_gate"]))
        inner = gate * up
    else:
        inner = act(up)
    out_buf = jnp.einsum("ecf,efd->ecd", inner, p["e_down"])
    out_buf = sharding.hint(out_buf, e_axes, None if sharding.ep2d() else "dp",
                            None)

    # combine: gather each entry's expert output, weight, scatter to tokens
    gathered = out_buf[ids_f, pos_c]                      # (KT, D)
    gathered = jnp.where(keep[:, None], gathered, 0) * w_f[:, None]
    y = jnp.zeros((T, D), h.dtype).at[tok_f].add(gathered)

    # load-balance auxiliary loss (Switch/GShard form)
    frac_tokens = jnp.mean(jax.nn.one_hot(ids[:, 0], E, dtype=jnp.float32),
                           axis=0)
    frac_prob = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_tokens * frac_prob) * cfg.router_aux_coef

    if cfg.n_shared_experts > 0:
        y = y + dense_ffn(p, h, cfg, prefix="s").reshape(T, D)
    if return_logits:
        return y.reshape(B, S, D), aux, logits
    return y.reshape(B, S, D), aux
