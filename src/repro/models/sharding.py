"""Logical-axis sharding rules for the model zoo.

Mesh axes: ``("data", "model")`` single pod, ``("pod", "data", "model")``
multi-pod.  Logical placement:

  * batch            -> ("pod", "data")        (DP)
  * TP / EP          -> "model"                (heads, d_ff, experts, vocab)
  * FSDP weight shard-> "data"                 (the d_model-ish dim)
  * stacked layer dim-> replicated (scan carries it)

Divisibility fallback: any dim not divisible by its mesh axis size is left
unsharded (e.g. whisper's 20 heads or 51866 vocab on a 16-wide model axis)
— recorded in the dry-run log so the roofline can attribute replication.

Activation hints are applied through ``hint`` which no-ops when no mesh
context is active, so smoke tests and CPU runs never see sharding.
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# module-level mesh context used for activation hints; set by launchers
_ACTIVE: dict[str, Any] = {"mesh": None, "dp": None, "ep2d": False}


def set_ep2d(on: bool) -> None:
    """2-D expert parallelism: distribute experts over model x data instead
    of EP(model) + FSDP(data).  Kills the per-step all-gather of the full
    expert stack (the dominant collective for 256-expert models); expert
    weights live whole on one device row, tokens move via all-to-all."""
    _ACTIVE["ep2d"] = on


def ep2d() -> bool:
    return _ACTIVE["ep2d"]


def set_mesh(mesh: Mesh | None) -> None:
    """Register the active mesh for activation hints (None to disable)."""
    if mesh is None:
        _ACTIVE["mesh"] = None
        _ACTIVE["dp"] = None
        return
    axes = mesh.axis_names
    _ACTIVE["mesh"] = mesh
    _ACTIVE["dp"] = ("pod", "data") if "pod" in axes else ("data",)


def dp_axes() -> tuple[str, ...] | None:
    return _ACTIVE["dp"]


def hint(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint that degrades to identity without a mesh.

    spec entries: "dp" (expands to the batch axes), a mesh axis name, or
    None.  Dims whose size is not divisible by the axis size fall back to
    None.
    """
    mesh = _ACTIVE["mesh"]
    if mesh is None:
        return x
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    resolved = []
    for dim, s in enumerate(spec):
        if s == "dp":
            s = _ACTIVE["dp"]
        if s is None:
            resolved.append(None)
            continue
        names = (s,) if isinstance(s, str) else tuple(s)
        total = 1
        for nm in names:
            total *= sizes.get(nm, 1)
        if x.shape[dim] % total != 0:
            resolved.append(None)
        else:
            resolved.append(names if len(names) > 1 else names[0])
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved)))


def _divis(n: int, size: int) -> bool:
    return size > 0 and n % size == 0


def spec_for(path: str, shape: tuple[int, ...], mesh: Mesh) -> P:
    """PartitionSpec for one parameter leaf, keyed on its path name.

    Weight naming convention (see models/model.py init):
      wq wk wv wo w_gate w_up w_down  — attention / FFN projections
      e_gate e_up e_down router       — MoE experts (leading E dim)
      embed lm_head pos_*             — vocab-space tables
      in_proj out_proj (ssm/rwkv)     — wide fused projections
      everything else (norms, biases, decay vectors) — replicated
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    m = sizes.get("model", 1)
    d = sizes.get("data", 1)
    leaf = path.split("/")[-1]
    nd = len(shape)

    def ax(i: int, name: str, size: int):
        return name if _divis(shape[i], size) else None

    if leaf in ("embed", "lm_head", "mtp_head"):
        # (V, D) or (D, V): shard vocab over model, other dim over data
        if leaf == "embed":
            return P(ax(0, "model", m), ax(1, "data", d))
        return P(ax(0, "data", d), ax(1, "model", m))
    if leaf.startswith("pos_"):
        return P(*([None] * nd))
    if leaf in ("e_gate", "e_up", "e_down"):
        if _ACTIVE["ep2d"] and shape[1] % (m * d) == 0:
            # 2-D EP: experts spread over model x data, no FSDP gather
            return P(None, ("model", "data"), None, None)
        # (L, E, Din, Dout): experts over model (EP), inner over data
        if leaf == "e_down":
            return P(None, ax(1, "model", m), None, ax(3, "data", d))
        return P(None, ax(1, "model", m), ax(2, "data", d), None)
    if leaf in ("wq", "wk", "wv", "w_gate", "w_up", "in_proj",
                "wq_a", "wq_b", "wkv_a", "wkv_b", "w_recv", "w_key",
                "w_val", "w_gateproj"):
        # (..., D_in, D_wide): FSDP on D_in, TP on the wide dim
        return P(*([None] * (nd - 2)),
                 ax(nd - 2, "data", d), ax(nd - 1, "model", m))
    if leaf in ("wo", "w_down", "out_proj", "w_out"):
        # (..., D_wide, D_out): TP on the wide dim, FSDP on D_out
        return P(*([None] * (nd - 2)),
                 ax(nd - 2, "model", m), ax(nd - 1, "data", d))
    if leaf == "router":
        return P(*([None] * (nd - 2)), ax(nd - 2, "data", d), None)
    if leaf == "conv":  # depthwise conv kernels (mamba) — small
        return P(*([None] * nd))
    # norms / scalar-ish leaves: replicated
    return P(*([None] * nd))


def param_shardings(params: Any, mesh: Mesh) -> Any:
    """Pytree of NamedShardings matching `params` (works on ShapeDtypeStructs)."""
    def one(kp, leaf):
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        return NamedSharding(mesh, spec_for(path, leaf.shape, mesh))
    return jax.tree_util.tree_map_with_path(one, params)
