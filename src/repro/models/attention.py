"""Attention: GQA/MQA (chunked causal), MLA (DeepSeek), cross-attention.

Training/prefill attention is q-chunked ("flash-lite"): the (S x S) score
matrix never materializes — each q-chunk computes a (chunk x S) row block,
masks, softmaxes and contracts immediately.  Memory is O(S * chunk) per
head instead of O(S^2), which is what lets prefill_32k compile inside a
v5e HBM budget.  The contraction runs on the MXU in bf16 with f32 softmax.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import sharding
from repro.models.common import apply_rope, rope_freqs

_NEG = -1e30


def _block_attn(qg, k, v, qpos, kv_idx, causal):
    """qg (B,L,G,R,hd) vs k/v (B,K,G,hd) -> (B,L,G,R,hd)."""
    scale = qg.shape[-1] ** -0.5
    s = jnp.einsum("blgrh,bkgh->bgrlk", qg, k,
                   preferred_element_type=jnp.float32) * scale
    if causal:
        mask = kv_idx[None, :] <= qpos[:, None]          # (L, K)
        s = jnp.where(mask[None, None, None], s, _NEG)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    return jnp.einsum("bgrlk,bkgh->blgrh", p, v)


def attention(q, k, v, *, causal: bool = True, chunk: int = 0,
              q_offset=0):
    """q (B,S,H,hd), k/v (B,K,Hkv,hd) -> (B,S,H,hd); GQA via head groups."""
    B, S, H, hd = q.shape
    K, Hkv = k.shape[1], k.shape[2]
    vd = v.shape[-1]  # may differ from hd (MLA: qk dim != v dim)
    rep = H // Hkv
    qg = q.reshape(B, S, Hkv, rep, hd)
    kv_idx = jnp.arange(K)
    qpos_all = q_offset + jnp.arange(S)

    if chunk and S > chunk and S % chunk == 0:
        n = S // chunk
        qs = jnp.moveaxis(qg.reshape(B, n, chunk, Hkv, rep, hd), 1, 0)
        pos = qpos_all.reshape(n, chunk)
        out = lax.map(lambda t: _block_attn(t[0], k, v, t[1], kv_idx, causal),
                      (qs, pos))
        out = jnp.moveaxis(out, 0, 1).reshape(B, S, H, vd)
    else:
        out = _block_attn(qg, k, v, qpos_all, kv_idx, causal)
        out = out.reshape(B, S, H, vd)
    return out


class KVCache(NamedTuple):
    k: jax.Array  # (B, Smax, Hkv, hd)
    v: jax.Array


def gqa_block(p, h, cfg, cos, sin, *, causal=True, cache: KVCache | None = None,
              pos=None):
    """Self-attention sublayer (projections + rope + attn + out proj).

    Train/prefill: cache is None, h is (B,S,D).
    Decode: cache holds Smax entries, h is (B,1,D), pos is the write index.
    """
    B, S, D = h.shape
    H, Hkv, hd = cfg.eff_heads, cfg.eff_kv_heads, cfg.head_dim
    q = (h @ p["wq"]).reshape(B, S, H, hd)
    k = (h @ p["wk"]).reshape(B, S, Hkv, hd)
    v = (h @ p["wv"]).reshape(B, S, Hkv, hd)
    q = sharding.hint(q, "dp", None, "model", None)
    k = sharding.hint(k, "dp", None, "model", None)
    v = sharding.hint(v, "dp", None, "model", None)
    if cos is not None:
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    if cache is None:
        out = attention(q, k, v, causal=causal, chunk=cfg.attn_chunk)
        new_cache = None
    else:
        ck = lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype),
                                      (0, pos, 0, 0))
        cv = lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype),
                                      (0, pos, 0, 0))
        new_cache = KVCache(ck, cv)
        out = attention(q, ck, cv, causal=True, q_offset=pos)
    if H != cfg.n_heads:
        # padded heads (TP-divisibility) are masked out: function- and
        # gradient-equivalent to the unpadded architecture
        out = out * (jnp.arange(H) < cfg.n_heads)[None, None, :, None]
    out = out.reshape(B, S, H * hd)
    return out @ p["wo"], new_cache


def cross_block(p, h, enc_kv, cfg):
    """Cross-attention sublayer (whisper decoder). enc_kv = (k, v) tensors."""
    B, S, D = h.shape
    H, hd = cfg.eff_heads, cfg.head_dim
    q = (h @ p["wq"]).reshape(B, S, H, hd)
    k, v = enc_kv
    out = attention(q, k, v, causal=False, chunk=cfg.attn_chunk)
    if H != cfg.n_heads:
        out = out * (jnp.arange(H) < cfg.n_heads)[None, None, :, None]
    return out.reshape(B, S, H * hd) @ p["wo"]


# ---------------------------------------------------------------- MLA ----

class MLACache(NamedTuple):
    c_kv: jax.Array    # (B, Smax, kv_lora)  compressed latent
    k_rope: jax.Array  # (B, Smax, rope_dim) shared positional key


def _mla_qkv(p, h, cfg, cos, sin):
    """Expanded-form MLA projections (train / prefill)."""
    from repro.models.common import rms_norm
    B, S, _ = h.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    cq = rms_norm(h @ p["wq_a"], p["q_norm"], cfg.norm_eps)  # (B,S,q_lora)
    q = (cq @ p["wq_b"]).reshape(B, S, H, dn + dr)
    ckv_full = h @ p["wkv_a"]                           # (B,S,kv_lora+dr)
    c_kv, k_rope = ckv_full[..., :cfg.kv_lora_rank], ckv_full[..., cfg.kv_lora_rank:]
    c_kv = rms_norm(c_kv, p["kv_norm"], cfg.norm_eps)
    kv = (c_kv @ p["wkv_b"]).reshape(B, S, H, dn + dv)
    k_nope, v = kv[..., :dn], kv[..., dn:]
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)  # 1 shared head
    k_rope_b = jnp.broadcast_to(k_rope, (B, S, H, dr))
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    return q_full, k_full, v, c_kv, k_rope[:, :, 0, :]


def mla_block(p, h, cfg, cos, sin, *, cache: MLACache | None = None, pos=None):
    """DeepSeek-V3 Multi-head Latent Attention sublayer.

    Decode uses the *absorbed* form: scores and context are computed in the
    compressed kv_lora space directly against the latent cache, so the
    per-token cache cost is kv_lora + rope_dim (576 for DSv3), not
    2 * H * hd — MLA's entire point.
    """
    B, S, D = h.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    if cache is None:
        q, k, v, _, _ = _mla_qkv(p, h, cfg, cos, sin)
        q = sharding.hint(q, "dp", None, "model", None)
        k = sharding.hint(k, "dp", None, "model", None)
        out = attention(q, k, v, causal=True, chunk=cfg.attn_chunk)
        return out.reshape(B, S, H * dv) @ p["wo"], None

    # ---- absorbed decode path ----
    from repro.models.common import rms_norm
    cq = rms_norm(h @ p["wq_a"], p["q_norm"], cfg.norm_eps)
    q = (cq @ p["wq_b"]).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, cos, sin)
    ckv_full = h @ p["wkv_a"]
    c_new, kr_new = ckv_full[..., :cfg.kv_lora_rank], ckv_full[..., cfg.kv_lora_rank:]
    c_new = rms_norm(c_new, p["kv_norm"], cfg.norm_eps)
    kr_new = apply_rope(kr_new[:, :, None, :], cos, sin)[:, :, 0, :]
    c_kv = lax.dynamic_update_slice(cache.c_kv, c_new.astype(cache.c_kv.dtype),
                                    (0, pos, 0))
    k_rope = lax.dynamic_update_slice(cache.k_rope, kr_new.astype(cache.k_rope.dtype),
                                      (0, pos, 0))
    new_cache = MLACache(c_kv, k_rope)

    wkv_b = p["wkv_b"].reshape(cfg.kv_lora_rank, H, dn + dv)
    w_uk, w_uv = wkv_b[..., :dn], wkv_b[..., dn:]
    # absorb W_uk into q: (B,1,H,dn) x (l,H,dn) -> (B,1,H,l)
    q_c = jnp.einsum("bshn,lhn->bshl", q_nope, w_uk)
    scale = (dn + dr) ** -0.5
    s = (jnp.einsum("bshl,bkl->bhsk", q_c, c_kv,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bshr,bkr->bhsk", q_rope, k_rope,
                      preferred_element_type=jnp.float32)) * scale
    kv_idx = jnp.arange(c_kv.shape[1])
    qpos = pos + jnp.arange(S)                 # per-query absolute position
    s = jnp.where(kv_idx[None, None, None, :] <= qpos[None, None, :, None],
                  s, _NEG)
    pr = jax.nn.softmax(s, axis=-1).astype(c_kv.dtype)
    ctx_c = jnp.einsum("bhsk,bkl->bshl", pr, c_kv)       # context in latent space
    out = jnp.einsum("bshl,lhv->bshv", ctx_c, w_uv)      # absorb W_uv
    return out.reshape(B, S, H * dv) @ p["wo"], new_cache
