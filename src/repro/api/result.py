"""The uniform result types every VAT rung returns.

``TendencyResult`` is the one shape the whole public API speaks: every
rung — vat, ivat, svat, bigvat, dvat, and the batched paths — returns it,
so downstream code (and third-party extensions like a ConiVAT-style
constrained rung) reads ``result.order`` / ``result.image()`` without
knowing which method produced it.  It is an immutable dataclass
registered as a JAX pytree (arrays are leaves, ``meta`` is static aux
data), so it moves through ``jax.block_until_ready``, ``jax.device_get``
and friends like any other pytree.

``ResultMeta`` is the single seed source: every sampling path — JAX-side
(maximin starts, Hopkins probes) and host-side (the Hopkins subsample's
numpy rng) — derives from ``meta.seed`` through ``jax_key(salt)`` /
``host_rng(salt)``, which makes a fit reproducible from its meta alone.

``TendencyReport`` is ``assess()``'s stable shape: the same keys whether
the fit was solo or batched, with dict-like access kept for backward
compatibility.

>>> from repro.api.result import TendencyReport
>>> rep = TendencyReport(method="vat", metric="euclidean", n=100,
...                      hopkins=0.9, block_score=0.8, k_est=3,
...                      clustered=True)
>>> rep["k_est"], rep.k_est            # dict-like and attribute access
(3, 3)
>>> sorted(rep.keys())[:3]
['batch_index', 'block_score', 'clustered']
>>> dict(rep)["batch_index"] is None   # solo fit: key present, value None
True
"""
from __future__ import annotations

import dataclasses
import math
from collections.abc import Mapping
from typing import Any, ClassVar

import numpy as np

import jax

from repro.core.approx_mst import ApproxStats
from repro.core.bigvat import expand_image
from repro.core.ivat import ivat_from_vat
from repro.numerics.condition import NumericsReport

# Salts for deriving independent streams from the one seed on ResultMeta.
# Fit-time sampling (maximin starts), assessment (Hopkins probe keys) and
# the host-side Hopkins subsample each get their own stream so no two
# consumers of the seed are correlated.
SALT_FIT = 0
SALT_ASSESS = 1
SALT_HOPKINS = 2


@dataclasses.dataclass(frozen=True)
class ResultMeta:
    """Static metadata of a fit — the pytree aux data of ``TendencyResult``.

    Attributes:
      method: resolved rung name, e.g. "svat".
      metric: dissimilarity metric the fit used ("precomputed" means the
        caller handed the matrix in).
      n: points per dataset.
      batch: batch size after ``fit_many``; None for a solo fit.
      seed: the single seed every sampling path derives from.
      sample_size: s for the sampling rungs; None where unused.
      use_pallas: whether Pallas kernels were requested.
      approx: the approx rung's error report (``core.ApproxStats`` — a
        frozen, hashable dataclass, so meta stays valid pytree aux
        data); None for every exact rung.
      encoder: fingerprint of the encoder that produced the fitted
        activations (the "embed" front-end rung / ``fit_embeddings``);
        None when the fit ran on raw input points.
      numerics: the numerics shield's plan for this fit
        (``numerics.NumericsReport`` — frozen and hashable, so meta
        stays valid pytree aux data): condition estimate κ, policy
        mode, tile form, storage dtype, whether the conditioning
        transform ran, and counted fallbacks.  None for fits that
        bypass the pre-pass (precomputed input, ``from_result``).
    """

    method: str
    metric: str = "euclidean"
    n: int = 0
    batch: int | None = None
    seed: int = 0
    sample_size: int | None = None
    use_pallas: bool = False
    approx: ApproxStats | None = None
    encoder: str | None = None
    numerics: NumericsReport | None = None

    def jax_key(self, salt: int = SALT_FIT) -> jax.Array:
        """PRNG key for device-side sampling, derived from the one seed."""
        return jax.random.fold_in(jax.random.PRNGKey(self.seed), salt)

    def host_rng(self, salt: int = SALT_FIT) -> np.random.Generator:
        """numpy Generator for host-side sampling, same seed source.

        Uses ``SeedSequence([seed, salt])`` so the host stream is
        deterministic in (seed, salt) exactly like ``jax_key`` — the two
        samplers differ in backend, never in provenance.
        """
        return np.random.default_rng(np.random.SeedSequence([self.seed, salt]))


@dataclasses.dataclass(frozen=True)
class TendencyResult:
    """What every rung returns: ordering + images + extension, one shape.

    Attributes:
      order: (n,) int32 VAT ordering — all points for vat/ivat/bigvat/
        dvat, the s sample points for svat; (b, n) after a batched fit.
      rstar: reordered dissimilarity image — (n, n) for the exact rungs,
        (s, s) sample image for svat/bigvat/dvat, (b, n, n) batched.
      ivat_image: geodesic (iVAT) image where the rung computed one
        (ivat, bigvat), else None; ``image(use_ivat=True)`` derives it on
        demand from ``rstar`` when absent.
      sample_idx: dataset rows of the maximin prototypes (svat/bigvat/
        dvat), else None.
      extension_labels: (n,) nearest-prototype id per point (bigvat's
        full-data extension), else None.
      meta: static fit metadata (method, metric, n, batch, seed, ...).
      group_sizes: (s,) per-prototype group counts in sample-VAT order
        (bigvat — drives the smoothed rendering), else None.

    Registered as a JAX pytree: array fields are children, ``meta`` is
    aux data, so the whole result works with ``jax.block_until_ready``
    and other tree utilities.
    """

    order: jax.Array
    rstar: jax.Array
    ivat_image: jax.Array | None
    sample_idx: jax.Array | None
    extension_labels: jax.Array | None
    meta: ResultMeta
    group_sizes: jax.Array | None = None

    _CHILDREN: ClassVar[tuple[str, ...]] = (
        "order", "rstar", "ivat_image", "sample_idx", "extension_labels",
        "group_sizes")

    @property
    def n(self) -> int:
        return self.meta.n

    @property
    def is_batched(self) -> bool:
        return self.meta.batch is not None

    def image(self, *, resolution: int = 256,
              use_ivat: bool | None = None) -> np.ndarray:
        """The reordered dissimilarity image (the thing you look at).

        Data-driven, no per-method branching: the geodesic image is used
        when one was computed (``use_ivat=None``) or demanded
        (``use_ivat=True`` — derived on demand from ``rstar`` if the rung
        didn't build one); ``use_ivat=False`` forces the plain reordered
        dissimilarities.  Results carrying ``group_sizes`` (the bigvat
        extension) are expanded to ``resolution`` pixels by group size;
        everything else returns the image at its native size.
        """
        want_ivat = (self.ivat_image is not None if use_ivat is None
                     else bool(use_ivat))
        if want_ivat:
            base = (self.ivat_image if self.ivat_image is not None
                    else ivat_from_vat(self.rstar,
                                       use_pallas=self.meta.use_pallas))
        else:
            base = self.rstar
        if self.group_sizes is not None:
            return expand_image(base, self.group_sizes, resolution)
        return np.asarray(base)


def _result_flatten(res: TendencyResult):
    return tuple(getattr(res, f) for f in TendencyResult._CHILDREN), res.meta


def _result_unflatten(meta: ResultMeta, children) -> TendencyResult:
    return TendencyResult(**dict(zip(TendencyResult._CHILDREN, children)),
                          meta=meta)


jax.tree_util.register_pytree_node(
    TendencyResult, _result_flatten, _result_unflatten)


@dataclasses.dataclass(frozen=True, eq=False)
class TendencyReport(Mapping):
    """``assess()``'s stable shape — identical keys solo and batched.

    A frozen dataclass that also satisfies the Mapping protocol, so the
    pre-redesign dict idioms (``rep["k_est"]``, ``dict(rep)``,
    ``rep.get("hopkins")``) keep working.  Equality treats NaN hopkins
    values (the precomputed-metric case) as equal, so "same fit, same
    report" holds for every metric.

    Attributes:
      method: resolved rung name.
      metric: dissimilarity metric of the fit.
      n: points per dataset.
      hopkins: Hopkins statistic (H > 0.75 => significant structure);
        NaN when metric="precomputed" (no point coordinates to probe).
      block_score: [0, 1] diagonal-block contrast of the VAT image.
      k_est: estimated cluster count from super-diagonal cuts.
      clustered: the combined verdict (hopkins and block_score bars;
        block_score alone when hopkins is NaN).
      batch_index: dataset index after ``fit_many``; None for solo fits.
    """

    method: str
    metric: str
    n: int
    hopkins: float
    block_score: float
    k_est: int
    clustered: bool
    batch_index: int | None = None

    _KEYS: ClassVar[tuple[str, ...]] = (
        "method", "metric", "n", "hopkins", "block_score", "k_est",
        "clustered", "batch_index")

    def __getitem__(self, key: str) -> Any:
        if key in self._KEYS:
            return getattr(self, key)
        raise KeyError(key)

    def __iter__(self):
        return iter(self._KEYS)

    def __len__(self) -> int:
        return len(self._KEYS)

    def __eq__(self, other):
        if not isinstance(other, TendencyReport):
            return NotImplemented
        return all(_field_eq(getattr(self, k), getattr(other, k))
                   for k in self._KEYS)

    def as_dict(self) -> dict:
        """Plain-dict copy (e.g. for json.dumps)."""
        return {k: getattr(self, k) for k in self._KEYS}


def _field_eq(a, b) -> bool:
    """Equality where NaN == NaN (hopkins is NaN for precomputed fits)."""
    if isinstance(a, float) and isinstance(b, float) \
            and math.isnan(a) and math.isnan(b):
        return True
    return a == b
