"""FastVAT — one front door for every VAT variant in this repo.

Picks the right scaling rung automatically (see ``docs/scaling.md``):

  n <= SMALL_N  (2_048)   exact ``vat``   — O(n^2) matrix fits easily
  n <= MEDIUM_N (50_000)  ``flashvat``    — exact, matrix-free, O(n·d),
                          Turbo persistent engine (auto-sharded on a
                          multi-device mesh)
  larger                  ``approx``      — kNN-graph Borůvka MST VAT,
                          O(n·k) edges, the million-point rung (the
                          ``knn_k`` knob trades error for speed)

``method`` overrides come from the rung registry (``repro.api.registry``)
— "vat" | "ivat" | "svat" | "flashvat" | "bigvat" | "approx" | "dvat" |
"embed" plus anything third-party code registered.  Every rung returns
the same ``TendencyResult`` pytree, so ``order()`` / ``image()`` /
``assess()`` below are branch-free reads.

Deep embeddings (DeepVAT): ``fit(X, encoder=fn)`` runs the ladder on
``fn(X)`` activations instead of raw inputs, and
``fit_embeddings(params, cfg, batch)`` does the same for a model from
the repo zoo — see docs/monitoring.md.

>>> import numpy as np
>>> rng = np.random.default_rng(0)
>>> X = np.concatenate([rng.normal(size=(30, 3)),
...                     rng.normal(size=(30, 3)) + 8]).astype(np.float32)
>>> fv = FastVAT().fit(X)                # auto-selects by n
>>> fv.method_resolved
'vat'
>>> fv.image().shape
(60, 60)
>>> rep = fv.assess()                    # TendencyReport, dict-like
>>> (rep["method"], rep["k_est"], rep["clustered"])
('vat', 2, True)

Any pairwise dissimilarity works — computed (``metric=``) or handed in
directly (``metric="precomputed"``):

>>> from repro.kernels import ops as kops
>>> D = np.asarray(kops.pairwise_dist(X))           # any (n, n) matrix
>>> fd = FastVAT(metric="precomputed").fit(D)
>>> bool(np.array_equal(fd.order(), fv.order()))
True

Batched: a (b, n, d) stack of datasets is assessed in one compiled
program (see ``docs/api.md``):

>>> Xs = np.stack([X, X[::-1]])
>>> fb = FastVAT(method="ivat").fit_many(Xs)
>>> fb.image().shape
(2, 60, 60)
>>> [r["batch_index"] for r in fb.assess()]
[0, 1]
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro import core
from repro.api import registry
from repro.api.metrics import as_dissimilarity, validate_metric
from repro.api.registry import SMALL_N, RungOptions, select_method
from repro.api.validation import (InvalidInput, validate_dissimilarity,
                                  validate_points)
from repro.api.result import (SALT_ASSESS, SALT_HOPKINS, ResultMeta,
                              TendencyReport, TendencyResult)
from repro.core.bigvat import DEFAULT_BLOCK
from repro.numerics import NumericsReport, as_policy
from repro.numerics import resolve as resolve_numerics

#: Method names at import time ("auto" + built-in rungs). The live list —
#: including later third-party registrations — is ``registry.methods()``.
METHODS = registry.methods()


class FastVAT:
    """Facade over the registered rungs with auto-selection.

    Parameters
    ----------
    method:       "auto" or any name in ``registry.methods()``; "auto"
                  picks by n at fit time.
    metric:       dissimilarity metric — one of ``repro.api.METRICS``:
                  "euclidean" | "sqeuclidean" | "manhattan" | "cosine",
                  or "precomputed" to pass ``fit`` an (n, n) matrix
                  directly (exact rungs only).
    sample_size:  s for svat/bigvat prototypes and flashvat's rendered
                  representative count.
    block:        row-block size of bigvat's tiled assignment pass.
    use_pallas:   route distance/iVAT work through the Pallas kernels
                  (interpret mode on CPU; compiled on TPU).
    turbo:        flashvat traversal engine — None (default) auto-selects
                  the persistent Turbo engine (and the sharded engine on
                  a multi-device mesh); True forces the solo persistent
                  engine (opting out of auto-sharding); False pins the
                  stepwise engine.  Orderings are identical either way;
                  only the wall clock moves.
    knn_k:        the approx rung's error-bound knob — neighbours per
                  point in its kNN graph.  Larger k costs linearly more
                  and drives the kNN-MST weight monotonically down to
                  the exact MST weight (reached at k = n-1); the fit's
                  ``ResultMeta.approx`` reports the realized error
                  model (components repaired, repair weight).
    seed:         the single seed every sampling path (device and host
                  side) derives from — see ``ResultMeta``.
    validate:     admission-check inputs before they reach a kernel
                  (one O(n·d) pass: finite values, real dtype, n >= 4,
                  non-degenerate, no zero-norm rows under cosine) and
                  fail with the typed ``InvalidInput`` — the kernels'
                  min/argmin folds are silent on NaN/Inf and would
                  return garbage orderings otherwise.  ``False`` skips
                  the pass for trusted hot loops.
    numerics:     the numerics shield's policy — a
                  ``repro.numerics.NumericsPolicy`` or a mode string
                  ("fast" | "safe" | "auto", default "auto").  Before
                  dispatch, ``numerics.resolve`` estimates the Gram
                  -cancellation condition κ and picks the tile form
                  (Gram vs direct) plus the conditioning transform;
                  what actually ran lands on
                  ``result.meta.numerics`` (a ``NumericsReport``).
                  Precomputed and np.memmap input bypass the pre-pass
                  (no point coordinates / out-of-core respectively).
    """

    def __init__(self, method: str = "auto", *, metric: str = "euclidean",
                 sample_size: int = 256, block: int = DEFAULT_BLOCK,
                 use_pallas: bool = False, turbo: bool | None = None,
                 knn_k: int = 15, seed: int = 0, validate: bool = True,
                 numerics="auto"):
        methods = registry.methods()
        if method not in methods:
            raise ValueError(f"method must be one of {methods}, "
                             f"got {method!r}")
        validate_metric(metric)
        self.method = method
        self.metric = metric
        self.sample_size = sample_size
        self.block = block
        self.use_pallas = use_pallas
        self.turbo = turbo
        self.knn_k = knn_k
        self.seed = seed
        self.validate = validate
        self.numerics = as_policy(numerics)
        self.method_resolved: str | None = None
        self.result: TendencyResult | None = None
        self._X = None

    @property
    def batched(self) -> bool:
        """True after ``fit_many`` (the result carries a batch axis)."""
        return self.result is not None and self.result.is_batched

    @classmethod
    def from_result(cls, result: TendencyResult, X=None) -> "FastVAT":
        """Adopt an externally produced fit (e.g. a served one).

        The serving layer (``repro.serve``) returns bare
        ``TendencyResult`` pytrees; wrapping one here restores the full
        facade surface — ``order()`` / ``image()`` / ``assess()`` —
        configured from the result's own meta, so a served fit assesses
        identically to the solo ``FastVAT(...).fit(X)`` it mirrors.

        Args:
          result: a fit result from any rung (solo or batched).
          X: the original dataset(s); required for ``assess()`` on
            non-precomputed metrics (the Hopkins probe needs points).

        Returns:
          A fitted facade (``fit`` was effectively already called).
        """
        m = result.meta
        fv = cls(method=m.method, metric=m.metric,
                 sample_size=(m.sample_size if m.sample_size is not None
                              else 256),
                 use_pallas=m.use_pallas, seed=m.seed)
        fv.result = result
        fv.method_resolved = m.method
        fv._X = None if X is None else np.asarray(X)
        return fv

    def _meta(self, method: str, n: int, batch: int | None,
              numerics: NumericsReport | None = None) -> ResultMeta:
        return ResultMeta(method=method, metric=self.metric, n=n,
                          batch=batch, seed=self.seed,
                          sample_size=self.sample_size,
                          use_pallas=self.use_pallas, numerics=numerics)

    def _options(self, num_form: str = "gram") -> RungOptions:
        return RungOptions(sample_size=self.sample_size, block=self.block,
                           turbo=self.turbo, knn_k=self.knn_k,
                           num_form=num_form)

    def _numerics_prepass(self, X, *, batched: bool = False):
        """Run the numerics shield on point input; (X', report | None).

        np.memmap input is passed through untouched — the conditioning
        transform would materialize an O(n·d) RAM copy and defeat the
        bigvat rung's out-of-core contract.
        """
        if isinstance(X, np.memmap):
            return X, None
        return resolve_numerics(X, metric=self.metric,
                                policy=self.numerics, batched=batched)

    # ------------------------------------------------------------- fit ----

    def fit(self, X, *, encoder=None) -> "FastVAT":
        """Run the resolved rung on one dataset.

        Args:
          X: (n, d) array-like of points (np.memmap ok for bigvat), or —
            with ``metric="precomputed"`` — an (n, n) dissimilarity
            matrix (square, symmetric, zero diagonal).
          encoder: route through the "embed" front-end rung
            (DeepVAT-style).  A callable maps X to an (n, d) activation
            matrix (any leading shape; flattened to rows) which the
            ladder then assesses; a string means X is *already* the
            activation matrix and the string is its encoder fingerprint.
            Either way ``result.meta.encoder`` records provenance and
            the inner rung is auto-selected by activation count.

        Returns:
          self; ``self.result`` is the rung's ``TendencyResult``.
        """
        if encoder is not None:
            return self._fit_embed_front(X, encoder)
        precomputed = self.metric == "precomputed"
        if precomputed:
            if self.validate:
                validate_dissimilarity(X)
            X = as_dissimilarity(X)
        elif self.validate and self.method != "embed":
            # the embed rung validates its *activations* (see
            # _fit_embed_front); raw fit(X) without an encoder is the
            # rung's own "encoder required" error, not an admission case
            validate_points(X, metric=self.metric)
        num_report = None
        if not precomputed and self.method != "embed":
            X, num_report = self._numerics_prepass(X)
        n = int(X.shape[0])
        method = (self.method if self.method != "auto"
                  else select_method(n, precomputed=precomputed))
        rung = registry.get_rung(method)
        if precomputed and not rung.supports_precomputed:
            ok = [r for r in registry.registered()
                  if registry.get_rung(r).supports_precomputed]
            raise ValueError(f"method {method!r} does not accept "
                             f"metric='precomputed'; rungs that do: {ok}")
        if rung.max_n is not None and n > rung.max_n:
            raise ValueError(f"method {method!r} caps at n={rung.max_n}, "
                             f"got n={n}")
        if rung.check is not None:
            rung.check(n)
        meta = self._meta(method, n, batch=None, numerics=num_report)
        self.result = rung.fit(X, meta, self._options(
            num_report.form if num_report is not None else "gram"))
        self.method_resolved = method
        self._X = X
        return self

    def _fit_embed_front(self, X, encoder) -> "FastVAT":
        """fit(X, encoder=...) tail: encode, then run the embed rung.

        Encoding happens here (not inside the rung fitter) so the
        activations become ``self._X`` — ``assess()``'s Hopkins probe
        then reads the embedding space the fit actually assessed, the
        DeepVAT semantics.
        """
        from repro.monitor.probes import callable_fingerprint
        if self.metric == "precomputed":
            raise ValueError("encoder= assesses activations; it is "
                             "incompatible with metric='precomputed'")
        if self.method not in ("auto", "embed"):
            raise ValueError("encoder= routes through the 'embed' rung; "
                             "method must be 'auto' or 'embed', got "
                             f"{self.method!r}")
        if callable(encoder):
            acts = np.asarray(jax.device_get(encoder(X)), np.float32)
            fingerprint = callable_fingerprint(encoder)
        else:
            acts = np.asarray(X, np.float32)
            fingerprint = str(encoder)
        if acts.ndim > 2:
            acts = acts.reshape(-1, acts.shape[-1])
        if self.validate:
            validate_points(acts, name="activations", metric=self.metric)
        acts, num_report = self._numerics_prepass(acts)
        n = int(acts.shape[0])
        meta = dataclasses.replace(
            self._meta("embed", n, batch=None, numerics=num_report),
            encoder=fingerprint)
        self.result = registry.get_rung("embed").fit(
            acts, meta,
            self._options(num_report.form if num_report is not None
                          else "gram"))
        self.method_resolved = "embed"
        self._X = acts
        return self

    def fit_embeddings(self, params, cfg, batch) -> "FastVAT":
        """Assess the cluster tendency of a model's activations.

        The DeepVAT workflow for the repo's model zoo: run one forward
        pass, flatten the final hidden states to (batch*seq, d_model)
        rows, and route them through the "embed" rung (which delegates
        to the exact/approx ladder by activation count).  The model's
        fingerprint — architecture identity + a weights digest — lands
        on ``result.meta.encoder``.

        Args:
          params: model parameter pytree (``models.model.init_params``).
          cfg: the ``ModelConfig`` matching params.
          batch: input batch dict (``data.tokens.make_batch``) — tokens
            plus any family extras (patches, enc_frames).

        Returns:
          self; ``self.result`` is a standard ``TendencyResult``.
        """
        from repro.monitor.probes import encode_batch, model_fingerprint
        acts = np.asarray(jax.device_get(encode_batch(params, cfg, batch)),
                          np.float32)
        return self.fit(acts, encoder=model_fingerprint(cfg, params))

    def fit_many(self, Xs) -> "FastVAT":
        """Assess a stack of datasets in ONE compiled program.

        Args:
          Xs: (b, n, d) array-like — b independent datasets of n points
            each (pad or truncate to a common n first; a Python list of
            equal-shape (n, d) arrays also works). With
            ``metric="precomputed"``: a (b, n, n) dissimilarity stack.

        Returns:
          self. ``order()`` then yields (b, n), ``image()`` (b, n, n),
          and ``assess()`` a list of b per-dataset reports.

        Only rungs with a batched fitter batch (built-ins: "vat",
        "ivat", "flashvat"; "auto" resolves among them and refuses n
        past the largest batch-capable threshold). Each dataset's
        ordering is bitwise-identical to a solo ``fit`` — the batch is a
        vmap / batched Pallas grid, never an approximation. For larger n,
        loop ``fit()`` per dataset instead (svat/bigvat don't vectorize
        over datasets yet).
        """
        precomputed = self.metric == "precomputed"
        num_report = None
        if precomputed:
            if self.validate:
                validate_dissimilarity(Xs)
            Xs = as_dissimilarity(Xs, batched=True)
        else:
            if self.validate:
                validate_points(Xs, batched=True, metric=self.metric)
            Xs = np.asarray(Xs, np.float32)
            if Xs.ndim != 3:
                raise ValueError(f"fit_many wants a (b, n, d) stack, got "
                                 f"shape {Xs.shape}")
            Xs, num_report = self._numerics_prepass(Xs, batched=True)
            Xs = jnp.asarray(Xs)
        b, n = int(Xs.shape[0]), int(Xs.shape[1])
        method = self.method
        if method == "auto":
            try:
                # precomputed input may exceed the exact rung's threshold:
                # the O(n^2) matrix already exists, so fall back to it
                method = select_method(n, precomputed=precomputed,
                                       batched=True, strict=not precomputed)
            except LookupError:
                cap = max((r.auto_threshold for r in
                           map(registry.get_rung, registry.registered())
                           if r.supports_batch and
                           r.auto_threshold is not None), default=SMALL_N)
                raise ValueError(
                    f"fit_many batches the exact rungs only (n <= {cap}),"
                    f" got per-dataset n={n}; loop fit() per dataset for"
                    " the svat/bigvat rungs") from None
        rung = registry.get_rung(method)
        if not rung.supports_batch:
            batchable = [r for r in registry.registered()
                         if registry.get_rung(r).supports_batch]
            raise ValueError(
                f"fit_many supports methods with a batched fitter "
                f"({batchable} or 'auto'), got {self.method!r}")
        if precomputed and not rung.supports_precomputed:
            raise ValueError(f"method {method!r} does not accept "
                             "metric='precomputed'")
        if rung.max_n is not None and n > rung.max_n:
            raise ValueError(f"method {method!r} caps at n={rung.max_n}, "
                             f"got n={n}")
        if rung.check is not None:
            rung.check(n)
        meta = self._meta(method, n, batch=b, numerics=num_report)
        self.result = rung.fit_batch(Xs, meta, self._options(
            num_report.form if num_report is not None else "gram"))
        self.method_resolved = method
        self._X = np.asarray(Xs)
        return self

    # --------------------------------------------------------- queries ----
    # All branch-free: they read the uniform TendencyResult fields.

    def _require_fit(self) -> TendencyResult:
        if self.result is None:
            raise RuntimeError("call fit(X) first")
        return self.result

    def order(self) -> np.ndarray:
        """VAT ordering: all n points (vat/ivat/bigvat/dvat) or the sample
        (svat — use sample_indices() to map back to dataset rows).
        After ``fit_many`` the result is a (b, n) stack of orderings."""
        return np.asarray(self._require_fit().order)

    def sample_indices(self) -> np.ndarray | None:
        """Dataset rows of the prototypes (svat/bigvat/dvat), else None."""
        idx = self._require_fit().sample_idx
        return None if idx is None else np.asarray(idx)

    def image(self, *, resolution: int = 256,
              use_ivat: bool | None = None) -> np.ndarray:
        """The reordered dissimilarity image (the thing you look at).

        Delegates to ``TendencyResult.image``: the geodesic (iVAT) image
        is used wherever one was computed (``use_ivat=None``) or demanded
        (``use_ivat=True``, derived on demand otherwise); results with a
        full-data extension (bigvat) are expanded to ``resolution``
        pixels by group size.  After ``fit_many`` the result carries a
        leading batch axis: (b, n, n).
        """
        return self._require_fit().image(resolution=resolution,
                                         use_ivat=use_ivat)

    def _hopkins_subsample(self, X, meta: ResultMeta,
                           cap: int = 2_048) -> np.ndarray:
        """Uniform random rows of X for the Hopkins statistic.

        Maximin prototypes are deliberately spread out, which biases
        Hopkins toward 0.5 — so the svat/bigvat rungs must not reuse them
        here.  Row indexing (sorted) keeps np.memmap inputs out-of-core.
        The rng derives from the fit's single seed source
        (``meta.host_rng``), so reports are repeatable per seed.
        """
        n = X.shape[0]
        if n <= cap:
            idx = np.arange(n)
        else:
            idx = np.sort(meta.host_rng(SALT_HOPKINS).choice(
                n, cap, replace=False))
        return np.asarray(X[idx], np.float32)

    def _assess_one(self, rstar, X, key, meta: ResultMeta,
                    batch_index: int | None) -> TendencyReport:
        """Score one (rstar, X) pair: Hopkins + block structure."""
        score, k_est = core.block_structure_score(rstar)
        if meta.metric == "precomputed":
            # no point coordinates to probe — Hopkins is undefined
            h, clustered = float("nan"), bool(float(score) > 0.3)
        else:
            Xh = self._hopkins_subsample(X, meta)
            h = float(core.hopkins(jnp.asarray(Xh), key))
            clustered = bool(h > 0.75 and float(score) > 0.3)
        return TendencyReport(method=meta.method, metric=meta.metric,
                              n=meta.n, hopkins=h,
                              block_score=float(score), k_est=int(k_est),
                              clustered=clustered, batch_index=batch_index)

    def assess(self, key: jax.Array | None = None):
        """Machine-checkable tendency report: Hopkins + block structure.

        Returns one ``TendencyReport`` after ``fit`` and a list of b of
        them after ``fit_many`` — the same keys either way (dict-like
        access included; ``batch_index`` is None for solo fits).
        """
        res = self._require_fit()
        meta = res.meta
        if key is None:
            key = meta.jax_key(SALT_ASSESS)
        if meta.batch is not None:
            keys = jax.random.split(key, meta.batch)
            return [
                self._assess_one(res.rstar[i], self._X[i], keys[i], meta, i)
                for i in range(meta.batch)
            ]
        return self._assess_one(res.rstar, self._X, key, meta, None)


def assess_tendency(X, **kwargs) -> TendencyReport:
    """One-shot convenience: FastVAT(**kwargs).fit(X).assess()."""
    return FastVAT(**kwargs).fit(X).assess()
