"""Input admission for the public fit surfaces (ISSUE 9, ladder rung 3).

The kernels' min/argmin folds are silent on non-finite input — a single
NaN row propagates through the Prim frontier and produces a garbage
ordering with no error — and a coalesced serving batch would carry that
garbage into every lane's program.  Admission therefore happens at the
*edges* (``FastVAT.fit``/``fit_many`` and ``TendencyServer.submit``),
before a bad request can reach a kernel or a batch, and it fails with
one typed error:

:class:`InvalidInput` subclasses ``ValueError``, so pre-existing
callers catching ``ValueError`` keep working, while the serving layer
can count admission rejects separately from scheduling errors.

Checks (all O(n·d), one vectorized pass — skippable via
``FastVAT(validate=False)`` for trusted hot loops):

  * dtype is real-numeric (bool/int/float; complex, strings and object
    arrays are rejected rather than silently cast),
  * every value is finite (no NaN / +-Inf),
  * n >= ``MIN_POINTS`` (a VAT ordering of fewer points is degenerate),
  * the points are not all identical (zero variance — every pairwise
    dissimilarity is 0 and the "ordering" is meaningless),
  * under ``metric="cosine"``: no zero-norm rows — the kernels' eps
    -guard silently maps them to distance 1.0 from everything, which
    is a fabricated geometry, not the caller's data.  Skipping
    validation (``validate=False``) keeps the documented eps-guard
    semantics for callers who want exactly that.
"""
from __future__ import annotations

import numpy as np

#: Smallest point count a tendency assessment is defined for.
MIN_POINTS = 4


class InvalidInput(ValueError):
    """A request/dataset was rejected at admission (never reached a
    kernel or a serving batch).  ``reason`` is a stable machine-readable
    tag: "dtype" | "non_finite" | "too_few_points" | "degenerate" |
    "zero_norm"."""

    def __init__(self, reason: str, message: str):
        self.reason = reason
        super().__init__(message)


def _as_real_array(X, name: str) -> np.ndarray:
    arr = np.asarray(X)
    if arr.dtype == object or arr.dtype.kind not in "bifu":
        raise InvalidInput(
            "dtype", f"{name} must be a real numeric array, got dtype "
            f"{arr.dtype}")
    return arr


def validate_points(X, *, batched: bool = False, name: str = "X",
                    metric: str | None = None) -> None:
    """Admission-check an (n, d) point matrix (or (b, n, d) stack).

    Args:
      X: the candidate points.
      batched: expect a (b, n, d) stack instead of (n, d).
      name: how to refer to X in error messages.
      metric: the metric the fit will run, when known — enables
        metric-specific checks (currently: cosine's zero-norm screen).

    Raises:
      InvalidInput: non-numeric dtype, non-finite values, n below
        ``MIN_POINTS``, an all-identical (zero-variance) dataset, or a
        zero-norm row under ``metric="cosine"``.  Batched input names
        the offending lane in the message.
    """
    arr = _as_real_array(X, name)
    want = 3 if batched else 2
    if arr.ndim != want:
        # shape errors stay plain ValueErrors at the callers; admission
        # only guards value-level poison.  Tolerate and let them handle.
        return
    n_axis = 1 if batched else 0
    n = arr.shape[n_axis]
    if n < MIN_POINTS:
        raise InvalidInput(
            "too_few_points",
            f"{name} has n={n} points; a tendency assessment needs at "
            f"least {MIN_POINTS}")
    if arr.dtype.kind == "f" and not bool(np.isfinite(arr).all()):
        if batched:
            bad = np.flatnonzero(
                ~np.isfinite(arr).all(axis=(1, 2)))
            where = f" (lane(s) {bad.tolist()})"
        else:
            where = ""
        raise InvalidInput(
            "non_finite",
            f"{name} contains non-finite values (NaN/Inf){where}; clean "
            "the data or pass validate=False to skip admission checks")
    spread = np.ptp(arr, axis=n_axis)
    if batched:
        dead = np.flatnonzero(~(spread.max(axis=-1) > 0))
        if dead.size:
            raise InvalidInput(
                "degenerate",
                f"{name} lane(s) {dead.tolist()} have zero variance "
                "(all points identical) — tendency is undefined")
    elif not bool(spread.max() > 0):
        raise InvalidInput(
            "degenerate",
            f"{name} has zero variance (all {n} points identical) — "
            "tendency is undefined")
    if metric == "cosine":
        norms = np.einsum("...nd,...nd->...n", np.asarray(arr, np.float64),
                          np.asarray(arr, np.float64))
        zero = norms == 0.0
        if bool(zero.any()):
            if batched:
                lanes = np.flatnonzero(zero.any(axis=-1))
                where = f" (lane(s) {lanes.tolist()})"
            else:
                where = f" (row(s) {np.flatnonzero(zero).tolist()})"
            raise InvalidInput(
                "zero_norm",
                f"{name} has zero-norm rows{where}; cosine dissimilarity "
                "is undefined for them (the kernels' eps-guard would "
                "silently map them to distance 1.0 from everything) — "
                "drop the rows or pass validate=False to keep the "
                "eps-guard semantics")


def validate_dissimilarity(D, *, name: str = "D") -> None:
    """Admission-check a precomputed dissimilarity (finite values only;
    shape/symmetry checks stay in ``metrics.as_dissimilarity``)."""
    arr = _as_real_array(D, name)
    if arr.dtype.kind == "f" and not bool(np.isfinite(arr).all()):
        raise InvalidInput(
            "non_finite",
            f"{name} contains non-finite dissimilarities (NaN/Inf); "
            "clean the matrix or pass validate=False")
