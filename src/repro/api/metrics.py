"""Metric names and validation for the public API.

VAT is defined on an arbitrary pairwise dissimilarity matrix; the facade
therefore accepts a ``metric=`` that is either one of the *computable*
metrics (threaded down to ``kernels/pairwise_dist`` / ``kernels/ref``) or
``"precomputed"``, in which case ``fit(D)`` takes the (n, n) matrix
directly and no kernel runs.

>>> from repro.api.metrics import METRICS, COMPUTED_METRICS
>>> "precomputed" in METRICS and "precomputed" not in COMPUTED_METRICS
True
>>> from repro.api.metrics import validate_metric
>>> validate_metric("cosine")
>>> validate_metric("hamming")   # doctest: +ELLIPSIS
Traceback (most recent call last):
    ...
ValueError: metric must be one of ...
"""
from __future__ import annotations

import numpy as np

from repro.kernels.ref import METRICS as COMPUTED_METRICS

PRECOMPUTED = "precomputed"

#: Everything ``FastVAT(metric=...)`` accepts.
METRICS = COMPUTED_METRICS + (PRECOMPUTED,)


def validate_metric(metric: str, *, allow_precomputed: bool = True):
    """Raise ValueError unless ``metric`` is an accepted name."""
    allowed = METRICS if allow_precomputed else COMPUTED_METRICS
    if metric not in allowed:
        raise ValueError(f"metric must be one of {allowed}, got {metric!r}")


def as_dissimilarity(D, *, batched: bool = False) -> np.ndarray:
    """Validate a user-supplied precomputed dissimilarity matrix.

    Args:
      D: (n, n) array-like — pairwise dissimilarities ((b, n, n) when
        ``batched``).
      batched: expect a leading batch axis.

    Returns:
      float32 numpy array of the validated matrix/stack.

    Raises:
      ValueError: wrong rank, non-square trailing axes, asymmetry beyond
        f32 tolerance, or a significantly non-zero diagonal — the VAT
        contract is a symmetric dissimilarity with zero self-distance.
    """
    D = np.asarray(D, np.float32)
    want = 3 if batched else 2
    shape_hint = "(b, n, n)" if batched else "(n, n)"
    if D.ndim != want or D.shape[-1] != D.shape[-2]:
        raise ValueError(
            f"metric='precomputed' expects a square {shape_hint} "
            f"dissimilarity matrix, got shape {D.shape}")
    scale = max(1.0, float(np.max(np.abs(D))) if D.size else 1.0)
    if not np.allclose(D, np.swapaxes(D, -1, -2), atol=1e-4 * scale):
        raise ValueError("precomputed dissimilarity matrix must be "
                         "symmetric (max |D - D.T| exceeds tolerance)")
    diag = np.diagonal(D, axis1=-2, axis2=-1)
    if D.size and float(np.max(np.abs(diag))) > 1e-4 * scale:
        raise ValueError("precomputed dissimilarity matrix must have a "
                         "zero diagonal (self-dissimilarity)")
    return D
