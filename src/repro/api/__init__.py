"""Public API package — and the compatibility shim for the old ``api.py``.

The old import spellings (``from repro.api import FastVAT,
assess_tendency, select_method, SMALL_N, MEDIUM_N, METHODS``) keep
working.  Behavior note: ``FastVAT.result`` is now always a
``TendencyResult`` — code that poked the old per-method shapes (e.g.
``fv.result[0].rstar`` after an ivat fit) reads the uniform fields
instead (``fv.result.rstar``); the migration table in ``docs/api.md``
maps every old attribute to its new home.  The module is now a package:

  facade.py    FastVAT / assess_tendency — thin, branch-free dispatch
  result.py    TendencyResult (the uniform pytree every rung returns),
               ResultMeta (single seed source), TendencyReport
  registry.py  Rung entries + capability flags; select_method; the
               extension point third-party rungs register into
  metrics.py   metric names ("euclidean" ... "precomputed") + validation

Most callers want the package root instead: ``from repro import FastVAT``.
"""
from repro.api import registry
from repro.api.facade import METHODS, FastVAT, assess_tendency
from repro.api.metrics import COMPUTED_METRICS, METRICS, validate_metric
from repro.api.registry import (FLASH_SHARD_MIN_N, MEDIUM_N, SMALL_N, Rung,
                                RungOptions, get_rung, register,
                                select_method)
from repro.api.result import (ResultMeta, TendencyReport, TendencyResult)
from repro.api.validation import (MIN_POINTS, InvalidInput,
                                  validate_dissimilarity, validate_points)
from repro.numerics import NumericsPolicy, NumericsReport

__all__ = [
    "FastVAT", "assess_tendency",
    "TendencyResult", "TendencyReport", "ResultMeta",
    "METRICS", "COMPUTED_METRICS", "validate_metric",
    "Rung", "RungOptions", "register", "get_rung", "registry",
    "select_method", "METHODS", "SMALL_N", "MEDIUM_N", "FLASH_SHARD_MIN_N",
    "InvalidInput", "MIN_POINTS", "validate_points",
    "validate_dissimilarity",
    "NumericsPolicy", "NumericsReport",
]
