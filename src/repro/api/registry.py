"""The rung registry: method names -> fitters + capability flags.

``FastVAT`` is pure data-driven dispatch over this table — it never
branches on a method name.  Each ``Rung`` entry owns:

  * ``fit`` / ``fit_batch``: adapters that run a ``repro.core`` rung and
    wrap its output into the uniform ``TendencyResult``,
  * capability flags (``supports_batch`` via ``fit_batch``,
    ``supports_precomputed``, ``max_n``, an optional ``check`` hook for
    environment requirements like dvat's device count),
  * the auto-selection threshold (``auto_threshold``; None = opt-in
    only, ``inf`` = the unbounded fallback rung).

Third-party rungs (a ConiVAT-style constrained VAT, a DeepVAT embedding
pipeline) register here and immediately work through ``FastVAT`` and
``select_method`` without touching the facade:

>>> from repro.api import registry
>>> sorted(registry.registered())
['approx', 'bigvat', 'dvat', 'embed', 'flashvat', 'ivat', 'svat', 'vat']
>>> registry.select_method(100), registry.select_method(10_000)
('vat', 'flashvat')
>>> registry.select_method(1_000_000)
'approx'
>>> registry.get_rung("bigvat").supports_batch
False
>>> registry.get_rung("vat").supports_precomputed
True
>>> registry.get_rung("flashvat").supports_precomputed  # never holds (n,n)
False
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

from repro import core
from repro.api.result import SALT_FIT, ResultMeta, TendencyResult
from repro.kernels import ops as kops

#: Auto-selection thresholds (see docs/scaling.md): materialized exact
#: VAT below SMALL_N, matrix-free exact VAT (flashvat) to MEDIUM_N, the
#: kNN-graph Boruvka approximation (approx) beyond — the million-point
#: rung.  sVAT and bigvat (the sampled approximations the exact/approx
#: ladder obsoletes in their former windows) stay registered as opt-in
#: rungs.  The Turbo persistent engine (ISSUE 5) cut flashvat's per-fit
#: wall time ~4x, so its practical ceiling rose from 20k to 50k points.
SMALL_N = 2_048
MEDIUM_N = 50_000

#: Smallest n the flashvat rung auto-shards over a multi-device mesh;
#: below it the per-step collectives cost more than they parallelize.
FLASH_SHARD_MIN_N = 4_096


class RungOptions(NamedTuple):
    """Facade knobs forwarded to a fitter (metric/seed/pallas ride on
    ``ResultMeta``).

    ``turbo`` picks the flashvat traversal engine: None (default) lets
    the rung auto-select — the persistent Turbo engine solo, the sharded
    engine when more than one device is visible and n is worth the
    collectives; True forces the SOLO persistent engine (opting out of
    auto-sharding); False forces the PR-4 stepwise engine (solo only).

    ``knn_k`` is the approx rung's accuracy knob: neighbours kept per
    point in the kNN graph its Boruvka MST runs over.  Larger k tightens
    the kNN-MST toward the exact MST (identical at k = n-1) at O(n·k)
    memory and time; the error actually incurred is reported on
    ``ResultMeta.approx``.

    ``encoder`` is the "embed" rung's model hook: a callable mapping the
    fit input to an (n, d) activation matrix (DeepVAT-style).  The
    facade encodes before dispatch and leaves this None; set it when
    driving the rung directly through the registry.

    ``num_form`` is the numerics shield's tile-form plan: "gram"
    (default — the ‖x‖²+‖y‖²−2x·y trick, MXU-friendly) or "direct"
    (per-coordinate (x−y)², no cancellation).  The facade sets it from
    ``numerics.resolve``'s static dispatch decision; it is threaded to
    every distance/traversal kernel a rung runs (see docs/numerics.md).
    """
    sample_size: int = 256
    block: int = 4096
    turbo: bool | None = None
    knn_k: int = 15
    encoder: Any = None
    num_form: str = "gram"


Fitter = Callable[[Any, ResultMeta, RungOptions], TendencyResult]


@dataclasses.dataclass(frozen=True)
class LatencyModel:
    """Per-rung wall-time model — the SLO router's cost data (ISSUE 7).

    ``predict_us(n, batch) = base_us + batch * (per_point_us * n +
    per_sq_point_us * n^2)``.  Coefficients are calibrated against the
    committed ``BENCH_*.json`` trajectory (CPU numbers from this repo's
    flash/turbo/approx/table4 rows — recalibrate when an accelerator
    trajectory exists); they exist to *rank* rungs and gate SLOs, they
    are not latency promises.

    Attributes:
      base_us: fixed dispatch + host-glue cost per fit.
      per_point_us: O(n) coefficient (kNN edges, sampling passes).
      per_sq_point_us: O(n^2) coefficient (materialized matrices, the
        matrix-free engines' recompute work).
      cap_n: feasibility ceiling — e.g. the O(n^2) matrix memory wall of
        the materialized rungs; the router never offers a rung past it
        no matter how generous the SLO.
    """

    base_us: float
    per_point_us: float = 0.0
    per_sq_point_us: float = 0.0
    cap_n: int | None = None

    def predict_us(self, n: int, batch: int = 1) -> float:
        """Predicted wall microseconds for a (batch, n, d)-ish fit."""
        per = self.per_point_us * n + self.per_sq_point_us * float(n) * n
        return self.base_us + batch * per

    def feasible(self, n: int) -> bool:
        """Whether the rung is offered at all at this n."""
        return self.cap_n is None or n <= self.cap_n


def predict_latency_us(method: str, n: int, *, batch: int = 1) -> float | None:
    """Predicted fit latency of a registered rung; None when unmodeled."""
    model = get_rung(method).latency_model
    return None if model is None else model.predict_us(n, batch=batch)


def select_method_for_slo(n: int, slo_us: float, *, batch: int = 1,
                          restrict=None) -> str:
    """Pick the rung to run under a latency SLO (the serving router).

    Policy: among the feasible, latency-modeled rungs (optionally
    restricted to a candidate set), return the **highest-fidelity rung
    the budget affords** — fidelity read from each rung's explicit
    ``fidelity`` rank (ivat's geodesic image > vat's raw image >
    flashvat's band render > the sampled/approx rungs), NOT proxied by
    predicted cost: fixed dispatch overhead (flashvat's base cost
    dominates at small n) would otherwise make the router buy a
    *costlier but coarser* picture.  Ties in fidelity go to the
    cheaper rung.  When no candidate fits the SLO, degrade gracefully
    to the cheapest feasible rung (best effort beats an error under
    load); callers that need a hard guarantee compare
    ``predict_latency_us`` against the SLO themselves.

    Args:
      n: points per dataset.
      slo_us: the latency budget in microseconds.
      batch: datasets per dispatch (coalesced serving amortizes base
        cost but multiplies per-dataset work).
      restrict: iterable of method names to choose among; None means
        every registered rung with a latency model.

    Returns:
      The selected method name.

    Raises:
      LookupError: no feasible modeled candidate exists.
    """
    names = tuple(restrict) if restrict is not None else registered()
    cands = []
    for name in names:
        model = get_rung(name).latency_model
        if model is not None and model.feasible(n):
            cands.append((name, model.predict_us(n, batch=batch)))
    if not cands:
        raise LookupError(
            f"no latency-modeled rung is feasible at n={n} "
            f"(candidates considered: {list(names)})")
    fitting = [c for c in cands if c[1] <= slo_us]
    if fitting:
        return max(fitting,
                   key=lambda c: (get_rung(c[0]).fidelity, -c[1]))[0]
    return min(cands, key=lambda c: c[1])[0]


@dataclasses.dataclass(frozen=True)
class Rung:
    """One registered VAT method.

    Attributes:
      name: the ``method=`` string.
      fit: solo fitter — (X_or_D, meta, options) -> TendencyResult.
      fit_batch: batched fitter over a (b, n, d) stack (or (b, n, n)
        precomputed stack); None means the rung doesn't batch.
      supports_precomputed: accepts metric="precomputed" input.
      auto_threshold: largest n ``select_method`` hands this rung
        (math.inf = unbounded fallback); None = never auto-selected.
      max_n: hard cap enforced at fit time; None = uncapped.
      check: optional environment validation hook, called with n before
        fitting (e.g. dvat's device-count requirements).
      latency_model: calibrated wall-time model for SLO routing
        (``select_method_for_slo``); None = the rung is never offered
        by the router (it stays reachable via explicit ``method=``).
      fidelity: explicit rank of how faithful the rung's picture is
        (higher = more faithful; exact geodesic > exact raw > banded
        render > sampled/approximate).  The SLO router picks the
        highest-fidelity rung fitting the budget — fidelity is ranked
        explicitly rather than proxied by cost, because fixed dispatch
        overhead can make a coarser rung predict costlier at small n.
        Third-party rungs slot in relative to the built-in ranks.
      description: one-liner for docs/tooling.
    """

    name: str
    fit: Fitter
    fit_batch: Fitter | None = None
    supports_precomputed: bool = False
    auto_threshold: float | None = None
    max_n: int | None = None
    check: Callable[[int], None] | None = None
    latency_model: LatencyModel | None = None
    fidelity: float = 0.0
    description: str = ""

    @property
    def supports_batch(self) -> bool:
        return self.fit_batch is not None


_REGISTRY: dict[str, Rung] = {}


def register(rung: Rung, *, overwrite: bool = False) -> Rung:
    """Add a rung; its name becomes a valid ``FastVAT(method=...)``.

    Args:
      rung: the entry to add. ``name`` must not be "auto".
      overwrite: allow replacing an existing entry of the same name.

    Returns:
      The registered rung (for decorator-ish chaining).
    """
    if rung.name == "auto" or not rung.name:
        raise ValueError(f"invalid rung name {rung.name!r}")
    if rung.name in _REGISTRY and not overwrite:
        raise ValueError(f"rung {rung.name!r} already registered "
                         "(pass overwrite=True to replace)")
    _REGISTRY[rung.name] = rung
    return rung


def get_rung(name: str) -> Rung:
    """Look up a registered rung by method name."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown method {name!r}; registered: "
                       f"{registered()}") from None


def registered() -> tuple[str, ...]:
    """Names of every registered rung (live — includes third-party)."""
    return tuple(_REGISTRY)


def methods() -> tuple[str, ...]:
    """Everything ``FastVAT(method=...)`` accepts: "auto" + the rungs."""
    return ("auto",) + registered()


def select_method(n: int, *, precomputed: bool = False,
                  batched: bool = False, strict: bool = False) -> str:
    """The auto-selection policy, data-driven over rung capabilities.

    Args:
      n: points per dataset.
      precomputed: restrict to rungs accepting metric="precomputed".
      batched: restrict to rungs with a batched fitter.
      strict: raise LookupError when no candidate's threshold covers n
        instead of falling back to the largest-threshold candidate (the
        fallback serves precomputed input, where the O(n^2) matrix
        already exists so the exact rung stays the right answer).

    Returns:
      The selected method name.
    """
    cands = [r for r in _REGISTRY.values() if r.auto_threshold is not None]
    if precomputed:
        cands = [r for r in cands if r.supports_precomputed]
    if batched:
        cands = [r for r in cands if r.supports_batch]
    cands.sort(key=lambda r: r.auto_threshold)
    if not cands:
        raise LookupError("no auto-selectable rung matches "
                          f"(precomputed={precomputed}, batched={batched})")
    for r in cands:
        if n <= r.auto_threshold:
            return r.name
    if strict:
        raise LookupError(f"no auto-selectable rung covers n={n}")
    return cands[-1].name


# ---------------------------------------------------------------------
# Built-in rung fitters: run a repro.core rung, wrap into TendencyResult.
# ---------------------------------------------------------------------

def _as_f32(X) -> jax.Array:
    return X if isinstance(X, jax.Array) else jnp.asarray(
        np.asarray(X, np.float32))


def _vat_result(data, meta: ResultMeta, opts: RungOptions) -> core.VATResult:
    if meta.metric == "precomputed":
        return core.vat_from_dist(_as_f32(data))
    return core.vat(_as_f32(data), use_pallas=meta.use_pallas,
                    metric=meta.metric, form=opts.num_form)


def _vat_result_batch(data, meta: ResultMeta,
                      opts: RungOptions) -> core.VATResult:
    if meta.metric == "precomputed":
        return core.vat_batch_from_dist(_as_f32(data))
    return core.vat_batch(_as_f32(data), use_pallas=meta.use_pallas,
                          metric=meta.metric, form=opts.num_form)


def _fit_vat(data, meta: ResultMeta, opts: RungOptions) -> TendencyResult:
    res = _vat_result(data, meta, opts)
    return TendencyResult(order=res.order, rstar=res.rstar, ivat_image=None,
                          sample_idx=None, extension_labels=None, meta=meta)


def _fit_vat_batch(data, meta: ResultMeta,
                   opts: RungOptions) -> TendencyResult:
    res = _vat_result_batch(data, meta, opts)
    return TendencyResult(order=res.order, rstar=res.rstar, ivat_image=None,
                          sample_idx=None, extension_labels=None, meta=meta)


def _fit_ivat(data, meta: ResultMeta, opts: RungOptions) -> TendencyResult:
    res = _vat_result(data, meta, opts)
    iv = core.ivat_from_vat(res.rstar, use_pallas=meta.use_pallas)
    return TendencyResult(order=res.order, rstar=res.rstar, ivat_image=iv,
                          sample_idx=None, extension_labels=None, meta=meta)


def _fit_ivat_batch(data, meta: ResultMeta,
                    opts: RungOptions) -> TendencyResult:
    res = _vat_result_batch(data, meta, opts)
    iv = core.ivat_from_vat(res.rstar, use_pallas=meta.use_pallas)
    return TendencyResult(order=res.order, rstar=res.rstar, ivat_image=iv,
                          sample_idx=None, extension_labels=None, meta=meta)


def _fit_svat(data, meta: ResultMeta, opts: RungOptions) -> TendencyResult:
    res = core.svat(_as_f32(data), meta.jax_key(SALT_FIT),
                    s=min(opts.sample_size, meta.n),
                    use_pallas=meta.use_pallas, metric=meta.metric)
    return TendencyResult(order=res.vat.order, rstar=res.vat.rstar,
                          ivat_image=None, sample_idx=res.sample_idx,
                          extension_labels=None, meta=meta)


def _fit_bigvat(data, meta: ResultMeta, opts: RungOptions) -> TendencyResult:
    res = core.bigvat(data, meta.jax_key(SALT_FIT), s=opts.sample_size,
                      block=opts.block, use_pallas=meta.use_pallas,
                      metric=meta.metric)
    return TendencyResult(order=res.order, rstar=res.sample.vat.rstar,
                          ivat_image=res.ivat,
                          sample_idx=res.sample.sample_idx,
                          extension_labels=res.labels, meta=meta,
                          group_sizes=res.group_sizes)


def _flash_groups(n: int, m: int):
    """Partition VAT-order positions 0..n-1 into m contiguous groups.

    Returns (sizes (m,) int64, mids (m,) int64): per-group lengths
    (remainder spread over the leading groups) and each group's middle
    position — the representative whose distances render that band.
    """
    base, extra = divmod(n, m)
    sizes = np.full(m, base, np.int64)
    sizes[:extra] += 1
    starts = np.concatenate([[0], np.cumsum(sizes)[:-1]])
    return sizes, starts + sizes // 2


def _rep_ivat(Rrep: jax.Array, use_pallas: bool) -> jax.Array:
    """iVAT image of a representative matrix, returned in band order.

    The Havens-Bezdek recurrence is only valid along a Prim traversal of
    the matrix it is applied to, and the band order (representatives
    sorted by their position in the *full-n* ordering) is generally not
    one — so the geodesics are computed along the representatives' own
    Prim order (``vat_from_dist``) and the result is permuted back to
    band order for rendering.  O(m^2) work on an (m, m) object.
    """
    sres = core.vat_from_dist(Rrep)
    iv_s = core.ivat_from_vat(sres.rstar, use_pallas=use_pallas)
    m = Rrep.shape[0]
    rank = jnp.zeros((m,), jnp.int32).at[sres.order].set(
        jnp.arange(m, dtype=jnp.int32))
    return iv_s[rank][:, rank]


def _flash_order(Xj, meta: ResultMeta, opts: RungOptions):
    """The flashvat rung's engine auto-select (ISSUE 5).

    ``opts.turbo`` None (auto) routes the Turbo persistent engine, or —
    with more than one visible device and n past ``FLASH_SHARD_MIN_N``,
    where the per-step collectives amortize — the X-row-sharded engine
    (same orderings bit for bit, per-device memory divided by P).
    ``turbo=True`` FORCES the solo persistent engine (the documented
    escape hatch from auto-sharding); ``turbo=False`` pins the PR-4
    stepwise engine.  The sharded engine speaks the Gram tile form only,
    so a "direct" numerics plan (``opts.num_form``) pins the solo
    persistent engine instead — conditioned fits trade the mesh for the
    cancellation-free tiles.
    """
    devs = jax.devices()
    if (opts.turbo is None and core.HAS_DISTRIBUTED and len(devs) > 1
            and meta.n >= FLASH_SHARD_MIN_N and opts.num_form == "gram"):
        from jax.sharding import Mesh
        mesh = Mesh(np.array(devs), ("data",))
        return core.vat_matrix_free_sharded(Xj, mesh, metric=meta.metric,
                                            use_pallas=meta.use_pallas)
    return core.vat_matrix_free(Xj, metric=meta.metric,
                                form=opts.num_form,
                                use_pallas=meta.use_pallas,
                                turbo=True if opts.turbo is None
                                else opts.turbo)


def _band_render(Xj: jax.Array, order: jax.Array, meta: ResultMeta,
                 opts: RungOptions) -> TendencyResult:
    """bigvat-style banded rendering of a full-n ordering.

    The rendering idea is bigvat's in reverse: m = sample_size
    representatives are taken at the middle of m contiguous bands of the
    given full-n ordering, their (m, m) dissimilarity matrix inherits
    that band order, and ``TendencyResult.image`` expands it by the true
    band sizes — so the picture shows all n points while only an (m, m)
    object ever exists.  The iVAT companion runs along the
    representatives' own Prim traversal (see ``_rep_ivat``) and is
    re-indexed to the same bands.  Shared by the flashvat (exact order)
    and approx (kNN-MST order) rungs.
    """
    n, m = meta.n, min(opts.sample_size, meta.n)
    sizes, mids = _flash_groups(n, m)
    rep_idx = order[jnp.asarray(mids)]
    Rrep = kops.pairwise_dist(Xj[rep_idx], use_pallas=meta.use_pallas,
                              metric=meta.metric, form=opts.num_form)
    iv = _rep_ivat(Rrep, meta.use_pallas)
    gid = jnp.asarray(np.repeat(np.arange(m, dtype=np.int32), sizes))
    labels = jnp.zeros((n,), jnp.int32).at[order].set(gid)
    return TendencyResult(order=order, rstar=Rrep, ivat_image=iv,
                          sample_idx=rep_idx, extension_labels=labels,
                          group_sizes=jnp.asarray(sizes, jnp.int32),
                          meta=meta)


def _fit_flashvat(data, meta: ResultMeta, opts: RungOptions) -> TendencyResult:
    """Flash-VAT: exact matrix-free ordering + bigvat-style tiled render.

    The ordering is the exact full-n VAT order (bitwise-identical to the
    materialized path) at O(n·d) memory — computed by the engine
    ``_flash_order`` selects (Turbo persistent / sharded / stepwise) —
    then rendered through the shared ``_band_render`` tail.
    """
    Xj = _as_f32(data)
    res = _flash_order(Xj, meta, opts)
    return _band_render(Xj, res.order, meta, opts)


def _fit_approx(data, meta: ResultMeta, opts: RungOptions) -> TendencyResult:
    """Approx-VAT: kNN-graph Boruvka MST ordering, the million-point rung.

    The ordering comes from ``core.approx_vat`` — a Prim traversal of
    the minimum spanning tree of the k-nearest-neighbour graph (exact
    blocked kNN below its crossover, anchor-partitioned beyond), built
    by a jitted Boruvka fold at O(n·k) edge memory.  It is exact
    whenever the kNN graph contains the true MST (guaranteed at
    k = n-1, typical for modest k on clusterable data); the incurred
    error is measured, not guessed: ``ResultMeta.approx`` carries the
    spanning defect (components before repair, edges the repair pass
    added and their weight) next to the kNN-MST weight, so callers can
    bound the approximation or rerun with a larger ``knn_k``.  Rendering
    shares flashvat's banded tail — no (n, n) object at any stage.
    """
    Xj = _as_f32(data)
    res = core.approx_vat(Xj, k=opts.knn_k, metric=meta.metric,
                          use_pallas=meta.use_pallas)
    meta = dataclasses.replace(meta, approx=res.stats)
    return _band_render(Xj, jnp.asarray(res.order), meta, opts)


def _fit_flashvat_batch(data, meta: ResultMeta,
                        opts: RungOptions) -> TendencyResult:
    """Batched Flash-VAT: one compiled program, per-lane exact orderings."""
    Xj = _as_f32(data)
    res = core.vat_matrix_free_batch(
        Xj, metric=meta.metric, form=opts.num_form,
        use_pallas=meta.use_pallas,
        turbo=True if opts.turbo is None else opts.turbo)
    n, m = meta.n, min(opts.sample_size, meta.n)
    sizes, mids = _flash_groups(n, m)
    rep_idx = res.order[:, jnp.asarray(mids)]                    # (b, m)
    prot = jnp.take_along_axis(Xj, rep_idx[:, :, None], axis=1)  # (b, m, d)
    Rrep = kops.pairwise_dist_batch(prot, use_pallas=meta.use_pallas,
                                    metric=meta.metric, form=opts.num_form)
    iv = jax.vmap(lambda R: _rep_ivat(R, meta.use_pallas))(Rrep)
    gid = jnp.asarray(np.repeat(np.arange(m, dtype=np.int32), sizes))
    labels = jax.vmap(
        lambda o: jnp.zeros((n,), jnp.int32).at[o].set(gid))(res.order)
    return TendencyResult(order=res.order, rstar=Rrep, ivat_image=iv,
                          sample_idx=rep_idx, extension_labels=labels,
                          group_sizes=jnp.asarray(sizes, jnp.int32),
                          meta=meta)


def _fit_embed(data, meta: ResultMeta, opts: RungOptions) -> TendencyResult:
    """The embeddings front-end rung (DeepVAT): assess activations.

    Raw inputs (pixels, tokens) are rarely clusterable; learned
    embeddings are.  This rung maps the input through an encoder —
    ``opts.encoder`` (a callable X -> (n, d) activations), or the data
    is already pre-encoded and ``meta.encoder`` carries the fingerprint
    — then delegates to whatever rung ``select_method`` picks for the
    activation count.  ``meta.method`` stays "embed" and
    ``meta.encoder`` records provenance; everything else (images,
    assess, serving adoption) is the inner rung's standard output.
    """
    enc = opts.encoder
    if callable(enc):
        from repro.monitor.probes import callable_fingerprint
        acts = np.asarray(jax.device_get(enc(data)), np.float32)
        if not meta.encoder:
            meta = dataclasses.replace(meta,
                                       encoder=callable_fingerprint(enc))
    elif meta.encoder:
        acts = np.asarray(data, np.float32)   # pre-encoded by the caller
    else:
        raise ValueError(
            "method='embed' needs an encoder: pass options.encoder (a "
            "callable X -> activations), or pre-encoded activations with "
            "the encoder fingerprint on meta.encoder — e.g. via "
            "FastVAT.fit(X, encoder=...) / FastVAT.fit_embeddings(...)")
    if acts.ndim > 2:
        acts = acts.reshape(-1, acts.shape[-1])
    meta = dataclasses.replace(meta, n=int(acts.shape[0]))
    inner = get_rung(select_method(meta.n))
    return inner.fit(acts, meta, opts)


def _check_dvat(n: int):
    if not core.HAS_DISTRIBUTED:
        raise RuntimeError(
            "method='dvat' needs a JAX with shard_map "
            "(repro.core.HAS_DISTRIBUTED is False; cause: "
            f"{core.DISTRIBUTED_IMPORT_ERROR})")
    devs = jax.devices()
    if len(devs) < 2:
        raise RuntimeError(
            f"method='dvat' needs >1 device, found {len(devs)}; "
            "use 'bigvat' on a single host")
    if n % len(devs):
        raise ValueError(
            f"method='dvat' needs n divisible by the device count "
            f"({n} % {len(devs)} != 0); pad or truncate X first")


def _fit_dvat(data, meta: ResultMeta, opts: RungOptions) -> TendencyResult:
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()), ("data",))
    Xj = _as_f32(data)
    dres = core.dvat(Xj, mesh, metric=meta.metric)
    # a maximin-sample image gives dvat the same assessable rstar every
    # other rung carries (the full-n ordering stays the headline output).
    # Cost: one O(n s) maximin pass + an (s, s) VAT at fit time — small
    # next to dvat's own O(n^2 d / P) exact-start pass, and it buys the
    # uniform image()/assess() surface without a lazy special case
    sres = core.svat(Xj, meta.jax_key(SALT_FIT),
                     s=min(opts.sample_size, meta.n),
                     use_pallas=meta.use_pallas, metric=meta.metric)
    return TendencyResult(order=dres.order, rstar=sres.vat.rstar,
                          ivat_image=None, sample_idx=sres.sample_idx,
                          extension_labels=None, meta=meta)


# Latency-model calibration (ISSUE 7): CPU coefficients fitted by eye
# against the committed BENCH_*.json trajectory — flash table (n=8192
# materialized ~0.86 s, persistent matrix-free ~0.15 s; n=100k ~59 s),
# table4 (approx at n=1e6 ~130 s, ~130 us/point), table1/batched for the
# small-n fixed costs.  cap_n = 20_000 is the materialized rungs' (n, n)
# memory wall (1.6 GB f32) — past it the router only offers matrix-free
# rungs regardless of SLO.  dvat carries no model: its cost is
# mesh-shaped, not n-shaped, and the router must not pretend otherwise.
_MATERIALIZE_CAP_N = 20_000

register(Rung(
    name="vat", fit=_fit_vat, fit_batch=_fit_vat_batch,
    supports_precomputed=True, auto_threshold=SMALL_N,
    latency_model=LatencyModel(base_us=3e3, per_point_us=1.5,
                               per_sq_point_us=1.3e-2,
                               cap_n=_MATERIALIZE_CAP_N),
    fidelity=50.0,
    description="exact VAT — O(n^2) matrix fits easily"))
register(Rung(
    name="ivat", fit=_fit_ivat, fit_batch=_fit_ivat_batch,
    supports_precomputed=True, auto_threshold=None,
    latency_model=LatencyModel(base_us=4e3, per_point_us=1.5,
                               per_sq_point_us=3.2e-2,
                               cap_n=_MATERIALIZE_CAP_N),
    fidelity=60.0,
    description="exact VAT + geodesic (iVAT) image; opt-in"))
register(Rung(
    name="svat", fit=_fit_svat, auto_threshold=None,
    latency_model=LatencyModel(base_us=4e3, per_point_us=25.0),
    fidelity=30.0,
    description="maximin sample VAT, O(ns + s^2); opt-in (flashvat "
                "covers its former auto window exactly)"))
register(Rung(
    name="flashvat", fit=_fit_flashvat, fit_batch=_fit_flashvat_batch,
    auto_threshold=MEDIUM_N,
    latency_model=LatencyModel(base_us=2.5e4, per_point_us=4.0,
                               per_sq_point_us=4e-3),
    fidelity=40.0,
    description="matrix-free exact VAT (Flash-VAT): fused streaming "
                "Prim, O(n·d) memory, no (n, n) object"))
register(Rung(
    name="bigvat", fit=_fit_bigvat, auto_threshold=None,
    latency_model=LatencyModel(base_us=2e5, per_point_us=60.0),
    fidelity=20.0,
    description="out-of-core clusiVAT pipeline, no (n, n) object; "
                "opt-in (approx covers its former auto window with a "
                "measured error bound)"))
register(Rung(
    name="approx", fit=_fit_approx, auto_threshold=math.inf,
    latency_model=LatencyModel(base_us=6e5, per_point_us=130.0),
    fidelity=10.0,
    description="kNN-graph Boruvka MST VAT, O(n·k) edges — the "
                "million-point rung; error reported on meta.approx"))
register(Rung(
    name="dvat", fit=_fit_dvat, check=_check_dvat, auto_threshold=None,
    description="matrix-free distributed VAT; needs >1 device"))
register(Rung(
    name="embed", fit=_fit_embed, auto_threshold=None,
    description="embeddings front-end (DeepVAT): encode, then run the "
                "exact/approx ladder on activations; encoder "
                "fingerprint on meta.encoder"))
