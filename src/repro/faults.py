"""Deterministic fault-injection registry (ISSUE 9 tentpole, part 1).

Every brittle seam in the stack carries a *named injection site* — a
``fault_point(site, ...)`` call at the exact host-level boundary where a
real failure would surface: the kernel-dispatch wrappers, the serving
layer's program build and execute paths, the checkpoint sidecar
write/read, and the history deserializer.  Tests and the chaos CLI
(``repro.launch.chaos``) *arm* deterministic faults against those sites;
production code never arms anything, and a disarmed site costs one
module-dict truthiness check (the ``if not _ARMED: return`` fast path)
— no locks, no allocation, nothing in a jaxpr.

Sites fire at **host** level only.  A site inside a jitted function
(``kernels.dispatch``) executes at *trace* time, so an armed fault
there models a compile-path failure; a warm cached program never
re-traces and is therefore immune — exactly the semantics the serving
layer's fallback chain needs.  Runtime failures are modeled at the
``serve.execute`` site, which runs per dispatch on the host.

Scheduling is deterministic: a fault fires on hit numbers
``after <= hit < after + times`` (``times=-1`` = forever), optionally
gated by a ``match`` predicate over the site's context dict, and any
randomness (corruption byte choice) derives from the fault's ``seed``.
Two runs with the same arm calls see byte-identical fault behavior —
that is what lets the chaos tests pin exact counter trajectories.

Kinds:

  raise     raise ``exc(message)`` (default :class:`FaultInjected`).
  delay     invoke the caller-provided ``sleep`` with ``delay_s``
            (the server passes its injectable sleep, so virtual-clock
            tests observe the delay without real wall time).
  corrupt   flip one deterministic byte of the site's payload —
            ``bytes``, ``np.ndarray``, a flat dict of arrays, or a file
            path (flipped in place).
  truncate  drop the tail of the payload (same payload types; files
            are truncated in place).

>>> import repro.faults as faults
>>> with faults.injected("serve.execute", times=1):
...     try:
...         faults.fault_point("serve.execute")
...     except faults.FaultInjected as e:
...         print("fired:", e.site)
...     faults.fault_point("serve.execute")   # times=1 => second hit clean
fired: serve.execute
>>> faults.armed()
{}
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from typing import Any, Callable

import numpy as np

#: The registered injection sites — ``arm`` rejects unknown names so a
#: typo'd site can never silently arm nothing.  The table in
#: docs/robustness.md documents where each one lives.
SITES = (
    "kernels.dispatch",     # kernels/ops.py public wrappers (trace time)
    "serve.build",          # serve/server.py::_build_program
    "serve.execute",        # serve/server.py::_execute program run
    "ckpt.aux_write",       # checkpoint/ckpt.py sidecar file just written
    "ckpt.aux_read",        # checkpoint/ckpt.py::load_aux before reading
    "history.deserialize",  # monitor/history.py::TendencyHistory arrays
    "kernels.numerics_trip",  # numerics/condition.py::resolve bf16 cert
)


class FaultInjected(RuntimeError):
    """The default exception an armed ``raise`` fault throws.

    ``site`` names the injection point, so handlers and tests can tell
    injected failures from organic ones.
    """

    def __init__(self, site: str, message: str = ""):
        self.site = site
        super().__init__(message or f"injected fault at site {site!r}")


@dataclasses.dataclass
class Fault:
    """One armed fault (see module docstring for the kind semantics).

    Attributes:
      site: the injection site this fault is bound to.
      kind: "raise" | "delay" | "corrupt" | "truncate".
      times: firings before the fault stops matching (-1 = forever).
      after: hits skipped before the first firing (count scheduling).
      exc: exception type for kind="raise" (constructed as
        ``exc(site, message)`` for FaultInjected subclasses, else
        ``exc(message)``).
      message: exception text override.
      delay_s: sleep length for kind="delay".
      seed: determinism source for corruption byte choices.
      match: optional predicate over the site's context dict — the hit
        does not count (and the fault does not fire) unless it returns
        True.  This is how a test poisons exactly one lane of a batch.
      hits: matched-context visits so far (telemetry).
      fired: actual firings so far (telemetry).
    """

    site: str
    kind: str = "raise"
    times: int = 1
    after: int = 0
    exc: type[BaseException] = FaultInjected
    message: str = ""
    delay_s: float = 0.0
    seed: int = 0
    match: Callable[[dict], bool] | None = None
    hits: int = 0
    fired: int = 0

    def _should_fire(self) -> bool:
        i = self.hits  # 0-based index of the *current* hit
        if i < self.after:
            return False
        return self.times < 0 or i < self.after + self.times


_ARMED: dict[str, Fault] = {}
_LOCK = threading.Lock()
_KINDS = ("raise", "delay", "corrupt", "truncate")


def arm(site: str, *, kind: str = "raise", times: int = 1, after: int = 0,
        exc: type[BaseException] = FaultInjected, message: str = "",
        delay_s: float = 0.0, seed: int = 0,
        match: Callable[[dict], bool] | None = None) -> Fault:
    """Arm one fault at a registered site (replacing any existing one)."""
    if site not in SITES:
        raise ValueError(f"unknown injection site {site!r}; registered "
                         f"sites: {list(SITES)}")
    if kind not in _KINDS:
        raise ValueError(f"kind must be one of {_KINDS}, got {kind!r}")
    fault = Fault(site=site, kind=kind, times=times, after=after, exc=exc,
                  message=message, delay_s=delay_s, seed=seed, match=match)
    with _LOCK:
        _ARMED[site] = fault
    return fault


def disarm(site: str) -> None:
    """Remove the fault at ``site`` (no-op when nothing is armed)."""
    with _LOCK:
        _ARMED.pop(site, None)


def disarm_all() -> None:
    """Remove every armed fault (test teardown)."""
    with _LOCK:
        _ARMED.clear()


def is_armed(site: str) -> bool:
    return site in _ARMED


def armed() -> dict[str, Fault]:
    """Snapshot copy of the armed-fault map."""
    with _LOCK:
        return dict(_ARMED)


def stats() -> dict[str, dict[str, int]]:
    """Per-site {hits, fired} telemetry for the armed faults."""
    with _LOCK:
        return {s: {"hits": f.hits, "fired": f.fired}
                for s, f in _ARMED.items()}


@contextlib.contextmanager
def injected(site: str, **kw):
    """``arm`` for the duration of a with-block, then disarm the site."""
    fault = arm(site, **kw)
    try:
        yield fault
    finally:
        disarm(site)


# --------------------------------------------------------- the hook ----

def fault_point(site: str, *, context: dict | None = None,
                data: Any = None, path: str | None = None,
                sleep: Callable[[float], None] | None = None) -> Any:
    """The injection hook production code calls at each named site.

    Disarmed (the production state) this returns ``data`` after a
    single dict truthiness check.  Armed, it applies the fault's kind:
    raising, delaying via ``sleep``, or returning/overwriting a
    corrupted payload (``data`` or the file at ``path``).

    Args:
      site: registered site name.
      context: site-specific facts the fault's ``match`` predicate can
        inspect (e.g. ``{"tags": [...], "key": ProgramKey}``).
      data: payload for corrupt/truncate kinds (bytes / ndarray / flat
        dict of arrays); returned unchanged for other kinds.
      path: file path for corrupt/truncate kinds that mutate a file.
      sleep: sleeper for delay kind; defaults to ``time.sleep``.

    Returns:
      ``data`` (possibly corrupted/truncated).
    """
    if not _ARMED:           # the zero-overhead disarmed fast path
        return data
    with _LOCK:
        fault = _ARMED.get(site)
        if fault is None:
            return data
        if fault.match is not None and not fault.match(context or {}):
            return data
        fire = fault._should_fire()
        fault.hits += 1
        if fire:
            fault.fired += 1
    if not fire:
        return data
    if fault.kind == "raise":
        if issubclass(fault.exc, FaultInjected):
            raise fault.exc(site, fault.message)
        raise fault.exc(fault.message or
                        f"injected fault at site {site!r}")
    if fault.kind == "delay":
        (sleep if sleep is not None else time.sleep)(fault.delay_s)
        return data
    if path is not None:
        _mutate_file(path, fault)
        return data
    return _mutate_payload(data, fault)


# ---------------------------------------------------- corruption ops ----

def _flip_index(length: int, seed: int) -> int:
    """Deterministic byte offset to flip — away from both ends so zip /
    npz magic headers survive and the corruption lands in array data."""
    if length <= 2:
        return 0
    rng = np.random.default_rng(np.random.SeedSequence([seed, length]))
    return int(rng.integers(low=length // 4, high=max(length // 4 + 1,
                                                      3 * length // 4)))


def _mutate_file(fpath: str, fault: Fault) -> None:
    with open(fpath, "rb") as f:
        raw = bytearray(f.read())
    if fault.kind == "truncate":
        raw = raw[: max(1, len(raw) // 2)]
    else:
        i = _flip_index(len(raw), fault.seed)
        raw[i] ^= 0xFF
    with open(fpath, "wb") as f:
        f.write(bytes(raw))


def _mutate_payload(data: Any, fault: Fault) -> Any:
    if data is None:
        return None
    if isinstance(data, (bytes, bytearray)):
        raw = bytearray(data)
        if fault.kind == "truncate":
            return bytes(raw[: max(1, len(raw) // 2)])
        i = _flip_index(len(raw), fault.seed)
        raw[i] ^= 0xFF
        return bytes(raw)
    if isinstance(data, np.ndarray):
        return _mutate_array(data, fault)
    if isinstance(data, dict):
        # flat dict of arrays (the history sidecar shape): corrupt one
        # value, chosen deterministically by seed.
        out = dict(data)
        keys = sorted(k for k, v in out.items()
                      if isinstance(v, np.ndarray) and v.nbytes > 0)
        if not keys:
            return out
        k = keys[fault.seed % len(keys)]
        out[k] = _mutate_array(np.asarray(out[k]), fault)
        return out
    raise TypeError(f"fault_point cannot corrupt payload of type "
                    f"{type(data).__name__}")


def _mutate_array(arr: np.ndarray, fault: Fault) -> np.ndarray:
    arr = np.array(arr, copy=True)
    if fault.kind == "truncate":
        flat = arr.reshape(-1)
        return flat[: max(1, flat.shape[0] // 2)]
    view = arr.view(np.uint8).reshape(-1)
    if view.size:
        view[_flip_index(view.size, fault.seed)] ^= 0xFF
    return arr
