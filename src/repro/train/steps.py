"""train_step / serve_step builders — the units the launcher jits and shards."""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.models import model as M
from repro.optim import adamw as O
from repro.optim import compression as C

Z_LOSS = 1e-4


class TrainState(NamedTuple):
    params: Any
    opt: O.OptState
    ef: C.EFState | None


def loss_fn(params, cfg: ModelConfig, batch):
    labels = batch["labels"]
    if cfg.ce_chunk > 0:
        # chunked CE: the (B, S, V) f32 logits never materialize
        h, aux = M.forward(params, cfg, batch, return_hidden=True)
        ce_sum, z_sum, cnt = M.ce_from_hidden(params, cfg, h, labels,
                                              chunk=cfg.ce_chunk)
    else:
        logits, aux = M.forward(params, cfg, batch)    # logits f32
        ce_sum, z_sum, cnt = M.ce_sums(logits, labels)
    denom = jnp.maximum(cnt, 1.0)
    ce = ce_sum / denom
    zloss = Z_LOSS * z_sum / denom
    total = ce + zloss + aux
    return total, {"loss": total, "ce": ce, "aux": aux}


def init_state(cfg: ModelConfig, tc: TrainConfig, key,
               param_dtype=jnp.float32) -> TrainState:
    params = M.init_params(cfg, key, param_dtype)
    return TrainState(params=params, opt=O.init_opt(tc, params),
                      ef=C.ef_init(params) if tc.compress_grads else None)


def build_train_step(cfg: ModelConfig, tc: TrainConfig):
    """Returns train_step(state, batch) -> (state, metrics), jit-ready."""

    def train_step(state: TrainState, batch):
        (_, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params, cfg, batch)
        grads, gnorm = O.clip_by_global_norm(grads, tc.grad_clip)
        ef = state.ef
        if ef is not None:
            grads, ef = C.compress(grads, ef, tc.topk_frac)
        params, opt = O.apply_opt(tc, state.params, grads, state.opt)
        metrics = dict(metrics, grad_norm=gnorm)
        return TrainState(params=params, opt=opt, ef=ef), metrics

    return train_step


def build_serve_step(cfg: ModelConfig, *, greedy: bool = True):
    """Returns serve_step(params, cache, tokens, pos) -> (next_tokens, cache).

    One new token per request stream against a seq_len-deep KV/state cache
    — exactly the decode_* / long_* dry-run cells.
    """

    def serve_step(params, cache, tokens, pos):
        logits, cache = M.decode_step(params, cfg, tokens, cache, pos)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return nxt[:, None], cache

    return serve_step
