from repro.train.steps import TrainState, build_train_step, build_serve_step, init_state, loss_fn
from repro.train.loop import train
