"""Fault-tolerant training loop.

Survivability posture (designed for 1000+ nodes, exercised here on CPU):

* **checkpoint/restart** — atomic step-tagged checkpoints every
  `ckpt_every` steps; on start the loop restores the latest checkpoint
  and *deterministically skips* the data stream to the restored step, so
  an interrupted run and an uninterrupted run are bitwise identical
  (tested in tests/test_train_loop.py by killing mid-run).
* **straggler mitigation** — host-side data dispatch has a per-step
  deadline; a late batch is skipped and logged rather than stalling the
  collective (on a real pod the skip is coordinated via the data service;
  here the deadline path is exercised directly).
* **elastic re-mesh** — checkpoints hold unsharded logical tensors, so a
  restart may come up on a different device count and re-shard.
* **tendency monitor** — every `diag_every` steps the `repro.monitor`
  subsystem runs its compiled probe program (embedding table, per-layer
  activations, MoE router logits, gradient leaves) in ONE dispatch,
  appends to a `TendencyHistory` serialized atomically alongside the
  checkpoint, and reports per-probe OK/WARN/COLLAPSE drift states in
  the log line.  A collapse (block_score -> 0 and k_est -> 1) is the
  embedding/router degeneracy signature.
"""
from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.data.tokens import SyntheticCorpus, make_batch
from repro.checkpoint import ckpt
from repro.monitor import STATE_CODES, TendencyMonitor
from repro.train import steps as S


def train(cfg: ModelConfig, tc: TrainConfig, shape: ShapeConfig,
          *, steps: int | None = None, log: Callable[[str], None] = print,
          step_deadline_s: float = 0.0, param_dtype=jnp.float32,
          interrupt_at: int | None = None,
          monitor: TendencyMonitor | None = None):
    """Run (or resume) training; returns (state, history list of metric dicts).

    interrupt_at: test hook — raise KeyboardInterrupt after that step to
    simulate a node failure between checkpoint and completion.
    monitor: optional pre-built TendencyMonitor (custom probes/thresholds);
    defaults to `TendencyMonitor(cfg, seed=tc.seed)`.
    """
    steps = steps or tc.total_steps
    train_step = jax.jit(S.build_train_step(cfg, tc), donate_argnums=(0,))
    corpus = SyntheticCorpus(cfg.vocab, seed=tc.seed)
    mon = monitor if monitor is not None else TendencyMonitor(cfg, seed=tc.seed)

    state = S.init_state(cfg, tc, jax.random.PRNGKey(tc.seed), param_dtype)
    start = 0
    restored, manifest = ckpt.restore(tc.ckpt_dir, state)
    if restored is not None:
        state, start = restored, manifest["step"]
        mon.restore(tc.ckpt_dir, start)
        log(f"[resume] restored step {start} from {tc.ckpt_dir} "
            f"({len(mon.history)} tendency rows)")

    history = []
    skipped = 0
    for step in range(start, steps):
        t0 = time.monotonic()
        batch = make_batch(cfg, shape, step=step, corpus=corpus)
        if step_deadline_s and (time.monotonic() - t0) > step_deadline_s:
            skipped += 1           # straggler: drop the batch, keep cadence
            log(f"[straggler] step {step}: data late, skipped "
                f"({skipped} total)")
            continue
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        state, metrics = train_step(state, batch)

        if (step + 1) % tc.diag_every == 0:
            summ = mon.observe(step + 1, state.params, batch)
            emb = summ[mon.specs[0].name]
            metrics = dict(metrics, vat_block_score=emb["block_score"],
                           vat_k_est=emb["k_est"], hopkins=emb["hopkins"])
            for name, s in summ.items():
                metrics[f"tendency/{name}/block_score"] = s["block_score"]
                metrics[f"tendency/{name}/k_est"] = s["k_est"]
                metrics[f"tendency/{name}/hopkins"] = s["hopkins"]
                metrics[f"tendency/{name}/state"] = STATE_CODES[s["state"]]
            log(f"[tendency] step {step + 1}: {mon.status_line(summ)}")
        history.append({k: float(v) for k, v in metrics.items()})
        if (step + 1) % tc.ckpt_every == 0 or step == steps - 1:
            path = ckpt.save(tc.ckpt_dir, step + 1, state,
                             aux_arrays=mon.save_arrays())
            log(f"[ckpt] step {step + 1} -> {path}")
        if step % 10 == 0:
            log(f"step {step}: loss={history[-1]['loss']:.4f}")
        if interrupt_at is not None and step + 1 >= interrupt_at:
            raise KeyboardInterrupt(f"simulated failure at step {step + 1}")
    return state, history
