"""LRU-bounded AOT program cache (ISSUE 7 tentpole, part 1).

Every distinct compiled fit path the server can dispatch is named by a
:class:`ProgramKey` — the full set of knobs that change generated code.
The cache maps keys to ``jax.jit(...).lower(...).compile()`` artifacts
so no request ever pays trace/compile time twice: a warm-cache request
runs the stored ``Compiled`` executable without re-entering Python
tracing at all (tests/test_serve.py pins this with a trace census).

The key contract (documented in docs/serving.md and pinned by the
key-distinctness tests): if a knob can alter the jaxpr or the lowered
HLO, it MUST appear in the key.  That is rung, padded shape
(b_bucket, n_bucket, d), metric, device-mesh fingerprint, turbo mode,
kNN fan-out, the Pallas toggle, svat's sample size, and the numerics
shield's resolved plan (tile form + storage dtype).  Seeds and request
deadlines are runtime data, not key material.

Capacity is a hard bound: inserting past it evicts the least recently
used program (compiled artifacts hold device buffers; an unbounded
cache is a memory leak with extra steps).  Hit/miss/eviction counters
are exposed via :meth:`ProgramCache.stats` and surface in the server's
``stats()`` and the bench "serve" table.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Any, Callable

import jax


@dataclasses.dataclass(frozen=True)
class ProgramKey:
    """Identity of one compiled fit program.

    Attributes:
      rung: registry rung name ("vat", "ivat", "flashvat", ...).
      b_bucket: padded lane count (0 while the request is queued and
        the group size is still unknown; see :meth:`with_batch`).
      n_bucket: padded row count (exact n for rungs that cannot be
        row-padded, e.g. flashvat's band renderer).
      d: feature dimension (never padded — it changes the math).
      metric: dissimilarity metric baked into the kernel.
      mesh: device-mesh fingerprint from :func:`mesh_fingerprint`.
      turbo: flashvat engine pin (RungOptions.turbo) — changes the
        generated traversal code.
      knn_k: approx-rung kNN fan-out.
      use_pallas: kernel-dispatch toggle.
      sample_size: svat's maximin sample size.
      num_form: the numerics shield's tile form ("gram" | "direct") —
        resolved host-side per request (``numerics.resolve``) and baked
        statically into the kernels, so it is key material: a
        direct-form batch must never ride a Gram-form program.
      num_dtype: resolved coordinate-storage precision ("f32" | "bf16")
        — bf16 requests that pass certification key separately so their
        quantized lanes never coalesce with full-precision ones.
    """
    rung: str
    b_bucket: int
    n_bucket: int
    d: int
    metric: str
    mesh: str
    turbo: bool | None = None
    knn_k: int = 15
    use_pallas: bool = False
    sample_size: int = 256
    num_form: str = "gram"
    num_dtype: str = "f32"

    def with_batch(self, b_bucket: int) -> "ProgramKey":
        """The same program family at a concrete lane count."""
        return dataclasses.replace(self, b_bucket=b_bucket)


def mesh_fingerprint() -> str:
    """Stable string naming the visible device mesh, e.g. ``"cpu:1"``.

    Programs are compiled against a concrete device set; a different
    mesh is different code, so this lands in every ProgramKey.
    """
    devices = jax.devices()
    return f"{devices[0].platform}:{len(devices)}"


@dataclasses.dataclass(frozen=True)
class CacheStats:
    """Point-in-time counters for a :class:`ProgramCache`."""
    hits: int
    misses: int
    evictions: int
    size: int
    capacity: int

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class ProgramCache:
    """Thread-safe LRU map from :class:`ProgramKey` to compiled program.

    ``get`` is the only mutation path: on a miss it calls ``build()``
    (outside nothing — compilation is serialized under the lock, which
    is deliberate: two threads racing to compile the same program would
    both pay the compile and one result would be discarded).
    """

    def __init__(self, capacity: int = 32):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self._capacity = capacity
        self._lock = threading.Lock()
        self._programs: OrderedDict[ProgramKey, Any] = OrderedDict()
        self._hits = 0
        self._misses = 0
        self._evictions = 0

    def get(self, key: ProgramKey, build: Callable[[], Any]) -> Any:
        """Return the program for ``key``, building+caching on miss."""
        with self._lock:
            if key in self._programs:
                self._hits += 1
                self._programs.move_to_end(key)
                return self._programs[key]
            self._misses += 1
            program = build()
            self._programs[key] = program
            while len(self._programs) > self._capacity:
                self._programs.popitem(last=False)
                self._evictions += 1
            return program

    def stats(self) -> CacheStats:
        with self._lock:
            return CacheStats(hits=self._hits, misses=self._misses,
                              evictions=self._evictions,
                              size=len(self._programs),
                              capacity=self._capacity)

    def clear(self) -> None:
        with self._lock:
            self._programs.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._programs)

    def __contains__(self, key: ProgramKey) -> bool:
        with self._lock:
            return key in self._programs
