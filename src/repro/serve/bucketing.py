"""Shape bucketing for the serving layer — pad-to-bucket without
perturbing the ordering (ISSUE 7 tentpole, part 2).

Real-world traffic carries arbitrary (n, d) shapes; compiling one XLA
program per exact shape would defeat the AOT cache.  This module
collapses the n axis onto power-of-2 buckets so a handful of programs
cover the whole shape distribution, and the batch axis onto power-of-2
lane counts so coalesced groups of any size reuse log2(max_batch)+1
programs per bucket.

Padding rows must not perturb the VAT ordering of the real points —
the served result has to be *bitwise* identical to the solo fit.  The
scheme that achieves this is **dup-row-0 padding**: rows n..bucket-1
of the padded matrix are copies of row 0.

Why dup-row-0 padding is exact (not just approximately harmless):

* Every padding point has a distance row identical to point 0's (its
  self-distance and its distance to the other dups are 0, matching
  point 0's diagonal entry).  While point 0 is unselected, a padding
  point's frontier value therefore equals point 0's at every Prim
  step.
* The kernels break ties by **first index** (``argmin``/``argmax``
  over a row pick the lowest index at equal value), and every padding
  index is >= n, so whenever a padding point is the frontier argmin a
  real point (point 0, or a lower-indexed real tie) wins instead —
  no padding point is ever selected before point 0.
* Padding points are NOT ordered after all real points: the moment
  point 0 enters the tree their frontier distance becomes
  ``d(X[0], X[0]) = 0``, so they ride in right after point 0 (real
  points at frontier 0 still win the tie).  That is harmless, because
  a duplicate of an already-selected point changes nothing: for every
  unselected point x, ``d(x, dup) = d(x, X[0])`` is already folded
  into x's frontier minimum, so no remaining frontier value — and no
  argmin tie-break among real points — moves.  The real-point
  subsequence of the padded ordering is therefore exactly the
  unpadded ordering, selected at the same frontier distances.
* The seed ``argmax(max(R, axis=1))`` cannot pick a padding row: its
  row maximum equals row 0's, and row 0 has the lower index.
* iVAT's path-max folds over duplicate rows are no-ops (folding a row
  with itself changes nothing), so the restricted geodesic image is
  unchanged too.

tests/test_serve.py pins all of this bitwise at bucket boundaries +-1
for every metric (property tests via the hypothesis stub).

``precomputed`` matrices cannot be padded this way — appending a
duplicate row to an (n, n) matrix does not yield an (n+1, n+1)
matrix — so :func:`ensure_bucketable` rejects the metric up front with
an actionable error instead of serving a silently wrong result.
"""
from __future__ import annotations

import numpy as np

#: Smallest n-bucket — shapes below this all share one program.
MIN_BUCKET = 64


def _next_pow2(x: int) -> int:
    return 1 << max(0, (int(x) - 1).bit_length())


def bucket_n(n: int) -> int:
    """Smallest power-of-2 bucket >= max(n, MIN_BUCKET).

    Args:
      n: real number of points in the request.

    Returns:
      The padded row count the compiled program will see.
    """
    if n < 1:
        raise ValueError(f"need at least one point, got n={n}")
    return _next_pow2(max(n, MIN_BUCKET))


def bucket_batch(b: int) -> int:
    """Smallest power-of-2 lane count >= b (>= 1)."""
    if b < 1:
        raise ValueError(f"need at least one request, got b={b}")
    return _next_pow2(b)


def ensure_bucketable(metric: str) -> None:
    """Reject metrics the padding scheme cannot serve.

    Raises:
      ValueError: for ``precomputed`` — a padded (n, n) matrix is not
        an (n_bucket, n_bucket) matrix; fit it directly via
        ``FastVAT.fit`` instead.
    """
    if metric == "precomputed":
        raise ValueError(
            "the serving layer cannot bucket metric='precomputed' "
            "(padding feature rows does not extend a distance matrix); "
            "use FastVAT(metric='precomputed').fit(D) directly")


def pad_rows(X: np.ndarray, n_bucket: int) -> np.ndarray:
    """Pad (n, d) -> (n_bucket, d) with copies of row 0 (see module
    docstring for why this is ordering-exact)."""
    n = X.shape[0]
    if n > n_bucket:
        raise ValueError(f"n={n} exceeds bucket {n_bucket}")
    if n == n_bucket:
        return X
    fill = np.broadcast_to(X[0], (n_bucket - n,) + X.shape[1:])
    return np.concatenate([X, fill], axis=0)


def pack_batch(Xs: list[np.ndarray], n_bucket: int,
               b_bucket: int) -> np.ndarray:
    """Stack requests into one (b_bucket, n_bucket, d) float32 block.

    Each dataset is row-padded to ``n_bucket``; empty lanes (when the
    group is smaller than ``b_bucket``) are copies of lane 0 — vmapped
    lanes are independent, so dup lanes cost compute but cannot perturb
    the real lanes' results.

    Args:
      Xs: the coalesced group's feature matrices, all with the same d.
      n_bucket: target row count (every ``len(X) <= n_bucket``).
      b_bucket: target lane count (``>= len(Xs)``).

    Returns:
      float32 array of shape (b_bucket, n_bucket, d).
    """
    if not Xs:
        raise ValueError("pack_batch needs at least one dataset")
    if b_bucket < len(Xs):
        raise ValueError(f"{len(Xs)} requests exceed lane bucket {b_bucket}")
    lanes = [pad_rows(np.asarray(X, dtype=np.float32), n_bucket)
             for X in Xs]
    lanes.extend(lanes[0] for _ in range(b_bucket - len(lanes)))
    return np.stack(lanes, axis=0)


def real_positions(order_pad: np.ndarray, n: int) -> np.ndarray:
    """Positions within the padded ordering that hold real points.

    Args:
      order_pad: the (n_bucket,) ordering from the padded fit.
      n: the real point count; indices < n are real.

    Returns:
      Increasing positions p with ``order_pad[p] < n`` — by the
      dup-row argument these select exactly the unpadded ordering.
    """
    return np.flatnonzero(np.asarray(order_pad) < n)


def restrict(M: np.ndarray, pos: np.ndarray) -> np.ndarray:
    """Restrict a padded (n_bucket, n_bucket) image to the real
    positions on both axes — the unpadded image, bitwise."""
    M = np.asarray(M)
    return M[np.ix_(pos, pos)]
