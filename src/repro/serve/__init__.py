"""Tendency-as-a-service: the serving layer over FastVAT (ISSUE 7).

Public surface:

  * :class:`TendencyServer` / :class:`ServeConfig` — the coalescing,
    AOT-cached server (``submit`` -> Future, ``fit`` sync, ``warm``,
    ``stats``).
  * :class:`ProgramCache` / :class:`ProgramKey` — the LRU AOT program
    cache and its key contract.
  * bucketing helpers — ordering-exact pad-to-bucket shape collapse.
  * :class:`CoalescerCore` + the error taxonomy — the clock-free
    scheduling state machine the deterministic test rig drives.

See docs/serving.md for the architecture and the cache-key contract.
"""
from repro.serve.bucketing import (MIN_BUCKET, bucket_batch, bucket_n,
                                   ensure_bucketable, pack_batch, pad_rows,
                                   real_positions, restrict)
from repro.serve.cache import (CacheStats, ProgramCache, ProgramKey,
                               mesh_fingerprint)
from repro.api.validation import InvalidInput
from repro.serve.coalesce import (Backpressure, Batch, CoalescerCore,
                                  DeadlineExceeded, ExecutionError,
                                  ServeError, ServeRequest)
from repro.serve.resilience import (BreakerConfig, CircuitBreaker,
                                    ResilienceStats, RetryPolicy,
                                    breaker_family, fallback_chain)
from repro.serve.server import (PADDED_RUNGS, SERVABLE, ServeConfig,
                                ServeStats, TendencyServer, resolve_key,
                                trace_census, reset_trace_census)

__all__ = [
    "MIN_BUCKET", "bucket_batch", "bucket_n", "ensure_bucketable",
    "pack_batch", "pad_rows", "real_positions", "restrict",
    "CacheStats", "ProgramCache", "ProgramKey", "mesh_fingerprint",
    "Backpressure", "Batch", "CoalescerCore", "DeadlineExceeded",
    "ExecutionError", "InvalidInput", "ServeError", "ServeRequest",
    "BreakerConfig", "CircuitBreaker", "ResilienceStats", "RetryPolicy",
    "breaker_family", "fallback_chain",
    "PADDED_RUNGS", "SERVABLE", "ServeConfig", "ServeStats",
    "TendencyServer", "resolve_key", "trace_census", "reset_trace_census",
]
