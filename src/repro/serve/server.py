"""TendencyServer — the tendency-as-a-service front door (ISSUE 7).

Composes the three serving mechanisms into one object:

  * :class:`~repro.serve.cache.ProgramCache` — AOT-compiled fit
    programs (``jax.jit(...).lower(...).compile()``), LRU-bounded, so a
    warm-cache request never pays trace or compile time;
  * :mod:`~repro.serve.bucketing` — power-of-2 shape buckets with
    ordering-exact dup-row-0 padding, collapsing shape diversity onto
    a small program set;
  * :class:`~repro.serve.coalesce.CoalescerCore` — same-bucket requests
    within a window ride one batched ``fit_batch`` dispatch.

Routing: ``method="auto"`` without an SLO uses the registry's
size-based policy (``select_method`` over the batch-capable rungs);
with ``slo_ms`` it asks the cost-model router
(``select_method_for_slo``) for the highest-fidelity rung the latency
budget affords.

Rung coverage: the servable set is the batch-capable rungs — vat, ivat,
flashvat.  vat/ivat are row-padded to n-buckets (the padding is proven
ordering-exact; see bucketing.py); flashvat programs key on the EXACT n
because its band-render shapes (group sizes, representative count) are
functions of n itself — flashvat still benefits from program reuse
across requests of the same n and from batch-lane coalescing.

Every served result is bitwise-identical to the solo
``FastVAT(...).fit(X)`` result — tests/test_serve.py pins this across
rungs, metrics, and concurrent mixed-shape load.

Threading model: ``submit`` enqueues under one condition variable and
returns a ``concurrent.futures.Future``; a single daemon dispatcher
thread replays coalescer events and executes ready batches OUTSIDE the
lock (compile/execute never block submitters).  All scheduling
decisions live in the clock-free ``CoalescerCore``, so the identical
logic is driven by the virtual-clock rig in tests with zero real
sleeps.

>>> import numpy as np
>>> from repro.serve import TendencyServer
>>> rng = np.random.default_rng(0)
>>> X = rng.normal(size=(100, 4)).astype(np.float32)
>>> with TendencyServer() as srv:
...     res = srv.fit(X)                       # submit().result()
...     same = srv.fit(X)                      # warm cache, zero traces
>>> bool(np.array_equal(np.asarray(res.order), np.asarray(same.order)))
True
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from concurrent.futures import Future

import numpy as np

import jax
import jax.numpy as jnp

from repro import faults
from repro.api.metrics import validate_metric
from repro.api.registry import (RungOptions, get_rung, select_method,
                                select_method_for_slo)
from repro.api.result import ResultMeta, TendencyResult
from repro.api.validation import InvalidInput, validate_points
from repro.serve.bucketing import (bucket_batch, bucket_n, ensure_bucketable,
                                   pack_batch, real_positions, restrict)
from repro.serve.cache import (CacheStats, ProgramCache, ProgramKey,
                               mesh_fingerprint)
from repro.serve.coalesce import (Batch, CoalescerCore, DeadlineExceeded,
                                  ExecutionError, ServeError, ServeRequest)
from repro.serve.resilience import (BreakerConfig, CircuitBreaker,
                                    ResilienceCounters, ResilienceStats,
                                    RetryPolicy, breaker_family,
                                    fallback_chain)
from repro.numerics import NumericsPolicy
from repro.numerics import resolve as resolve_numerics

#: Rungs the server dispatches — exactly the batch-capable registry set.
SERVABLE = ("vat", "ivat", "flashvat")
#: Rungs whose rows may be padded to n-buckets (ordering-exact dup-row
#: padding); flashvat is excluded — its band-render shapes depend on the
#: exact n, so its programs key on n itself.
PADDED_RUNGS = ("vat", "ivat")

# Trace census in the style of kernels.ops.kernel_dispatch_stats: the
# counter increments inside the jitted fit fn, so it only moves at TRACE
# time — executing a cached compiled program leaves it untouched.  The
# census tests pin "warm cache => zero new traces" with it.
_TRACE_CENSUS = {"traces": 0}


def trace_census() -> dict:
    """Copy of the trace counters ({"traces": total trace entries})."""
    return dict(_TRACE_CENSUS)


def reset_trace_census() -> None:
    """Zero the trace counters (test isolation)."""
    _TRACE_CENSUS["traces"] = 0


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Server knobs — everything that shapes programs or scheduling.

    Attributes:
      window_s: coalescing window in seconds — a bucket's first request
        waits at most this long for companions.
      max_batch: a group dispatches immediately at this many requests.
      max_pending: bounded-queue limit; past it ``submit`` raises
        :class:`~repro.serve.coalesce.Backpressure`.
      cache_capacity: LRU bound of the AOT program cache.
      sample_size: flashvat's rendered representative count (key
        material — it changes compiled shapes).
      use_pallas: route kernels through Pallas (key material).
      turbo: flashvat engine pin (key material).
      knn_k: approx-rung fan-out carried in the key for forward
        compatibility (the approx rung has no batched fitter yet).
      seed: the single seed baked into every program's ResultMeta —
        served results match solo fits of the same seed.
      drift_window: opt-in serving-side drift detection (0 = off, the
        default — the warm path stays byte-identical).  When > 0, every
        served result's (block_score, k_est) summary feeds a
        ``repro.monitor.drift.DriftDetector`` whose StreamingVAT window
        holds this many summaries; the current OK/WARN/COLLAPSE state
        is surfaced on ``stats().drift``.
      validate: admission-check every submitted X (finite, real dtype,
        n >= 4, non-degenerate) and refuse poison with the typed
        :class:`~repro.api.validation.InvalidInput` *before* it can
        join a coalesced batch (rejects counted on
        ``stats().resilience.invalid_rejects``).
      retry: bounded jittered retry schedule applied at each fallback
        level (see ``repro.serve.resilience``).
      breaker: circuit-breaker thresholds; after ``breaker.threshold``
        consecutive primary failures a key family is pinned to its
        fallback chain until ``breaker.cooldown_s`` elapses on the
        server clock, then re-probed once.
      numerics: the numerics shield's policy
        (``repro.numerics.NumericsPolicy``) applied host-side to every
        submitted X before it can join a batch.  The resolved plan
        (tile form, storage dtype) becomes key material
        (``ProgramKey.num_form`` / ``num_dtype``), the per-request
        report is stamped on each unpacked result's meta, and bf16
        certification fallbacks are counted on
        ``stats().resilience.numerics_fallbacks``.
    """
    window_s: float = 0.002
    max_batch: int = 8
    max_pending: int = 256
    cache_capacity: int = 32
    sample_size: int = 256
    use_pallas: bool = False
    turbo: bool | None = None
    knn_k: int = 15
    seed: int = 0
    drift_window: int = 0
    validate: bool = True
    retry: RetryPolicy = RetryPolicy()
    breaker: BreakerConfig = BreakerConfig()
    numerics: NumericsPolicy = NumericsPolicy()


def resolve_key(n: int, d: int, *, method: str = "auto",
                metric: str = "euclidean",
                config: ServeConfig = ServeConfig(),
                slo_ms: float | None = None,
                mesh: str | None = None,
                num_form: str = "gram",
                num_dtype: str = "f32") -> ProgramKey:
    """Route a request shape to its program-cache group key.

    Pure function of its arguments (no server state), so tests and the
    virtual-clock rig build keys exactly the way ``submit`` does.

    Args:
      n, d: the request's real shape.
      method: "auto" or a name in :data:`SERVABLE`.
      metric: dissimilarity metric (``precomputed`` is rejected — see
        ``ensure_bucketable``).
      config: the server's program-shaping knobs.
      slo_ms: latency budget in milliseconds; with ``method="auto"``
        routes through the cost-model router instead of the size policy.
      mesh: device-mesh fingerprint override (defaults to the live one).
      num_form / num_dtype: the numerics shield's resolved plan for the
        request's data (``numerics.resolve``) — key material, since the
        tile form and storage precision are baked into the program.

    Returns:
      The group :class:`ProgramKey` with ``b_bucket=0`` (lane count is
      bound at dispatch via ``with_batch``).

    Raises:
      ValueError: unservable metric/method, or n beyond every servable
        rung's auto window.
    """
    validate_metric(metric)
    ensure_bucketable(metric)
    if method == "auto":
        if slo_ms is not None:
            method = select_method_for_slo(n, slo_ms * 1e3,
                                           restrict=SERVABLE)
        else:
            try:
                method = select_method(n, batched=True, strict=True)
            except LookupError:
                raise ValueError(
                    f"n={n} exceeds every servable rung's window "
                    f"(servable: {list(SERVABLE)}); fit it directly via "
                    "FastVAT (the approx rung has no batched fitter "
                    "yet)") from None
    if method not in SERVABLE:
        raise ValueError(f"the serving layer dispatches {list(SERVABLE)}, "
                         f"got method={method!r}")
    n_bucket = bucket_n(n) if method in PADDED_RUNGS else n
    return ProgramKey(rung=method, b_bucket=0, n_bucket=n_bucket, d=d,
                      metric=metric,
                      mesh=mesh if mesh is not None else mesh_fingerprint(),
                      turbo=config.turbo, knn_k=config.knn_k,
                      use_pallas=config.use_pallas,
                      sample_size=config.sample_size,
                      num_form=num_form, num_dtype=num_dtype)


def _build_program(key: ProgramKey, seed: int):
    """AOT-compile the batched fit program for a concrete ProgramKey.

    ``jax.jit(fit).lower(spec).compile()`` traces exactly once, here;
    the returned ``Compiled`` executable never re-enters Python, which
    is what the warm-cache zero-trace census pin rests on.
    """
    if key.b_bucket < 1:
        raise ValueError(f"program wants a concrete lane count, got "
                         f"b_bucket={key.b_bucket} (call with_batch first)")
    faults.fault_point("serve.build", context={"key": key,
                                               "rung": key.rung,
                                               "use_pallas": key.use_pallas})
    rung = get_rung(key.rung)
    meta = ResultMeta(method=key.rung, metric=key.metric, n=key.n_bucket,
                      batch=key.b_bucket, seed=seed,
                      sample_size=key.sample_size,
                      use_pallas=key.use_pallas)
    opts = RungOptions(sample_size=key.sample_size, turbo=key.turbo,
                       knn_k=key.knn_k, num_form=key.num_form)

    def fit(Xs):
        _TRACE_CENSUS["traces"] += 1
        return rung.fit_batch(Xs, meta, opts)

    spec = jax.ShapeDtypeStruct((key.b_bucket, key.n_bucket, key.d),
                                jnp.float32)
    return jax.jit(fit).lower(spec).compile()


def _unpack(key: ProgramKey, res: TendencyResult, lane: int,
            n: int, seed: int, numerics=None) -> TendencyResult:
    """Extract one request's solo-equivalent result from a batched fit.

    For the padded rungs the real-point subsequence of the padded
    ordering IS the unpadded ordering (bucketing.py's dup-row
    argument), so slicing the lane at the real positions reproduces the
    solo fit bitwise.  flashvat lanes are unpadded — take the lane.
    ``numerics`` is the request's own resolved plan (NumericsReport),
    stamped on the solo-equivalent meta exactly where FastVAT stamps it.
    """
    meta = ResultMeta(method=key.rung, metric=key.metric, n=n, batch=None,
                      seed=seed, sample_size=key.sample_size,
                      use_pallas=key.use_pallas, numerics=numerics)
    if key.rung in PADDED_RUNGS:
        order_pad = np.asarray(res.order[lane])
        pos = real_positions(order_pad, n)
        iv = res.ivat_image
        return TendencyResult(
            order=order_pad[pos],
            rstar=restrict(np.asarray(res.rstar[lane]), pos),
            ivat_image=(None if iv is None
                        else restrict(np.asarray(iv[lane]), pos)),
            sample_idx=None, extension_labels=None, meta=meta)
    return TendencyResult(
        order=np.asarray(res.order[lane]),
        rstar=np.asarray(res.rstar[lane]),
        ivat_image=(None if res.ivat_image is None
                    else np.asarray(res.ivat_image[lane])),
        sample_idx=(None if res.sample_idx is None
                    else np.asarray(res.sample_idx[lane])),
        extension_labels=(None if res.extension_labels is None
                          else np.asarray(res.extension_labels[lane])),
        group_sizes=(None if res.group_sizes is None
                     else np.asarray(res.group_sizes)),
        meta=meta)


@dataclasses.dataclass(frozen=True)
class ServeStats:
    """Point-in-time server counters (scheduler + program cache).

    ``drift`` is the serving-side tendency drift state ("OK" / "WARN" /
    "COLLAPSE") when ``ServeConfig.drift_window`` is enabled, else None.
    ``resilience`` carries the degradation-ladder counters (fallbacks,
    splits, retries, breaker state, admission rejects) — all zero /
    empty on a healthy server; see ``repro.serve.resilience``.
    """
    cache: CacheStats
    submitted: int
    dispatched_batches: int
    dispatched_requests: int
    timeouts: int
    rejected: int
    pending: int
    drift: str | None = None
    resilience: ResilienceStats = ResilienceStats()

    @property
    def coalesce_rate(self) -> float:
        """Mean requests per dispatched batch (1.0 = no coalescing)."""
        if not self.dispatched_batches:
            return 0.0
        return self.dispatched_requests / self.dispatched_batches


class TendencyServer:
    """Coalescing, AOT-cached cluster-tendency server (see module doc).

    Args:
      config: scheduling + program-shaping knobs.
      clock: monotonic time source — injectable so the deterministic
        rig can drive the same scheduling logic with a virtual clock.
      sleep: blocking wait used for retry backoff (and armed delay
        faults) — injectable alongside ``clock`` so chaos tests advance
        a virtual clock instead of really sleeping.
    """

    def __init__(self, config: ServeConfig = ServeConfig(), *,
                 clock=time.monotonic, sleep=time.sleep):
        self.config = config
        self._clock = clock
        self._sleep = sleep
        self._drift = None
        if config.drift_window > 0:
            from repro.monitor.drift import DriftConfig, DriftDetector
            self._drift = DriftDetector(
                DriftConfig(window=config.drift_window))
        self._cache = ProgramCache(capacity=config.cache_capacity)
        self._core = CoalescerCore(window=config.window_s,
                                   max_batch=config.max_batch,
                                   max_pending=config.max_pending)
        self._counters = ResilienceCounters()
        self._breakers: dict[str, CircuitBreaker] = {}
        self._cv = threading.Condition()
        self._ready: deque[Batch] = deque()
        self._inflight: list[ServeRequest] = []
        self._closed = False
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="tendency-serve-dispatch")
        self._thread.start()

    # ---------------------------------------------------------- submit ----

    def submit(self, X, *, metric: str = "euclidean",
               method: str = "auto", slo_ms: float | None = None,
               timeout_s: float = 30.0, tag=None) -> Future:
        """Enqueue one fit; returns a Future of its TendencyResult.

        Args:
          X: (n, d) feature matrix.
          metric: dissimilarity metric (not "precomputed").
          method: "auto" (size/SLO routed) or a :data:`SERVABLE` name.
          slo_ms: latency budget for the cost-model router.
          timeout_s: per-request deadline; still queued past it => the
            future fails with :class:`DeadlineExceeded`.
          tag: caller label, carried on the request (test bookkeeping).

        Returns:
          Future resolving to a solo-equivalent
          :class:`~repro.api.result.TendencyResult`.

        Raises:
          InvalidInput: admission refused X (non-finite / bad dtype /
            degenerate) — the request never reached a batch.
          Backpressure: the bounded queue is full.
          ServeError: the server is closed.
          ValueError: unservable shape/metric/method.
        """
        if self.config.validate:
            try:
                validate_points(X, metric=metric)
            except InvalidInput:
                self._counters.bump("invalid_rejects")
                raise
        X = np.asarray(X, dtype=np.float32)
        if X.ndim != 2:
            raise ValueError(f"submit wants an (n, d) matrix, got shape "
                             f"{X.shape}")
        # The numerics shield runs host-side at admission, exactly like
        # the solo facade: X becomes the conditioned (possibly bf16
        # -quantized) copy and the resolved plan keys the program, so a
        # direct-form request can never ride a Gram-form batch.
        X, num_report = resolve_numerics(X, metric=metric,
                                         policy=self.config.numerics)
        if num_report.fallbacks:
            self._counters.bump("numerics_fallbacks", num_report.fallbacks)
        n, d = int(X.shape[0]), int(X.shape[1])
        key = resolve_key(n, d, method=method, metric=metric,
                          config=self.config, slo_ms=slo_ms,
                          num_form=num_report.form,
                          num_dtype=num_report.dtype)
        now = self._clock()
        req = ServeRequest(X=X, n=n, key=key, arrival=now,
                           deadline=now + timeout_s, future=Future(),
                           tag=tag, numerics=num_report)
        # Poll-then-enqueue: due flushes/expiries are pulled out of the
        # core and handed to the dispatcher BEFORE the bound check, so a
        # Backpressure rejection can never strand a flushed batch (its
        # futures would otherwise hang forever).  Expired futures are
        # failed outside the lock on every exit path.
        expired: list[ServeRequest] = []
        try:
            with self._cv:
                if self._closed:
                    raise ServeError("server is closed")
                try:
                    batches, expired = self._core.poll(now)
                    self._ready.extend(batches)
                    flush = self._core.try_enqueue(req, now)
                    if flush is not None:
                        self._ready.append(flush)
                finally:
                    self._cv.notify()
        finally:
            for r in expired:
                self._fail_expired(r)
        return req.future

    def fit(self, X, **kwargs) -> TendencyResult:
        """Synchronous convenience: ``submit(X, **kwargs).result()``."""
        return self.submit(X, **kwargs).result()

    def warm(self, n: int, d: int, *, metric: str = "euclidean",
             method: str = "auto", slo_ms: float | None = None,
             batch: int = 1, num_form: str = "gram",
             num_dtype: str = "f32") -> ProgramKey:
        """Pre-compile the program a future (n, d) request will hit.

        Pass the same ``slo_ms`` the requests will carry: with an SLO
        the router may pick a different rung than the size policy, and
        warming must target the key those requests resolve to or they
        pay trace+compile on the serving path anyway.  Likewise
        ``num_form`` / ``num_dtype``: requests whose data resolves to a
        direct-form or bf16 plan hit a different program — warm with
        the plan ``numerics.resolve`` will produce for the real data.

        Returns the concrete (batched) ProgramKey that was compiled —
        a subsequent matching request is a pure cache hit.
        """
        key = resolve_key(n, d, method=method, metric=metric,
                          config=self.config, slo_ms=slo_ms,
                          num_form=num_form,
                          num_dtype=num_dtype).with_batch(bucket_batch(batch))
        self._cache.get(key, lambda: _build_program(key, self.config.seed))
        return key

    # ----------------------------------------------------- introspection --

    def stats(self) -> ServeStats:
        with self._cv:
            return ServeStats(cache=self._cache.stats(),
                              submitted=self._core.submitted,
                              dispatched_batches=self._core.dispatched_batches,
                              dispatched_requests=self._core.dispatched_requests,
                              timeouts=self._core.timeouts,
                              rejected=self._core.rejected,
                              pending=self._core.pending,
                              drift=(None if self._drift is None
                                     else self._drift.state),
                              resilience=self._counters.snapshot(
                                  self._breakers))

    def breaker_state(self, n: int, d: int, *, metric: str = "euclidean",
                      method: str = "auto",
                      slo_ms: float | None = None) -> str:
        """Breaker state ("CLOSED"/"OPEN"/"HALF_OPEN") for the key
        family an (n, d) request resolves to — introspection for tests
        and the chaos CLI."""
        from repro.serve.resilience import CLOSED
        key = resolve_key(n, d, method=method, metric=metric,
                          config=self.config, slo_ms=slo_ms)
        b = self._breakers.get(breaker_family(key))
        return CLOSED if b is None else b.state

    # --------------------------------------------------------- lifecycle --

    def close(self) -> None:
        """Stop accepting work, drain queued requests, join the thread.

        Queued requests still within deadline are dispatched (possibly
        before their window elapsed); expired ones fail with
        DeadlineExceeded.
        """
        with self._cv:
            if self._closed:
                return
            self._closed = True
            self._cv.notify()
        self._thread.join()

    def __enter__(self) -> "TendencyServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # --------------------------------------------------------- internals --

    def _fail_expired(self, req: ServeRequest) -> None:
        if not req.future.done():
            req.future.set_exception(DeadlineExceeded(
                f"request (n={req.n}, rung={req.key.rung}) expired after "
                f"{req.deadline - req.arrival:.3f}s in queue"))

    def _run(self) -> None:
        """Dispatcher entry: run the loop; if it ever dies on an
        unexpected error, fail every outstanding future with a typed
        ServeError instead of leaving callers hanging on result()."""
        try:
            self._run_loop()
        except BaseException as exc:  # noqa: BLE001 — last-resort failsafe
            self._emergency_shutdown(exc)

    def _emergency_shutdown(self, exc: BaseException) -> None:
        """The dispatcher died: close the server and fail everything
        queued (core groups, ready batches) so no future hangs."""
        stranded: list[ServeRequest] = []
        with self._cv:
            self._closed = True
            try:
                batches, expired = self._core.drain(float("inf"))
            except Exception:  # noqa: BLE001 — even a broken core drains
                batches, expired = [], []
                for reqs in getattr(self._core, "_groups", {}).values():
                    stranded.extend(reqs)
            for b in list(self._ready) + list(batches):
                stranded.extend(b.requests)
            stranded.extend(expired)
            stranded.extend(self._inflight)   # the batch that killed us
            self._ready.clear()
            self._inflight = []
        for req in stranded:
            if not req.future.done():
                req.future.set_exception(ServeError(
                    f"dispatcher thread died: {exc!r}"))

    def _run_loop(self) -> None:
        """Dispatcher loop: replay coalescer events, execute batches
        outside the lock, exit after a drained close."""
        while True:
            with self._cv:
                while True:
                    now = self._clock()
                    batches, expired = self._core.poll(now)
                    self._ready.extend(batches)
                    if self._ready or expired or self._closed:
                        break
                    event = self._core.next_event()
                    wait = (None if event is None
                            else max(0.0, event[0] - now))
                    self._cv.wait(timeout=wait)
                if self._closed:
                    drained, late = self._core.drain(self._clock())
                    self._ready.extend(drained)
                    expired = list(expired) + late
                todo = list(self._ready)
                self._ready.clear()
                # Track the pulled batches: if _execute dies on a
                # BaseException, _emergency_shutdown must still see (and
                # fail) these requests — they are in no other structure.
                self._inflight = [r for b in todo for r in b.requests]
                closed = self._closed
            for req in expired:
                self._fail_expired(req)
            for batch in todo:
                self._execute(batch)
            with self._cv:
                self._inflight = []
            if closed:
                return

    def _execute(self, batch: Batch) -> None:
        """Serve one flushed batch through the degradation ladder.

        Order of defenses (see ``repro.serve.resilience``):

          1. dispatch the whole batch down the fallback chain with
             bounded retries (breaker-gated primary);
          2. if the *batch* still fails and has >1 lanes, split it and
             retry every lane solo — one poison request must not take
             its batchmates down (their solo results are produced by
             the identical program family, so they stay bitwise-equal
             to their solo fits);
          3. a single lane that exhausts the ladder fails its future
             with the typed :class:`ExecutionError` — never the thread.
        """
        requests = [r for r in batch.requests if not r.future.done()]
        if not requests:
            return
        try:
            res, used_key = self._dispatch_ladder(batch.key, requests)
        except Exception as exc:  # noqa: BLE001 — ladder exhausted
            if len(requests) > 1:
                self._counters.bump("splits")
                for req in requests:
                    self._execute(Batch(key=batch.key, requests=[req],
                                        created=batch.created))
                return
            self._counters.bump("failed")
            err = ExecutionError(
                f"request (n={requests[0].n}, rung={batch.key.rung}) "
                f"failed after exhausting the degradation ladder: {exc!r}")
            err.__cause__ = exc
            requests[0].future.set_exception(err)
            return
        for lane, req in enumerate(requests):
            lane_res = _unpack(used_key, res, lane, req.n,
                               self.config.seed, req.numerics)
            if self._drift is not None:
                # drift only runs on the dispatcher thread; stats()
                # reads the state attribute (GIL-atomic) elsewhere
                from repro.core.vat import block_structure_score
                score, k = block_structure_score(
                    jnp.asarray(lane_res.rstar))
                self._drift.update(float(score), float(k))
            req.future.set_result(lane_res)

    def _breaker(self, family: str) -> CircuitBreaker:
        b = self._breakers.get(family)
        if b is None:
            b = CircuitBreaker(self.config.breaker)
            self._breakers[family] = b
        return b

    def _run_once(self, key: ProgramKey,
                  requests: list[ServeRequest]) -> TendencyResult:
        """One program dispatch attempt at a concrete chain level."""
        faults.fault_point(
            "serve.execute",
            context={"key": key, "lanes": len(requests),
                     "tags": [r.tag for r in requests]},
            sleep=self._sleep)
        program = self._cache.get(
            key, lambda: _build_program(key, self.config.seed))
        packed = pack_batch([r.X for r in requests],
                            key.n_bucket, key.b_bucket)
        return jax.block_until_ready(program(jnp.asarray(packed)))

    def _dispatch_ladder(self, group_key: ProgramKey,
                         requests: list[ServeRequest]):
        """Fallback chain + bounded retry + circuit breaker.

        Returns (batched TendencyResult, the concrete key that served
        it); raises the last underlying error when every level of the
        chain is exhausted.  Counter semantics (pinned by the chaos
        suite): ``retries`` += 1 per same-level re-attempt,
        ``fallbacks`` += 1 per level transition (including the
        breaker-pinned skip of the primary), ``degraded`` += lanes
        served by a non-primary level.
        """
        b = bucket_batch(len(requests))
        chain = [k.with_batch(b) for k in fallback_chain(group_key)]
        breaker = self._breaker(breaker_family(group_key))
        start = 0
        if len(chain) > 1 and not breaker.allow_primary(self._clock()):
            start = 1                      # pinned to the fallback chain
            self._counters.bump("fallbacks")
        last_exc: Exception | None = None
        for level in range(start, len(chain)):
            key = chain[level]
            for attempt in range(self.config.retry.max_attempts):
                if attempt:
                    self._counters.bump("retries")
                    self._sleep(self.config.retry.delay_s(
                        attempt - 1, seed=self.config.seed))
                try:
                    res = self._run_once(key, requests)
                except Exception as exc:  # noqa: BLE001 — degrade, don't die
                    last_exc = exc
                    continue
                if level == 0:
                    breaker.record_success(self._clock())
                else:
                    self._counters.bump("degraded", len(requests))
                return res, key
            if level == 0:
                breaker.record_failure(self._clock())
            if level + 1 < len(chain):
                self._counters.bump("fallbacks")
        assert last_exc is not None
        raise last_exc
