"""Request coalescer (ISSUE 7 tentpole, part 3) — a pure state machine.

Same-bucket requests arriving within a configurable window are packed
into one batched dispatch; the vmapped ``fit_batch`` path then amortizes
one program execution across all of them.  The scheduling logic lives
here as :class:`CoalescerCore`, a **clock-free** state machine: every
method takes the current time as an argument and returns the batches
that became ready.  Nothing in this module sleeps, spawns threads, or
reads a wall clock — that is what makes the deterministic concurrency
rig (tests/_serve_clock.py) possible: tests inject arrival times and
assert exactly which requests land in which batch, with zero real
sleeps.  The threaded :class:`~repro.serve.server.TendencyServer`
drives the same core with ``time.monotonic``.

Semantics (pinned by tests/test_serve.py):

* A group opens when the first request for a ProgramKey arrives; it
  flushes at ``opened + window`` or immediately when it reaches
  ``max_batch``, whichever comes first.
* Each request carries an absolute ``deadline``; a request still
  queued at its deadline is expired with :class:`DeadlineExceeded`.
  At the instant ``deadline == flush`` the flush wins — the request
  rides the batch (events at equal time are ordered flush-first).
* ``max_pending`` bounds the total queued requests; past it
  ``try_enqueue`` raises :class:`Backpressure` instead of buffering
  unboundedly.  Dispatch latency is the caller's signal to shed load.
  The rejection has NO side effects on the queue: callers replay due
  events via ``poll(now)`` *before* enqueueing, so a rejected submit
  can never swallow batches or expiries the poll produced.
"""
from __future__ import annotations

import dataclasses
from concurrent.futures import Future
from typing import Any

from repro.serve.cache import ProgramKey


class ServeError(RuntimeError):
    """Base class for serving-layer errors."""


class Backpressure(ServeError):
    """The bounded queue is full — retry later or shed load."""


class DeadlineExceeded(ServeError):
    """The request was still queued when its deadline passed."""


class ExecutionError(ServeError):
    """The request's dispatch failed after the whole degradation ladder
    (every fallback level, every retry) was exhausted.  ``__cause__``
    carries the last underlying error."""


@dataclasses.dataclass
class ServeRequest:
    """One queued fit request.

    Attributes:
      X: the (n, d) feature matrix as submitted (unpadded).
      n: real row count (needed to extract the unpadded result).
      key: group key — b_bucket is 0 until dispatch.
      arrival: submit time on the driving clock.
      deadline: absolute expiry time on the same clock.
      future: resolved with a TendencyResult-backed payload, or failed
        with DeadlineExceeded / the dispatch error.
      tag: optional caller-provided label (tests use it to identify
        requests in dispatch records).
      numerics: the request's resolved numerics plan
        (``repro.numerics.NumericsReport`` — X above is already the
        conditioned/quantized copy it describes), stamped onto the
        unpacked result's meta; None when the server skipped the
        pre-pass.
    """
    X: Any
    n: int
    key: ProgramKey
    arrival: float
    deadline: float
    future: Future
    tag: Any = None
    numerics: Any = None


@dataclasses.dataclass
class Batch:
    """A flushed group ready for one batched dispatch."""
    key: ProgramKey
    requests: list[ServeRequest]
    created: float


class CoalescerCore:
    """Clock-free coalescing state machine (see module docstring).

    Args:
      window: coalescing window in clock units — a group flushes this
        long after it opened.
      max_batch: a group flushes immediately at this size.
      max_pending: total queued-request bound across all groups.
    """

    def __init__(self, window: float = 0.002, max_batch: int = 8,
                 max_pending: int = 256):
        if window < 0:
            raise ValueError(f"window must be >= 0, got {window}")
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got {max_pending}")
        self.window = window
        self.max_batch = max_batch
        self.max_pending = max_pending
        self._groups: dict[ProgramKey, list[ServeRequest]] = {}
        self._opened: dict[ProgramKey, float] = {}
        # counters (exposed via server.stats())
        self.submitted = 0
        self.rejected = 0
        self.timeouts = 0
        self.dispatched_batches = 0
        self.dispatched_requests = 0

    @property
    def pending(self) -> int:
        return sum(len(g) for g in self._groups.values())

    def _flush(self, key: ProgramKey, now: float) -> Batch:
        reqs = self._groups.pop(key)
        self._opened.pop(key)
        self.dispatched_batches += 1
        self.dispatched_requests += len(reqs)
        return Batch(key=key, requests=reqs, created=now)

    def _expire(self, now: float) -> list[ServeRequest]:
        expired = []
        for key in list(self._groups):
            reqs = self._groups[key]
            live = [r for r in reqs if r.deadline > now]
            if len(live) != len(reqs):
                expired.extend(r for r in reqs if r.deadline <= now)
                if live:
                    self._groups[key] = live
                else:
                    del self._groups[key]
                    del self._opened[key]
        self.timeouts += len(expired)
        return expired

    def poll(self, now: float) -> tuple[list[Batch], list[ServeRequest]]:
        """Advance the machine to ``now``.

        Replays every event with timestamp <= now in order.  Events at
        equal time are ordered flush-before-deadline, so a request
        whose deadline coincides with its group's flush rides the
        batch rather than expiring.

        Returns:
          (batches ready to dispatch, requests expired past deadline).
        """
        batches: list[Batch] = []
        expired: list[ServeRequest] = []
        while True:
            event = self.next_event()
            if event is None or event[0] > now:
                break
            t, kind, key = event
            if kind == 0:
                batches.append(self._flush(key, t))
            else:
                expired.extend(self._expire(t))
        return batches, expired

    def next_event(self) -> tuple[float, int, ProgramKey | None] | None:
        """Earliest pending event as ``(time, kind, key)``.

        kind 0 = group flush (at ``opened + window``), kind 1 = request
        deadline.  The tuple ordering doubles as the tie rule: at equal
        time the flush (kind 0) fires first.  None when idle.
        """
        events: list[tuple[float, int, ProgramKey | None]] = []
        for key, opened in self._opened.items():
            events.append((opened + self.window, 0, key))
        for key, reqs in self._groups.items():
            for r in reqs:
                events.append((r.deadline, 1, key))
        if not events:
            return None
        return min(events, key=lambda e: (e[0], e[1]))

    def try_enqueue(self, req: ServeRequest, now: float) -> Batch | None:
        """Enqueue one request at time ``now``; no implicit poll.

        Callers MUST call ``poll(now)`` first and handle its output —
        that replays due flushes/expiries before the queue-bound check,
        and it is what makes the Backpressure raise safe: a rejection
        here has no side effects beyond the ``rejected`` counter, so it
        can never discard batches whose futures would then hang.

        Returns:
          The group's batch when this request filled it to
          ``max_batch`` (flushed immediately), else None.

        Raises:
          Backpressure: ``max_pending`` requests are already queued.
            The queue state is untouched.
        """
        if self.pending >= self.max_pending:
            self.rejected += 1
            raise Backpressure(
                f"serving queue full ({self.max_pending} pending); "
                "retry later or raise max_pending")
        self.submitted += 1
        group = self._groups.setdefault(req.key, [])
        if req.key not in self._opened:
            self._opened[req.key] = now
        group.append(req)
        if len(group) >= self.max_batch:
            return self._flush(req.key, now)
        return None

    def drain(self, now: float) -> tuple[list[Batch], list[ServeRequest]]:
        """Flush every open group regardless of window (shutdown path).

        Expiry is applied first, so a request past deadline at drain
        time still fails with DeadlineExceeded rather than being fit.
        """
        expired = self._expire(now)
        batches = [self._flush(key, now) for key in list(self._groups)]
        return batches, expired
