"""Graceful-degradation ladder for the serving layer (ISSUE 9 tentpole).

The serving layer's promise upgrades here from "fast and bitwise-correct
when everything works" to "stays up and *observably* degrades when
something doesn't".  Four mechanisms, composed by
``TendencyServer._execute``:

1. **Batch-failure isolation** — when a coalesced batch's execute
   raises, the batch is split and every lane retried solo, so one
   poison request fails alone and its batchmates still get their
   bitwise-correct results (the split lanes run the identical program
   family the clean path uses).

2. **Per-key fallback chain** (:func:`fallback_chain`) — an ordered
   ladder of degraded :class:`~repro.serve.cache.ProgramKey` variants:
   a Pallas-routed key falls back to the XLA reference path
   (``use_pallas=False``), a flashvat key additionally falls from the
   persistent Turbo engine to the stepwise engine (``turbo=False``),
   and an ivat key finally steps down one fidelity rung to vat (same
   n-bucket, same padding proof, coarser image).  Every transition is a
   *served result instead of an error* and increments ``fallbacks``.

3. **Bounded jittered retry** (:class:`RetryPolicy`) — each chain level
   gets ``max_attempts`` tries with exponential backoff; the jitter is
   deterministic in (seed, attempt) so the chaos tests can pin exact
   schedules, and the wait runs through the server's injectable sleep
   so virtual-clock rigs never really sleep.

4. **Circuit breaker** (:class:`CircuitBreaker`) — ``threshold``
   consecutive primary-level dispatch failures open the breaker: the
   primary is skipped (requests go straight to the fallback chain)
   until ``cooldown_s`` elapses on the injectable clock, after which
   ONE probe dispatch re-tries the primary (HALF_OPEN); success closes
   the breaker, failure re-opens it for another cooldown.  The machine
   is clock-free — every transition takes ``now`` — mirroring
   ``CoalescerCore`` so the same virtual-clock rig drives it.

Every degradation increments a typed counter on
:class:`ResilienceCounters`; the snapshot (:class:`ResilienceStats`)
surfaces on ``ServeStats.resilience`` so tests and the chaos CLI pin
exact trajectories.
"""
from __future__ import annotations

import dataclasses
import threading

import numpy as np

from repro.serve.cache import ProgramKey

# breaker states
CLOSED = "CLOSED"
OPEN = "OPEN"
HALF_OPEN = "HALF_OPEN"


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff with deterministic jitter.

    Attributes:
      max_attempts: tries per chain level (1 = no retry).
      backoff_s: base delay before the first retry.
      backoff_cap_s: upper bound on any single delay (pre-jitter).
      jitter: +-relative jitter applied to each delay, drawn
        deterministically from (seed, attempt) — bounded, reproducible,
        and still decorrelating real concurrent retries.
    """

    max_attempts: int = 2
    backoff_s: float = 0.005
    backoff_cap_s: float = 0.1
    jitter: float = 0.25

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, got "
                             f"{self.max_attempts}")

    def delay_s(self, attempt: int, *, seed: int = 0) -> float:
        """Backoff before retry number ``attempt`` (0-based)."""
        base = min(self.backoff_cap_s, self.backoff_s * (2.0 ** attempt))
        if self.jitter <= 0:
            return base
        rng = np.random.default_rng(np.random.SeedSequence([seed, attempt]))
        frac = float(rng.uniform(-self.jitter, self.jitter))
        return base * (1.0 + frac)


@dataclasses.dataclass(frozen=True)
class BreakerConfig:
    """Circuit-breaker thresholds (see module docstring)."""

    threshold: int = 3      # consecutive primary failures that open it
    cooldown_s: float = 30.0


class CircuitBreaker:
    """Clock-free CLOSED -> OPEN -> HALF_OPEN state machine, per key."""

    def __init__(self, config: BreakerConfig = BreakerConfig()):
        self.config = config
        self.state = CLOSED
        self.failures = 0        # consecutive primary dispatch failures
        self.opened_at: float | None = None
        self.opens = 0           # lifetime transitions into OPEN
        self.probes = 0          # lifetime HALF_OPEN probe dispatches

    def allow_primary(self, now: float) -> bool:
        """May this dispatch try the primary level?  OPEN past cooldown
        moves to HALF_OPEN and admits exactly one probe."""
        if self.state == CLOSED:
            return True
        if self.state == OPEN:
            if now - self.opened_at >= self.config.cooldown_s:
                self.state = HALF_OPEN
                self.probes += 1
                return True
            return False
        # HALF_OPEN: a probe is already in flight on this dispatcher
        # thread; concurrent dispatches stay on the fallback.
        return False

    def record_success(self, now: float) -> None:
        self.failures = 0
        self.state = CLOSED
        self.opened_at = None

    def record_failure(self, now: float) -> None:
        self.failures += 1
        if (self.state == HALF_OPEN
                or self.failures >= self.config.threshold):
            if self.state != OPEN:
                self.opens += 1
            self.state = OPEN
            self.opened_at = now


def fallback_chain(key: ProgramKey) -> tuple[ProgramKey, ...]:
    """The ordered program ladder for one group key, primary first.

    Degradation moves (applied cumulatively, each a strictly "more
    boring" configuration):

      use_pallas=True  -> use_pallas=False       (Pallas -> XLA ref)
      flashvat turbo   -> turbo=False            (persistent -> stepwise)
      rung "ivat"      -> "vat"                  (geodesic -> raw image;
                                                  same n-bucket, same
                                                  dup-row padding proof)

    The rung step-down preserves the bucketing contract: ivat and vat
    share ``PADDED_RUNGS`` semantics, so a vat fallback still unpacks
    each lane bitwise-equal to its solo vat fit.  vat itself has no
    lower padded rung, and flashvat's band-render shapes key on exact n,
    so neither steps further down.
    """
    chain = [key]

    def push(k: ProgramKey) -> None:
        if k != chain[-1]:
            chain.append(k)

    k = key
    if k.use_pallas:
        k = dataclasses.replace(k, use_pallas=False)
        push(k)
    if k.rung == "flashvat" and k.turbo is not False:
        k = dataclasses.replace(k, turbo=False)
        push(k)
    if k.rung == "ivat":
        k = dataclasses.replace(k, rung="vat")
        push(k)
    return tuple(chain)


@dataclasses.dataclass(frozen=True)
class ResilienceStats:
    """Point-in-time degradation counters (on ``ServeStats.resilience``).

    Attributes:
      fallbacks: chain-level transitions taken (primary -> level 1,
        level 1 -> level 2, ...) across all dispatches.
      splits: failed multi-lane batches split into solo retries.
      retries: same-level re-attempts after a failure.
      degraded: requests served by a non-primary chain level (every one
        of these was an error turned into a result).
      breaker_opens: breaker transitions into OPEN.
      breaker_probes: HALF_OPEN probe dispatches after cooldown.
      invalid_rejects: requests refused at admission (InvalidInput).
      failed: futures ultimately failed after the whole ladder.
      numerics_fallbacks: requests whose bf16 storage request failed
        certification (or was fault-tripped) and was served at f32
        instead — the numerics shield's counted degradation (mirrors
        ``NumericsReport.fallbacks``; see repro.numerics).
      breakers: sorted (key-family, state) pairs of every breaker whose
        state is not CLOSED — empty on a healthy server.
    """

    fallbacks: int = 0
    splits: int = 0
    retries: int = 0
    degraded: int = 0
    breaker_opens: int = 0
    breaker_probes: int = 0
    invalid_rejects: int = 0
    failed: int = 0
    numerics_fallbacks: int = 0
    breakers: tuple[tuple[str, str], ...] = ()

    @property
    def open_breakers(self) -> int:
        return sum(1 for _, s in self.breakers if s == OPEN)


class ResilienceCounters:
    """Mutable counter block the server increments; lock-guarded since
    submit (rejects) and the dispatcher (everything else) both write."""

    def __init__(self):
        self._lock = threading.Lock()
        self.fallbacks = 0
        self.splits = 0
        self.retries = 0
        self.degraded = 0
        self.invalid_rejects = 0
        self.failed = 0
        self.numerics_fallbacks = 0

    def bump(self, field: str, by: int = 1) -> None:
        with self._lock:
            setattr(self, field, getattr(self, field) + by)

    def snapshot(self, breakers: dict[str, CircuitBreaker]) -> ResilienceStats:
        with self._lock:
            return ResilienceStats(
                fallbacks=self.fallbacks, splits=self.splits,
                retries=self.retries, degraded=self.degraded,
                breaker_opens=sum(b.opens for b in breakers.values()),
                breaker_probes=sum(b.probes for b in breakers.values()),
                invalid_rejects=self.invalid_rejects, failed=self.failed,
                numerics_fallbacks=self.numerics_fallbacks,
                breakers=tuple(sorted(
                    (name, b.state) for name, b in breakers.items()
                    if b.state != CLOSED)))


def breaker_family(key: ProgramKey) -> str:
    """Breaker identity for a group key: the program family minus the
    lane count — every batch size of one (rung, shape, knob) family
    shares failure history (a broken Pallas build is broken at every
    b_bucket)."""
    return (f"{key.rung}/n{key.n_bucket}/d{key.d}/{key.metric}/"
            f"pallas={key.use_pallas}/turbo={key.turbo}")
