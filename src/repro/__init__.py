"""repro — Fast-VAT reproduction, rebuilt for accelerators.

The supported import surface lives at the package root:

>>> from repro import FastVAT, assess_tendency, TendencyResult

Submodules (``repro.core``, ``repro.kernels``, ...) remain importable as
documented library layers; the names below are the stable public API.
Attribute access is lazy (PEP 562) so ``import repro`` stays cheap for
consumers that only want a submodule.
"""
from __future__ import annotations

__all__ = [
    "FastVAT", "assess_tendency",
    "TendencyResult", "TendencyReport", "ResultMeta",
    "METRICS", "select_method", "InvalidInput",
    "NumericsPolicy", "NumericsReport",
]

_API_NAMES = frozenset(__all__)


def __getattr__(name: str):
    if name in _API_NAMES:
        from repro import api
        return getattr(api, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | _API_NAMES)
