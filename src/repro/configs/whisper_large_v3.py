"""Whisper-large-v3 backbone — enc-dec transformer; conv frontend STUBBED
(input_specs supplies precomputed frame embeddings). [arXiv:2212.04356]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20, head_dim=64,
    d_ff=5120, vocab=51866, act="gelu",
    is_encdec=True, n_enc_layers=32, enc_seq=1500,
)
