"""Zamba2-2.7B — Mamba2 backbone + shared GQA attention block.

[arXiv:2411.15242; hf] 54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64.  Hybrid layout: one *shared* attention+MLP
block (single weight set) applied every 6 layers between Mamba2 blocks.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
    d_ff=10240, vocab=32000, act="swiglu",
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, attn_every=6,
)
