"""DeepSeek-V3-671B (37B active) — MLA, 1 shared + 256 routed top-8, MTP.
[arXiv:2412.19437; hf]

Deviation noted in DESIGN.md: the paper's first 3 dense layers are modeled
as MoE layers too (uniform stack keeps the scan compact); expert width
2048, MLA dims q_lora=1536 kv_lora=512 nope=128 rope=64 v=128.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, head_dim=128,
    d_ff=2048, vocab=129280, act="swiglu",
    n_experts=256, top_k=8, n_shared_experts=1, d_ff_expert=2048,
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128, mtp=True,
)
