"""Model / run configuration dataclasses shared by the whole framework."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    act: str = "swiglu"         # swiglu | geglu | relu2 | gelu
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    route_groups: int = 0       # DeepSeek group-limited routing: experts
    route_top_groups: int = 0   # partitioned into groups, top-g selected
                                # per token before expert top-k (locality)
    # --- MLA (DeepSeek-V3) ---
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    mtp: bool = False           # multi-token-prediction auxiliary head
    # --- SSM / hybrid ---
    ssm_state: int = 0          # Mamba2 d_state / RWKV6 head size
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    attn_every: int = 0         # zamba2: one shared attn block every k layers
    rwkv_head_dim: int = 64
    # --- encoder/decoder (whisper) ---
    is_encdec: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 1500         # precomputed audio frame embeddings (stub)
    # --- VLM ---
    n_patches: int = 0          # precomputed ViT patch embeddings (stub)
    # --- misc ---
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: str = "full"         # none | full | dots
    attn_chunk: int = 512       # q-chunk for blocked causal attention
    seq_shard: bool = False     # sequence-parallel activation sharding
    vocab_pad: int = 0          # pad embed rows to a multiple (0 = exact);
                                # lets odd vocabs (51866, 151655) TP-shard
    ce_chunk: int = 0           # seq-chunked CE loss (0 = full logits)
    head_pad: int = 0           # pad head counts to a multiple (0 = exact);
                                # extra heads' output rows init to zero —
                                # lets odd head counts (20, 36, 14) TP-shard

    @property
    def padded_vocab(self) -> int:
        if self.vocab_pad <= 0:
            return self.vocab
        return -(-self.vocab // self.vocab_pad) * self.vocab_pad

    @property
    def eff_heads(self) -> int:
        # padding is only group-mapping-safe for MHA (q == kv head count);
        # GQA padding would re-pair query groups with the wrong KV heads
        if self.head_pad <= 0 or self.n_heads != self.n_kv_heads:
            return self.n_heads
        return -(-self.n_heads // self.head_pad) * self.head_pad

    @property
    def eff_kv_heads(self) -> int:
        if self.head_pad <= 0 or self.n_heads != self.n_kv_heads:
            return self.n_kv_heads
        return -(-self.n_kv_heads // self.head_pad) * self.head_pad

    @property
    def q_dim(self) -> int:
        return self.eff_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.eff_kv_heads * self.head_dim

    @property
    def gated(self) -> bool:
        return self.act in ("swiglu", "geglu")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}


@dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1_000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    optimizer: str = "adamw"    # adamw | adafactor
    seed: int = 0
    # fault tolerance / scale knobs
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    diag_every: int = 25        # VAT diagnostics cadence
    compress_grads: bool = False
    topk_frac: float = 0.05     # gradient-compression keep fraction
