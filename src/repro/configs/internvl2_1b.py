"""InternVL2-1B — InternViT frontend STUBBED (precomputed patch embeddings)
+ 0.5B-class LM backbone. [arXiv:2404.16821; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, head_dim=64,
    d_ff=4864, vocab=151655, act="swiglu",
    n_patches=256, tie_embeddings=True,
)
