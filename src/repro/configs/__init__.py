"""Architecture registry: exact assigned configs + reduced smoke variants."""
from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig, SHAPES

from repro.configs.zamba2_2p7b import CONFIG as zamba2_2p7b
from repro.configs.phi3_mini_3p8b import CONFIG as phi3_mini_3p8b
from repro.configs.nemotron_4_15b import CONFIG as nemotron_4_15b
from repro.configs.gemma_2b import CONFIG as gemma_2b
from repro.configs.starcoder2_7b import CONFIG as starcoder2_7b
from repro.configs.whisper_large_v3 import CONFIG as whisper_large_v3
from repro.configs.rwkv6_3b import CONFIG as rwkv6_3b
from repro.configs.phi35_moe_42b import CONFIG as phi35_moe_42b
from repro.configs.deepseek_v3_671b import CONFIG as deepseek_v3_671b
from repro.configs.internvl2_1b import CONFIG as internvl2_1b

ARCHS: dict[str, ModelConfig] = {c.name: c for c in [
    zamba2_2p7b, phi3_mini_3p8b, nemotron_4_15b, gemma_2b, starcoder2_7b,
    whisper_large_v3, rwkv6_3b, phi35_moe_42b, deepseek_v3_671b, internvl2_1b,
]}

# archs with sub-quadratic sequence mixing run the 500k-context cell
SUBQUADRATIC = {"zamba2-2.7b", "rwkv6-3b"}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; choices: {sorted(ARCHS)}")
    return ARCHS[name]


def cells(arch: str) -> list[str]:
    """Shape names this arch runs (long_500k only for sub-quadratic)."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if arch in SUBQUADRATIC:
        names.append("long_500k")
    return names


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config: tiny dims, runs a CPU step in seconds."""
    cfg = get_config(name)
    kw = dict(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=max(1, 4 * cfg.n_kv_heads // max(cfg.n_heads, 1)) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=16, d_ff=128, vocab=128,
        attn_chunk=8, ssm_chunk=8, remat="none",
    )
    if cfg.family == "moe":
        kw.update(n_experts=4, top_k=2, d_ff_expert=64)
        if cfg.use_mla:
            kw.update(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                      qk_rope_dim=8, v_head_dim=16)
    if cfg.family == "hybrid":
        kw.update(n_layers=4, attn_every=2, ssm_state=16, ssm_head_dim=16)
    if cfg.family == "ssm":
        kw.update(rwkv_head_dim=16)
    if cfg.family == "audio":
        kw.update(n_enc_layers=2, enc_seq=16)
    if cfg.family == "vlm":
        kw.update(n_patches=4)
    return cfg.replace(**kw)
