"""Pallas TPU kernels for the paper's compute hot-spots.

pairwise_dist — MXU-tiled Euclidean distance matrix (the O(n^2 d) stage
                the paper's Cython version optimizes with flattened loops)
prim_update   — fused masked block-argmin for Prim's greedy selection
ops           — jit'd dispatch wrappers (pallas | xla)
ref           — pure-jnp oracles, also the production CPU path

Design notes (BlockSpec tiling, VMEM budget, interpret-mode-on-CPU
convention): docs/architecture.md.
"""
