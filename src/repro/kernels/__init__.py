"""Pallas TPU kernels for the paper's compute hot-spots.

pairwise_dist — MXU-tiled Euclidean distance matrix (the O(n^2 d) stage
                the paper's Cython version optimizes with flattened
                loops), plus the batched (b, n, d)-stack grid variant
prim_update   — fused masked block-argmin for Prim's greedy selection
prim_stream   — fused matrix-free Prim step (Flash-VAT): distance-tile
                recompute + frontier min-update + masked block-argmin
                in one pass; the (n, n) matrix is never formed
ivat_update   — fused VMEM-resident iVAT recurrence (Havens & Bezdek
                row update; replaces the XLA ``at[].set`` copies)
ops           — jit'd dispatch wrappers (pallas | xla), the only front
                door core code uses
ref           — pure-jnp oracles, also the production CPU path

Design notes (BlockSpec tiling conventions, VMEM budgeting, padding
rules, interpret-mode-on-CPU testing recipe): docs/kernels.md.
"""
