"""Pallas TPU kernel: blocked pairwise Euclidean distance matrix.

TPU-native replacement for the paper's Cython flattened-loop distance
computation.  The Cython trick (``R[i*n+j]`` for cache locality) has no TPU
meaning; the equivalent control over memory is the BlockSpec tiling below:

  * grid (n/BM, n/BN); program (i, j) owns output tile R[iBM:(i+1)BM, jBN:...]
  * X row-tile (BM, d) and Y row-tile (BN, d) are staged HBM->VMEM by the
    BlockSpec machinery; d is kept fully resident (padded to 128) so the
    cross term is a single (BM, d) x (d, BN) MXU matmul per tile.
  * accumulation and sqrt in f32 on the VPU; output cast to the requested
    dtype on the way out.

VMEM budget at the default BM=BN=256, d<=512:
  2 * 256*512*4B (tiles) + 256*256*4B (out) ~= 1.3 MiB  << 16 MiB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 256
_LANE = 128  # MXU/VREG lane width — pad contraction dim to a multiple


def _pairwise_kernel(x_ref, y_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)          # (BM, d)
    y = y_ref[...].astype(jnp.float32)          # (BN, d)
    nx = jnp.sum(x * x, axis=1)                 # (BM,)
    ny = jnp.sum(y * y, axis=1)                 # (BN,)
    cross = jax.lax.dot_general(                # MXU: (BM, d) x (BN, d)^T
        x, y, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    sq = nx[:, None] + ny[None, :] - 2.0 * cross
    o_ref[...] = jnp.sqrt(jnp.maximum(sq, 0.0)).astype(o_ref.dtype)


def _pad_to(a: jax.Array, size: int, axis: int) -> jax.Array:
    pad = size - a.shape[axis]
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def pairwise_dist_pallas(
    X: jax.Array,
    Y: jax.Array | None = None,
    *,
    block: int = DEFAULT_BLOCK,
    interpret: bool = False,
) -> jax.Array:
    """(n, d), (m, d) -> (n, m) Euclidean distance matrix via pallas_call."""
    if Y is None:
        Y = X
    n, d = X.shape
    m = Y.shape[0]
    bm = min(block, max(8, n))
    bn = min(block, max(8, m))
    n_pad = -(-n // bm) * bm
    m_pad = -(-m // bn) * bn
    d_pad = -(-d // _LANE) * _LANE
    Xp = _pad_to(_pad_to(X, n_pad, 0), d_pad, 1)
    Yp = _pad_to(_pad_to(Y, m_pad, 0), d_pad, 1)

    out = pl.pallas_call(
        _pairwise_kernel,
        grid=(n_pad // bm, m_pad // bn),
        in_specs=[
            pl.BlockSpec((bm, d_pad), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d_pad), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_pad, m_pad), jnp.float32),
        interpret=interpret,
    )(Xp, Yp)
    return out[:n, :m]
