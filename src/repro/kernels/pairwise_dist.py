"""Pallas TPU kernel: blocked pairwise Euclidean distance matrix.

TPU-native replacement for the paper's Cython flattened-loop distance
computation.  The Cython trick (``R[i*n+j]`` for cache locality) has no TPU
meaning; the equivalent control over memory is the BlockSpec tiling below:

  * grid (n/BM, n/BN); program (i, j) owns output tile R[iBM:(i+1)BM, jBN:...]
  * X row-tile (BM, d) and Y row-tile (BN, d) are staged HBM->VMEM by the
    BlockSpec machinery; d is kept fully resident (padded to 128) so the
    cross term is a single (BM, d) x (d, BN) MXU matmul per tile.
  * accumulation and sqrt in f32 on the VPU; output cast to the requested
    dtype on the way out.

VMEM budget at the default BM=BN=256, d<=512:
  2 * 256*512*4B (tiles) + 256*256*4B (out) ~= 1.3 MiB  << 16 MiB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 256
_LANE = 128  # MXU/VREG lane width — pad contraction dim to a multiple


def _tile_dist(x, y):
    """(BM, d), (BN, d) -> (BM, BN) Euclidean tile, f32 accumulate."""
    nx = jnp.sum(x * x, axis=1)                 # (BM,)
    ny = jnp.sum(y * y, axis=1)                 # (BN,)
    cross = jax.lax.dot_general(                # MXU: (BM, d) x (BN, d)^T
        x, y, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    sq = nx[:, None] + ny[None, :] - 2.0 * cross
    return jnp.sqrt(jnp.maximum(sq, 0.0))


def _pairwise_kernel(x_ref, y_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)          # (BM, d)
    y = y_ref[...].astype(jnp.float32)          # (BN, d)
    o_ref[...] = _tile_dist(x, y).astype(o_ref.dtype)


def _pairwise_kernel_batch(x_ref, y_ref, o_ref):
    x = x_ref[0].astype(jnp.float32)            # (1, BM, d) slab -> (BM, d)
    y = y_ref[0].astype(jnp.float32)
    o_ref[0] = _tile_dist(x, y).astype(o_ref.dtype)


def _pad_to(a: jax.Array, size: int, axis: int) -> jax.Array:
    pad = size - a.shape[axis]
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def pairwise_dist_pallas(
    X: jax.Array,
    Y: jax.Array | None = None,
    *,
    block: int = DEFAULT_BLOCK,
    interpret: bool = False,
) -> jax.Array:
    """Blocked Euclidean distance matrix via pallas_call.

    Args:
      X: (n, d) float — query points.
      Y: (m, d) float or None — reference points (None: Y = X).
      block: output tile edge BM = BN (static; clamped to n/m).
      interpret: Pallas interpret mode (CPU correctness path).

    Returns:
      (n, m) float32 distance matrix. n, m are padded to the block and d
      to the 128-lane width internally; padding lives in sliced-off
      tiles, so it never reaches the caller.
    """
    if Y is None:
        Y = X
    n, d = X.shape
    m = Y.shape[0]
    bm = min(block, max(8, n))
    bn = min(block, max(8, m))
    n_pad = -(-n // bm) * bm
    m_pad = -(-m // bn) * bn
    d_pad = -(-d // _LANE) * _LANE
    Xp = _pad_to(_pad_to(X, n_pad, 0), d_pad, 1)
    Yp = _pad_to(_pad_to(Y, m_pad, 0), d_pad, 1)

    out = pl.pallas_call(
        _pairwise_kernel,
        grid=(n_pad // bm, m_pad // bn),
        in_specs=[
            pl.BlockSpec((bm, d_pad), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d_pad), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_pad, m_pad), jnp.float32),
        interpret=interpret,
    )(Xp, Yp)
    return out[:n, :m]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def pairwise_dist_pallas_batch(
    X: jax.Array,
    *,
    block: int = DEFAULT_BLOCK,
    interpret: bool = False,
) -> jax.Array:
    """Batched self-distance matrices for a stack of datasets.

    Args:
      X: (b, n, d) float — b independent datasets of n points each.
      block: square output tile edge (BM = BN); clamped to n.
      interpret: Pallas interpret mode (CPU correctness path).

    Returns:
      (b, n, n) float32 — per-dataset Euclidean distance matrices.

    One pallas_call serves the whole stack: the grid grows a leading batch
    axis, (b, n/BM, n/BN), and every BlockSpec gains a size-1 slab dim
    indexed by the batch coordinate — the per-tile compute (one MXU matmul
    + VPU sqrt) is shared with the unbatched kernel, so VMEM per program
    stays at the unbatched budget regardless of b.
    """
    b, n, d = X.shape
    bm = min(block, max(8, n))
    n_pad = -(-n // bm) * bm
    d_pad = -(-d // _LANE) * _LANE
    Xp = _pad_to(_pad_to(X, n_pad, 1), d_pad, 2)

    out = pl.pallas_call(
        _pairwise_kernel_batch,
        grid=(b, n_pad // bm, n_pad // bm),
        in_specs=[
            pl.BlockSpec((1, bm, d_pad), lambda bi, i, j: (bi, i, 0)),
            pl.BlockSpec((1, bm, d_pad), lambda bi, i, j: (bi, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, bm), lambda bi, i, j: (bi, i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n_pad, n_pad), jnp.float32),
        interpret=interpret,
    )(Xp, Xp)
    return out[:, :n, :n]
