"""Pallas TPU kernel: blocked pairwise dissimilarity matrix, metric-dispatched.

TPU-native replacement for the paper's Cython flattened-loop distance
computation.  The Cython trick (``R[i*n+j]`` for cache locality) has no TPU
meaning; the equivalent control over memory is the BlockSpec tiling below:

  * grid (n/BM, n/BN); program (i, j) owns output tile R[iBM:(i+1)BM, jBN:...]
  * X row-tile (BM, d) and Y row-tile (BN, d) are staged HBM->VMEM by the
    BlockSpec machinery; d is kept fully resident (padded to 128) so the
    cross term is a single (BM, d) x (d, BN) MXU matmul per tile.
  * accumulation in f32 on the VPU; output cast to the requested dtype on
    the way out.

The per-tile math dispatches on ``metric`` (static, so each variant
compiles its own kernel):

  euclidean / sqeuclidean — Gram trick, one MXU matmul per tile
  cosine                  — same matmul + rsqrt row norms on the VPU
  manhattan               — no matmul form exists; the tile loops over
                            128-lane feature chunks and reduces a
                            (BM, BN, 128) |diff| broadcast per chunk

VMEM budget at the default BM=BN=256, d<=512 (matmul metrics):
  2 * 256*512*4B (tiles) + 256*256*4B (out) ~= 1.3 MiB  << 16 MiB VMEM.
Manhattan's broadcast chunk adds BM*BN*128*4B, so its block is clamped
to 64: 64*64*128*4B = 2 MiB — still comfortable.

Zero padding is harmless for every metric, for two different reasons:
padded *features* contribute the reduction identity (0) to dots, squared
diffs and |diffs| alike, so real-row entries are exact; padded *rows* DO
produce computed entries (a partial last tile holds real and padded rows
side by side — e.g. cosine's eps-guard maps zero rows to 1.0), but every
per-element formula reads only its own row pair, and the final
``out[:n, :m]`` slice discards all padded-row output.  Any future
in-kernel reduction *across* a tile must re-prove this (padded rows are
live inside the tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import check_metric
from repro.numerics.condition import check_form

DEFAULT_BLOCK = 256
_LANE = 128  # MXU/VREG lane width — pad contraction dim to a multiple
_MANHATTAN_BLOCK = 64  # broadcast-chunk metrics pay BM*BN*_LANE VMEM


def _tile_dissim(x, y, metric, form):
    """(BM, d), (BN, d) -> (BM, BN) dissimilarity tile, f32 accumulate.

    ``form == "direct"`` (euclidean/sqeuclidean under the safe/auto
    numerics policies) trades the single MXU matmul for the manhattan
    -style broadcast-chunk loop over squared differences — no
    cancellation, ~2x slower, and it pays the same BM*BN*_LANE VMEM
    bill (so ``_clamp_block`` clamps it like manhattan).
    """
    if metric == "manhattan":
        acc = jnp.zeros((x.shape[0], y.shape[0]), jnp.float32)
        for k0 in range(0, x.shape[1], _LANE):  # d is static: unrolled
            xc = x[:, k0:k0 + _LANE]
            yc = y[:, k0:k0 + _LANE]
            acc += jnp.sum(jnp.abs(xc[:, None, :] - yc[None, :, :]), axis=-1)
        return acc
    if form == "direct" and metric != "cosine":
        acc = jnp.zeros((x.shape[0], y.shape[0]), jnp.float32)
        for k0 in range(0, x.shape[1], _LANE):  # d is static: unrolled
            dc = x[:, None, k0:k0 + _LANE] - y[None, :, k0:k0 + _LANE]
            acc += jnp.sum(dc * dc, axis=-1)
        return jnp.sqrt(acc) if metric == "euclidean" else acc
    cross = jax.lax.dot_general(                # MXU: (BM, d) x (BN, d)^T
        x, y, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    if metric == "cosine":
        nx = jnp.sqrt(jnp.sum(x * x, axis=1))   # (BM,)
        ny = jnp.sqrt(jnp.sum(y * y, axis=1))   # (BN,)
        denom = jnp.maximum(nx[:, None] * ny[None, :], 1e-12)
        return jnp.clip(1.0 - cross / denom, 0.0, 2.0)
    nx = jnp.sum(x * x, axis=1)                 # (BM,)
    ny = jnp.sum(y * y, axis=1)                 # (BN,)
    sq = jnp.maximum(nx[:, None] + ny[None, :] - 2.0 * cross, 0.0)
    return jnp.sqrt(sq) if metric == "euclidean" else sq


def _pairwise_kernel(x_ref, y_ref, o_ref, *, metric, form):
    x = x_ref[...].astype(jnp.float32)          # (BM, d)
    y = y_ref[...].astype(jnp.float32)          # (BN, d)
    o_ref[...] = _tile_dissim(x, y, metric, form).astype(o_ref.dtype)


def _pairwise_kernel_batch(x_ref, y_ref, o_ref, *, metric, form):
    x = x_ref[0].astype(jnp.float32)            # (1, BM, d) slab -> (BM, d)
    y = y_ref[0].astype(jnp.float32)
    o_ref[0] = _tile_dissim(x, y, metric, form).astype(o_ref.dtype)


def _pad_to(a: jax.Array, size: int, axis: int) -> jax.Array:
    pad = size - a.shape[axis]
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


def _clamp_block(block: int, n: int, metric: str,
                 form: str = "gram") -> int:
    if metric == "manhattan" or (form == "direct" and metric != "cosine"):
        block = min(block, _MANHATTAN_BLOCK)  # broadcast-chunk VMEM bill
    return min(block, max(8, n))


@functools.partial(jax.jit,
                   static_argnames=("metric", "form", "block", "interpret"))
def pairwise_dist_pallas(
    X: jax.Array,
    Y: jax.Array | None = None,
    *,
    metric: str = "euclidean",
    form: str = "gram",
    block: int = DEFAULT_BLOCK,
    interpret: bool = False,
) -> jax.Array:
    """Blocked pairwise dissimilarity matrix via pallas_call.

    Args:
      X: (n, d) float — query points.
      Y: (m, d) float or None — reference points (None: Y = X).
      metric: one of ``kernels.ref.METRICS`` (static — each metric
        compiles its own tile; see the module docstring for the math).
      form: "gram" (default) or "direct" — the numerics-policy tile
        form (static; see ``_tile_dissim`` and ``numerics.resolve``).
      block: output tile edge BM = BN (static; clamped to n/m, and to
        ``_MANHATTAN_BLOCK`` for the broadcast-chunk tiles — manhattan,
        and direct-form euclidean/sqeuclidean).
      interpret: Pallas interpret mode (CPU correctness path).

    Returns:
      (n, m) float32 dissimilarity matrix. n, m are padded to the block
      and d to the 128-lane width internally; padding lives in sliced-off
      tiles (rows) or contributes the reduction identity (features), so
      it never reaches the caller.
    """
    check_metric(metric)
    check_form(form)
    if Y is None:
        Y = X
    n, d = X.shape
    m = Y.shape[0]
    bm = _clamp_block(block, n, metric, form)
    bn = _clamp_block(block, m, metric, form)
    n_pad = -(-n // bm) * bm
    m_pad = -(-m // bn) * bn
    d_pad = -(-d // _LANE) * _LANE
    Xp = _pad_to(_pad_to(X, n_pad, 0), d_pad, 1)
    Yp = _pad_to(_pad_to(Y, m_pad, 0), d_pad, 1)

    out = pl.pallas_call(
        functools.partial(_pairwise_kernel, metric=metric, form=form),
        grid=(n_pad // bm, m_pad // bn),
        in_specs=[
            pl.BlockSpec((bm, d_pad), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d_pad), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_pad, m_pad), jnp.float32),
        interpret=interpret,
    )(Xp, Yp)
    return out[:n, :m]


@functools.partial(jax.jit,
                   static_argnames=("metric", "form", "block", "interpret"))
def pairwise_dist_pallas_batch(
    X: jax.Array,
    *,
    metric: str = "euclidean",
    form: str = "gram",
    block: int = DEFAULT_BLOCK,
    interpret: bool = False,
) -> jax.Array:
    """Batched self-dissimilarity matrices for a stack of datasets.

    Args:
      X: (b, n, d) float — b independent datasets of n points each.
      metric: one of ``kernels.ref.METRICS`` (static).
      form: "gram" (default) or "direct" — the numerics-policy tile
        form (static).
      block: square output tile edge (BM = BN); clamped to n (and to
        ``_MANHATTAN_BLOCK`` for the broadcast-chunk tiles).
      interpret: Pallas interpret mode (CPU correctness path).

    Returns:
      (b, n, n) float32 — per-dataset dissimilarity matrices.

    One pallas_call serves the whole stack: the grid grows a leading batch
    axis, (b, n/BM, n/BN), and every BlockSpec gains a size-1 slab dim
    indexed by the batch coordinate — the per-tile compute is shared with
    the unbatched kernel, so VMEM per program stays at the unbatched
    budget regardless of b.
    """
    check_metric(metric)
    check_form(form)
    b, n, d = X.shape
    bm = _clamp_block(block, n, metric, form)
    n_pad = -(-n // bm) * bm
    d_pad = -(-d // _LANE) * _LANE
    Xp = _pad_to(_pad_to(X, n_pad, 1), d_pad, 2)

    out = pl.pallas_call(
        functools.partial(_pairwise_kernel_batch, metric=metric, form=form),
        grid=(b, n_pad // bm, n_pad // bm),
        in_specs=[
            pl.BlockSpec((1, bm, d_pad), lambda bi, i, j: (bi, i, 0)),
            pl.BlockSpec((1, bm, d_pad), lambda bi, i, j: (bi, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, bm), lambda bi, i, j: (bi, i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n_pad, n_pad), jnp.float32),
        interpret=interpret,
    )(Xp, Xp)
    return out[:, :n, :n]
