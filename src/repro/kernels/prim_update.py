"""Pallas TPU kernel: masked block-argmin for Prim's greedy selection.

The Numba-accelerated hot loop of the paper's MST step is
``argmin_j (not selected[j]) mind[j]``.  On TPU this is a VPU reduction;
the kernel tiles the length-n vector into VMEM blocks, each grid step
emitting a per-block (min, argmin) pair, and the (tiny) cross-block
reduction happens in the jit'd wrapper.  One fused pass replaces the
mask-materialize + global argmin XLA emits on its own.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 1024


def _block_argmin_kernel(vals_ref, mask_ref, minv_ref, mini_ref):
    b = pl.program_id(0)
    vals = vals_ref[...].astype(jnp.float32)
    mask = mask_ref[...]
    masked = jnp.where(mask, jnp.inf, vals)
    idx = jnp.argmin(masked).astype(jnp.int32)
    minv_ref[0] = masked[idx]
    mini_ref[0] = idx + b * vals.shape[0]


def _pad_to(a: jax.Array, size: int) -> jax.Array:
    pad = size - a.shape[0]
    if pad == 0:
        return a
    return jnp.pad(a, (0, pad), constant_values=(True if a.dtype == jnp.bool_ else 0))


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def masked_argmin_pallas(
    vals: jax.Array,
    mask: jax.Array,
    *,
    block: int = DEFAULT_BLOCK,
    interpret: bool = False,
):
    """Fused masked argmin over VMEM blocks.

    Args:
      vals: (n,) float — candidate values (Prim frontier distances).
      mask: (n,) bool — True lanes are excluded; padding is masked True
        so it can never win.
      block: VMEM tile length (static; clamped to n).
      interpret: Pallas interpret mode (CPU correctness path).

    Returns:
      (f32 scalar min, i32 scalar global argmin), first-index
      tie-breaking across and within blocks (block-local argmin is
      offset by the block base; the tiny cross-block reduction runs in
      the jit'd wrapper).
    """
    n = vals.shape[0]
    bn = min(block, max(8, n))
    n_pad = -(-n // bn) * bn
    vp = _pad_to(vals, n_pad)
    mp = _pad_to(mask, n_pad)  # padded lanes masked out (True)
    nblk = n_pad // bn

    minv, mini = pl.pallas_call(
        _block_argmin_kernel,
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((bn,), lambda b: (b,)),
            pl.BlockSpec((bn,), lambda b: (b,)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda b: (b,)),
            pl.BlockSpec((1,), lambda b: (b,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblk,), jnp.float32),
            jax.ShapeDtypeStruct((nblk,), jnp.int32),
        ],
        interpret=interpret,
    )(vp, mp)
    # cross-block reduction: nblk values, negligible
    best_blk = jnp.argmin(minv)
    return minv[best_blk], mini[best_blk]
