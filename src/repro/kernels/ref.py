"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness references the kernel tests sweep against, and
also the production XLA fallback path (they jit and shard fine — the
Pallas kernels exist to beat them on TPU, not to replace them).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pairwise_dist_ref(X: jax.Array, Y: jax.Array | None = None) -> jax.Array:
    """Euclidean distance matrix via the Gram trick.

    ||xi - yj||^2 = ||xi||^2 + ||yj||^2 - 2 xi.yj  — the cross term is one
    matmul, which is what makes this MXU-friendly (and is the exact
    decomposition the Pallas kernel tiles).
    """
    if Y is None:
        Y = X
    Xf = X.astype(jnp.float32)
    Yf = Y.astype(jnp.float32)
    nx = jnp.sum(Xf * Xf, axis=-1)
    ny = jnp.sum(Yf * Yf, axis=-1)
    sq = nx[:, None] + ny[None, :] - 2.0 * (Xf @ Yf.T)
    return jnp.sqrt(jnp.maximum(sq, 0.0))


def masked_argmin_ref(vals: jax.Array, mask: jax.Array):
    """(min value, argmin index) of vals where mask is False.

    `mask=True` means "excluded" (already selected in Prim's loop).
    First-index tie-breaking, matching jnp.argmin.
    """
    masked = jnp.where(mask, jnp.inf, vals.astype(jnp.float32))
    idx = jnp.argmin(masked).astype(jnp.int32)
    return masked[idx], idx
