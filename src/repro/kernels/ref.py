"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness references the kernel tests sweep against, and
also the production XLA fallback path (they jit and shard fine — the
Pallas kernels exist to beat them on TPU, not to replace them).

Metric support: VAT is defined on an arbitrary pairwise *dissimilarity*
matrix, so the distance oracles are metric-dispatched.  ``METRICS`` is
the canonical tuple of computable metrics; ``"precomputed"`` (the user
hands the matrix in directly) is an API-layer concept and never reaches
this module.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

#: Metrics every pairwise path (XLA ref + Pallas tile) implements.
METRICS = ("euclidean", "sqeuclidean", "manhattan", "cosine")


def check_metric(metric: str):
    """Raise ValueError unless ``metric`` names a computable metric.

    The one canonical check every pairwise path (refs and Pallas
    wrappers) shares — keep error wording and the accepted set here.
    """
    if metric not in METRICS:
        raise ValueError(f"metric must be one of {METRICS}, got {metric!r}")


def pairwise_dissim_ref(X: jax.Array, Y: jax.Array | None = None, *,
                        metric: str = "euclidean") -> jax.Array:
    """Metric-dispatched pairwise dissimilarity matrix.

    Args:
      X: (n, d) float — query points.
      Y: (m, d) float or None — reference points (None: Y = X).
      metric: one of ``METRICS``.
        euclidean    ||xi - yj||_2          (Gram trick, one MXU matmul)
        sqeuclidean  ||xi - yj||_2^2        (same, no sqrt)
        manhattan    sum_k |xik - yjk|      (broadcast |diff| reduce)
        cosine       1 - xi.yj/(|xi||yj|)   (in [0, 2]; zero-norm rows
                                             get an eps-guarded denom)

    Returns:
      (n, m) float32 dissimilarity matrix.
    """
    check_metric(metric)
    if Y is None:
        Y = X
    Xf = X.astype(jnp.float32)
    Yf = Y.astype(jnp.float32)
    if metric in ("euclidean", "sqeuclidean"):
        # ||xi - yj||^2 = ||xi||^2 + ||yj||^2 - 2 xi.yj — the cross term is
        # one matmul, which is what makes this MXU-friendly (and is the
        # exact decomposition the Pallas kernel tiles).
        nx = jnp.sum(Xf * Xf, axis=-1)
        ny = jnp.sum(Yf * Yf, axis=-1)
        sq = jnp.maximum(nx[:, None] + ny[None, :] - 2.0 * (Xf @ Yf.T), 0.0)
        return jnp.sqrt(sq) if metric == "euclidean" else sq
    if metric == "manhattan":
        return jnp.sum(jnp.abs(Xf[:, None, :] - Yf[None, :, :]), axis=-1)
    # cosine
    cross = Xf @ Yf.T
    nx = jnp.sqrt(jnp.sum(Xf * Xf, axis=-1))
    ny = jnp.sqrt(jnp.sum(Yf * Yf, axis=-1))
    denom = jnp.maximum(nx[:, None] * ny[None, :], 1e-12)
    return jnp.clip(1.0 - cross / denom, 0.0, 2.0)


def pairwise_dist_ref(X: jax.Array, Y: jax.Array | None = None) -> jax.Array:
    """Euclidean distance matrix via the Gram trick (legacy name).

    Kept as the stable spelling older call sites and tests use;
    ``pairwise_dissim_ref`` is the metric-dispatched front door.
    """
    return pairwise_dissim_ref(X, Y, metric="euclidean")


def row_dissim_ref(X: jax.Array, x: jax.Array, *,
                   metric: str = "euclidean") -> jax.Array:
    """Dissimilarity of every row of X to a single point x.

    The O(n) building block the matrix-free paths use (maximin sampling's
    frontier update, dvat's recomputed distance rows) — no (n, n) or even
    (n, m) intermediate.

    Args:
      X: (n, d) float — data points.
      x: (d,) float — the probe point.
      metric: one of ``METRICS``.

    Returns:
      (n,) float32 dissimilarities, matching ``pairwise_dissim_ref``'s
      column for the same point up to f32 rounding (this path computes
      differences directly instead of the Gram trick, which is the more
      accurate formula — do not mix the two inside one bitwise contract).
    """
    check_metric(metric)
    Xf = X.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    diff = Xf - xf[None, :]
    if metric == "euclidean":
        return jnp.sqrt(jnp.maximum(jnp.sum(diff * diff, axis=-1), 0.0))
    if metric == "sqeuclidean":
        return jnp.sum(diff * diff, axis=-1)
    if metric == "manhattan":
        return jnp.sum(jnp.abs(diff), axis=-1)
    nx = jnp.sqrt(jnp.sum(Xf * Xf, axis=-1))
    nq = jnp.sqrt(jnp.sum(xf * xf))
    denom = jnp.maximum(nx * nq, 1e-12)
    return jnp.clip(1.0 - (Xf @ xf) / denom, 0.0, 2.0)


def metric_aux_ref(X: jax.Array, *, metric: str = "euclidean") -> jax.Array:
    """Per-point auxiliary vector the Gram-trick pivot row needs.

    Args:
      X: (n, d) float — data points (any leading batch axes are fine).
      metric: one of ``METRICS``.

    Returns:
      (n,) float32 — squared norms for euclidean/sqeuclidean, norms for
      cosine, zeros for manhattan (which needs no precomputation).
      Computed once, it turns every later pivot row into O(n d) work
      with no per-row norm recomputation.
    """
    check_metric(metric)
    Xf = X.astype(jnp.float32)
    if metric in ("euclidean", "sqeuclidean"):
        return jnp.sum(Xf * Xf, axis=-1)
    if metric == "cosine":
        return jnp.sqrt(jnp.sum(Xf * Xf, axis=-1))
    return jnp.zeros(Xf.shape[:-1], jnp.float32)


def pivot_row_ref(X: jax.Array, aux: jax.Array, q: jax.Array, *,
                  metric: str = "euclidean") -> jax.Array:
    """Row q of the pairwise dissimilarity matrix, never materializing it.

    The matrix-free Prim engine's inner product: one (n, d) x (d,) cross
    term plus O(n) elementwise work per call.  Unlike ``row_dissim_ref``
    (direct differences — the more accurate formula), this path uses the
    *same Gram-trick decomposition as* ``pairwise_dissim_ref``, so its
    values are bitwise-identical to the materialized matrix's row q —
    the property ``core.vat.vat_matrix_free`` needs to reproduce
    ``vat_order``'s ordering exactly.  Do not mix the two row oracles
    inside one bitwise contract.

    Args:
      X: (n, d) float — data points.
      aux: (n,) float32 — ``metric_aux_ref(X, metric=metric)``.
      q: int scalar (traced ok) — the pivot row index.
      metric: one of ``METRICS``.

    Returns:
      (n,) float32 — dissimilarity of every point to point q.  The
      self-entry [q] is computed, not forced to zero; callers that need
      the materialized matrix's exact zero diagonal must mask it.
    """
    check_metric(metric)
    Xf = X.astype(jnp.float32)
    xq = jnp.take(Xf, q, axis=0)
    if metric == "manhattan":
        return jnp.sum(jnp.abs(Xf - xq[None, :]), axis=-1)
    cross = Xf @ xq
    aq = jnp.take(aux, q)
    if metric == "cosine":
        denom = jnp.maximum(aux * aq, 1e-12)
        return jnp.clip(1.0 - cross / denom, 0.0, 2.0)
    sq = jnp.maximum(aux + aq - 2.0 * cross, 0.0)
    return jnp.sqrt(sq) if metric == "euclidean" else sq


def prim_stream_step_ref(X: jax.Array, aux: jax.Array, q: jax.Array,
                         mind: jax.Array, selected: jax.Array, *,
                         metric: str = "euclidean"):
    """One fused matrix-free Prim step — the XLA oracle for prim_stream.

    Recomputes pivot q's distance row, folds it into the frontier with a
    min-update, and returns the masked argmin over the *updated* frontier
    — the next vertex Prim visits.  Chaining n-1 of these reproduces
    ``core.vat.vat_order`` on the materialized matrix bitwise (the row
    values are bitwise-identical via ``pivot_row_ref``, and the argmin
    shares jnp.argmin's first-index tie-breaking).

    Args:
      X: (n, d) float — data points.
      aux: (n,) float32 — ``metric_aux_ref`` of X.
      q: int scalar — the pivot selected by the previous step.
      mind: (n,) float32 — frontier distances *before* folding in q's row.
      selected: (n,) bool — True lanes are already in the MST (q included).
      metric: one of ``METRICS``.

    Returns:
      (new_mind (n,) f32, edge f32 scalar — the masked min (the MST edge
      weight of the next vertex), next (i32 scalar) — the next vertex).
    """
    row = pivot_row_ref(X, aux, q, metric=metric)
    new_mind = jnp.minimum(mind, row)
    edge, nxt = masked_argmin_ref(new_mind, selected)
    return new_mind, edge, nxt


def masked_argmin_ref(vals: jax.Array, mask: jax.Array):
    """(min value, argmin index) of vals where mask is False.

    Args:
      vals: (n,) float — candidate values (Prim frontier distances).
      mask: (n,) bool — True means "excluded" (already selected).

    Returns:
      (min value: f32 scalar, argmin index: i32 scalar) over unmasked
      lanes, first-index tie-breaking, matching jnp.argmin.
    """
    masked = jnp.where(mask, jnp.inf, vals.astype(jnp.float32))
    idx = jnp.argmin(masked).astype(jnp.int32)
    return masked[idx], idx


def ivat_from_vat_ref(rstar: jax.Array) -> jax.Array:
    """iVAT geodesic transform — the XLA fallback for kernels/ivat_update.

    Args:
      rstar: (n, n) float — VAT-ordered dissimilarity matrix.

    Returns:
      (n, n) float32 — max-min path distance matrix D' (Havens & Bezdek
      2012 recurrence; see ``core.ivat.ivat_from_vat`` for the math).

    Each fori_loop step is a fully vectorized O(n) row update, but the
    two ``at[].set`` writes lower to full-matrix dynamic_update_slice
    copies — the cost the fused Pallas kernel removes by keeping D'
    resident in VMEM.
    """
    n = rstar.shape[0]
    R = rstar.astype(jnp.float32)
    idx = jnp.arange(n)

    def body(r, Dp):
        row = R[r]
        mask = idx < r
        j = jnp.argmin(jnp.where(mask, row, jnp.inf))
        # D'[r,k] = max(R*[r,j], D'[j,k]) for k<r; at k=j, D'[j,j]=0 gives R*[r,j]
        newrow = jnp.where(mask, jnp.maximum(R[r, j], Dp[j]), 0.0)
        Dp = Dp.at[r, :].set(newrow)
        Dp = Dp.at[:, r].set(newrow)
        return Dp

    return jax.lax.fori_loop(1, n, body, jnp.zeros_like(R))
