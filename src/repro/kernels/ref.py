"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness references the kernel tests sweep against, and
also the production XLA fallback path (they jit and shard fine — the
Pallas kernels exist to beat them on TPU, not to replace them).

Metric support: VAT is defined on an arbitrary pairwise *dissimilarity*
matrix, so the distance oracles are metric-dispatched.  ``METRICS`` is
the canonical tuple of computable metrics; ``"precomputed"`` (the user
hands the matrix in directly) is an API-layer concept and never reaches
this module.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.numerics.condition import check_form

#: Metrics every pairwise path (XLA ref + Pallas tile) implements.
METRICS = ("euclidean", "sqeuclidean", "manhattan", "cosine")


def check_metric(metric: str):
    """Raise ValueError unless ``metric`` names a computable metric.

    The one canonical check every pairwise path (refs and Pallas
    wrappers) shares — keep error wording and the accepted set here.
    """
    if metric not in METRICS:
        raise ValueError(f"metric must be one of {METRICS}, got {metric!r}")


def pairwise_dissim_ref(X: jax.Array, Y: jax.Array | None = None, *,
                        metric: str = "euclidean",
                        form: str = "gram") -> jax.Array:
    """Metric-dispatched pairwise dissimilarity matrix.

    Args:
      X: (n, d) float — query points.
      Y: (m, d) float or None — reference points (None: Y = X).
      metric: one of ``METRICS``.
        euclidean    ||xi - yj||_2          (Gram trick, one MXU matmul)
        sqeuclidean  ||xi - yj||_2^2        (same, no sqrt)
        manhattan    sum_k |xik - yjk|      (broadcast |diff| reduce)
        cosine       1 - xi.yj/(|xi||yj|)   (in [0, 2]; zero-norm rows
                                             get an eps-guarded denom)
      form: "gram" (default — the MXU decomposition above, absolute
        cancellation error ~eps·max||x||²) or "direct" — squared
        differences ``sum_k (xik - yjk)²``, no cancellation, relative
        error only.  Selected by ``numerics.resolve``; only meaningful
        for euclidean/sqeuclidean (manhattan is already direct, cosine
        has no direct form and ignores it).

    Returns:
      (n, m) float32 dissimilarity matrix.
    """
    check_metric(metric)
    check_form(form)
    if Y is None:
        Y = X
    Xf = X.astype(jnp.float32)
    Yf = Y.astype(jnp.float32)
    if metric in ("euclidean", "sqeuclidean"):
        if form == "direct":
            # No cancellation: every term is a squared difference, so the
            # error is relative to the distance itself.  Same formula as
            # every other direct-form ref in this module — ref↔ref rows
            # stay bitwise-identical, the property the matrix-free
            # ordering contracts need under the safe/auto policies.
            diff = Xf[:, None, :] - Yf[None, :, :]
            sq = jnp.sum(diff * diff, axis=-1)
        else:
            # ||xi - yj||^2 = ||xi||^2 + ||yj||^2 - 2 xi.yj — the cross
            # term is one matmul, which is what makes this MXU-friendly
            # (and is the exact decomposition the Pallas kernel tiles).
            nx = jnp.sum(Xf * Xf, axis=-1)
            ny = jnp.sum(Yf * Yf, axis=-1)
            sq = jnp.maximum(
                nx[:, None] + ny[None, :] - 2.0 * (Xf @ Yf.T), 0.0)
        return jnp.sqrt(sq) if metric == "euclidean" else sq
    if metric == "manhattan":
        return jnp.sum(jnp.abs(Xf[:, None, :] - Yf[None, :, :]), axis=-1)
    # cosine
    cross = Xf @ Yf.T
    nx = jnp.sqrt(jnp.sum(Xf * Xf, axis=-1))
    ny = jnp.sqrt(jnp.sum(Yf * Yf, axis=-1))
    denom = jnp.maximum(nx[:, None] * ny[None, :], 1e-12)
    return jnp.clip(1.0 - cross / denom, 0.0, 2.0)


def pairwise_dist_ref(X: jax.Array, Y: jax.Array | None = None) -> jax.Array:
    """Euclidean distance matrix via the Gram trick (legacy name).

    Kept as the stable spelling older call sites and tests use;
    ``pairwise_dissim_ref`` is the metric-dispatched front door.
    """
    return pairwise_dissim_ref(X, Y, metric="euclidean")


def knn_graph_ref(X: jax.Array, *, k: int,
                  metric: str = "euclidean") -> tuple[jax.Array, jax.Array]:
    """k nearest neighbours of every point — the materializing oracle.

    Small-n correctness reference for ``kernels/knn_graph.py``: builds the
    full (n, n) dissimilarity matrix (so never use it past a few thousand
    points), masks the diagonal, and takes the k smallest per row via
    ``lax.top_k`` on negated values.  XLA's top_k breaks ties toward the
    lower index, which is exactly the selection order of the blocked
    paths' (value, position) fold — the tie contract every kNN path in
    this package shares.

    Args:
      X: (n, d) float — data points.
      k: neighbours per point; must satisfy 1 <= k <= n - 1.
      metric: one of ``METRICS``.

    Returns:
      (dist (n, k) f32 ascending per row, idx (n, k) i32) — idx[i, 0] is
      i's nearest neighbour; the point itself is never its own neighbour.
    """
    check_metric(metric)
    n = X.shape[0]
    if not 1 <= k <= n - 1:
        raise ValueError(f"k must satisfy 1 <= k <= n-1 = {n - 1}, got {k}")
    R = pairwise_dissim_ref(X, metric=metric)
    R = jnp.where(jnp.eye(n, dtype=bool), jnp.inf, R)
    neg, idx = jax.lax.top_k(-R, k)
    return -neg, idx.astype(jnp.int32)


def row_dissim_ref(X: jax.Array, x: jax.Array, *,
                   metric: str = "euclidean") -> jax.Array:
    """Dissimilarity of every row of X to a single point x.

    The O(n) building block the matrix-free paths use (maximin sampling's
    frontier update, dvat's recomputed distance rows) — no (n, n) or even
    (n, m) intermediate.

    Args:
      X: (n, d) float — data points.
      x: (d,) float — the probe point.
      metric: one of ``METRICS``.

    Returns:
      (n,) float32 dissimilarities, matching ``pairwise_dissim_ref``'s
      column for the same point up to f32 rounding (this path computes
      differences directly instead of the Gram trick, which is the more
      accurate formula — do not mix the two inside one bitwise contract).
    """
    check_metric(metric)
    Xf = X.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    diff = Xf - xf[None, :]
    if metric == "euclidean":
        return jnp.sqrt(jnp.maximum(jnp.sum(diff * diff, axis=-1), 0.0))
    if metric == "sqeuclidean":
        return jnp.sum(diff * diff, axis=-1)
    if metric == "manhattan":
        return jnp.sum(jnp.abs(diff), axis=-1)
    nx = jnp.sqrt(jnp.sum(Xf * Xf, axis=-1))
    nq = jnp.sqrt(jnp.sum(xf * xf))
    denom = jnp.maximum(nx * nq, 1e-12)
    return jnp.clip(1.0 - (Xf @ xf) / denom, 0.0, 2.0)


def metric_aux_ref(X: jax.Array, *, metric: str = "euclidean") -> jax.Array:
    """Per-point auxiliary vector the Gram-trick pivot row needs.

    Args:
      X: (n, d) float — data points (any leading batch axes are fine).
      metric: one of ``METRICS``.

    Returns:
      (n,) float32 — squared norms for euclidean/sqeuclidean, norms for
      cosine, zeros for manhattan (which needs no precomputation).
      Computed once, it turns every later pivot row into O(n d) work
      with no per-row norm recomputation.
    """
    check_metric(metric)
    Xf = X.astype(jnp.float32)
    if metric in ("euclidean", "sqeuclidean"):
        return jnp.sum(Xf * Xf, axis=-1)
    if metric == "cosine":
        return jnp.sqrt(jnp.sum(Xf * Xf, axis=-1))
    return jnp.zeros(Xf.shape[:-1], jnp.float32)


def pivot_row_ref(X: jax.Array, aux: jax.Array, q: jax.Array, *,
                  metric: str = "euclidean",
                  form: str = "gram") -> jax.Array:
    """Row q of the pairwise dissimilarity matrix, never materializing it.

    The matrix-free Prim engine's inner product: one (n, d) x (d,) cross
    term plus O(n) elementwise work per call.  This path uses the *same
    decomposition as* ``pairwise_dissim_ref`` for the same ``form``, so
    its values are bitwise-identical to the materialized matrix's row q
    — the property ``core.vat.vat_matrix_free`` needs to reproduce
    ``vat_order``'s ordering exactly.  Do not mix forms (or this oracle
    with ``row_dissim_ref``'s slightly different clamp) inside one
    bitwise contract.

    Args:
      X: (n, d) float — data points.
      aux: (n,) float32 — ``metric_aux_ref(X, metric=metric)``.
      q: int scalar (traced ok) — the pivot row index.
      metric: one of ``METRICS``.
      form: "gram" (default) or "direct" — see ``pairwise_dissim_ref``.

    Returns:
      (n,) float32 — dissimilarity of every point to point q.  The
      self-entry [q] is computed, not forced to zero; callers that need
      the materialized matrix's exact zero diagonal must mask it.
    """
    check_metric(metric)
    check_form(form)
    Xf = X.astype(jnp.float32)
    xq = jnp.take(Xf, q, axis=0)
    if metric == "manhattan":
        return jnp.sum(jnp.abs(Xf - xq[None, :]), axis=-1)
    if form == "direct" and metric != "cosine":
        diff = Xf - xq[None, :]
        sq = jnp.sum(diff * diff, axis=-1)
        return jnp.sqrt(sq) if metric == "euclidean" else sq
    cross = Xf @ xq
    aq = jnp.take(aux, q)
    if metric == "cosine":
        denom = jnp.maximum(aux * aq, 1e-12)
        return jnp.clip(1.0 - cross / denom, 0.0, 2.0)
    sq = jnp.maximum(aux + aq - 2.0 * cross, 0.0)
    return jnp.sqrt(sq) if metric == "euclidean" else sq


def pivot_row_from_point_ref(X: jax.Array, aux: jax.Array, xq: jax.Array,
                             auxq: jax.Array, *,
                             metric: str = "euclidean",
                             form: str = "gram") -> jax.Array:
    """``pivot_row_ref`` when the pivot's (point, aux) are already in hand.

    The building block of the sharded matrix-free engine: the pivot
    usually lives on another device, so its row x_q arrives by collective
    broadcast rather than a local gather.  The formula is *identical* to
    ``pivot_row_ref`` term for term (same decomposition per ``form``,
    same clamps), so a shard's slice of this row is bitwise-equal to the
    solo path's row restricted to the shard — the property the sharded
    ordering contract rests on.

    Args:
      X: (n, d) float — data points (a device's local shard is fine).
      aux: (n,) float32 — ``metric_aux_ref`` of X.
      xq: (d,) float — the pivot point.
      auxq: float32 scalar — the pivot's ``metric_aux_ref`` entry.
      metric: one of ``METRICS``.
      form: "gram" (default) or "direct" — see ``pairwise_dissim_ref``.

    Returns:
      (n,) float32 dissimilarity of every row of X to xq.
    """
    check_metric(metric)
    check_form(form)
    Xf = X.astype(jnp.float32)
    xqf = xq.astype(jnp.float32)
    if metric == "manhattan":
        return jnp.sum(jnp.abs(Xf - xqf[None, :]), axis=-1)
    if form == "direct" and metric != "cosine":
        diff = Xf - xqf[None, :]
        sq = jnp.sum(diff * diff, axis=-1)
        return jnp.sqrt(sq) if metric == "euclidean" else sq
    cross = Xf @ xqf
    if metric == "cosine":
        denom = jnp.maximum(aux * auxq, 1e-12)
        return jnp.clip(1.0 - cross / denom, 0.0, 2.0)
    sq = jnp.maximum(aux + auxq - 2.0 * cross, 0.0)
    return jnp.sqrt(sq) if metric == "euclidean" else sq


def prim_frontier_step_ref(X: jax.Array, aux: jax.Array, xq: jax.Array,
                           auxq: jax.Array, mind: jax.Array, *,
                           metric: str = "euclidean", form: str = "gram"):
    """Fused frontier fold + masked argmin with the pivot passed by value.

    The per-device body of ``core.distributed.vat_matrix_free_sharded``:
    fold the broadcast pivot's distance row into the local frontier and
    emit the local (min, argmin) pair for the cross-device reduction.

    Selected lanes are encoded *in-band* as ``mind = +inf`` (the
    persistent engine's convention — see ``prim_persist_ref``): the fold
    keeps +inf lanes +inf, so no separate ``selected`` mask ships through
    the loop.  Bitwise contract: folds are f32 ``min`` (exact, so fold
    order never matters) over rows identical to ``pivot_row_ref``.

    Args:
      X: (n, d) float — local points.
      aux: (n,) float32 — ``metric_aux_ref`` of X.
      xq: (d,) float — the pivot point (broadcast from its owner).
      auxq: f32 scalar — the pivot's aux entry.
      mind: (n,) float32 — frontier; +inf lanes are selected/padding.
      metric: one of ``METRICS``.
      form: "gram" (default) or "direct" — see ``pairwise_dissim_ref``.

    Returns:
      (new_mind (n,) f32, value f32 scalar, idx i32 scalar) — the updated
      frontier and its min with first-index tie-breaking.
    """
    row = pivot_row_from_point_ref(X, aux, xq, auxq, metric=metric,
                                   form=form)
    new_mind = jnp.where(jnp.isinf(mind), jnp.inf, jnp.minimum(mind, row))
    value = jnp.min(new_mind)
    n = new_mind.shape[0]
    idx = jnp.min(jnp.where(new_mind == value,
                            jnp.arange(n, dtype=jnp.int32), n)).astype(
                                jnp.int32)
    return new_mind, value, idx


#: "No distance folded yet" sentinel of the persistent engine's in-band
#: frontier encoding (+inf = selected).  Any real dissimilarity folds
#: below it; it can only win the argmin on pathological (inf/nan) input,
#: which no metric here produces from finite points.
UNSEEN = float(jnp.finfo(jnp.float32).max)


def prim_persist_ref(X: jax.Array, aux: jax.Array, i0: jax.Array, *,
                     metric: str = "euclidean", form: str = "gram",
                     unroll: int = 4):
    """The whole Prim traversal in one call — the persistent engine's
    XLA mirror (Turbo Flash-VAT).

    Where the stepwise path (``prim_stream_step_ref`` driven by
    ``core.vat``'s fori_loop) re-enters the runtime every step, this
    mirror keeps the entire n-1 step recurrence inside a single scan and
    strips the per-step op count to the bone:

      * selected lanes live *in-band* as ``mind = +inf`` (one carried
        vector instead of mind + selected + per-step masking),
      * the masked argmin is a vectorized ``min`` + index-min over
        ``where(mind == min, iota, n)`` — XLA:CPU lowers ``jnp.argmin``'s
        variadic reduce to a scalar loop, and replacing it is worth ~3x
        on the whole traversal at n = 8192,
      * order/edges are carried (n,) buffers updated in place by
        ``dynamic_update_slice`` — scan ys would need a concatenate for
        the seed slot, which blocks XLA's in-place ys accumulation and
        costs ~2x the whole loop,
      * the scan is unrolled to amortize loop bookkeeping.

    Bitwise contract with ``core.vat.vat_order`` / the stepwise engine:
    rows come from ``pivot_row_ref`` (the shared Gram-trick oracle), f32
    ``min`` folds are exact so fold scheduling can't change values, and
    the index-min reduction reproduces ``jnp.argmin``'s first-index
    tie-breaking (the winner set {mind == min} is exact equality on
    identical floats).

    Args:
      X: (n, d) float — data points.
      aux: (n,) float32 — ``metric_aux_ref`` of X.
      i0: i32 scalar — the seed vertex (``core.vat._streamed_seed_pivot``).
      metric: one of ``METRICS``.
      form: "gram" (default) or "direct" — see ``pairwise_dissim_ref``.
      unroll: scan unroll factor (static; perf only).

    Returns:
      (order (n,) i32, edges (n,) f32) — the exact VAT visit order and
      each visit's MST edge weight (edges[0] = 0), matching the stepwise
      engine bitwise.
    """
    check_metric(metric)
    n = X.shape[0]
    Xf = X.astype(jnp.float32)
    iota = jnp.arange(n, dtype=jnp.int32)
    q0 = jnp.asarray(i0, jnp.int32)
    mind0 = jnp.where(iota == q0, jnp.inf, jnp.float32(UNSEEN))
    order0 = jnp.zeros((n,), jnp.int32).at[0].set(q0)
    edges0 = jnp.zeros((n,), jnp.float32)
    if n == 1:
        return order0, edges0

    def step(carry, t):
        mind, q, order, edges = carry
        row = pivot_row_ref(Xf, aux, q, metric=metric, form=form)
        mind = jnp.where(jnp.isinf(mind), jnp.inf, jnp.minimum(mind, row))
        ev = jnp.min(mind)
        nq = jnp.min(jnp.where(mind == ev, iota, n)).astype(jnp.int32)
        mind = jax.lax.dynamic_update_slice(
            mind, jnp.reshape(ev * 0 + jnp.inf, (1,)), (nq,))
        order = jax.lax.dynamic_update_slice(order, nq[None], (t,))
        edges = jax.lax.dynamic_update_slice(edges, ev[None], (t,))
        return (mind, nq, order, edges), None

    (_, _, order, edges), _ = jax.lax.scan(
        step, (mind0, q0, order0, edges0), jnp.arange(1, n), unroll=unroll)
    return order, edges


def prim_stream_step_ref(X: jax.Array, aux: jax.Array, q: jax.Array,
                         mind: jax.Array, selected: jax.Array, *,
                         metric: str = "euclidean", form: str = "gram"):
    """One fused matrix-free Prim step — the XLA oracle for prim_stream.

    Recomputes pivot q's distance row, folds it into the frontier with a
    min-update, and returns the masked argmin over the *updated* frontier
    — the next vertex Prim visits.  Chaining n-1 of these reproduces
    ``core.vat.vat_order`` on the materialized matrix bitwise (the row
    values are bitwise-identical via ``pivot_row_ref``, and the argmin
    shares jnp.argmin's first-index tie-breaking).

    Args:
      X: (n, d) float — data points.
      aux: (n,) float32 — ``metric_aux_ref`` of X.
      q: int scalar — the pivot selected by the previous step.
      mind: (n,) float32 — frontier distances *before* folding in q's row.
      selected: (n,) bool — True lanes are already in the MST (q included).
      metric: one of ``METRICS``.
      form: "gram" (default) or "direct" — see ``pairwise_dissim_ref``.

    Returns:
      (new_mind (n,) f32, edge f32 scalar — the masked min (the MST edge
      weight of the next vertex), next (i32 scalar) — the next vertex).
    """
    row = pivot_row_ref(X, aux, q, metric=metric, form=form)
    new_mind = jnp.minimum(mind, row)
    edge, nxt = masked_argmin_ref(new_mind, selected)
    return new_mind, edge, nxt


def masked_argmin_ref(vals: jax.Array, mask: jax.Array):
    """(min value, argmin index) of vals where mask is False.

    Args:
      vals: (n,) float — candidate values (Prim frontier distances).
      mask: (n,) bool — True means "excluded" (already selected).

    Returns:
      (min value: f32 scalar, argmin index: i32 scalar) over unmasked
      lanes, first-index tie-breaking, matching jnp.argmin.
    """
    masked = jnp.where(mask, jnp.inf, vals.astype(jnp.float32))
    idx = jnp.argmin(masked).astype(jnp.int32)
    return masked[idx], idx


def ivat_from_vat_ref(rstar: jax.Array) -> jax.Array:
    """iVAT geodesic transform — the XLA fallback for kernels/ivat_update.

    Args:
      rstar: (n, n) float — VAT-ordered dissimilarity matrix.

    Returns:
      (n, n) float32 — max-min path distance matrix D' (Havens & Bezdek
      2012 recurrence; see ``core.ivat.ivat_from_vat`` for the math).

    Each fori_loop step is a fully vectorized O(n) row update, but the
    two ``at[].set`` writes lower to full-matrix dynamic_update_slice
    copies — the cost the fused Pallas kernel removes by keeping D'
    resident in VMEM.
    """
    n = rstar.shape[0]
    R = rstar.astype(jnp.float32)
    idx = jnp.arange(n)

    def body(r, Dp):
        row = R[r]
        mask = idx < r
        j = jnp.argmin(jnp.where(mask, row, jnp.inf))
        # D'[r,k] = max(R*[r,j], D'[j,k]) for k<r; at k=j, D'[j,j]=0 gives R*[r,j]
        newrow = jnp.where(mask, jnp.maximum(R[r, j], Dp[j]), 0.0)
        Dp = Dp.at[r, :].set(newrow)
        Dp = Dp.at[:, r].set(newrow)
        return Dp

    return jax.lax.fori_loop(1, n, body, jnp.zeros_like(R))
