"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness references the kernel tests sweep against, and
also the production XLA fallback path (they jit and shard fine — the
Pallas kernels exist to beat them on TPU, not to replace them).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pairwise_dist_ref(X: jax.Array, Y: jax.Array | None = None) -> jax.Array:
    """Euclidean distance matrix via the Gram trick.

    ||xi - yj||^2 = ||xi||^2 + ||yj||^2 - 2 xi.yj  — the cross term is one
    matmul, which is what makes this MXU-friendly (and is the exact
    decomposition the Pallas kernel tiles).
    """
    if Y is None:
        Y = X
    Xf = X.astype(jnp.float32)
    Yf = Y.astype(jnp.float32)
    nx = jnp.sum(Xf * Xf, axis=-1)
    ny = jnp.sum(Yf * Yf, axis=-1)
    sq = nx[:, None] + ny[None, :] - 2.0 * (Xf @ Yf.T)
    return jnp.sqrt(jnp.maximum(sq, 0.0))


def masked_argmin_ref(vals: jax.Array, mask: jax.Array):
    """(min value, argmin index) of vals where mask is False.

    Args:
      vals: (n,) float — candidate values (Prim frontier distances).
      mask: (n,) bool — True means "excluded" (already selected).

    Returns:
      (min value: f32 scalar, argmin index: i32 scalar) over unmasked
      lanes, first-index tie-breaking, matching jnp.argmin.
    """
    masked = jnp.where(mask, jnp.inf, vals.astype(jnp.float32))
    idx = jnp.argmin(masked).astype(jnp.int32)
    return masked[idx], idx


def ivat_from_vat_ref(rstar: jax.Array) -> jax.Array:
    """iVAT geodesic transform — the XLA fallback for kernels/ivat_update.

    Args:
      rstar: (n, n) float — VAT-ordered dissimilarity matrix.

    Returns:
      (n, n) float32 — max-min path distance matrix D' (Havens & Bezdek
      2012 recurrence; see ``core.ivat.ivat_from_vat`` for the math).

    Each fori_loop step is a fully vectorized O(n) row update, but the
    two ``at[].set`` writes lower to full-matrix dynamic_update_slice
    copies — the cost the fused Pallas kernel removes by keeping D'
    resident in VMEM.
    """
    n = rstar.shape[0]
    R = rstar.astype(jnp.float32)
    idx = jnp.arange(n)

    def body(r, Dp):
        row = R[r]
        mask = idx < r
        j = jnp.argmin(jnp.where(mask, row, jnp.inf))
        # D'[r,k] = max(R*[r,j], D'[j,k]) for k<r; at k=j, D'[j,j]=0 gives R*[r,j]
        newrow = jnp.where(mask, jnp.maximum(R[r, j], Dp[j]), 0.0)
        Dp = Dp.at[r, :].set(newrow)
        Dp = Dp.at[:, r].set(newrow)
        return Dp

    return jax.lax.fori_loop(1, n, body, jnp.zeros_like(R))
