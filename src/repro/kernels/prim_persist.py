"""Pallas TPU megakernel: the ENTIRE Prim traversal in one pallas_call.

The stepwise Flash-VAT engine (``kernels/prim_stream.py``) removed the
O(n^2) memory wall but kept a time wall: n-1 separate ``pallas_call``
dispatches, each round-tripping the O(n) frontier state through HBM.
This module is the Turbo layer — ONE persistent kernel that:

  * keeps every piece of traversal state VMEM-resident for the whole
    run: the frontier ``mind`` (selected lanes in-band as +inf), the
    ``order``/``edges`` outputs, and the per-tile pruning state
    (``tmin``/``pend_lb``/``nfold``).  At n = 100k the f32 state is
    ~2 MB — far under the 16 MiB core;
  * streams X tiles HBM->VMEM on demand with explicit DMA (X lives in
    ``ANY`` memory space; only one (block, d_pad) tile plus one pivot row
    is ever resident), so VMEM stays O(n + block·d);
  * prunes with per-tile frontier lower bounds (lazy Prim): a tile whose
    bound provably exceeds the best exact candidate skips its distance
    recompute — and its DMA — entirely this step.  On clustered data
    most steps touch ~1 of n/block tiles, a data-dependent ~(n/block)x
    HBM-traffic cut over the eager stepwise engine.

Lazy-fold exactness argument (why pruning cannot change the ordering):

  * f32 ``min`` is exact (no rounding), so folding pivot rows into a
    tile in any order — or arbitrarily late — produces bitwise-identical
    frontier values; per-(pivot, lane) row values come from the same
    Gram-trick formula as ``ref.pivot_row_ref``.
  * per tile T the kernel tracks ``tmin[T]`` (min of its stored, possibly
    stale frontier lanes) and ``pend_lb[T]`` (a lower bound on every
    pending, unfolded pivot's distance to any lane of T, from the tile's
    centroid + radius via the triangle inequality — both computed in the
    direct difference form — shrunk by ``_LB_MARGIN`` against relative
    f32 rounding AND debited ``lb_slack_ulps(form)·eps·max‖x‖²``
    against the absolute cancellation error of the Gram-trick rows it
    is compared with — 4 ulps suffice for direct-form rows, which have
    no cancellation).  ``min(tmin, pend_lb)`` lower-bounds T's computed
    frontier min.
  * per step, tiles are folded in ascending-bound order until every
    unfolded tile's bound strictly exceeds the best exact candidate.
    Stale lanes then provably exceed the winner too (stale >= true >
    best), so the global first-index argmin over the stored frontier is
    exactly the eager argmin — ties included.

Metric geometry of the bound: euclidean/manhattan are metrics, so
``d(q, x) >= d(q, c_T) - r_T`` directly; sqeuclidean bounds in euclidean
space and squares; cosine is not a metric here, so its radius is +inf
and the bound degrades to 0 — correct, just never prunes.

Scalar state (loop carries, DMA indices) stays in registers/SMEM; the
seed vertex arrives via an SMEM (1,) block.  Padded lanes (from
``prim_stream.pad_points``) are +inf in-band from step 0 and can never
win; padded tail columns of X are zeros, which contribute exact 0.0
terms to every dot product.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.prim_stream import (_LANE, DEFAULT_BLOCK, _tile_pivot_row,
                                       pad_points)
from repro.kernels.ref import UNSEEN, check_metric
from repro.numerics.condition import check_form, lb_slack_ulps

#: VMEM the persistent kernel may plan for (bytes).  Conservative slice
#: of the ~16 MiB core: leaves room for compiler temporaries and the
#: double-buffering headroom the DMA pipeline wants.
PERSIST_VMEM_BUDGET = 12 * 1024 * 1024

#: Relative safety factor applied to every pruning lower bound.  The
#: bound math (direct-form centroid distance minus radius) carries a few
#: ulp of f32 rounding; shrinking it 1e-3 relative keeps it a true lower
#: bound with ~100x margin while costing no measurable pruning power (a
#: tile within 0.1% of the winner would be folded next step anyway).
_LB_MARGIN = 0.999

#: Absolute-error allowance for the frontier-row side of the comparison:
#: ``numerics.condition.lb_slack_ulps(form)`` ulps at scale max‖x‖².
#: Gram-form rows (``_tile_pivot_row``'s aux + aux_q - 2·cross
#: decomposition) carry ABSOLUTE cancellation error — up to
#: ~C·eps·max‖x‖² regardless of how small the distance is — so a
#: relative margin alone is unsound on uncentered data; the kernel
#: subtracts ``lb_slack_ulps(form) · eps · max(aux)`` in squared
#: -distance units from every bound (its sqrt in euclidean units).
#: The gram value (64) is the original PR-5 constant, now shared with
#: the ``KAPPA_SAFE`` derivation; direct-form rows have no cancellation
#: and keep only a tiny final-sum allowance (4).
_F32_EPS = float(jnp.finfo(jnp.float32).eps)


def persist_state_bytes(n: int, d: int, *, block: int = DEFAULT_BLOCK) -> int:
    """VMEM bytes the persistent kernel keeps resident for an (n, d) run.

    Mirrors ``prim_stream.pad_points`` padding arithmetic.  Counted:
    the in-band frontier + aux + an iota temporary (3 f32 lanes per
    padded point), order/edges outputs, per-tile pruning state and
    centroids, and the X-tile + pivot-row DMA scratch.  X itself is NOT
    counted — it stays in ANY/HBM and is streamed tile-by-tile.

    Args:
      n: real point count.
      d: feature count.
      block: tile length the kernel will use.

    Returns:
      bytes — compare against ``PERSIST_VMEM_BUDGET``.
    """
    bn = min(block, max(8, n))
    n_pad = -(-n // bn) * bn
    d_pad = -(-d // _LANE) * _LANE
    nblk = n_pad // bn
    per_point = 3 * 4 * n_pad          # mind + aux + iota (f32/i32)
    outputs = 2 * 4 * n                # order + edges
    per_tile = nblk * (d_pad * 4 + 5 * 4)  # centroid row + caux/rad/tmin/pend/nfold
    scratch = (bn * d_pad + d_pad) * 4     # X tile + pivot row
    return per_point + outputs + per_tile + scratch


def persist_supported(n: int, d: int, *, block: int = DEFAULT_BLOCK) -> bool:
    """True when the resident state fits ``PERSIST_VMEM_BUDGET``.

    The dispatch guard ``kernels.ops.prim_persist`` consults; above the
    seam the XLA mirror (``ref.prim_persist_ref``) — never the stepwise
    engine — takes over.
    """
    return persist_state_bytes(n, d, block=block) <= PERSIST_VMEM_BUDGET


def persist_tile_bounds(Xp: jax.Array, n: int, *, metric: str,
                        block: int):
    """Per-tile (centroid, radius) for the pruning bounds.

    Args:
      Xp: (n_pad, d_pad) f32 — points padded by ``pad_points``.
      n: real point count (padded lanes are excluded from the geometry).
      metric: one of ``kernels.ref.METRICS``.
      block: tile length (must divide n_pad).

    Returns:
      (cent (nblk, d_pad) f32, rad (nblk,) f32): per-tile mean point and
      tile radius in the bound's geometry — euclidean for
      euclidean/sqeuclidean, L1 for manhattan, +inf for cosine (which
      disables pruning; cosine dissimilarity has no triangle inequality
      to lean on).  Both sides are computed in the DIRECT difference
      form, so their errors are relative and the kernel's _LB_MARGIN
      covers them.
    """
    check_metric(metric)
    n_pad, d_pad = Xp.shape
    nblk = n_pad // block
    tiles = Xp.reshape(nblk, block, d_pad)
    real = (jnp.arange(n_pad).reshape(nblk, block) < n)
    cnt = jnp.maximum(jnp.sum(real, axis=1), 1).astype(jnp.float32)
    cent = jnp.sum(tiles * real[..., None], axis=1) / cnt[:, None]
    if metric == "cosine":
        rad = jnp.full((nblk,), jnp.inf, jnp.float32)
    elif metric == "manhattan":
        dist = jnp.sum(jnp.abs(tiles - cent[:, None, :]), axis=-1)
        rad = jnp.max(jnp.where(real, dist, -jnp.inf), axis=1)
    else:
        diff = tiles - cent[:, None, :]
        dist = jnp.sqrt(jnp.maximum(jnp.sum(diff * diff, axis=-1), 0.0))
        rad = jnp.max(jnp.where(real, dist, -jnp.inf), axis=1)
    return cent, jnp.maximum(rad, 0.0)


def _persist_kernel(i0_ref, aux_ref, cent_ref, rad_ref, x_ref,
                    order_ref, edges_ref, stats_ref, tile_ref, row_ref,
                    sem_t, sem_r, *, n, metric, form, block, prune):
    n_pad = aux_ref.shape[0]
    nblk = n_pad // block
    aux = aux_ref[...]
    cent = cent_ref[...]
    rad = rad_ref[...]
    iota = lax.broadcasted_iota(jnp.int32, (n_pad, 1), 0)[:, 0]
    blk_iota = lax.broadcasted_iota(jnp.int32, (nblk, 1), 0)[:, 0]
    i0 = i0_ref[0]
    inf = jnp.float32(jnp.inf)

    def fetch_row(p):
        """DMA point p's (padded) row HBM->VMEM; returns it (1, d_pad)."""
        cp = pltpu.make_async_copy(x_ref.at[pl.ds(p, 1)], row_ref, sem_r)
        cp.start()
        cp.wait()
        return row_ref[...]

    # row-side cancellation allowance, squared-distance units, per tile
    # form (the module constants explain why a relative margin alone is
    # unsound against Gram rows)
    slack_sq = jnp.float32(lb_slack_ulps(form) * _F32_EPS) * jnp.max(aux)

    def tile_lb(xq):
        """Lower bound on d(q, any lane of tile T) for every T: triangle
        inequality off the tile centroid — DIRECT-form centroid distance
        (relative error only, matching the radius computation), shrunk
        by _LB_MARGIN and debited the Gram slack.  xq is (1, d_pad)."""
        diff = cent - xq
        if metric == "manhattan":
            dq = jnp.sum(jnp.abs(diff), axis=-1)
        else:
            dq = jnp.sqrt(jnp.maximum(jnp.sum(diff * diff, axis=-1), 0.0))
        e = jnp.maximum(dq - rad, 0.0) * jnp.float32(_LB_MARGIN)
        if metric == "euclidean":
            lb = jnp.maximum(e - jnp.sqrt(slack_sq), 0.0)
        elif metric == "sqeuclidean":
            lb = jnp.maximum(e * e - slack_sq, 0.0)
        else:               # manhattan: direct |diff| sums both sides —
            lb = e          # no cancellation, margin alone covers it
        if not prune:       # pruning disabled: bound 0 folds every tile
            lb = lb * 0.0
        return lb

    # frontier init: +inf = selected or padding, UNSEEN = no fold yet
    mind0 = jnp.where((iota >= n) | (iota == i0), inf, jnp.float32(UNSEEN))
    tmin0 = jnp.min(mind0.reshape(nblk, block), axis=1)
    pend0 = jnp.full((nblk,), inf)
    nfold0 = jnp.zeros((nblk,), jnp.int32)
    order0 = jnp.where(lax.broadcasted_iota(jnp.int32, (n, 1), 0)[:, 0] == 0,
                       i0, 0).astype(jnp.int32)
    edges0 = jnp.zeros((n,), jnp.float32)

    def fold_tile(T, t, mind, tmin, pend, nfold, order, stats):
        """Fold every pending pivot (order[nfold[T]:t]) into tile T."""
        start = T * block
        cp = pltpu.make_async_copy(x_ref.at[pl.ds(start, block)], tile_ref,
                                   sem_t)
        cp.start()
        cp.wait()
        tile = tile_ref[...]
        aux_t = lax.dynamic_slice(aux, (start,), (block,))
        mt = lax.dynamic_slice(mind, (start,), (block,))
        k0 = lax.dynamic_slice(nfold, (T,), (1,))[0]

        def fold_one(k, mt):
            p = lax.dynamic_slice(order, (k,), (1,))[0]
            xp = fetch_row(p)                               # (1, d_pad)
            ap = lax.dynamic_slice(aux, (p,), (1,))         # (1,)
            # the stream kernel's own tile formula — term-for-term (and
            # dot-shape-for-dot-shape) identical rows across both Pallas
            # engines, so near-tie metrics cannot flip between them on
            # 1-ulp dot-lowering differences
            row = _tile_pivot_row(tile, xp, aux_t, ap, metric, form)
            return jnp.where(jnp.isinf(mt), inf, jnp.minimum(mt, row))

        mt = lax.fori_loop(k0, t, fold_one, mt)
        mnew = jnp.min(mt)
        mind = lax.dynamic_update_slice(mind, mt, (start,))
        tmin = lax.dynamic_update_slice(tmin, mnew[None], (T,))
        # a traced +inf (a (1,) constant would be captured; mnew*0 would
        # make NaN when the tile is fully selected and mnew is +inf)
        pend = lax.dynamic_update_slice(pend, jnp.maximum(mnew, inf)[None],
                                        (T,))
        nfold = lax.dynamic_update_slice(nfold, t[None], (T,))
        stats = stats + jnp.stack([jnp.int32(1), (t - k0).astype(jnp.int32)])
        return mind, tmin, pend, nfold, stats

    def step(t, carry):
        mind, tmin, pend, nfold, order, edges, stats, q = carry
        xq = fetch_row(q)                                   # (1, d_pad)
        pend = jnp.minimum(pend, tile_lb(xq))

        # lazy-fold loop: fold ascending-bound tiles until every unfolded
        # tile provably exceeds the best exact candidate (<= keeps ties
        # exact; fuel bounds the loop — each pass folds one tile).  Dead
        # tiles (tmin == +inf: every lane selected/padding, forever) are
        # excluded outright — their stored lanes can never win, and
        # without the mask their pend bound keeps shrinking toward an
        # active pivot and re-fetches the tile every step for nothing
        def fold_bound(tmin, pend, nfold):
            foldable = (nfold < t) & (tmin < inf)
            return jnp.where(foldable, jnp.minimum(tmin, pend), inf)

        def fold_cond(s):
            fuel, mind, tmin, pend, nfold, stats = s
            bound = fold_bound(tmin, pend, nfold)
            best_exact = jnp.min(jnp.where(nfold == t, tmin, inf))
            return (fuel < nblk) & (jnp.min(bound) <= best_exact)

        def fold_body(s):
            fuel, mind, tmin, pend, nfold, stats = s
            bound = fold_bound(tmin, pend, nfold)
            bmin = jnp.min(bound)
            T = jnp.min(jnp.where(bound == bmin, blk_iota, nblk))
            mind, tmin, pend, nfold, stats = fold_tile(
                T, t, mind, tmin, pend, nfold, order, stats)
            return fuel + 1, mind, tmin, pend, nfold, stats

        _, mind, tmin, pend, nfold, stats = lax.while_loop(
            fold_cond, fold_body,
            (jnp.int32(0), mind, tmin, pend, nfold, stats))

        best = jnp.min(jnp.where(nfold == t, tmin, inf))
        winner = jnp.min(jnp.where(mind == best, iota, n_pad)).astype(
            jnp.int32)
        mind = lax.dynamic_update_slice(mind, jnp.maximum(best, inf)[None],
                                        (winner,))
        Tw = winner // block
        mw = lax.dynamic_slice(mind, (Tw * block,), (block,))
        tmin = lax.dynamic_update_slice(tmin, jnp.min(mw)[None], (Tw,))
        order = lax.dynamic_update_slice(order, winner[None], (t,))
        edges = lax.dynamic_update_slice(edges, best[None], (t,))
        return mind, tmin, pend, nfold, order, edges, stats, winner

    stats0 = jnp.zeros((2,), jnp.int32)
    carry = lax.fori_loop(
        1, n, step, (mind0, tmin0, pend0, nfold0, order0, edges0, stats0, i0))
    order_ref[...] = carry[4]
    edges_ref[...] = carry[5]
    stats_ref[...] = carry[6]


@functools.partial(jax.jit, static_argnames=("metric", "form", "block",
                                             "interpret", "prune"))
def prim_persist_pallas(
    X: jax.Array,
    aux: jax.Array,
    i0: jax.Array,
    *,
    metric: str = "euclidean",
    form: str = "gram",
    block: int = DEFAULT_BLOCK,
    interpret: bool = False,
    prune: bool = True,
):
    """Exact VAT ordering of X in ONE persistent pallas_call.

    Pads X once (``prim_stream.pad_points``), precomputes the per-tile
    pruning geometry, and hands everything to the megakernel: the whole
    n-1 step Prim recurrence runs inside the kernel with the frontier
    VMEM-resident and X streamed tile-by-tile from ANY/HBM.

    Args:
      X: (n, d) float — data points (unpadded; padding is internal).
      aux: (n,) float32 — ``kernels.ref.metric_aux_ref`` of X.
      i0: i32 scalar — seed vertex (``core.vat._streamed_seed_pivot``).
      metric: one of ``kernels.ref.METRICS`` (static).
      form: "gram" (default) or "direct" — the numerics-policy tile
        form (static); the pruning slack is debited per form via
        ``numerics.condition.lb_slack_ulps``.
      block: X tile length (static); clamped like ``pad_points``.
      interpret: Pallas interpret mode (the CPU correctness path).
      prune: lazy-Prim tile pruning (static).  False forces the eager
        fold-everything schedule — same outputs bit for bit (the pin
        tests/test_turbo.py holds the pruning proof to), only more DMA.

    Returns:
      (order (n,) i32, edges (n,) f32, stats (2,) i32) — the exact
      ordering/edge trace plus the traffic census [tile fetches, pivot
      row folds].  Eager folding costs (n-1)·nblk tile fetches; the gap
      to ``stats[0]`` is what pruning saved.  Orderings are
      bitwise-identical to ``ref.prim_persist_ref`` for every metric
      (near-tie caveat: under heavy Gram-trick cancellation — e.g.
      cosine between near-parallel points — 1-ulp differences between
      this kernel's dot lowering and other engines' can flip exact ties;
      the two Pallas engines share one tile formula so they never flip
      against each other).

    Callers must keep ``persist_supported(n, d, block=block)`` true —
    ``kernels.ops.prim_persist`` owns that guard.
    """
    check_metric(metric)
    check_form(form)
    n = X.shape[0]
    Xp, auxp, n_pad, bn = pad_points(X.astype(jnp.float32), aux, block=block)
    cent, rad = persist_tile_bounds(Xp, n, metric=metric, block=bn)
    d_pad = Xp.shape[1]

    order, edges, stats = pl.pallas_call(
        functools.partial(_persist_kernel, n=n, metric=metric, form=form,
                          block=bn, prune=prune),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),          # i0
            pl.BlockSpec((n_pad,), lambda: (0,)),           # aux
            pl.BlockSpec((n_pad // bn, d_pad), lambda: (0, 0)),  # cent
            pl.BlockSpec((n_pad // bn,), lambda: (0,)),     # rad
            pl.BlockSpec(memory_space=pltpu.ANY),           # X (streamed)
        ],
        out_specs=[
            pl.BlockSpec((n,), lambda: (0,)),
            pl.BlockSpec((n,), lambda: (0,)),
            pl.BlockSpec((2,), lambda: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((2,), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bn, d_pad), jnp.float32),   # streamed X tile
            pltpu.VMEM((1, d_pad), jnp.float32),    # pivot row
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(jnp.asarray(i0, jnp.int32)[None], auxp, cent, rad, Xp)
    return order, edges, stats
