"""Jit'd public wrappers for the kernels package.

Dispatch policy: Pallas kernels target TPU; on a CPU backend (this
container) they run in ``interpret=True`` mode for correctness validation,
while the default production path on CPU is the XLA reference in ref.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.pairwise_dist import pairwise_dist_pallas
from repro.kernels.prim_update import masked_argmin_pallas


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def pairwise_dist(X: jax.Array, Y: jax.Array | None = None, *,
                  use_pallas: bool = False, block: int = 256) -> jax.Array:
    """Euclidean distance matrix; Pallas-tiled on request, XLA otherwise."""
    if use_pallas:
        R = pairwise_dist_pallas(X, Y, block=block, interpret=_interpret())
    else:
        R = ref.pairwise_dist_ref(X, Y)
    if Y is None:  # exact zero diagonal for self-distances
        n = R.shape[0]
        R = R * (1.0 - jnp.eye(n, dtype=R.dtype))
    return R


def masked_argmin(vals: jax.Array, mask: jax.Array, *,
                  use_pallas: bool = False, block: int = 1024):
    """(min, argmin) over unmasked entries (mask=True excludes)."""
    if use_pallas:
        return masked_argmin_pallas(vals, mask, block=block,
                                    interpret=_interpret())
    return ref.masked_argmin_ref(vals, mask)
