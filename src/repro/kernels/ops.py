"""Jit'd public wrappers for the kernels package.

Dispatch policy: Pallas kernels target TPU; on a CPU backend (this
container) they run in ``interpret=True`` mode for correctness validation,
while the default production path on CPU is the XLA reference in ref.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.ivat_update import MAX_FUSED_N, ivat_from_vat_pallas
from repro.kernels.pairwise_dist import (pairwise_dist_pallas,
                                         pairwise_dist_pallas_batch)
from repro.kernels.prim_stream import (prim_stream_step_pallas,
                                       prim_stream_step_pallas_batch)
from repro.kernels.prim_update import masked_argmin_pallas


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def pairwise_dist(X: jax.Array, Y: jax.Array | None = None, *,
                  metric: str = "euclidean", use_pallas: bool = False,
                  block: int = 256) -> jax.Array:
    """Pairwise dissimilarity matrix; Pallas-tiled on request, XLA otherwise.

    Args:
      X: (n, d) float — query points.
      Y: (m, d) float or None — reference points; None means self-
        dissimilarities (and forces an exactly-zero diagonal).
      metric: one of ``kernels.ref.METRICS`` (euclidean | sqeuclidean |
        manhattan | cosine). "precomputed" is an API-layer concept and
        never reaches the kernels.
      use_pallas: route through the MXU-tiled Pallas kernel (interpret
        mode on CPU; compiled on TPU). Default is the XLA reference path.
      block: Pallas output tile edge.

    Returns:
      (n, m) float32 dissimilarity matrix ((n, n) when Y is None).
    """
    if use_pallas:
        R = pairwise_dist_pallas(X, Y, metric=metric, block=block,
                                 interpret=_interpret())
    else:
        R = ref.pairwise_dissim_ref(X, Y, metric=metric)
    if Y is None:  # exact zero diagonal for self-dissimilarities
        n = R.shape[0]
        R = R * (1.0 - jnp.eye(n, dtype=R.dtype))
    return R


def pairwise_dist_batch(X: jax.Array, *, metric: str = "euclidean",
                        use_pallas: bool = False,
                        block: int = 256) -> jax.Array:
    """Per-dataset self-dissimilarity matrices for a (b, n, d) stack.

    Args:
      X: (b, n, d) float — b independent datasets.
      metric: one of ``kernels.ref.METRICS``.
      use_pallas: route through the batched-grid Pallas kernel
        (``pairwise_dist_pallas_batch``); default is a vmap of the XLA
        reference, which lowers to one batched dot_general.
      block: Pallas output tile edge.

    Returns:
      (b, n, n) float32 stack with exactly-zero diagonals.
    """
    if use_pallas:
        R = pairwise_dist_pallas_batch(X, metric=metric, block=block,
                                       interpret=_interpret())
    else:
        R = jax.vmap(
            lambda A: ref.pairwise_dissim_ref(A, metric=metric))(X)
    n = R.shape[-1]
    return R * (1.0 - jnp.eye(n, dtype=R.dtype))


def masked_argmin(vals: jax.Array, mask: jax.Array, *,
                  use_pallas: bool = False, block: int = 1024):
    """(min, argmin) over unmasked entries (mask=True excludes).

    Args:
      vals: (n,) float — candidate values.
      mask: (n,) bool — True lanes are excluded from the reduction.
      use_pallas: fused block-argmin kernel vs the XLA reference.
      block: Pallas VMEM tile length.

    Returns:
      (f32 scalar min, i32 scalar argmin), first-index tie-breaking.
    """
    if use_pallas:
        return masked_argmin_pallas(vals, mask, block=block,
                                    interpret=_interpret())
    return ref.masked_argmin_ref(vals, mask)


def prim_stream_step(X: jax.Array, aux: jax.Array, q: jax.Array,
                     mind: jax.Array, selected: jax.Array, *,
                     metric: str = "euclidean", use_pallas: bool = False,
                     block: int = 1024):
    """One fused matrix-free Prim step (the Flash-VAT hot loop).

    Recomputes pivot q's distance row tile-by-tile, folds it into the
    frontier min-update, and returns the masked argmin over the updated
    frontier — the next Prim vertex — without ever forming the (n, n)
    matrix.  Solo (n,)-state and batched (b, n)-state inputs both work:
    the batched Pallas path uses the slab-of-1 grid, the batched XLA
    path a vmap of the reference step.

    Args:
      X: (n, d) or (b, n, d) float — data points.  The Pallas path wants
        these pre-padded by ``kernels.prim_stream.pad_points`` (padding
        per step would copy X n times); the XLA path is pad-agnostic.
      aux: (n,) or (b, n) float32 — ``ref.metric_aux_ref`` of X.
      q: i32 scalar or (b,) — pivot(s) selected by the previous step.
      mind: like aux — frontier distances (padded lanes +inf).
      selected: bool, like aux — visited mask (padded lanes True).
      metric: one of ``kernels.ref.METRICS``.
      use_pallas: fused Pallas kernel vs the XLA reference step.
      block: Pallas VMEM tile length (must divide the padded n).

    Returns:
      (new_mind, edge, next) with the input's leading shape — see
      ``ref.prim_stream_step_ref``.
    """
    batched = X.ndim == 3
    if use_pallas:
        step = (prim_stream_step_pallas_batch if batched
                else prim_stream_step_pallas)
        return step(X, aux, q, mind, selected, metric=metric, block=block,
                    interpret=_interpret())
    if batched:
        return jax.vmap(
            lambda Xi, ai, qi, mi, si: ref.prim_stream_step_ref(
                Xi, ai, qi, mi, si, metric=metric)
        )(X, aux, q, mind, selected)
    return ref.prim_stream_step_ref(X, aux, q, mind, selected, metric=metric)


def ivat_from_vat(rstar: jax.Array, *, use_pallas: bool = False) -> jax.Array:
    """iVAT geodesic transform of VAT-ordered dissimilarities.

    Args:
      rstar: (n, n) or (b, n, n) float — VAT-ordered matrix/stack.
      use_pallas: route through the fused VMEM-resident row-update kernel
        (``kernels/ivat_update.py``; interpret mode on CPU, compiled on
        TPU). Matrices with n > ``MAX_FUSED_N`` exceed the kernel's VMEM
        slab budget and silently take the XLA fallback instead.

    Returns:
      (n, n) or (b, n, n) float32 max-min path distance matrix/stack.
    """
    n = rstar.shape[-1]
    if use_pallas and n <= MAX_FUSED_N:
        return ivat_from_vat_pallas(rstar, interpret=_interpret())
    if rstar.ndim == 3:
        return jax.vmap(ref.ivat_from_vat_ref)(rstar)
    return ref.ivat_from_vat_ref(rstar)
