"""Jit'd public wrappers for the kernels package.

Dispatch policy: Pallas kernels target TPU; on a CPU backend (this
container) they run in ``interpret=True`` mode for correctness validation,
while the default production path on CPU is the XLA reference in ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import faults
from repro.kernels import ref
from repro.kernels.ivat_update import MAX_FUSED_N, ivat_from_vat_pallas
from repro.kernels.knn_graph import (MAX_PALLAS_K, XLA_BLOCK,
                                     knn_graph_blocked, knn_graph_pallas,
                                     knn_graph_pallas_batch)
from repro.kernels.pairwise_dist import (pairwise_dist_pallas,
                                         pairwise_dist_pallas_batch)
from repro.kernels.prim_persist import (persist_supported,
                                        prim_persist_pallas)
from repro.kernels.prim_stream import (prim_frontier_step_pallas,
                                       prim_stream_step_pallas,
                                       prim_stream_step_pallas_batch)
from repro.kernels.prim_update import masked_argmin_pallas


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def _dispatch_site(op: str, use_pallas: bool) -> None:
    """The ``kernels.dispatch`` fault-injection site (ISSUE 9).

    Called at the top of every public wrapper — i.e. at *trace* time
    when the wrapper runs under jit — so an armed fault here models a
    kernel compile/build failure: fresh traces raise, already-compiled
    programs are untouched.  Disarmed (production) this is one dict
    truthiness check; it adds nothing to the jaxpr, so the dispatch
    census stays byte-identical (pinned by tests/test_resilience.py).
    """
    faults.fault_point("kernels.dispatch",
                       context={"op": op, "use_pallas": use_pallas})


def pairwise_dist(X: jax.Array, Y: jax.Array | None = None, *,
                  metric: str = "euclidean", form: str = "gram",
                  use_pallas: bool = False, block: int = 256) -> jax.Array:
    """Pairwise dissimilarity matrix; Pallas-tiled on request, XLA otherwise.

    Args:
      X: (n, d) float — query points.
      Y: (m, d) float or None — reference points; None means self-
        dissimilarities (and forces an exactly-zero diagonal).
      metric: one of ``kernels.ref.METRICS`` (euclidean | sqeuclidean |
        manhattan | cosine). "precomputed" is an API-layer concept and
        never reaches the kernels.
      form: "gram" (default) or "direct" — the numerics-policy tile
        form, resolved host-side by ``numerics.resolve`` (static).
      use_pallas: route through the MXU-tiled Pallas kernel (interpret
        mode on CPU; compiled on TPU). Default is the XLA reference path.
      block: Pallas output tile edge.

    Returns:
      (n, m) float32 dissimilarity matrix ((n, n) when Y is None).
    """
    _dispatch_site("pairwise_dist", use_pallas)
    if use_pallas:
        R = pairwise_dist_pallas(X, Y, metric=metric, form=form, block=block,
                                 interpret=_interpret())
    else:
        R = ref.pairwise_dissim_ref(X, Y, metric=metric, form=form)
    if Y is None:  # exact zero diagonal for self-dissimilarities
        n = R.shape[0]
        R = R * (1.0 - jnp.eye(n, dtype=R.dtype))
    return R


def pairwise_dist_batch(X: jax.Array, *, metric: str = "euclidean",
                        form: str = "gram", use_pallas: bool = False,
                        block: int = 256) -> jax.Array:
    """Per-dataset self-dissimilarity matrices for a (b, n, d) stack.

    Args:
      X: (b, n, d) float — b independent datasets.
      metric: one of ``kernels.ref.METRICS``.
      form: "gram" (default) or "direct" — the numerics-policy tile form.
      use_pallas: route through the batched-grid Pallas kernel
        (``pairwise_dist_pallas_batch``); default is a vmap of the XLA
        reference, which lowers to one batched dot_general.
      block: Pallas output tile edge.

    Returns:
      (b, n, n) float32 stack with exactly-zero diagonals.
    """
    _dispatch_site("pairwise_dist_batch", use_pallas)
    if use_pallas:
        R = pairwise_dist_pallas_batch(X, metric=metric, form=form,
                                       block=block, interpret=_interpret())
    else:
        R = jax.vmap(
            lambda A: ref.pairwise_dissim_ref(A, metric=metric,
                                              form=form))(X)
    n = R.shape[-1]
    return R * (1.0 - jnp.eye(n, dtype=R.dtype))


def knn_graph(X: jax.Array, *, k: int, metric: str = "euclidean",
              use_pallas: bool = False, block: int | None = None):
    """k-nearest-neighbour graph at O(n·k) memory; never builds (n, n).

    The approximate-MST rung's first stage.  Both paths share one tie
    contract (lower index wins on equal distances) and one output shape;
    see ``kernels/knn_graph.py`` for the tiling story.

    Args:
      X: (n, d) float — data points.
      k: neighbours per point (1 <= k <= n-1).
      metric: one of ``kernels.ref.METRICS``.
      use_pallas: route through the fused Pallas top-k fold (interpret
        mode on CPU; compiled on TPU).  k > ``MAX_PALLAS_K`` exceeds the
        fold's unroll budget and silently takes the XLA driver instead
        (the ``MAX_FUSED_N`` precedent).
      block: tile edge; None picks each path's default (the Pallas tile
        is VMEM-bound, the XLA tile dispatch-bound, so they differ).

    Returns:
      (dist (n, k) f32 ascending per row, idx (n, k) i32) — idx[i, 0] is
      i's nearest neighbour; a point is never its own neighbour.
    """
    _dispatch_site("knn_graph", use_pallas)
    if use_pallas and k <= MAX_PALLAS_K:
        return knn_graph_pallas(X, k=k, metric=metric,
                                block=block if block is not None else 256,
                                interpret=_interpret())
    return knn_graph_blocked(
        X, k=k, metric=metric,
        block=block if block is not None else XLA_BLOCK)


def knn_graph_batch(X: jax.Array, *, k: int, metric: str = "euclidean",
                    use_pallas: bool = False, block: int | None = None):
    """Per-dataset kNN graphs for a (b, n, d) stack.

    Args:
      X: (b, n, d) float — b independent datasets.
      k, metric, use_pallas, block: as ``knn_graph``; the Pallas path is
        the slab-of-1 batched grid, the XLA path a vmap of the blocked
        driver.

    Returns:
      (dist (b, n, k) f32, idx (b, n, k) i32).
    """
    _dispatch_site("knn_graph_batch", use_pallas)
    if use_pallas and k <= MAX_PALLAS_K:
        return knn_graph_pallas_batch(
            X, k=k, metric=metric,
            block=block if block is not None else 256,
            interpret=_interpret())
    return jax.vmap(lambda A: knn_graph_blocked(
        A, k=k, metric=metric,
        block=block if block is not None else XLA_BLOCK))(X)


def masked_argmin(vals: jax.Array, mask: jax.Array, *,
                  use_pallas: bool = False, block: int = 1024):
    """(min, argmin) over unmasked entries (mask=True excludes).

    Args:
      vals: (n,) float — candidate values.
      mask: (n,) bool — True lanes are excluded from the reduction.
      use_pallas: fused block-argmin kernel vs the XLA reference.
      block: Pallas VMEM tile length.

    Returns:
      (f32 scalar min, i32 scalar argmin), first-index tie-breaking.
    """
    _dispatch_site("masked_argmin", use_pallas)
    if use_pallas:
        return masked_argmin_pallas(vals, mask, block=block,
                                    interpret=_interpret())
    return ref.masked_argmin_ref(vals, mask)


def prim_stream_step(X: jax.Array, aux: jax.Array, q: jax.Array,
                     mind: jax.Array, selected: jax.Array, *,
                     metric: str = "euclidean", form: str = "gram",
                     use_pallas: bool = False, block: int = 1024):
    """One fused matrix-free Prim step (the Flash-VAT hot loop).

    Recomputes pivot q's distance row tile-by-tile, folds it into the
    frontier min-update, and returns the masked argmin over the updated
    frontier — the next Prim vertex — without ever forming the (n, n)
    matrix.  Solo (n,)-state and batched (b, n)-state inputs both work:
    the batched Pallas path uses the slab-of-1 grid, the batched XLA
    path a vmap of the reference step.

    Args:
      X: (n, d) or (b, n, d) float — data points.  The Pallas path wants
        these pre-padded by ``kernels.prim_stream.pad_points`` (padding
        per step would copy X n times); the XLA path is pad-agnostic.
      aux: (n,) or (b, n) float32 — ``ref.metric_aux_ref`` of X.
      q: i32 scalar or (b,) — pivot(s) selected by the previous step.
      mind: like aux — frontier distances (padded lanes +inf).
      selected: bool, like aux — visited mask (padded lanes True).
      metric: one of ``kernels.ref.METRICS``.
      form: "gram" (default) or "direct" — the numerics-policy tile form.
      use_pallas: fused Pallas kernel vs the XLA reference step.
      block: Pallas VMEM tile length (must divide the padded n).

    Returns:
      (new_mind, edge, next) with the input's leading shape — see
      ``ref.prim_stream_step_ref``.
    """
    _dispatch_site("prim_stream_step", use_pallas)
    batched = X.ndim == 3
    if use_pallas:
        step = (prim_stream_step_pallas_batch if batched
                else prim_stream_step_pallas)
        return step(X, aux, q, mind, selected, metric=metric, form=form,
                    block=block, interpret=_interpret())
    if batched:
        return jax.vmap(
            lambda Xi, ai, qi, mi, si: ref.prim_stream_step_ref(
                Xi, ai, qi, mi, si, metric=metric, form=form)
        )(X, aux, q, mind, selected)
    return ref.prim_stream_step_ref(X, aux, q, mind, selected, metric=metric,
                                    form=form)


def prim_persist(X: jax.Array, aux: jax.Array, i0: jax.Array, *,
                 metric: str = "euclidean", form: str = "gram",
                 block: int = 1024, use_pallas: bool = False):
    """The whole Prim traversal in one dispatch (the Turbo engine).

    Solo (n, d) input runs the persistent path: the Pallas megakernel
    (``kernels/prim_persist.py`` — one pallas_call, VMEM-resident state,
    lazy-Prim tile pruning) when requested AND its resident state fits
    ``PERSIST_VMEM_BUDGET``, else the single-scan XLA mirror
    (``ref.prim_persist_ref``).  The fallback is always the *persistent*
    mirror — the stepwise engine is never silently substituted (pinned
    by tests/test_turbo.py).  Batched (b, n, d) input vmaps the mirror:
    the megakernel is deliberately solo-only (its DMA streaming does not
    batch; per-lane orderings are identical either way).

    Args:
      X: (n, d) or (b, n, d) float — data points (unpadded).
      aux: (n,) or (b, n) float32 — ``ref.metric_aux_ref`` of X.
      i0: i32 scalar or (b,) — seed vertex per dataset.
      metric: one of ``kernels.ref.METRICS``.
      form: "gram" (default) or "direct" — the numerics-policy tile
        form; the megakernel's pruning slack is debited per form.
      block: megakernel X-tile length.
      use_pallas: megakernel vs the XLA mirror (solo only).

    Returns:
      (order, edges) with the input's leading shape — (n,)/(b, n) i32
      and f32; bitwise-identical across every path for every metric.
    """
    _dispatch_site("prim_persist", use_pallas)
    if X.ndim == 3:
        return jax.vmap(lambda Xi, ai, ii: ref.prim_persist_ref(
            Xi, ai, ii, metric=metric, form=form))(X, aux, i0)
    if use_pallas and persist_supported(X.shape[0], X.shape[1], block=block):
        order, edges, _ = prim_persist_pallas(X, aux, i0, metric=metric,
                                              form=form, block=block,
                                              interpret=_interpret())
        return order, edges
    return ref.prim_persist_ref(X, aux, i0, metric=metric, form=form)


def prim_frontier_step(X: jax.Array, aux: jax.Array, xq: jax.Array,
                       auxq: jax.Array, mind: jax.Array, *,
                       metric: str = "euclidean", form: str = "gram",
                       use_pallas: bool = False, block: int = 1024):
    """Fused frontier fold + masked argmin, pivot passed by value.

    The per-device body of the sharded matrix-free engine
    (``core.distributed.vat_matrix_free_sharded``): the pivot row arrives
    by collective broadcast, the device folds it into its local frontier
    and emits the local (min, argmin) pair for the cross-device
    reduction.  Selected/padded lanes are carried *in-band* as
    ``mind = +inf`` (see ``ref.prim_frontier_step_ref``); the Pallas
    path derives its mask from that and re-masks the folded frontier so
    the in-band encoding survives the kernel.

    Args:
      X: (n, d) float — local points (Pallas path: pre-padded, with
        ``block`` dividing n).
      aux: (n,) float32 — ``ref.metric_aux_ref`` of X.
      xq: (d,) float — the pivot point.
      auxq: f32 scalar — the pivot's aux entry.
      mind: (n,) float32 — in-band frontier (+inf = selected/padding).
      metric: one of ``kernels.ref.METRICS``.
      form: "gram" (default) or "direct" — the numerics-policy tile form.
      use_pallas: fused Pallas tile kernel vs the XLA reference.
      block: Pallas VMEM tile length.

    Returns:
      (new_mind (n,) f32, value f32 scalar, idx i32 scalar) — first-index
      tie-breaking, identical across both paths.
    """
    _dispatch_site("prim_frontier_step", use_pallas)
    if use_pallas:
        selected = jnp.isinf(mind)
        new_mind, value, idx = prim_frontier_step_pallas(
            X, aux, xq, auxq, mind, selected, metric=metric, form=form,
            block=block, interpret=_interpret())
        return jnp.where(selected, jnp.inf, new_mind), value, idx
    return ref.prim_frontier_step_ref(X, aux, xq, auxq, mind, metric=metric,
                                      form=form)


def kernel_dispatch_stats(fn, *args, **kwargs) -> dict:
    """Static dispatch census of a jittable function: how many
    ``pallas_call`` equations its jaxpr holds, and how many sit OUTSIDE
    any loop (while/scan) — i.e. run exactly once per invocation.

    The persistent-engine regression gate reads this: the Turbo path
    must show one loop-free pallas_call (the megakernel), while the
    stepwise engine's kernel lives under the Prim while-loop and
    re-dispatches every step.

    Args:
      fn: the function to trace (positional ``args`` / keyword
        ``kwargs`` forwarded to ``jax.make_jaxpr``).

    Returns:
      {"pallas_calls": total count, "persistent": count outside loops}.
    """
    jaxpr = jax.make_jaxpr(functools.partial(fn, **kwargs))(*args).jaxpr

    def walk(jx, in_loop):
        total = persistent = 0
        for eqn in jx.eqns:
            name = eqn.primitive.name
            if name == "pallas_call":
                total += 1
                persistent += 0 if in_loop else 1
            looped = in_loop or name in ("while", "scan")
            for v in eqn.params.values():
                for u in (v if isinstance(v, (list, tuple)) else (v,)):
                    sub = getattr(u, "jaxpr", u)
                    if hasattr(sub, "eqns"):
                        t, p = walk(sub, looped)
                        total += t
                        persistent += p
        return total, persistent

    total, persistent = walk(jaxpr, False)
    return {"pallas_calls": total, "persistent": persistent}


def ivat_from_vat(rstar: jax.Array, *, use_pallas: bool = False) -> jax.Array:
    """iVAT geodesic transform of VAT-ordered dissimilarities.

    Args:
      rstar: (n, n) or (b, n, n) float — VAT-ordered matrix/stack.
      use_pallas: route through the fused VMEM-resident row-update kernel
        (``kernels/ivat_update.py``; interpret mode on CPU, compiled on
        TPU). Matrices with n > ``MAX_FUSED_N`` exceed the kernel's VMEM
        slab budget and silently take the XLA fallback instead.

    Returns:
      (n, n) or (b, n, n) float32 max-min path distance matrix/stack.
    """
    _dispatch_site("ivat_from_vat", use_pallas)
    n = rstar.shape[-1]
    if use_pallas and n <= MAX_FUSED_N:
        return ivat_from_vat_pallas(rstar, interpret=_interpret())
    if rstar.ndim == 3:
        return jax.vmap(ref.ivat_from_vat_ref)(rstar)
    return ref.ivat_from_vat_ref(rstar)
