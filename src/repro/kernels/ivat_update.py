"""Pallas TPU kernel: fused iVAT row-update (Havens & Bezdek recurrence).

The XLA path in ``kernels/ref.py::ivat_from_vat_ref`` builds each geodesic
row with ``Dp.at[r].set`` / ``Dp.at[:, r].set`` — every step re-emits a
full-matrix dynamic_update_slice pair, which the VPU executes as two
(n, n) copies.  This kernel keeps the growing D' matrix resident in VMEM
across the whole recurrence and touches only the O(n) row/column actually
written per step:

  * grid (b, n-1): the batch dim first, then one grid step per recurrence
    step r = t + 1.  TPU grids iterate sequentially (last axis fastest),
    which is exactly the dependency order the recurrence needs, and the
    constant index map means each (n, n) slab stays in VMEM for all of
    its n-1 steps (the batch axis revision semantics re-materialize it
    per batch element).
  * each step is three VPU-friendly (1, n) vector ops (masked argmin,
    max-merge, predicated select) plus two O(n) stores — no
    full-matrix traffic.
  * the column store ``o_ref[0, :, ds(r, 1)]`` is a dynamic lane-dim
    scatter; Mosaic lowers it as a strided store (docs/kernels.md
    discusses the cost and the VMEM ceiling this kernel accepts to keep
    D' resident).

VMEM budget: input slab + output slab = 2 * n^2 * 4 B, so n <= 1024 fits
comfortably (~8.4 MiB with temporaries) and n = 1448 is the hard ceiling
on a 16 MiB core.  ``kernels/ops.py::ivat_from_vat`` falls back to the
XLA path above ``MAX_FUSED_N``; on CPU the kernel runs in interpret mode
for correctness testing, matching ``pairwise_dist.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_LANE = 128   # pad n to a lane multiple so (1, n) rows are VREG-aligned
MAX_FUSED_N = 1024  # keep 2 * n^2 * 4B well under the 16 MiB VMEM core


def _ivat_kernel(rstar_ref, o_ref):
    """One recurrence step r = program_id(1) + 1 on a (1, n, n) slab pair."""
    n = rstar_ref.shape[-1]
    t = pl.program_id(1)
    r = t + 1

    @pl.when(t == 0)
    def _init():  # D'[0, :] = D'[:, 0] = 0 seeds the recurrence
        o_ref[...] = jnp.zeros_like(o_ref)

    row = rstar_ref[0, pl.ds(r, 1), :].reshape(n).astype(jnp.float32)
    k = jax.lax.broadcasted_iota(jnp.int32, (n,), 0)
    prefix = k < r                                  # already-ordered points
    masked = jnp.where(prefix, row, jnp.inf)
    j = jnp.argmin(masked).astype(jnp.int32)        # nearest ordered point
    dcut = jnp.min(masked)                          # = R*[r, j], the MST edge
    dpj = o_ref[0, pl.ds(j, 1), :].reshape(n)       # D'[j, :]
    newrow = jnp.where(prefix, jnp.maximum(dcut, dpj), 0.0)
    o_ref[0, pl.ds(r, 1), :] = newrow.reshape(1, n).astype(o_ref.dtype)
    o_ref[0, :, pl.ds(r, 1)] = newrow.reshape(n, 1).astype(o_ref.dtype)


def _pad_square(R: jax.Array, n_pad: int) -> jax.Array:
    """Zero-pad the trailing (n, n) dims of a (b, n, n) stack to n_pad."""
    pad = n_pad - R.shape[-1]
    if pad == 0:
        return R
    return jnp.pad(R, ((0, 0), (0, pad), (0, pad)))


@functools.partial(jax.jit, static_argnames=("interpret",))
def ivat_from_vat_pallas(rstar: jax.Array, *, interpret: bool = False
                         ) -> jax.Array:
    """Fused iVAT transform of VAT-ordered dissimilarities.

    Args:
      rstar: (n, n) or (b, n, n) float — VAT-ordered dissimilarity
        matrix/stack (``core.vat.vat_order`` output order). n is padded to
        a lane multiple internally; padding never enters the recurrence
        because the per-step prefix mask only admits k < r < n.
      interpret: run the kernel in Pallas interpret mode (the CPU
        correctness path; compiled Mosaic on TPU).

    Returns:
      (n, n) or (b, n, n) float32 — geodesic (max-min path) distance
      matrix D', same leading shape as the input.
    """
    squeeze = rstar.ndim == 2
    R = rstar[None] if squeeze else rstar
    b, n, _ = R.shape
    if n < 2:  # recurrence is empty; D' is all zeros
        out0 = jnp.zeros(R.shape, jnp.float32)
        return out0[0] if squeeze else out0
    n_pad = -(-n // _LANE) * _LANE
    Rp = _pad_square(R.astype(jnp.float32), n_pad)

    out = pl.pallas_call(
        _ivat_kernel,
        grid=(b, n - 1),
        in_specs=[pl.BlockSpec((1, n_pad, n_pad), lambda bi, t: (bi, 0, 0))],
        out_specs=pl.BlockSpec((1, n_pad, n_pad), lambda bi, t: (bi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n_pad, n_pad), jnp.float32),
        interpret=interpret,
    )(Rp)
    out = out[:, :n, :n]
    return out[0] if squeeze else out
