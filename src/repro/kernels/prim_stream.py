"""Pallas TPU kernel: fused matrix-free Prim step (the Flash-VAT engine).

Exact VAT needs, per Prim step, three things the materialized path reads
off the (n, n) matrix: the pivot's distance row, the frontier min-update
``mind = min(mind, row)``, and the masked argmin that picks the next
vertex.  This kernel does all three in ONE pass over X tiled from HBM —
FlashAttention's trick applied to Prim's traversal: recompute the
distance tile on the fly, reduce it immediately, never write it back.
The (n, n) matrix is never formed; peak memory is O(n·d) for X plus the
O(n) frontier state, which is what lets *exact* VAT reach the sizes that
previously forced the sampled (approximate) rungs.

Per grid step b (one VMEM tile of B points):

  * X tile (B, d) and the pivot row x_q (1, d) are staged HBM->VMEM;
    the cross term is a single (B, d) x (d, 1) MXU matvec (Gram trick,
    same decomposition as ``kernels/pairwise_dist.py``), or a broadcast
    |diff| reduce for manhattan — all ``kernels.ref.METRICS`` dispatch
    statically, each compiling its own tile.
  * the min-update and the per-block masked (min, argmin) pair happen on
    the VPU in the same pass; the tiny (nblk,) cross-block reduction runs
    in the jit'd wrapper, first-index tie-breaking preserved.

VMEM budget at the default B=1024, d<=512: X tile 1024*512*4B = 2 MiB
plus four (B,) vectors — far under the 16 MiB core.  The batched grid
(b, nblk) follows the slab-of-1 BlockSpec pattern of
``pairwise_dist_pallas_batch``: per-program VMEM stays at the unbatched
budget regardless of the batch size.

Padding: padded rows (X zeros) DO produce computed distances, but their
``selected`` lanes are padded True and their ``mind`` lanes +inf, so
they can never win the argmin; ``core.vat.vat_matrix_free`` keeps its
frontier state padded across the whole loop, so nothing is re-padded
per step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import check_metric
from repro.numerics.condition import check_form

DEFAULT_BLOCK = 1024
_LANE = 128  # MXU/VREG lane width — pad d to a multiple


def _tile_pivot_row(x, xq, aux, auxq, metric, form):
    """((B, d), (1, d), (B,), (1,)) -> (B,) dissimilarities to the pivot.

    Mirrors ``kernels.ref.pivot_row_ref`` term for term so the fused path
    reproduces the XLA path's orderings (same formula, same clamps).
    ``form == "direct"`` (euclidean/sqeuclidean under the safe/auto
    numerics policies) replaces the MXU matvec with a broadcast squared
    -difference reduce — no Gram cancellation, at a VPU-bound cost.
    """
    if metric == "manhattan":
        return jnp.sum(jnp.abs(x - xq), axis=-1)
    if form == "direct" and metric != "cosine":
        diff = x - xq
        sq = jnp.sum(diff * diff, axis=-1)
        return jnp.sqrt(sq) if metric == "euclidean" else sq
    cross = jax.lax.dot_general(            # MXU: (B, d) x (1, d)^T
        x, xq, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32).reshape(x.shape[0])
    aq = auxq[0]
    if metric == "cosine":
        denom = jnp.maximum(aux * aq, 1e-12)
        return jnp.clip(1.0 - cross / denom, 0.0, 2.0)
    sq = jnp.maximum(aux + aq - 2.0 * cross, 0.0)
    return jnp.sqrt(sq) if metric == "euclidean" else sq


def _prim_stream_kernel(x_ref, xq_ref, aux_ref, auxq_ref, mind_ref, sel_ref,
                        newmind_ref, minv_ref, mini_ref, *, metric, form):
    b = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)          # (B, d)
    xq = xq_ref[...].astype(jnp.float32)        # (1, d)
    row = _tile_pivot_row(x, xq, aux_ref[...], auxq_ref[...], metric, form)
    new = jnp.minimum(mind_ref[...], row)       # Prim min-update, fused
    newmind_ref[...] = new
    masked = jnp.where(sel_ref[...], jnp.inf, new)
    # plain reductions only — a dynamic masked[argmin] gather is the
    # least-supported VMEM access pattern in Mosaic, and min(masked) is
    # the same value (the argmin's element) by definition
    minv_ref[0] = jnp.min(masked)
    i = jnp.argmin(masked).astype(jnp.int32)    # block-local, first-index
    mini_ref[0] = i + b * x.shape[0]


def _prim_stream_kernel_batch(x_ref, xq_ref, aux_ref, auxq_ref, mind_ref,
                              sel_ref, newmind_ref, minv_ref, mini_ref, *,
                              metric, form):
    j = pl.program_id(1)
    x = x_ref[0].astype(jnp.float32)            # (1, B, d) slab -> (B, d)
    xq = xq_ref[0].astype(jnp.float32)          # (1, 1, d) slab -> (1, d)
    row = _tile_pivot_row(x, xq, aux_ref[0], auxq_ref[0], metric, form)
    new = jnp.minimum(mind_ref[0], row)
    newmind_ref[0] = new
    masked = jnp.where(sel_ref[0], jnp.inf, new)
    minv_ref[0, 0] = jnp.min(masked)            # see solo kernel note
    i = jnp.argmin(masked).astype(jnp.int32)
    mini_ref[0, 0] = i + j * x.shape[0]


def pad_points(X: jax.Array, aux: jax.Array, *, block: int = DEFAULT_BLOCK):
    """Pad (X, aux) once so every later fused step runs pad-free.

    Args:
      X: (n, d) float — data points.
      aux: (n,) float32 — ``kernels.ref.metric_aux_ref`` of X.
      block: the tile length the steps will use (static).

    Returns:
      (Xp (..., n_pad, d_pad) f32, auxp (..., n_pad) f32, n_pad,
      block_clamped) — n padded to a multiple of the clamped block, d to
      the 128-lane width; leading (batch) axes pass through untouched.
      Padded rows are zero; the caller masks them via its frontier state
      (selected=True, mind=+inf), never via the kernel.
    """
    n, d = X.shape[-2:]
    bn = min(block, max(8, n))
    n_pad = -(-n // bn) * bn
    d_pad = -(-d // _LANE) * _LANE
    lead = [(0, 0)] * (X.ndim - 2)
    Xp = jnp.pad(X.astype(jnp.float32),
                 lead + [(0, n_pad - n), (0, d_pad - d)])
    auxp = jnp.pad(aux.astype(jnp.float32), lead + [(0, n_pad - n)])
    return Xp, auxp, n_pad, bn


def _stream_call(Xp, xq, auxp, auxq, mind, selected, *, metric, form, block,
                 interpret):
    """Shared pallas_call of the solo fused step: pivot passed by value.

    Both front doors use it — ``prim_stream_step_pallas`` (pivot given as
    an index into Xp, the stepwise Flash-VAT engine) and
    ``prim_frontier_step_pallas`` (pivot given as a point, the sharded
    engine where the pivot row arrives by collective broadcast).
    """
    n_pad, d_pad = Xp.shape
    nblk = n_pad // block
    new_mind, minv, mini = pl.pallas_call(
        functools.partial(_prim_stream_kernel, metric=metric, form=form),
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((block, d_pad), lambda b: (b, 0)),
            pl.BlockSpec((1, d_pad), lambda b: (0, 0)),
            pl.BlockSpec((block,), lambda b: (b,)),
            pl.BlockSpec((1,), lambda b: (0,)),
            pl.BlockSpec((block,), lambda b: (b,)),
            pl.BlockSpec((block,), lambda b: (b,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda b: (b,)),
            pl.BlockSpec((1,), lambda b: (b,)),
            pl.BlockSpec((1,), lambda b: (b,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad,), jnp.float32),
            jax.ShapeDtypeStruct((nblk,), jnp.float32),
            jax.ShapeDtypeStruct((nblk,), jnp.int32),
        ],
        interpret=interpret,
    )(Xp, xq, auxp, auxq, mind, selected)
    best = jnp.argmin(minv)         # (nblk,) cross-block pass, negligible
    return new_mind, minv[best], mini[best]


@functools.partial(jax.jit,
                   static_argnames=("metric", "form", "block", "interpret"))
def prim_frontier_step_pallas(
    Xp: jax.Array,
    auxp: jax.Array,
    xq: jax.Array,
    auxq: jax.Array,
    mind: jax.Array,
    selected: jax.Array,
    *,
    metric: str = "euclidean",
    form: str = "gram",
    block: int = DEFAULT_BLOCK,
    interpret: bool = False,
):
    """The fused step with the pivot passed by VALUE instead of index.

    The sharded matrix-free engine's per-device kernel: the pivot row
    usually lives on another device and arrives via a psum broadcast, so
    there is no local index to gather.  Same kernel, same tile math,
    same first-index tie-breaking as ``prim_stream_step_pallas``.

    Args:
      Xp: (n_pad, d_pad) f32 — the device's padded local points.
      auxp: (n_pad,) f32 — padded local auxiliary vector.
      xq: (d_pad,) f32 — the (padded) pivot point.
      auxq: f32 scalar — the pivot's ``metric_aux_ref`` entry.
      mind / selected / metric / form / block / interpret: as in
        ``prim_stream_step_pallas``.

    Returns:
      (new_mind (n_pad,) f32, value f32, idx i32) — the folded frontier
      (selected lanes carry ``min(mind, row)`` like the stepwise kernel;
      in-band callers re-mask, see ``kernels.ops.prim_frontier_step``)
      and its masked (min, argmin) pair.
    """
    check_metric(metric)
    check_form(form)
    return _stream_call(Xp, xq.reshape(1, -1), auxp, auxq.reshape(1),
                        mind, selected, metric=metric, form=form,
                        block=block, interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("metric", "form", "block", "interpret"))
def prim_stream_step_pallas(
    Xp: jax.Array,
    auxp: jax.Array,
    q: jax.Array,
    mind: jax.Array,
    selected: jax.Array,
    *,
    metric: str = "euclidean",
    form: str = "gram",
    block: int = DEFAULT_BLOCK,
    interpret: bool = False,
):
    """One fused Prim step over pre-padded points (see ``pad_points``).

    Args:
      Xp: (n_pad, d_pad) f32 — padded data points.
      auxp: (n_pad,) f32 — padded metric auxiliary vector.
      q: i32 scalar (traced ok) — pivot selected by the previous step;
        its row x_q is gathered here (O(d)) and broadcast to every tile.
      mind: (n_pad,) f32 — frontier distances before folding in q's row;
        padded lanes must be +inf.
      selected: (n_pad,) bool — True lanes excluded from the argmin
        (already visited + padding).
      metric: one of ``kernels.ref.METRICS`` (static).
      form: "gram" (default) or "direct" — the numerics-policy tile
        form (static; see ``_tile_pivot_row``).
      block: VMEM tile length (static; must divide n_pad — use the
        clamped block ``pad_points`` returns).
      interpret: Pallas interpret mode (CPU correctness path).

    Returns:
      (new_mind (n_pad,) f32, edge f32 scalar, next i32 scalar) —
      matching ``kernels.ref.prim_stream_step_ref`` on the unpadded
      prefix: the updated frontier, the next vertex's MST edge weight,
      and the next vertex index (first-index tie-breaking across and
      within blocks).
    """
    check_metric(metric)
    check_form(form)
    xq = jax.lax.dynamic_slice_in_dim(Xp, q, 1, axis=0)        # (1, d_pad)
    auxq = jax.lax.dynamic_slice_in_dim(auxp, q, 1, axis=0)    # (1,)
    return _stream_call(Xp, xq, auxp, auxq, mind, selected, metric=metric,
                        form=form, block=block, interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("metric", "form", "block", "interpret"))
def prim_stream_step_pallas_batch(
    Xp: jax.Array,
    auxp: jax.Array,
    q: jax.Array,
    mind: jax.Array,
    selected: jax.Array,
    *,
    metric: str = "euclidean",
    form: str = "gram",
    block: int = DEFAULT_BLOCK,
    interpret: bool = False,
):
    """Batched fused Prim step: b independent frontiers, one pallas_call.

    The grid grows a leading batch axis, (b, nblk), and every BlockSpec
    gains a size-1 slab dim indexed by the batch coordinate — the same
    pattern as ``pairwise_dist_pallas_batch``, so per-program VMEM stays
    at the unbatched budget regardless of b.

    Args:
      Xp: (b, n_pad, d_pad) f32 — padded datasets.
      auxp: (b, n_pad) f32 — padded per-dataset auxiliary vectors.
      q: (b,) i32 — per-dataset pivot from the previous step.
      mind: (b, n_pad) f32 — per-dataset frontiers (padding +inf).
      selected: (b, n_pad) bool — per-dataset visited masks (padding True).
      metric, form, block, interpret: as in ``prim_stream_step_pallas``.

    Returns:
      (new_mind (b, n_pad) f32, edge (b,) f32, next (b,) i32) — each lane
      bitwise-identical to the solo step on its own dataset (no
      cross-dataset reduction exists anywhere).
    """
    check_metric(metric)
    check_form(form)
    b, n_pad, d_pad = Xp.shape
    nblk = n_pad // block
    xq = jax.vmap(
        lambda x, i: jax.lax.dynamic_slice_in_dim(x, i, 1, 0))(Xp, q)
    auxq = jax.vmap(
        lambda a, i: jax.lax.dynamic_slice_in_dim(a, i, 1, 0))(auxp, q)

    new_mind, minv, mini = pl.pallas_call(
        functools.partial(_prim_stream_kernel_batch, metric=metric,
                          form=form),
        grid=(b, nblk),
        in_specs=[
            pl.BlockSpec((1, block, d_pad), lambda bi, j: (bi, j, 0)),
            pl.BlockSpec((1, 1, d_pad), lambda bi, j: (bi, 0, 0)),
            pl.BlockSpec((1, block), lambda bi, j: (bi, j)),
            pl.BlockSpec((1, 1), lambda bi, j: (bi, 0)),
            pl.BlockSpec((1, block), lambda bi, j: (bi, j)),
            pl.BlockSpec((1, block), lambda bi, j: (bi, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, block), lambda bi, j: (bi, j)),
            pl.BlockSpec((1, 1), lambda bi, j: (bi, j)),
            pl.BlockSpec((1, 1), lambda bi, j: (bi, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, n_pad), jnp.float32),
            jax.ShapeDtypeStruct((b, nblk), jnp.float32),
            jax.ShapeDtypeStruct((b, nblk), jnp.int32),
        ],
        interpret=interpret,
    )(Xp, xq, auxp, auxq, mind, selected)
    best = jnp.argmin(minv, axis=1)                      # (b,) per lane
    lane = jnp.arange(b)
    return new_mind, minv[lane, best], mini[lane, best]
