"""Pallas TPU kernel: blocked brute-force kNN graph, metric-dispatched.

The approximate-MST rung (``core/approx_mst.py``) consumes a sparse
(n, k) neighbour graph instead of the (n, n) matrix.  This kernel
produces it at O(n·k) output memory by fusing a running top-k fold into
the same metric-dispatched distance tiles as ``pairwise_dist``:

  * grid (n/BM, n/BN); program (i, j) computes the (BM, BN) dissimilarity
    tile with the shared ``_tile_dissim`` formula, then folds it into the
    running per-row top-k held in the OUTPUT refs.  The output BlockSpec
    index map is (i, 0) — constant along j — so the same (BM, k) slab
    stays resident across the whole column sweep (TPU grids iterate the
    last axis innermost), and ``@pl.when(j == 0)`` re-initializes it to
    (+inf, -1) when a new row block begins.
  * the fold is k statically-unrolled selection steps over the
    concatenated (BM, k + BN) candidates: vectorized min, then index-min
    over ``where(val == min, position, width)`` (the jnp.argmin
    replacement trick from prim_persist), then the winner's distance is
    masked to +inf.  Selection order is lexicographic (value, position):
    ties keep the earliest candidate — the running best sits in positions
    [0, k), so earlier-seen neighbours win, exactly XLA top_k's
    lower-index tie rule.  That makes the Pallas fold, the blocked XLA
    driver below, and ``ref.knn_graph_ref`` agree on one tie contract.
  * self-pairs (col == row) and padded columns (col >= n) are masked to
    +inf before the fold; padded rows are computed and sliced off, per
    the padding discipline of ``pairwise_dist`` (the fold reduces along
    the row, never across the tile's row axis, so live padded rows stay
    harmless).

VMEM at BM=BN=256, d<=512, k<=128: two (256, 512) point tiles + two
(256, 128) best slabs + the transient (256, 384) concat pair
~= 1.3 MiB + 0.25 MiB + 0.75 MiB << 16 MiB.  The unroll cost grows
linearly in k, so the Pallas path is capped at ``MAX_PALLAS_K``;
``ops.knn_graph`` silently falls back to the XLA driver past it (the
``MAX_FUSED_N`` precedent from the iVAT kernel).

``knn_graph_blocked`` is the production XLA path: an O(n/B)^2 fori_loop
over (B, B) tiles with a ``lax.top_k`` merge per tile — no Pallas, no
(n, n) or even (B, n) intermediate, and the same tie contract.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref
from repro.kernels.pairwise_dist import (DEFAULT_BLOCK, _clamp_block,
                                         _LANE, _pad_to, _tile_dissim)

#: Pallas fold unroll cap — past this, ops.knn_graph takes the XLA driver.
MAX_PALLAS_K = 128
#: Default tile edge of the XLA blocked driver (bigger than the Pallas
#: tile: XLA pays per-iteration dispatch, not VMEM, for tile size).
XLA_BLOCK = 2048


def _check_k(k: int, n: int):
    if not 1 <= k <= n - 1:
        raise ValueError(f"k must satisfy 1 <= k <= n-1 = {n - 1}, got {k}")


def _fold_topk(best_d, best_i, tile_d, tile_i, k: int):
    """Merge a (BM, BN) candidate tile into the (BM, k) running top-k.

    k statically-unrolled steps of: min value per row, first position
    holding it, gather-free winner extraction (sum over the one-hot
    position mask), winner masked to +inf.  Ties select the earliest
    concat position — the running best occupies positions [0, k), so
    earlier-seen candidates win, matching lax.top_k's lower-index rule.
    """
    cat_d = jnp.concatenate([best_d, tile_d], axis=1)
    cat_i = jnp.concatenate([best_i, tile_i], axis=1)
    width = cat_d.shape[1]
    pos = jax.lax.broadcasted_iota(jnp.int32, cat_d.shape, 1)
    out_d, out_i = [], []
    for _ in range(k):
        v = jnp.min(cat_d, axis=1)
        p = jnp.min(jnp.where(cat_d == v[:, None], pos, width), axis=1)
        hit = pos == p[:, None]
        out_d.append(v)
        out_i.append(jnp.sum(jnp.where(hit, cat_i, 0), axis=1))
        cat_d = jnp.where(hit, jnp.inf, cat_d)
    return (jnp.stack(out_d, axis=1),
            jnp.stack(out_i, axis=1).astype(jnp.int32))


def _masked_tile(x, y, i, j, bm, bn, n, metric):
    """Distance tile with self-pairs and padded columns masked to +inf."""
    # gram form always: the approx rung runs on data the numerics
    # pre-pass has already conditioned when needed (post-transform
    # kappa is tiny), so the cancellation-free direct tile buys nothing
    d = _tile_dissim(x, y, metric, "gram")
    rows = i * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0)
    cols = j * bn + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1)
    return jnp.where((cols == rows) | (cols >= n), jnp.inf, d), cols


def _knn_kernel(x_ref, y_ref, od_ref, oi_ref, *, metric, k, n, bm, bn):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        od_ref[...] = jnp.full(od_ref.shape, jnp.inf, od_ref.dtype)
        oi_ref[...] = jnp.full(oi_ref.shape, -1, oi_ref.dtype)

    x = x_ref[...].astype(jnp.float32)
    y = y_ref[...].astype(jnp.float32)
    d, cols = _masked_tile(x, y, i, j, bm, bn, n, metric)
    nd, ni = _fold_topk(od_ref[...], oi_ref[...], d, cols, k)
    od_ref[...] = nd
    oi_ref[...] = ni


def _knn_kernel_batch(x_ref, y_ref, od_ref, oi_ref, *, metric, k, n, bm, bn):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        od_ref[...] = jnp.full(od_ref.shape, jnp.inf, od_ref.dtype)
        oi_ref[...] = jnp.full(oi_ref.shape, -1, oi_ref.dtype)

    x = x_ref[0].astype(jnp.float32)            # (1, BM, d) slab -> (BM, d)
    y = y_ref[0].astype(jnp.float32)
    d, cols = _masked_tile(x, y, i, j, bm, bn, n, metric)
    nd, ni = _fold_topk(od_ref[0], oi_ref[0], d, cols, k)
    od_ref[0] = nd
    oi_ref[0] = ni


@functools.partial(jax.jit,
                   static_argnames=("k", "metric", "block", "interpret"))
def knn_graph_pallas(X: jax.Array, *, k: int, metric: str = "euclidean",
                     block: int = DEFAULT_BLOCK, interpret: bool = False):
    """k nearest neighbours per point via the fused Pallas top-k fold.

    Args:
      X: (n, d) float — data points.
      k: neighbours per point (static; 1 <= k <= n-1, k <= MAX_PALLAS_K).
      metric: one of ``kernels.ref.METRICS`` (static).
      block: distance tile edge BM = BN (static; clamped like
        ``pairwise_dist_pallas``).
      interpret: Pallas interpret mode (CPU correctness path).

    Returns:
      (dist (n, k) f32 ascending per row, idx (n, k) i32); a point is
      never its own neighbour.
    """
    ref.check_metric(metric)
    n, d = X.shape
    _check_k(k, n)
    if k > MAX_PALLAS_K:
        raise ValueError(f"Pallas kNN fold capped at k={MAX_PALLAS_K}; "
                         f"use knn_graph_blocked for k={k}")
    bm = _clamp_block(block, n, metric)
    n_pad = -(-n // bm) * bm
    d_pad = -(-d // _LANE) * _LANE
    Xp = _pad_to(_pad_to(X, n_pad, 0), d_pad, 1)

    dist, idx = pl.pallas_call(
        functools.partial(_knn_kernel, metric=metric, k=k, n=n,
                          bm=bm, bn=bm),
        grid=(n_pad // bm, n_pad // bm),
        in_specs=[
            pl.BlockSpec((bm, d_pad), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, d_pad), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad, k), jnp.float32),
            jax.ShapeDtypeStruct((n_pad, k), jnp.int32),
        ],
        interpret=interpret,
    )(Xp, Xp)
    return dist[:n], idx[:n]


@functools.partial(jax.jit,
                   static_argnames=("k", "metric", "block", "interpret"))
def knn_graph_pallas_batch(X: jax.Array, *, k: int,
                           metric: str = "euclidean",
                           block: int = DEFAULT_BLOCK,
                           interpret: bool = False):
    """Batched kNN graphs for a (b, n, d) stack — slab-of-1 grid.

    Same per-tile compute as the solo kernel; the grid grows a leading
    batch axis and every BlockSpec a size-1 slab dim, so VMEM per program
    stays at the solo budget regardless of b.

    Returns:
      (dist (b, n, k) f32, idx (b, n, k) i32).
    """
    ref.check_metric(metric)
    b, n, d = X.shape
    _check_k(k, n)
    if k > MAX_PALLAS_K:
        raise ValueError(f"Pallas kNN fold capped at k={MAX_PALLAS_K}; "
                         f"use knn_graph_blocked for k={k}")
    bm = _clamp_block(block, n, metric)
    n_pad = -(-n // bm) * bm
    d_pad = -(-d // _LANE) * _LANE
    Xp = _pad_to(_pad_to(X, n_pad, 1), d_pad, 2)

    dist, idx = pl.pallas_call(
        functools.partial(_knn_kernel_batch, metric=metric, k=k, n=n,
                          bm=bm, bn=bm),
        grid=(b, n_pad // bm, n_pad // bm),
        in_specs=[
            pl.BlockSpec((1, bm, d_pad), lambda bi, i, j: (bi, i, 0)),
            pl.BlockSpec((1, bm, d_pad), lambda bi, i, j: (bi, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bm, k), lambda bi, i, j: (bi, i, 0)),
            pl.BlockSpec((1, bm, k), lambda bi, i, j: (bi, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, n_pad, k), jnp.float32),
            jax.ShapeDtypeStruct((b, n_pad, k), jnp.int32),
        ],
        interpret=interpret,
    )(Xp, Xp)
    return dist[:, :n], idx[:, :n]


@functools.partial(jax.jit, static_argnames=("k", "metric", "block"))
def knn_graph_blocked(X: jax.Array, *, k: int, metric: str = "euclidean",
                      block: int = XLA_BLOCK):
    """Blocked-both-ways XLA kNN driver — the production CPU path.

    fori_loop over (B, B) tiles of ``ref.pairwise_dissim_ref`` with a
    ``lax.top_k`` merge of (running best ++ tile) per step.  Peak
    temporaries are O(B² + B·k + n·k); nothing (n, n) or (B, n) ever
    exists.  Tie contract identical to the Pallas fold (lower concat
    position wins, running best sits first).

    Args:
      X: (n, d) float — data points.
      k: neighbours per point (static; 1 <= k <= n-1, any size).
      metric: one of ``kernels.ref.METRICS`` (static).
      block: tile edge B (static; clamped to n).

    Returns:
      (dist (n, k) f32 ascending per row, idx (n, k) i32).
    """
    ref.check_metric(metric)
    n, d = X.shape
    _check_k(k, n)
    bs = min(block, max(8, n))
    n_pad = -(-n // bs) * bs
    Xp = _pad_to(X.astype(jnp.float32), n_pad, 0)
    nblk = n_pad // bs
    iota = jnp.arange(bs, dtype=jnp.int32)

    def col_body(j, best, xb, rows):
        bd, bi = best
        yb = jax.lax.dynamic_slice_in_dim(Xp, j * bs, bs, 0)
        tile = ref.pairwise_dissim_ref(xb, yb, metric=metric)
        cols = j * bs + iota
        bad = (cols[None, :] == rows[:, None]) | (cols[None, :] >= n)
        tile = jnp.where(bad, jnp.inf, tile)
        cat_d = jnp.concatenate([bd, tile], axis=1)
        cat_i = jnp.concatenate(
            [bi, jnp.broadcast_to(cols[None, :], tile.shape)], axis=1)
        neg, p = jax.lax.top_k(-cat_d, k)
        return -neg, jnp.take_along_axis(cat_i, p, axis=1)

    def row_body(i, out):
        od, oi = out
        xb = jax.lax.dynamic_slice_in_dim(Xp, i * bs, bs, 0)
        rows = i * bs + iota
        bd, bi = jax.lax.fori_loop(
            0, nblk, lambda j, best: col_body(j, best, xb, rows),
            (jnp.full((bs, k), jnp.inf, jnp.float32),
             jnp.full((bs, k), -1, jnp.int32)))
        od = jax.lax.dynamic_update_slice_in_dim(od, bd, i * bs, 0)
        oi = jax.lax.dynamic_update_slice_in_dim(oi, bi, i * bs, 0)
        return od, oi

    od, oi = jax.lax.fori_loop(
        0, nblk, row_body,
        (jnp.full((n_pad, k), jnp.inf, jnp.float32),
         jnp.full((n_pad, k), -1, jnp.int32)))
    return od[:n], oi[:n]
