"""Pure-Python VAT — the paper's baseline implementation.

This is a faithful transcription of the "standard Python VAT" the paper
benchmarks against (Table 1): nested-loop pairwise distances and a
list-based Prim reordering.  Deliberately unvectorized — it is both the
correctness oracle for the accelerated paths and the denominator of every
speedup number in ``benchmarks/vat_tables.py::table1``.
"""
from __future__ import annotations

import math
from typing import List, Sequence, Tuple


def pairwise_distances_naive(X: Sequence[Sequence[float]]) -> List[List[float]]:
    """O(n^2 d) nested-loop Euclidean distance matrix (pure Python)."""
    n = len(X)
    d = len(X[0])
    R = [[0.0] * n for _ in range(n)]
    for i in range(n):
        xi = X[i]
        for j in range(i + 1, n):
            xj = X[j]
            s = 0.0
            for k in range(d):
                diff = xi[k] - xj[k]
                s += diff * diff
            dist = math.sqrt(s)
            R[i][j] = dist
            R[j][i] = dist
    return R


def vat_order_naive(R: Sequence[Sequence[float]]) -> List[int]:
    """Prim-based VAT reordering (Bezdek & Hathaway 2002), pure Python.

    Step 1: the first vertex is the row containing the global maximum of R.
    Step t: append the unselected vertex with minimum distance to the
    selected set (greedy MST growth).
    """
    n = len(R)
    # row of the global maximum
    best_i, best_val = 0, -1.0
    for i in range(n):
        for j in range(n):
            if R[i][j] > best_val:
                best_val = R[i][j]
                best_i = i
    order = [best_i]
    selected = [False] * n
    selected[best_i] = True
    # min distance from each vertex to the selected set
    mind = list(R[best_i])
    for _ in range(1, n):
        q, qval = -1, float("inf")
        for j in range(n):
            if not selected[j] and mind[j] < qval:
                qval = mind[j]
                q = j
        order.append(q)
        selected[q] = True
        rq = R[q]
        for j in range(n):
            if rq[j] < mind[j]:
                mind[j] = rq[j]
    return order


def vat_naive(X: Sequence[Sequence[float]]) -> Tuple[List[List[float]], List[int]]:
    """Full naive VAT: returns (reordered matrix R*, order)."""
    R = pairwise_distances_naive(X)
    order = vat_order_naive(R)
    n = len(R)
    Rstar = [[R[order[i]][order[j]] for j in range(n)] for i in range(n)]
    return Rstar, order


def ivat_naive(Rstar: Sequence[Sequence[float]]) -> List[List[float]]:
    """iVAT transform (Havens & Bezdek 2012 recurrence), pure Python.

    Operates on a VAT-ordered dissimilarity matrix; produces the
    graph-geodesic (max-min path) distance matrix with sharper blocks.
    """
    n = len(Rstar)
    Dp = [[0.0] * n for _ in range(n)]
    for r in range(1, n):
        # nearest previously-ordered vertex
        j, jval = 0, float("inf")
        for k in range(r):
            if Rstar[r][k] < jval:
                jval = Rstar[r][k]
                j = k
        for k in range(r):
            v = Rstar[r][j] if k == j else max(Rstar[r][j], Dp[j][k])
            Dp[r][k] = v
            Dp[k][r] = v
    return Dp
