"""Distributed VAT — lifting the paper's O(n^2) memory wall with shard_map.

The paper's Limitations section: "VAT requires storage of the full pairwise
dissimilarity matrix ... a bottleneck for n > 10^4".  Two remedies here:

* ``pairwise_dist_sharded``: the n x n matrix is computed and kept sharded
  over the mesh `data` axis (row blocks) — aggregate pod HBM instead of
  one host's RAM (x256 on a 16x16 pod).

* ``dvat``: **matrix-free** distributed VAT.  Points are sharded; the Prim
  loop keeps only the O(n) min-distance frontier (itself sharded) and
  recomputes the needed distance row on the fly each step.  Per-step cost:
  one all_gather of P (value, index) pairs + one psum broadcast of the
  selected point.  Memory is O(n d / P + n / P) per device — no n x n
  object ever exists, so n ~ 10^6+ fits a pod.

Both run under jit+shard_map on any mesh axis name (default "data").

This module is optional: repro.core imports it behind a try/except and
publishes ``repro.core.HAS_DISTRIBUTED`` (docs/scaling.md has the full
vat -> svat -> bigvat -> dvat -> streaming ladder).
"""
from __future__ import annotations

import functools
import inspect
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax >= 0.6: top-level export
    from jax import shard_map as _shard_map_impl
except ImportError:  # jax 0.4.x / 0.5.x: experimental home
    from jax.experimental.shard_map import shard_map as _shard_map_impl

from repro.kernels import ops as kops
from repro.kernels.ref import row_dissim_ref


def _shard_map(f, *, mesh, in_specs, out_specs, check: bool | None = None):
    """Version-tolerant shard_map: the replication-check kwarg was renamed
    from ``check_rep`` (<= 0.5) to ``check_vma`` (>= 0.6)."""
    kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    if check is not None:
        params = inspect.signature(_shard_map_impl).parameters
        for name in ("check_vma", "check_rep"):
            if name in params:
                kwargs[name] = check
                break
    return _shard_map_impl(f, **kwargs)


class DVATResult(NamedTuple):
    order: jax.Array  # (n,) int32 VAT permutation (replicated)


def pairwise_dist_sharded(X: jax.Array, mesh: Mesh, axis: str = "data"):
    """Distance matrix with rows sharded over `axis`; X gathered per shard."""

    def shard_fn(Xl, Xfull):
        return kops.pairwise_dist(Xl, Xfull)

    fn = _shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(axis, None), P(None, None)),
        out_specs=P(axis, None))
    return fn(X, X)


def _dvat_shard(Xl: jax.Array, axis: str, exact_start: bool, metric: str):
    """Runs on each shard: Xl is the local (n/P, d) slice of the points."""
    p = lax.axis_index(axis)
    Pn = lax.psum(1, axis)
    nl, d = Xl.shape
    n = nl * Pn
    offset = (p * nl).astype(jnp.int32)
    local_ids = jnp.arange(nl, dtype=jnp.int32) + offset

    def bcast_point(q):
        """Fetch row q of X from whichever shard owns it (one psum)."""
        owner = q // nl
        lq = q - owner * nl
        mine = jnp.where(p == owner, Xl[lq], jnp.zeros((d,), Xl.dtype))
        return lax.psum(mine, axis)

    def dist_to_local(xq):
        return row_dissim_ref(Xl, xq, metric=metric)

    if exact_start:
        # exact VAT start: row of the global max of R (O(n^2 d / P) pass,
        # done in n/P-row chunks against a gathered X)
        Xfull = lax.all_gather(Xl, axis, tiled=True)          # (n, d)
        Rl = kops.pairwise_dist(Xl, Xfull, metric=metric)      # (nl, n)
        local_max = jnp.max(Rl, axis=1)                        # per local row
        li = jnp.argmax(local_max).astype(jnp.int32)
        vals = lax.all_gather(local_max[li], axis)             # (P,)
        idxs = lax.all_gather(li + offset, axis)
        i0 = idxs[jnp.argmax(vals)].astype(jnp.int32)
    else:
        # matrix-free start: farthest point from the global mean
        mean = lax.pmean(jnp.mean(Xl, axis=0), axis)
        dm = row_dissim_ref(Xl, mean, metric=metric)
        li = jnp.argmax(dm).astype(jnp.int32)
        vals = lax.all_gather(dm[li], axis)
        idxs = lax.all_gather(li + offset, axis)
        i0 = idxs[jnp.argmax(vals)].astype(jnp.int32)

    x0 = bcast_point(i0)
    mind0 = dist_to_local(x0)
    sel0 = local_ids == i0
    order0 = jnp.zeros((n,), jnp.int32).at[0].set(i0)

    def body(t, carry):
        mind, selected, order = carry
        masked = jnp.where(selected, jnp.inf, mind)
        li = jnp.argmin(masked).astype(jnp.int32)
        vals = lax.all_gather(masked[li], axis)                # (P,)
        idxs = lax.all_gather(li + offset, axis)
        w = jnp.argmin(vals)                                    # first-index ties
        q = idxs[w].astype(jnp.int32)
        order = order.at[t].set(q)
        xq = bcast_point(q)
        mind = jnp.minimum(mind, dist_to_local(xq))
        selected = selected | (local_ids == q)
        return mind, selected, order

    _, _, order = lax.fori_loop(1, n, body, (mind0, sel0, order0))
    return order


def dvat(X: jax.Array, mesh: Mesh, axis: str = "data", *,
         exact_start: bool = True,
         metric: str = "euclidean") -> DVATResult:
    """Matrix-free distributed VAT ordering of X (n, d).

    n must be divisible by the mesh axis size (pad upstream otherwise).
    exact_start=False skips the O(n^2 d / P) max-pair pass and starts from
    the point farthest from the mean (block structure is unaffected; the
    ordering may start in a different cluster).  ``metric`` picks the
    dissimilarity (one of ``kernels.ref.METRICS``) — every distance row
    is recomputed from points, so any rowwise-computable metric works.
    """
    fn = _shard_map(
        functools.partial(_dvat_shard, axis=axis, exact_start=exact_start,
                          metric=metric),
        mesh=mesh,
        in_specs=(P(axis, None),),
        out_specs=P(),  # order replicated (built from all_gathered data)
        check=False)
    return DVATResult(order=jax.jit(fn)(X))
