"""Distributed VAT — lifting the paper's O(n^2) memory wall with shard_map.

The paper's Limitations section: "VAT requires storage of the full pairwise
dissimilarity matrix ... a bottleneck for n > 10^4".  Two remedies here:

* ``pairwise_dist_sharded``: the n x n matrix is computed and kept sharded
  over the mesh `data` axis (row blocks) — aggregate pod HBM instead of
  one host's RAM (x256 on a 16x16 pod).

* ``dvat``: **matrix-free** distributed VAT.  Points are sharded; the Prim
  loop keeps only the O(n) min-distance frontier (itself sharded) and
  recomputes the needed distance row on the fly each step.  Per-step cost:
  one all_gather of P (value, index) pairs + one psum broadcast of the
  selected point.  Memory is O(n d / P + n / P) per device — no n x n
  object ever exists, so n ~ 10^6+ fits a pod.

* ``vat_matrix_free_sharded``: the Turbo Flash-VAT engine over a mesh —
  dvat's communication pattern married to the solo engine's *bitwise*
  ordering contract: Gram-trick rows (not dvat's direct differences), the
  exact streamed row-max seed, the in-band (+inf) frontier, and the fused
  local step dispatched through ``kernels.ops.prim_frontier_step`` (XLA
  ref or Pallas tile).  Orderings match ``core.vat.vat_matrix_free`` bit
  for bit on any shard count, so the flashvat rung auto-shards when more
  than one device is visible without changing a single answer.

Both run under jit+shard_map on any mesh axis name (default "data").

This module is optional: repro.core imports it behind a try/except and
publishes ``repro.core.HAS_DISTRIBUTED`` (docs/scaling.md has the full
vat -> svat -> bigvat -> dvat -> streaming ladder).
"""
from __future__ import annotations

import functools
import inspect
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax >= 0.6: top-level export
    from jax import shard_map as _shard_map_impl
except ImportError:  # jax 0.4.x / 0.5.x: experimental home
    from jax.experimental.shard_map import shard_map as _shard_map_impl

from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels.ref import row_dissim_ref


def _shard_map(f, *, mesh, in_specs, out_specs, check: bool | None = None):
    """Version-tolerant shard_map: the replication-check kwarg was renamed
    from ``check_rep`` (<= 0.5) to ``check_vma`` (>= 0.6)."""
    kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    if check is not None:
        params = inspect.signature(_shard_map_impl).parameters
        for name in ("check_vma", "check_rep"):
            if name in params:
                kwargs[name] = check
                break
    return _shard_map_impl(f, **kwargs)


class DVATResult(NamedTuple):
    order: jax.Array  # (n,) int32 VAT permutation (replicated)


def pairwise_dist_sharded(X: jax.Array, mesh: Mesh, axis: str = "data"):
    """Distance matrix with rows sharded over `axis`; X gathered per shard."""

    def shard_fn(Xl, Xfull):
        return kops.pairwise_dist(Xl, Xfull)

    fn = _shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(axis, None), P(None, None)),
        out_specs=P(axis, None))
    return fn(X, X)


def _dvat_shard(Xl: jax.Array, axis: str, exact_start: bool, metric: str):
    """Runs on each shard: Xl is the local (n/P, d) slice of the points."""
    p = lax.axis_index(axis)
    Pn = lax.psum(1, axis)
    nl, d = Xl.shape
    n = nl * Pn
    offset = (p * nl).astype(jnp.int32)
    local_ids = jnp.arange(nl, dtype=jnp.int32) + offset

    def bcast_point(q):
        """Fetch row q of X from whichever shard owns it (one psum)."""
        owner = q // nl
        lq = q - owner * nl
        mine = jnp.where(p == owner, Xl[lq], jnp.zeros((d,), Xl.dtype))
        return lax.psum(mine, axis)

    def dist_to_local(xq):
        return row_dissim_ref(Xl, xq, metric=metric)

    if exact_start:
        # exact VAT start: row of the global max of R (O(n^2 d / P) pass,
        # done in n/P-row chunks against a gathered X)
        Xfull = lax.all_gather(Xl, axis, tiled=True)          # (n, d)
        Rl = kops.pairwise_dist(Xl, Xfull, metric=metric)      # (nl, n)
        local_max = jnp.max(Rl, axis=1)                        # per local row
        li = jnp.argmax(local_max).astype(jnp.int32)
        vals = lax.all_gather(local_max[li], axis)             # (P,)
        idxs = lax.all_gather(li + offset, axis)
        i0 = idxs[jnp.argmax(vals)].astype(jnp.int32)
    else:
        # matrix-free start: farthest point from the global mean
        mean = lax.pmean(jnp.mean(Xl, axis=0), axis)
        dm = row_dissim_ref(Xl, mean, metric=metric)
        li = jnp.argmax(dm).astype(jnp.int32)
        vals = lax.all_gather(dm[li], axis)
        idxs = lax.all_gather(li + offset, axis)
        i0 = idxs[jnp.argmax(vals)].astype(jnp.int32)

    x0 = bcast_point(i0)
    mind0 = dist_to_local(x0)
    sel0 = local_ids == i0
    order0 = jnp.zeros((n,), jnp.int32).at[0].set(i0)

    def body(t, carry):
        mind, selected, order = carry
        masked = jnp.where(selected, jnp.inf, mind)
        li = jnp.argmin(masked).astype(jnp.int32)
        vals = lax.all_gather(masked[li], axis)                # (P,)
        idxs = lax.all_gather(li + offset, axis)
        w = jnp.argmin(vals)                                    # first-index ties
        q = idxs[w].astype(jnp.int32)
        order = order.at[t].set(q)
        xq = bcast_point(q)
        mind = jnp.minimum(mind, dist_to_local(xq))
        selected = selected | (local_ids == q)
        return mind, selected, order

    _, _, order = lax.fori_loop(1, n, body, (mind0, sel0, order0))
    return order


def _flash_shard(Xl: jax.Array, axis: str, n: int, metric: str,
                 use_pallas: bool, block: int):
    """Per-device body of ``vat_matrix_free_sharded``.

    Xl is the local contiguous row block of the padded points.  The
    frontier is in-band (+inf = selected/padding, ``kref.UNSEEN`` = not
    yet folded) and every formula is the solo Turbo engine's, restricted
    to the shard — elementwise and row-local, so the shard's slice of
    each quantity is bitwise-equal to the solo path's.
    """
    p = lax.axis_index(axis)
    nl, d = Xl.shape
    offset = (p * nl).astype(jnp.int32)
    aux_l = kref.metric_aux_ref(Xl, metric=metric)

    def bcast_point(q):
        """Row q of X (+ its aux entry) from whichever shard owns it."""
        owner = q // nl
        lq = q - owner * nl
        mine = jnp.where(p == owner, lax.dynamic_slice_in_dim(Xl, lq, 1, 0),
                         jnp.zeros((1, d), Xl.dtype))
        amine = jnp.where(p == owner,
                          lax.dynamic_slice_in_dim(aux_l, lq, 1, 0),
                          jnp.zeros((1,), aux_l.dtype))
        return lax.psum(mine, axis)[0], lax.psum(amine, axis)[0]

    # ---- seed: the solo streamed row-max scan, rows restricted to the
    # shard, columns over a gathered X copy in (bs, bs) blocks — one
    # O(n·d) gather lives through the seed (freed after), but never an
    # (n/P, n) matrix.  Entries come from the same pairwise front door,
    # the diag is forced exactly zero at GLOBAL coordinates, padded
    # rows/columns are masked out; f32 max is exact, so this blocking
    # reproduces the solo row maxima bit for bit.
    Xfull = lax.all_gather(Xl, axis, tiled=True)            # (n_padP, d)
    nfull = Xfull.shape[0]
    per_entry = 4 * (d if metric == "manhattan" else 1)
    bs = max(8, min(1024, int(((4 << 20) // per_entry) ** 0.5), nl, nfull))
    nl_pad = -(-nl // bs) * bs
    nf_pad = -(-nfull // bs) * bs
    Xlp = jnp.pad(Xl, ((0, nl_pad - nl), (0, 0)))
    Xfp = jnp.pad(Xfull, ((0, nf_pad - nfull), (0, 0)))
    lane = jnp.arange(bs)

    def row_block(i, acc):
        xb = lax.dynamic_slice_in_dim(Xlp, i * bs, bs, 0)
        rids = offset + i * bs + lane                       # global row ids

        def col_block(j, rm):
            yb = lax.dynamic_slice_in_dim(Xfp, j * bs, bs, 0)
            T = kops.pairwise_dist(xb, yb, metric=metric,
                                   use_pallas=use_pallas)
            cids = j * bs + lane
            T = jnp.where(cids[None, :] == rids[:, None], 0.0, T)  # diag
            T = jnp.where(cids[None, :] < n, T, -jnp.inf)          # padding
            return jnp.maximum(rm, jnp.max(T, axis=1))

        rm = lax.fori_loop(0, nf_pad // bs, col_block,
                           jnp.full((bs,), -jnp.inf))
        return lax.dynamic_update_slice_in_dim(acc, rm, i * bs, 0)

    rowmax = lax.fori_loop(0, nl_pad // bs, row_block,
                           jnp.zeros((nl_pad,), jnp.float32))
    lrow = jnp.arange(nl_pad)
    rowmax = jnp.where((lrow < nl) & (lrow + offset < n), rowmax, -jnp.inf)
    li = jnp.argmax(rowmax).astype(jnp.int32)               # local, < nl
    vals = lax.all_gather(rowmax[li], axis)                 # (P,)
    idxs = lax.all_gather(li + offset, axis)
    i0 = idxs[jnp.argmax(vals)].astype(jnp.int32)           # first-index ties

    # ---- Prim loop: local fused frontier step + (min, argmin) reduce.
    # The Pallas step kernel needs its block to divide the lane count,
    # so the state arrays are padded ONCE via pad_points (rows to the
    # clamped block, d to the 128-lane width); padded lanes ride in-band
    # as +inf and can never win, exactly like the solo engine's padding.
    if use_pallas:
        from repro.kernels.prim_stream import pad_points
        Xs, auxs, _, bn = pad_points(Xl, aux_l, block=block)
        d_pad = Xs.shape[1]
    else:
        Xs, auxs, bn, d_pad = Xl, aux_l, block, d
    m = Xs.shape[0]
    lidx_all = jnp.arange(m, dtype=jnp.int32)
    state_ids = lidx_all + offset       # fake ids on pad lanes stay inert:
    mind0 = jnp.where(                  # their mind is +inf forever
        (lidx_all >= nl) | (state_ids >= n) | (state_ids == i0),
        jnp.inf, jnp.float32(kref.UNSEEN))
    order0 = jnp.zeros((n,), jnp.int32).at[0].set(i0)
    edges0 = jnp.zeros((n,), jnp.float32)

    def body(t, carry):
        mind, order, edges, q = carry
        xq, auxq = bcast_point(q)
        if use_pallas:
            xq = jnp.pad(xq, (0, d_pad - d))
        mind, lv, lidx = kops.prim_frontier_step(
            Xs, auxs, xq, auxq, mind, metric=metric, use_pallas=use_pallas,
            block=bn)
        vals = lax.all_gather(lv, axis)                     # (P,)
        idxs = lax.all_gather(lidx + offset, axis)
        w = jnp.argmin(vals)          # first-device ties = first-index ties
        nq = idxs[w].astype(jnp.int32)
        mind = jnp.where(state_ids == nq, jnp.inf, mind)
        return (mind, order.at[t].set(nq), edges.at[t].set(vals[w]), nq)

    _, order, edges, _ = lax.fori_loop(1, n, body,
                                       (mind0, order0, edges0, i0))
    return order, edges


@functools.lru_cache(maxsize=32)
def _flash_sharded_program(mesh: Mesh, axis: str, n: int, metric: str,
                           use_pallas: bool, block: int):
    """Build-and-jit the sharded traversal ONCE per (mesh, config).

    ``shard_map`` closures are fresh objects per call, so wrapping one in
    ``jax.jit`` inline would defeat the jit cache and re-trace the whole
    n-step program on every invocation (review finding: the warmup fit
    paid for nothing).  Caching the jitted callable restores the
    compile-once-run-many behavior the solo engines get from their
    module-level ``@jax.jit``.
    """
    fn = _shard_map(
        functools.partial(_flash_shard, axis=axis, n=n, metric=metric,
                          use_pallas=use_pallas, block=block),
        mesh=mesh,
        in_specs=(P(axis, None),),
        out_specs=(P(), P()),             # order/edges replicated
        check=False)
    return jax.jit(fn)


def vat_matrix_free_sharded(X: jax.Array, mesh: Mesh, axis: str = "data", *,
                            metric: str = "euclidean",
                            use_pallas: bool = False, block: int = 1024):
    """Sharded Turbo Flash-VAT: exact matrix-free VAT over a device mesh.

    X is row-sharded over the ``axis`` mesh axis (rows padded to the axis
    size first; padded lanes ride in-band as +inf and can never win).
    Each device runs the fused local frontier step
    (``kernels.ops.prim_frontier_step`` — the XLA reference or the Pallas
    tile kernel, its state padded once to the kernel's block) against
    its O(n/P · d) shard, then one ``(min, argmin)`` all-reduce picks
    the global next vertex and one psum broadcasts its row.  Steady-state
    memory per device is O(n·d/P + n/P); the seed scan additionally
    holds one gathered O(n·d) X copy per device while it runs (streamed
    through (bs, bs) blocks — never an (n/P, n) matrix), freed before
    the traversal.

    The ordering (and edge trace) is bitwise-identical to the solo
    ``vat_matrix_free`` for every metric: shards are contiguous row
    blocks, every per-lane formula is the solo engine's restricted to
    the shard, f32 min folds are exact, and first-device tie-breaking
    over contiguous blocks equals global first-index tie-breaking —
    pinned (1-device and 8-device) in tests/test_turbo.py.

    Args:
      X: (n, d) float — data points; n need NOT divide the axis size
        (rows are padded internally).
      mesh: the device mesh; ``axis`` names the sharding axis.
      axis: mesh axis name (default "data").
      metric: one of ``kernels.ref.METRICS``.
      use_pallas: route the per-device fused step and the seed scan's
        pairwise tiles through the Pallas kernels.
      block: Pallas step-kernel tile length (clamped to the shard size).

    Returns:
      ``core.vat.FlashVATResult`` — order (n,) i32 and edges (n,) f32,
      replicated on every device.
    """
    from repro.core.vat import FlashVATResult
    n, _ = X.shape
    nshards = mesh.shape[axis]
    n_pad = -(-n // nshards) * nshards
    Xf = jnp.pad(X.astype(jnp.float32), ((0, n_pad - n), (0, 0)))
    program = _flash_sharded_program(mesh, axis, n, metric, use_pallas,
                                     block)
    order, edges = program(Xf)
    return FlashVATResult(order=order, edges=edges)


def dvat(X: jax.Array, mesh: Mesh, axis: str = "data", *,
         exact_start: bool = True,
         metric: str = "euclidean") -> DVATResult:
    """Matrix-free distributed VAT ordering of X (n, d).

    n must be divisible by the mesh axis size (pad upstream otherwise).
    exact_start=False skips the O(n^2 d / P) max-pair pass and starts from
    the point farthest from the mean (block structure is unaffected; the
    ordering may start in a different cluster).  ``metric`` picks the
    dissimilarity (one of ``kernels.ref.METRICS``) — every distance row
    is recomputed from points, so any rowwise-computable metric works.
    """
    fn = _shard_map(
        functools.partial(_dvat_shard, axis=axis, exact_start=exact_start,
                          metric=metric),
        mesh=mesh,
        in_specs=(P(axis, None),),
        out_specs=P(),  # order replicated (built from all_gathered data)
        check=False)
    return DVATResult(order=jax.jit(fn)(X))
