"""Big-VAT — clusiVAT-style out-of-core cluster tendency for n >= 1e5.

The paper's Limitations section concedes that VAT "requires storage of the
full pairwise dissimilarity matrix", capping practical use near n ~ 1e4.
Big-VAT breaks that wall with the sVAT/clusiVAT recipe (Rathore et al.):

  1. **sample**  — s maximin "distinguished" prototypes (O(n s) time,
     O(n) memory),
  2. **assess**  — exact VAT + iVAT on the (s, s) sample matrix
     (steps 1+2 together are exactly ``core.svat.svat``, reused here),
  3. **extend**  — a *tiled nearest-prototype pass* that streams X through
     ``kernels/pairwise_dist`` in row blocks (Pallas on TPU, XLA tiling on
     CPU): each block yields a (block, s) tile, reduced immediately to the
     per-point nearest prototype and its distance.  Peak intermediate is
     O(block * s); **no (n, n) — or even (n, s) device — array is ever
     materialized**, so memory scales with n*d instead of n^2.

The full-data ordering groups points by their prototype's position in the
sample VAT order (nearest-prototype extension), and ``smoothed_image``
renders the aggregated VAT image where each prototype's row/column band is
as wide as its group — the clusiVAT "smoothed" picture of all n points.

X may be a numpy array or ``np.memmap``: the extension pass iterates host
row blocks, so it touches only O(block * d) of X per step.  The maximin
sampling pass currently loads X once as a device array — total footprint
is O(n d) + O(block * s), never O(n^2); a block-streamed maximin frontier
is the remaining step to a fully disk-bound pipeline.

See ``docs/scaling.md`` for where Big-VAT sits on the vat -> svat ->
bigvat -> dvat -> streaming ladder, and ``repro.api.FastVAT`` for the
facade that auto-selects it by n.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.ivat import ivat_from_vat
from repro.core.svat import SVATResult, svat
from repro.kernels import ops as kops

DEFAULT_SAMPLE = 256
DEFAULT_BLOCK = 4096


class BigVATResult(NamedTuple):
    sample: SVATResult      # exact VAT on the s maximin prototypes
    ivat: jax.Array | None  # (s, s) iVAT image, or None if compute_ivat=False
    labels: jax.Array       # (n,) int32 nearest-prototype id (raw sample pos)
    proto_dist: jax.Array   # (n,) float32 distance to the nearest prototype
    order: jax.Array        # (n,) int32 full-data ordering (see bigvat())
    group_sizes: jax.Array  # (s,) int32 group counts, in sample-VAT order

    @property
    def n(self) -> int:
        return int(self.labels.shape[0])

    @property
    def s(self) -> int:
        return int(self.group_sizes.shape[0])


def nearest_prototype_assign(X, prototypes, *, block: int = DEFAULT_BLOCK,
                             use_pallas: bool = False,
                             metric: str = "euclidean"):
    """Tiled nearest-prototype pass.

    Args:
      X: (n, d) array-like supporting row slicing (np.memmap included).
      prototypes: (s, d) float — the maximin sample.
      block: rows per streamed tile.
      use_pallas: route each (block, s) tile through the Pallas kernel.
      metric: dissimilarity metric, one of ``kernels.ref.METRICS``.

    Returns:
      (labels (n,) int32 nearest-prototype ids, dists (n,) float32
      distances to that prototype).

    Streams X in row blocks of ``block`` through ``kernels.ops.pairwise_
    dist`` against the (s, d) prototype matrix and reduces each (block, s)
    tile on the spot.  The loop runs on the host so X may be any ndarray-
    like supporting slicing (np.memmap included, sliced lazily from disk;
    jax arrays, sliced on device without a host round-trip); each tile is
    device-resident only while being reduced — peak intermediate
    O(block * s).
    """
    P = jnp.asarray(prototypes)
    n = X.shape[0]
    labels = np.empty((n,), np.int32)
    dists = np.empty((n,), np.float32)
    for start in range(0, n, block):
        stop = min(start + block, n)
        blk = X[start:stop]
        if not isinstance(blk, jax.Array):
            blk = jnp.asarray(np.asarray(blk, np.float32))
        D = kops.pairwise_dist(blk, P, use_pallas=use_pallas,
                               metric=metric)          # (<=block, s)
        labels[start:stop] = np.asarray(jnp.argmin(D, axis=1), np.int32)
        dists[start:stop] = np.asarray(jnp.min(D, axis=1), np.float32)
    return jnp.asarray(labels), jnp.asarray(dists)


def bigvat(X, key: jax.Array | None = None, *, s: int = DEFAULT_SAMPLE,
           block: int = DEFAULT_BLOCK, use_pallas: bool = False,
           compute_ivat: bool = True,
           metric: str = "euclidean") -> BigVATResult:
    """clusiVAT-style big-data VAT of X (n, d) without any (n, n) array.

    Args:
      X: (n, d) array-like (np.memmap ok — rows are streamed).
      key: PRNG key for the maximin start (None: PRNGKey(0)).
      s: prototype count; block: rows per extension tile;
      use_pallas: Pallas distance tiles; compute_ivat: also build the
        (s, s) geodesic image.
      metric: dissimilarity metric for sampling, the sample VAT and the
        extension pass, one of ``kernels.ref.METRICS``.

    Returns:
      BigVATResult (see the NamedTuple fields above). ``order`` lists all
      n points grouped by their prototype's position in the sample VAT
      ordering (points within a group sorted by distance to their
      prototype) — the nearest-prototype extension of the sample ordering
      to the full dataset.
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    n = X.shape[0]
    s = min(s, n)

    # 1+2. maximin prototypes + exact VAT on the (s, s) sample (= sVAT)
    Xj = X if isinstance(X, jax.Array) else jnp.asarray(np.asarray(X, np.float32))
    sample = svat(Xj, key, s=s, use_pallas=use_pallas, metric=metric)
    res = sample.vat
    prototypes = Xj[sample.sample_idx]
    iv = (ivat_from_vat(res.rstar, use_pallas=use_pallas)
          if compute_ivat else None)

    # 3. tiled nearest-prototype extension over all n points (Xj: the
    # device copy already made for sampling — avoids a second transfer)
    labels, proto_dist = nearest_prototype_assign(
        Xj, prototypes, block=block, use_pallas=use_pallas, metric=metric)

    # rank[p] = position of prototype p in the sample VAT order
    rank = jnp.zeros((s,), jnp.int32).at[res.order].set(
        jnp.arange(s, dtype=jnp.int32))
    # group by VAT rank of the assigned prototype; within a group, nearest
    # points first (lexsort: last key is primary)
    order = jnp.lexsort((proto_dist, rank[labels])).astype(jnp.int32)
    group_sizes = jnp.bincount(labels, length=s)[res.order].astype(jnp.int32)

    return BigVATResult(sample=sample, ivat=iv, labels=labels,
                        proto_dist=proto_dist, order=order,
                        group_sizes=group_sizes)


def expand_image(base, group_sizes, resolution: int = 256) -> np.ndarray:
    """Expand an (s, s) sample image to ``resolution`` pixels by group size.

    Args:
      base: (s, s) array — sample VAT/iVAT image in sample-VAT order; a
        leading batch axis (b, s, s) passes through (flashvat's batched
        render shares one group layout across lanes).
      group_sizes: (s,) int — per-prototype group counts, in the same
        order as ``base``'s rows.
      resolution: output image edge in pixels.

    Returns:
      (resolution, resolution) float32 numpy image where each prototype's
      row/column band spans pixels proportional to its group size — the
      picture a full n x n VAT image would show, rendered from the
      (s, s) sample alone.  O(resolution^2) memory, independent of n.
    """
    base = np.asarray(base)
    sizes = np.asarray(group_sizes, np.int64)
    edges = np.cumsum(sizes)                     # group boundaries in [0, n]
    n = int(edges[-1])
    pix = (np.arange(resolution) + 0.5) * n / resolution
    g = np.searchsorted(edges, pix, side="right")
    g = np.minimum(g, len(sizes) - 1)
    return base[..., g[:, None], g[None, :]]


def smoothed_image(result: BigVATResult, resolution: int = 256,
                   *, use_ivat: bool = False) -> np.ndarray:
    """Aggregated "smoothed" VAT image of all n points at a fixed resolution.

    Args:
      result: a fitted BigVATResult.
      resolution: output image edge in pixels.
      use_ivat: render from the geodesic (s, s) image instead of rstar
        (requires the result to have been built with compute_ivat=True).

    Returns:
      (resolution, resolution) float32 numpy image.

    Each prototype's row/column band spans pixels proportional to its group
    size, so the picture a full n x n VAT image would show (cluster blocks
    sized by membership) is rendered from the (s, s) sample image alone —
    O(resolution^2) memory, independent of n.
    """
    if use_ivat and result.ivat is None:
        raise ValueError("this BigVATResult was built with compute_ivat="
                         "False; no iVAT image to render")
    base = result.ivat if use_ivat else result.sample.vat.rstar
    return expand_image(base, result.group_sizes, resolution)
