"""t-SNE (exact O(n^2) variant) — the paper validates cluster tendency
with PCA and t-SNE alongside VAT; both live here as JAX-native utilities.

Standard formulation (van der Maaten & Hinton 2008): per-point sigmas by
bisection to a target perplexity, symmetrized affinities, KL gradient
descent with early exaggeration and momentum — all inside one jit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels import ops as kops


def _cond_probs(D2: jax.Array, perplexity: float, iters: int = 32):
    """Row-wise conditional P_{j|i} at the target perplexity (bisection)."""
    n = D2.shape[0]
    target = jnp.log(perplexity)
    eye = jnp.eye(n, dtype=bool)

    def entropy_probs(beta):
        logits = -D2 * beta[:, None]
        logits = jnp.where(eye, -jnp.inf, logits)
        P = jax.nn.softmax(logits, axis=1)
        H = -jnp.sum(P * jnp.where(P > 0, jnp.log(P), 0.0), axis=1)
        return H, P

    def body(_, carry):
        lo, hi, beta = carry
        H, _ = entropy_probs(beta)
        too_high = H > target          # entropy too high -> raise beta
        lo = jnp.where(too_high, beta, lo)
        hi = jnp.where(too_high, hi, beta)
        beta = jnp.where(jnp.isinf(hi), beta * 2.0, (lo + hi) / 2.0)
        return lo, hi, beta

    beta0 = jnp.ones((n,))
    lo0 = jnp.zeros((n,))
    hi0 = jnp.full((n,), jnp.inf)
    _, _, beta = lax.fori_loop(0, iters, body, (lo0, hi0, beta0))
    _, P = entropy_probs(beta)
    return P


@functools.partial(jax.jit,
                   static_argnames=("perplexity", "iters", "dim", "lr"))
def tsne(X: jax.Array, key: jax.Array, *, perplexity: float = 30.0,
         iters: int = 500, dim: int = 2, lr: float = 10.0) -> jax.Array:
    """X (n, d) -> (n, dim) embedding."""
    n = X.shape[0]
    D = kops.pairwise_dist(X)
    P = _cond_probs(D * D, perplexity)
    P = (P + P.T) / (2.0 * n)
    P = jnp.maximum(P, 1e-12)

    Y0 = 1e-2 * jax.random.normal(key, (n, dim))
    eye = jnp.eye(n, dtype=bool)

    def grad(Y, exaggeration):
        d2 = jnp.sum((Y[:, None] - Y[None]) ** 2, axis=-1)
        num = 1.0 / (1.0 + d2)
        num = jnp.where(eye, 0.0, num)
        Q = jnp.maximum(num / jnp.sum(num), 1e-12)
        PQ = (exaggeration * P - Q) * num
        return 4.0 * (jnp.sum(PQ, axis=1, keepdims=True) * Y - PQ @ Y)

    def body(t, carry):
        Y, V = carry
        exag = jnp.where(t < 100, 12.0, 1.0)
        mom = jnp.where(t < 100, 0.5, 0.8)
        g = grad(Y, exag)
        V = mom * V - lr * g
        Y = Y + V
        return Y - jnp.mean(Y, axis=0), V

    Y, _ = lax.fori_loop(0, iters, body, (Y0, jnp.zeros_like(Y0)))
    return Y
