"""VAT — Visual Assessment of Cluster Tendency, JAX-native.

The paper accelerates three stages; each has a TPU-native counterpart here:

  1. pairwise dissimilarity  -> kernels/pairwise_dist (MXU-tiled Pallas) or
                                the XLA path in kernels/ref.py
  2. Prim MST reordering     -> ``vat_order``: lax.fori_loop with a fully
                                vectorized O(n) min-update + argmin step
  3. matrix reordering       -> one gather, ``reorder``

All functions are jit-able and differentiable-safe (no Python side effects).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels import ops as kops


class VATResult(NamedTuple):
    rstar: jax.Array   # (n, n) reordered dissimilarity matrix
    order: jax.Array   # (n,) int32 permutation
    dist: jax.Array    # (n, n) original dissimilarity matrix


def vat_order(R: jax.Array, *, use_pallas_argmin: bool = False) -> jax.Array:
    """Prim-based VAT ordering of a dissimilarity matrix.

    Args:
      R: (n, n) float — symmetric dissimilarity matrix, zero diagonal.
      use_pallas_argmin: route the per-step masked argmin through the
        fused ``prim_update`` Pallas kernel (the Numba-accelerated hot
        loop of the paper); on CPU it runs in interpret mode — TPU is the
        target.

    Returns:
      (n,) int32 permutation — the VAT visit order.

    Matches ``core.naive.vat_order_naive`` exactly (first vertex = row of
    the global max; greedy min-edge growth; first-index tie-breaking, which
    jnp.argmin / the naive `<` scan share).
    """
    n = R.shape[0]
    i0 = jnp.argmax(jnp.max(R, axis=1)).astype(jnp.int32)
    order0 = jnp.zeros((n,), jnp.int32).at[0].set(i0)
    selected0 = jnp.zeros((n,), jnp.bool_).at[i0].set(True)
    mind0 = R[i0]

    def body(t, carry):
        mind, selected, order = carry
        if use_pallas_argmin:
            _, q = kops.masked_argmin(mind, selected, use_pallas=True)
        else:
            q = jnp.argmin(jnp.where(selected, jnp.inf, mind)).astype(jnp.int32)
        order = order.at[t].set(q)
        selected = selected.at[q].set(True)
        mind = jnp.minimum(mind, R[q])
        return mind, selected, order

    _, _, order = lax.fori_loop(1, n, body, (mind0, selected0, order0))
    return order


def reorder(R: jax.Array, order: jax.Array) -> jax.Array:
    """R* = R[order][:, order] — one gather along each axis.

    Args:
      R: (n, n) float — dissimilarity matrix.
      order: (n,) int — permutation from ``vat_order``.

    Returns:
      (n, n) float — R with rows and columns permuted by ``order``.
    """
    return R[order][:, order]


@functools.partial(jax.jit, static_argnames=("use_pallas", "metric"))
def vat(X: jax.Array, *, use_pallas: bool = False,
        metric: str = "euclidean") -> VATResult:
    """Full VAT on a data matrix.

    Args:
      X: (n, d) float — data points.
      use_pallas: route the dissimilarity matrix through the Pallas
        kernel (interpret mode on CPU; compiled on TPU). Default is the
        XLA path.
      metric: dissimilarity metric, one of ``kernels.ref.METRICS``.
        For an already-computed matrix use ``vat_from_dist`` instead.

    Returns:
      VATResult — rstar (n, n) reordered image, order (n,) int32
      permutation, dist (n, n) original dissimilarities.
    """
    R = kops.pairwise_dist(X, use_pallas=use_pallas, metric=metric)
    order = vat_order(R)
    return VATResult(rstar=reorder(R, order), order=order, dist=R)


@jax.jit
def vat_from_dist(R: jax.Array) -> VATResult:
    """VAT when the dissimilarity matrix is precomputed (paper step 2+3).

    Args:
      R: (n, n) float — symmetric dissimilarity matrix, zero diagonal.

    Returns:
      VATResult with ``dist`` aliasing the input R.
    """
    order = vat_order(R)
    return VATResult(rstar=reorder(R, order), order=order, dist=R)


@functools.partial(jax.jit, static_argnames=("use_pallas", "metric"))
def vat_batch(X: jax.Array, *, use_pallas: bool = False,
              metric: str = "euclidean") -> VATResult:
    """Batched VAT: assess a stack of datasets in one compiled program.

    Args:
      X: (b, n, d) float — b independent datasets of n points each.
      use_pallas: route distances through the batched-grid Pallas kernel
        (``kernels.pairwise_dist_pallas_batch``); default is the batched
        XLA path.
      metric: dissimilarity metric, one of ``kernels.ref.METRICS``.
        For precomputed (b, n, n) stacks use ``vat_batch_from_dist``.

    Returns:
      VATResult whose fields carry a leading batch axis: rstar (b, n, n),
      order (b, n) int32, dist (b, n, n).

    The per-dataset ordering is bitwise-identical to ``vat`` on the same
    rows (the vmapped ``vat_order`` runs the same argmin/min-update steps
    per batch lane; no cross-dataset reduction exists anywhere).
    """
    R = kops.pairwise_dist_batch(X, use_pallas=use_pallas, metric=metric)
    return jax.vmap(vat_from_dist)(R)


@jax.jit
def vat_batch_from_dist(R: jax.Array) -> VATResult:
    """Batched ``vat_from_dist``: (b, n, n) stack -> batched VATResult."""
    return jax.vmap(vat_from_dist)(R)


def block_structure_score(rstar: jax.Array, threshold: float | None = None):
    """Quantify diagonal block structure of a VAT image.

    Args:
      rstar: (n, n) float — VAT-reordered dissimilarity matrix.
      threshold: cut threshold as a fraction of the matrix mean; None
        derives one from the super-diagonal statistics (mean + 2 std,
        floored at half the largest jump).

    Returns:
      (score, k_est): `score` in [0, 1] — mean off-diagonal-band
      contrast; `k_est` — estimated number of diagonal blocks by counting
      super-diagonal "cuts" (adjacent-in-order distances above threshold).
      Used by diagnostics and by benchmarks/table3 to turn a VAT image
      into a machine-checkable "VAT insight".
    """
    n = rstar.shape[0]
    sup = jnp.diagonal(rstar, offset=1)           # adjacent-in-order dists
    scale = jnp.mean(rstar) + 1e-12
    if threshold is None:
        # a "cut" must stand out both locally (vs typical adjacent dist)
        # and globally (a sizeable fraction of the largest jump)
        thr = jnp.maximum(jnp.mean(sup) + 2.0 * jnp.std(sup),
                          0.5 * jnp.max(sup))
    else:
        thr = jnp.asarray(threshold) * scale
    cuts = jnp.sum(sup > thr)
    k_est = cuts + 1
    # contrast: how much darker the near-diagonal band is vs global mean
    band = jnp.mean(sup)
    score = jnp.clip(1.0 - band / scale, 0.0, 1.0)
    return score, k_est
