"""VAT — Visual Assessment of Cluster Tendency, JAX-native.

The paper accelerates three stages; each has a TPU-native counterpart here:

  1. pairwise dissimilarity  -> kernels/pairwise_dist (MXU-tiled Pallas) or
                                the XLA path in kernels/ref.py
  2. Prim MST reordering     -> ``vat_order``: lax.fori_loop with a fully
                                vectorized O(n) min-update + argmin step
  3. matrix reordering       -> one gather, ``reorder``

``vat_matrix_free`` is the Flash-VAT engine: the same exact ordering
without ever materializing the (n, n) matrix — distance rows are
recomputed tile-by-tile and reduced on the fly, so exact VAT runs at
O(n·d) memory and n = 10^5 fits a laptop CPU.  Two traversal engines
share that contract: the default Turbo persistent engine (ISSUE 5 —
the whole recurrence in ONE dispatch, ``kernels/prim_persist.py``
megakernel or its single-scan XLA mirror) and the PR-4 stepwise engine
(``turbo=False``, n−1 fused ``kernels/prim_stream.py`` steps); the
mesh-sharded variant lives in ``core.distributed``.

All functions are jit-able and differentiable-safe (no Python side effects).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels.prim_stream import pad_points


class VATResult(NamedTuple):
    rstar: jax.Array   # (n, n) reordered dissimilarity matrix
    order: jax.Array   # (n,) int32 permutation
    dist: jax.Array    # (n, n) original dissimilarity matrix


class FlashVATResult(NamedTuple):
    order: jax.Array   # (n,) int32 permutation — exact, same as vat_order
    edges: jax.Array   # (n,) float32 MST edge weight of each visit; [0]=0


def vat_order(R: jax.Array, *, use_pallas_argmin: bool = False) -> jax.Array:
    """Prim-based VAT ordering of a dissimilarity matrix.

    Args:
      R: (n, n) float — symmetric dissimilarity matrix, zero diagonal.
      use_pallas_argmin: route the per-step masked argmin through the
        fused ``prim_update`` Pallas kernel (the Numba-accelerated hot
        loop of the paper); on CPU it runs in interpret mode — TPU is the
        target.

    Returns:
      (n,) int32 permutation — the VAT visit order.

    Matches ``core.naive.vat_order_naive`` exactly (first vertex = row of
    the global max; greedy min-edge growth; first-index tie-breaking, which
    jnp.argmin / the naive `<` scan share).
    """
    n = R.shape[0]
    i0 = jnp.argmax(jnp.max(R, axis=1)).astype(jnp.int32)
    order0 = jnp.zeros((n,), jnp.int32).at[0].set(i0)
    selected0 = jnp.zeros((n,), jnp.bool_).at[i0].set(True)
    mind0 = R[i0]

    def body(t, carry):
        mind, selected, order = carry
        if use_pallas_argmin:
            _, q = kops.masked_argmin(mind, selected, use_pallas=True)
        else:
            q = jnp.argmin(jnp.where(selected, jnp.inf, mind)).astype(jnp.int32)
        order = order.at[t].set(q)
        selected = selected.at[q].set(True)
        mind = jnp.minimum(mind, R[q])
        return mind, selected, order

    _, _, order = lax.fori_loop(1, n, body, (mind0, selected0, order0))
    return order


def reorder(R: jax.Array, order: jax.Array) -> jax.Array:
    """R* = R[order][:, order] — one gather along each axis.

    Args:
      R: (n, n) float — dissimilarity matrix.
      order: (n,) int — permutation from ``vat_order``.

    Returns:
      (n, n) float — R with rows and columns permuted by ``order``.
    """
    return R[order][:, order]


@functools.partial(jax.jit, static_argnames=("use_pallas", "metric", "form"))
def vat(X: jax.Array, *, use_pallas: bool = False,
        metric: str = "euclidean", form: str = "gram") -> VATResult:
    """Full VAT on a data matrix.

    Args:
      X: (n, d) float — data points.
      use_pallas: route BOTH hot paths through Pallas kernels — the
        dissimilarity matrix (``kernels/pairwise_dist``) and the per-step
        masked argmin of the Prim loop (``kernels/prim_update``).
        Interpret mode on CPU; compiled on TPU.  Default is XLA.
      metric: dissimilarity metric, one of ``kernels.ref.METRICS``.
        For an already-computed matrix use ``vat_from_dist`` instead.
      form: "gram" (default) or "direct" — the numerics-policy tile
        form (static; resolved host-side by ``numerics.resolve``).

    Returns:
      VATResult — rstar (n, n) reordered image, order (n,) int32
      permutation, dist (n, n) original dissimilarities.
    """
    R = kops.pairwise_dist(X, use_pallas=use_pallas, metric=metric,
                           form=form)
    order = vat_order(R, use_pallas_argmin=use_pallas)
    return VATResult(rstar=reorder(R, order), order=order, dist=R)


@functools.partial(jax.jit, static_argnames=("use_pallas_argmin",))
def vat_from_dist(R: jax.Array, *,
                  use_pallas_argmin: bool = False) -> VATResult:
    """VAT when the dissimilarity matrix is precomputed (paper step 2+3).

    Args:
      R: (n, n) float — symmetric dissimilarity matrix, zero diagonal.
      use_pallas_argmin: route the Prim loop's masked argmin through the
        fused ``prim_update`` Pallas kernel (see ``vat_order``).

    Returns:
      VATResult with ``dist`` aliasing the input R.
    """
    order = vat_order(R, use_pallas_argmin=use_pallas_argmin)
    return VATResult(rstar=reorder(R, order), order=order, dist=R)


@functools.partial(jax.jit, static_argnames=("use_pallas", "metric", "form"))
def vat_batch(X: jax.Array, *, use_pallas: bool = False,
              metric: str = "euclidean", form: str = "gram") -> VATResult:
    """Batched VAT: assess a stack of datasets in one compiled program.

    Args:
      X: (b, n, d) float — b independent datasets of n points each.
      use_pallas: route distances through the batched-grid Pallas kernel
        (``kernels.pairwise_dist_pallas_batch``) AND the Prim loop's
        masked argmin through the vmapped ``prim_update`` kernel;
        default is the batched XLA path.
      metric: dissimilarity metric, one of ``kernels.ref.METRICS``.
        For precomputed (b, n, n) stacks use ``vat_batch_from_dist``.
      form: "gram" (default) or "direct" — the numerics-policy tile form.

    Returns:
      VATResult whose fields carry a leading batch axis: rstar (b, n, n),
      order (b, n) int32, dist (b, n, n).

    The per-dataset ordering is bitwise-identical to ``vat`` on the same
    rows (the vmapped ``vat_order`` runs the same argmin/min-update steps
    per batch lane; no cross-dataset reduction exists anywhere).
    """
    R = kops.pairwise_dist_batch(X, use_pallas=use_pallas, metric=metric,
                                 form=form)
    return jax.vmap(
        lambda Ri: vat_from_dist(Ri, use_pallas_argmin=use_pallas))(R)


@functools.partial(jax.jit, static_argnames=("use_pallas_argmin",))
def vat_batch_from_dist(R: jax.Array, *,
                        use_pallas_argmin: bool = False) -> VATResult:
    """Batched ``vat_from_dist``: (b, n, n) stack -> batched VATResult."""
    return jax.vmap(
        lambda Ri: vat_from_dist(Ri, use_pallas_argmin=use_pallas_argmin)
    )(R)


# ------------------------------------------------------------------------
# Flash-VAT: matrix-free fused Prim ordering — exact VAT at O(n·d) memory.
# ------------------------------------------------------------------------

def _streamed_seed_pivot(Xf: jax.Array, *, metric: str, form: str = "gram",
                         use_pallas: bool = False) -> jax.Array:
    """VAT's seed vertex i0 = argmax_i max_j R[i, j], streamed.

    Reproduces ``vat_order``'s seed bitwise without forming R: (bs, bs)
    blocks of the matrix are recomputed through the one pairwise front
    door — ``kernels.ops.pairwise_dist``, so ``use_pallas`` reaches the
    MXU tile here exactly like everywhere else — and reduced to per-row
    maxima on the spot.  Every per-entry value depends only on its own
    (x_i, y_j) pair and f32 ``max`` is exact, so any blocking yields the
    same row maxima bit for bit.

    Blocks are square and sized to keep each in-flight tile near 4 MiB
    (times d for manhattan's broadcast form, which shrinks the block):
    cache-resident tiles let XLA's fused epilogue (diag mask + rowmax)
    read the matmul output before it spills, ~2.5x over the previous
    (br, n) strip mining at n = 8192.
    """
    n, d = Xf.shape
    broadcast = metric == "manhattan" or (form == "direct"
                                          and metric != "cosine")
    per_entry = 4 * (d if broadcast else 1)  # |diff|/(diff)^2 keep (bs,bs,d)
    bs = max(8, min(1024, int(((4 << 20) // per_entry) ** 0.5), n))
    n_pad = -(-n // bs) * bs
    Xp = jnp.pad(Xf, ((0, n_pad - n), (0, 0)))
    nblk = n_pad // bs
    lane = jnp.arange(bs)

    def row_block(i, acc):
        xb = lax.dynamic_slice_in_dim(Xp, i * bs, bs, 0)
        rids = i * bs + lane

        def col_block(j, rm):
            yb = lax.dynamic_slice_in_dim(Xp, j * bs, bs, 0)
            T = kops.pairwise_dist(xb, yb, metric=metric, form=form,
                                   use_pallas=use_pallas)
            cids = j * bs + lane
            T = jnp.where(cids[None, :] == rids[:, None], 0.0, T)  # diag
            T = jnp.where(cids[None, :] < n, T, -jnp.inf)          # padding
            return jnp.maximum(rm, jnp.max(T, axis=1))

        rm = lax.fori_loop(0, nblk, col_block, jnp.full((bs,), -jnp.inf))
        return lax.dynamic_update_slice_in_dim(acc, rm, i * bs, 0)

    rowmax = lax.fori_loop(0, nblk, row_block,
                           jnp.zeros((n_pad,), jnp.float32))
    return jnp.argmax(rowmax[:n]).astype(jnp.int32)


def _prim_stream_order(Xs, auxs, i0, n, *, metric, form, use_pallas, block):
    """Drive n-1 fused Prim steps from seed i0; shared by both paths.

    Args:
      Xs / auxs: points + metric auxiliary — pre-padded (Pallas path) or
        raw (XLA path); the step dispatch in ``kernels.ops`` is
        pad-agnostic because padded lanes arrive masked.
      i0: i32 scalar seed vertex.
      n: true (unpadded) point count — sizes the order/edges outputs.
    """
    m = Xs.shape[0]
    mind0 = jnp.full((m,), jnp.inf, jnp.float32)
    sel0 = (jnp.arange(m) >= n).at[i0].set(True)
    order0 = jnp.zeros((n,), jnp.int32).at[0].set(i0)
    edges0 = jnp.zeros((n,), jnp.float32)

    def body(t, carry):
        mind, sel, order, edges, q = carry
        mind, ev, nq = kops.prim_stream_step(
            Xs, auxs, q, mind, sel, metric=metric, form=form,
            use_pallas=use_pallas, block=block)
        return (mind, sel.at[nq].set(True), order.at[t].set(nq),
                edges.at[t].set(ev), nq)

    _, _, order, edges, _ = lax.fori_loop(
        1, n, body, (mind0, sel0, order0, edges0, i0))
    return FlashVATResult(order=order, edges=edges)


@functools.partial(jax.jit, static_argnames=("metric", "form", "block",
                                             "use_pallas", "turbo"))
def vat_matrix_free(X: jax.Array, *, metric: str = "euclidean",
                    form: str = "gram", block: int = 1024,
                    use_pallas: bool = False,
                    turbo: bool = True) -> FlashVATResult:
    """Exact VAT ordering of X without ever materializing the (n, n) matrix.

    The Flash-VAT engine: the seed pivot comes from a streamed row-max
    pass, then the Prim traversal runs through one of two engines:

      * ``turbo=True`` (default) — the persistent Turbo engine
        (``kernels.ops.prim_persist``): the entire n-1 step recurrence
        in ONE dispatch — the Pallas megakernel with VMEM-resident state
        and lazy-Prim tile pruning on the ``use_pallas`` path, the
        single-scan XLA mirror otherwise.  ~4x the stepwise engine at
        n = 8192 on CPU (benchmarks "turbo" table).
      * ``turbo=False`` — the PR-4 stepwise engine: n-1 fused steps
        (``kernels/prim_stream.py`` on the Pallas path, the vectorized
        XLA step otherwise), each re-entering the runtime.

    Peak memory is O(n·d) for X plus O(n) frontier state either way —
    never O(n^2) — so exact VAT scales to n = 10^5+ on a CPU and far
    beyond on accelerators.

    The ordering is bitwise-identical to ``vat_order`` on the
    materialized ``kernels.ops.pairwise_dist`` matrix for every metric
    and both engines: identical Gram-trick rows (``kernels.ref.
    pivot_row_ref``), exact f32 min folds, identical first-index
    tie-breaking, identical seed rule.

    Args:
      X: (n, d) float — data points.
      metric: dissimilarity metric, one of ``kernels.ref.METRICS``
        ("precomputed" is meaningless here — the point is to never hold
        the matrix; use ``vat_from_dist`` if you already have it).
      form: "gram" (default) or "direct" — the numerics-policy tile
        form, shared by the seed scan and the traversal (static).
      block: X-tile length of the fused kernels (static).
      use_pallas: route the traversal (and the seed scan's pairwise
        tiles) through the Pallas kernels (interpret mode on CPU;
        compiled on TPU).  Default is XLA — the production CPU path.
      turbo: persistent engine (True, default) vs stepwise (False).

    Returns:
      FlashVATResult — ``order`` (n,) int32 exact VAT visit order and
      ``edges`` (n,) float32, the MST edge weight that admitted each
      vertex (edges[0] = 0; large edges mark cluster boundaries, which
      is what ``block_structure_score`` reads off a VAT image's
      super-diagonal).
    """
    n = X.shape[0]
    Xf = X.astype(jnp.float32)
    aux = kref.metric_aux_ref(Xf, metric=metric)
    i0 = _streamed_seed_pivot(Xf, metric=metric, form=form,
                              use_pallas=use_pallas)
    if turbo:
        order, edges = kops.prim_persist(Xf, aux, i0, metric=metric,
                                         form=form, block=block,
                                         use_pallas=use_pallas)
        return FlashVATResult(order=order, edges=edges)
    if use_pallas:
        Xs, auxs, _, bn = pad_points(Xf, aux, block=block)
    else:
        Xs, auxs, bn = Xf, aux, block
    return _prim_stream_order(Xs, auxs, i0, n, metric=metric, form=form,
                              use_pallas=use_pallas, block=bn)


@functools.partial(jax.jit, static_argnames=("metric", "form", "block",
                                             "use_pallas", "turbo"))
def vat_matrix_free_batch(X: jax.Array, *, metric: str = "euclidean",
                          form: str = "gram", block: int = 1024,
                          use_pallas: bool = False,
                          turbo: bool = True) -> FlashVATResult:
    """Batched Flash-VAT: exact matrix-free orderings for a (b, n, d) stack.

    One compiled program serves all b datasets.  ``turbo=True`` (default)
    vmaps the persistent single-scan mirror (the megakernel itself is
    solo-only — its DMA streaming does not batch); ``turbo=False`` keeps
    the stepwise engines — the XLA path vmaps the solo engine, the
    Pallas path drives the batched fused kernel (slab-of-1 grid,
    ``kernels.prim_stream.prim_stream_step_pallas_batch``) so
    per-program VMEM stays at the unbatched budget.  Each lane's
    ordering is bitwise-identical to ``vat_matrix_free`` on that dataset
    under every engine combination.

    Args:
      X: (b, n, d) float — b independent datasets.
      metric / form / block / use_pallas / turbo: as in
        ``vat_matrix_free``.

    Returns:
      FlashVATResult with a leading batch axis: order (b, n) int32,
      edges (b, n) float32.
    """
    if turbo:
        Xf = X.astype(jnp.float32)
        aux = kref.metric_aux_ref(Xf, metric=metric)
        i0 = jax.vmap(functools.partial(
            _streamed_seed_pivot, metric=metric, form=form,
            use_pallas=use_pallas))(Xf)
        order, edges = kops.prim_persist(Xf, aux, i0, metric=metric,
                                         form=form, block=block,
                                         use_pallas=use_pallas)
        return FlashVATResult(order=order, edges=edges)
    if not use_pallas:
        return jax.vmap(functools.partial(
            vat_matrix_free, metric=metric, form=form, block=block,
            turbo=False))(X)
    b, n, _ = X.shape
    Xf = X.astype(jnp.float32)
    aux = kref.metric_aux_ref(Xf, metric=metric)
    i0 = jax.vmap(functools.partial(
        _streamed_seed_pivot, metric=metric, form=form, use_pallas=True))(Xf)
    Xp, auxp, n_pad, bn = pad_points(Xf, aux, block=block)
    lane = jnp.arange(b)

    mind0 = jnp.full((b, n_pad), jnp.inf, jnp.float32)
    sel0 = jnp.broadcast_to(jnp.arange(n_pad) >= n, (b, n_pad))
    sel0 = sel0.at[lane, i0].set(True)
    order0 = jnp.zeros((b, n), jnp.int32).at[:, 0].set(i0)
    edges0 = jnp.zeros((b, n), jnp.float32)

    def body(t, carry):
        mind, sel, order, edges, q = carry
        mind, ev, nq = kops.prim_stream_step(
            Xp, auxp, q, mind, sel, metric=metric, form=form,
            use_pallas=True, block=bn)
        return (mind, sel.at[lane, nq].set(True),
                order.at[:, t].set(nq), edges.at[:, t].set(ev), nq)

    _, _, order, edges, _ = lax.fori_loop(
        1, n, body, (mind0, sel0, order0, edges0, i0))
    return FlashVATResult(order=order, edges=edges)


def block_structure_score(rstar: jax.Array, threshold: float | None = None):
    """Quantify diagonal block structure of a VAT image.

    Args:
      rstar: (n, n) float — VAT-reordered dissimilarity matrix.
      threshold: cut threshold as a fraction of the matrix mean; None
        derives one from the super-diagonal statistics (mean + 2 std,
        floored at half the largest jump).

    Returns:
      (score, k_est): `score` in [0, 1] — mean off-diagonal-band
      contrast; `k_est` — estimated number of diagonal blocks by counting
      super-diagonal "cuts" (adjacent-in-order distances above threshold).
      Used by diagnostics and by benchmarks/table3 to turn a VAT image
      into a machine-checkable "VAT insight".
    """
    n = rstar.shape[0]
    sup = jnp.diagonal(rstar, offset=1)           # adjacent-in-order dists
    scale = jnp.mean(rstar) + 1e-12
    if threshold is None:
        # a "cut" must stand out both locally (vs typical adjacent dist)
        # and globally (a sizeable fraction of the largest jump)
        thr = jnp.maximum(jnp.mean(sup) + 2.0 * jnp.std(sup),
                          0.5 * jnp.max(sup))
    else:
        thr = jnp.asarray(threshold) * scale
    cuts = jnp.sum(sup > thr)
    k_est = cuts + 1
    # contrast: how much darker the near-diagonal band is vs global mean
    band = jnp.mean(sup)
    score = jnp.clip(1.0 - band / scale, 0.0, 1.0)
    return score, k_est
