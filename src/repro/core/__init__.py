"""Fast-VAT core: the paper's contribution as composable JAX modules.

Module map: README.md (architecture) and docs/scaling.md (the
vat -> svat -> bigvat -> dvat -> streaming ladder); the user-facing
facade with automatic method selection is ``repro.api.FastVAT``.
"""
from repro.core.vat import (vat, vat_batch, vat_batch_from_dist,
                            vat_from_dist, vat_matrix_free,
                            vat_matrix_free_batch, vat_order, reorder,
                            VATResult, FlashVATResult,
                            block_structure_score)
from repro.core.ivat import (ivat, ivat_batch, ivat_batch_from_dist,
                             ivat_batch_from_vat, ivat_from_vat)
from repro.core.svat import svat, maximin_sample, SVATResult
from repro.core.hopkins import hopkins
try:  # optional: needs a JAX with shard_map (any home); see distributed.py
    from repro.core.distributed import (dvat, pairwise_dist_sharded,
                                        vat_matrix_free_sharded, DVATResult)
    HAS_DISTRIBUTED = True
    DISTRIBUTED_IMPORT_ERROR = None
except ImportError as _e:  # degrade gracefully — single-host paths stay usable
    dvat = pairwise_dist_sharded = DVATResult = None  # type: ignore[assignment]
    vat_matrix_free_sharded = None  # type: ignore[assignment]
    HAS_DISTRIBUTED = False
    DISTRIBUTED_IMPORT_ERROR = repr(_e)   # keep the real cause debuggable
from repro.core.bigvat import bigvat, BigVATResult, nearest_prototype_assign
from repro.core.approx_mst import (approx_vat, boruvka_mst, knn_graph_anchored,
                                   mst_vat_order, ApproxStats,
                                   ApproxVATResult, MSTEdges)
from repro.core.cluster import kmeans, dbscan, adjusted_rand_index, pca

_DIAG_NAMES = ("activation_report", "embedding_tendency", "router_tendency",
               "TendencyReport")


def __getattr__(name):
    # Lazy: diagnostics now lives in repro.monitor.probes, which itself
    # imports repro.core primitives — an eager import here would cycle.
    if name in _DIAG_NAMES:
        from repro.core import diagnostics
        return getattr(diagnostics, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "vat", "vat_batch", "vat_batch_from_dist", "vat_from_dist",
    "vat_matrix_free", "vat_matrix_free_batch", "vat_order", "reorder",
    "VATResult", "FlashVATResult",
    "block_structure_score", "ivat", "ivat_batch", "ivat_batch_from_dist",
    "ivat_batch_from_vat", "ivat_from_vat", "svat",
    "maximin_sample", "SVATResult", "hopkins", "HAS_DISTRIBUTED",
    "bigvat", "BigVATResult", "nearest_prototype_assign",
    "approx_vat", "boruvka_mst", "knn_graph_anchored", "mst_vat_order",
    "ApproxStats", "ApproxVATResult", "MSTEdges",
    "activation_report",
    "embedding_tendency", "router_tendency", "TendencyReport",
]
if HAS_DISTRIBUTED:
    __all__ += ["dvat", "pairwise_dist_sharded", "vat_matrix_free_sharded",
                "DVATResult"]
from repro.core.streaming import StreamingVAT
__all__.append("StreamingVAT")
from repro.core.tsne import tsne
__all__.append("tsne")
