"""Fast-VAT core: the paper's contribution as composable JAX modules."""
from repro.core.vat import vat, vat_from_dist, vat_order, reorder, VATResult, block_structure_score
from repro.core.ivat import ivat, ivat_from_vat
from repro.core.svat import svat, maximin_sample, SVATResult
from repro.core.hopkins import hopkins
from repro.core.distributed import dvat, pairwise_dist_sharded, DVATResult
from repro.core.diagnostics import activation_report, embedding_tendency, router_tendency, TendencyReport
from repro.core.cluster import kmeans, dbscan, adjusted_rand_index, pca

__all__ = [
    "vat", "vat_from_dist", "vat_order", "reorder", "VATResult",
    "block_structure_score", "ivat", "ivat_from_vat", "svat",
    "maximin_sample", "SVATResult", "hopkins", "dvat",
    "pairwise_dist_sharded", "DVATResult", "activation_report",
    "embedding_tendency", "router_tendency", "TendencyReport",
]
from repro.core.streaming import StreamingVAT
__all__.append("StreamingVAT")
from repro.core.tsne import tsne
__all__.append("tsne")
