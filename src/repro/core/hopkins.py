"""Hopkins statistic — the paper's quantitative clusterability check (Table 2).

H = sum(u) / (sum(u) + sum(w)) where u are nearest-neighbour distances of
m synthetic uniform points to the data and w are NN distances of m sampled
data points to the rest of the data.  H ~ 0.5 for uniform data; H > 0.75
indicates significant cluster structure (the threshold the paper uses).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops


@functools.partial(jax.jit, static_argnames=("m",))
def hopkins(X: jax.Array, key: jax.Array, *, m: int = 0) -> jax.Array:
    """Hopkins statistic of a dataset.

    Args:
      X: (n, d) float — data points.
      key: PRNG key (split for the uniform probe and the data sample).
      m: probe count (static); 0 means max(8, min(n // 10, 256)).

    Returns:
      f32 scalar H in (0, 1): ~0.5 for uniform data, > 0.75 indicates
      significant cluster structure (the paper's threshold).
    """
    n, d = X.shape
    if m == 0:
        m = max(8, min(n // 10, 256))
    m = min(m, n - 1)
    k_samp, k_unif = jax.random.split(key)

    lo = jnp.min(X, axis=0)
    hi = jnp.max(X, axis=0)
    U = jax.random.uniform(k_unif, (m, d), dtype=X.dtype,
                           minval=lo, maxval=hi)
    idx = jax.random.choice(k_samp, n, (m,), replace=False)
    S = X[idx]

    # u: NN distance from uniform points to the data
    du = kops.pairwise_dist(U, X)
    u = jnp.min(du, axis=1)
    # w: NN distance from sampled data points to the data minus themselves
    dw = kops.pairwise_dist(S, X)
    dw = dw.at[jnp.arange(m), idx].set(jnp.inf)
    w = jnp.min(dw, axis=1)

    return jnp.sum(u) / (jnp.sum(u) + jnp.sum(w) + 1e-12)
