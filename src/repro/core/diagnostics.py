"""VAT as a first-class training diagnostic (compat shim).

The implementation moved to `repro.monitor.probes` when the monitor
subsystem absorbed one-shot diagnostics into the continuous
probes -> history -> drift pipeline.  This module keeps the original
import surface alive:

* ``embedding_tendency`` — VAT + Hopkins over a sample of token embeddings.
* ``router_tendency``   — VAT over MoE router logits.
* ``activation_report`` — generic entry point; sVAT-sampled AND
  Hopkins-bounded, so a diag step is O(s²) regardless of batch x seq.

New code should import from ``repro.monitor`` directly.
"""
from __future__ import annotations

from repro.monitor.probes import (TendencyReport, activation_report,
                                  embedding_tendency, router_tendency)

__all__ = ["TendencyReport", "activation_report", "embedding_tendency",
           "router_tendency"]
