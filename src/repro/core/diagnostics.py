"""VAT as a first-class training diagnostic.

This is where the paper's technique plugs into the LM framework: cluster-
tendency assessment of *activation streams* during training/serving.

* ``embedding_tendency`` — VAT + Hopkins over a sample of token embeddings;
  a collapsing embedding table loses block structure (score -> 0).
* ``router_tendency``   — VAT over MoE router logits; healthy top-k routing
  shows multiple dark blocks (k_est > 1), a collapsed router shows one.
* ``activation_report`` — generic entry point the train loop calls every N
  steps; cheap (sVAT-sampled, device-resident, no host sync inside jit).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.hopkins import hopkins
from repro.core.svat import maximin_sample
from repro.core.vat import block_structure_score, vat_from_dist
from repro.kernels import ops as kops


class TendencyReport(NamedTuple):
    hopkins: jax.Array        # scalar in [0, 1]
    block_score: jax.Array    # diagonal-contrast score in [0, 1]
    k_est: jax.Array          # estimated number of diagonal blocks
    rstar: jax.Array          # (s, s) VAT image of the sample


@functools.partial(jax.jit, static_argnames=("sample",))
def activation_report(acts: jax.Array, key: jax.Array, *,
                      sample: int = 128) -> TendencyReport:
    """Cluster-tendency report for a (n, d) activation matrix.

    Subsamples to `sample` points by maximin so the VAT cost is O(s^2),
    independent of batch size.
    """
    acts = acts.reshape(-1, acts.shape[-1]).astype(jnp.float32)
    n = acts.shape[0]
    s = min(sample, n)
    k_s, k_h = jax.random.split(key)
    idx = maximin_sample(acts, s, k_s)
    sub = acts[idx]
    R = kops.pairwise_dist(sub)
    res = vat_from_dist(R)
    score, k_est = block_structure_score(res.rstar)
    return TendencyReport(
        hopkins=hopkins(acts, k_h),
        block_score=score,
        k_est=k_est,
        rstar=res.rstar,
    )


def embedding_tendency(embed_table: jax.Array, key: jax.Array,
                       sample: int = 128) -> TendencyReport:
    """Tendency of a (vocab, d) embedding table (collapse detector)."""
    return activation_report(embed_table, key, sample=sample)


def router_tendency(router_logits: jax.Array, key: jax.Array,
                    sample: int = 128) -> TendencyReport:
    """Tendency of (tokens, n_experts) router logits (specialization check).

    k_est ~ 1 => router collapse; k_est >~ top_k => healthy specialization.
    """
    return activation_report(router_logits, key, sample=sample)
