"""sVAT — scalable VAT via maximin (k-centroid) sampling.

The paper lists sampling-based approximation as future work (citing sVAT);
we implement it: pick s "distinguished" points by greedy maximin (farthest-
point) sampling — which preserves global cluster geometry — then run exact
VAT on the sample.  Turns the O(n^2) wall into O(ns + s^2).

This is the second rung of the scaling ladder (docs/scaling.md); for the
full-dataset extension see core/bigvat.py, and for automatic selection
by n see repro.api.FastVAT.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.vat import VATResult, vat_from_dist
from repro.kernels import ops as kops
from repro.kernels.ref import row_dissim_ref


class SVATResult(NamedTuple):
    vat: VATResult
    sample_idx: jax.Array  # (s,) indices of the distinguished points


def maximin_sample(X: jax.Array, s: int, key: jax.Array, *,
                   metric: str = "euclidean") -> jax.Array:
    """Greedy farthest-point (maximin) sampling.

    Args:
      X: (n, d) float — data points.
      s: number of distinguished points to pick.
      key: PRNG key for the random start point.
      metric: dissimilarity used for the frontier updates, one of
        ``kernels.ref.METRICS`` — sampling under the same metric the VAT
        image will use keeps the prototypes spread in *that* geometry.

    Returns:
      (s,) int32 indices into X — each pick maximizes the dissimilarity
      to the already-picked set. O(n s) time, O(n) memory.
    """
    n = X.shape[0]
    i0 = jax.random.randint(key, (), 0, n)
    idx0 = jnp.zeros((s,), jnp.int32).at[0].set(i0.astype(jnp.int32))
    d0 = row_dissim_ref(X, X[i0], metric=metric)

    def body(t, carry):
        mind, idx = carry
        q = jnp.argmax(mind).astype(jnp.int32)
        idx = idx.at[t].set(q)
        dq = row_dissim_ref(X, X[q], metric=metric)
        return jnp.minimum(mind, dq), idx

    _, idx = lax.fori_loop(1, s, body, (d0, idx0))
    return idx


@functools.partial(jax.jit, static_argnames=("s", "use_pallas", "metric"))
def svat(X: jax.Array, key: jax.Array, *, s: int = 256,
         use_pallas: bool = False,
         metric: str = "euclidean") -> SVATResult:
    """Approximate VAT image of X using s maximin-sampled points.

    Args:
      X: (n, d) float — data points.
      key: PRNG key for the maximin start.
      s: sample size (static; clamped to n).
      use_pallas: route the (s, s) sample dissimilarity matrix through
        the Pallas kernel (interpret mode on CPU; compiled on TPU).
      metric: dissimilarity metric for both the maximin sampling and the
        sample VAT image, one of ``kernels.ref.METRICS``.

    Returns:
      SVATResult — ``vat`` is the exact VATResult of the sample,
      ``sample_idx`` the (s,) dataset rows of the distinguished points.
    """
    s = min(s, X.shape[0])
    idx = maximin_sample(X, s, key, metric=metric)
    Xs = X[idx]
    R = kops.pairwise_dist(Xs, use_pallas=use_pallas, metric=metric)
    res = vat_from_dist(R)
    return SVATResult(vat=res, sample_idx=idx)
