"""Approximate VAT via a kNN-graph Borůvka MST — the million-point rung.

Exact VAT is a Prim traversal of the complete graph: O(n²·d) work no
matter how well it streams (the Turbo engine's ceiling is ~100k points
on CPU).  This rung trades exactness for scale the way tmap does for
molecular maps: build a sparse kNN graph (O(n·k) edges), take ITS
minimum spanning tree with Borůvka's algorithm, and traverse that tree
in Prim order to get a VAT ordering.  The kNN-MST weight is always >=
the true MST weight (it spans using a subset of edges), with equality
exactly when the true MST is contained in the kNN graph — at k = n-1
the two pipelines coincide, which is the oracle the property suite
certifies against.

Stages:

  * kNN graph — ``kernels.ops.knn_graph`` (blocked/Pallas, exact) below
    ``EXACT_KNN_N``, else ``knn_graph_anchored``: an IVF-style two-level
    search (random anchors ≈ sqrt(n), a blocked assignment pass, brute
    force within each point's ``probes`` nearest anchor cells) that
    keeps every intermediate O(n·probes·k) — brute-force kNN at 1M
    points would be 10^13 flops; the anchored pass is ~10^10.
  * Borůvka — ``_boruvka_pass`` is one jittable fold: symmetrize the
    directed kNN list (each entry contributes (u→v) and (v→u) sharing
    ONE weight, so every component sees every incident edge under a
    globally consistent key), pick each component's minimum incident
    cross edge by a three-stage lexicographic ``segment_min`` on
    (w, min-endpoint, max-endpoint) — x64 is disabled, so no packed
    64-bit keys — hook components along the picks, break the resulting
    2-cycles toward the smaller root, and collapse labels by pointer
    jumping.  Distinct lexicographic keys make cycles longer than 2
    impossible (keys are non-increasing around any hooking cycle, so
    all hops share one key = one edge pair), which is what lets the
    pointer-jump ``while_loop`` terminate unconditionally.  A host loop
    re-invokes the pass until no component finds a cross edge —
    Borůvka halves the component count per pass, so ≤ ceil(log2 n)+2
    iterations.
  * connectivity repair — a kNN graph need not be connected (separated
    blobs with small k never are).  The surviving components are
    spliced with per-component fallback edges: the minimum-index vertex
    represents each component, and an exact host-side Prim over the
    representatives' true pairwise dissimilarities supplies C-1 real
    edges (a chain over representatives past ``REPAIR_MAX_C``, where
    the (C, C) matrix would defeat the memory story).  The repair is
    reported in ``ApproxStats`` — it is the spanning-defect estimate.
  * ordering — ``mst_vat_order``: a host heap Prim restricted to the
    tree's n-1 edges.  The heap key (weight, vertex) reproduces exact
    Prim's first-index tie rule, so on the full graph (k = n-1) the
    ordering is identical to ``core.vat.vat_matrix_free``'s given the
    same seed.  The default seed is the vertex with the largest k-NN
    radius — at k = n-1 that IS exact VAT's "argmax of row max" rule.
"""
from __future__ import annotations

import dataclasses
import functools
import heapq
import math
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.kernels.ref import check_metric, pairwise_dissim_ref

#: Largest n the auto mode serves with exact blocked kNN (O(n²·d) work);
#: past it the anchored two-level search keeps the build near-linear.
EXACT_KNN_N = 32_768

#: Largest surviving-component count repaired with an exact Prim over
#: the (C, C) representative matrix; past it a representative chain
#: keeps repair memory O(C).
REPAIR_MAX_C = 4_096


@dataclasses.dataclass(frozen=True)
class ApproxStats:
    """The approx rung's error-model report (rides on ``ResultMeta``).

    Attributes:
      k: neighbours per point actually used (min(k, n-1)).
      mode: "exact" (blocked brute-force kNN) or "anchored" (two-level).
      n_passes: Borůvka passes until no cross edge remained.
      components: kNN-graph components before repair (1 = no defect).
      repaired_edges: fallback edges spliced in (= components - 1).
      mst_weight: total tree weight, repair included (f64 sum).  Always
        >= the exact MST weight; the ratio against exact is the
        quality row ``benchmarks.bench`` reports on overlap sizes.
      repair_weight: weight contributed by the fallback edges alone —
        together with ``repaired_edges`` this is the spanning-defect
        estimate (0.0 means the kNN graph already spanned).
    """

    k: int
    mode: str
    n_passes: int
    components: int
    repaired_edges: int
    mst_weight: float
    repair_weight: float


class MSTEdges(NamedTuple):
    """A spanning tree as parallel host arrays (n-1 edges when spanning)."""
    src: np.ndarray      # (m,) int32
    dst: np.ndarray      # (m,) int32
    weight: np.ndarray   # (m,) float32


class ApproxVATResult(NamedTuple):
    """Approximate VAT ordering + its MST edge trace + the error report."""
    order: np.ndarray    # (n,) int32 — visit order
    edges: np.ndarray    # (n,) float32 — per-visit tree edge (edges[0]=0)
    stats: ApproxStats


@jax.jit
def _boruvka_pass(comp, src, dst, w):
    """One Borůvka round: per-component min cross edge, hook, collapse.

    Args:
      comp: (n,) int32 — current component label per vertex (a vertex id;
        label arrays double as the union-find forest).
      src, dst: (m,) int32 — directed edge endpoints, both directions
        present, self-loops allowed (they mask out as cu == cv).
      w: (m,) float32 — edge weights, identical for the two directions
        of one edge (the caller's symmetrization guarantees it).

    Returns:
      (new_comp (n,) i32, va (n,) i32, vb (n,) i32, ew (n,) f32,
       rec (n,) bool): per component-root c, the selected edge
      (va[c], vb[c], ew[c]) and whether to record it (rec — False for
      rootless indices and the dropped side of each 2-cycle).
    """
    n = comp.shape[0]
    iota = jnp.arange(n, dtype=jnp.int32)
    cu = comp[src]
    cv = comp[dst]
    wm = jnp.where(cu != cv, w, jnp.inf)
    amin = jnp.minimum(src, dst)
    amax = jnp.maximum(src, dst)
    # Lexicographic (w, amin, amax) segment-min, one stage per field —
    # ties on w resolve to one concrete edge pair, which is what rules
    # out hooking cycles longer than 2.
    m1 = jax.ops.segment_min(wm, cu, num_segments=n)
    e1 = wm == m1[cu]
    m2 = jax.ops.segment_min(jnp.where(e1, amin, n), cu, num_segments=n)
    e2 = e1 & (amin == m2[cu])
    m3 = jax.ops.segment_min(jnp.where(e2, amax, n), cu, num_segments=n)
    has = jnp.isfinite(m1)
    va = jnp.where(has, m2, 0).astype(jnp.int32)
    vb = jnp.where(has, m3, 0).astype(jnp.int32)
    ca = comp[va]
    cb = comp[vb]
    parent = jnp.where(has, jnp.where(ca == iota, cb, ca), iota)
    # 2-cycle break: both sides picked the same edge; keep the smaller
    # root, drop the larger side's copy (equal keys => equal weights, so
    # the recorded weight sum is unaffected).
    drop = has & (parent[parent] == iota) & (iota < parent)
    parent = jnp.where(drop, iota, parent)
    parent = jax.lax.while_loop(
        lambda p: jnp.any(p != p[p]), lambda p: p[p], parent)
    return parent[comp], va, vb, jnp.where(has, m1, 0.0), has & ~drop


def _prim_edges_np(R: np.ndarray) -> list[tuple[int, int, float]]:
    """Exact MST edge list of a dense dissimilarity matrix (host Prim).

    O(C²) numpy — the connectivity-repair solver and the small-n oracle
    the property suite compares Borůvka against.  First-index
    tie-breaking via np.argmin, matching the exact engine's rule.
    """
    C = R.shape[0]
    in_tree = np.zeros(C, bool)
    in_tree[0] = True
    best = R[0].astype(np.float64).copy()
    best_from = np.zeros(C, np.int64)
    edges = []
    for _ in range(C - 1):
        cand = np.where(in_tree, np.inf, best)
        v = int(np.argmin(cand))
        edges.append((int(best_from[v]), v, float(best[v])))
        in_tree[v] = True
        upd = R[v] < best
        best_from = np.where(upd, v, best_from)
        best = np.where(upd, R[v], best)
    return edges


def _rowwise_dissim_np(A: np.ndarray, B: np.ndarray, metric: str):
    """Per-row dissimilarity of paired points (repair-chain fallback)."""
    A = A.astype(np.float32)
    B = B.astype(np.float32)
    if metric == "sqeuclidean":
        return np.sum((A - B) ** 2, axis=1)
    if metric == "euclidean":
        return np.sqrt(np.sum((A - B) ** 2, axis=1))
    if metric == "manhattan":
        return np.sum(np.abs(A - B), axis=1)
    na = np.sqrt(np.sum(A * A, axis=1))
    nb = np.sqrt(np.sum(B * B, axis=1))
    denom = np.maximum(na * nb, 1e-12)
    return np.clip(1.0 - np.sum(A * B, axis=1) / denom, 0.0, 2.0)


def boruvka_mst(idx, dist, *, X=None, metric: str = "euclidean"):
    """MST of a directed kNN graph + connectivity repair.

    Args:
      idx: (n, k) int — per-row neighbour indices; self-loops mark
        invalid slots and are ignored.
      dist: (n, k) float — matching dissimilarities.  Each directed
        entry is symmetrized in here (both directions share its weight),
        so duplicate (u, v)/(v, u) discoveries become parallel edges of
        a multigraph rather than an inconsistently-weighted edge.
      X: (n, d) float or None — required only when the graph turns out
        disconnected (repair recomputes true representative distances).
      metric: one of ``kernels.ref.METRICS`` (repair edges only).

    Returns:
      (MSTEdges, n_passes, components, repair_weight): the spanning
      edge list (always n-1 edges — repair guarantees it), the Borůvka
      pass count, the pre-repair component count, and the repair's
      weight contribution.
    """
    check_metric(metric)
    n, k = np.asarray(idx).shape
    rows = np.repeat(np.arange(n, dtype=np.int32), k)
    flat_i = np.asarray(idx, np.int32).ravel()
    flat_d = np.asarray(dist, np.float32).ravel()
    src = jnp.asarray(np.concatenate([rows, flat_i]))
    dst = jnp.asarray(np.concatenate([flat_i, rows]))
    w = jnp.asarray(np.concatenate([flat_d, flat_d]))

    comp = jnp.arange(n, dtype=jnp.int32)
    es, ed, ew = [], [], []
    passes = 0
    cap = int(math.ceil(math.log2(max(n, 2)))) + 2
    while passes < cap:
        comp, va, vb, pw, rec = _boruvka_pass(comp, src, dst, w)
        recn = np.asarray(rec)
        if not recn.any():
            break
        passes += 1
        es.append(np.asarray(va)[recn])
        ed.append(np.asarray(vb)[recn])
        ew.append(np.asarray(pw)[recn])

    comp_np = np.asarray(comp)
    roots = np.unique(comp_np)
    ncomp = int(roots.size)
    repair_w = 0.0
    if ncomp > 1:
        if X is None:
            raise ValueError(
                "kNN graph is disconnected; pass X so the spanning repair "
                "can compute fallback edges")
        Xn = np.asarray(X, np.float32)
        reps = np.full(n, n, np.int64)
        np.minimum.at(reps, comp_np, np.arange(n))
        reps = reps[roots]                       # min vertex per component
        if ncomp <= REPAIR_MAX_C:
            R = np.asarray(kops.pairwise_dist(jnp.asarray(Xn[reps]),
                                              metric=metric))
            extra = _prim_edges_np(R)
            ra = reps[[a for a, _, _ in extra]]
            rb = reps[[b for _, b, _ in extra]]
            rw = np.asarray([wgt for _, _, wgt in extra], np.float32)
        else:  # too many islands for a (C, C) matrix: chain them
            ra, rb = reps[:-1], reps[1:]
            rw = _rowwise_dissim_np(Xn[ra], Xn[rb], metric).astype(np.float32)
        es.append(ra.astype(np.int32))
        ed.append(rb.astype(np.int32))
        ew.append(rw)
        repair_w = float(np.sum(rw, dtype=np.float64))

    if es:
        tree = MSTEdges(np.concatenate(es).astype(np.int32),
                        np.concatenate(ed).astype(np.int32),
                        np.concatenate(ew).astype(np.float32))
    else:  # n == 1
        tree = MSTEdges(np.empty(0, np.int32), np.empty(0, np.int32),
                        np.empty(0, np.float32))
    return tree, passes, ncomp, repair_w


def mst_vat_order(n: int, tree: MSTEdges, i0: int):
    """VAT ordering of a spanning tree: Prim restricted to tree edges.

    On a tree, Prim's traversal from any vertex visits every vertex by
    its unique lightest connection to the visited set — the heap key
    (weight, vertex) reproduces exact Prim's (min value, first index)
    tie rule, so restricted to the TRUE MST this equals full-graph
    Prim's order for the same seed.

    Args:
      n: vertex count.
      tree: spanning edge list (n-1 edges).
      i0: seed vertex.

    Returns:
      (order (n,) int32, edges (n,) float32) — visit order and each
      visit's tree edge weight (edges[0] = 0), the same trace shape as
      ``core.vat.FlashVATResult``.
    """
    starts = np.concatenate([tree.src, tree.dst]).astype(np.int64)
    ends = np.concatenate([tree.dst, tree.src]).astype(np.int64)
    ws = np.concatenate([tree.weight, tree.weight]).astype(np.float64)
    perm = np.argsort(starts, kind="stable")
    ends = ends[perm]
    ws = ws[perm]
    off = np.zeros(n + 1, np.int64)
    np.cumsum(np.bincount(starts, minlength=n), out=off[1:])

    order = np.empty(n, np.int32)
    edges = np.zeros(n, np.float32)
    visited = np.zeros(n, bool)
    best = np.full(n, np.inf)
    best[i0] = 0.0
    heap = [(0.0, int(i0))]
    t = 0
    while heap and t < n:
        wv, v = heapq.heappop(heap)
        if visited[v] or wv > best[v]:
            continue
        visited[v] = True
        order[t] = v
        edges[t] = wv
        t += 1
        for e in range(off[v], off[v + 1]):
            u = int(ends[e])
            if not visited[u] and ws[e] < best[u]:
                best[u] = ws[e]
                heapq.heappush(heap, (float(ws[e]), u))
    if t < n:  # unreachable once repair guarantees spanning; keep total
        rest = np.flatnonzero(~visited)
        order[t:] = rest
        edges[t:] = 0.0
    return order, edges


def _bucket(size: int) -> int:
    """Next power of two >= size (floor 8) — the cell-shape bucketing
    that bounds the jit cache: cells come in every size, and compiling
    per exact shape would dominate the whole anchored pass."""
    b = 8
    while b < size:
        b <<= 1
    return b


@functools.partial(jax.jit, static_argnames=("metric", "kk"))
def _cell_topk(Xq, Xc, qid, cid, *, metric: str, kk: int):
    """Top-kk candidates per query within one (padded) anchor cell.

    Padded candidate columns carry cid = -1 and padded query rows
    qid = -2 (distinct sentinels so padding never self-matches); both
    mask to +inf before the top_k, so they can only fill trailing slots
    of undersized cells, which the caller invalidates by the inf test.
    """
    D = pairwise_dissim_ref(Xq, Xc, metric=metric)
    bad = (cid[None, :] < 0) | (cid[None, :] == qid[:, None])
    neg, p = jax.lax.top_k(-jnp.where(bad, jnp.inf, D), kk)
    return -neg, jnp.take(cid, p)


def knn_graph_anchored(X, *, k: int, metric: str = "euclidean",
                       anchors: int | None = None, probes: int = 2,
                       use_pallas: bool = False, assign_block: int = 8_192,
                       rng: np.random.Generator | None = None):
    """Approximate kNN graph by two-level (IVF-style) search.

    Sample ``anchors`` random points (≈ sqrt(n) by default — random
    anchors track data density, so cell sizes concentrate near
    n/anchors), assign every point to its ``probes`` nearest anchors in
    a blocked pass, then brute-force each anchor cell: the candidates
    are the cell's primary members, the queries everyone probing it.
    Probe pools are disjoint (primary assignment partitions the data),
    so the per-point merge over probes needs no dedup.  Every
    intermediate is O(assign_block · anchors) or O(cell² ) — nothing
    (n, n), nothing O(n) per point.

    Recall is the usual IVF story: a true neighbour is missed only when
    it lives in none of the probed cells; the Borůvka stage's repair
    covers the resulting (rare) disconnections.

    Args:
      X: (n, d) float — data points (numpy in, numpy out; the blocked
        passes go through ``kernels.ops.pairwise_dist``).
      k: neighbours per point.
      metric: one of ``kernels.ref.METRICS``.
      anchors: cell count; None = max(32, round(sqrt(n))).
      probes: anchor cells searched per point.
      use_pallas: forwarded to the distance tiles.
      assign_block: rows per assignment-pass tile.
      rng: anchor-sampling generator (default_rng(0) when None).

    Returns:
      (dist (n, k) f32, idx (n, k) i64) — ascending per row; slots the
      probed cells could not fill hold (inf, -1).
    """
    check_metric(metric)
    Xn = np.asarray(X, np.float32)
    n, _ = Xn.shape
    c = anchors if anchors is not None else max(32, int(round(math.sqrt(n))))
    c = min(c, n)
    probes = max(1, min(probes, c))
    rng = rng if rng is not None else np.random.default_rng(0)
    aidx = rng.choice(n, size=c, replace=False)
    A = jnp.asarray(Xn[aidx])

    d = Xn.shape[1]
    probe_idx = np.empty((n, probes), np.int32)
    for s0 in range(0, n, assign_block):
        xb = Xn[s0:s0 + assign_block]
        rows = xb.shape[0]
        if rows < assign_block:  # keep one eager shape for the whole pass
            xb = np.vstack([xb, np.zeros((assign_block - rows, d),
                                         np.float32)])
        D = kops.pairwise_dist(jnp.asarray(xb), A, metric=metric,
                               use_pallas=use_pallas)
        _, pid = jax.lax.top_k(-D, probes)
        probe_idx[s0:s0 + rows] = np.asarray(pid, np.int32)[:rows]

    # CSR views: candidates by primary cell, queries by each probe slot.
    primary = probe_idx[:, 0]
    by_cell = np.argsort(primary, kind="stable")
    start = np.concatenate([[0],
                            np.cumsum(np.bincount(primary, minlength=c))])
    q_order = [np.argsort(probe_idx[:, s], kind="stable")
               for s in range(probes)]
    q_start = [np.concatenate(
        [[0], np.cumsum(np.bincount(probe_idx[:, s], minlength=c))])
        for s in range(probes)]

    part_d = np.full((n, probes, k), np.inf, np.float32)
    part_i = np.full((n, probes, k), -1, np.int64)
    for g in range(c):
        cand = by_cell[start[g]:start[g + 1]]
        if cand.size == 0:
            continue
        qs = [q_order[s][q_start[s][g]:q_start[s][g + 1]]
              for s in range(probes)]
        slot = np.concatenate(
            [np.full(x.size, s, np.int64) for s, x in enumerate(qs)])
        q = np.concatenate(qs)
        if q.size == 0:
            continue
        qp, cp = _bucket(q.size), _bucket(int(cand.size))
        Xq = np.zeros((qp, d), np.float32)
        Xq[:q.size] = Xn[q]
        Xc = np.zeros((cp, d), np.float32)
        Xc[:cand.size] = Xn[cand]
        qid = np.full(qp, -2, np.int32)
        qid[:q.size] = q
        cid = np.full(cp, -1, np.int32)
        cid[:cand.size] = cand
        kk = min(k, cp)
        gd, gi = _cell_topk(jnp.asarray(Xq), jnp.asarray(Xc),
                            jnp.asarray(qid), jnp.asarray(cid),
                            metric=metric, kk=kk)
        gd = np.asarray(gd, np.float32)[:q.size]
        gi = np.asarray(gi, np.int64)[:q.size]
        gi = np.where(np.isfinite(gd), gi, -1)
        part_d[q, slot, :kk] = gd
        part_i[q, slot, :kk] = gi

    flat_d = part_d.reshape(n, probes * k)
    flat_i = part_i.reshape(n, probes * k)
    sel = np.argsort(flat_d, axis=1, kind="stable")[:, :k]
    return (np.take_along_axis(flat_d, sel, axis=1),
            np.take_along_axis(flat_i, sel, axis=1))


def approx_vat(X, *, k: int = 15, metric: str = "euclidean",
               knn_mode: str = "auto", probes: int = 2,
               use_pallas: bool = False, block: int | None = None,
               anchors: int | None = None, seed_vertex: int | None = None,
               rng: np.random.Generator | None = None) -> ApproxVATResult:
    """kNN-graph Borůvka VAT — the whole approximate pipeline.

    Args:
      X: (n, d) float — data points.
      k: neighbours per point — THE error-bound knob.  The kNN-MST
        weight is non-increasing in k (larger k gives a supergraph) and
        reaches the exact MST weight at k = n-1; ``docs/scaling.md``
        has the choosing-k guidance.
      metric: one of ``kernels.ref.METRICS``.
      knn_mode: "auto" (exact blocked kNN up to ``EXACT_KNN_N``, then
        anchored), "exact", or "anchored".
      probes / anchors: anchored-search knobs (see
        ``knn_graph_anchored``).
      use_pallas: forwarded to every distance tile.
      block: kNN tile edge override (None = per-path default).
      seed_vertex: traversal seed; None picks the vertex with the
        largest k-NN radius — at k = n-1 this is exactly the exact
        engine's argmax-of-row-max seed rule.
      rng: anchor sampling generator (anchored mode only).

    Returns:
      ``ApproxVATResult`` (order, per-visit edge trace, ``ApproxStats``).
    """
    check_metric(metric)
    if knn_mode not in ("auto", "exact", "anchored"):
        raise ValueError(f"knn_mode must be auto|exact|anchored, "
                         f"got {knn_mode!r}")
    Xn = np.asarray(X, np.float32)
    n = Xn.shape[0]
    if n == 1:
        stats = ApproxStats(k=0, mode="exact", n_passes=0, components=1,
                            repaired_edges=0, mst_weight=0.0,
                            repair_weight=0.0)
        return ApproxVATResult(np.zeros(1, np.int32), np.zeros(1, np.float32),
                               stats)
    k_eff = min(k, n - 1)
    exact = knn_mode == "exact" or (knn_mode == "auto" and n <= EXACT_KNN_N)
    if exact:
        dj, ij = kops.knn_graph(jnp.asarray(Xn), k=k_eff, metric=metric,
                                use_pallas=use_pallas, block=block)
        dist = np.asarray(dj)
        idx = np.asarray(ij, np.int64)
        mode = "exact"
    else:
        dist, idx = knn_graph_anchored(Xn, k=k_eff, metric=metric,
                                       anchors=anchors, probes=probes,
                                       use_pallas=use_pallas, rng=rng)
        mode = "anchored"

    finite = np.isfinite(dist) & (idx >= 0)
    radius = np.where(finite, dist, -np.inf).max(axis=1)
    i0 = int(seed_vertex) if seed_vertex is not None \
        else int(np.argmax(radius))
    rows = np.arange(n, dtype=np.int64)
    idx = np.where(finite, idx, rows[:, None]).astype(np.int32)
    dist = np.where(finite, dist, 0.0).astype(np.float32)

    tree, passes, ncomp, repair_w = boruvka_mst(idx, dist, X=Xn,
                                               metric=metric)
    order, edges = mst_vat_order(n, tree, i0)
    stats = ApproxStats(
        k=k_eff, mode=mode, n_passes=passes, components=ncomp,
        repaired_edges=max(ncomp - 1, 0),
        mst_weight=float(np.sum(tree.weight, dtype=np.float64)),
        repair_weight=repair_w)
    return ApproxVATResult(order, edges, stats)
