"""iVAT — improved VAT via graph-geodesic (max-min path) distances.

Uses the Havens & Bezdek (2012) O(n^2) recurrence, which requires the
input to already be VAT-ordered.  The paper cites iVAT as the main
interpretability extension; two implementations live in ``kernels/``:

  * XLA fallback (``kernels/ref.py::ivat_from_vat_ref``): lax.fori_loop
    whose body is a fully vectorized O(n) row update.
  * fused Pallas kernel (``kernels/ivat_update.py``): keeps the growing
    D' matrix resident in VMEM, replacing the per-step full-matrix
    ``at[].set`` copies with two O(n) stores.

``kernels/ops.py::ivat_from_vat`` picks between them; this module is the
stable public surface.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.vat import VATResult, vat_batch_from_dist, vat_from_dist
from repro.kernels import ops as kops


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def ivat_from_vat(rstar: jax.Array, *, use_pallas: bool = False) -> jax.Array:
    """VAT-ordered dissimilarity matrix -> iVAT geodesic matrix.

    Args:
      rstar: (n, n) float — VAT-ordered dissimilarity matrix (the
        ``rstar`` field of a ``VATResult``). Must be VAT-ordered: the
        recurrence below is only valid along a recorded Prim traversal.
      use_pallas: route through the fused VMEM-resident Pallas kernel
        (interpret mode on CPU; compiled on TPU); falls back to XLA for
        n > ``kernels.ivat_update.MAX_FUSED_N``.

    Returns:
      (n, n) float32 — D', the max-min path ("geodesic") distance matrix,
      symmetric with zero diagonal.

    The Havens & Bezdek (2012) recurrence: with D = R* VAT-ordered,
    D'[0, 0] = 0, and for each r = 1 .. n-1 in order,

        j        = argmin_{k < r} D[r, k]          (nearest ordered point —
                                                    the MST edge that
                                                    attached point r)
        D'[r, k] = max(D[r, j], D'[j, k])   for k < r, k != j
        D'[r, j] = D[r, j]
        D'[k, r] = D'[r, k]                 (symmetry), D'[r, r] = 0.

    Every path from r to an earlier point k must cross r's MST attachment
    edge (r, j), so the minimax path cost is that edge's weight capped
    below by the already-known minimax cost D'[j, k] — hence the single
    max per entry and the O(n^2) total.
    """
    return kops.ivat_from_vat(rstar, use_pallas=use_pallas)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def ivat(R: jax.Array, *, use_pallas: bool = False
         ) -> tuple[jax.Array, VATResult]:
    """Dissimilarity matrix -> (iVAT image, underlying VAT result).

    Args:
      R: (n, n) float — symmetric dissimilarity matrix, zero diagonal.
      use_pallas: forwarded to ``ivat_from_vat``.

    Returns:
      ((n, n) float32 geodesic image, VATResult of the ordering pass).
    """
    res = vat_from_dist(R)
    return ivat_from_vat(res.rstar, use_pallas=use_pallas), res


@functools.partial(jax.jit, static_argnames=("use_pallas", "metric"))
def ivat_batch(X: jax.Array, *, use_pallas: bool = False,
               metric: str = "euclidean") -> tuple[jax.Array, VATResult]:
    """Batched iVAT: stack of datasets -> stack of geodesic images.

    Args:
      X: (b, n, d) float — b independent datasets of n points each.
        NOTE: raw data, unlike the unbatched ``ivat`` which takes a
        precomputed dissimilarity matrix — for a (b, n, n) dissimilarity
        stack use ``ivat_batch_from_dist``.
      use_pallas: batched Pallas distance grid + fused iVAT kernel
        (interpret mode on CPU); default is the batched XLA path.
      metric: dissimilarity metric, one of ``kernels.ref.METRICS``.

    Returns:
      ((b, n, n) float32 iVAT stack, batched VATResult — rstar (b, n, n),
      order (b, n), dist (b, n, n)).

    Per-dataset results are bitwise-identical to running ``ivat`` on each
    X[i]: the batch axis is a vmap (XLA) or a leading grid axis (Pallas)
    with no cross-dataset interaction.
    """
    R = kops.pairwise_dist_batch(X, use_pallas=use_pallas, metric=metric)
    res = vat_batch_from_dist(R)
    return kops.ivat_from_vat(res.rstar, use_pallas=use_pallas), res


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def ivat_batch_from_dist(R: jax.Array, *, use_pallas: bool = False
                         ) -> tuple[jax.Array, VATResult]:
    """Batched ``ivat``: precomputed (b, n, n) dissimilarity stack in.

    Args:
      R: (b, n, n) float — symmetric dissimilarity matrices, zero
        diagonals (the batched analogue of ``ivat``'s input).
      use_pallas: forwarded to the fused iVAT kernel.

    Returns:
      ((b, n, n) float32 iVAT stack, batched VATResult).
    """
    res = vat_batch_from_dist(R)
    return kops.ivat_from_vat(res.rstar, use_pallas=use_pallas), res


@jax.jit
def ivat_batch_from_vat(rstar: jax.Array) -> jax.Array:
    """Batched geodesic transform of an already-ordered (b, n, n) stack."""
    return kops.ivat_from_vat(rstar)
