"""iVAT — improved VAT via graph-geodesic (max-min path) distances.

Uses the Havens & Bezdek (2012) O(n^2) recurrence, which requires the
input to already be VAT-ordered.  The paper cites iVAT as the main
interpretability extension; here it is a lax.fori_loop whose body is a
fully vectorized O(n) row update (VPU-friendly).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.vat import VATResult, vat_from_dist


@jax.jit
def ivat_from_vat(rstar: jax.Array) -> jax.Array:
    """VAT-ordered dissimilarity matrix -> iVAT geodesic matrix."""
    n = rstar.shape[0]
    idx = jnp.arange(n)

    def body(r, Dp):
        row = rstar[r]
        mask = idx < r
        j = jnp.argmin(jnp.where(mask, row, jnp.inf))
        # D'[r,k] = max(R*[r,j], D'[j,k]) for k<r; at k=j, D'[j,j]=0 gives R*[r,j]
        newrow = jnp.where(mask, jnp.maximum(rstar[r, j], Dp[j]), 0.0)
        Dp = Dp.at[r, :].set(newrow)
        Dp = Dp.at[:, r].set(newrow)
        return Dp

    return lax.fori_loop(1, n, body, jnp.zeros_like(rstar))


@jax.jit
def ivat(R: jax.Array) -> tuple[jax.Array, VATResult]:
    """Dissimilarity matrix -> (iVAT image, underlying VAT result)."""
    res = vat_from_dist(R)
    return ivat_from_vat(res.rstar), res
