"""Streaming VAT — incremental cluster-tendency monitoring (paper §5.2:
"Streaming VAT for Online Data ... enabling real-time cluster tendency
monitoring" listed as future work; implemented here).

Exact-insertion idea: VAT's ordering is a recorded Prim traversal.  For a
new point x, the MST changes only through edges incident to x, so the
updated ordering can be recomputed from the *cached distance state* in
O(n d) (distances to x) + O(n * k_changed) instead of O(n^2 d).  We keep
the dissimilarity matrix implicit: the stream state holds the points and
the running Prim frontier statistics.

For bounded memory the stream holds a maximin *reservoir* of size `cap`
(farthest-point thinning — same geometry preservation as sVAT): each
arriving point either replaces its nearest reservoir slot (if closer than
the thinning radius, it is absorbed — counts only) or evicts the point
whose removal least shrinks coverage.

`StreamingVAT.order()` returns the exact VAT ordering of the reservoir;
tests verify it equals batch VAT on the same reservoir.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.vat import vat as batch_vat
from repro.kernels.ref import check_metric


def _np_dissim_to_point(P: np.ndarray, x: np.ndarray,
                        metric: str) -> np.ndarray:
    """Host-side ``kernels.ref.row_dissim_ref`` twin: dissimilarity of
    every reservoir row to one point, in the stream's metric.

    The reservoir maintenance (absorb radius, eviction scoring) runs in
    numpy on the host — routing these O(cap) probes through jit would
    cost more in dispatch than they compute — so the metric dispatch is
    mirrored here, formula for formula.
    """
    diff = P - x
    if metric == "euclidean":
        return np.sqrt(np.maximum(np.sum(diff * diff, axis=-1), 0.0))
    if metric == "sqeuclidean":
        return np.sum(diff * diff, axis=-1)
    if metric == "manhattan":
        return np.sum(np.abs(diff), axis=-1)
    # cosine
    norms = np.sqrt(np.sum(P * P, axis=-1))
    nx = np.sqrt(np.sum(x * x))
    denom = np.maximum(norms * nx, 1e-12)
    return np.clip(1.0 - (P @ x) / denom, 0.0, 2.0)


def _np_pairwise(P: np.ndarray, metric: str) -> np.ndarray:
    """Host-side all-pairs twin of ``kernels.ref.pairwise_dissim_ref``:
    one vectorized numpy expression per metric — ``_nn_dists`` runs once
    per streamed point, so a Python loop over reservoir rows here would
    dominate the whole ingest path."""
    if metric in ("euclidean", "sqeuclidean"):
        d2 = np.sum((P[:, None] - P[None]) ** 2, axis=-1)
        return np.sqrt(np.maximum(d2, 0.0)) if metric == "euclidean" else d2
    if metric == "manhattan":
        return np.sum(np.abs(P[:, None] - P[None]), axis=-1)
    # cosine
    norms = np.sqrt(np.sum(P * P, axis=-1))
    denom = np.maximum(norms[:, None] * norms[None, :], 1e-12)
    return np.clip(1.0 - (P @ P.T) / denom, 0.0, 2.0)


class StreamingVAT:
    """Online cluster-tendency monitor with bounded memory.

    >>> sv = StreamingVAT(cap=256, d=8)
    >>> for chunk in stream: sv.update(chunk)
    >>> img, order = sv.image(), sv.order()

    ``metric`` threads end-to-end (ISSUE 5 satellite): the reservoir's
    absorb/evict geometry AND the VAT queries all run in the chosen
    dissimilarity, so a cosine stream thins by angle, not by L2.  The
    absorb step still folds into a coordinate running mean — for
    non-euclidean metrics that mean is the standard centroid surrogate,
    which preserves counts exactly and perturbs geometry by at most the
    thinning radius.

    ``validate`` (default True) admission-checks each ingested chunk the
    way the fit facades do — under a cosine stream a zero-norm point is
    refused with the typed ``InvalidInput(reason="zero_norm")`` before
    it can poison the reservoir (the eps-guard would otherwise place it
    at distance 1.0 from everything, a fabricated geometry the maximin
    thinning then preserves forever).  ``validate=False`` keeps the
    documented eps-guard semantics.
    """

    def __init__(self, cap: int, d: int, *, metric: str = "euclidean",
                 validate: bool = True):
        check_metric(metric)
        self.cap = cap
        self.d = d
        self.metric = metric
        self.validate = validate
        self.pts = np.empty((0, d), np.float32)
        self.counts = np.empty((0,), np.int64)   # absorbed multiplicity
        self.n_seen = 0
        self._dirty = True
        self._cached = None

    # ------------------------------------------------------- ingest ----

    def update(self, X) -> None:
        """Ingest a chunk of streaming points.

        Args:
          X: (m, d) array-like (or anything reshapeable to it) — the next
            m points of the stream, inserted one at a time into the
            maximin reservoir (absorb / evict per the class docstring).

        Raises:
          InvalidInput: with ``validate=True`` and ``metric="cosine"``,
            a zero-norm point in the chunk (the whole chunk is refused
            before any insertion, so the reservoir never holds a
            partial chunk).
        """
        X = np.asarray(X, np.float32).reshape(-1, self.d)
        if self.validate and self.metric == "cosine":
            norms = np.einsum("nd,nd->n", np.asarray(X, np.float64),
                              np.asarray(X, np.float64))
            zero = np.flatnonzero(norms == 0.0)
            if zero.size:
                # lazy import: core must not pull the api package in at
                # module-import time (facade imports core)
                from repro.api.validation import InvalidInput
                raise InvalidInput(
                    "zero_norm",
                    f"streamed chunk has zero-norm rows {zero.tolist()}; "
                    "cosine dissimilarity is undefined for them — drop "
                    "the rows or construct StreamingVAT(validate=False) "
                    "to keep the eps-guard semantics")
        for x in X:
            self._insert(x)
        self.n_seen += len(X)
        self._dirty = True

    def _insert(self, x: np.ndarray) -> None:
        if len(self.pts) < self.cap:
            self.pts = np.concatenate([self.pts, x[None]])
            self.counts = np.concatenate([self.counts, [1]])
            return
        dist = _np_dissim_to_point(self.pts, x, self.metric)
        j = int(np.argmin(dist))
        # thinning radius: current minimum pairwise separation estimate
        radius = self._min_sep()
        if dist[j] <= radius:
            # absorb: x is redundant at the current resolution — fold it
            # into the slot's running mean with the OLD multiplicity as
            # the weight (mean_new = (mean * c + x) / (c + 1))
            c = self.counts[j]
            self.pts[j] = (self.pts[j] * c + x) / (c + 1)
            self.counts[j] = c + 1
            return
        # evict the most redundant reservoir point (smallest NN distance)
        nn = self._nn_dists()
        k = int(np.argmin(nn))
        self.pts[k] = x
        self.counts[k] = 1

    def _nn_dists(self) -> np.ndarray:
        D = _np_pairwise(self.pts, self.metric)
        np.fill_diagonal(D, np.inf)
        return D.min(axis=1)

    def _min_sep(self) -> float:
        return float(self._nn_dists().min())

    # ------------------------------------------------------ queries ----

    def _vat(self):
        if self._dirty or self._cached is None:
            self._cached = batch_vat(jnp.asarray(self.pts),
                                     metric=self.metric)
            self._dirty = False
        return self._cached

    def order(self) -> np.ndarray:
        """Exact VAT ordering of the current reservoir: (len(pts),) int32."""
        return np.asarray(self._vat().order)

    def image(self) -> np.ndarray:
        """Reordered dissimilarity image of the reservoir: (len(pts),)^2."""
        return np.asarray(self._vat().rstar)

    def tendency(self, key=None):
        """Tendency snapshot of the current reservoir.

        Args:
          key: optional PRNG key for the Hopkins sample (defaults to a
            key derived from ``n_seen``, so repeated calls between
            updates are deterministic).

        Returns:
          (hopkins: float, block_score: float, k_est: int).
        """
        from repro.core.hopkins import hopkins
        from repro.core.vat import block_structure_score
        key = key if key is not None else jax.random.PRNGKey(self.n_seen)
        res = self._vat()
        score, k = block_structure_score(res.rstar)
        return (float(hopkins(jnp.asarray(self.pts), key)),
                float(score), int(k))
