"""Clustering baselines the paper compares VAT against (Table 3).

K-Means (Lloyd) and DBSCAN, both JAX-native and O(n^2)-dense — DBSCAN's
neighbour graph reuses the same pairwise-distance kernel as VAT, and its
cluster assignment is a vectorized min-label propagation (no Python BFS).
ARI (adjusted Rand index) is host-side numpy.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.kernels import ops as kops


@functools.partial(jax.jit, static_argnames=("k", "iters"))
def kmeans(X: jax.Array, key: jax.Array, *, k: int, iters: int = 50):
    """Lloyd's algorithm with greedy maximin seeding.

    Args:
      X: (n, d) float — data points.
      key: PRNG key for the seeding start point.
      k: number of clusters (static).
      iters: Lloyd iterations (static).

    Returns:
      (labels (n,) int32, centers (k, d) float, inertia: f32 scalar sum
      of squared distances to the assigned center).
    """
    n, d = X.shape
    # k-means++-lite: greedy maximin seeding from a random start
    from repro.core.svat import maximin_sample
    centers = X[maximin_sample(X, k, key)]

    def body(_, centers):
        dist = kops.pairwise_dist(X, centers)            # (n, k)
        lab = jnp.argmin(dist, axis=1)
        oh = jax.nn.one_hot(lab, k, dtype=X.dtype)       # (n, k)
        counts = jnp.sum(oh, axis=0)                     # (k,)
        sums = oh.T @ X                                  # (k, d)
        new = sums / jnp.maximum(counts[:, None], 1.0)
        return jnp.where(counts[:, None] > 0, new, centers)

    centers = lax.fori_loop(0, iters, body, centers)
    dist = kops.pairwise_dist(X, centers)
    labels = jnp.argmin(dist, axis=1).astype(jnp.int32)
    inertia = jnp.sum(jnp.min(dist, axis=1) ** 2)
    return labels, centers, inertia


@functools.partial(jax.jit, static_argnames=("min_pts",))
def dbscan(X: jax.Array, *, eps: float, min_pts: int = 5):
    """Density-based clustering (DBSCAN), JAX-native and O(n^2)-dense.

    Args:
      X: (n, d) float — data points.
      eps: neighbourhood radius.
      min_pts: core-point threshold, self included (static).

    Returns:
      (n,) int32 labels; -1 marks noise. Label values are core-point
      indices (not compacted to 0..k-1) — feed through
      ``adjusted_rand_index`` or np.unique for canonical ids.

    Connected components of the core-point graph are found by iterated
    min-label propagation (O(n^2) matmul-ish per sweep, <= n sweeps,
    converges in diameter-many; we run until fixpoint via while_loop).
    """
    n = X.shape[0]
    R = kops.pairwise_dist(X)
    nbr = R <= eps                                       # (n, n) bool, incl self
    core = jnp.sum(nbr, axis=1) >= min_pts

    ids = jnp.arange(n, dtype=jnp.int32)
    big = jnp.int32(n)
    labels0 = jnp.where(core, ids, big)

    core_nbr = nbr & core[None, :]                       # edges into core pts

    def sweep(labels):
        # each core point takes the min label among its core neighbours
        cand = jnp.where(core_nbr, labels[None, :], big)
        best = jnp.min(cand, axis=1)
        return jnp.where(core, jnp.minimum(labels, best), labels)

    def cond(c):
        labels, changed = c
        return changed

    def body(c):
        labels, _ = c
        new = sweep(labels)
        return new, jnp.any(new != labels)

    labels, _ = lax.while_loop(cond, body, (labels0, jnp.bool_(True)))
    # border points join the min-labelled core neighbour; else noise (-1)
    cand = jnp.where(core_nbr, labels[None, :], big)
    border = jnp.min(cand, axis=1)
    out = jnp.where(core, labels, jnp.where(border < big, border, -1))
    return out.astype(jnp.int32)


def adjusted_rand_index(a: np.ndarray, b: np.ndarray) -> float:
    """Adjusted Rand index between two labelings.

    Args:
      a, b: (n,) integer label vectors (noise -1 treated as a label).

    Returns:
      float in [-1, 1]; 1 = identical partitions, ~0 = chance agreement.
    """
    a = np.asarray(a)
    b = np.asarray(b)
    _, ai = np.unique(a, return_inverse=True)
    _, bi = np.unique(b, return_inverse=True)
    C = np.zeros((ai.max() + 1, bi.max() + 1), np.int64)
    np.add.at(C, (ai, bi), 1)
    comb = lambda x: x * (x - 1) // 2
    sum_ij = comb(C).sum()
    sum_a = comb(C.sum(1)).sum()
    sum_b = comb(C.sum(0)).sum()
    total = comb(len(a))
    exp = sum_a * sum_b / max(total, 1)
    mx = 0.5 * (sum_a + sum_b)
    if mx == exp:
        return 1.0
    return float((sum_ij - exp) / (mx - exp))


def pca(X: jax.Array, k: int = 2) -> jax.Array:
    """Top-k principal components (validation visual the paper uses).

    Args:
      X: (n, d) float — data points.
      k: number of components.

    Returns:
      (n, k) float — X centered and projected onto the top-k PCs.
    """
    Xc = X - jnp.mean(X, axis=0)
    _, _, vt = jnp.linalg.svd(Xc, full_matrices=False)
    return Xc @ vt[:k].T
