"""Declarative tendency probes over a training step.

A `ProbeSpec` names one tensor stream inside the model — the embedding
table, a layer's activations (captured from `models/model.py`'s scanned
forward pass via the ``taps=True`` aux-output hook), MoE router logits,
or a gradient leaf — and how to summarize it (maximin sample size,
optional rstar thumbnail).  `build_probe_program` compiles the whole
probe tree into ONE jitted program per diag step: a single dispatch runs
the tapped forward pass (and one backward pass iff any grad probe is
present) and emits a dict of pytree-registered `TendencyTrace`s, one per
probe, with no host sync inside jit.

Cost discipline: every probe is O(s²) in its `sample` size regardless of
batch x seq — VAT runs on a maximin sample and Hopkins on a bounded
uniform subsample (`hopkins_cap`, default 4*s), never the full (n, d)
activation matrix.

The legacy `core/diagnostics.py` entry points (`activation_report`,
`embedding_tendency`, `router_tendency`, `TendencyReport`) now live here
and are re-exported there for back-compat.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hopkins import hopkins
from repro.core.svat import maximin_sample
from repro.core.vat import block_structure_score, vat_from_dist
from repro.kernels import ops as kops

# ------------------------------------------------------------ census ----

# Trace-time census (house pattern, cf. serve._TRACE_CENSUS): the
# counters move only when jax *traces* — a warm diag step moves neither.
# "programs" counts compiled probe programs, "traces" counts trace
# events; the monitor test pins one diag step == exactly one program.
_DIAG_CENSUS = {"programs": 0, "traces": 0}


def probe_dispatch_stats() -> dict:
    """Snapshot of the probe-program census: {"programs", "traces"}."""
    return dict(_DIAG_CENSUS)


# ------------------------------------------------------------- specs ----

_KINDS = ("embedding", "layer", "router", "grad")


@dataclasses.dataclass(frozen=True)
class ProbeSpec:
    """One declarative probe: which tensor stream, how to summarize it.

    kind:
      "embedding" — the (V, D) token embedding table.
      "layer"     — per-layer activations from the tapped forward pass;
                    `layer` indexes the stacked (L, B, S, D) tap (-1 =
                    final layer).
      "router"    — MoE router logits (L, T, E) from the tapped forward
                    pass; `layer` indexes as above.  MoE configs only.
      "grad"      — a gradient leaf of the training loss; `target` is a
                    "/"-joined path into the params tree (e.g. "embed",
                    "layers/w_up").

    sample:    maximin sample size s; the probe costs O(s²).
    thumbnail: side of the optional downsampled rstar image carried in
               the trace (0 = no thumbnail; scalars only).
    """
    name: str
    kind: str
    layer: int = -1
    target: str = "embed"
    sample: int = 128
    thumbnail: int = 0

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown probe kind {self.kind!r}; "
                             f"expected one of {_KINDS}")


@dataclasses.dataclass(frozen=True)
class TendencyTrace:
    """Per-probe tendency summary emitted by the probe program.

    A registered pytree: (hopkins, block_score, k_est, thumbnail) are
    children (device arrays), `spec` is static aux data — so a traces
    dict flows through jit / device_get / tree_map untouched.
    """
    hopkins: jax.Array       # scalar f32 in [0, 1]
    block_score: jax.Array   # scalar f32 in [0, 1]
    k_est: jax.Array         # scalar, estimated number of diagonal blocks
    thumbnail: jax.Array | None  # (t, t) f32 downsampled rstar, or None
    spec: ProbeSpec


jax.tree_util.register_pytree_node(
    TendencyTrace,
    lambda t: ((t.hopkins, t.block_score, t.k_est, t.thumbnail), t.spec),
    lambda spec, kids: TendencyTrace(*kids, spec=spec),
)


def default_probes(cfg, *, sample: int = 128,
                   thumbnail: int = 0) -> tuple[ProbeSpec, ...]:
    """Default probe tree for a model config.

    Embedding table + final-layer activations + embedding gradient, plus
    router logits for MoE families.  The embedding probe comes first —
    the train loop's legacy vat_block_score/vat_k_est/hopkins metric
    keys are fed from it.
    """
    specs = [
        ProbeSpec("embed_table", "embedding", sample=sample,
                  thumbnail=thumbnail),
        ProbeSpec("acts_final", "layer", layer=-1, sample=sample,
                  thumbnail=thumbnail),
    ]
    if cfg.family == "moe":
        specs.append(ProbeSpec("router", "router", layer=-1, sample=sample,
                               thumbnail=thumbnail))
    specs.append(ProbeSpec("grad_embed", "grad", target="embed",
                           sample=sample, thumbnail=thumbnail))
    return tuple(specs)


# ------------------------------------------------------ trace innards ----


def _trace_parts(acts, key, *, sample, thumbnail, hopkins_cap=0):
    """Shared tendency math: (hopkins, block_score, k_est, rstar, thumb).

    VAT runs on a maximin sample of s points; Hopkins runs on a bounded
    *uniform* subsample (maximin would bias it toward 0.5) of at most
    `hopkins_cap` points (default 4*s) so the whole trace stays O(s²)
    regardless of the activation matrix height.
    """
    acts = acts.reshape(-1, acts.shape[-1]).astype(jnp.float32)
    n = acts.shape[0]
    s = min(sample, n)
    k_s, k_h, k_u = jax.random.split(key, 3)
    idx = maximin_sample(acts, s, k_s)
    sub = acts[idx]
    R = kops.pairwise_dist(sub)
    res = vat_from_dist(R)
    score, k_est = block_structure_score(res.rstar)
    cap = hopkins_cap if hopkins_cap > 0 else 4 * s
    if n > cap:
        hx = acts[jax.random.choice(k_u, n, (cap,), replace=False)]
    else:
        hx = acts
    h = hopkins(hx, k_h)
    thumb = None
    if thumbnail > 0:
        t = min(thumbnail, s)
        ti = jnp.round(jnp.linspace(0, s - 1, t)).astype(jnp.int32)
        thumb = res.rstar[ti][:, ti]
    return h, score, k_est, res.rstar, thumb


class TendencyReport(NamedTuple):
    hopkins: jax.Array        # scalar in [0, 1]
    block_score: jax.Array    # diagonal-contrast score in [0, 1]
    k_est: jax.Array          # estimated number of diagonal blocks
    rstar: jax.Array          # (s, s) VAT image of the sample


@functools.partial(jax.jit, static_argnames=("sample", "hopkins_cap"))
def activation_report(acts: jax.Array, key: jax.Array, *,
                      sample: int = 128,
                      hopkins_cap: int = 0) -> TendencyReport:
    """Cluster-tendency report for a (n, d) activation matrix.

    Subsamples to `sample` points by maximin so the VAT cost is O(s^2),
    and bounds the Hopkins input to `hopkins_cap` (default 4*sample)
    uniformly-sampled rows — the whole report is O(s²), independent of
    batch size.
    """
    h, score, k_est, rstar, _ = _trace_parts(
        acts, key, sample=sample, thumbnail=0, hopkins_cap=hopkins_cap)
    return TendencyReport(hopkins=h, block_score=score, k_est=k_est,
                          rstar=rstar)


def embedding_tendency(embed_table: jax.Array, key: jax.Array,
                       sample: int = 128) -> TendencyReport:
    """Tendency of a (vocab, d) embedding table (collapse detector)."""
    return activation_report(embed_table, key, sample=sample)


def router_tendency(router_logits: jax.Array, key: jax.Array,
                    sample: int = 128) -> TendencyReport:
    """Tendency of (tokens, n_experts) router logits (specialization check).

    k_est ~ 1 => router collapse; k_est >~ top_k => healthy specialization.
    """
    return activation_report(router_logits, key, sample=sample)


# ----------------------------------------------------- probe program ----


def _leaf(tree, path: str):
    node = tree
    for part in path.split("/"):
        node = node[part]
    return node


def _select(spec: ProbeSpec, params, taps, grads):
    if spec.kind == "embedding":
        return params["embed"]
    if spec.kind == "layer":
        return taps["layer_out"][spec.layer]
    if spec.kind == "router":
        if "router_logits" not in taps:
            raise ValueError(f"probe {spec.name!r}: router probes need a "
                             "moe-family config")
        return taps["router_logits"][spec.layer]
    if spec.kind == "grad":
        return _leaf(grads, spec.target)
    raise ValueError(spec.kind)


@functools.lru_cache(maxsize=64)
def _probe_program(cfg, specs: tuple[ProbeSpec, ...]):
    """Compile the probe tree into one jitted program.

    lru-cached on (cfg, specs) so repeated monitors (across train calls,
    tests, benches) reuse the compiled program; the census distinguishes
    cache hits (no movement) from rebuilds.
    """
    from repro.models import model as M
    from repro.train import steps as S

    need_taps = any(s.kind in ("layer", "router") for s in specs)
    need_grads = any(s.kind == "grad" for s in specs)

    def diag(params, batch, key):
        _DIAG_CENSUS["traces"] += 1
        taps = {}
        if need_taps:
            _, _, taps = M.forward(params, cfg, batch, taps=True)
        grads = None
        if need_grads:
            if "labels" not in batch:
                raise ValueError("grad probes need a batch with 'labels'")
            grads = jax.grad(lambda p: S.loss_fn(p, cfg, batch)[0])(params)
        out = {}
        for i, spec in enumerate(specs):
            arr = _select(spec, params, taps, grads)
            h, score, k_est, _, thumb = _trace_parts(
                arr, jax.random.fold_in(key, i),
                sample=spec.sample, thumbnail=spec.thumbnail)
            out[spec.name] = TendencyTrace(hopkins=h, block_score=score,
                                           k_est=k_est, thumbnail=thumb,
                                           spec=spec)
        return out

    _DIAG_CENSUS["programs"] += 1
    return jax.jit(diag)


def run_probes(cfg, specs, params, batch, key):
    """Run the probe tree: one dispatch -> {name: TendencyTrace}."""
    return _probe_program(cfg, tuple(specs))(params, batch, key)


# ------------------------------------------- embeddings front-end ----


def encode_batch(params, cfg, batch) -> jax.Array:
    """Final hidden states of a forward pass, flattened to (B*S, d_model).

    The DeepVAT front-end: `FastVAT.fit_embeddings` runs the rung ladder
    on these activations instead of raw inputs.
    """
    from repro.models import model as M
    b = {k: jnp.asarray(v) for k, v in batch.items()}
    h, _ = M.forward(params, cfg, b, return_hidden=True)
    return h.reshape(-1, h.shape[-1]).astype(jnp.float32)


def model_fingerprint(cfg, params) -> str:
    """Stable short fingerprint of (config, weights) for ResultMeta.

    Hashes the architecture identity plus the first embedding row, so
    two checkpoints of the same arch fingerprint differently but a
    re-created identical model fingerprints the same.
    """
    leaves = jax.tree_util.tree_leaves(params)
    n_params = sum(int(np.prod(x.shape)) for x in leaves)
    head = np.asarray(jax.device_get(
        params["embed"][0, : min(8, params["embed"].shape[-1])]),
        np.float32).tobytes()
    ident = f"{cfg.name}:{cfg.family}:{cfg.n_layers}:{cfg.d_model}:{n_params}"
    return f"{cfg.name}@{hashlib.sha1(ident.encode() + head).hexdigest()[:12]}"


def callable_fingerprint(fn) -> str:
    """Best-effort short fingerprint of an arbitrary encoder callable."""
    code = getattr(fn, "__code__", None)
    payload = code.co_code if code is not None else repr(fn).encode()
    name = getattr(fn, "__qualname__", type(fn).__name__)
    return f"{name}@{hashlib.sha1(payload).hexdigest()[:12]}"
