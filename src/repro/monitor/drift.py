"""Rolling-window drift / collapse detection over probe summaries.

One `DriftDetector` per probe consumes the (block_score, k_est, hopkins)
summary stream and maintains an explicit state machine:

  OK       — healthy; within warm-up, or no structural regression.
  WARN     — the EWMA block score has dropped `warn_drop` (relative)
             below its running peak, or the StreamingVAT window over
             recent summaries has split into distinct regimes (the
             summary stream itself became bimodal — a drift signature).
  COLLAPSE — the EWMA block score AND k_est have both fallen below the
             collapse thresholds: the probed stream has lost block
             structure (score -> 0) and merged into one cluster
             (k_est -> 1).

Everything is deterministic in the input sequence (the StreamingVAT
window keys its Hopkins sample off n_seen), so replaying a restored
`TendencyHistory` through fresh detectors reproduces the live states —
the resume path relies on this.
"""
from __future__ import annotations

import dataclasses

OK = "OK"
WARN = "WARN"
COLLAPSE = "COLLAPSE"
STATES = (OK, WARN, COLLAPSE)
# numeric codes for metric dicts (train history stores floats only)
STATE_CODES = {OK: 0.0, WARN: 1.0, COLLAPSE: 2.0}
STATE_NAMES = {v: k for k, v in STATE_CODES.items()}
_SEVERITY = {OK: 0, WARN: 1, COLLAPSE: 2}


@dataclasses.dataclass(frozen=True)
class DriftConfig:
    """Thresholds for the drift state machine (see module docstring).

    alpha:       EWMA smoothing factor for block_score / k_est.
    min_obs:     observations before any alert can fire (warm-up).
    collapse_block_score / collapse_k_est:
                 COLLAPSE when both EWMAs fall below these.
    warn_drop:   relative EWMA-vs-peak block-score drop that fires WARN.
    warn_floor:  the running peak must exceed this for the drop rule to
                 apply (streams that never had structure can't "drop").
    window:      StreamingVAT reservoir size over summary vectors
                 (0 disables the window detector).
    window_split_score:
                 window block score above which a k>=2 window reading is
                 reported as a regime split (WARN).
    window_min_spread:
                 smallest coordinate range the windowed summaries must
                 span before the split rule applies — block scores are
                 scale-invariant, so a near-constant healthy stream
                 would otherwise read its own noise as two regimes.
    """
    alpha: float = 0.3
    min_obs: int = 3
    collapse_block_score: float = 0.05
    collapse_k_est: float = 1.5
    warn_drop: float = 0.35
    warn_floor: float = 0.15
    window: int = 16
    window_split_score: float = 0.7
    window_min_spread: float = 0.15


class DriftDetector:
    """Streaming drift detector for one probe's summary sequence."""

    def __init__(self, config: DriftConfig | None = None):
        self.config = config or DriftConfig()
        self.nobs = 0
        self.ewma_score: float | None = None
        self.ewma_k: float | None = None
        self.peak_score = 0.0
        self.state = OK
        self._window = None
        self._recent: list[tuple[float, float, float]] = []
        if self.config.window > 0:
            from repro.core.streaming import StreamingVAT
            self._window = StreamingVAT(self.config.window, 3)

    def update(self, block_score: float, k_est: float,
               hopkins: float = 0.5) -> str:
        """Ingest one summary; returns the new state."""
        cfg = self.config
        a = cfg.alpha
        score = float(block_score)
        k = float(k_est)
        self.nobs += 1
        if self.ewma_score is None:
            self.ewma_score, self.ewma_k = score, k
        else:
            self.ewma_score = (1 - a) * self.ewma_score + a * score
            self.ewma_k = (1 - a) * self.ewma_k + a * k
        self.peak_score = max(self.peak_score, self.ewma_score)
        if self._window is not None:
            h = float(hopkins)
            if h != h:  # NaN-safe (e.g. probes without a Hopkins value)
                h = 0.5
            self._window.update([[h, score, k / 8.0]])
            self._recent.append((h, score, k / 8.0))
            del self._recent[:-self.config.window]

        if self.nobs < cfg.min_obs:
            self.state = OK
            return self.state
        if (self.ewma_score < cfg.collapse_block_score
                and self.ewma_k < cfg.collapse_k_est):
            self.state = COLLAPSE
            return self.state
        if (self.peak_score > cfg.warn_floor
                and self.ewma_score < (1 - cfg.warn_drop) * self.peak_score):
            self.state = WARN
            return self.state
        if self._window is not None and len(self._window.pts) >= self.config.window:
            lo = [min(v) for v in zip(*self._recent)]
            hi = [max(v) for v in zip(*self._recent)]
            spread = max(b - a for a, b in zip(lo, hi))
            if spread >= cfg.window_min_spread:
                _, wscore, wk = self._window.tendency()
                if wk >= 2 and wscore > cfg.window_split_score:
                    self.state = WARN
                    return self.state
        self.state = OK
        return self.state


def worst_state(states) -> str:
    """Most severe state in an iterable (OK < WARN < COLLAPSE)."""
    worst = OK
    for s in states:
        if _SEVERITY[s] > _SEVERITY[worst]:
            worst = s
    return worst
