"""Continuous training-diagnostics subsystem: probes -> history -> drift.

`TendencyMonitor` is the train loop's one-stop object: each diag step it
runs the compiled probe program (one dispatch), appends per-probe
summaries to an append-only `TendencyHistory` (serialized atomically
alongside checkpoints), and feeds per-probe `DriftDetector`s whose
OK/WARN/COLLAPSE states surface in the loop's log line.

Determinism: the probe key is fold_in(PRNGKey(seed), step), the history
round-trips bitwise through the checkpoint, and detectors replay the
restored history on resume — an interrupted+resumed run reproduces the
uninterrupted run's history (and drift states) exactly.

See docs/monitoring.md for the probe spec, history schema, thresholds,
and overhead guidance.
"""
from __future__ import annotations

import warnings

import jax

from repro.monitor.drift import (COLLAPSE, OK, STATE_CODES, STATE_NAMES,
                                 STATES, WARN, DriftConfig, DriftDetector,
                                 worst_state)
from repro.monitor.history import FIELDS, HISTORY_SCHEMA, TendencyHistory
from repro.monitor.probes import (ProbeSpec, TendencyReport, TendencyTrace,
                                  activation_report, callable_fingerprint,
                                  default_probes, embedding_tendency,
                                  encode_batch, model_fingerprint,
                                  probe_dispatch_stats, router_tendency,
                                  run_probes)

AUX_NAME = "tendency_history"


class TendencyMonitor:
    """Probe program + history + drift detectors for one training run."""

    def __init__(self, cfg, *, specs=None, drift: DriftConfig | None = None,
                 seed: int = 0):
        self.cfg = cfg
        self.specs = tuple(specs) if specs is not None else default_probes(cfg)
        self.seed = int(seed)
        self.drift_config = drift or DriftConfig()
        self.history = TendencyHistory(tuple(s.name for s in self.specs))
        self.detectors = {s.name: DriftDetector(self.drift_config)
                          for s in self.specs}

    # ------------------------------------------------------ observe ----

    def observe(self, step: int, params, batch) -> dict:
        """Run one diag step; returns {probe: {field..., "state"}}.

        One compiled dispatch, one host sync; deterministic in
        (seed, step) so resumed runs reproduce uninterrupted ones.
        """
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), int(step))
        traces = jax.device_get(run_probes(self.cfg, self.specs,
                                           params, batch, key))
        summaries = {}
        for spec in self.specs:
            tr = traces[spec.name]
            summaries[spec.name] = {
                "hopkins": float(tr.hopkins),
                "block_score": float(tr.block_score),
                "k_est": float(tr.k_est),
            }
        self.history.append(step, summaries)
        for name, s in summaries.items():
            s["state"] = self.detectors[name].update(
                s["block_score"], s["k_est"], s["hopkins"])
        return summaries

    # ---------------------------------------------------- states ----

    def states(self) -> dict:
        """Current {probe: state} map."""
        return {s.name: self.detectors[s.name].state for s in self.specs}

    def worst_state(self) -> str:
        return worst_state(self.states().values())

    @staticmethod
    def status_line(summaries: dict) -> str:
        """Compact per-probe status string for the train log line."""
        parts = []
        for name, s in summaries.items():
            parts.append(f"{name}={s.get('state', OK)}"
                         f"(score={s['block_score']:.2f},"
                         f"k={s['k_est']:.0f})")
        return " ".join(parts)

    # ------------------------------------------------- persistence ----

    def save_arrays(self) -> dict:
        """aux_arrays payload for `ckpt.save` (history rides the ckpt)."""
        return {AUX_NAME: self.history.to_arrays()}

    def restore(self, ckpt_dir: str, upto_step: int) -> bool:
        """Restore history from a checkpoint dir and replay drift state.

        Truncates to rows <= upto_step (the restored weights' step) and
        replays the rows through fresh detectors, reproducing the live
        states deterministically.  Returns False (and starts fresh) if
        no history was saved or the probe set changed.

        Corruption policy (ISSUE 9, docs/robustness.md): a sidecar that
        fails strict verification is salvaged via
        `TendencyHistory.recover` — truncate to the last verifiable row,
        WARN, and resume; only a structurally unreadable sidecar (or one
        with zero verifiable rows) falls back to a fresh history.
        """
        from repro.checkpoint import ckpt
        arrays = ckpt.load_aux(ckpt_dir, AUX_NAME)
        if arrays is None:
            return False
        try:
            hist = TendencyHistory.from_arrays(arrays)
        except Exception as exc:  # noqa: BLE001 — recover-and-warn policy
            recovered = TendencyHistory.recover(arrays)
            if recovered is None or len(recovered[0]) == 0:
                warnings.warn(
                    f"[monitor] history sidecar unrecoverable ({exc!r}); "
                    "starting fresh", RuntimeWarning, stacklevel=2)
                return False
            hist, dropped = recovered
            warnings.warn(
                f"[monitor] history sidecar failed verification ({exc!r});"
                f" recovered {len(hist)} rows, dropped {dropped}",
                RuntimeWarning, stacklevel=2)
        if hist.probes != tuple(s.name for s in self.specs):
            return False
        hist.truncate(int(upto_step))
        self.history = hist
        self.detectors = {s.name: DriftDetector(self.drift_config)
                          for s in self.specs}
        for i in range(len(hist)):
            for name, s in hist.row(i).items():
                self.detectors[name].update(s["block_score"], s["k_est"],
                                            s["hopkins"])
        return True


__all__ = [
    "AUX_NAME", "COLLAPSE", "DriftConfig", "DriftDetector", "FIELDS",
    "HISTORY_SCHEMA", "OK", "ProbeSpec", "STATES", "STATE_CODES",
    "STATE_NAMES", "TendencyHistory", "TendencyMonitor", "TendencyReport",
    "TendencyTrace", "WARN", "activation_report", "callable_fingerprint",
    "default_probes", "embedding_tendency", "encode_batch",
    "model_fingerprint", "probe_dispatch_stats", "router_tendency",
    "run_probes", "worst_state",
]
