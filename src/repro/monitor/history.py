"""Append-only, schema-versioned tendency history.

`TendencyHistory` records one row per diag step: the step number plus
(hopkins, block_score, k_est) per probe.  It serializes to a flat dict
of numpy arrays (`to_arrays`/`from_arrays`) that `checkpoint/ckpt.py`
writes atomically inside the checkpoint step directory, so history and
weights commit (or are garbage-collected) together — an interrupted and
resumed run reproduces history bitwise identical to an uninterrupted
run.

Bitwise discipline: npz *file bytes* are not stable (zip timestamps), so
equality is defined over the deserialized arrays via `digest()` — a
sha256 over the schema version, probe names, step vector, and each field
array's raw bytes in a canonical order.
"""
from __future__ import annotations

import hashlib

import numpy as np

HISTORY_SCHEMA = 1
FIELDS = ("hopkins", "block_score", "k_est")


class TendencyHistory:
    """Append-only per-probe tendency record.

    Rows are keyed by strictly-increasing step numbers; values are
    stored as float32 (the serialized dtype), so an append followed by a
    round-trip is exact.
    """

    def __init__(self, probes: tuple[str, ...]):
        if not probes:
            raise ValueError("TendencyHistory needs at least one probe")
        self.probes = tuple(str(p) for p in probes)
        self.steps: list[int] = []
        self._data: dict[str, dict[str, list[np.float32]]] = {
            p: {f: [] for f in FIELDS} for p in self.probes}

    # ------------------------------------------------------ record ----

    def append(self, step: int, summaries: dict) -> None:
        """Append one diag step: {probe: {field: value}} (append-only)."""
        step = int(step)
        if self.steps and step <= self.steps[-1]:
            raise ValueError(
                f"append-only: step {step} <= last step {self.steps[-1]}")
        missing = [p for p in self.probes if p not in summaries]
        if missing:
            raise ValueError(f"missing probes in summary: {missing}")
        self.steps.append(step)
        for p in self.probes:
            for f in FIELDS:
                self._data[p][f].append(np.float32(summaries[p][f]))

    def __len__(self) -> int:
        return len(self.steps)

    def series(self, probe: str, field: str) -> np.ndarray:
        """(T,) float32 series of one probe field."""
        return np.asarray(self._data[probe][field], np.float32)

    def row(self, i: int) -> dict:
        """{probe: {field: float}} for history row i."""
        return {p: {f: float(self._data[p][f][i]) for f in FIELDS}
                for p in self.probes}

    def truncate(self, max_step: int) -> None:
        """Drop rows with step > max_step (resume-from-checkpoint)."""
        keep = sum(1 for s in self.steps if s <= max_step)
        self.steps = self.steps[:keep]
        for p in self.probes:
            for f in FIELDS:
                self._data[p][f] = self._data[p][f][:keep]

    # --------------------------------------------------- serialize ----

    def to_arrays(self) -> dict[str, np.ndarray]:
        """Flat arrays dict for atomic serialization alongside a ckpt."""
        out: dict[str, np.ndarray] = {
            "schema": np.asarray([HISTORY_SCHEMA], np.int64),
            "steps": np.asarray(self.steps, np.int64),
            "probes": np.asarray(self.probes),
        }
        for p in self.probes:
            for f in FIELDS:
                out[f"{p}/{f}"] = self.series(p, f)
        return out

    @classmethod
    def from_arrays(cls, arrays: dict) -> "TendencyHistory":
        schema = int(np.asarray(arrays["schema"]).reshape(-1)[0])
        if schema > HISTORY_SCHEMA:
            raise ValueError(f"history schema {schema} is newer than "
                             f"supported ({HISTORY_SCHEMA})")
        probes = tuple(str(p) for p in np.asarray(arrays["probes"]))
        hist = cls(probes)
        hist.steps = [int(s) for s in np.asarray(arrays["steps"])]
        for p in probes:
            for f in FIELDS:
                col = np.asarray(arrays[f"{p}/{f}"], np.float32)
                hist._data[p][f] = [np.float32(v) for v in col]
        return hist

    def digest(self) -> str:
        """Canonical content hash — the bitwise-equality primitive."""
        h = hashlib.sha256()
        h.update(f"schema={HISTORY_SCHEMA}".encode())
        h.update(("probes=" + ",".join(self.probes)).encode())
        h.update(np.asarray(self.steps, np.int64).tobytes())
        for p in self.probes:
            for f in FIELDS:
                h.update(self.series(p, f).tobytes())
        return h.hexdigest()

    def nbytes_per_step(self) -> float:
        """Serialized array bytes per recorded step (growth rate)."""
        per_row = 8 + 4 * len(self.probes) * len(FIELDS)
        return float(per_row)
