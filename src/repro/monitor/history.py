"""Append-only, schema-versioned tendency history.

`TendencyHistory` records one row per diag step: the step number plus
(hopkins, block_score, k_est) per probe.  It serializes to a flat dict
of numpy arrays (`to_arrays`/`from_arrays`) that `checkpoint/ckpt.py`
writes atomically inside the checkpoint step directory, so history and
weights commit (or are garbage-collected) together — an interrupted and
resumed run reproduces history bitwise identical to an uninterrupted
run.

Bitwise discipline: npz *file bytes* are not stable (zip timestamps), so
equality is defined over the deserialized arrays via `digest()` — a
sha256 over the schema version, probe names, step vector, and each field
array's raw bytes in a canonical order.

Schema 2 (ISSUE 9) adds integrity metadata: a per-row uint64 checksum
vector (``row_check``, blake2b over the step and that row's field values
in canonical order) plus the overall ``digest`` bytes.  `from_arrays`
verifies both and raises on mismatch; `recover` is the lenient path —
it salvages the longest verifiable prefix of rows, which is what lets a
training resume survive a corrupted sidecar instead of crashing.
Schema-1 payloads (no checksums) still load unchanged.
"""
from __future__ import annotations

import hashlib

import numpy as np

from repro import faults

HISTORY_SCHEMA = 2
FIELDS = ("hopkins", "block_score", "k_est")


def _row_check64(step: int, values) -> np.uint64:
    """uint64 checksum of one row: step + field values, canonical order."""
    h = hashlib.blake2b(digest_size=8)
    h.update(np.int64(step).tobytes())
    h.update(np.asarray(values, np.float32).tobytes())
    return np.uint64(int.from_bytes(h.digest(), "little"))


class TendencyHistory:
    """Append-only per-probe tendency record.

    Rows are keyed by strictly-increasing step numbers; values are
    stored as float32 (the serialized dtype), so an append followed by a
    round-trip is exact.
    """

    def __init__(self, probes: tuple[str, ...]):
        if not probes:
            raise ValueError("TendencyHistory needs at least one probe")
        self.probes = tuple(str(p) for p in probes)
        self.steps: list[int] = []
        self._data: dict[str, dict[str, list[np.float32]]] = {
            p: {f: [] for f in FIELDS} for p in self.probes}

    # ------------------------------------------------------ record ----

    def append(self, step: int, summaries: dict) -> None:
        """Append one diag step: {probe: {field: value}} (append-only)."""
        step = int(step)
        if self.steps and step <= self.steps[-1]:
            raise ValueError(
                f"append-only: step {step} <= last step {self.steps[-1]}")
        missing = [p for p in self.probes if p not in summaries]
        if missing:
            raise ValueError(f"missing probes in summary: {missing}")
        self.steps.append(step)
        for p in self.probes:
            for f in FIELDS:
                self._data[p][f].append(np.float32(summaries[p][f]))

    def __len__(self) -> int:
        return len(self.steps)

    def series(self, probe: str, field: str) -> np.ndarray:
        """(T,) float32 series of one probe field."""
        return np.asarray(self._data[probe][field], np.float32)

    def row(self, i: int) -> dict:
        """{probe: {field: float}} for history row i."""
        return {p: {f: float(self._data[p][f][i]) for f in FIELDS}
                for p in self.probes}

    def truncate(self, max_step: int) -> None:
        """Drop rows with step > max_step (resume-from-checkpoint)."""
        keep = sum(1 for s in self.steps if s <= max_step)
        self.steps = self.steps[:keep]
        for p in self.probes:
            for f in FIELDS:
                self._data[p][f] = self._data[p][f][:keep]

    # --------------------------------------------------- serialize ----

    def _row_checksum(self, i: int) -> np.uint64:
        values = [self._data[p][f][i] for p in self.probes for f in FIELDS]
        return _row_check64(self.steps[i], values)

    def to_arrays(self) -> dict[str, np.ndarray]:
        """Flat arrays dict for atomic serialization alongside a ckpt.

        Schema 2: includes the per-row ``row_check`` checksum vector and
        the overall ``digest`` bytes, so the deserializer can verify row
        integrity and `recover` can truncate to a verifiable prefix.
        """
        out: dict[str, np.ndarray] = {
            "schema": np.asarray([HISTORY_SCHEMA], np.int64),
            "steps": np.asarray(self.steps, np.int64),
            "probes": np.asarray(self.probes),
        }
        for p in self.probes:
            for f in FIELDS:
                out[f"{p}/{f}"] = self.series(p, f)
        out["row_check"] = np.asarray(
            [self._row_checksum(i) for i in range(len(self))], np.uint64)
        out["digest"] = np.frombuffer(bytes.fromhex(self.digest()), np.uint8)
        return out

    @classmethod
    def from_arrays(cls, arrays: dict) -> "TendencyHistory":
        """Strict deserializer: verifies schema-2 integrity metadata.

        Raises ValueError on a row-checksum or digest mismatch; use
        `recover` for the lenient salvage path.  Schema-1 payloads have
        no checksums and load unverified (backward compatible).
        """
        # fault-injection site: chaos tests corrupt the arrays payload
        # through the real deserialize path (disarmed: returns as-is)
        arrays = faults.fault_point("history.deserialize", data=dict(arrays),
                                    context={"keys": sorted(arrays)})
        schema = int(np.asarray(arrays["schema"]).reshape(-1)[0])
        if schema > HISTORY_SCHEMA:
            raise ValueError(f"history schema {schema} is newer than "
                             f"supported ({HISTORY_SCHEMA})")
        probes = tuple(str(p) for p in np.asarray(arrays["probes"]))
        hist = cls(probes)
        hist.steps = [int(s) for s in np.asarray(arrays["steps"])]
        for p in probes:
            for f in FIELDS:
                col = np.asarray(arrays[f"{p}/{f}"], np.float32)
                hist._data[p][f] = [np.float32(v) for v in col]
        if schema >= 2:
            check = np.asarray(arrays["row_check"], np.uint64).reshape(-1)
            if check.shape[0] != len(hist):
                raise ValueError(
                    f"history row_check length {check.shape[0]} != "
                    f"{len(hist)} rows")
            for i in range(len(hist)):
                if np.uint64(check[i]) != hist._row_checksum(i):
                    raise ValueError("history row checksum mismatch at "
                                     f"step {hist.steps[i]}")
            if "digest" in arrays:
                stored = bytes(np.asarray(arrays["digest"], np.uint8))
                if stored != bytes.fromhex(hist.digest()):
                    raise ValueError("history digest mismatch")
        return hist

    @classmethod
    def recover(cls, arrays: dict) -> tuple["TendencyHistory", int] | None:
        """Salvage the longest verifiable prefix of a (possibly corrupt)
        serialized history.

        Rows are kept while (a) step numbers stay strictly increasing
        and (b) when a ``row_check`` vector is present, the row's
        checksum verifies.  A digest mismatch alone never drops rows —
        the row is the integrity unit.  Returns ``(history, dropped)``
        where ``dropped`` counts discarded rows, or None when even the
        structure (probes / steps / columns) is unreadable.
        """
        try:
            arrays = dict(arrays)
            probes = tuple(str(p) for p in np.asarray(arrays["probes"]))
            if not probes:
                return None
            steps = [int(s) for s in
                     np.asarray(arrays["steps"]).reshape(-1)]
            total = len(steps)
            limit = total
            cols: dict[tuple[str, str], np.ndarray] = {}
            for p in probes:
                for f in FIELDS:
                    col = np.asarray(arrays[f"{p}/{f}"],
                                     np.float32).reshape(-1)
                    cols[(p, f)] = col
                    limit = min(limit, col.shape[0])
            check = None
            if "row_check" in arrays:
                check = np.asarray(arrays["row_check"],
                                   np.uint64).reshape(-1)
                limit = min(limit, check.shape[0])
        except Exception:
            return None
        hist = cls(probes)
        for i in range(limit):
            if hist.steps and steps[i] <= hist.steps[-1]:
                break
            values = [cols[(p, f)][i] for p in probes for f in FIELDS]
            if check is not None and \
                    np.uint64(check[i]) != _row_check64(steps[i], values):
                break
            hist.append(steps[i],
                        {p: {f: float(cols[(p, f)][i]) for f in FIELDS}
                         for p in probes})
        return hist, total - len(hist)

    def digest(self) -> str:
        """Canonical content hash — the bitwise-equality primitive."""
        h = hashlib.sha256()
        h.update(f"schema={HISTORY_SCHEMA}".encode())
        h.update(("probes=" + ",".join(self.probes)).encode())
        h.update(np.asarray(self.steps, np.int64).tobytes())
        for p in self.probes:
            for f in FIELDS:
                h.update(self.series(p, f).tobytes())
        return h.hexdigest()

    def nbytes_per_step(self) -> float:
        """Serialized array bytes per recorded step (growth rate)."""
        per_row = 8 + 4 * len(self.probes) * len(FIELDS)
        return float(per_row)
