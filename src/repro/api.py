"""FastVAT — one front door for every VAT variant in this repo.

Picks the right scaling rung automatically (see ``docs/scaling.md``):

  n <= SMALL_N  (2_048)   exact ``vat``   — O(n^2) matrix fits easily
  n <= MEDIUM_N (20_000)  ``svat``        — maximin sample, O(ns + s^2)
  larger                  ``bigvat``      — clusiVAT pipeline, no (n, n)

``method`` overrides: "vat" | "ivat" | "svat" | "bigvat" | "dvat" | "auto".
"dvat" (matrix-free distributed VAT) needs >1 JAX device and a JAX whose
shard_map import resolves (``repro.core.HAS_DISTRIBUTED``).

>>> from repro.api import FastVAT
>>> fv = FastVAT().fit(X)            # auto-selects by n
>>> fv.method_resolved               # e.g. "bigvat"
>>> img = fv.image(resolution=256)   # reordered dissimilarity image
>>> fv.assess()                      # {"hopkins": ..., "k_est": ..., ...}

Batched: a (b, n, d) stack of datasets is assessed in one compiled
program (see ``docs/api.md``):

>>> fv = FastVAT(method="ivat").fit_many(Xs)   # Xs: (b, n, d)
>>> fv.image().shape                           # (b, n, n)
>>> fv.assess()                                # list of b reports
"""
from __future__ import annotations

from typing import Any

import numpy as np

import jax
import jax.numpy as jnp

from repro import core
from repro.core.bigvat import DEFAULT_BLOCK, bigvat, smoothed_image

SMALL_N = 2_048
MEDIUM_N = 20_000

METHODS = ("auto", "vat", "ivat", "svat", "bigvat", "dvat")


def select_method(n: int) -> str:
    """The auto-selection policy: exact below SMALL_N, sVAT to MEDIUM_N,
    Big-VAT beyond (the only rung with no O(n^2) object)."""
    if n <= SMALL_N:
        return "vat"
    if n <= MEDIUM_N:
        return "svat"
    return "bigvat"


class FastVAT:
    """Facade over vat / ivat / svat / bigvat / dvat with auto-selection.

    Parameters
    ----------
    method:       one of METHODS; "auto" picks by n at fit time.
    sample_size:  s for svat/bigvat prototypes.
    block:        row-block size of bigvat's tiled assignment pass.
    use_pallas:   route distance tiles through the Pallas kernel
                  (interpret mode on CPU; compiled on TPU).
    seed:         PRNG seed for sampling.
    """

    def __init__(self, method: str = "auto", *, sample_size: int = 256,
                 block: int = DEFAULT_BLOCK, use_pallas: bool = False,
                 seed: int = 0):
        if method not in METHODS:
            raise ValueError(f"method must be one of {METHODS}, got {method!r}")
        self.method = method
        self.sample_size = sample_size
        self.block = block
        self.use_pallas = use_pallas
        self.seed = seed
        self.method_resolved: str | None = None
        self.result: Any = None
        self.batched = False
        self._X = None

    # ------------------------------------------------------------- fit ----

    def fit(self, X) -> "FastVAT":
        n = X.shape[0]
        method = self.method if self.method != "auto" else select_method(n)
        key = jax.random.PRNGKey(self.seed)

        if method in ("vat", "ivat"):
            Xj = jnp.asarray(np.asarray(X, np.float32))
            res = core.vat(Xj, use_pallas=self.use_pallas)
            if method == "ivat":
                self.result = (res, core.ivat_from_vat(
                    res.rstar, use_pallas=self.use_pallas))
            else:
                self.result = res
        elif method == "svat":
            Xj = jnp.asarray(np.asarray(X, np.float32))
            self.result = core.svat(Xj, key, s=min(self.sample_size, n),
                                    use_pallas=self.use_pallas)
        elif method == "bigvat":
            self.result = bigvat(X, key, s=self.sample_size,
                                    block=self.block,
                                    use_pallas=self.use_pallas)
        elif method == "dvat":
            if not core.HAS_DISTRIBUTED:
                raise RuntimeError(
                    "method='dvat' needs a JAX with shard_map "
                    "(repro.core.HAS_DISTRIBUTED is False; cause: "
                    f"{core.DISTRIBUTED_IMPORT_ERROR})")
            devs = jax.devices()
            if len(devs) < 2:
                raise RuntimeError(
                    f"method='dvat' needs >1 device, found {len(devs)}; "
                    "use 'bigvat' on a single host")
            if n % len(devs):
                raise ValueError(
                    f"method='dvat' needs n divisible by the device count "
                    f"({n} % {len(devs)} != 0); pad or truncate X first")
            from jax.sharding import Mesh
            mesh = Mesh(np.array(devs), ("data",))
            Xj = jnp.asarray(np.asarray(X, np.float32))
            self.result = core.dvat(Xj, mesh)
        self.method_resolved = method
        self.batched = False
        self._X = X
        return self

    def fit_many(self, Xs) -> "FastVAT":
        """Assess a stack of datasets in ONE compiled program.

        Args:
          Xs: (b, n, d) array-like — b independent datasets of n points
            each (pad or truncate to a common n first; a Python list of
            equal-shape (n, d) arrays also works).

        Returns:
          self. ``order()`` then yields (b, n), ``image()`` (b, n, n),
          and ``assess()`` a list of b per-dataset reports.

        Only the exact rungs batch: method "vat" / "ivat" (or "auto",
        which resolves to "vat" for n <= SMALL_N and "ivat" is opt-in).
        Each dataset's ordering is bitwise-identical to a solo ``fit`` —
        the batch is a vmap / batched Pallas grid, never an
        approximation. For n past the exact-VAT rung, loop ``fit()`` per
        dataset instead (svat/bigvat don't vectorize over datasets yet).
        """
        Xs = jnp.asarray(np.asarray(Xs, np.float32))
        if Xs.ndim != 3:
            raise ValueError(f"fit_many wants a (b, n, d) stack, got "
                             f"shape {Xs.shape}")
        n = Xs.shape[1]
        method = self.method
        if method == "auto":
            if n > SMALL_N:
                raise ValueError(
                    f"fit_many batches the exact rungs only (n <= "
                    f"{SMALL_N}), got per-dataset n={n}; loop fit() per "
                    "dataset for the svat/bigvat rungs")
            method = "vat"
        if method not in ("vat", "ivat"):
            raise ValueError(
                f"fit_many supports method 'vat', 'ivat' or 'auto', "
                f"got {self.method!r}")
        if method == "vat":
            self.result = core.vat_batch(Xs, use_pallas=self.use_pallas)
        else:
            img, res = core.ivat_batch(Xs, use_pallas=self.use_pallas)
            self.result = (res, img)
        self.method_resolved = method
        self.batched = True
        self._X = np.asarray(Xs)
        return self

    # --------------------------------------------------------- queries ----

    def _require_fit(self):
        if self.result is None:
            raise RuntimeError("call fit(X) first")
        return self.result

    def order(self) -> np.ndarray:
        """VAT ordering: all n points (vat/ivat/bigvat/dvat) or the sample
        (svat — use sample_indices() to map back to dataset rows).
        After ``fit_many`` the result is a (b, n) stack of orderings."""
        res = self._require_fit()
        m = self.method_resolved
        if m in ("vat", "dvat"):
            return np.asarray(res.order)
        if m == "ivat":
            return np.asarray(res[0].order)
        if m == "svat":
            return np.asarray(res.vat.order)
        return np.asarray(res.order)                      # bigvat: full n

    def sample_indices(self) -> np.ndarray | None:
        """Dataset rows of the prototypes (svat/bigvat), else None."""
        res = self._require_fit()
        if self.method_resolved == "svat":
            return np.asarray(res.sample_idx)
        if self.method_resolved == "bigvat":
            return np.asarray(res.sample.sample_idx)
        return None

    def image(self, *, resolution: int = 256,
              use_ivat: bool | None = None) -> np.ndarray:
        """The reordered dissimilarity image (the thing you look at).

        vat/svat/ivat return their exact image; bigvat returns the
        smoothed clusiVAT image expanded to ``resolution`` pixels by group
        size.  ``use_ivat=None`` (default) uses the geodesic (iVAT) image
        wherever one was computed (ivat and bigvat); pass False to force
        the plain reordered distances.  After ``fit_many`` the result
        carries a leading batch axis: (b, n, n).
        """
        res = self._require_fit()
        m = self.method_resolved
        if m == "vat":
            # geodesic image computed on demand when explicitly requested
            return np.asarray(
                core.ivat_from_vat(res.rstar, use_pallas=self.use_pallas)
                if use_ivat else res.rstar)
        if m == "ivat":
            return np.asarray(res[1] if use_ivat in (None, True) else res[0].rstar)
        if m == "svat":
            return np.asarray(
                core.ivat_from_vat(res.vat.rstar, use_pallas=self.use_pallas)
                if use_ivat else res.vat.rstar)
        if m == "bigvat":
            return smoothed_image(res, resolution,
                                  use_ivat=use_ivat in (None, True))
        raise RuntimeError(f"method {m!r} produces an ordering, not an image")

    def _hopkins_subsample(self, X, cap: int = 2_048) -> np.ndarray:
        """Uniform random rows of X for the Hopkins statistic.

        Maximin prototypes are deliberately spread out, which biases
        Hopkins toward 0.5 — so the svat/bigvat rungs must not reuse them
        here.  Row indexing (sorted) keeps np.memmap inputs out-of-core.
        """
        n = X.shape[0]
        if n <= cap:
            idx = np.arange(n)
        else:
            idx = np.sort(np.random.default_rng(self.seed).choice(
                n, cap, replace=False))
        return np.asarray(X[idx], np.float32)

    def _assess_one(self, rstar, X, key, extra: dict) -> dict:
        """Score one (rstar, X) pair: Hopkins + block structure."""
        Xh = self._hopkins_subsample(X)
        score, k_est = core.block_structure_score(rstar)
        h = core.hopkins(jnp.asarray(Xh), key)
        return {
            **extra,
            "hopkins": float(h),
            "block_score": float(score),
            "k_est": int(k_est),
            "clustered": bool(h > 0.75 and float(score) > 0.3),
        }

    def assess(self, key: jax.Array | None = None):
        """Machine-checkable tendency report: Hopkins + block structure.

        Returns one dict after ``fit`` (keys: method, n, hopkins,
        block_score, k_est, clustered) and a list of b such dicts (plus a
        ``batch_index`` key) after ``fit_many``.
        """
        res = self._require_fit()
        m = self.method_resolved
        if key is None:
            key = jax.random.PRNGKey(self.seed + 1)

        if self.batched:
            rstars = res.rstar if m == "vat" else res[0].rstar   # (b, n, n)
            b = rstars.shape[0]
            keys = jax.random.split(key, b)
            return [
                self._assess_one(
                    rstars[i], self._X[i], keys[i],
                    {"method": m, "n": int(self._X.shape[1]),
                     "batch_index": i})
                for i in range(b)
            ]

        if m == "vat":
            rstar = res.rstar
        elif m == "ivat":
            rstar = res[0].rstar
        elif m == "svat":
            rstar = res.vat.rstar
        elif m == "bigvat":
            rstar = res.sample.vat.rstar
        else:  # dvat: ordering only — score a maximin-sample image
            Xj = jnp.asarray(np.asarray(self._X, np.float32))
            sub = core.svat(Xj, key, s=min(self.sample_size, len(Xj)))
            rstar = sub.vat.rstar

        return self._assess_one(rstar, self._X, key,
                                {"method": m, "n": int(self._X.shape[0])})


def assess_tendency(X, **kwargs) -> dict:
    """One-shot convenience: FastVAT(**kwargs).fit(X).assess()."""
    return FastVAT(**kwargs).fit(X).assess()
