from repro.optim.adamw import (OptState, adamw_init, adamw_update,
                               adafactor_init, adafactor_update, init_opt,
                               apply_opt, clip_by_global_norm, cosine_lr)
from repro.optim.compression import EFState, ef_init, compress
