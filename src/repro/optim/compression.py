"""Error-feedback top-k gradient compression for the DP axis.

At 1000+-node scale the data-parallel all-reduce of dense gradients can
dominate step time for fat-embedding models.  EF-top-k keeps only the
largest `frac` fraction of each gradient tensor (by magnitude), carries
the residual forward (error feedback guarantees convergence), and lets
the all-reduce move ~frac of the bytes.

In the SPMD/jit world the "compression" is expressed as sparsification
*before* the pseudo-all-reduce (the mean over the DP axis happens inside
jit); the bytes saving is realized on real multi-host meshes where the
gradient tensors are sharded over `data` — we verify semantics (masking +
error feedback) here and count collective bytes in the roofline.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: Any  # same-structure tree of carried-forward error


def ef_init(params) -> EFState:
    return EFState(residual=jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def _topk_mask(x: jax.Array, frac: float) -> jax.Array:
    k = max(1, int(frac * x.size))
    flat = jnp.abs(x.reshape(-1))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(x) >= thresh).astype(x.dtype)


def compress(grads, ef: EFState, frac: float):
    """Returns (sparse grads to all-reduce, new EF state)."""
    def one(g, r):
        acc = g.astype(jnp.float32) + r
        if acc.ndim < 2:          # don't sparsify norms/biases
            return acc, jnp.zeros_like(acc)
        mask = _topk_mask(acc, frac)
        sent = acc * mask
        return sent, acc - sent

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(ef.residual)
    res = [one(g, r) for g, r in zip(flat_g, flat_r)]
    sent = tdef.unflatten([r[0] for r in res])
    new_r = tdef.unflatten([r[1] for r in res])
    return sent, EFState(residual=new_r)
