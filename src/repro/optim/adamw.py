"""Optimizers: AdamW and Adafactor(-style factored second moment).

Self-contained (no optax dependency).  Adafactor is the memory play for
the 671B config: first moment in bf16, second moment factored into row/col
statistics — O(d_in + d_out) instead of O(d_in * d_out) per matrix.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class OptState(NamedTuple):
    step: jax.Array
    m: Any        # first moment (adamw: f32 tree; adafactor: bf16 tree)
    v: Any        # second moment (adamw: f32 tree; adafactor: factored)


def cosine_lr(tc: TrainConfig, step):
    warm = jnp.minimum(step / jnp.maximum(tc.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - tc.warmup_steps)
                    / jnp.maximum(tc.total_steps - tc.warmup_steps, 1), 0.0, 1.0)
    return tc.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gn


# ------------------------------------------------------------- AdamW ----

def adamw_init(params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(step=jnp.zeros((), jnp.int32),
                    m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params))


def adamw_update(tc: TrainConfig, params, grads, st: OptState):
    step = st.step + 1
    lr = cosine_lr(tc, step)
    b1, b2 = tc.b1, tc.b2

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * gf * gf
        mhat = m2 / (1 - b1 ** step)
        vhat = v2 / (1 - b2 ** step)
        delta = mhat / (jnp.sqrt(vhat) + 1e-8)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + tc.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(st.m)
    flat_v = tdef.flatten_up_to(st.v)
    res = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([r[0] for r in res])
    new_m = tdef.unflatten([r[1] for r in res])
    new_v = tdef.unflatten([r[2] for r in res])
    return new_p, OptState(step=step, m=new_m, v=new_v)


# --------------------------------------------------------- Adafactor ----

def adafactor_init(params, *, momentum: bool = True) -> OptState:
    def m_init(p):
        return jnp.zeros(p.shape, jnp.bfloat16)

    def v_init(p):
        if p.ndim >= 2:
            return (jnp.zeros(p.shape[:-1], jnp.float32),        # row stats
                    jnp.zeros((*p.shape[:-2], p.shape[-1]), jnp.float32))
        return jnp.zeros(p.shape, jnp.float32)

    return OptState(step=jnp.zeros((), jnp.int32),
                    m=jax.tree.map(m_init, params) if momentum else None,
                    v=jax.tree.map(v_init, params,
                                   is_leaf=lambda x: isinstance(x, jax.Array)))


def adafactor_update(tc: TrainConfig, params, grads, st: OptState):
    step = st.step + 1
    lr = cosine_lr(tc, step)
    b2 = 1.0 - step.astype(jnp.float32) ** -0.8  # Shazeer-Stern decay

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        g2 = gf * gf + 1e-30
        if p.ndim >= 2:
            vr, vc = v
            vr2 = b2 * vr + (1 - b2) * jnp.mean(g2, axis=-1)
            vc2 = b2 * vc + (1 - b2) * jnp.mean(g2, axis=-2)
            denom = (vr2[..., None] * vc2[..., None, :]
                     / (jnp.mean(vr2, axis=-1, keepdims=True)[..., None] + 1e-30))
            u = gf * jax.lax.rsqrt(denom + 1e-30)
            v2 = (vr2, vc2)
        else:
            v2 = b2 * v + (1 - b2) * g2
            u = gf * jax.lax.rsqrt(v2 + 1e-30)
        # update clipping (RMS <= 1)
        rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms)
        if m is None:                 # momentum-free (Shazeer-Stern) mode
            m2, delta = None, u
        else:
            m2 = (tc.b1 * m.astype(jnp.float32) + (1 - tc.b1) * u)
            delta = m2
            m2 = m2.astype(jnp.bfloat16)
        if p.ndim >= 2:
            delta = delta + tc.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m2, v2)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = ([None] * len(flat_p) if st.m is None
              else tdef.flatten_up_to(st.m))
    flat_v = tdef.flatten_up_to(st.v)
    res = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([r[0] for r in res])
    new_m = None if st.m is None else tdef.unflatten([r[1] for r in res])
    new_v = tdef.unflatten([r[2] for r in res])
    return new_p, OptState(step=step, m=new_m, v=new_v)


def init_opt(tc: TrainConfig, params) -> OptState:
    if tc.optimizer == "adamw":
        return adamw_init(params)
    return adafactor_init(params, momentum=tc.b1 > 0.0)


def apply_opt(tc: TrainConfig, params, grads, st: OptState):
    if tc.optimizer == "adamw":
        return adamw_update(tc, params, grads, st)
    return adafactor_update(tc, params, grads, st)
