"""Fault-tolerant checkpointing: atomic, step-tagged, mesh-elastic.

* Atomic: write to ``<dir>/tmp.<step>`` then ``os.replace`` to
  ``step_<n>`` — a crash mid-write never corrupts the latest checkpoint.
* Step-tagged with retention of the last `keep` checkpoints.
* Mesh-elastic: tensors are saved *unsharded* (gathered logical arrays),
  so a restart may load onto a different mesh/topology and re-shard.
* Self-describing: the pytree structure is stored as a flattened
  path->array npz plus a small JSON manifest (step, rng, config digest).
"""
from __future__ import annotations

import json
import os
import shutil
import warnings
import zipfile
from typing import Any

import jax
import numpy as np

from repro import faults


class CorruptSidecar(RuntimeError):
    """An aux sidecar exists but cannot be read (truncated/corrupt zip).

    ``load_aux`` raises this only under ``strict=True``; the default
    policy is recover-and-warn (return None), because a torn sidecar
    must never abort a training resume — the weights checkpoint itself
    is still valid (ISSUE 9 recovery policy, docs/robustness.md).
    """


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":      # npz can't store bf16;
            arr = arr.astype(np.float32)      # f32 upcast is lossless
        flat[path] = arr
    return flat


def save(ckpt_dir: str, step: int, tree: Any, *, extra: dict | None = None,
         aux_arrays: dict[str, dict[str, np.ndarray]] | None = None,
         keep: int = 3) -> str:
    """Atomically publish one checkpoint step.

    `aux_arrays` maps sidecar names to flat array dicts (e.g. the
    monitor's `{"tendency_history": {...}}`); each is written as
    ``<name>.npz`` inside the step directory *before* the atomic
    publish, so weights and sidecars commit — and are garbage-collected
    — together.
    """
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp.{step}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "arrays.npz"), **_flatten(tree))
    for name, arrays in (aux_arrays or {}).items():
        aux_path = os.path.join(tmp, f"{name}.npz")
        np.savez(aux_path, **arrays)
        # fault-injection site: chaos tests corrupt/truncate the sidecar
        # file through the real write path (disarmed: a no-op)
        faults.fault_point("ckpt.aux_write", path=aux_path,
                           context={"name": name, "step": step})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, **(extra or {})}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)                      # atomic publish
    _gc(ckpt_dir, keep)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, template: Any, step: int | None = None):
    """Load into the structure of `template` (shapes/dtypes preserved).

    Returns (tree, manifest) or (None, None) when no checkpoint exists.
    """
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        return None, None
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    leaves_paths = jax.tree_util.tree_flatten_with_path(template)
    new_leaves = []
    for kp, leaf in leaves_paths[0]:
        p = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        arr = data[p]
        assert arr.shape == leaf.shape, f"{p}: ckpt {arr.shape} != {leaf.shape}"
        new_leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(leaves_paths[1], new_leaves), manifest


def load_aux(ckpt_dir: str, name: str, step: int | None = None, *,
             strict: bool = False) -> dict[str, np.ndarray] | None:
    """Load a sidecar ``<name>.npz`` saved via `save(aux_arrays=...)`.

    Returns the arrays dict, or None when the checkpoint (or the
    sidecar) doesn't exist — older checkpoints without the sidecar
    restore cleanly.

    An *unreadable* sidecar (truncated file, torn zip directory, a
    member that fails CRC) is recovered per the ISSUE 9 policy: by
    default it warns and returns None — the caller resumes as if the
    sidecar were missing, because the weights checkpoint is still good.
    Readable members of a partially-torn archive are salvaged and
    returned (per-row verification downstream decides how much of them
    to trust).  ``strict=True`` raises :class:`CorruptSidecar` instead.
    """
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        return None
    path = os.path.join(ckpt_dir, f"step_{step:08d}", f"{name}.npz")
    if not os.path.exists(path):
        return None
    try:
        # fault-injection site: chaos tests model read failures (raise)
        # or corrupt the file in place just before the real read
        faults.fault_point("ckpt.aux_read", path=path,
                           context={"name": name, "step": step})
        with np.load(path, allow_pickle=False) as data:
            out = {}
            for k in data.files:
                out[k] = data[k]      # per-member read may hit a bad CRC
            return out
    except Exception as exc:  # noqa: BLE001 — torn zip/CRC/pickle refuse
        if strict:
            raise CorruptSidecar(
                f"sidecar {path} is unreadable: {exc!r}") from exc
        warnings.warn(f"[ckpt] sidecar {name!r} at step {step} is "
                      f"unreadable ({exc!r}); resuming without it",
                      RuntimeWarning, stacklevel=2)
        return None


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                   if d.startswith("step_"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
