"""Fault-tolerant checkpointing: atomic, step-tagged, mesh-elastic.

* Atomic: write to ``<dir>/tmp.<step>`` then ``os.replace`` to
  ``step_<n>`` — a crash mid-write never corrupts the latest checkpoint.
* Step-tagged with retention of the last `keep` checkpoints.
* Mesh-elastic: tensors are saved *unsharded* (gathered logical arrays),
  so a restart may load onto a different mesh/topology and re-shard.
* Self-describing: the pytree structure is stored as a flattened
  path->array npz plus a small JSON manifest (step, rng, config digest).
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":      # npz can't store bf16;
            arr = arr.astype(np.float32)      # f32 upcast is lossless
        flat[path] = arr
    return flat


def save(ckpt_dir: str, step: int, tree: Any, *, extra: dict | None = None,
         aux_arrays: dict[str, dict[str, np.ndarray]] | None = None,
         keep: int = 3) -> str:
    """Atomically publish one checkpoint step.

    `aux_arrays` maps sidecar names to flat array dicts (e.g. the
    monitor's `{"tendency_history": {...}}`); each is written as
    ``<name>.npz`` inside the step directory *before* the atomic
    publish, so weights and sidecars commit — and are garbage-collected
    — together.
    """
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp.{step}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "arrays.npz"), **_flatten(tree))
    for name, arrays in (aux_arrays or {}).items():
        np.savez(os.path.join(tmp, f"{name}.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, **(extra or {})}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)                      # atomic publish
    _gc(ckpt_dir, keep)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, template: Any, step: int | None = None):
    """Load into the structure of `template` (shapes/dtypes preserved).

    Returns (tree, manifest) or (None, None) when no checkpoint exists.
    """
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        return None, None
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    leaves_paths = jax.tree_util.tree_flatten_with_path(template)
    new_leaves = []
    for kp, leaf in leaves_paths[0]:
        p = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        arr = data[p]
        assert arr.shape == leaf.shape, f"{p}: ckpt {arr.shape} != {leaf.shape}"
        new_leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(leaves_paths[1], new_leaves), manifest


def load_aux(ckpt_dir: str, name: str,
             step: int | None = None) -> dict[str, np.ndarray] | None:
    """Load a sidecar ``<name>.npz`` saved via `save(aux_arrays=...)`.

    Returns the arrays dict, or None when the checkpoint (or the
    sidecar) doesn't exist — older checkpoints without the sidecar
    restore cleanly.
    """
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        return None
    path = os.path.join(ckpt_dir, f"step_{step:08d}", f"{name}.npz")
    if not os.path.exists(path):
        return None
    with np.load(path, allow_pickle=False) as data:
        return {k: data[k] for k in data.files}


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                   if d.startswith("step_"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
