"""Roofline analysis: three terms per (arch x shape x mesh) cell.

Sources of truth and their roles:

* ``compiled.memory_analysis()``  — peak per-device bytes (proves fit).
  Correct across loops (buffer assignment is whole-program).
* ``compiled.cost_analysis()`` + HLO collective census — *structural*
  validation: which collectives, how many per loop body, per-body flops.
  XLA counts while-loop bodies ONCE (verified empirically), so these
  cannot be the roofline numerators for scanned models.
* **Analytic workload model (this file)** — FLOPs / HBM bytes /
  collective bytes per step from the architecture + shape + sharding
  scheme, with formulas documented inline.  These are the roofline
  numerators; the HLO census validates the collective *pattern* and the
  scan-body costs validate per-layer magnitudes.

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (1-link-bottleneck convention, conservative).
"""
from __future__ import annotations

import json
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, cells, get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import model as M

PEAK_FLOPS = 197e12     # bf16 / chip
HBM_BW = 819e9          # bytes/s / chip
ICI_BW = 50e9           # bytes/s / link


# ------------------------------------------------------- param census ----

def param_census(cfg: ModelConfig) -> dict:
    """Exact parameter counts from the real init tree (eval_shape only)."""
    tree = jax.eval_shape(
        lambda: M.init_params(cfg, jax.random.PRNGKey(0), jnp.bfloat16))
    total = expert = embed = 0
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        n = leaf.size
        total += n
        leafname = path.split("/")[-1]
        if leafname.startswith("e_"):
            expert += n
        if leafname in ("embed", "lm_head"):
            embed += n
    routed_frac = cfg.top_k / cfg.n_experts if cfg.n_experts else 1.0
    active = total - int(expert * (1.0 - routed_frac))
    return {"total": total, "active": active, "expert": expert,
            "embed": embed, "active_nonembed": active - embed}


# ---------------------------------------------------- workload model -----

def _attn_layers(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.attn_every
    if cfg.family == "ssm":
        return 0
    if cfg.family == "audio":
        return cfg.n_layers + cfg.n_enc_layers  # + cross handled separately
    return cfg.n_layers


def analytic_flops(cfg: ModelConfig, shape: ShapeConfig, *,
                   remat: bool = True) -> dict:
    """Global FLOPs per step.

    train: matmul params contribute 2 (fwd) + 4 (bwd) + 2 (remat recompute)
    FLOPs per param per token; quadratic attention adds
    2*B*S^2*H*hd per layer fwd (causal halves the S^2 matmuls).
    decode: 2 FLOPs per active matmul param per token + KV-cache reads.
    """
    c = param_census(cfg)
    B, S = shape.global_batch, shape.seq_len
    Hhd = cfg.n_heads * cfg.head_dim
    if cfg.use_mla:
        Hhd = cfg.n_heads * (cfg.qk_nope_dim + cfg.qk_rope_dim)
    La = _attn_layers(cfg)

    if shape.kind == "decode":
        tokens = B
        matmul = 2.0 * c["active_nonembed"] + 2.0 * cfg.d_model * cfg.vocab
        flops = matmul * tokens
        if cfg.use_mla:  # absorbed decode: latent-space scores + context
            lat = cfg.kv_lora_rank + cfg.qk_rope_dim
            flops += 4.0 * B * cfg.n_heads * lat * S * La
        else:
            flops += 4.0 * B * cfg.n_kv_heads * cfg.head_dim * S * La \
                * (cfg.n_heads // max(cfg.n_kv_heads, 1))
        model_flops = 2.0 * c["active"] * tokens
        return {"total": flops, "model": model_flops, "tokens": tokens}

    tokens = B * S
    if shape.kind == "train":
        f = 8.0 if remat else 6.0    # per-param-per-token matmul factor
        tf = 4.0 if remat else 3.0   # multiples of one fwd pass
    else:                            # prefill: forward only
        f, tf = 2.0, 1.0
    matmul = c["active_nonembed"] + cfg.d_model * cfg.vocab
    flops = f * matmul * tokens

    def quad_term(Sq, Sk, layers, causal):
        fwd = 4.0 * B * Sq * Sk * Hhd * (0.5 if causal else 1.0)
        return tf * fwd * layers

    if cfg.family == "audio":
        quad = (quad_term(cfg.enc_seq, cfg.enc_seq, cfg.n_enc_layers, False)
                + quad_term(S, S, cfg.n_layers, True)
                + quad_term(S, cfg.enc_seq, cfg.n_layers, False))
    elif cfg.family == "ssm":
        # rwkv recurrence: ~6 flops per (head-channel x N) per token
        quad = tf / 3.0 * 6.0 * tokens * cfg.d_model * cfg.rwkv_head_dim \
            * cfg.n_layers
    else:
        quad = quad_term(S, S, La, True)
        if cfg.family == "hybrid":
            inner, P = cfg.ssm_expand * cfg.d_model, cfg.ssm_head_dim
            N, Lc = cfg.ssm_state, cfg.ssm_chunk
            Hm = inner // P
            n_mamba = cfg.n_layers - La
            # SSD fwd: intra-chunk (Lc*N + Lc*Hm*P) + state in/out (8*N*Hm*P)
            per_tok = 2 * (Lc * N + Lc * Hm * P) + 8 * N * Hm * P
            quad += tf / 3.0 * per_tok * tokens * n_mamba
    flops += quad
    model_flops = (6.0 if shape.kind == "train" else 2.0) * c["active"] * tokens
    return {"total": flops, "model": model_flops, "tokens": tokens}


def analytic_hbm_bytes(cfg: ModelConfig, shape: ShapeConfig, mesh_shape: dict,
                       *, remat: bool = True, ctx_shard: bool = True) -> float:
    """Per-device HBM traffic per step (documented approximation).

    train: gathered weights stream through HBM twice (fwd + bwd recompute
    pass), optimizer state read+write in f32-equivalents, activations ~12
    passes of the (B_loc, S_loc, D) residual per layer.
    decode: active weight shard once + local KV/state cache once.
    """
    c = param_census(cfg)
    devs = 1
    for v in mesh_shape.values():
        devs *= v
    model = mesh_shape.get("model", 1)
    B, S = shape.global_batch, shape.seq_len
    D = cfg.d_model
    L = cfg.n_layers

    if shape.kind == "decode":
        wbytes = 2 * c["active"] / devs * max(model, 1)  # TP shard per device
        cache = jax.eval_shape(lambda: M.init_cache(cfg, B, S, jnp.bfloat16))
        cbytes = sum(l.size * l.dtype.itemsize
                     for l in jax.tree.leaves(cache)) / devs
        if cfg.n_kv_heads % model != 0 and not ctx_shard:
            # heads can't split over `model` and the cache isn't context-
            # sharded: every model rank re-reads a replicated cache
            cbytes *= model
        return wbytes + cbytes

    train = shape.kind == "train"
    wbytes = 2 * c["total"] / model * (2 if train else 1)  # gathered passes
    opt = 12 * c["total"] / devs if train else 0.0  # m,v,p f32 read+write
    b_loc = max(B // (devs // model), 1)
    s_loc = S / model if cfg.seq_shard else S
    act = (12 if train else 6) * L * b_loc * s_loc * D * 2
    return wbytes + opt + act


def analytic_collective_bytes(cfg: ModelConfig, shape: ShapeConfig,
                              mesh_shape: dict, *,
                              ep2d: bool = False) -> dict:
    """Per-device collective bytes per step, by purpose.

    ep2d: experts distributed over model x data (no FSDP gather of expert
    weights; tokens move via all-to-all instead — which MoE dispatch does
    in *both* modes, so the a2a term is always counted).
    """
    c = param_census(cfg)
    d = mesh_shape.get("data", 1)
    m = mesh_shape.get("model", 1)
    p = mesh_shape.get("pod", 1)
    devs = d * m * p
    B, S = shape.global_batch, shape.seq_len
    D, L = cfg.d_model, cfg.n_layers
    dp = d * p

    if shape.kind == "decode":
        b_loc = max(B // dp, 1)
        tp = 2 * L * b_loc * D * 2               # per-layer TP all-reduce
        a2a = (2 * L * cfg.top_k * b_loc * D * 2) if cfg.n_experts else 0.0
        return {"tp": tp, "fsdp": 0.0, "dp_grad": 0.0, "a2a": a2a,
                "total": tp + a2a}

    train = shape.kind == "train"
    passes = 3 if train else 1
    # FSDP: gather weights over `data` (fwd [+ bwd recompute]), RS grads.
    # Under 2-D EP the expert stack is never gathered.
    gathered = c["total"] - (c["expert"] if ep2d else 0)
    fsdp = passes * (2 * gathered / m) * (d - 1) / d
    # DP gradient all-reduce over `pod`
    dp_grad = (2 * (2 * c["total"] / (m * d)) * (p - 1) / p) \
        if (p > 1 and train) else 0.0
    # TP activation collectives: ~4 per layer per pass of the local residual
    b_loc = max(B // dp, 1)
    tp = (8 if train else 4) * L * b_loc * S * D * 2 / m
    # MoE dispatch/combine all-to-all: top_k entries per token per layer,
    # each direction, every pass
    a2a = 0.0
    if cfg.n_experts:
        tok_per_dev = B * S / devs
        a2a = passes * 2 * L * cfg.top_k * tok_per_dev * D * 2
        if cfg.route_groups > 1:
            # group-limited routing confines dispatch to top_g/g of the
            # mesh; per-link traffic scales with the reachable fraction
            a2a *= cfg.route_top_groups / cfg.route_groups
    total = fsdp + dp_grad + tp + a2a
    return {"tp": tp, "fsdp": fsdp, "dp_grad": dp_grad, "a2a": a2a,
            "total": total}


# ------------------------------------------------------------ report -----

@dataclass
class Cell:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float
    useful_ratio: float
    peak_gib: float
    hlo_collectives: dict
    note: str = ""


def analyze(rec: dict) -> Cell:
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    mesh_shape = ({"pod": 2, "data": 16, "model": 16}
                  if rec["mesh"] == "2x16x16" else {"data": 16, "model": 16})
    devs = rec["n_devices"]
    over = dict(rec.get("overrides", {}))
    ep2d = over.pop("ep2d", False)
    over.pop("momentum", None)
    remat = over.get("remat", "full") != "none" and shape.kind == "train"
    if shape.kind == "train":
        cfg = cfg.replace(seq_shard=True)
    cfg = cfg.replace(**{k: v for k, v in over.items()
                         if hasattr(cfg, k)})
    fl = analytic_flops(cfg, shape, remat=remat)
    # baseline records predate the context-sharded cache rule; perf
    # records (tagged "exp") ran with it
    hbm = analytic_hbm_bytes(cfg, shape, mesh_shape, remat=remat,
                             ctx_shard="exp" in rec)
    coll = analytic_collective_bytes(cfg, shape, mesh_shape, ep2d=ep2d)
    compute_s = fl["total"] / devs / PEAK_FLOPS
    memory_s = hbm / HBM_BW
    coll_s = coll["total"] / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    bn = max(terms, key=terms.get)
    return Cell(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        bottleneck=bn,
        model_flops=fl["model"],
        useful_ratio=fl["model"] / fl["total"],
        peak_gib=rec.get("peak_bytes", 0) / 2**30,
        hlo_collectives=rec.get("collectives", {}),
    )


def markdown_table(records: list[dict]) -> str:
    rows = ["| arch | shape | mesh | compute_s | memory_s | collective_s | "
            "bottleneck | useful | peak GiB | HLO collectives |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for rec in records:
        if not rec.get("ok"):
            rows.append(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | "
                        f"FAILED: {rec.get('error','?')} | | | | | | |")
            continue
        c = analyze(rec)
        hlo = ", ".join(f"{k.split('-')[0]}-{k.split('-')[1][:1]}x{v['count']}"
                        for k, v in sorted(c.hlo_collectives.items()))
        rows.append(
            f"| {c.arch} | {c.shape} | {c.mesh} | {c.compute_s:.3e} | "
            f"{c.memory_s:.3e} | {c.collective_s:.3e} | **{c.bottleneck}** | "
            f"{c.useful_ratio:.2f} | {c.peak_gib:.2f} | {hlo} |")
    return "\n".join(rows)


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="dryrun_results.json")
    args = ap.parse_args()
    records = json.load(open(args.results))
    print(markdown_table(records))


if __name__ == "__main__":
    main()
