"""Chaos driver: scripted fault schedules against a live TendencyServer.

The command-line twin of tests/test_resilience.py (ISSUE 9): each
scenario arms a deterministic fault schedule from ``repro.faults``,
drives the real serving stack on a virtual clock (injected ``clock`` +
``sleep`` — zero real waits), and asserts the EXACT
``ServeStats.resilience`` counter trajectory plus bitwise-correct
survivor results.  Any mismatch prints the expectation diff and exits
non-zero — CI runs this as the ``chaos`` job.

  PYTHONPATH=src python -m repro.launch.chaos --smoke
  PYTHONPATH=src python -m repro.launch.chaos --scenarios poison,breaker

Scenarios:

  poison     one poisoned lane of a 4-lane coalesced batch: batchmates
             bitwise-correct, the poison fails typed, split/retry
             counters pinned.
  fallback   a primary whose program build fails is served by the next
             rung down the fallback chain (error -> coarser result).
  breaker    repeated primary failures trip the breaker, the cooldown
             probe re-opens it, a healthy probe closes it.
  admission  non-finite / degenerate inputs are refused typed at
             submit, counted, and never reach a batch.
  numerics_trip  a bf16 request whose certification is fault-tripped
             degrades to f32 — counted, stamped on the report, and
             bitwise-equal to the solo f32 fit.
  disarmed   all faults disarmed: served results bitwise-equal solo
             fits and every resilience counter is zero.
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from repro import faults
from repro.api import FastVAT, InvalidInput
from repro.numerics import NumericsPolicy
from repro.serve import (BreakerConfig, ExecutionError, ResilienceStats,
                         RetryPolicy, ServeConfig, TendencyServer)


class _VirtualClock:
    """Monotonic clock the scenarios advance by hand (no real waits)."""

    def __init__(self):
        self._t = 0.0

    def __call__(self) -> float:
        return self._t

    def advance(self, dt: float) -> None:
        self._t += dt


def _blobs(n: int, d: int = 3, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    half = n // 2
    return np.concatenate([
        rng.normal(size=(half, d)),
        rng.normal(size=(n - half, d)) + 6.0]).astype(np.float32)


def _server(clock, **cfg) -> TendencyServer:
    cfg.setdefault("window_s", 999.0)     # flushes come from max_batch
    cfg.setdefault("retry", RetryPolicy(max_attempts=2, jitter=0.0))
    return TendencyServer(ServeConfig(**cfg), clock=clock,
                          sleep=lambda s: None)


def _solo(X: np.ndarray, method: str):
    return FastVAT(method=method).fit(X).result


def _same(a, b) -> bool:
    for f in ("order", "rstar", "ivat_image"):
        va, vb = getattr(a, f), getattr(b, f)
        if (va is None) != (vb is None):
            return False
        if va is not None and not np.array_equal(np.asarray(va),
                                                 np.asarray(vb)):
            return False
    return True


def _expect(problems: list, what: str, got, want) -> None:
    if got != want:
        problems.append(f"{what}: expected {want!r}, got {got!r}")


# ---------------------------------------------------------- scenarios ----

def scenario_poison(problems: list) -> None:
    srv = _server(_VirtualClock(), max_batch=4)
    try:
        faults.arm("serve.execute", times=-1,
                   match=lambda ctx: "poison" in ctx.get("tags", ()))
        data = {tag: _blobs(48, seed=i)
                for i, tag in enumerate(("a", "b", "poison", "c"))}
        futs = {tag: srv.submit(X, method="vat", tag=tag)
                for tag, X in data.items()}       # 4th submit flushes
        for tag in ("a", "b", "c"):
            served = futs[tag].result(timeout=300)
            if not _same(served, _solo(data[tag], "vat")):
                problems.append(f"survivor {tag!r} diverged from solo fit")
        try:
            futs["poison"].result(timeout=300)
            problems.append("poison lane produced a result; expected "
                            "ExecutionError")
        except ExecutionError as exc:
            if not isinstance(exc.__cause__, faults.FaultInjected):
                problems.append(f"poison cause: {exc.__cause__!r}")
        _expect(problems, "poison counters", srv.stats().resilience,
                ResilienceStats(splits=1, retries=2, failed=1))
    finally:
        srv.close()
        faults.disarm_all()


def scenario_fallback(problems: list) -> None:
    srv = _server(_VirtualClock(), max_batch=1)
    try:
        faults.arm("serve.build", times=-1,
                   match=lambda ctx: ctx.get("rung") == "ivat")
        X = _blobs(48)
        served = srv.submit(X, method="ivat").result(timeout=300)
        _expect(problems, "fallback rung", served.meta.method, "vat")
        if not _same(served, _solo(X, "vat")):
            problems.append("fallback result diverged from solo vat fit")
        _expect(problems, "fallback counters", srv.stats().resilience,
                ResilienceStats(fallbacks=1, retries=1, degraded=1))
    finally:
        srv.close()
        faults.disarm_all()


def scenario_breaker(problems: list) -> None:
    clock = _VirtualClock()
    srv = _server(clock, max_batch=1, retry=RetryPolicy(max_attempts=1),
                  breaker=BreakerConfig(threshold=2, cooldown_s=10.0))
    try:
        faults.arm("serve.build", times=-1,
                   match=lambda ctx: ctx.get("rung") == "ivat")
        X = _blobs(48)
        for _ in range(2):                        # trip: 2 primary fails
            srv.submit(X, method="ivat").result(timeout=300)
        _expect(problems, "tripped state",
                srv.breaker_state(48, 3, method="ivat"), "OPEN")
        built = faults.stats()["serve.build"]["fired"]
        srv.submit(X, method="ivat").result(timeout=300)  # pinned
        _expect(problems, "pinned primary attempts",
                faults.stats()["serve.build"]["fired"], built)
        clock.advance(10.0)
        srv.submit(X, method="ivat").result(timeout=300)  # probe, fails
        _expect(problems, "re-opened state",
                srv.breaker_state(48, 3, method="ivat"), "OPEN")
        faults.disarm("serve.build")              # "deploy the fix"
        clock.advance(10.0)
        served = srv.submit(X, method="ivat").result(timeout=300)
        _expect(problems, "recovered rung", served.meta.method, "ivat")
        _expect(problems, "recovered state",
                srv.breaker_state(48, 3, method="ivat"), "CLOSED")
        _expect(problems, "breaker counters", srv.stats().resilience,
                ResilienceStats(fallbacks=4, degraded=4, breaker_opens=2,
                                breaker_probes=2))
    finally:
        srv.close()
        faults.disarm_all()


def scenario_admission(problems: list) -> None:
    srv = _server(_VirtualClock(), max_batch=1)
    try:
        bad = _blobs(32)
        bad[0, 0] = np.nan
        for X, reason in ((bad, "non_finite"),
                          (np.ones((16, 3), np.float32), "degenerate")):
            try:
                srv.submit(X)
                problems.append(f"{reason} input was admitted")
            except InvalidInput as exc:
                _expect(problems, "admission reason", exc.reason, reason)
        _expect(problems, "admission counters", srv.stats().resilience,
                ResilienceStats(invalid_rejects=2))
    finally:
        srv.close()


def scenario_numerics_trip(problems: list) -> None:
    srv = _server(_VirtualClock(), max_batch=1,
                  numerics=NumericsPolicy(dtype="bf16"))
    try:
        offset = np.float32(1.0e4)          # conditions; then bf16-safe
        clean = srv.submit(_blobs(48) + offset,
                           method="vat").result(timeout=300)
        _expect(problems, "certified dtype",
                clean.meta.numerics.dtype, "bf16")
        _expect(problems, "certified fallbacks",
                clean.meta.numerics.fallbacks, 0)
        faults.arm("kernels.numerics_trip", times=1)
        X = _blobs(48, seed=1) + offset
        tripped = srv.submit(X, method="vat").result(timeout=300)
        rep = tripped.meta.numerics
        _expect(problems, "tripped dtype", rep.dtype, "f32")
        _expect(problems, "tripped fallbacks", rep.fallbacks, 1)
        _expect(problems, "tripped form", rep.form, "direct")
        # the degradation lands on the default f32 path: bitwise-equal
        # to the solo auto-policy fit of the same data
        if not _same(tripped, _solo(X, "vat")):
            problems.append("tripped bf16 result diverged from solo "
                            "f32 fit")
        _expect(problems, "numerics counters", srv.stats().resilience,
                ResilienceStats(numerics_fallbacks=1))
    finally:
        srv.close()
        faults.disarm_all()


def scenario_disarmed(problems: list) -> None:
    _expect(problems, "armed faults before disarmed run",
            faults.armed(), {})
    srv = _server(_VirtualClock(), max_batch=1)
    try:
        X = _blobs(48)
        served = srv.submit(X, method="vat").result(timeout=300)
        if not _same(served, _solo(X, "vat")):
            problems.append("disarmed served result diverged from solo fit")
        _expect(problems, "disarmed counters", srv.stats().resilience,
                ResilienceStats())
    finally:
        srv.close()


SCENARIOS = {
    "poison": scenario_poison,
    "fallback": scenario_fallback,
    "breaker": scenario_breaker,
    "admission": scenario_admission,
    "numerics_trip": scenario_numerics_trip,
    "disarmed": scenario_disarmed,
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="scripted fault schedules against the serving layer")
    ap.add_argument("--scenarios", default=",".join(SCENARIOS),
                    help=f"comma-separated subset of {tuple(SCENARIOS)}")
    ap.add_argument("--smoke", action="store_true",
                    help="accepted for CI symmetry; the schedules are "
                         "already CI-sized")
    args = ap.parse_args(argv)

    names = [s.strip() for s in args.scenarios.split(",") if s.strip()]
    if unknown := set(names) - set(SCENARIOS):
        ap.error(f"unknown scenarios {sorted(unknown)}; choose from "
                 f"{tuple(SCENARIOS)}")

    failed = 0
    for name in names:
        problems: list[str] = []
        SCENARIOS[name](problems)
        status = "PASS" if not problems else "FAIL"
        print(f"chaos/{name:<10s} {status}")
        for p in problems:
            print(f"    {p}", file=sys.stderr)
        failed += bool(problems)
    leftover = faults.armed()
    if leftover:
        print(f"chaos: faults left armed after run: {sorted(leftover)}",
              file=sys.stderr)
        faults.disarm_all()
        failed += 1
    print(f"chaos: {len(names) - failed}/{len(names)} scenarios clean")
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
