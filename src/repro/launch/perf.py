import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf hillclimb driver: run named optimization experiments on the three
chosen cells and append results (with overrides recorded) to a JSON.

Each experiment is hypothesis -> override set; the before row is the
baseline record from dryrun_results.json.  See EXPERIMENTS.md §Perf for
the hypothesis/result log.

Usage: python -m repro.launch.perf [--exp NAME ...] [--out perf_results.json]
"""
import argparse
import json
import traceback

# (name, arch, shape, multi_pod, overrides)
EXPERIMENTS = [
    # Cell A: deepseek train — collective-bound (FSDP gathers 675B of
    # expert weight per step).  A1: 2-D expert parallelism.
    ("A1_ep2d", "deepseek-v3-671b", "train_4k", True, {"ep2d": True}),
    # A2: + chunked CE (kill the (B,S,V) f32 logits peak; also MTP head)
    ("A2_ep2d_cechunk", "deepseek-v3-671b", "train_4k", True,
     {"ep2d": True, "ce_chunk": 512}),
    # A3: + bf16-dots remat policy instead of full remat (trade a little
    # activation memory for 25% fewer recompute FLOPs)
    ("A3_ep2d_cechunk_dots", "deepseek-v3-671b", "train_4k", True,
     {"ep2d": True, "ce_chunk": 512, "remat": "dots"}),
    # single-pod variants for the roofline table
    ("A2_sp", "deepseek-v3-671b", "train_4k", False,
     {"ep2d": True, "ce_chunk": 512}),
    # A4: + precise factored-stat sharding (shardspecs fix) and
    # momentum-free adafactor (Shazeer-Stern): optimizer state per device
    # drops from ~17 GB to ~7 GB
    ("A4_ep2d_cechunk_nomom", "deepseek-v3-671b", "train_4k", True,
     {"ep2d": True, "ce_chunk": 512, "momentum": False}),
    ("A4_sp", "deepseek-v3-671b", "train_4k", False,
     {"ep2d": True, "ce_chunk": 512, "momentum": False}),
    # A5: + DeepSeek group-limited routing (8 groups, top-4): dispatch
    # traffic confined to half the mesh -> a2a per-link bytes halve
    ("A5_ep2d_groups", "deepseek-v3-671b", "train_4k", True,
     {"ep2d": True, "ce_chunk": 512, "momentum": False,
      "route_groups": 8, "route_top_groups": 4}),

    # Cell B: whisper decode — memory-bound at 52 GiB because 20 KV heads
    # can't shard over the 16-way model axis.  B1: context-shard the cache
    # over `model` (shardspecs rule) — already active, re-measure;
    # B2: + vocab padding so the 51866-row embed/logits TP-shards.
    ("B1_ctx_shard", "whisper-large-v3", "decode_32k", False, {}),
    ("B2_ctx_vpad", "whisper-large-v3", "decode_32k", False,
     {"vocab_pad": 256}),

    # Cell C: internvl prefill — 39 GiB peak is replicated fat-vocab
    # logits (151655 unshardable).  C1: vocab padding.
    ("C1_vpad", "internvl2-1b", "prefill_32k", False, {"vocab_pad": 256}),
    # C2: + last-token-only logits would be serving-specific; instead
    # measure the train cell with chunked CE (same logits pressure).
    ("C2_train_cechunk", "internvl2-1b", "train_4k", False,
     {"ce_chunk": 512, "vocab_pad": 256}),

    # B3: head padding (20 -> 32 heads, padded heads masked so the arch
    # function is exactly preserved): attention/KV shard 16-way instead of
    # replicating; applies to MHA archs (whisper)
    ("B3_head_pad", "whisper-large-v3", "decode_32k", False,
     {"vocab_pad": 256, "head_pad": 32}),
    ("B3_train", "whisper-large-v3", "train_4k", False,
     {"vocab_pad": 256, "head_pad": 32}),
]


def main() -> None:
    from repro.launch.dryrun import run_cell

    ap = argparse.ArgumentParser()
    ap.add_argument("--exp", nargs="*", default=None)
    ap.add_argument("--out", default="perf_results.json")
    args = ap.parse_args()

    results = []
    if os.path.exists(args.out):
        results = json.load(open(args.out))
    done = {r["exp"] for r in results if r.get("ok")}

    for name, arch, shape, mp, over in EXPERIMENTS:
        if args.exp and name not in args.exp:
            continue
        if name in done:
            print(f"[skip] {name} (cached)")
            continue
        print(f"[perf] {name}: {arch} {shape} "
              f"{'2x16x16' if mp else '16x16'} {over}", flush=True)
        try:
            rec = run_cell(arch, shape, multi_pod=mp, overrides=over)
            rec["exp"] = name
            print(f"  ok: flops/dev={rec['flops_per_device']:.3e} "
                  f"peak={rec['peak_bytes']/2**30:.2f}GiB "
                  f"compile={rec['compile_s']}s", flush=True)
        except Exception as e:  # noqa: BLE001
            rec = {"exp": name, "arch": arch, "shape": shape, "ok": False,
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
            print(f"  FAIL: {rec['error']}", flush=True)
        results.append(rec)
        json.dump(results, open(args.out, "w"), indent=1)


if __name__ == "__main__":
    main()
