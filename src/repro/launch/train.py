"""Training launcher: mesh-aware entry point for real runs.

On this CPU container it drives the host mesh (1 device); on a pod the
same script shards over whatever `jax.devices()` reports — the launcher
only picks the mesh, `train_step` is identical to the dry-run one.

  PYTHONPATH=src python -m repro.launch.train --arch gemma-2b \
      --steps 100 --batch 8 --seq 128 [--smoke] [--model-axis 1]
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import ARCHS, get_config, smoke_config
from repro.configs.base import ShapeConfig, TrainConfig
from repro.launch.mesh import make_host_mesh
from repro.models import sharding
from repro.train.loop import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-friendly)")
    ap.add_argument("--model-axis", type=int, default=1,
                    help="TP width of the host mesh")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--deadline", type=float, default=0.0,
                    help="per-step data deadline in seconds (straggler)")
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh(model=args.model_axis)
    if mesh.devices.size > 1:
        sharding.set_mesh(mesh)
    tc = TrainConfig(lr=args.lr, total_steps=args.steps,
                     ckpt_dir=args.ckpt_dir,
                     compress_grads=args.compress_grads)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")

    with mesh:
        state, hist = train(cfg, tc, shape,
                            step_deadline_s=args.deadline)
    print(f"final loss {hist[-1]['loss']:.4f} over {len(hist)} steps "
          f"on {mesh.devices.size} device(s)")


if __name__ == "__main__":
    main()
