"""Input/state sharding specs for the launchers (train + serve)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.sharding import param_shardings


def _dp(mesh: Mesh) -> tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def batch_shardings(cfg: ModelConfig, mesh: Mesh, specs: dict) -> dict:
    """Shardings for the input batch dict (tokens/labels/patches/frames)."""
    dp = _dp(mesh)
    dpsize = 1
    for a in dp:
        dpsize *= _sizes(mesh)[a]

    out = {}
    for name, sds in specs.items():
        b = sds.shape[0]
        lead = dp if b % dpsize == 0 else None
        out[name] = NamedSharding(mesh, P(lead, *([None] * (sds.ndim - 1))))
    return out


def _recheck(spec, shape, mesh: Mesh) -> NamedSharding:
    """Divisibility-validate a raw spec list against a concrete shape."""
    sizes = _sizes(mesh)
    ok = []
    for dim, s in enumerate(list(spec)[:len(shape)]):
        names = (s,) if isinstance(s, str) else tuple(s or ())
        total = 1
        for nm in names:
            total *= sizes.get(nm, 1)
        ok.append(s if total and shape[dim] % total == 0 else None)
    ok += [None] * (len(shape) - len(ok))
    return NamedSharding(mesh, P(*ok))


def state_shardings(state, mesh: Mesh):
    """TrainState shardings.

    params / first moment reuse the param rules directly.  Adafactor's
    factored second moment derives from the param spec by *dropping the
    reduced dim*: vr (row stats, mean over last dim) keeps spec[:-1];
    vc (col stats, mean over dim -2) keeps spec[:-2] + spec[-1].  This is
    what keeps the 61x256-expert stat tensors sharded over the expert dim
    instead of replicating hundreds of GB.
    """
    params = state.params
    p_sh = param_shardings(params, mesh)
    flat_psh, tdef = jax.tree.flatten(p_sh)
    flat_p = tdef.flatten_up_to(params)

    def like_params(tree):
        flat_t = tdef.flatten_up_to(tree)
        out = []
        for sh, t in zip(flat_psh, flat_t):
            if isinstance(t, tuple):            # factored (vr, vc)
                spec = list(sh.spec)
                vr = _recheck(spec[:-1], t[0].shape, mesh)
                vc = _recheck(spec[:-2] + [spec[-1]], t[1].shape, mesh)
                out.append((vr, vc))
            else:
                out.append(_recheck(list(sh.spec), t.shape, mesh))
        return tdef.unflatten(out)

    from repro.train.steps import TrainState
    from repro.optim.adamw import OptState
    opt = state.opt
    return TrainState(
        params=p_sh,
        opt=OptState(step=NamedSharding(mesh, P()),
                     m=None if opt.m is None else like_params(opt.m),
                     v=like_params(opt.v)),
        ef=None if state.ef is None else type(state.ef)(
            residual=like_params(state.ef.residual)))


def cache_shardings(cfg: ModelConfig, mesh: Mesh, cache, batch: int,
                    max_len: int):
    """Decode-cache shardings.

    Rules (by dim size, per leaf): the batch dim shards over the DP axes
    when divisible; KV/state head dims shard over `model` when divisible;
    if batch cannot shard (long_500k: B=1), the max_len dim shards over
    `data` instead (context-sharded cache).
    """
    dp = _dp(mesh)
    sizes = _sizes(mesh)
    dpsize = 1
    for a in dp:
        dpsize *= sizes[a]
    m = sizes.get("model", 1)
    d = sizes.get("data", 1)
    batch_ok = batch % dpsize == 0
    head_sizes = {cfg.eff_kv_heads, cfg.eff_heads}
    if cfg.family in ("hybrid",):
        head_sizes.add(cfg.ssm_expand * cfg.d_model // cfg.ssm_head_dim)
    if cfg.family == "ssm":
        head_sizes.add(cfg.d_model // cfg.rwkv_head_dim)

    heads_shardable = any(h % m == 0 for h in head_sizes)

    def one(leaf):
        spec = []
        used_batch = used_seq = used_head = False
        for dim in leaf.shape:
            if dim == batch and not used_batch:
                spec.append(dp if batch_ok else None)
                used_batch = True
            elif dim == max_len and not used_seq and not batch_ok:
                spec.append("data" if dim % d == 0 else None)
                used_seq = True
            elif (dim == max_len and not used_seq and not heads_shardable
                  and dim % m == 0):
                # context sharding: heads can't split over `model` (e.g.
                # whisper's 20 heads on a 16-way axis) — shard the KV
                # sequence dim there instead, so the cache doesn't
                # replicate 16x per device
                spec.append("model")
                used_seq = True
            elif dim in head_sizes and not used_head and dim % m == 0:
                spec.append("model")
                used_head = True
            else:
                spec.append(None)
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, cache)
