"""Serving launcher: synthetic concurrent load against TendencyServer.

Drives the real serving path (ISSUE 7) — warm the AOT program cache,
fire ``--requests`` fits from ``--concurrency`` client threads, and
report the latency distribution (p50/p99), throughput, and scheduler
counters (coalesce rate, cache hits/misses/evictions, timeouts).  This
is the command-line twin of the bench "serve" table, sized for quick
interactive runs:

  PYTHONPATH=src python -m repro.launch.serve --smoke
  PYTHONPATH=src python -m repro.launch.serve --requests 64 \
      --concurrency 8 --sizes 90,120,200 --window-ms 5 --slo-ms 50
"""
from __future__ import annotations

import argparse
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.serve import ServeConfig, TendencyServer


def _datasets(sizes: list[int], count: int, d: int, seed: int):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(count):
        n = sizes[i % len(sizes)]
        half = n // 2
        out.append(np.concatenate([
            rng.normal(size=(half, d)),
            rng.normal(size=(n - half, d)) + 7.0,
        ]).astype(np.float32))
    return out


def _pct(values: list[float], q: float) -> float:
    return float(np.percentile(np.asarray(values), q))


def main() -> None:
    ap = argparse.ArgumentParser(
        description="concurrent-load driver for the tendency server")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--concurrency", type=int, default=8)
    ap.add_argument("--window-ms", type=float, default=2.0)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--sizes", default="90,120,200",
                    help="comma-separated per-request point counts")
    ap.add_argument("--dim", type=int, default=4)
    ap.add_argument("--metric", default="euclidean")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="route through the cost-model router under "
                         "this latency budget")
    ap.add_argument("--timeout-s", type=float, default=120.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fixed workload (CI-sized)")
    args = ap.parse_args()

    if args.smoke:
        args.requests, args.concurrency = 16, 4
        args.sizes, args.window_ms = "48,60", 5.0

    sizes = [int(s) for s in args.sizes.split(",") if s]
    data = _datasets(sizes, args.requests, args.dim, args.seed)
    config = ServeConfig(window_s=args.window_ms / 1e3,
                         max_batch=args.max_batch)

    with TendencyServer(config) as server:
        for n in sizes:  # cold compiles out of the measured window —
            # warm the same key the requests resolve (incl. SLO
            # routing), at every lane bucket a coalesced group can form
            b = 1
            while b <= args.max_batch:
                server.warm(n, args.dim, metric=args.metric,
                            slo_ms=args.slo_ms, batch=b)
                b *= 2

        latencies: list[float] = []

        def one(X) -> float:
            t0 = time.perf_counter()
            server.fit(X, metric=args.metric, slo_ms=args.slo_ms,
                       timeout_s=args.timeout_s)
            return time.perf_counter() - t0

        t_wall = time.perf_counter()
        with ThreadPoolExecutor(max_workers=args.concurrency) as pool:
            latencies = list(pool.map(one, data))
        t_wall = time.perf_counter() - t_wall
        stats = server.stats()

    qps = args.requests / max(t_wall, 1e-9)
    print(f"{args.requests} requests x {args.concurrency} clients, "
          f"sizes {sizes}, window {args.window_ms:.1f} ms")
    print(f"latency p50 {1e3 * _pct(latencies, 50):.2f} ms   "
          f"p99 {1e3 * _pct(latencies, 99):.2f} ms   "
          f"throughput {qps:.1f} req/s")
    c = stats.cache
    print(f"batches {stats.dispatched_batches} "
          f"(coalesce rate {stats.coalesce_rate:.2f} req/batch)   "
          f"cache {c.hits} hits / {c.misses} misses / "
          f"{c.evictions} evictions   timeouts {stats.timeouts}   "
          f"rejected {stats.rejected}")


if __name__ == "__main__":
    main()
