"""Serving launcher: batched prefill + greedy decode loop.

Drives the real serving path (prefill fills the cache, decode_step
continues) with sVAT request-group diagnostics every --diag-every
batches.  Reduced configs make it runnable on CPU:

  PYTHONPATH=src python -m repro.launch.serve --arch phi3-mini-3.8b \
      --smoke --requests 8 --prompt-len 16 --gen 24
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro import core
from repro.configs import ARCHS, get_config, smoke_config
from repro.models import model as M


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    B, P, G = args.requests, args.prompt_len, args.gen
    prompts = rng.integers(1, cfg.vocab, (B, P)).astype(np.int32)

    batch = {"tokens": jnp.asarray(prompts)}
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_patches, cfg.d_model)), jnp.float32)
    if cfg.family == "audio":
        batch["enc_frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.enc_seq, cfg.d_model)), jnp.float32)

    max_len = P + G + (cfg.n_patches if cfg.family == "vlm" else 0)
    prefill = jax.jit(lambda p, b: M.prefill(p, cfg, b, max_len))
    decode = jax.jit(lambda p, t, c, pos: M.decode_step(p, cfg, t, c, pos))

    t0 = time.perf_counter()
    logits, cache, pos = prefill(params, batch)
    nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    jax.block_until_ready(nxt)
    t_prefill = time.perf_counter() - t0

    gen = [np.asarray(nxt)[:, 0]]
    t0 = time.perf_counter()
    for i in range(G - 1):
        lg, cache = decode(params, nxt, cache, pos + i)
        nxt = jnp.argmax(lg[:, -1:], axis=-1).astype(jnp.int32)
        gen.append(np.asarray(nxt)[:, 0])
    t_decode = time.perf_counter() - t0
    out = np.stack(gen, axis=1)

    print(f"prefill {B}x{P}: {t_prefill*1e3:.1f} ms   "
          f"decode {G-1} steps: {t_decode*1e3:.1f} ms "
          f"({(G-1)*B/max(t_decode,1e-9):.1f} tok/s)")
    print(f"sample continuation[0]: {out[0][:12].tolist()}")

    # request-pool tendency diagnostic (paper integration)
    emb = np.asarray(params["embed"])[prompts].mean(axis=1)
    rep = core.activation_report(jnp.asarray(emb), jax.random.PRNGKey(1),
                                 sample=min(64, B))
    print(f"request tendency: hopkins={float(rep.hopkins):.3f} "
          f"block={float(rep.block_score):.3f} k={int(rep.k_est)}")


if __name__ == "__main__":
    main()
