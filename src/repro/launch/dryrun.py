import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: 512 host
platform devices stand in for two v5e-256 pods.  For each cell we jit the
real train_step / serve_step against ShapeDtypeStruct inputs with the
production shardings, ``.lower().compile()`` it, and record
``memory_analysis()`` / ``cost_analysis()`` plus the HLO collective mix
into a JSON the roofline analysis consumes.

Usage:
  python -m repro.launch.dryrun --arch gemma-2b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, cells, get_config
from repro.configs.base import TrainConfig
from repro.data.tokens import input_specs
from repro.launch.mesh import make_production_mesh
from repro.launch.shardspecs import (batch_shardings, cache_shardings,
                                     state_shardings)
from repro.models import model as M
from repro.models import sharding
from repro.optim import adamw as O
from repro.train import steps as S

_COLL_RE = re.compile(
    r"(\w+)\[([0-9,]*)\][^=]*\b"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")

_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
          "u8": 1, "pred": 1, "f64": 8, "s64": 8, "f8e4m3fn": 1,
          "f8e5m2": 1, "s16": 2, "u16": 2}


def parse_collectives(hlo: str) -> dict:
    """Sum result-shape bytes of every collective op in (partitioned) HLO.

    Shapes in the partitioned module are per-device; ops inside while
    bodies are counted once per appearance — the roofline multiplies by
    trip counts analytically (see launch/roofline.py).
    """
    out: dict[str, dict] = {}
    for m in _COLL_RE.finditer(hlo):
        dty, dims, kind = m.group(1), m.group(2), m.group(3)
        if dty not in _BYTES:
            continue
        n = _BYTES[dty]
        for d in dims.split(","):
            if d:
                n *= int(d)
        rec = out.setdefault(kind, {"count": 0, "bytes": 0})
        rec["count"] += 1
        rec["bytes"] += n
    return out


# best-known beyond-paper flags per arch (see EXPERIMENTS.md §Perf);
# all exact except route_groups (routing-local variant)
OPTIMIZED = {
    "deepseek-v3-671b": {"ep2d": True, "ce_chunk": 512, "momentum": False,
                         "route_groups": 8, "route_top_groups": 4},
    "whisper-large-v3": {"vocab_pad": 256, "head_pad": 32, "ce_chunk": 512},
    "internvl2-1b": {"vocab_pad": 256, "ce_chunk": 512},
    "*": {"ce_chunk": 512},
}


def optimized_overrides(arch: str, kind: str) -> dict:
    over = dict(OPTIMIZED.get(arch, OPTIMIZED["*"]))
    if kind != "train":  # train-only knobs
        over.pop("ce_chunk", None)
        over.pop("momentum", None)
    return over


def _train_config(cfg, momentum: bool = True) -> TrainConfig:
    # adafactor for the 671B config (factored 2nd moment), adamw otherwise
    opt = "adafactor" if cfg.name.startswith("deepseek") else "adamw"
    return TrainConfig(optimizer=opt, b1=0.9 if momentum else 0.0)


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               overrides: dict | None = None):
    """Build (lowered, mesh, cfg) for one cell — shared with roofline."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    param_dtype = jnp.bfloat16
    over = dict(overrides or {})
    ep2d = over.pop("ep2d", False)
    momentum = over.pop("momentum", True)
    if shape.kind == "train":
        over.setdefault("remat", "full")
        over.setdefault("seq_shard", True)
    else:
        over.setdefault("remat", "none")
        over.setdefault("mtp", False)   # MTP head is train-only
    cfg = cfg.replace(**over)

    mesh = make_production_mesh(multi_pod=multi_pod)
    sharding.set_mesh(mesh)
    sharding.set_ep2d(ep2d)
    specs = input_specs(cfg, shape, dtype=param_dtype)
    b_sh = batch_shardings(cfg, mesh, specs)

    if shape.kind == "train":
        tc = _train_config(cfg, momentum=momentum)
        state_shape = jax.eval_shape(
            lambda: S.init_state(cfg, tc, jax.random.PRNGKey(0), param_dtype))
        st_sh = state_shardings(state_shape, mesh)
        step = S.build_train_step(cfg, tc)
        fn = jax.jit(step, in_shardings=(st_sh, b_sh), donate_argnums=(0,))
        args = (state_shape, specs)
    elif shape.kind == "prefill":
        params_shape = jax.eval_shape(
            lambda: M.init_params(cfg, jax.random.PRNGKey(0), param_dtype))
        p_sh = sharding.param_shardings(params_shape, mesh)
        fn = jax.jit(lambda params, batch: M.forward(params, cfg, batch),
                     in_shardings=(p_sh, b_sh))
        args = (params_shape, specs)
    else:
        B, L = shape.global_batch, shape.seq_len
        params_shape = jax.eval_shape(
            lambda: M.init_params(cfg, jax.random.PRNGKey(0), param_dtype))
        p_sh = sharding.param_shardings(params_shape, mesh)
        cache_shape = jax.eval_shape(
            lambda: M.init_cache(cfg, B, L, param_dtype))
        c_sh = cache_shardings(cfg, mesh, cache_shape, B, L)
        step = S.build_serve_step(cfg)
        tok_sh = b_sh["tokens"]
        pos_sh = NamedSharding(mesh, P())
        fn = jax.jit(step, in_shardings=(p_sh, c_sh, tok_sh, pos_sh),
                     donate_argnums=(1,))
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        args = (params_shape, cache_shape, specs["tokens"], pos)

    with mesh:
        lowered = fn.lower(*args)
    return lowered, mesh, cfg


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             overrides: dict | None = None) -> dict:
    t0 = time.time()
    lowered, mesh, cfg = lower_cell(arch, shape_name, multi_pod=multi_pod,
                                    overrides=overrides)
    t_lower = time.time() - t0
    with mesh:
        compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    colls = parse_collectives(compiled.as_text())
    rec = {
        "arch": arch, "shape": shape_name,
        "overrides": dict(overrides or {}),
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": mesh.devices.size,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops_per_device": cost.get("flops", -1.0),
        "bytes_accessed_per_device": cost.get("bytes accessed", -1.0),
        "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "output_bytes": getattr(mem, "output_size_in_bytes", 0),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
        "collectives": colls,
        "ok": True,
    }
    sharding.set_mesh(None)
    sharding.set_ep2d(False)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) cell for the chosen mesh")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--optimized", action="store_true",
                    help="apply best-known per-arch flags (§Perf)")
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    if args.all:
        todo = [(a, s) for a in ARCHS for s in cells(a)]
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        todo = [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    if args.out and os.path.exists(args.out):
        results = json.load(open(args.out))
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results if r.get("ok")}

    for arch, shape in todo:
        for mp in meshes:
            meshname = "2x16x16" if mp else "16x16"
            if (arch, shape, meshname) in done:
                print(f"[skip] {arch} {shape} {meshname} (cached)")
                continue
            # drop stale failed records for this cell before re-running
            results = [r for r in results
                       if (r["arch"], r["shape"], r["mesh"])
                       != (arch, shape, meshname)]
            print(f"[dryrun] {arch} {shape} {meshname} ...", flush=True)
            over = (optimized_overrides(arch, SHAPES[shape].kind)
                    if args.optimized else None)
            try:
                rec = run_cell(arch, shape, multi_pod=mp, overrides=over)
                print(f"  ok: flops/dev={rec['flops_per_device']:.3e} "
                      f"peak={rec['peak_bytes']/2**30:.2f}GiB "
                      f"lower={rec['lower_s']}s compile={rec['compile_s']}s",
                      flush=True)
            except Exception as e:  # noqa: BLE001 — record and continue
                rec = {"arch": arch, "shape": shape, "mesh": meshname,
                       "ok": False, "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]}
                print(f"  FAIL: {rec['error']}", flush=True)
            results.append(rec)
            if args.out:
                json.dump(results, open(args.out, "w"), indent=1)
    n_ok = sum(r.get("ok", False) for r in results)
    print(f"done: {n_ok}/{len(results)} cells ok")


if __name__ == "__main__":
    main()
