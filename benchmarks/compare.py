"""Diff two BENCH_*.json snapshots — the perf-trajectory regression gate.

Rows are matched by their unique ``name``; for every match the wall-time
delta is reported per table, and the process exits non-zero when any
matched row regressed by more than ``--threshold`` (default 20%).  Rows
present in only one snapshot are listed as added/removed but never fail
the gate (new tables land all the time; the gate is for the rows both
snapshots measured).  ``peak_bytes`` deltas (schema v3) are reported the
same way but are informational only — memory accounting is deterministic
per build, so a real change there shows up in review, not as flake.

Per-table overrides (ISSUE 5 satellite): ``--table-threshold NAME=VAL``
(repeatable) replaces the global gate for one table — looser for tables
whose rows are dominated by loop-dispatch jitter on shared runners
(turbo), tighter where timings are stable.  Rows whose baseline
``us_per_call`` is 0 (the quality tables table2/table3) or that carry
the schema-v4 ``"quality": true`` flag (approx's MST-weight ratio)
never participate in the wall-time gate — they carry accuracy in
``derived``.

CLI:
  PYTHONPATH=src python -m benchmarks.compare BASELINE.json NEW.json
  PYTHONPATH=src python -m benchmarks.compare old.json new.json --threshold 0.5
  PYTHONPATH=src python -m benchmarks.compare old.json new.json \\
      --table-threshold turbo=0.8 --table-threshold ivat=0.3

CI runs this against the committed smoke baseline
(``benchmarks/BENCH_smoke_baseline.json``) after every smoke-bench job —
see .github/workflows/ci.yml.
"""
from __future__ import annotations

import argparse
import sys

from benchmarks.bench_schema import validate_file


def diff(base: dict, new: dict, *, threshold: float = 0.20,
         table_thresholds: dict[str, float] | None = None) -> dict:
    """Compare two validated BENCH documents.

    Args:
      base: the older snapshot (the reference the gate protects).
      new: the fresh snapshot under test.
      threshold: relative wall-time growth that counts as a regression
        (0.20 = new row is >20% slower than baseline).
      table_thresholds: per-table overrides of ``threshold`` keyed by
        table name; tables absent here use the global value.

    Returns:
      {"tables": {table: [row-delta dicts]}, "regressions": [...],
       "added": [names], "removed": [names]} — each row-delta dict has
      name, base_us, new_us, ratio (new/base), the gating threshold,
      and the peak_bytes pair when both sides carry one.
    """
    overrides = table_thresholds or {}
    brows = {r["name"]: r for r in base["rows"]}
    nrows = {r["name"]: r for r in new["rows"]}
    tables: dict[str, list[dict]] = {}
    regressions = []
    for name in (k for k in brows if k in nrows):
        b, n = brows[name], nrows[name]
        # quality rows carry accuracy, not wall time — nothing to gate
        if b["us_per_call"] == 0 or b.get("quality") or n.get("quality"):
            continue
        ratio = n["us_per_call"] / b["us_per_call"]
        thr = overrides.get(b["table"], threshold)
        d = {"name": name, "base_us": b["us_per_call"],
             "new_us": n["us_per_call"], "ratio": ratio, "threshold": thr}
        pb, pn = b.get("peak_bytes"), n.get("peak_bytes")
        if pb is not None and pn is not None:
            d["base_peak_bytes"], d["new_peak_bytes"] = pb, pn
        tables.setdefault(b["table"], []).append(d)
        if ratio > 1.0 + thr:
            regressions.append(d)
    return {"tables": tables, "regressions": regressions,
            "added": sorted(set(nrows) - set(brows)),
            "removed": sorted(set(brows) - set(nrows))}


def _fmt_row(d: dict) -> str:
    pct = (d["ratio"] - 1.0) * 100.0
    flag = "  << REGRESSION" if d["ratio"] > 1.0 + d["threshold"] else ""
    mem = ""
    if "base_peak_bytes" in d:
        mem = f"  peak {d['base_peak_bytes']:>12} -> {d['new_peak_bytes']:>12}B"
    return (f"  {d['name']:48s} {d['base_us']:>12.1f} -> "
            f"{d['new_us']:>12.1f} us  {pct:+7.1f}%{mem}{flag}")


def report(result: dict, *, threshold: float, out=sys.stdout) -> None:
    """Human-readable per-table delta report of a ``diff`` result."""
    for table in sorted(result["tables"]):
        rows = result["tables"][table]
        thr = rows[0]["threshold"] if rows else threshold
        gate = f" (gate {thr:.0%})" if thr != threshold else ""
        print(f"# {table}{gate}", file=out)
        for d in sorted(rows, key=lambda r: r["name"]):
            print(_fmt_row(d), file=out)
    if result["added"]:
        print(f"# rows only in NEW ({len(result['added'])}): "
              + ", ".join(result["added"]), file=out)
    if result["removed"]:
        print(f"# rows only in BASELINE ({len(result['removed'])}): "
              + ", ".join(result["removed"]), file=out)
    n_reg = len(result["regressions"])
    matched = sum(len(v) for v in result["tables"].values())
    verdict = (f"{n_reg} regression(s) past the {threshold:.0%} gate"
               if n_reg else "no regressions")
    print(f"# compared {matched} rows: {verdict}", file=out)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("baseline", help="reference BENCH_*.json")
    p.add_argument("new", help="fresh BENCH_*.json under test")
    p.add_argument("--threshold", type=float, default=0.20,
                   help="relative slowdown that fails the gate "
                        "(default 0.20 = 20%%)")
    p.add_argument("--table-threshold", action="append", default=[],
                   metavar="TABLE=VAL",
                   help="per-table gate override, e.g. turbo=0.8 "
                        "(repeatable; overrides --threshold for that "
                        "table only)")
    a = p.parse_args(argv)

    overrides = {}
    for spec in a.table_threshold:
        table, _, val = spec.partition("=")
        if not table or not val:
            p.error(f"--table-threshold wants TABLE=VAL, got {spec!r}")
        try:
            overrides[table] = float(val)
        except ValueError:
            p.error(f"--table-threshold value must be a float: {spec!r}")

    base = validate_file(a.baseline)
    new = validate_file(a.new)
    result = diff(base, new, threshold=a.threshold,
                  table_thresholds=overrides)
    report(result, threshold=a.threshold)
    return 1 if result["regressions"] else 0


if __name__ == "__main__":
    raise SystemExit(main())
