"""Benchmark harness — one section per paper table.

Prints ``name,us_per_call,derived`` CSV rows (plus human-readable tables
to stderr-ish comments).  Run: PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import vat_tables as T

    print("name,us_per_call,derived")

    # ---- Table 1: execution time + speedup ----
    t1 = T.table1()
    for r in t1:
        tag = " (py scaled)" if r["scaled"] else ""
        print(f"table1/{r['dataset']}/python,{r['python_s']*1e6:.1f},"
              f"baseline{tag}")
        print(f"table1/{r['dataset']}/jax,{r['jax_s']*1e6:.1f},"
              f"speedup={r['speedup_jax']:.1f}x")
        print(f"table1/{r['dataset']}/pallas_interpret,"
              f"{r['pallas_interp_s']*1e6:.1f},correctness-mode")

    # ---- Table 2: Hopkins ----
    for r in T.table2():
        print(f"table2/{r['dataset']}/hopkins,0,{r['hopkins']:.4f}")

    # ---- Table 3: clustering alignment ----
    for r in T.table3():
        print(f"table3/{r['dataset']}/vat,0,"
              f"block_score={r['vat_block_score']:.3f};k_est={r['vat_k_est']}")
        print(f"table3/{r['dataset']}/kmeans,0,ari={r['kmeans_ari']:.3f}")
        print(f"table3/{r['dataset']}/dbscan,0,ari={r['dbscan_ari']:.3f}")

    # ---- Table 4: Big-VAT scaling past the paper's n ~ 1e4 wall ----
    for r in T.table4():
        print(f"table4/n{r['n']}/{r['method']},{r['fit_s']*1e6:.1f},"
              f"pts_per_s={r['points_per_s']:.0f};k_est={r['k_est']}"
              f"/{r['k_true']};hopkins={r['hopkins']:.3f}")


if __name__ == "__main__":
    main()
