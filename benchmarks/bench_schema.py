"""Schema validator for BENCH_*.json perf-trajectory snapshots.

The schema is documented in benchmarks/README.md ("BENCH_*.json
trajectory"); this module is the executable version of that table —
hand-rolled (no jsonschema dependency) so it runs anywhere the repo does.

CLI:      PYTHONPATH=src python -m benchmarks.bench_schema BENCH_x.json
Library:  from benchmarks.bench_schema import validate, validate_file
"""
from __future__ import annotations

import json
import sys

SCHEMA_VERSION = 8
#: Older snapshot versions this validator still accepts (the committed
#: BENCH_*.json trajectory must keep validating as the schema grows).
ACCEPTED_VERSIONS = (2, 3, 4, 5, 6, 7, 8)

_TOP_KEYS = {"schema_version", "created_utc", "host", "config", "rows"}
_HOST_KEYS = {"platform", "python", "jax", "backend", "cpu_count"}
_CONFIG_KEYS = {"smoke", "reps", "tables"}
_ROW_KEYS = {"table", "name", "metric", "us_per_call", "derived"}
# v3 adds per-row peak working-set accounting (null where not profiled)
_ROW_KEYS_V3 = _ROW_KEYS | {"peak_bytes"}
# v4 adds the OPTIONAL per-row ``quality`` flag: true marks a row that
# records accuracy (e.g. approx's MST-weight ratio) rather than wall
# time — compare.py keeps such rows out of the regression gate.
# v5 adds the OPTIONAL per-row ``percentiles`` object — exactly
# {"p50_us", "p99_us"}, numbers >= 0 with p99 >= p50 — for tables
# measured under load (serve), where best-of-reps would hide the tail.
# v6 adds the OPTIONAL per-row ``bytes_per_step`` number >= 0 — the
# serialized growth rate of a continuously-recorded artifact (the
# tendency monitor's history), so storage-cost regressions land on the
# perf record like wall time and peak_bytes do.
# v7 adds NO row fields; it marks snapshots new enough to carry the
# ``faults`` resilience table (admission overhead, batch-split recovery
# latency — ISSUE 9), gated in CI at the looser faults=1.5 threshold.
# v8 likewise adds NO row fields; it marks snapshots that carry the
# ``numerics`` shield table (gram-vs-direct tile cost, the conditioning
# pre-pass, fit-level shield overhead — ISSUE 10), gated in CI at the
# looser numerics=1.5 threshold (host-driven timings).
_PCT_KEYS = {"p50_us", "p99_us"}


def _fail(msg: str):
    raise ValueError(f"BENCH schema violation: {msg}")


def validate(doc: dict) -> dict:
    """Validate a parsed BENCH document; returns it unchanged on success.

    Args:
      doc: the json.load()'d snapshot.

    Returns:
      doc, if every check passes.

    Raises:
      ValueError naming the first violated rule.
    """
    if not isinstance(doc, dict):
        _fail(f"top level must be an object, got {type(doc).__name__}")
    if missing := _TOP_KEYS - doc.keys():
        _fail(f"missing top-level keys {sorted(missing)}")
    if doc["schema_version"] not in ACCEPTED_VERSIONS:
        _fail(f"schema_version must be one of {ACCEPTED_VERSIONS}, "
              f"got {doc['schema_version']!r}")
    version = doc["schema_version"]
    row_keys = _ROW_KEYS_V3 if version >= 3 else _ROW_KEYS
    if not isinstance(doc["created_utc"], str) or "T" not in doc["created_utc"]:
        _fail("created_utc must be an ISO-8601 UTC string")

    host, config, rows = doc["host"], doc["config"], doc["rows"]
    if not isinstance(host, dict) or (m := _HOST_KEYS - host.keys()):
        _fail(f"host must be an object with keys {sorted(_HOST_KEYS)}"
              + (f"; missing {sorted(m)}" if isinstance(host, dict) else ""))
    if not isinstance(config, dict) or (m := _CONFIG_KEYS - config.keys()):
        _fail(f"config must be an object with keys {sorted(_CONFIG_KEYS)}")
    if not isinstance(config["smoke"], bool):
        _fail("config.smoke must be a bool")
    if not isinstance(config["tables"], list):
        _fail("config.tables must be a list of table names")

    if not isinstance(rows, list) or not rows:
        _fail("rows must be a non-empty list")
    for i, row in enumerate(rows):
        where = f"rows[{i}]"
        if not isinstance(row, dict) or (m := row_keys - row.keys()):
            _fail(f"{where} must have keys {sorted(row_keys)}")
        if not isinstance(row["table"], str) or not row["table"]:
            _fail(f"{where}.table must be a non-empty string")
        if not isinstance(row["name"], str) or \
                not row["name"].startswith(row["table"] + "/"):
            _fail(f"{where}.name must start with '{row['table']}/' "
                  f"(got {row['name']!r})")
        us = row["us_per_call"]
        if not isinstance(us, (int, float)) or isinstance(us, bool) or us < 0:
            _fail(f"{where}.us_per_call must be a number >= 0")
        if not isinstance(row["derived"], dict):
            _fail(f"{where}.derived must be an object")
        if not isinstance(row["metric"], str) or not row["metric"]:
            _fail(f"{where}.metric must be a non-empty string (the "
                  "dissimilarity metric the row was measured under)")
        if version >= 3:
            pb = row["peak_bytes"]
            if pb is not None and (not isinstance(pb, (int, float))
                                   or isinstance(pb, bool) or pb < 0):
                _fail(f"{where}.peak_bytes must be a number >= 0 or null")
        if "quality" in row:
            if version < 4:
                _fail(f"{where}.quality needs schema_version >= 4")
            if not isinstance(row["quality"], bool):
                _fail(f"{where}.quality must be a bool when present")
        if "percentiles" in row:
            if version < 5:
                _fail(f"{where}.percentiles needs schema_version >= 5")
            pct = row["percentiles"]
            if not isinstance(pct, dict) or set(pct) != _PCT_KEYS:
                _fail(f"{where}.percentiles must be an object with "
                      f"exactly keys {sorted(_PCT_KEYS)}")
            for k in sorted(_PCT_KEYS):
                v = pct[k]
                if not isinstance(v, (int, float)) or isinstance(v, bool) \
                        or v < 0:
                    _fail(f"{where}.percentiles.{k} must be a number >= 0")
            if pct["p99_us"] < pct["p50_us"]:
                _fail(f"{where}.percentiles: p99_us must be >= p50_us")
        if "bytes_per_step" in row:
            if version < 6:
                _fail(f"{where}.bytes_per_step needs schema_version >= 6")
            bps = row["bytes_per_step"]
            if not isinstance(bps, (int, float)) or isinstance(bps, bool) \
                    or bps < 0:
                _fail(f"{where}.bytes_per_step must be a number >= 0")
    return doc


def validate_file(path: str) -> dict:
    """json.load + validate; returns the document."""
    with open(path) as f:
        return validate(json.load(f))


def main(argv=None) -> int:
    args = (argv if argv is not None else sys.argv[1:])
    if len(args) != 1:
        print("usage: python -m benchmarks.bench_schema BENCH_<stamp>.json",
              file=sys.stderr)
        return 2
    doc = validate_file(args[0])
    print(f"{args[0]}: schema v{doc['schema_version']} OK "
          f"({len(doc['rows'])} rows, tables={doc['config']['tables']})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
