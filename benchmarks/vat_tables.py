"""Paper-table benchmarks.

table1 — execution time + speedup (paper Table 1): pure-Python VAT
         baseline vs the JAX/XLA path vs the Pallas kernel path.
table2 — Hopkins statistic per dataset (paper Table 2).
table3 — clustering alignment: VAT insight vs K-Means vs DBSCAN ARI
         against ground truth (paper Table 3).
table4 — scaling beyond the paper's n ~ 1e4 wall: wall time, throughput,
         and k-estimate accuracy of the FastVAT facade at n = 2e4 .. 1e6
         (auto-selects matrix-free exact flashvat through 5e4, the
         kNN-graph Borůvka ``approx`` rung above it — the only method
         that fits the 1e6 row on one CPU; each row names its method).

Usage and output schema: benchmarks/README.md.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import core
from repro.data.synth import DATASETS, make_dataset

# DBSCAN eps tuned per dataset family (paper tunes per dataset too)
_EPS = {"iris": 0.6, "mall": 10.0, "spotify": 1.6, "blobs": 0.8,
        "moons": 0.12, "circles": 0.12, "gmm": 0.45}
_K = {"iris": 3, "mall": 5, "spotify": 4, "blobs": 3, "moons": 2,
      "circles": 2, "gmm": 3}


def _time(fn, *args, reps: int = 3) -> float:
    fn(*args)  # warmup / compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out) if hasattr(out, "block_until_ready") or \
            isinstance(out, (tuple, jax.Array)) else None
        best = min(best, time.perf_counter() - t0)
    return best


def table1(naive_cap: int = 400, datasets=None, reps: int = 3):
    """Returns rows: dataset, n, t_python, t_jax, t_pallas, speedups.

    The pure-Python baseline on n=1000 takes O(10s) on this container, so
    it is *measured* on min(n, naive_cap) points and linearly^2-scaled to
    n (documented; the paper's own baseline is the same O(n^2 d) loop).
    ``datasets`` restricts the sweep (default: every paper dataset) —
    benchmarks/bench.py --smoke uses this to stay CI-sized.
    """
    from repro.core import naive
    rows = []
    for name in (datasets or DATASETS):
        X, _ = make_dataset(name)
        n = len(X)
        ncap = min(n, naive_cap)
        Xl = X[:ncap].tolist()
        t0 = time.perf_counter()
        naive.vat_naive(Xl)
        t_py = (time.perf_counter() - t0) * (n / ncap) ** 2
        Xj = jnp.asarray(X)
        t_jax = _time(lambda A: core.vat(A).rstar, Xj, reps=reps)
        t_pal = _time(lambda A: core.vat(A, use_pallas=True).rstar, Xj,
                      reps=reps)
        rows.append({
            "dataset": name, "n": n,
            "python_s": t_py, "jax_s": t_jax, "pallas_interp_s": t_pal,
            "speedup_jax": t_py / t_jax,
            "scaled": ncap != n,
        })
    return rows


def table2(datasets=None):
    rows = []
    for name in (datasets or DATASETS):
        X, _ = make_dataset(name)
        h = float(core.hopkins(jnp.asarray(X), jax.random.PRNGKey(0)))
        rows.append({"dataset": name, "hopkins": h})
    return rows


def table4(sizes=(20_000, 50_000, 100_000), k_true: int = 5, reps: int = 1):
    """Scaling wall time + tendency accuracy at paper-breaking n.

    Rows: n, fit_s, points_per_s, k_est, k_true, hopkins, method — each n
    runs the FastVAT facade, which auto-selects flashvat/approx by size.
    ``fit_s`` is best-of-``reps`` (default 1: a fit at n = 1e5 is
    seconds, and run-to-run variance is small next to it).
    """
    from repro.api import FastVAT
    from repro.data.synth import make_big_blobs
    rows = []
    for n in sizes:
        X, _ = make_big_blobs(n=n, k=k_true)
        # warmup run absorbs jit compiles; timed runs sync the result
        # pytree so async dispatch doesn't fake the throughput (cf _time)
        jax.block_until_ready(
            FastVAT(sample_size=256, block=8_192).fit(X).result)
        dt = float("inf")
        for _ in range(max(1, reps)):
            fv = FastVAT(sample_size=256, block=8_192)
            t0 = time.perf_counter()
            fv.fit(X)
            jax.block_until_ready(fv.result)
            dt = min(dt, time.perf_counter() - t0)
        rep = fv.assess()
        rows.append({
            "n": n, "fit_s": dt, "points_per_s": n / dt,
            "k_est": rep["k_est"], "k_true": k_true,
            "hopkins": rep["hopkins"], "method": fv.method_resolved,
        })
    return rows


def table3(datasets=None):
    rows = []
    for name in (datasets or DATASETS):
        X, y = make_dataset(name)
        Xj = jnp.asarray(X)
        res = core.vat(Xj)
        score, k_est = core.block_structure_score(res.rstar)
        km, _, _ = core.kmeans(Xj, jax.random.PRNGKey(0), k=_K[name])
        db = core.dbscan(Xj, eps=_EPS[name], min_pts=5)
        row = {"dataset": name,
               "vat_block_score": float(score), "vat_k_est": int(k_est)}
        if y is not None:
            row["kmeans_ari"] = core.adjusted_rand_index(np.array(km), y)
            row["dbscan_ari"] = core.adjusted_rand_index(np.array(db), y)
        else:
            row["kmeans_ari"] = row["dbscan_ari"] = float("nan")
        rows.append(row)
    return rows
