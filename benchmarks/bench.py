"""Reproducible benchmark harness -> machine-readable BENCH_<stamp>.json.

Where ``benchmarks/run.py`` prints the paper tables as CSV for humans,
this harness snapshots a run as a schema-versioned JSON document (the
repo's perf trajectory — see "BENCH_*.json trajectory" in
benchmarks/README.md), adding two tables the paper doesn't have:

  batched — the batched VAT engine: one compiled ``vat_batch`` /
            ``ivat_batch`` program over a (b, n, d) stack vs a Python
            loop of b solo fits (the serving-many-workloads story).
  ivat    — the fused Pallas iVAT kernel vs the XLA ``at[].set`` path
            (interpret mode on CPU — correctness-grade timing; compiled
            numbers belong on TPU hardware, the ``mode`` field says
            which you are looking at).
  metrics — the metric-dispatched pairwise kernel (ISSUE 3): XLA vs
            Pallas-interpret per metric, so each metric's tile variant
            is on the perf record from day one.
  flash   — materialized exact VAT vs the matrix-free Flash-VAT engine
            (ISSUE 4): wall time AND peak working-set bytes from XLA's
            compiled-program memory accounting, the table that shows the
            O(n^2) -> O(n·d) memory drop buys exact VAT at bigvat sizes.

Every row records the ``metric`` it was measured under and (schema v3)
its ``peak_bytes`` — XLA temp + output allocation of the measured
program, or null where memory was not profiled; tables predating metric
pluggability are euclidean throughout.

Run:
  PYTHONPATH=src python -m benchmarks.bench            # full, ~minutes
  PYTHONPATH=src python -m benchmarks.bench --smoke    # CI-sized, ~1 min
  PYTHONPATH=src python -m benchmarks.bench --tables batched,ivat

Validate a snapshot:
  PYTHONPATH=src python -m benchmarks.bench_schema BENCH_<stamp>.json
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

TABLES = ("table1", "table4", "batched", "ivat", "metrics", "flash")

# (b, n, d) batched workloads; smoke keeps compile + run under CI budgets
_BATCH_WORKLOADS = ((8, 256, 8), (16, 512, 8))
_BATCH_WORKLOADS_SMOKE = ((4, 128, 8),)
_IVAT_SIZES = (512, 1024)
_IVAT_SIZES_SMOKE = (192,)
_METRIC_SHAPE = (1024, 64)
_METRIC_SHAPE_SMOKE = (256, 16)
_FLASH_SIZES = (2_048, 8_192)
# smoke must stay big enough that the streamed seed pass's (br, n) tile
# (br caps at 1024) is a strict subset of the matrix — below ~2k the
# row records no memory win and can't catch a regression
_FLASH_SIZES_SMOKE = (4_096,)


def _time(fn, *args, reps: int = 3) -> float:
    """Best-of-reps wall seconds; warmup call absorbs jit compilation."""
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def _peak_bytes(fn, *args):
    """Peak working set of the compiled program: XLA temp + output bytes.

    Arguments (the inputs the caller already holds, e.g. X itself) are
    excluded — this measures what the *algorithm* allocates, which is
    exactly where materialized VAT's O(n^2) shows up and Flash-VAT's
    doesn't.  Returns None where the backend can't report it.
    """
    try:
        ma = jax.jit(fn).lower(*args).compile().memory_analysis()
        return int(ma.temp_size_in_bytes) + int(ma.output_size_in_bytes)
    except Exception:
        return None


def _row(table: str, name: str, seconds: float, *,
         metric: str = "euclidean", peak_bytes=None, **derived) -> dict:
    return {"table": table, "name": f"{table}/{name}", "metric": metric,
            "us_per_call": seconds * 1e6, "peak_bytes": peak_bytes,
            "derived": derived}


# ------------------------------------------------------------ tables ----

def bench_table1(smoke: bool, reps: int) -> list[dict]:
    from benchmarks import vat_tables as T
    kwargs = {"naive_cap": 150, "datasets": ("iris", "blobs")} if smoke else {}
    rows = []
    for r in T.table1(reps=reps, **kwargs):
        # the python baseline is one measured run by design (it is already
        # seconds long); every jitted row is best-of-`reps`
        rows.append(_row("table1", f"{r['dataset']}/python", r["python_s"],
                         scaled=r["scaled"], n=r["n"], reps=1))
        rows.append(_row("table1", f"{r['dataset']}/jax", r["jax_s"],
                         speedup_vs_python=round(r["speedup_jax"], 2)))
        rows.append(_row("table1", f"{r['dataset']}/pallas_interpret",
                         r["pallas_interp_s"], mode="interpret"))
    return rows


def bench_table4(smoke: bool, reps: int) -> list[dict]:
    from benchmarks import vat_tables as T
    sizes = (20_000,) if smoke else (20_000, 50_000, 100_000)
    rows = []
    for r in T.table4(sizes=sizes, reps=reps):
        rows.append(_row("table4", f"n{r['n']}/{r['method']}", r["fit_s"],
                         points_per_s=round(r["points_per_s"]),
                         k_est=r["k_est"], k_true=r["k_true"],
                         hopkins=round(r["hopkins"], 4)))
    return rows


def bench_batched(smoke: bool, reps: int) -> list[dict]:
    from repro import core
    rows = []
    for b, n, d in (_BATCH_WORKLOADS_SMOKE if smoke else _BATCH_WORKLOADS):
        rng = np.random.default_rng(b * 1000 + n)
        Xb = jnp.asarray(rng.normal(size=(b, n, d)).astype(np.float32))
        tag = f"b{b}xn{n}xd{d}"

        t_batch = _time(lambda A: core.vat_batch(A).rstar, Xb, reps=reps)

        def loop_vat(A):  # b solo programs — what fit_many replaces
            return [core.vat(A[i]).rstar for i in range(A.shape[0])]
        t_loop = _time(loop_vat, Xb, reps=reps)

        rows.append(_row("batched", f"{tag}/vat_batch", t_batch,
                         datasets_per_s=round(b / t_batch, 1),
                         speedup_vs_loop=round(t_loop / t_batch, 2)))
        rows.append(_row("batched", f"{tag}/vat_loop", t_loop,
                         datasets_per_s=round(b / t_loop, 1)))

        t_ib = _time(lambda A: core.ivat_batch(A)[0], Xb, reps=reps)
        rows.append(_row("batched", f"{tag}/ivat_batch", t_ib,
                         datasets_per_s=round(b / t_ib, 1)))
    return rows


def bench_ivat(smoke: bool, reps: int) -> list[dict]:
    from repro import core
    from repro.kernels import ops
    mode = "interpret" if jax.default_backend() == "cpu" else "compiled"
    rows = []
    for n in (_IVAT_SIZES_SMOKE if smoke else _IVAT_SIZES):
        rng = np.random.default_rng(n)
        X = jnp.asarray(rng.normal(size=(n, 4)).astype(np.float32))
        rstar = core.vat(X).rstar

        t_xla = _time(lambda R: ops.ivat_from_vat(R), rstar, reps=reps)
        t_pal = _time(lambda R: ops.ivat_from_vat(R, use_pallas=True),
                      rstar, reps=reps)
        rows.append(_row("ivat", f"n{n}/xla", t_xla, mode="xla"))
        rows.append(_row("ivat", f"n{n}/pallas", t_pal, mode=mode,
                         speedup_vs_xla=round(t_xla / t_pal, 3)))
    return rows


def bench_metrics(smoke: bool, reps: int) -> list[dict]:
    from repro.kernels import ops
    from repro.kernels.ref import METRICS
    mode = "interpret" if jax.default_backend() == "cpu" else "compiled"
    n, d = _METRIC_SHAPE_SMOKE if smoke else _METRIC_SHAPE
    rng = np.random.default_rng(n)
    X = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    rows = []
    for metric in METRICS:
        t_xla = _time(lambda A: ops.pairwise_dist(A, metric=metric),
                      X, reps=reps)
        t_pal = _time(
            lambda A: ops.pairwise_dist(A, metric=metric, use_pallas=True),
            X, reps=reps)
        tag = f"n{n}xd{d}/{metric}"
        rows.append(_row("metrics", f"{tag}/xla", t_xla, metric=metric,
                         mode="xla"))
        rows.append(_row("metrics", f"{tag}/pallas", t_pal, metric=metric,
                         mode=mode,
                         speedup_vs_xla=round(t_xla / t_pal, 3)))
    return rows


def bench_flash(smoke: bool, reps: int) -> list[dict]:
    """Materialized exact VAT vs matrix-free Flash-VAT: time + memory.

    Both columns produce bitwise-identical orderings (pinned in
    tests/test_flashvat.py); the table records what that equivalence
    costs — the matrix-free engine trades MXU-batched O(n^2) matmul
    throughput for an O(n·d) working set, which is the trade that lets
    exact VAT past the materialized rungs' memory wall.
    """
    from repro import core
    rows = []
    for n in (_FLASH_SIZES_SMOKE if smoke else _FLASH_SIZES):
        rng = np.random.default_rng(n)
        X = jnp.asarray(rng.normal(size=(n, 8)).astype(np.float32))

        t_mat = _time(lambda A: core.vat(A).order, X, reps=reps)
        pb_mat = _peak_bytes(lambda A: core.vat(A), X)
        t_mf = _time(lambda A: core.vat_matrix_free(A).order, X, reps=reps)
        pb_mf = _peak_bytes(lambda A: core.vat_matrix_free(A), X)

        rows.append(_row("flash", f"n{n}/materialized", t_mat,
                         peak_bytes=pb_mat, nn_bytes=n * n * 4))
        derived = {"time_vs_materialized": round(t_mf / t_mat, 3)}
        if pb_mat and pb_mf:
            derived["mem_shrink_vs_materialized"] = round(pb_mat / pb_mf, 1)
        rows.append(_row("flash", f"n{n}/matrix_free", t_mf,
                         peak_bytes=pb_mf, **derived))
    return rows


_BENCHES = {"table1": bench_table1, "table4": bench_table4,
            "batched": bench_batched, "ivat": bench_ivat,
            "metrics": bench_metrics, "flash": bench_flash}
assert set(_BENCHES) == set(TABLES)


# ------------------------------------------------------------ driver ----

def run(tables=TABLES, *, smoke: bool = False, reps: int = 3) -> dict:
    """Execute the requested tables; returns the schema-valid document."""
    rows = []
    for t in tables:
        print(f"# bench: {t} ...", file=sys.stderr)
        rows.extend(_BENCHES[t](smoke, reps))
    return {
        "schema_version": 3,
        "created_utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "host": {
            "platform": platform.platform(),
            "python": platform.python_version(),
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "cpu_count": os.cpu_count(),
        },
        "config": {"smoke": smoke, "reps": reps, "tables": list(tables)},
        "rows": rows,
    }


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--smoke", action="store_true",
                   help="CI-sized run: tiny datasets, ~1 minute on CPU")
    p.add_argument("--tables", default=",".join(TABLES),
                   help=f"comma-separated subset of {TABLES}")
    p.add_argument("--reps", type=int, default=3,
                   help="timing repetitions (best-of)")
    p.add_argument("--out", default=None,
                   help="output path (default BENCH_<stamp>.json in cwd)")
    a = p.parse_args(argv)

    tables = tuple(t.strip() for t in a.tables.split(",") if t.strip())
    if unknown := set(tables) - set(TABLES):
        p.error(f"unknown tables {sorted(unknown)}; choose from {TABLES}")

    doc = run(tables, smoke=a.smoke, reps=a.reps)

    from benchmarks.bench_schema import validate
    validate(doc)  # never write an out-of-schema snapshot

    stamp = doc["created_utc"].replace(":", "").replace("-", "")
    out = a.out or f"BENCH_{stamp}.json"
    with open(out, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    print(f"wrote {out} ({len(doc['rows'])} rows)")
    for r in doc["rows"]:
        print(f"  {r['name']:40s} {r['us_per_call']:>14.1f} us  {r['derived']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
