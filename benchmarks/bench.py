"""Reproducible benchmark harness -> machine-readable BENCH_<stamp>.json.

The ONE benchmark entry point (the legacy CSV printer ``benchmarks/
run.py`` was folded in here — ISSUE 5 satellite): every paper table plus
the repo's own engineering tables snapshot into a schema-versioned JSON
document (the perf trajectory — see "BENCH_*.json trajectory" in
benchmarks/README.md):

  batched — the batched VAT engine: one compiled ``vat_batch`` /
            ``ivat_batch`` program over a (b, n, d) stack vs a Python
            loop of b solo fits (the serving-many-workloads story).
  ivat    — the fused Pallas iVAT kernel vs the XLA ``at[].set`` path
            (interpret mode on CPU — correctness-grade timing; compiled
            numbers belong on TPU hardware, the ``mode`` field says
            which you are looking at).
  metrics — the metric-dispatched pairwise kernel (ISSUE 3): XLA vs
            Pallas-interpret per metric, so each metric's tile variant
            is on the perf record from day one.
  flash   — materialized exact VAT vs the matrix-free Flash-VAT engine
            (ISSUE 4): wall time AND peak working-set bytes from XLA's
            compiled-program memory accounting, the table that shows the
            O(n^2) -> O(n·d) memory drop buys exact VAT at bigvat sizes.
  turbo   — the ISSUE 5 headline: the PR-4 stepwise matrix-free engine
            vs the persistent Turbo engine vs the sharded engine on the
            same points — wall time, peak_bytes, and the static dispatch
            census (how many pallas_calls, how many outside any loop)
            of each engine's Pallas variant.
  approx  — the million-point rung (ISSUE 6): the exact matrix-free
            engine vs the kNN-graph Borůvka pipeline on overlap sizes
            where both run — wall time, the kNN kernel's compiled
            working set, and the MST-weight ratio vs exact (a schema-v4
            ``quality`` row: accuracy on record, exempt from the
            wall-time gate).
  serve   — the tendency-as-a-service layer (ISSUE 7): cold-start vs
            warm-cache fit latency through ``TendencyServer`` (the AOT
            program cache's whole point — warm p50 strictly below
            cold), plus p50/p99 and throughput under concurrent
            multi-client load with the coalesce rate and cache hit
            rate on record.  Rows carry the schema-v5 ``percentiles``
            object.
  faults  — the robustness tax (ISSUE 9): warm served fits with input
            admission on vs off (the per-request validation overhead),
            and a poisoned 4-lane coalesced batch recovered through the
            batch-split ladder vs the same batch clean (what graceful
            degradation costs when it actually fires).  Scheduling-
            heavy timings — CI gates this table at the looser 1.5
            threshold.
  monitor — the training-diagnostics subsystem (ISSUE 8): jitted
            train-step wall time with the tendency monitor off vs
            observing every N steps vs every step (the amortized
            overhead story), one warm diag-step latency (the single
            compiled probe-program dispatch), and the history's
            serialized growth rate on the schema-v6 ``bytes_per_step``
            field (a ``quality`` row — storage, not wall time).
  numerics — the numerics shield's price tag (ISSUE 10): Gram-form vs
            direct-form pairwise tiles on the same points (what the
            condition-aware dispatch pays when it switches), the host
            conditioning pre-pass (``numerics.resolve`` — κ statistics
            + transform) on its own, and the end-to-end facade fit
            under ``numerics="fast"`` vs the default ``auto`` on
            ill-conditioned data — the shield tax on record.
  table2/table3 — the paper's Hopkins and clustering-alignment quality
            tables (us_per_call 0 — they record accuracy, not speed).

Every row records the ``metric`` it was measured under and (schema v3)
its ``peak_bytes`` — XLA temp + output allocation of the measured
program, or null where memory was not profiled; tables predating metric
pluggability are euclidean throughout.  Schema v4 adds the optional
per-row ``quality`` flag: true marks rows that carry accuracy, not wall
time, and ``compare.py`` keeps them out of the regression gate.  Schema
v5 adds the optional per-row ``percentiles`` object ({p50_us, p99_us})
for tables measured under load, where best-of-reps would hide the tail.
Schema v6 adds the optional per-row ``bytes_per_step`` number — the
serialized growth rate of a continuously-recorded artifact (the tendency
monitor's history).  Schema v7 adds no row fields; it marks snapshots
that carry the ``faults`` resilience table.  Schema v8 likewise adds no
row fields; it marks snapshots that carry the ``numerics`` shield table.

Run:
  PYTHONPATH=src python -m benchmarks.bench            # full, ~minutes
  PYTHONPATH=src python -m benchmarks.bench --smoke    # CI-sized, ~1 min
  PYTHONPATH=src python -m benchmarks.bench --tables batched,ivat

Validate a snapshot:
  PYTHONPATH=src python -m benchmarks.bench_schema BENCH_<stamp>.json
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

TABLES = ("table1", "table2", "table3", "table4", "batched", "ivat",
          "metrics", "flash", "turbo", "approx", "serve", "monitor",
          "faults", "numerics")

# (b, n, d) batched workloads; smoke keeps compile + run under CI budgets
_BATCH_WORKLOADS = ((8, 256, 8), (16, 512, 8))
_BATCH_WORKLOADS_SMOKE = ((4, 128, 8),)
_IVAT_SIZES = (512, 1024)
_IVAT_SIZES_SMOKE = (192,)
_METRIC_SHAPE = (1024, 64)
_METRIC_SHAPE_SMOKE = (256, 16)
_FLASH_SIZES = (2_048, 8_192)
# smoke must stay big enough that the streamed seed pass's (br, n) tile
# (br caps at 1024) is a strict subset of the matrix — below ~2k the
# row records no memory win and can't catch a regression
_FLASH_SIZES_SMOKE = (4_096,)
_TURBO_SIZES = (8_192,)
_TURBO_SIZES_SMOKE = (2_048,)
# approx-vs-exact overlap sizes: both engines must finish, so the sweep
# tops out where the exact matrix-free engine is still minutes-feasible
_APPROX_SIZES = (20_000, 50_000)
_APPROX_SIZES_SMOKE = (4_096,)
_APPROX_K = 15
# paper datasets the CI-sized table2/table3 keep (mirrors table1 smoke)
_QUALITY_DATASETS_SMOKE = ("iris", "blobs")
# serving-layer load shapes: per-request points, total requests, clients
_SERVE_SIZES = (90, 1024)
_SERVE_SIZES_SMOKE = (48,)
_SERVE_LOAD = (64, 8)
_SERVE_LOAD_SMOKE = (16, 4)
# monitor overhead loop: (seq, batch, steps per measured loop, diag_every)
_MONITOR_SHAPE = (64, 8, 20, 20)
_MONITOR_SHAPE_SMOKE = (32, 4, 8, 4)
# faults table: per-request points for the admission/recovery timings
_FAULTS_SIZES = (90, 512)
_FAULTS_SIZES_SMOKE = (48,)
# numerics table: points for the gram-vs-direct + pre-pass timings
_NUMERICS_SIZES = (2_048, 8_192)
_NUMERICS_SIZES_SMOKE = (512,)


def _time(fn, *args, reps: int = 3) -> float:
    """Best-of-reps wall seconds; warmup call absorbs jit compilation."""
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def _peak_bytes(fn, *args):
    """Peak working set of the compiled program: XLA temp + output bytes.

    Arguments (the inputs the caller already holds, e.g. X itself) are
    excluded — this measures what the *algorithm* allocates, which is
    exactly where materialized VAT's O(n^2) shows up and Flash-VAT's
    doesn't.  Returns None where the backend can't report it.
    """
    try:
        ma = jax.jit(fn).lower(*args).compile().memory_analysis()
        return int(ma.temp_size_in_bytes) + int(ma.output_size_in_bytes)
    except Exception:
        return None


def _row(table: str, name: str, seconds: float, *,
         metric: str = "euclidean", peak_bytes=None, **derived) -> dict:
    return {"table": table, "name": f"{table}/{name}", "metric": metric,
            "us_per_call": seconds * 1e6, "peak_bytes": peak_bytes,
            "derived": derived}


# ------------------------------------------------------------ tables ----

def bench_table1(smoke: bool, reps: int) -> list[dict]:
    from benchmarks import vat_tables as T
    kwargs = {"naive_cap": 150, "datasets": ("iris", "blobs")} if smoke else {}
    rows = []
    for r in T.table1(reps=reps, **kwargs):
        # the python baseline is one measured run by design (it is already
        # seconds long); every jitted row is best-of-`reps`
        rows.append(_row("table1", f"{r['dataset']}/python", r["python_s"],
                         scaled=r["scaled"], n=r["n"], reps=1))
        rows.append(_row("table1", f"{r['dataset']}/jax", r["jax_s"],
                         speedup_vs_python=round(r["speedup_jax"], 2)))
        rows.append(_row("table1", f"{r['dataset']}/pallas_interpret",
                         r["pallas_interp_s"], mode="interpret"))
    return rows


def bench_table2(smoke: bool, reps: int) -> list[dict]:
    from benchmarks import vat_tables as T
    datasets = _QUALITY_DATASETS_SMOKE if smoke else None
    return [_row("table2", f"{r['dataset']}/hopkins", 0.0,
                 hopkins=round(r["hopkins"], 4))
            for r in T.table2(datasets=datasets)]


def bench_table3(smoke: bool, reps: int) -> list[dict]:
    from benchmarks import vat_tables as T
    datasets = _QUALITY_DATASETS_SMOKE if smoke else None
    rows = []
    for r in T.table3(datasets=datasets):
        tag = r["dataset"]
        rows.append(_row("table3", f"{tag}/vat", 0.0,
                         block_score=round(r["vat_block_score"], 3),
                         k_est=r["vat_k_est"]))
        rows.append(_row("table3", f"{tag}/kmeans", 0.0,
                         ari=round(r["kmeans_ari"], 3)))
        rows.append(_row("table3", f"{tag}/dbscan", 0.0,
                         ari=round(r["dbscan_ari"], 3)))
    return rows


def bench_table4(smoke: bool, reps: int) -> list[dict]:
    from benchmarks import vat_tables as T
    # the 1M row is the ISSUE-6 headline: the approx rung is the only
    # method that fits it on one CPU (auto-selected past MEDIUM_N)
    sizes = (20_000,) if smoke else (20_000, 50_000, 100_000, 1_000_000)
    rows = []
    for r in T.table4(sizes=sizes, reps=reps):
        rows.append(_row("table4", f"n{r['n']}/{r['method']}", r["fit_s"],
                         points_per_s=round(r["points_per_s"]),
                         k_est=r["k_est"], k_true=r["k_true"],
                         hopkins=round(r["hopkins"], 4)))
    return rows


def bench_batched(smoke: bool, reps: int) -> list[dict]:
    from repro import core
    rows = []
    for b, n, d in (_BATCH_WORKLOADS_SMOKE if smoke else _BATCH_WORKLOADS):
        rng = np.random.default_rng(b * 1000 + n)
        Xb = jnp.asarray(rng.normal(size=(b, n, d)).astype(np.float32))
        tag = f"b{b}xn{n}xd{d}"

        t_batch = _time(lambda A: core.vat_batch(A).rstar, Xb, reps=reps)

        def loop_vat(A):  # b solo programs — what fit_many replaces
            return [core.vat(A[i]).rstar for i in range(A.shape[0])]
        t_loop = _time(loop_vat, Xb, reps=reps)

        rows.append(_row("batched", f"{tag}/vat_batch", t_batch,
                         datasets_per_s=round(b / t_batch, 1),
                         speedup_vs_loop=round(t_loop / t_batch, 2)))
        rows.append(_row("batched", f"{tag}/vat_loop", t_loop,
                         datasets_per_s=round(b / t_loop, 1)))

        t_ib = _time(lambda A: core.ivat_batch(A)[0], Xb, reps=reps)
        rows.append(_row("batched", f"{tag}/ivat_batch", t_ib,
                         datasets_per_s=round(b / t_ib, 1)))
    return rows


def bench_ivat(smoke: bool, reps: int) -> list[dict]:
    from repro import core
    from repro.kernels import ops
    mode = "interpret" if jax.default_backend() == "cpu" else "compiled"
    rows = []
    for n in (_IVAT_SIZES_SMOKE if smoke else _IVAT_SIZES):
        rng = np.random.default_rng(n)
        X = jnp.asarray(rng.normal(size=(n, 4)).astype(np.float32))
        rstar = core.vat(X).rstar

        t_xla = _time(lambda R: ops.ivat_from_vat(R), rstar, reps=reps)
        t_pal = _time(lambda R: ops.ivat_from_vat(R, use_pallas=True),
                      rstar, reps=reps)
        rows.append(_row("ivat", f"n{n}/xla", t_xla, mode="xla"))
        rows.append(_row("ivat", f"n{n}/pallas", t_pal, mode=mode,
                         speedup_vs_xla=round(t_xla / t_pal, 3)))
    return rows


def bench_metrics(smoke: bool, reps: int) -> list[dict]:
    from repro.kernels import ops
    from repro.kernels.ref import METRICS
    mode = "interpret" if jax.default_backend() == "cpu" else "compiled"
    n, d = _METRIC_SHAPE_SMOKE if smoke else _METRIC_SHAPE
    rng = np.random.default_rng(n)
    X = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    rows = []
    for metric in METRICS:
        t_xla = _time(lambda A: ops.pairwise_dist(A, metric=metric),
                      X, reps=reps)
        t_pal = _time(
            lambda A: ops.pairwise_dist(A, metric=metric, use_pallas=True),
            X, reps=reps)
        tag = f"n{n}xd{d}/{metric}"
        rows.append(_row("metrics", f"{tag}/xla", t_xla, metric=metric,
                         mode="xla"))
        rows.append(_row("metrics", f"{tag}/pallas", t_pal, metric=metric,
                         mode=mode,
                         speedup_vs_xla=round(t_xla / t_pal, 3)))
    return rows


def bench_flash(smoke: bool, reps: int) -> list[dict]:
    """Materialized exact VAT vs matrix-free Flash-VAT: time + memory.

    Both columns produce bitwise-identical orderings (pinned in
    tests/test_flashvat.py); the table records what that equivalence
    costs — the matrix-free engine trades MXU-batched O(n^2) matmul
    throughput for an O(n·d) working set, which is the trade that lets
    exact VAT past the materialized rungs' memory wall.
    """
    from repro import core
    rows = []
    for n in (_FLASH_SIZES_SMOKE if smoke else _FLASH_SIZES):
        rng = np.random.default_rng(n)
        X = jnp.asarray(rng.normal(size=(n, 8)).astype(np.float32))

        t_mat = _time(lambda A: core.vat(A).order, X, reps=reps)
        pb_mat = _peak_bytes(lambda A: core.vat(A), X)
        t_mf = _time(lambda A: core.vat_matrix_free(A).order, X, reps=reps)
        pb_mf = _peak_bytes(lambda A: core.vat_matrix_free(A), X)

        rows.append(_row("flash", f"n{n}/materialized", t_mat,
                         peak_bytes=pb_mat, nn_bytes=n * n * 4))
        derived = {"time_vs_materialized": round(t_mf / t_mat, 3)}
        if pb_mat and pb_mf:
            derived["mem_shrink_vs_materialized"] = round(pb_mat / pb_mf, 1)
        rows.append(_row("flash", f"n{n}/matrix_free", t_mf,
                         peak_bytes=pb_mf, **derived))
    return rows


def bench_turbo(smoke: bool, reps: int) -> list[dict]:
    """Stepwise vs persistent vs sharded matrix-free VAT (ISSUE 5).

    All three engines produce bitwise-identical orderings (pinned in
    tests/test_turbo.py); this table records what the persistent rewrite
    buys: wall time (XLA engines — the honest CPU numbers; compiled
    megakernel timings belong on TPU hardware), peak working-set bytes,
    and the static dispatch census of each engine's Pallas variant —
    the stepwise engine re-enters a pallas_call every Prim step, the
    Turbo engine compiles to ONE loop-free pallas_call.
    """
    from repro import core
    from repro.core.vat import _streamed_seed_pivot
    from repro.kernels import ops as kops
    rows = []
    for n in (_TURBO_SIZES_SMOKE if smoke else _TURBO_SIZES):
        rng = np.random.default_rng(n)
        X = jnp.asarray(rng.normal(size=(n, 8)).astype(np.float32))
        tag = f"n{n}"

        def stepwise(A):
            return core.vat_matrix_free(A, turbo=False).order

        def persistent(A):
            return core.vat_matrix_free(A).order

        # the streamed seed scan is SHARED by both engines (and was
        # itself sped ~2.5x this PR); measuring it separately lets the
        # rows report the traversal-only speedup the engine swap buys
        t_seed = _time(jax.jit(lambda A: _streamed_seed_pivot(
            A, metric="euclidean")), X, reps=reps)

        t_sw = _time(stepwise, X, reps=reps)
        pb_sw = _peak_bytes(stepwise, X)
        d_sw = kops.kernel_dispatch_stats(
            lambda A: core.vat_matrix_free(A, turbo=False,
                                           use_pallas=True), X)
        rows.append(_row("turbo", f"{tag}/stepwise", t_sw, peak_bytes=pb_sw,
                         seed_us=round(t_seed * 1e6, 1),
                         pallas_calls=d_sw["pallas_calls"],
                         persistent_calls=d_sw["persistent"]))

        t_tb = _time(persistent, X, reps=reps)
        pb_tb = _peak_bytes(persistent, X)
        d_tb = kops.kernel_dispatch_stats(
            lambda A: core.vat_matrix_free(A, use_pallas=True), X)
        rows.append(_row("turbo", f"{tag}/persistent", t_tb,
                         peak_bytes=pb_tb,
                         seed_us=round(t_seed * 1e6, 1),
                         pallas_calls=d_tb["pallas_calls"],
                         persistent_calls=d_tb["persistent"],
                         speedup_vs_stepwise=round(t_sw / t_tb, 2),
                         traversal_speedup_vs_stepwise=round(
                             (t_sw - t_seed) / max(t_tb - t_seed, 1e-9),
                             2)))

        if core.HAS_DISTRIBUTED:
            mesh = jax.make_mesh((1,), ("data",))

            def sharded(A):
                return core.vat_matrix_free_sharded(A, mesh).order

            t_sh = _time(sharded, X, reps=reps)
            rows.append(_row("turbo", f"{tag}/sharded_1dev", t_sh,
                             peak_bytes=_peak_bytes(sharded, X),
                             devices=len(jax.devices()),
                             speedup_vs_stepwise=round(t_sw / t_sh, 2)))
    return rows


def bench_approx(smoke: bool, reps: int) -> list[dict]:
    """Exact matrix-free VAT vs the kNN-graph Borůvka rung (ISSUE 6).

    Run on overlap sizes where BOTH engines finish, so every approx row
    carries its ground truth: wall time against the exact engine, the
    kNN kernel's compiled working set against the (n, n) bytes exact
    materialization would need, and the MST-weight ratio (approx / exact
    — 1.0 means the kNN graph contained the true MST).  The ratio row is
    a schema-v4 ``quality`` row: us_per_call 0, exempt from compare.py's
    wall gate, so accuracy regressions surface in review rather than as
    timing flake.
    """
    from repro import core
    from repro.data.synth import make_big_blobs
    from repro.kernels import ops as kops
    k = _APPROX_K
    rows = []
    for n in (_APPROX_SIZES_SMOKE if smoke else _APPROX_SIZES):
        X, _ = make_big_blobs(n=n, k=5)
        Xj = jnp.asarray(X)
        kk = min(k, n - 1)

        exact = core.vat_matrix_free(Xj)                   # warm + reference
        exact_w = float(np.sum(np.asarray(exact.edges), dtype=np.float64))
        t_exact = _time(lambda A: core.vat_matrix_free(A).order, Xj,
                        reps=reps)
        rows.append(_row("approx", f"n{n}/exact_flash", t_exact,
                         peak_bytes=_peak_bytes(
                             lambda A: core.vat_matrix_free(A), Xj)))

        res = core.approx_vat(X, k=kk)                     # warm jit caches
        t_apx = float("inf")
        for _ in range(max(1, reps)):
            t0 = time.perf_counter()
            res = core.approx_vat(X, k=kk)
            t_apx = min(t_apx, time.perf_counter() - t0)
        # the kNN build dominates the pipeline; its compiled working set
        # is the memory story (vs n^2 * 4 bytes for materialization)
        pb = _peak_bytes(lambda A: kops.knn_graph(A, k=kk)[0], Xj)
        rows.append(_row("approx", f"n{n}/knn_boruvka_k{kk}", t_apx,
                         peak_bytes=pb, nn_bytes=n * n * 4,
                         knn_mode=res.stats.mode,
                         speedup_vs_exact=round(t_exact / t_apx, 2)))
        quality = _row("approx", f"n{n}/mst_weight_ratio_k{kk}", 0.0,
                       weight_ratio=round(res.stats.mst_weight / exact_w, 6),
                       components=res.stats.components,
                       repair_weight=round(res.stats.repair_weight, 4))
        quality["quality"] = True
        rows.append(quality)
    return rows


def bench_serve(smoke: bool, reps: int) -> list[dict]:
    """Tendency-as-a-service latencies (ISSUE 7).

    Three rows per request size:

      cold_fit    — first request on a fresh server: trace + XLA
                    compile + dispatch, the cost the AOT cache exists
                    to amortize.
      warm_fit    — p50 of repeated same-bucket fits (``us_per_call``)
                    with the p50/p99 pair on the row's ``percentiles``;
                    must sit strictly below cold_fit (the acceptance
                    pin — tests/test_serve.py holds the same line).
      concurrent  — p50 under multi-client threaded load through the
                    coalescer (window + batching included), with
                    throughput, coalesce rate, and cache hit rate in
                    ``derived``.

    Percentiles rather than best-of-reps: a serving layer is judged by
    its tail, and best-of would hide exactly the scheduling costs
    (window waits, batched neighbors) this table exists to track.
    """
    from concurrent.futures import ThreadPoolExecutor

    from repro.serve import ServeConfig, TendencyServer
    n_requests, clients = _SERVE_LOAD_SMOKE if smoke else _SERVE_LOAD
    warm_reps = max(8, reps * 4)
    rows = []
    for n in (_SERVE_SIZES_SMOKE if smoke else _SERVE_SIZES):
        rng = np.random.default_rng(n)
        datasets = [rng.normal(size=(n, 8)).astype(np.float32)
                    for _ in range(n_requests)]
        tag = f"n{n}"

        config = ServeConfig(window_s=0.002, max_batch=8)
        with TendencyServer(config) as srv:
            t0 = time.perf_counter()
            srv.fit(datasets[0])                 # cold: compile included
            t_cold = time.perf_counter() - t0
            lat = []
            for _ in range(warm_reps):
                t0 = time.perf_counter()
                srv.fit(datasets[0])
                lat.append(time.perf_counter() - t0)
        p50, p99 = (float(np.percentile(lat, q)) for q in (50, 99))
        rows.append(_row("serve", f"{tag}/cold_fit", t_cold,
                         compile_included=True))
        warm = _row("serve", f"{tag}/warm_fit", p50,
                    speedup_vs_cold=round(t_cold / p50, 1))
        warm["percentiles"] = {"p50_us": p50 * 1e6, "p99_us": p99 * 1e6}
        rows.append(warm)

        with TendencyServer(config) as srv:
            for b in (1, 2, 4, 8):               # pre-compile lane buckets
                srv.warm(n, 8, batch=b)

            def one(X):
                t0 = time.perf_counter()
                srv.fit(X)
                return time.perf_counter() - t0

            t_wall = time.perf_counter()
            with ThreadPoolExecutor(max_workers=clients) as pool:
                lat = list(pool.map(one, datasets))
            t_wall = time.perf_counter() - t_wall
            st = srv.stats()
        p50, p99 = (float(np.percentile(lat, q)) for q in (50, 99))
        conc = _row("serve", f"{tag}/concurrent_c{clients}", p50,
                    requests=n_requests, clients=clients,
                    qps=round(n_requests / t_wall, 1),
                    coalesce_rate=round(st.coalesce_rate, 2),
                    cache_hit_rate=round(st.cache.hit_rate, 3))
        conc["percentiles"] = {"p50_us": p50 * 1e6, "p99_us": p99 * 1e6}
        rows.append(conc)
    return rows


def bench_monitor(smoke: bool, reps: int) -> list[dict]:
    """Training overhead of the tendency monitor (ISSUE 8).

    Five rows per shape:

      train_step       — the plain jitted train step, monitor off (the
                         baseline every overhead row is relative to).
      loop_diag_everyN — amortized per-step wall time of a hand-rolled
                         train loop observing every N steps (the
                         default cadence: N=20 full, N=4 smoke).
      loop_diag_every1 — worst case: one probe dispatch per step.
      diag_step        — one warm ``TendencyMonitor.observe`` (the
                         single compiled probe-program dispatch plus its
                         one host sync).
      history_bytes    — ``quality`` row carrying the history's
                         serialized growth rate on the schema-v6
                         ``bytes_per_step`` field.

    The acceptance line the compare gate holds (monitor=1.5): the
    every-N loop must stay within noise of the plain step — diagnostics
    are free at the default cadence or they won't stay on.
    """
    from repro.configs import smoke_config
    from repro.configs.base import ShapeConfig, TrainConfig
    from repro.data.tokens import make_batch
    from repro.monitor import TendencyMonitor
    from repro.train import steps as S

    seq, batch_size, loop_steps, diag_every = (
        _MONITOR_SHAPE_SMOKE if smoke else _MONITOR_SHAPE)
    cfg = smoke_config("gemma-2b")
    shape = ShapeConfig("bench", seq, batch_size, "train")
    tc = TrainConfig(lr=1e-3, total_steps=max(loop_steps, 100))
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, shape).items()}
    state = S.init_state(cfg, tc, jax.random.PRNGKey(0))
    step = jax.jit(S.build_train_step(cfg, tc))
    tag = f"s{seq}xb{batch_size}"

    def loop(diag: int) -> float:
        """Best-of-reps amortized per-step seconds of a loop_steps run."""
        mon = TendencyMonitor(cfg, seed=0)
        st = state
        st, _ = step(st, batch)                  # warm the step program
        if diag:
            mon.observe(0, st.params, batch)     # warm the probe program
        best = float("inf")
        for r in range(reps):
            mon = TendencyMonitor(cfg, seed=0)
            st = state
            t0 = time.perf_counter()
            for i in range(loop_steps):
                st, _ = step(st, batch)
                if diag and (i + 1) % diag == 0:
                    mon.observe(i + 1, st.params, batch)
            jax.block_until_ready(st.params)
            best = min(best, (time.perf_counter() - t0) / loop_steps)
        return best

    t_plain = loop(0)
    rows = [_row("monitor", f"{tag}/train_step", t_plain)]
    t_n = loop(diag_every)
    rows.append(_row("monitor", f"{tag}/loop_diag_every{diag_every}", t_n,
                     overhead_vs_plain=round(t_n / t_plain, 3)))
    t_1 = loop(1)
    rows.append(_row("monitor", f"{tag}/loop_diag_every1", t_1,
                     overhead_vs_plain=round(t_1 / t_plain, 3)))

    mon = TendencyMonitor(cfg, seed=0)
    mon.observe(0, state.params, batch)          # warm
    best = float("inf")
    for r in range(reps):
        t0 = time.perf_counter()
        mon.observe(r + 1, state.params, batch)  # observe() host-syncs
        best = min(best, time.perf_counter() - t0)
    rows.append(_row("monitor", f"{tag}/diag_step", best,
                     probes=len(mon.specs)))

    hist = _row("monitor", f"{tag}/history_bytes", 0.0,
                probes=len(mon.specs))
    hist["quality"] = True
    hist["bytes_per_step"] = mon.history.nbytes_per_step()
    rows.append(hist)
    return rows


def bench_faults(smoke: bool, reps: int) -> list[dict]:
    """The robustness tax (ISSUE 9): admission overhead + split recovery.

    Four rows per request size:

      warm_fit_unvalidated — p50 warm served fit, admission checks off
                             (the PR-8 warm path, the baseline).
      warm_fit_validated   — the same fit with the O(n·d) admission
                             pass on (the default); ``derived``
                             carries the overhead ratio — the pin is
                             "validation is noise on a warm fit".
      batch_clean_4lane    — wall time for a 4-lane coalesced batch,
                             submit-to-all-resolved, nothing armed.
      batch_split_recovery — the same 4-lane batch with one lane
                             poisoned via the ``serve.execute`` fault
                             site: the ladder retries, splits, serves
                             the three survivors solo, and fails the
                             poison typed.  ``derived`` carries the
                             recovery-vs-clean ratio (bounded retry
                             backoff included — that IS the recovery
                             latency).
    """
    from concurrent.futures import wait

    from repro import faults as F
    from repro.serve import ServeConfig, TendencyServer
    warm_reps = max(8, reps * 4)
    rows = []
    for n in (_FAULTS_SIZES_SMOKE if smoke else _FAULTS_SIZES):
        rng = np.random.default_rng(n)
        tag = f"n{n}"
        X = rng.normal(size=(n, 8)).astype(np.float32)

        p50s = {}
        for validate in (False, True):
            config = ServeConfig(window_s=0.002, max_batch=8,
                                 validate=validate)
            with TendencyServer(config) as srv:
                srv.fit(X)                       # cold compile absorbed
                lat = []
                for _ in range(warm_reps):
                    t0 = time.perf_counter()
                    srv.fit(X)
                    lat.append(time.perf_counter() - t0)
            p50s[validate] = float(np.percentile(lat, 50))
        rows.append(_row("faults", f"{tag}/warm_fit_unvalidated",
                         p50s[False]))
        rows.append(_row("faults", f"{tag}/warm_fit_validated", p50s[True],
                         validation_overhead=round(
                             p50s[True] / p50s[False], 3)))

        datasets = [rng.normal(size=(n, 8)).astype(np.float32)
                    for _ in range(4)]
        config = ServeConfig(window_s=0.2, max_batch=4)
        with TendencyServer(config) as srv:
            srv.warm(n, 8, batch=4)              # the coalesced program
            srv.warm(n, 8, batch=1)              # the split-lane program

            def batch_once() -> float:
                t0 = time.perf_counter()
                futs = [srv.submit(Xi, tag=f"lane{i}")
                        for i, Xi in enumerate(datasets)]
                wait(futs, timeout=300)
                return time.perf_counter() - t0

            t_clean = min(batch_once() for _ in range(reps))
            F.arm("serve.execute", times=-1,
                  match=lambda ctx: "lane0" in ctx.get("tags", ()))
            try:
                t_recover = min(batch_once() for _ in range(reps))
            finally:
                F.disarm_all()
        rows.append(_row("faults", f"{tag}/batch_clean_4lane", t_clean,
                         lanes=4))
        rows.append(_row("faults", f"{tag}/batch_split_recovery", t_recover,
                         lanes=4, survivors=3,
                         recovery_vs_clean=round(t_recover / t_clean, 2)))
    return rows


def bench_numerics(smoke: bool, reps: int) -> list[dict]:
    """The numerics shield's price tag (ISSUE 10).

    Seven rows per size, all measured on ill-conditioned points (a 1e4
    common offset — the canonical Gram catastrophe the shield exists
    for):

      pairwise_gram    — the Gram-decomposition tile (the pre-shield
                         fast path, what ``fast``/unconditioned ``auto``
                         runs).
      pairwise_direct  — the cancellation-free (x−y)² tile the auto
                         policy switches to past KAPPA_SAFE; ``derived``
                         carries the cost ratio the dispatch trades for
                         its certified bound.
      prepass_resolve  — the host-side conditioning pre-pass on its own
                         (κ statistics + mean-center/rescale transform),
                         the fixed per-fit tax every policy but ``fast``
                         pays; κ and the decision are in ``derived``.
      kappa            — schema-v4 ``quality`` row (us_per_call 0,
                         exempt from the wall-time gate) putting the
                         measured condition estimate and its
                         post-conditioning value on the perf record.
      fit_fast         — end-to-end ``FastVAT(numerics="fast")`` warm
                         fit: the pre-shield baseline.
      fit_safe         — the always-condition policy: the shield's
                         worst-case price (``derived.cost_vs_fast``).
      fit_auto         — the default policy (here: pre-pass +
                         conditioned direct-form tiles, since the data
                         is hostile); ``derived.shield_overhead`` is
                         the headline — certified orderings on hostile
                         data cost percents, not multiples.
    """
    from repro.api import FastVAT
    from repro.kernels import ops as kops
    from repro.numerics import resolve
    rows = []
    for n in (_NUMERICS_SIZES_SMOKE if smoke else _NUMERICS_SIZES):
        rng = np.random.default_rng(n)
        half = n // 2
        X = np.concatenate([
            rng.normal(size=(half, 8)),
            rng.normal(size=(n - half, 8)) + 6.0]).astype(np.float32)
        X += np.float32(1.0e4)
        Xj = jnp.asarray(X)
        tag = f"n{n}"

        t_gram = _time(lambda A: kops.pairwise_dist(A, form="gram"),
                       Xj, reps=reps)
        t_dir = _time(lambda A: kops.pairwise_dist(A, form="direct"),
                      Xj, reps=reps)
        rows.append(_row("numerics", f"{tag}/pairwise_gram", t_gram,
                         peak_bytes=_peak_bytes(
                             lambda A: kops.pairwise_dist(A, form="gram"),
                             Xj)))
        rows.append(_row("numerics", f"{tag}/pairwise_direct", t_dir,
                         peak_bytes=_peak_bytes(
                             lambda A: kops.pairwise_dist(A, form="direct"),
                             Xj),
                         cost_vs_gram=round(t_dir / t_gram, 3)))

        best = float("inf")
        rep = None
        for _ in range(max(1, reps)):
            t0 = time.perf_counter()
            _, rep = resolve(X, metric="euclidean")
            best = min(best, time.perf_counter() - t0)
        rows.append(_row("numerics", f"{tag}/prepass_resolve", best,
                         form=rep.form, conditioned=rep.conditioned))
        from repro.numerics import condition_stats
        stats = condition_stats(X)
        quality = _row("numerics", f"{tag}/kappa", 0.0,
                       kappa=round(stats.kappa, 1),
                       kappa_centered=round(stats.kappa_centered, 3))
        quality["quality"] = True
        rows.append(quality)

        t_fit = {}
        for mode in ("fast", "safe", "auto"):
            fv = FastVAT(numerics=mode)
            fv.fit(X)                            # warm the program cache
            t_best = float("inf")
            for _ in range(max(1, reps)):
                t0 = time.perf_counter()
                FastVAT(numerics=mode).fit(X)
                t_best = min(t_best, time.perf_counter() - t0)
            t_fit[mode] = t_best
        rows.append(_row("numerics", f"{tag}/fit_fast", t_fit["fast"]))
        rows.append(_row("numerics", f"{tag}/fit_safe", t_fit["safe"],
                         cost_vs_fast=round(
                             t_fit["safe"] / t_fit["fast"], 3)))
        rows.append(_row("numerics", f"{tag}/fit_auto", t_fit["auto"],
                         shield_overhead=round(
                             t_fit["auto"] / t_fit["fast"], 3)))
    return rows


_BENCHES = {"table1": bench_table1, "table2": bench_table2,
            "table3": bench_table3, "table4": bench_table4,
            "batched": bench_batched, "ivat": bench_ivat,
            "metrics": bench_metrics, "flash": bench_flash,
            "turbo": bench_turbo, "approx": bench_approx,
            "serve": bench_serve, "monitor": bench_monitor,
            "faults": bench_faults, "numerics": bench_numerics}
assert set(_BENCHES) == set(TABLES)


# ------------------------------------------------------------ driver ----

def run(tables=TABLES, *, smoke: bool = False, reps: int = 3) -> dict:
    """Execute the requested tables; returns the schema-valid document."""
    rows = []
    for t in tables:
        print(f"# bench: {t} ...", file=sys.stderr)
        rows.extend(_BENCHES[t](smoke, reps))
    return {
        "schema_version": 8,
        "created_utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "host": {
            "platform": platform.platform(),
            "python": platform.python_version(),
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "cpu_count": os.cpu_count(),
        },
        "config": {"smoke": smoke, "reps": reps, "tables": list(tables)},
        "rows": rows,
    }


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--smoke", action="store_true",
                   help="CI-sized run: tiny datasets, ~1 minute on CPU")
    p.add_argument("--tables", default=",".join(TABLES),
                   help=f"comma-separated subset of {TABLES}")
    p.add_argument("--reps", type=int, default=3,
                   help="timing repetitions (best-of)")
    p.add_argument("--out", default=None,
                   help="output path (default BENCH_<stamp>.json in cwd)")
    a = p.parse_args(argv)

    tables = tuple(t.strip() for t in a.tables.split(",") if t.strip())
    if unknown := set(tables) - set(TABLES):
        p.error(f"unknown tables {sorted(unknown)}; choose from {TABLES}")

    doc = run(tables, smoke=a.smoke, reps=a.reps)

    from benchmarks.bench_schema import validate
    validate(doc)  # never write an out-of-schema snapshot

    stamp = doc["created_utc"].replace(":", "").replace("-", "")
    out = a.out or f"BENCH_{stamp}.json"
    with open(out, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    print(f"wrote {out} ({len(doc['rows'])} rows)")
    for r in doc["rows"]:
        print(f"  {r['name']:40s} {r['us_per_call']:>14.1f} us  {r['derived']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
